"""Sharded-ensemble correctness: run in a SUBPROCESS with 8 virtual devices
(XLA_FLAGS must not leak into other tests, which expect 1 device)."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
jax.config.update("jax_enable_x64", True)

from repro.core import (
    DT, broadcast_params, default_params, initial_magnetization,
    integrate_ensemble, integrate_ensemble_sharded, make_coupling_matrix,
    norm_error,
)

assert len(jax.devices()) == 8
mesh = jax.make_mesh((4, 2), ("data", "model"))

n, e = 16, 8
p = default_params(jnp.float64)
pe = broadcast_params(p, e, current=jnp.linspace(1e-3, 4e-3, e))
w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float64)
m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float64), (e, n, 3))

ref, _ = integrate_ensemble(pe, w, m0, DT, 50)
out = integrate_ensemble_sharded(mesh, pe, w, m0, DT, 50,
                                 ensemble_axes=("data",), model_axis="model")
err = float(jnp.max(jnp.abs(out - ref)))
cons = float(norm_error(out))

# model_axis=None variant (pure ensemble parallelism)
mesh1 = jax.make_mesh((8,), ("data",))
out2 = integrate_ensemble_sharded(mesh1, pe, w, m0, DT, 50,
                                  ensemble_axes=("data",), model_axis=None)
err2 = float(jnp.max(jnp.abs(out2 - ref)))

# bf16 coupling-path variant (SS Perf C): wire/matmul in bf16, state f32.
# The coupling is a ~1 Oe perturbation against ~600 Oe local fields, so the
# trajectory deviation stays small over short horizons and |m|=1 holds.
out3 = integrate_ensemble_sharded(mesh, pe, w, m0,
                                  DT, 50, ensemble_axes=("data",),
                                  model_axis="model",
                                  gather_dtype=jnp.bfloat16)
err3 = float(jnp.max(jnp.abs(out3.astype(jnp.float64) - ref)))
cons3 = float(norm_error(out3))

# sharded DRIVE (input on) vs the single-reservoir drive, member by member
from repro.core.ensemble import drive_ensemble_sharded, fit_ridge_ensemble
from repro.core.reservoir import Reservoir, drive as drive_single
from repro.core import make_input_matrix
from repro.core import tasks

p300 = p._replace(a_in=jnp.float64(300.0))
pe2 = broadcast_params(p300, 4, current=jnp.linspace(2e-3, 3e-3, 4))
win = jnp.asarray(make_input_matrix(n, 1, seed=1), jnp.float64)
m0d = m0[:4]
u, y = tasks.narma_series(30, order=2, seed=0)
mT, states = drive_ensemble_sharded(
    mesh, pe2, w, win, m0d, jnp.asarray(u[:, None]), DT, 10)
errs = []
for i in range(4):
    pi = p300._replace(current=jnp.float64(float(pe2.current[i, 0])))
    res = Reservoir(pi, w, win, m0d[i], float(DT), 10)
    _, st = drive_single(res, jnp.asarray(u[:, None]))
    errs.append(float(jnp.max(jnp.abs(st - states[:, i]))))
wout = fit_ridge_ensemble(states, jnp.asarray(y[:, None]), reg=1e-6, washout=5)

print(json.dumps({"err": err, "cons": cons, "err2": err2,
                  "err3": err3, "cons3": cons3,
                  "drive_err": max(errs),
                  "readout_shape": list(wout.shape)}))
"""


@pytest.mark.slow
def test_sharded_matches_batched():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # sharded (all-gather per stage) == batched, up to f64 reduction order
    assert res["err"] < 1e-10
    assert res["err2"] < 1e-12
    assert res["cons"] < 1e-7
    # bf16 coupling path: bounded deviation, conservation intact
    assert res["err3"] < 5e-2
    assert res["cons3"] < 1e-4
    # sharded drive (input on) matches the single-reservoir reference
    assert res["drive_err"] < 1e-9
    assert res["readout_shape"] == [4, 17, 1]
