"""Core physics tests: LLG field, conservation law, integrator orders,
coupling construction. Mirrors the paper's own correctness criteria (§3.2):
identical solutions across implementations + |m_k| = 1 conservation.

Property-based (hypothesis) variants live in tests/test_property_based.py so
this module collects on a clean checkout without dev extras."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import (
    DT,
    EULER,
    HEUN,
    RK4,
    broadcast_params,
    convergence_order,
    coupling_field_x,
    default_params,
    initial_magnetization,
    integrate_ensemble,
    integrate_python_loop,
    integrate_scan,
    llg_field,
    make_coupling_matrix,
    make_input_matrix,
    norm_error,
    spectral_radius,
)


def _field(params, w):
    return lambda m, _: llg_field(m, params, w)


class TestParameters:
    def test_derived_constants_match_paper_scales(self):
        p = default_params(jnp.float64)
        # H_s ~ 135 Oe with Table-1 values (comparable to H_appl = 200 Oe).
        assert 120.0 < float(p.hs_coef) < 150.0
        # Hk - 4 pi Ms ~ 416 Oe.
        assert 400.0 < float(p.demag_field) < 430.0
        assert np.isclose(float(p.llg_prefactor), 1.764e7 / (1 + 0.005**2))

    def test_initial_state_unit_norm(self):
        m0 = initial_magnetization(17, jnp.float64)
        assert m0.shape == (17, 3)
        np.testing.assert_allclose(np.linalg.norm(m0, axis=-1), 1.0, rtol=1e-12)
        # m(0) ~ (0, 0, 1) per the paper.
        assert float(m0[0, 2]) > 0.99


class TestCoupling:
    @pytest.mark.parametrize("n", [2, 8, 64, 300])
    def test_spectral_radius_one(self, n):
        w = make_coupling_matrix(n, seed=3)
        rho = np.max(np.abs(np.linalg.eigvals(w.astype(np.float64))))
        np.testing.assert_allclose(rho, 1.0, rtol=1e-4)

    @pytest.mark.parametrize("n", [2, 33])
    def test_no_self_coupling(self, n):
        w = make_coupling_matrix(n, seed=0)
        np.testing.assert_array_equal(np.diag(w), 0.0)

    def test_large_n_circular_law_estimate(self):
        # Beyond the exact-eig cutoff the estimate should still land near 1.
        w = make_coupling_matrix(3000, seed=0)
        # exact check on the generated matrix (slow but feasible once)
        rho = np.max(np.abs(np.linalg.eigvals(w.astype(np.float64))))
        assert 0.8 < rho < 1.25

    def test_coupling_field_is_matmul(self):
        n, e = 16, 5
        w = jnp.asarray(make_coupling_matrix(n, seed=1), jnp.float64)
        mx = jnp.asarray(np.random.default_rng(0).standard_normal((e, n)))
        out = coupling_field_x(w, mx, 2.5)
        np.testing.assert_allclose(
            np.asarray(out), 2.5 * np.asarray(mx) @ np.asarray(w).T, rtol=1e-12
        )

    def test_input_matrix_range(self):
        w = make_input_matrix(100, 3, seed=2)
        assert w.shape == (100, 3)
        assert np.all(np.abs(w) <= 1.0)


class TestConservation:
    @pytest.mark.parametrize("n", [1, 4, 32])
    def test_norm_conserved_rk4(self, n):
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float64)
        m0 = initial_magnetization(n, jnp.float64)
        mT, _ = integrate_scan(_field(p, w), m0, DT, 2000)
        assert float(norm_error(mT)) < 5e-6

    def test_norm_conserved_f32(self):
        # The TPU default dtype: drift stays well below node-signal scale.
        p = default_params(jnp.float32)
        w = jnp.asarray(make_coupling_matrix(8, seed=0), jnp.float32)
        m0 = initial_magnetization(8, jnp.float32)
        mT, _ = integrate_scan(_field(p, w), m0, DT, 2000)
        assert float(norm_error(mT)) < 5e-4


class TestIntegrators:
    def test_rk4_order(self):
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(6, seed=0), jnp.float64)
        m0 = initial_magnetization(6, jnp.float64)
        order = convergence_order(
            _field(p, w), m0, 400 * float(DT), tableau=RK4, base_steps=64
        )
        assert order > 3.5

    def test_heun_order(self):
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(6, seed=0), jnp.float64)
        m0 = initial_magnetization(6, jnp.float64)
        order = convergence_order(
            _field(p, w), m0, 400 * float(DT), tableau=HEUN, base_steps=64
        )
        assert 1.5 < order < 3.0

    def test_python_loop_matches_scan(self):
        """Paper §3.2: implementations must agree on the solution."""
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(5, seed=0), jnp.float64)
        m0 = initial_magnetization(5, jnp.float64)
        a, _ = integrate_scan(_field(p, w), m0, DT, 50)
        b = integrate_python_loop(_field(p, w), m0, DT, 50)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-12, atol=1e-14)

    def test_save_every_trajectory(self):
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(3, seed=0), jnp.float64)
        m0 = initial_magnetization(3, jnp.float64)
        mT, ys = integrate_scan(_field(p, w), m0, DT, 100, save_every=25)
        assert ys.shape == (4, 3, 3)
        np.testing.assert_allclose(np.asarray(ys[-1]), np.asarray(mT))

    def test_uncoupled_is_o_n(self):
        """w_cp=None path (paper: coupling off -> O(N) field)."""
        p = default_params(jnp.float64)
        m0 = initial_magnetization(4, jnp.float64)
        f = lambda m, _: llg_field(m, p, None)
        mT, _ = integrate_scan(f, m0, DT, 100)
        # all oscillators identical (same init, no coupling)
        np.testing.assert_allclose(
            np.asarray(mT),
            np.broadcast_to(np.asarray(mT)[0:1], mT.shape),
            rtol=1e-12,
        )


class TestAdaptive:
    def _setup(self):
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(6, seed=0), jnp.float64)
        m0 = initial_magnetization(6, jnp.float64)
        return p, w, m0

    def test_matches_fixed_rk4(self):
        from repro.core import integrate_adaptive

        p, w, m0 = self._setup()
        t_end = 500 * float(DT)
        ref, _ = integrate_scan(_field(p, w), m0, DT, 500)
        y, stats = integrate_adaptive(_field(p, w), m0, t_end, rtol=1e-7, atol=1e-11)
        assert float(jnp.max(jnp.abs(y - ref))) < 1e-3
        assert float(norm_error(y)) < 1e-6
        assert int(stats["rejected"]) < int(stats["steps"])

    def test_tighter_tolerance_more_steps(self):
        from repro.core import integrate_adaptive

        p, w, m0 = self._setup()
        t_end = 200 * float(DT)
        _, loose = integrate_adaptive(_field(p, w), m0, t_end, rtol=1e-4, atol=1e-8)
        _, tight = integrate_adaptive(_field(p, w), m0, t_end, rtol=1e-8, atol=1e-12)
        assert int(tight["steps"]) > int(loose["steps"])

    def test_reaches_t_end(self):
        from repro.core import integrate_adaptive

        p, w, m0 = self._setup()
        t_end = 100 * float(DT)
        _, stats = integrate_adaptive(_field(p, w), m0, t_end, rtol=1e-6)
        np.testing.assert_allclose(float(stats["t"]), t_end, rtol=1e-9)


class TestEnsemble:
    def test_ensemble_matches_single(self):
        """E identical parameter sets -> E identical trajectories, each equal
        to the single-reservoir run (batching does not change the math)."""
        p64 = default_params(jnp.float64)
        n, e = 6, 3
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float64)
        m0 = initial_magnetization(n, jnp.float64)
        single, _ = integrate_scan(_field(p64, w), m0, DT, 64)

        pe = broadcast_params(p64, e)
        m0e = jnp.broadcast_to(m0, (e, n, 3))
        batched, _ = integrate_ensemble(pe, w, m0e, DT, 64)
        for i in range(e):
            np.testing.assert_allclose(
                np.asarray(batched[i]), np.asarray(single), rtol=1e-12
            )

    def test_ensemble_sweep_changes_dynamics(self):
        p64 = default_params(jnp.float64)
        n, e = 4, 3
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float64)
        m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float64), (e, n, 3))
        pe = broadcast_params(p64, e, current=jnp.array([1e-3, 2.5e-3, 4e-3]))
        out, _ = integrate_ensemble(pe, w, m0, DT, 200)
        # different currents -> different trajectories
        assert not np.allclose(np.asarray(out[0]), np.asarray(out[1]))
        assert not np.allclose(np.asarray(out[1]), np.asarray(out[2]))
        assert float(norm_error(out)) < 1e-6

    def test_broadcast_params_rejects_unknown(self):
        with pytest.raises(ValueError):
            broadcast_params(default_params(), 2, bogus=jnp.zeros(2))
