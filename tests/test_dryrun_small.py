"""Dry-run path coverage on a SMALL virtual mesh (subprocess, 8 devices):
lower+compile a full-size train cell and a decode cell through the same
lower_cell() the production sweep uses, asserting cost/memory/collective
artifacts come back populated."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
from repro.launch.dryrun import lower_cell

recs = {}
# full-size whisper-base (smallest arch) through the real train path
recs["train"] = lower_cell(
    "whisper-base", "train_4k", multi_pod=False,
    mesh_override=((4, 2), ("data", "model")),
)
# full-size xlstm decode (recurrent-state serve path)
recs["decode"] = lower_cell(
    "xlstm-125m", "decode_32k", multi_pod=False,
    mesh_override=((4, 2), ("data", "model")),
)
print("JSON:" + json.dumps(
    {k: {kk: v.get(kk) for kk in
         ("hlo_flops", "temp_size_in_bytes", "argument_size_in_bytes")}
     | {"coll": sum(c["bytes"] for c in v["collectives"].values())}
     for k, v in recs.items()}
))
"""


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1800,
    )
    assert res.returncode == 0, res.stderr[-4000:]
    line = [l for l in res.stdout.splitlines() if l.startswith("JSON:")][-1]
    out = json.loads(line[5:])
    for kind in ("train", "decode"):
        assert out[kind]["hlo_flops"] and out[kind]["hlo_flops"] > 0
        assert out[kind]["argument_size_in_bytes"] > 0
    # whisper train on a (4,2) TP mesh must communicate
    assert out["train"]["coll"] > 0
