"""Streaming reservoir engine: correctness contract + scheduling.

The engine's contract (mirroring test_serve_engine for the LLM engine):
every session's streamed states / readout outputs are element-wise close —
same dtype/tolerance family as tests/test_kernels_sto.py — to running that
stream alone through reservoir.drive + predict, including sessions admitted
and retired mid-run while the batch keeps advancing.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    coerce_input_series,
    drive,
    fit_ridge,
    make_reservoir,
    predict,
)
from repro.kernels import ops
from repro.serve.reservoir import ReservoirEngine, SessionResult, StreamSession
from repro.serve.scheduler import SlotScheduler

ATOL = 5e-5  # tests/test_kernels_sto.py's f32 tolerance


def _sessions(res, count, rng, lengths=(8, 11, 14), with_readout=True, reg=1e-3):
    """Build sessions + solo references (drive + predict per stream)."""
    sessions, refs = [], {}
    for sid in range(count):
        t = lengths[sid % len(lengths)]
        u = rng.uniform(0.0, 0.5, size=(t, 1)).astype(np.float32)
        _, states = drive(res, jnp.asarray(u))
        ro = None
        pred = None
        if with_readout:
            ro = fit_ridge(states, jnp.asarray(u[:, 0]), washout=2, reg=reg)
            pred = predict(ro, states)
        sessions.append(StreamSession(sid=sid, u_seq=u, readout=ro))
        refs[sid] = (states, pred)
    return sessions, refs


def _assert_matches(results, refs, atol=ATOL):
    assert set(results) == set(refs)
    for sid, r in results.items():
        s_ref, p_ref = refs[sid]
        np.testing.assert_allclose(
            np.asarray(r.states), np.asarray(s_ref), atol=atol,
            err_msg=f"states mismatch for session {sid}",
        )
        if p_ref is not None:
            np.testing.assert_allclose(
                np.asarray(r.outputs), np.asarray(p_ref), atol=atol,
                err_msg=f"outputs mismatch for session {sid}",
            )


class TestEngineMatchesSolo:
    @pytest.mark.parametrize("backend", ["scan", "ref"])
    def test_streams_match_solo_drive_and_predict(self, backend):
        """Slot-batched execution must not change any tenant's math — on the
        core-layout exact-parity backend AND the planes-layout serving
        default."""
        res = make_reservoir(n=16, n_in=1, hold_steps=20, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=4, backend=backend)
        sessions, refs = _sessions(res, 10, np.random.default_rng(0))
        results = eng.run(sessions)
        _assert_matches(results, refs)

    def test_mid_run_admit_and_retire(self):
        """More sessions than slots: later sessions are admitted into slots
        freed mid-run, and still match their solo references."""
        res = make_reservoir(n=12, n_in=1, hold_steps=10, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=3, backend="scan")
        sessions, refs = _sessions(res, 9, np.random.default_rng(1), lengths=(5, 9, 13))
        results = eng.run(sessions)
        _assert_matches(results, refs)
        admits = sorted(r.admitted_tick for r in results.values())
        assert admits[0] == 0 and admits[-1] > 0  # mid-run admissions happened
        assert eng.scheduler.stats.retired == 9

    def test_64_concurrent_sessions(self):
        """Acceptance floor: >= 64 concurrent sessions with slot turnover."""
        res = make_reservoir(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=64, backend="auto")
        sessions, refs = _sessions(
            res, 80, np.random.default_rng(2), lengths=(4, 6, 8)
        )
        results = eng.run(sessions)
        assert len(results) == 80
        assert max(len(eng.store.free_slots()), 0) == 64  # all drained
        # full batch was actually concurrent at some point
        assert eng.scheduler.stats.session_ticks > 64
        _assert_matches(results, refs)

    def test_per_tenant_params_lanes(self):
        """Tenants with different physics share a batch but keep their own
        dynamics: each matches a solo reservoir with that tenant's params."""
        res = make_reservoir(n=8, n_in=1, hold_steps=10, dtype=jnp.float32)
        rng = np.random.default_rng(3)
        u = rng.uniform(0.0, 0.5, size=(8, 1)).astype(np.float32)
        currents = [1e-3, 2.5e-3, 4e-3]
        sessions, refs = [], {}
        for sid, cur in enumerate(currents):
            p = res.params._replace(current=jnp.asarray(cur, jnp.float32))
            solo = res._replace(params=p)
            _, states = drive(solo, jnp.asarray(u))
            refs[sid] = (states, None)
            sessions.append(StreamSession(sid=sid, u_seq=u, params=p))
        eng = ReservoirEngine(res, num_slots=4, backend="scan")
        results = eng.run(sessions)
        _assert_matches(results, refs)
        # and the dynamics genuinely differ across lanes
        assert not np.allclose(
            np.asarray(results[0].states), np.asarray(results[2].states)
        )

    def test_session_resume_from_final_state(self):
        """final_m resumes a stream: two half-streams == one full stream."""
        res = make_reservoir(n=10, n_in=1, hold_steps=10, dtype=jnp.float32)
        rng = np.random.default_rng(4)
        u = rng.uniform(0.0, 0.5, size=(12, 1)).astype(np.float32)
        _, full = drive(res, jnp.asarray(u))
        eng = ReservoirEngine(res, num_slots=2, backend="scan")
        first = eng.run([StreamSession(sid=0, u_seq=u[:7])])[0]
        second = eng.run([StreamSession(sid=1, u_seq=u[7:], m0=first.final_m)])[1]
        stitched = jnp.concatenate([first.states, second.states])
        np.testing.assert_allclose(np.asarray(stitched), np.asarray(full), atol=ATOL)


class TestKernelBackends:
    @pytest.mark.parametrize("backend,interpret", [("ref", False), ("fused", True), ("tiled", True)])
    def test_backend_matches_solo(self, backend, interpret):
        """The Pallas-layout backends serve the same numbers (interpret mode
        on CPU; tiny shapes — the pad-to-128 path is exercised either way)."""
        res = make_reservoir(n=8, n_in=1, hold_steps=4, dtype=jnp.float32)
        rng = np.random.default_rng(5)
        eng = ReservoirEngine(res, num_slots=3, backend=backend, interpret=interpret)
        sessions, refs = _sessions(
            res, 4, rng, lengths=(3, 5), with_readout=False
        )
        results = eng.run(sessions)
        _assert_matches(results, refs)

    def test_auto_backend_resolves(self):
        res = make_reservoir(n=8, n_in=1, hold_steps=4, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=2, backend="auto")
        assert eng.backend in ("scan", "ref", "fused", "tiled", "chunk")

    def test_measured_latency_table_drives_dispatch(self):
        """A measured entry overrides the heuristic for its padded shape."""
        try:
            import jax

            platform = jax.default_backend()
            ops.register_impl_choice(333, 7, "tiled", platform=platform)
            assert ops.choose_impl(333, 7) == "tiled"
            # a different padded shape is unaffected
            assert ops.choose_impl(8, 8) != "tiled" or platform == "tpu"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_table_update_applies_to_already_jitted_shape(self):
        """impl="auto" is resolved OUTSIDE the jit cache: registering a new
        winner changes the path taken on the next call at the same shape."""
        from repro.core import DT, default_params, initial_magnetization, make_coupling_matrix
        from repro.kernels import ref as kref

        n, e = 8, 4
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float32), (e, n, 3))
        pv = kref.pack_params(default_params(jnp.float32), e, jnp.float32)
        try:
            a = ops.sto_rk4_integrate(m0, w, pv, float(DT), 2)  # auto, cached
            ops.register_impl_choice(n, e, "bogus-impl")
            with pytest.raises(ValueError, match="unknown impl"):
                # proof the re-resolved table entry reached dispatch
                ops.sto_rk4_integrate(m0, w, pv, float(DT), 2)
        finally:
            ops._LATENCY_TABLE.clear()
        b = ops.sto_rk4_integrate(m0, w, pv, float(DT), 2)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_measure_impl_latency_registers_winner(self):
        try:
            timings = ops.measure_impl_latency(8, 4, n_steps=2, reps=1)
            assert timings  # at least the oracle ran
            assert ops.choose_impl(8, 4) in timings
        finally:
            ops._LATENCY_TABLE.clear()


class TestPartialBatchMasking:
    def test_masked_lanes_frozen(self):
        """ops lane_mask: False lanes return bit-identical input state."""
        from repro.core import DT, default_params, initial_magnetization, make_coupling_matrix
        from repro.kernels import ref as kref

        n, e = 8, 4
        p = default_params(jnp.float32)
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = ops.to_planes(
            jnp.broadcast_to(initial_magnetization(n, jnp.float32), (e, n, 3))
        )
        pv = kref.pack_params(p, e, jnp.float32)
        mask = jnp.asarray([True, False, True, False])
        out = ops.sto_rk4_integrate_planes(
            m0, w, pv, float(DT), 4, lane_mask=mask, impl="ref"
        )
        np.testing.assert_array_equal(np.asarray(out[:, :, 1]), np.asarray(m0[:, :, 1]))
        np.testing.assert_array_equal(np.asarray(out[:, :, 3]), np.asarray(m0[:, :, 3]))
        assert not np.allclose(np.asarray(out[:, :, 0]), np.asarray(m0[:, :, 0]))

    def test_driven_integrate_planes_matches_drive(self):
        """h_in plane == drive()'s held input field (one hold window)."""
        res = make_reservoir(n=6, n_in=1, hold_steps=7, dtype=jnp.float32)
        from repro.kernels import ref as kref

        u0 = jnp.asarray([[0.3]], jnp.float32)
        _, states = drive(res, u0)  # one tick
        pv = kref.pack_params(res.params, 1, jnp.float32)
        h = (res.params.a_in * (res.w_in @ u0[0]))[:, None]  # (N, 1)
        out = ops.sto_rk4_integrate_planes(
            ops.to_planes(res.m0), res.w_cp, pv, float(res.dt), res.hold_steps,
            h_in=h, impl="ref",
        )
        np.testing.assert_allclose(
            np.asarray(out[0, :, 0]), np.asarray(states[0]), atol=ATOL
        )


class TestScheduler:
    def test_fifo_order_and_slot_reuse(self):
        sched = SlotScheduler(2)
        for sid in range(4):
            sched.submit(f"s{sid}")
        placed = sched.admissions([0, 1])
        assert placed == [(0, "s0"), (1, "s1")]
        assert sched.admissions([]) == []
        assert sched.retire(0) == "s0"
        assert sched.admissions([0]) == [(0, "s2")]
        assert sched.stats.admitted == 3 and sched.stats.retired == 1

    def test_has_work(self):
        sched = SlotScheduler(1)
        assert not sched.has_work()
        sched.submit("x")
        assert sched.has_work()
        sched.admissions([0])
        assert sched.has_work()
        sched.retire(0)
        assert not sched.has_work()


class TestDriveContract:
    def test_accepts_1d_for_single_input(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=5, dtype=jnp.float32)
        u = np.linspace(0, 0.5, 7).astype(np.float32)
        _, s1 = drive(res, u)
        _, s2 = drive(res, u[:, None])
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))

    def test_rejects_transposed_row_vector(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=5, dtype=jnp.float32)
        with pytest.raises(ValueError, match=r"\(T, 1\)"):
            drive(res, np.zeros((1, 7), np.float32))

    def test_rejects_1d_for_multi_input(self):
        res = make_reservoir(n=6, n_in=3, hold_steps=5, dtype=jnp.float32)
        with pytest.raises(ValueError, match="n_in == 3"):
            drive(res, np.zeros(7, np.float32))

    def test_rejects_wrong_width(self):
        assert coerce_input_series(np.zeros((4, 2)), 2, jnp.float32).shape == (4, 2)
        with pytest.raises(ValueError, match=r"\(T, 2\)"):
            coerce_input_series(np.zeros((4, 3)), 2, jnp.float32)

    def test_resume_m0_equivalent_to_one_drive(self):
        # chunked drive re-runs the identical op sequence, so equality is
        # exact (bitwise) even in f32
        res = make_reservoir(n=8, n_in=1, hold_steps=10, dtype=jnp.float32)
        u = np.random.default_rng(6).uniform(0, 0.5, size=(10, 1)).astype(np.float32)
        mT_full, s_full = drive(res, jnp.asarray(u))
        m_half, s_a = drive(res, jnp.asarray(u[:5]))
        mT_res, s_b = drive(res, jnp.asarray(u[5:]), m0=m_half)
        np.testing.assert_allclose(
            np.asarray(jnp.concatenate([s_a, s_b])), np.asarray(s_full), rtol=1e-12
        )
        np.testing.assert_allclose(np.asarray(mT_res), np.asarray(mT_full), rtol=1e-12)

    def test_rejects_bad_m0_shape(self):
        res = make_reservoir(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        with pytest.raises(ValueError, match="m0 must have shape"):
            drive(res, np.zeros((3, 1), np.float32), m0=np.zeros((4, 3)))


class TestEngineValidation:
    def test_rejects_bad_stream_shape(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=5, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=2, backend="scan")
        with pytest.raises(ValueError, match=r"\(T, 1\)"):
            eng.submit(StreamSession(sid=0, u_seq=np.zeros((1, 9), np.float32)))

    def test_rejects_empty_stream(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=5, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=2, backend="scan")
        with pytest.raises(ValueError, match="empty"):
            eng.submit(StreamSession(sid=0, u_seq=np.zeros((0, 1), np.float32)))

    def test_rejects_unknown_backend(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=5, dtype=jnp.float32)
        with pytest.raises(ValueError, match="backend"):
            ReservoirEngine(res, num_slots=2, backend="warp")

    def test_rejects_misshapen_readout_at_submit(self):
        from repro.core import Readout

        res = make_reservoir(n=6, n_in=1, hold_steps=5, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=2, backend="scan")
        bad = Readout(w_out=jnp.zeros((7,), jnp.float32), washout=0)  # 1-D
        with pytest.raises(ValueError, match="w_out shape"):
            eng.submit(
                StreamSession(sid=0, u_seq=np.zeros((3, 1), np.float32), readout=bad)
            )
