"""Fault tolerance: supervision, failover, quarantine, and the harness.

The fleet's recovery contract is the migration contract under fire: a
session whose replica is killed, hung, or starved mid-stream must come
back BIT-IDENTICAL — states, predictions, and in-flight learned readout
weights all equal to the same stream served by one unmolested scan
engine. Every failure here is injected deterministically through
`repro.serve.fleet.faults` (seeded `FaultPlan` threaded into the replica
transports), so each scenario replays exactly: crash at a known chunk,
drop exactly K sends, hang past the RPC deadline, NaN into one tenant's
lane at a known tick. The NaN tests additionally pin the blast radius —
quarantining a poisoned tenant must not move a single bit of any
co-tenant's output (lanes are independent GEMM columns).
"""

import asyncio
import dataclasses
import time

import numpy as np
import pytest

from repro.serve.fleet import (
    CRASH_EXIT_CODE,
    Fault,
    FaultPlan,
    FleetFrontend,
    FleetRouter,
    HEALTH_DEAD,
    HEALTH_DEGRADED,
    HEALTH_HEALTHY,
    LocalReplica,
    OverloadError,
    ProcessReplica,
    ReplicaError,
    validate_supervision,
)
from repro.core.reservoir import make_reservoir
from repro.serve.reservoir import ReservoirEngine, StreamSession

# same tiny deterministic config as test_fleet: scan is the bit-exact oracle
ENGINE_KW = dict(
    n=10, num_slots=4, hold_steps=6, seed=3, backend="scan", chunk_ticks=5
)
LEARN_KW = dict(ENGINE_KW, learn="rls")


def _stream(rng, t=23, n_in=1):
    return rng.uniform(0.0, 0.5, size=(t, n_in)).astype(np.float32)


def _learn_sessions(k=4, t=23, seed=7):
    """k independent RLS tenants — learned weights are part of the
    recovery contract, so every failover test streams learners."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(k):
        u = _stream(rng, t=t)
        y = (0.3 * u + 0.1 * np.roll(u, 1, axis=0)).astype(np.float32)
        out.append(
            dict(sid=i, u_seq=u, targets=y, learn_washout=3)
        )
    return out


def _drain_router(router):
    while router.run_for(1):
        pass
    return router.results()


def _clean_fleet_results(session_kws, engine_kw=LEARN_KW, replicas=2):
    """Reference: the same tenants through an unfaulted fleet."""
    router = FleetRouter()
    for _ in range(replicas):
        router.add_replica(LocalReplica(**engine_kw))
    for kw in session_kws:
        router.submit(engine_kw["n"], StreamSession(**{k: (v.copy() if isinstance(v, np.ndarray) else v) for k, v in kw.items()}))
    try:
        return _drain_router(router)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# fault plan: validation + determinism
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_fault_validation(self):
        with pytest.raises(ValueError):
            Fault("meteor")
        with pytest.raises(ValueError):
            Fault("crash", at_chunk=-1)
        with pytest.raises(ValueError):
            Fault("drop", count=0)
        with pytest.raises(ValueError):
            Fault("delay")  # delay faults need delay_s > 0
        with pytest.raises(ValueError):
            Fault("nan")  # nan faults need a target sid
        with pytest.raises(TypeError):
            FaultPlan((Fault("crash"), "not a fault"))

    def test_random_plan_deterministic(self):
        a = FaultPlan.random(42, n_faults=5)
        b = FaultPlan.random(42, n_faults=5)
        assert a == b and a.faults == b.faults
        c = FaultPlan.random(43, n_faults=5)
        assert a != c

    def test_runtime_counts_events(self):
        plan = FaultPlan(
            (Fault("delay", op="stats", delay_s=0.001, count=2),
             Fault("drop", op="run_for", count=1))
        )
        rt = plan.runtime()
        drop, delay = rt.before_send("stats")
        assert not drop and delay == 0.001
        drop, delay = rt.before_send("stats")
        assert not drop and delay == 0.001
        drop, _ = rt.before_send("stats")
        assert not drop  # count exhausted
        drop, _ = rt.before_send("run_for")
        assert drop
        drop, _ = rt.before_send("run_for")
        assert not drop
        assert rt.delays_fired == 2 and rt.drops_fired == 1


# ---------------------------------------------------------------------------
# knob validation
# ---------------------------------------------------------------------------


class TestKnobValidation:
    @pytest.mark.parametrize(
        "kw",
        [
            dict(rpc_timeout_s=0.0),
            dict(rpc_timeout_s=-1.0),
            dict(rpc_retries=-1),
            dict(rpc_retries=True),
            dict(rpc_retries=1.5),
            dict(rpc_backoff_s=0.0),
        ],
    )
    def test_supervision_rejects_nonpositive(self, kw):
        base = dict(rpc_timeout_s=60.0, rpc_retries=3, rpc_backoff_s=0.05)
        base.update(kw)
        with pytest.raises(ValueError):
            validate_supervision(**base)

    def test_supervision_accepts_none_timeout(self):
        validate_supervision(None, 0, 0.01)  # no deadline is a valid choice

    @pytest.mark.parametrize("bad", [0, -3, True, 1.5])
    def test_router_rejects_bad_checkpoint_every(self, bad):
        with pytest.raises(ValueError):
            FleetRouter(checkpoint_every=bad)


# ---------------------------------------------------------------------------
# failover: killed replica, bit-exact recovery
# ---------------------------------------------------------------------------


class TestFailover:
    def _chaotic_router(self, engine_kw=LEARN_KW, at_chunk=2):
        """Two replicas; the first crashes at `at_chunk` and respawns."""
        router = FleetRouter(checkpoint_every=2)
        plan = FaultPlan((Fault("crash", at_chunk=at_chunk),))
        router.add_replica(
            LocalReplica(faults=plan, **engine_kw),
            respawn=lambda: LocalReplica(**engine_kw),
        )
        router.add_replica(LocalReplica(**engine_kw))
        return router

    def test_crash_failover_bit_exact(self):
        kws = _learn_sessions(k=4)
        clean = _clean_fleet_results(kws)

        router = self._chaotic_router()
        for kw in kws:
            router.submit(LEARN_KW["n"], StreamSession(**{k: (v.copy() if isinstance(v, np.ndarray) else v) for k, v in kw.items()}))
        try:
            chaotic = _drain_router(router)
            fs = router.fault_stats()
        finally:
            router.close()

        assert sorted(chaotic) == sorted(clean)
        for sid in clean:
            np.testing.assert_array_equal(chaotic[sid].states, clean[sid].states)
            np.testing.assert_array_equal(
                chaotic[sid].predictions, clean[sid].predictions
            )
            np.testing.assert_array_equal(
                np.asarray(chaotic[sid].learned_readout.w_out),
                np.asarray(clean[sid].learned_readout.w_out),
            )
            assert chaotic[sid].error is None
        assert fs["replica_deaths"] == 1 and fs["failovers"] == 1
        assert fs["sessions_lost"] == 0 and fs["sessions_recovered"] >= 1

    def test_crash_before_first_checkpoint_recovers_from_submit(self):
        # crash at chunk 0: only the synthesized t=0 checkpoint exists —
        # recovery must restart the stream from the submit-time snapshot
        kws = _learn_sessions(k=2)
        clean = _clean_fleet_results(kws)
        router = self._chaotic_router(at_chunk=0)
        for kw in kws:
            router.submit(LEARN_KW["n"], StreamSession(**{k: (v.copy() if isinstance(v, np.ndarray) else v) for k, v in kw.items()}))
        try:
            chaotic = _drain_router(router)
            fs = router.fault_stats()
        finally:
            router.close()
        assert fs["sessions_lost"] == 0
        for sid in clean:
            np.testing.assert_array_equal(chaotic[sid].states, clean[sid].states)
            np.testing.assert_array_equal(
                np.asarray(chaotic[sid].learned_readout.w_out),
                np.asarray(clean[sid].learned_readout.w_out),
            )

    def test_push_stream_replay_recovery(self):
        # rows pushed after the last checkpoint live in the router's replay
        # buffer; failover must replay them so the open stream is whole
        rng = np.random.default_rng(11)
        u = _stream(rng, t=20)

        solo = LocalReplica(**ENGINE_KW)
        solo.submit(StreamSession(sid=0, u_seq=u.copy()))
        while solo.run_for(1):
            pass
        (control,) = solo.results()

        router = FleetRouter(checkpoint_every=100)  # only the t=0 ckpt lands
        plan = FaultPlan((Fault("crash", at_chunk=2),))
        router.add_replica(
            LocalReplica(faults=plan, **ENGINE_KW),
            respawn=lambda: LocalReplica(**ENGINE_KW),
        )
        sid = router.next_sid()
        router.submit(
            ENGINE_KW["n"],
            StreamSession(sid=sid, u_seq=u[:8].copy(), open=True),
        )
        router.run_for(1)
        router.append_ticks(sid, u[8:].copy())
        try:
            router.close_session(sid)
            res = _drain_router(router)[sid]
            fs = router.fault_stats()
        finally:
            router.close()
        np.testing.assert_array_equal(res.states, control.states)
        np.testing.assert_array_equal(res.final_m, control.final_m)
        assert fs["replica_deaths"] == 1
        assert fs["replayed_ticks"] >= 12  # the pushed tail came from replay

    def test_snapshot_is_non_perturbing(self):
        # auto-checkpointing must never change what a healthy fleet serves
        kws = _learn_sessions(k=3)
        clean = _clean_fleet_results(kws)
        router = FleetRouter(checkpoint_every=1)  # snapshot every round
        for _ in range(2):
            router.add_replica(LocalReplica(**LEARN_KW))
        for kw in kws:
            router.submit(LEARN_KW["n"], StreamSession(**{k: (v.copy() if isinstance(v, np.ndarray) else v) for k, v in kw.items()}))
        try:
            snapped = _drain_router(router)
        finally:
            router.close()
        for sid in clean:
            np.testing.assert_array_equal(snapped[sid].states, clean[sid].states)
            np.testing.assert_array_equal(
                np.asarray(snapped[sid].learned_readout.w_out),
                np.asarray(clean[sid].learned_readout.w_out),
            )


# ---------------------------------------------------------------------------
# NaN quarantine: poisoned tenant out, co-tenants untouched
# ---------------------------------------------------------------------------


class TestQuarantine:
    def test_quarantine_isolates_tenant(self):
        rng = np.random.default_rng(13)
        streams = {i: _stream(rng, t=23) for i in range(3)}

        solo = {}
        for i, u in streams.items():
            rep = LocalReplica(**ENGINE_KW)
            rep.submit(StreamSession(sid=0, u_seq=u.copy()))
            while rep.run_for(1):
                pass
            solo[i] = rep.results()[0]

        eng = ReservoirEngine(
            make_reservoir(n=10, hold_steps=6, seed=3),
            num_slots=4, backend="scan", chunk_ticks=5,
        )
        poisoned = streams[1].copy()
        poisoned[7, 0] = np.nan
        sessions = [
            StreamSession(sid=0, u_seq=streams[0].copy()),
            StreamSession(sid=1, u_seq=poisoned),
            StreamSession(sid=2, u_seq=streams[2].copy()),
        ]
        results = eng.run(sessions)

        assert "non_finite" in results[1].error
        assert np.isfinite(results[1].states).all()  # clean prefix only
        assert results[1].states.shape[0] < streams[1].shape[0]
        for i in (0, 2):  # co-tenants: not a single bit moved
            assert results[i].error is None
            np.testing.assert_array_equal(results[i].states, solo[i].states)
            np.testing.assert_array_equal(results[i].final_m, solo[i].final_m)
        assert eng.stats().quarantined_lanes == 1

    def test_nan_fault_injection_through_replica(self):
        rng = np.random.default_rng(14)
        plan = FaultPlan((Fault("nan", sid=5, tick=4),))
        rep = LocalReplica(faults=plan, **ENGINE_KW)
        rep.submit(StreamSession(sid=5, u_seq=_stream(rng, t=23)))
        rep.submit(StreamSession(sid=6, u_seq=_stream(rng, t=23)))
        while rep.run_for(1):
            pass
        results = {r.sid: r for r in rep.results()}
        assert "non_finite" in results[5].error
        assert results[6].error is None
        assert rep.stats().quarantined_lanes == 1

    def test_nan_guard_off_is_legacy_behavior(self):
        rng = np.random.default_rng(15)
        u = _stream(rng, t=13)
        u[3, 0] = np.inf
        eng = ReservoirEngine(
            make_reservoir(n=10, hold_steps=6, seed=3),
            num_slots=2, backend="scan", chunk_ticks=5, nan_guard=False,
        )
        (res,) = eng.run([StreamSession(sid=0, u_seq=u)]).values()
        assert res.error is None  # guard off: garbage flows through
        assert not np.isfinite(res.states).all()


# ---------------------------------------------------------------------------
# process transport supervision (real child processes)
# ---------------------------------------------------------------------------


class TestProcessSupervision:
    def test_drop_faults_retry_then_degrade(self):
        plan = FaultPlan((Fault("drop", op="stats", count=2),))
        rep = ProcessReplica(
            faults=plan, rpc_timeout_s=60.0, rpc_retries=3,
            rpc_backoff_s=0.01, **ENGINE_KW
        )
        try:
            assert rep.health == HEALTH_HEALTHY
            st = rep.stats()  # both drops swallowed by resends
            assert st.active == 0
            assert rep.rpc_retries_total == 2
            assert rep.health == HEALTH_DEGRADED  # sticky: retries fired
        finally:
            rep.close()
        assert not rep._proc.is_alive()  # close() reaps, no zombie

    def test_child_crash_raises_with_exit_code(self):
        rng = np.random.default_rng(16)
        plan = FaultPlan((Fault("crash", at_chunk=1),))
        rep = ProcessReplica(faults=plan, rpc_timeout_s=30.0, **ENGINE_KW)
        try:
            rep.submit(StreamSession(sid=1, u_seq=_stream(rng, t=23)))
            with pytest.raises(ReplicaError) as ei:
                while rep.run_for(1):
                    pass
            assert ei.value.exit_code == CRASH_EXIT_CODE
            assert rep.health == HEALTH_DEAD
            with pytest.raises(ReplicaError):
                rep.stats()  # dead replica fails fast, never blocks
        finally:
            rep.close()
        assert not rep._proc.is_alive()

    def test_hung_child_trips_rpc_deadline(self):
        rng = np.random.default_rng(17)
        plan = FaultPlan((Fault("hang", at_chunk=1),))
        rep = ProcessReplica(faults=plan, rpc_timeout_s=1.5, **ENGINE_KW)
        try:
            rep.submit(StreamSession(sid=1, u_seq=_stream(rng, t=23)))
            t0 = time.monotonic()
            with pytest.raises(ReplicaError, match="timed out"):
                while rep.run_for(1):
                    pass
            assert time.monotonic() - t0 < 30.0  # deadline, not forever
            assert rep.health == HEALTH_DEAD
        finally:
            rep.close()
        assert not rep._proc.is_alive()  # hung child force-killed

    def test_process_crash_failover_bit_exact(self):
        rng = np.random.default_rng(18)
        u = _stream(rng, t=23)
        solo = LocalReplica(**ENGINE_KW)
        solo.submit(StreamSession(sid=0, u_seq=u.copy()))
        while solo.run_for(1):
            pass
        (control,) = solo.results()

        router = FleetRouter(checkpoint_every=1)
        plan = FaultPlan((Fault("crash", at_chunk=2),))
        router.add_replica(
            ProcessReplica(faults=plan, rpc_timeout_s=60.0, **ENGINE_KW),
            respawn=lambda: LocalReplica(**ENGINE_KW),
        )
        sid = router.next_sid()
        router.submit(ENGINE_KW["n"], StreamSession(sid=sid, u_seq=u.copy()))
        try:
            res = _drain_router(router)[sid]
            fs = router.fault_stats()
        finally:
            router.close()
        np.testing.assert_array_equal(res.states, control.states)
        np.testing.assert_array_equal(res.final_m, control.final_m)
        assert fs["replica_deaths"] == 1 and fs["sessions_lost"] == 0


# ---------------------------------------------------------------------------
# frontend: overload shed + retry + counters
# ---------------------------------------------------------------------------


class TestFrontend:
    def test_overload_shed_structured_error(self):
        rng = np.random.default_rng(19)
        router = FleetRouter()
        router.add_replica(LocalReplica(**ENGINE_KW))

        async def main():
            async with FleetFrontend(router, degraded=True) as fleet:
                assert fleet.degraded and fleet.pool_degraded(10)
                limit = fleet.pool_limit(10, degraded=True)
                sids = [
                    await fleet.submit_stream(10, _stream(rng, t=5), open=True)
                    for _ in range(limit)
                ]
                with pytest.raises(OverloadError) as ei:
                    await fleet.submit_stream(10, _stream(rng, t=5))
                err = ei.value
                assert err.to_dict()["error"] == "overload"
                assert err.n == 10 and err.inflight >= err.limit
                assert fleet.shed_streams == 1
                assert fleet.fault_stats()["shed_streams"] == 1
                fleet.set_degraded(False)
                assert not fleet.pool_degraded(10)  # healthy pool again
                for sid in sids:
                    await fleet.close_stream(sid)
                await fleet.drain_results()

        asyncio.run(main())

    def test_unhealthy_replica_forces_degraded(self):
        router = FleetRouter()
        rep = LocalReplica(**ENGINE_KW)
        router.add_replica(rep)

        async def main():
            async with FleetFrontend(router) as fleet:
                assert not fleet.pool_degraded(10)
                rep.health = HEALTH_DEGRADED
                assert fleet.pool_degraded(10)

        asyncio.run(main())

    def test_frontend_retry_knob_validation(self):
        router = FleetRouter()
        with pytest.raises(ValueError):
            FleetFrontend(router, rpc_retries=-1)
        with pytest.raises(ValueError):
            FleetFrontend(router, rpc_backoff_s=0.0)
        with pytest.raises(ValueError):
            FleetFrontend(router, rpc_backoff_max_s=0.001, rpc_backoff_s=0.05)

    def test_fleet_fault_stats_roundtrip(self):
        router = FleetRouter()
        router.add_replica(LocalReplica(**ENGINE_KW))
        fs = router.fault_stats()
        for key in (
            "replica_deaths", "failovers", "sessions_recovered",
            "sessions_lost", "replayed_ticks", "rpc_retries",
            "quarantined_lanes",
        ):
            assert fs[key] == 0
        router.close()
