"""Flash-attention kernel vs plain-softmax oracle, swept over shapes, GQA
group sizes, masks (causal / sliding-window / none) and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_reference


def _rand_qkv(key, b, h, kvh, sq, sk, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, h, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, kvh, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, kvh, sk, d), dtype)
    return q, k, v


def _ref(q, k, v, causal, window):
    g = q.shape[1] // k.shape[1]
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    return mha_reference(q, kr, vr, causal=causal, window=window)


SHAPES = [
    # b, h, kvh, sq, sk, d
    (2, 4, 2, 256, 256, 64),
    (1, 8, 8, 128, 128, 32),  # MHA
    (1, 4, 1, 128, 128, 128),  # MQA
    (2, 4, 4, 128, 384, 64),  # q shorter than k (chunked prefill)
    (1, 16, 4, 256, 256, 80),  # non-pow2 head dim (h2o-danube style)
]


class TestFlashShapes:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("causal", [True, False])
    def test_against_oracle(self, shape, causal):
        b, h, kvh, sq, sk, d = shape
        if not causal and sq != sk:
            pytest.skip("offset alignment only meaningful causally")
        q, k, v = _rand_qkv(jax.random.PRNGKey(0), *shape, jnp.float32)
        out = flash_attention(q, k, v, causal=causal, interpret=True)
        ref = _ref(q, k, v, causal, 0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)

    @pytest.mark.parametrize("window", [64, 128, 256])
    def test_sliding_window(self, window):
        q, k, v = _rand_qkv(jax.random.PRNGKey(1), 1, 4, 2, 256, 256, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window, interpret=True)
        ref = _ref(q, k, v, True, window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)

    def test_bf16_inputs(self):
        q, k, v = _rand_qkv(jax.random.PRNGKey(2), 1, 4, 2, 128, 128, 64, jnp.bfloat16)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = _ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), True, 0)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref), atol=3e-2
        )

    @pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
    def test_block_shape_invariance(self, bq, bk):
        """Output must not depend on the tiling."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(3), 1, 2, 2, 256, 256, 64, jnp.float32)
        a = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
        b = flash_attention(q, k, v, causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-6)


class TestFlashProperties:
    @settings(max_examples=8, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        g=st.sampled_from([1, 2, 4]),
        d=st.sampled_from([32, 64]),
    )
    def test_rows_are_convex_combinations(self, seed, g, d):
        """Each output row lies in the convex hull of V rows: max|out| <=
        max|v| (softmax weights sum to 1)."""
        key = jax.random.PRNGKey(seed)
        q, k, v = _rand_qkv(key, 1, 2 * g, 2, 128, 128, d, jnp.float32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-5

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_permutation_equivariance_over_batch(self, seed):
        key = jax.random.PRNGKey(seed)
        q, k, v = _rand_qkv(key, 3, 2, 2, 128, 128, 32, jnp.float32)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        perm = jnp.array([2, 0, 1])
        out_p = flash_attention(q[perm], k[perm], v[perm], causal=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p), atol=1e-6)

    def test_decode_single_query(self):
        """Sq=1 (decode step) against the oracle with a long cache. Uses
        block_q=1 — the decode specialization."""
        q, k, v = _rand_qkv(jax.random.PRNGKey(9), 2, 4, 2, 1, 512, 64, jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=1, interpret=True)
        ref = _ref(q, k, v, True, 0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-6)
