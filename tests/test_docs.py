"""Docs stay healthy: internal markdown links resolve and docstring
examples execute (the same checks CI's docs leg runs via
tools/check_docs.py)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "tools"))

import check_docs  # noqa: E402


def test_markdown_links_resolve():
    assert check_docs.check_links() == []


def test_doctests_pass():
    assert check_docs.check_doctests() == []
