"""Chunked pipelined serving: parity, autoscaling, and plan validation.

The contracts this file pins:

  - `CompiledSim.tick_chunk` (K > 1) is BIT-EXACT against K sequential
    `tick` calls on the scan backend — including per-tick masks that turn a
    lane on mid-chunk (admit) or off mid-chunk (retire) — and
    tolerance-equal on the planes backends (ref, and fused/tiled in
    interpret mode).
  - `ReservoirEngine.run` (pipelined chunks) is bit-exact against the
    synchronous per-tick `step()` loop on the scan backend: states,
    readout outputs, and final_m.
  - Autoscaling migrates running sessions between bucketed plans without
    perturbing their dynamics; scheduler stats expose the load signals.
  - `pop_results` / `max_retained` bound retired-session retention.
  - ExecPlan rejects chunk_ticks < 1 / non-int and non-dtype gather_dtype.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecPlan, compile_plan, make_spec
from repro.core import drive, fit_ridge, make_reservoir
from repro.kernels import ops
from repro.serve.reservoir import ReservoirEngine, StreamSession, _bucket_slots
from repro.serve.scheduler import AutoscalePolicy, QueueDepthPolicy, SlotScheduler

ATOL = 5e-5  # tests/test_kernels_sto.py's f32 tolerance


def _chunk_inputs(k, e, n_in, seed=0):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.uniform(0.0, 0.5, (k, e, n_in)).astype(np.float32))
    # per-tick masks with mid-chunk admits (False -> True) and retires
    # (True -> False): lane 0 always on, lane 1 admitted at tick 2, lane 2
    # retired after tick 1, remaining lanes random
    mask = rng.uniform(size=(k, e)) > 0.4
    mask[:, 0] = True
    if e > 1:
        mask[:, 1] = [t >= 2 for t in range(k)]
    if e > 2:
        mask[:, 2] = [t < 2 for t in range(k)]
    return u, jnp.asarray(mask)


def _sequential_ticks(sim, m0, u, mask):
    m, states = m0, []
    for t in range(u.shape[0]):
        m, s = sim.tick(m, u[t], lane_mask=mask[t])
        states.append(s)
    return m, jnp.stack(states)


class TestTickChunkParity:
    def test_scan_bitexact_vs_per_tick(self):
        spec = make_spec(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=4, chunk_ticks=6))
        u, mask = _chunk_inputs(6, 4, 1)
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (4, 8, 3)))
        m_seq, s_seq = _sequential_ticks(sim, m0, u, mask)
        m_chk, s_chk = sim.tick_chunk(m0, u, mask)
        np.testing.assert_array_equal(np.asarray(m_chk), np.asarray(m_seq))
        np.testing.assert_array_equal(np.asarray(s_chk), np.asarray(s_seq))

    @pytest.mark.parametrize(
        "impl,interpret", [("ref", False), ("fused", True), ("tiled", True)]
    )
    def test_planes_impls_close_to_per_tick(self, impl, interpret):
        spec = make_spec(n=8, n_in=1, hold_steps=3, dtype=jnp.float32)
        sim = compile_plan(
            spec, ExecPlan(impl=impl, ensemble=3, chunk_ticks=4, interpret=interpret)
        )
        u, mask = _chunk_inputs(4, 3, 1, seed=1)
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (3, 8, 3)))
        m_seq, s_seq = _sequential_ticks(sim, m0, u, mask)
        m_chk, s_chk = sim.tick_chunk(m0, u, mask)
        np.testing.assert_allclose(np.asarray(m_chk), np.asarray(m_seq), atol=ATOL)
        np.testing.assert_allclose(np.asarray(s_chk), np.asarray(s_seq), atol=ATOL)

    def test_mid_chunk_admit_equals_boundary_admit(self):
        """A lane spliced at the chunk boundary but masked until tick k
        integrates exactly as if the chunk had started at tick k — the
        masking rule mid-chunk admissions rely on."""
        spec = make_spec(n=8, n_in=1, hold_steps=4, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=1, chunk_ticks=4))
        rng = np.random.default_rng(2)
        u = jnp.asarray(rng.uniform(0.0, 0.5, (4, 1, 1)).astype(np.float32))
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (1, 8, 3)))
        mask = jnp.asarray([[False], [False], [True], [True]])
        m_late, s_late = sim.tick_chunk(m0, u, mask)
        m_short, s_short = sim.tick_chunk(m0, u[2:], None)
        np.testing.assert_array_equal(np.asarray(m_late), np.asarray(m_short))
        np.testing.assert_array_equal(
            np.asarray(s_late[2:]), np.asarray(s_short)
        )
        # masked-off ticks echo the frozen (admission) state
        np.testing.assert_array_equal(np.asarray(s_late[0]), np.asarray(m0[0]))

    def test_shared_mask_row_broadcasts(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=2, chunk_ticks=3))
        rng = np.random.default_rng(3)
        u = jnp.asarray(rng.uniform(0.0, 0.5, (3, 2, 1)).astype(np.float32))
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (2, 6, 3)))
        row = jnp.asarray([True, False])
        m_a, s_a = sim.tick_chunk(m0, u, row)
        m_b, s_b = sim.tick_chunk(m0, u, jnp.broadcast_to(row[None, :], (3, 2)))
        np.testing.assert_array_equal(np.asarray(m_a), np.asarray(m_b))
        np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))

    def test_rejects_bad_shapes(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=2))
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (2, 6, 3)))
        with pytest.raises(ValueError, match="u_block"):
            sim.tick_chunk(m0, jnp.zeros((4, 3, 1), jnp.float32))
        with pytest.raises(ValueError, match="lane_mask"):
            sim.tick_chunk(
                m0, jnp.zeros((4, 2, 1), jnp.float32), jnp.zeros((3, 2), bool)
            )


class TestEnginePipelinedParity:
    def _mk_sessions(self, res, count, rng, lengths, with_readout=True):
        sessions, clones, refs = [], [], {}
        for sid in range(count):
            t = lengths[sid % len(lengths)]
            u = rng.uniform(0.0, 0.5, size=(t, 1)).astype(np.float32)
            ro = None
            if with_readout:
                _, states = drive(res, jnp.asarray(u))
                ro = fit_ridge(states, jnp.asarray(u[:, 0]), washout=2, reg=1e-3)
                refs[sid] = states
            sessions.append(StreamSession(sid=sid, u_seq=u, readout=ro))
            clones.append(StreamSession(sid=sid, u_seq=u.copy(), readout=ro))
        return sessions, clones, refs

    def test_run_bitexact_vs_step_loop_scan(self):
        """The pipelined chunked path and the synchronous per-tick path are
        the same numbers, bit for bit, on the scan backend — states,
        outputs, final_m — across slot turnover and mid-chunk finishes."""
        res = make_reservoir(n=12, n_in=1, hold_steps=8, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        sessions, clones, _ = self._mk_sessions(res, 9, rng, (5, 9, 14))
        chunked = ReservoirEngine(res, num_slots=3, backend="scan", chunk_ticks=4)
        r_chunk = chunked.run(sessions)
        sync = ReservoirEngine(res, num_slots=3, backend="scan")
        for s in clones:
            sync.submit(s)
        while sync.scheduler.has_work():
            sync.step()
        assert set(r_chunk) == set(sync.results)
        for sid, r in r_chunk.items():
            ref = sync.results[sid]
            np.testing.assert_array_equal(np.asarray(r.states), np.asarray(ref.states))
            np.testing.assert_array_equal(
                np.asarray(r.outputs), np.asarray(ref.outputs)
            )
            np.testing.assert_array_equal(
                np.asarray(r.final_m), np.asarray(ref.final_m)
            )

    def test_chunk_ticks_one_matches_step(self):
        """K=1 pipelining (bulk harvest, no per-slot slicing) is still the
        per-tick math."""
        res = make_reservoir(n=10, n_in=1, hold_steps=6, dtype=jnp.float32)
        rng = np.random.default_rng(1)
        sessions, clones, _ = self._mk_sessions(res, 5, rng, (4, 7), with_readout=False)
        a = ReservoirEngine(res, num_slots=2, backend="scan", chunk_ticks=1)
        ra = a.run(sessions)
        b = ReservoirEngine(res, num_slots=2, backend="scan")
        for s in clones:
            b.submit(s)
        while b.scheduler.has_work():
            b.step()
        for sid in ra:
            np.testing.assert_array_equal(
                np.asarray(ra[sid].states), np.asarray(b.results[sid].states)
            )

    def test_ref_backend_chunked_matches_solo(self):
        """Chunked serving on the planes default stays within kernel
        tolerance of solo drive."""
        res = make_reservoir(n=12, n_in=1, hold_steps=8, dtype=jnp.float32)
        rng = np.random.default_rng(2)
        sessions, _, refs = self._mk_sessions(res, 8, rng, (6, 9, 12))
        eng = ReservoirEngine(res, num_slots=4, backend="ref", chunk_ticks=4)
        results = eng.run(sessions)
        for sid, r in results.items():
            np.testing.assert_allclose(
                np.asarray(r.states), np.asarray(refs[sid]), atol=ATOL
            )

    def test_resume_across_engines(self):
        """final_m from a chunked run resumes bit-exactly (scan)."""
        res = make_reservoir(n=8, n_in=1, hold_steps=10, dtype=jnp.float32)
        u = np.random.default_rng(3).uniform(0, 0.5, (12, 1)).astype(np.float32)
        _, full = drive(res, jnp.asarray(u))
        eng = ReservoirEngine(res, num_slots=2, backend="scan", chunk_ticks=3)
        first = eng.run([StreamSession(sid=0, u_seq=u[:7])])[0]
        second = eng.run([StreamSession(sid=1, u_seq=u[7:], m0=first.final_m)])[1]
        stitched = np.concatenate(
            [np.asarray(first.states), np.asarray(second.states)]
        )
        np.testing.assert_allclose(stitched, np.asarray(full), atol=ATOL)


class TestAutoscale:
    def test_grow_and_shrink_preserve_dynamics(self):
        """A burst grows the batch (bucketed), the drain shrinks it; every
        session still matches its solo reference across the migrations."""
        res = make_reservoir(n=10, n_in=1, hold_steps=6, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        sessions, refs = [], {}
        for sid in range(20):
            u = rng.uniform(0.0, 0.5, ((6, 10, 14)[sid % 3], 1)).astype(np.float32)
            _, states = drive(res, jnp.asarray(u))
            sessions.append(StreamSession(sid=sid, u_seq=u))
            refs[sid] = states
        eng = ReservoirEngine(
            res, num_slots=4, backend="scan", chunk_ticks=4,
            autoscale=QueueDepthPolicy(), min_slots=2, max_slots=16,
        )
        results = eng.run(sessions)
        assert len(results) == 20
        assert eng.scheduler.stats.grows >= 1
        assert eng.scheduler.stats.shrinks >= 1
        assert len(eng._sims) >= 2  # bucketed plan cache populated
        for sid, r in results.items():
            np.testing.assert_allclose(
                np.asarray(r.states), np.asarray(refs[sid]), atol=ATOL
            )

    def test_bucketing(self):
        assert _bucket_slots(1, 2, 16) == 2
        assert _bucket_slots(3, 2, 16) == 4
        assert _bucket_slots(9, 2, 16) == 16
        assert _bucket_slots(100, 2, 16) == 16
        assert _bucket_slots(5, 8, 64) == 8

    def test_autoscale_true_uses_default_policy(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        eng = ReservoirEngine(
            res, num_slots=2, backend="scan", autoscale=True, max_slots=8
        )
        assert isinstance(eng.autoscale, QueueDepthPolicy)

    def test_custom_policy_plugs_in(self):
        class AlwaysMax(AutoscalePolicy):
            def target_slots(self, *, active, queued, num_slots, min_slots, max_slots):
                return max_slots

        res = make_reservoir(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        eng = ReservoirEngine(
            res, num_slots=2, backend="scan", chunk_ticks=2,
            autoscale=AlwaysMax(), min_slots=2, max_slots=8,
        )
        u = np.random.default_rng(1).uniform(0, 0.5, (4, 1)).astype(np.float32)
        eng.run([StreamSession(sid=0, u_seq=u)])
        assert eng.num_slots == 8
        assert eng.scheduler.stats.grows == 1

    def test_rejects_bad_bounds(self):
        res = make_reservoir(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        with pytest.raises(ValueError, match="min_slots"):
            ReservoirEngine(
                res, num_slots=4, backend="scan", autoscale=True,
                min_slots=8, max_slots=16,
            )

    def test_scheduler_load_signals(self):
        sched = SlotScheduler(4)
        for sid in range(3):
            sched.submit(f"s{sid}")
        assert sched.queue_depth() == 3
        sched.admissions([0, 1])
        sched.on_ticks(4, 8)
        assert sched.stats.slot_ticks == 16
        assert sched.occupancy() == pytest.approx(0.5)
        sched.admissions([2])  # s2 waited 4 ticks
        assert sched.stats.queue_wait_ticks == 4
        assert sched.mean_queue_wait() == pytest.approx(4 / 3)
        sched.remap({0: 0, 1: 1, 2: 2}, 8)
        assert sched.num_slots == 8 and sched.stats.grows == 1


class TestResultRetention:
    def _serve(self, **kw):
        res = make_reservoir(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        eng = ReservoirEngine(res, num_slots=2, backend="scan", chunk_ticks=2, **kw)
        rng = np.random.default_rng(0)
        sessions = [
            StreamSession(
                sid=i, u_seq=rng.uniform(0, 0.5, (4, 1)).astype(np.float32),
                collect_states=False,
            )
            for i in range(8)
        ]
        return eng, eng.run(sessions)

    def test_max_retained_bounds_results(self):
        eng, results = self._serve(max_retained=3)
        assert len(results) == 3
        assert eng.scheduler.stats.retired == 8  # all served, oldest evicted

    def test_pop_results_drains(self):
        eng, results = self._serve()
        assert len(results) == 8
        popped = eng.pop_results()
        assert set(popped) == set(range(8))
        assert eng.results == {}
        assert eng.pop_results() == {}


class TestPlanValidation:
    def test_chunk_ticks_must_be_positive_int(self):
        with pytest.raises(ValueError, match="chunk_ticks"):
            ExecPlan(chunk_ticks=0)
        with pytest.raises(ValueError, match="chunk_ticks"):
            ExecPlan(chunk_ticks=-3)
        with pytest.raises(ValueError, match="chunk_ticks"):
            ExecPlan(chunk_ticks=2.5)
        with pytest.raises(ValueError, match="chunk_ticks"):
            ExecPlan(chunk_ticks=True)
        assert ExecPlan(chunk_ticks=16).chunk_ticks == 16

    def test_gather_dtype_must_be_dtype(self):
        with pytest.raises(ValueError, match="gather_dtype"):
            ExecPlan(gather_dtype="not-a-dtype")
        with pytest.raises(ValueError, match="gather_dtype"):
            ExecPlan(gather_dtype=object())
        assert ExecPlan(gather_dtype=jnp.bfloat16).gather_dtype is jnp.bfloat16
        assert ExecPlan(gather_dtype=None).gather_dtype is None

    def test_engine_rejects_chunk_ticks_with_compiled_sim(self):
        spec = make_spec(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=2))
        with pytest.raises(ValueError, match="chunk_ticks"):
            ReservoirEngine(sim, chunk_ticks=4)

    def test_engine_adopts_plan_chunk_ticks(self):
        spec = make_spec(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=2, chunk_ticks=8))
        assert ReservoirEngine(sim).chunk_ticks == 8

    def test_plan_replace_keeps_chunk_ticks(self):
        plan = ExecPlan(ensemble=4, chunk_ticks=8)
        assert dataclasses.replace(plan, ensemble=16).chunk_ticks == 8
