"""Property-based conservation tests (require the `hypothesis` dev extra).

Guarded with pytest.importorskip so a clean checkout without dev
requirements still collects and runs the rest of the suite; install
requirements-dev.txt to enable these.
"""

import pytest

pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from repro.core import (
    DT,
    default_params,
    integrate_scan,
    llg_field,
    make_coupling_matrix,
    norm_error,
)
from repro.kernels import ops
from repro.kernels import ref as kref


def _field(params, w):
    return lambda m, _: llg_field(m, params, w)


class TestCoreConservationProperty:
    @settings(max_examples=10, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(1, 12),
        steps=st.integers(10, 300),
    )
    def test_norm_conserved_property(self, seed, n, steps):
        """Conservation holds from ANY unit-norm initial state (|m|=1 is an
        invariant manifold of Eq. 1, [BMS09])."""
        p = default_params(jnp.float64)
        w = jnp.asarray(make_coupling_matrix(n, seed=seed % 1000), jnp.float64)
        rng = np.random.default_rng(seed)
        m0 = rng.standard_normal((n, 3))
        m0 /= np.linalg.norm(m0, axis=-1, keepdims=True)
        mT, _ = integrate_scan(_field(p, w), jnp.asarray(m0), DT, steps)
        # RK4 truncation drift ~3.5e-10/step; 300 steps => ~1e-7 headroom 10x
        assert float(norm_error(mT)) < 1e-6
        assert not bool(jnp.any(jnp.isnan(mT)))


class TestKernelConservationProperty:
    @settings(max_examples=8, deadline=None)
    @given(
        n=st.integers(1, 40),
        e=st.integers(1, 6),
        seed=st.integers(0, 10_000),
        steps=st.sampled_from([4, 8, 12]),
    )
    def test_kernel_conserves_norm_any_state(self, n, e, seed, steps):
        p = default_params(jnp.float32)
        w = jnp.asarray(make_coupling_matrix(n, seed=seed % 97), jnp.float32)
        rng = np.random.default_rng(seed)
        m0 = rng.standard_normal((e, n, 3)).astype(np.float32)
        m0 /= np.linalg.norm(m0, axis=-1, keepdims=True)
        pv = kref.pack_params(p, e, jnp.float32)
        out = ops.sto_rk4_integrate(
            jnp.asarray(m0), w, pv, float(DT), steps, impl="fused", interpret=True
        )
        assert float(norm_error(out)) < 1e-4
        assert np.all(np.isfinite(np.asarray(out)))
