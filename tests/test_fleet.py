"""Fleet serving tier: planner model, router placement, bit-exact
migration, async front-end admission, and the replica transports.

The correctness contract extends test_serve_reservoir's one level up:
anything the fleet does to a stream — placing it on a replica, pushing
ticks through the affinity map, checkpointing it out of one engine and
restoring it into another (process boundaries included) — must leave the
served states/outputs BIT-IDENTICAL to the same stream served by a
single unmigrated engine. The planner tests pin the analytical model's
self-consistency: fit recovery on a synthetic grid, scale-invariant fit
error under host recalibration, and sanity bounds on the committed
BENCH_serve.json grid.
"""

import asyncio
import os

import numpy as np
import pytest

from repro.serve.fleet import (
    AdmissionError,
    CapacityModel,
    FleetFrontend,
    FleetRouter,
    LocalReplica,
    WorkloadClass,
    start_fleet,
)
from repro.serve.reservoir import EngineStats, StreamSession

# tiny deterministic engine config shared by the correctness tests: the
# scan backend is the bit-exactness oracle everywhere else in tests/
ENGINE_KW = dict(
    n=10, num_slots=4, hold_steps=6, seed=3, backend="scan", chunk_ticks=5
)
BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")


def _stream(rng, t=23, n_in=1):
    return rng.uniform(0.0, 0.5, size=(t, n_in)).astype(np.float32)


def _serve_solo(u, targets=None, engine_kw=ENGINE_KW, **session_kw):
    """Reference: the same stream through one unmigrated LocalReplica."""
    rep = LocalReplica(**engine_kw)
    rep.submit(StreamSession(sid=0, u_seq=u, targets=targets, **session_kw))
    while rep.run_for(1):
        pass
    (res,) = rep.results()
    return res


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------


def _synthetic_bench(coef, burst_slowdown=1.5, k=8, h=5):
    """A grid generated FROM the model family: fit must recover it."""
    cells = []
    for n in (16, 64, 256):
        for e in (8, 32, 128):
            t = float(CapacityModel._features(n, e, k, h) @ np.asarray(coef))
            cells.append(
                dict(
                    n=n,
                    e=e,
                    steady_chunk_s=t,
                    ticks_per_sec_burst=e * k / (t * burst_slowdown),
                    learn_overhead=1.4,
                    precision_speedup=1.2,
                )
            )
    return dict(
        cells=cells,
        chunk_ticks=k,
        hold_steps=h,
        ref_stream_ticks=7,
        backend_platform="cpu",
    )


class TestPlanner:
    COEF = np.array([2e-4, 1e-6, 3e-12, 2e-10, 5e-13])

    def test_fit_recovers_synthetic_grid(self):
        m = CapacityModel.from_bench(_synthetic_bench(self.COEF))
        err = m.prediction_error()
        assert err["max"] < 1e-6  # noise-free grid: exact recovery
        assert err["sustained_max"] < 1e-6
        # sustained family carries the churn slowdown
        ratio = m.t_chunk(64, 32, sustained=True) / m.t_chunk(64, 32)
        assert ratio == pytest.approx(1.5, rel=1e-6)

    def test_multipliers_and_capacity_shape(self):
        m = CapacityModel.from_bench(_synthetic_bench(self.COEF))
        base = m.sessions_per_sec(64, 32)
        assert m.sessions_per_sec(64, 32, learn=True) == pytest.approx(
            base / 1.4, rel=1e-6
        )
        assert m.sessions_per_sec(64, 32, precision="mixed") == pytest.approx(
            base * 1.2, rel=1e-6
        )
        # fleet scaling is min(replicas, cores): never super-linear
        assert m.fleet_sessions_per_sec(
            64, 32, replicas=4, cores=2
        ) == pytest.approx(2 * base, rel=1e-6)
        with pytest.raises(ValueError):
            m.sessions_per_sec(64, 32, platform="gpu")

    def test_recalibrate_rescales_both_families(self):
        m = CapacityModel.from_bench(_synthetic_bench(self.COEF))
        d0 = m.drain_seconds(64, 32, sessions=16, stream_ticks=40, cores=1)
        err0 = m.prediction_error()
        # probe says the host now runs at half the calibration speed
        half_rate = 0.5 * 32 * m.chunk_ticks / m.t_chunk(64, 32, sustained=True)
        scale = m.recalibrate({64: {32: half_rate}})
        assert scale == pytest.approx(0.5, rel=1e-6)
        assert m.drain_seconds(
            64, 32, sessions=16, stream_ticks=40, cores=1
        ) == pytest.approx(2 * d0, rel=1e-6)
        # fit error is evaluated at calibration scale: recalibrating must
        # not flatter or damn the model's shape
        err1 = m.prediction_error()
        assert err1["max"] == pytest.approx(err0["max"], abs=1e-12)
        with pytest.raises(ValueError):
            m.recalibrate({})

    def test_plan_fleet_covers_offered_load(self):
        m = CapacityModel.from_bench(_synthetic_bench(self.COEF))
        plan = m.plan_fleet(
            [WorkloadClass(n=16, rate=50.0), WorkloadClass(n=256, rate=5.0)],
            headroom=0.2,
            cores=64,  # enough cores that replica counts are demand math
        )
        assert len(plan.replicas) == 2
        for spec in plan.replicas:
            offered = {16: 50.0, 256: 5.0}[spec.n]
            assert spec.count * spec.sessions_per_sec >= offered * 1.2
        assert 0.0 < plan.utilization <= 1.0 / 1.2 + 1e-9

    @pytest.mark.skipif(
        not os.path.exists(BENCH_PATH), reason="no committed BENCH_serve.json"
    )
    def test_committed_grid_sanity_bounds(self):
        """Predicted-vs-measured on the committed grid: the model must sit
        within the fit-error band the planner itself publishes (the ~30%
        acceptance bound lives on the cells the model calibrated on)."""
        m = CapacityModel.from_bench(BENCH_PATH)
        err = m.prediction_error()
        assert err["max"] < 0.35, err["per_cell"]
        if "sustained_max" in err:
            assert err["sustained_max"] < 0.35, err["per_cell_sustained"]
        # sustained (churn billed) can never beat peak by more than jitter
        for c in m.cells:
            assert m.t_chunk(c["n"], c["e"], sustained=True) > 0.5 * m.t_chunk(
                c["n"], c["e"]
            )


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


class TestRouter:
    def test_pools_are_bucketed_by_n(self):
        router = FleetRouter()
        for r in start_fleet(2, "local", **ENGINE_KW):
            router.add_replica(r)
        for r in start_fleet(1, "local", **{**ENGINE_KW, "n": 20}):
            router.add_replica(r)
        assert sorted(router.pools) == [10, 20]
        assert len(router.pool(10)) == 2
        with pytest.raises(KeyError):
            router.pool(1024)  # no cross-bucket head-of-line sharing
        router.close()

    def test_least_loaded_placement_and_affinity(self):
        rng = np.random.default_rng(0)
        router = FleetRouter()
        reps = start_fleet(2, "local", **ENGINE_KW)
        for r in reps:
            router.add_replica(r)
        owners = [
            router.submit(10, StreamSession(sid=i, u_seq=_stream(rng)))
            for i in range(4)
        ]
        # least-loaded placement alternates across the empty pool
        assert {owners.count(reps[0]), owners.count(reps[1])} == {2}
        for i, owner in enumerate(owners):
            assert router.replica_for(i) is owner
        with pytest.raises(ValueError):
            router.submit(10, StreamSession(sid=0, u_seq=_stream(rng)))
        out = router.drain()
        assert sorted(out) == [0, 1, 2, 3]
        with pytest.raises(KeyError):
            router.replica_for(0)  # affinity released on finish
        router.close()


# ---------------------------------------------------------------------------
# checkpoint -> migrate -> resume
# ---------------------------------------------------------------------------


class TestMigration:
    def test_midstream_migration_bit_exact(self):
        rng = np.random.default_rng(1)
        u = _stream(rng, t=23)
        control = _serve_solo(u)
        router = FleetRouter()
        for r in start_fleet(2, "local", **ENGINE_KW):
            router.add_replica(r)
        router.submit(10, StreamSession(sid=7, u_seq=u))
        src = router.replica_for(7)
        router.run_for(2)  # mid-stream: 10 of 23 ticks done
        dst = router.migrate(7)
        assert dst is not src and router.replica_for(7) is dst
        out = router.drain()
        np.testing.assert_array_equal(out[7].states, control.states)
        np.testing.assert_array_equal(out[7].final_m, control.final_m)
        router.close()

    def test_migration_with_inflight_rls_learner(self):
        """The hard case: P and Wl lanes of an in-progress RLS learner ride
        the checkpoint; the learned readout must finish bit-identical to
        never having moved."""
        kw = {**ENGINE_KW, "learn": "rls"}
        rng = np.random.default_rng(2)
        u, y = _stream(rng, t=23), _stream(rng, t=23)
        control = _serve_solo(u, targets=y, engine_kw=kw, learn_washout=3)
        router = FleetRouter()
        for r in start_fleet(2, "local", **kw):
            router.add_replica(r)
        router.submit(
            10, StreamSession(sid=1, u_seq=u, targets=y, learn_washout=3)
        )
        router.run_for(2)  # learner has already absorbed ticks
        router.migrate(1)
        out = router.drain()
        np.testing.assert_array_equal(
            np.asarray(out[1].learned_readout.w_out),
            np.asarray(control.learned_readout.w_out),
        )
        np.testing.assert_array_equal(out[1].predictions, control.predictions)
        np.testing.assert_array_equal(out[1].states, control.states)
        router.close()

    def test_migration_of_queued_session(self):
        """A session still waiting for a slot migrates too (checkpoint at
        t=0) and serves identically on the destination."""
        rng = np.random.default_rng(3)
        streams = [_stream(rng, t=12) for _ in range(5)]
        control = _serve_solo(streams[4])
        kw = {**ENGINE_KW, "num_slots": 2}
        router = FleetRouter()
        reps = start_fleet(2, "local", **kw)
        for r in reps:
            router.add_replica(r)
        # overload replica 0's queue by explicit submit, then migrate the
        # queued tail session to the idle replica
        for i, u in enumerate(streams):
            reps[0].submit(StreamSession(sid=i, u_seq=u))
            router._affinity[i] = reps[0]
        dst = router.migrate(4, dst=reps[1])
        assert dst is reps[1]
        out = router.drain()
        assert sorted(out) == [0, 1, 2, 3, 4]
        np.testing.assert_array_equal(out[4].states, control.states)
        router.close()


# ---------------------------------------------------------------------------
# async front-end
# ---------------------------------------------------------------------------


class TestFrontend:
    def _router(self, planner=None, replicas=2, **overrides):
        router = FleetRouter(planner=planner)
        for r in start_fleet(replicas, "local", **{**ENGINE_KW, **overrides}):
            router.add_replica(r)
        return router

    def test_submit_push_drain_round_trip(self):
        rng = np.random.default_rng(4)
        u = _stream(rng, t=23)
        control = _serve_solo(u)

        async def main():
            async with FleetFrontend(self._router()) as fleet:
                # closed streams
                sids = [
                    await fleet.submit_stream(10, _stream(rng)) for _ in range(3)
                ]
                # open stream fed in two pushes: must equal the one-shot serve
                osid = await fleet.submit_stream(10, u[:9], open=True)
                await fleet.push_ticks(osid, u[9:])
                await fleet.close_stream(osid)
                res = await fleet.result(osid)
                np.testing.assert_array_equal(res.states, control.states)
                rest = await fleet.drain_results()
                assert sorted(rest) == sorted(sids)

        asyncio.run(main())

    def test_pool_limit_and_admission_error(self):
        planner = CapacityModel.from_bench(
            _synthetic_bench(TestPlanner.COEF)
        )
        # a glacial host: the planner ceiling collapses to the slot floor
        planner.host_scale = 1e-9
        rng = np.random.default_rng(5)

        async def main():
            router = self._router(planner=None)
            async with FleetFrontend(router) as fleet:
                assert fleet.pool_limit(10) is None  # no planner: unlimited
            router = self._router(planner=planner)
            async with FleetFrontend(
                router, admit_window_s=0.01, max_waiters=0
            ) as fleet:
                limit = fleet.pool_limit(10)
                assert limit == 2 * ENGINE_KW["num_slots"]  # slot floor
                # open streams hold their slots forever -> a deterministic
                # full pool; the next submit must fail fast, not queue
                sids = [
                    await fleet.submit_stream(
                        10, _stream(rng, t=5), open=True
                    )
                    for _ in range(limit)
                ]
                with pytest.raises(AdmissionError):
                    await fleet.submit_stream(10, _stream(rng, t=5))
                for sid in sids:
                    await fleet.close_stream(sid)
                    await fleet.result(sid)

        asyncio.run(main())


# ---------------------------------------------------------------------------
# per-session n_out through the fleet
# ---------------------------------------------------------------------------


def test_per_session_n_out_round_trip():
    """Sessions with different readout widths share one replica: the
    q-column slice of the padded lane must bit-match each session served
    by an engine sized exactly to its own q."""
    from repro.core.reservoir import fit_ridge, make_reservoir
    from repro.core.reservoir import drive as res_drive

    rng = np.random.default_rng(6)
    res = make_reservoir(n=10, n_in=1, hold_steps=6, seed=3)
    u_fit = rng.uniform(0.0, 0.5, size=(40, 1)).astype(np.float32)
    _, states_fit = res_drive(res, u_fit)
    ro2 = fit_ridge(
        states_fit,
        rng.uniform(0.0, 0.5, size=(40, 2)).astype(np.float32),
        washout=4,
    )
    ro1 = fit_ridge(states_fit, u_fit[:, 0], washout=4)
    u = _stream(rng, t=17)
    narrow = _serve_solo(u, readout=ro1)  # engine n_out=1
    wide = _serve_solo(u, readout=ro2, engine_kw={**ENGINE_KW, "n_out": 2})

    router = FleetRouter()
    for r in start_fleet(1, "local", **{**ENGINE_KW, "n_out": 2}):
        router.add_replica(r)
    router.submit(10, StreamSession(sid=1, u_seq=u, readout=ro2))
    router.submit(10, StreamSession(sid=2, u_seq=u, readout=ro1))
    out = router.drain()
    # outputs are (T - readout washout, q): the q-slice never sees padding
    assert out[1].outputs.shape == (13, 2) and out[2].outputs.shape == (13, 1)
    np.testing.assert_array_equal(out[1].outputs, wide.outputs)
    np.testing.assert_array_equal(out[2].outputs, narrow.outputs)
    router.close()


# ---------------------------------------------------------------------------
# observability + process transport
# ---------------------------------------------------------------------------


def test_stats_through_replica_protocol():
    rng = np.random.default_rng(8)
    rep = LocalReplica(**ENGINE_KW)
    for i in range(3):
        rep.submit(StreamSession(sid=i, u_seq=_stream(rng, t=11)))
    rep.run_for(1)
    st = rep.stats()
    assert isinstance(st, EngineStats)
    assert st.n == 10 and st.num_slots == 4 and st.backend == "scan"
    assert st.active == 3 and 0.0 < st.occupancy <= 1.0
    assert st.chunk_median_s is not None and st.chunk_median_s > 0.0
    d = st.to_dict()
    assert d["active"] == 3 and d["ticks_per_sec"] > 0.0
    while rep.run_for(1):
        pass
    assert rep.stats().active == 0


@pytest.mark.parametrize("transport", ["process"])
def test_process_transport_end_to_end(transport):
    """One spawned replica: serve, stats, and a cross-process checkpoint
    restored into an in-process engine — all bit-exact with local."""
    rng = np.random.default_rng(9)
    u = _stream(rng, t=23)
    control = _serve_solo(u)
    (rep,) = start_fleet(1, transport, **ENGINE_KW)
    try:
        rep.submit(StreamSession(sid=5, u_seq=u))
        for _ in range(2):
            rep.run_for(1)
        st = rep.stats()
        assert st.active == 1 and st.backend == "scan"
        ckpt = rep.checkpoint_session(5)  # crosses the pipe as numpy
        local = LocalReplica(**ENGINE_KW)
        local.restore_session(ckpt)
        while local.run_for(1):
            pass
        (res,) = local.results()
        np.testing.assert_array_equal(res.states, control.states)
        np.testing.assert_array_equal(res.final_m, control.final_m)
    finally:
        rep.close()
