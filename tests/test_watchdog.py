"""End-to-end fault tolerance: the watchdog restarts a crashed training
subprocess, which resumes from its checkpoint and completes."""

import os
import sys
from pathlib import Path

import pytest

from repro.train.watchdog import run_supervised


@pytest.mark.slow
def test_watchdog_restarts_crashed_training(tmp_path):
    ckpt = tmp_path / "ckpt"
    cmd = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "xlstm-125m", "--reduced",
        "--steps", "8", "--batch", "2", "--seq", "16",
        "--ckpt-every", "2", "--ckpt-dir", str(ckpt),
        "--fail-at-step", "5",
    ]
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    rc = run_supervised(
        cmd,
        heartbeat=ckpt / "heartbeat.json",
        stall_s=600.0,  # crash path, not stall path
        max_restarts=2,
        poll_s=0.2,
        env=env,
    )
    assert rc == 0
    # final checkpoint is the last step
    steps = sorted(d.name for d in ckpt.glob("step_*"))
    assert steps and steps[-1] == "step_00000007"


def test_watchdog_gives_up(tmp_path):
    """A command that always fails exhausts max_restarts and reports it."""
    rc = run_supervised(
        [sys.executable, "-c", "import sys; sys.exit(3)"],
        heartbeat=tmp_path / "none.json",
        stall_s=60.0,
        max_restarts=1,
        poll_s=0.05,
    )
    assert rc == 3
