"""Per-kernel validation: Pallas STO kernels (interpret mode) vs the pure-jnp
oracle, swept over shapes/dtypes as the deliverable requires.

Property-based (hypothesis) variants live in tests/test_property_based.py so
this module collects on a clean checkout without dev extras."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DT,
    broadcast_params,
    default_params,
    initial_magnetization,
    integrate_scan,
    llg_field,
    make_coupling_matrix,
    norm_error,
)
from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels import sto_step


def _setup(n, e, dtype, seed=0):
    p = default_params(dtype)
    w = jnp.asarray(make_coupling_matrix(n, seed=seed), dtype)
    m0 = jnp.broadcast_to(initial_magnetization(n, dtype), (e, n, 3))
    key = jax.random.PRNGKey(seed)
    m0 = m0 + 0.01 * jax.random.normal(key, m0.shape, dtype)
    m0 = m0 / jnp.linalg.norm(m0, axis=-1, keepdims=True)
    pv = kref.pack_params(p, e, dtype)
    return p, w, m0, pv


def _core_reference(p, w, m0, steps):
    field = lambda m, _: llg_field(m, p, w)
    out, _ = integrate_scan(field, m0, DT, steps)
    return out


TOL = {jnp.float32: 5e-5}


class TestOracleLayout:
    @pytest.mark.parametrize("n,e", [(1, 1), (7, 3), (32, 5), (130, 2)])
    def test_planes_oracle_equals_core_field(self, n, e):
        p, w, m0, pv = _setup(n, e, jnp.float32)
        k_core = llg_field(m0, p, w)
        k_planes = kref.llg_field_planes(ops.to_planes(m0), w, pv)
        np.testing.assert_allclose(
            np.asarray(ops.from_planes(k_planes, (e,))),
            np.asarray(k_core),
            rtol=1e-5,
            atol=1e-2,  # field units are Oe*gamma ~ 1e10; atol scaled below
        )

    def test_layout_roundtrip(self):
        m = jax.random.normal(jax.random.PRNGKey(0), (5, 9, 3))
        np.testing.assert_array_equal(
            np.asarray(ops.from_planes(ops.to_planes(m), (5,))), np.asarray(m)
        )


class TestFusedKernel:
    @pytest.mark.parametrize(
        "n,e,steps,n_inner",
        [
            (1, 1, 8, 1),
            (4, 3, 8, 2),
            (32, 130, 6, 3),  # E forces padding to 256
            (100, 8, 8, 4),  # N not lane-aligned
            (128, 128, 4, 4),  # exactly aligned
        ],
    )
    def test_matches_core(self, n, e, steps, n_inner):
        p, w, m0, pv = _setup(n, e, jnp.float32)
        ref = _core_reference(p, w, m0, steps)
        out = ops.sto_rk4_integrate(
            m0, w, pv, float(DT), steps, impl="fused", n_inner=n_inner, interpret=True
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)
        assert float(norm_error(out)) < 1e-4

    def test_multi_step_fusion_equals_stepwise(self):
        """n_inner > 1 must not change the math, only the HBM traffic."""
        p, w, m0, pv = _setup(16, 4, jnp.float32)
        a = ops.sto_rk4_integrate(m0, w, pv, float(DT), 8, impl="fused", n_inner=1, interpret=True)
        b = ops.sto_rk4_integrate(m0, w, pv, float(DT), 8, impl="fused", n_inner=8, interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


class TestTiledKernel:
    @pytest.mark.parametrize(
        "n,e,steps",
        [
            (130, 4, 4),  # N padded to 256, two row tiles
            (256, 130, 2),  # two row tiles x two lane tiles
            (64, 64, 4),  # sub-tile shapes (padded up)
        ],
    )
    def test_matches_core(self, n, e, steps):
        p, w, m0, pv = _setup(n, e, jnp.float32)
        ref = _core_reference(p, w, m0, steps)
        out = ops.sto_rk4_integrate(
            m0, w, pv, float(DT), steps, impl="tiled", interpret=True
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5)

    def test_tiled_equals_fused(self):
        p, w, m0, pv = _setup(128, 128, jnp.float32)
        a = ops.sto_rk4_integrate(m0, w, pv, float(DT), 4, impl="tiled", interpret=True)
        b = ops.sto_rk4_integrate(m0, w, pv, float(DT), 4, impl="fused", interpret=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestDispatch:
    def test_auto_picks_fused_small(self):
        assert ops.fused_fits_vmem(512, 128)

    def test_auto_picks_tiled_large(self):
        assert not ops.fused_fits_vmem(4096, 128)

    def test_param_sweep_inside_kernel(self):
        """Per-lane parameters: three currents -> three distinct dynamics."""
        n, e = 8, 3
        base = default_params(jnp.float32)
        pe = broadcast_params(base, e, current=jnp.array([1e-3, 2.5e-3, 4e-3]))
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float32), (e, n, 3))
        pv = kref.pack_params(pe, e, jnp.float32)
        out = ops.sto_rk4_integrate(m0, w, pv, float(DT), 64, impl="fused", interpret=True)
        assert not np.allclose(np.asarray(out[0]), np.asarray(out[1]))
        # and matches the unbatched core integration per member
        from repro.core import STOParams

        for i, cur in enumerate([1e-3, 2.5e-3, 4e-3]):
            pi = base._replace(current=jnp.asarray(cur, jnp.float32))
            ref = _core_reference(pi, w, m0[i], 64)
            np.testing.assert_allclose(np.asarray(out[i]), np.asarray(ref), atol=5e-5)
