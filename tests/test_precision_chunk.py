"""ExecPlan.precision policies + the chunk-resident "chunk" impl.

Pins the PR-5 contracts:
  - precision=None / "highest" plans are BIT-exact against plans that
    predate the field, on every impl (the acceptance bar's "bit-exact vs
    current main on the scan backend" — and stronger: also on ref/chunk).
  - impl="chunk" (the chunk-resident K x hold x 4-stage region) agrees
    with the ref oracle to the bit on CPU, masks included; the Pallas
    rk4_chunk kernel agrees in interpret mode.
  - "bf16_coupling"/"mixed" deviate only at reduced-precision scale, and
    the task-level guardrail holds: NARMA-10 NMSE under "mixed" within
    10% of f32.
  - dispatch is precision-keyed with a fallback to the bit-exact entry;
    measure_impl_latency reports failed candidates instead of swallowing
    them; the persisted dispatch table round-trips v1 -> v2 without drops
    or collisions.
"""

from __future__ import annotations

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.api import ExecPlan, compile_plan, make_spec
from repro.api.plan import PLAN_PRECISIONS
from repro.kernels import dispatch_table, ops
from repro.kernels import ref as kref
from repro.kernels import rls as krls
from repro.kernels import sto_step
from repro.core import constants

N, N_IN, HOLD, E, K = 24, 2, 4, 4, 3
DTYPE = jnp.float32


def _spec(n=N):
    return make_spec(n=n, n_in=N_IN, hold_steps=HOLD, dtype=DTYPE, seed=3)


def _chunk_inputs(spec, e=E, k=K, seed=0):
    rng = np.random.default_rng(seed)
    m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (e, spec.n, 3)))
    u_block = rng.uniform(0.0, 0.5, (k, e, spec.n_in)).astype(np.float32)
    mask = np.ones((k, e), bool)
    mask[1, 1] = False  # a mid-chunk freeze, so masking is exercised
    return m0, jnp.asarray(u_block), jnp.asarray(mask)


class TestPrecisionValidation:
    def test_plan_precisions(self):
        for p in PLAN_PRECISIONS:
            assert ExecPlan(precision=p).precision == p

    def test_unknown_precision_rejected(self):
        with pytest.raises(ValueError, match="precision"):
            ExecPlan(precision="fp8")

    def test_scan_refuses_reduced_precision(self):
        with pytest.raises(ValueError, match="bit-exact oracle"):
            ExecPlan(impl="scan", precision="mixed")
        # the bit-exact aliases are fine on scan
        assert ExecPlan(impl="scan", precision="highest").effective_precision is None

    def test_effective_gather_dtype_subsumes_gather_dtype(self):
        assert ExecPlan().effective_gather_dtype is None
        assert ExecPlan(precision="bf16_coupling").effective_gather_dtype == jnp.bfloat16
        assert ExecPlan(precision="mixed").effective_gather_dtype == jnp.bfloat16
        # an explicit gather_dtype wins (backward compat)
        assert (
            ExecPlan(precision="mixed", gather_dtype=jnp.float16).effective_gather_dtype
            == jnp.float16
        )

    def test_chunk_requires_rk4(self):
        spec = make_spec(n=8, n_in=1, hold_steps=2, dtype=DTYPE, tableau="euler")
        with pytest.raises(ValueError, match="RK4 only"):
            compile_plan(spec, ExecPlan(impl="chunk"))


class TestBitExactDefault:
    """precision=None / "highest" must not perturb a single bit."""

    @pytest.mark.parametrize("impl", ["scan", "ref", "chunk"])
    def test_drive_batch_bit_exact(self, impl):
        spec = _spec()
        u = np.random.default_rng(1).uniform(0, 0.5, (6, N_IN)).astype(np.float32)
        base = compile_plan(spec, ExecPlan(impl=impl, ensemble=E)).drive_batch(u)
        for precision in (None, "highest"):
            if impl == "scan" and precision is None:
                continue  # identical object-level default; nothing to compare
            got = compile_plan(
                spec, ExecPlan(impl=impl, ensemble=E, precision=precision)
            ).drive_batch(u)
            assert np.array_equal(np.asarray(base[0]), np.asarray(got[0]))
            assert np.array_equal(np.asarray(base[1]), np.asarray(got[1]))

    def test_tick_chunk_bit_exact_scan(self):
        spec = _spec()
        m0, u_block, mask = _chunk_inputs(spec)
        base = compile_plan(
            spec, ExecPlan(impl="scan", ensemble=E, chunk_ticks=K)
        ).tick_chunk(m0, u_block, mask)
        got = compile_plan(
            spec, ExecPlan(impl="scan", ensemble=E, chunk_ticks=K, precision="highest")
        ).tick_chunk(m0, u_block, mask)
        assert np.array_equal(np.asarray(base[0]), np.asarray(got[0]))
        assert np.array_equal(np.asarray(base[1]), np.asarray(got[1]))


class TestChunkImpl:
    def test_chunk_matches_ref_tick_chunk_bitwise(self):
        spec = _spec()
        m0, u_block, mask = _chunk_inputs(spec)
        ref = compile_plan(
            spec, ExecPlan(impl="ref", ensemble=E, chunk_ticks=K)
        ).tick_chunk(m0, u_block, mask)
        chunk = compile_plan(
            spec, ExecPlan(impl="chunk", ensemble=E, chunk_ticks=K)
        ).tick_chunk(m0, u_block, mask)
        assert np.array_equal(np.asarray(ref[0]), np.asarray(chunk[0]))
        assert np.array_equal(np.asarray(ref[1]), np.asarray(chunk[1]))

    def test_chunk_frozen_lane_bit_identical(self):
        spec = _spec()
        m0, u_block, _ = _chunk_inputs(spec)
        mask = np.ones((K, E), bool)
        mask[:, 2] = False  # lane 2 frozen for the whole chunk
        sim = compile_plan(spec, ExecPlan(impl="chunk", ensemble=E, chunk_ticks=K))
        mT, _ = sim.tick_chunk(m0, u_block, jnp.asarray(mask))
        assert np.array_equal(np.asarray(mT[:, :, 2]), np.asarray(m0[:, :, 2]))

    def test_chunk_learn_matches_ref_learn_bitwise(self):
        spec = _spec()
        m0, u_block, mask = _chunk_inputs(spec)
        rng = np.random.default_rng(5)
        targets = rng.uniform(0, 0.5, (K, E, 1)).astype(np.float32)
        outs = {}
        for impl in ("ref", "chunk"):
            sim = compile_plan(
                spec,
                ExecPlan(impl=impl, ensemble=E, chunk_ticks=K, learn="rls",
                         learn_reg=1e-2),
            )
            p0, w0 = sim.init_learn_state()
            outs[impl] = sim.tick_chunk(
                m0, u_block, mask, targets=targets, learn_state=(p0, w0)
            )
        for a, b in zip(outs["ref"][:2], outs["chunk"][:2]):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(outs["ref"][2], outs["chunk"][2]):  # (P, W)
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert np.array_equal(np.asarray(outs["ref"][3]), np.asarray(outs["chunk"][3]))

    def test_chunk_per_window_pallas_path_matches_ref(self):
        """impl="chunk" is first-class at the per-hold-window entry points
        too: on TPU (here: interpret mode) it runs the Pallas rk4_chunk as
        a one-tick chunk, so a dispatch winner measured on the chunked
        shape stays a sane choice for tick()/drive()/integrate()."""
        spec = make_spec(n=128, n_in=1, hold_steps=2, dtype=DTYPE)
        u = np.random.default_rng(3).uniform(0, 0.5, (2, 1)).astype(np.float32)
        ref = compile_plan(spec, ExecPlan(impl="ref", ensemble=2)).drive_batch(u)
        chk = compile_plan(
            spec, ExecPlan(impl="chunk", ensemble=2, interpret=True)
        ).drive_batch(u)
        np.testing.assert_allclose(
            np.asarray(ref[1]), np.asarray(chk[1]), atol=1e-6
        )

    def test_pallas_rk4_chunk_interpret_matches_oracle(self):
        n, e, k, hold = 128, 128, 2, 3
        rng = np.random.default_rng(7)
        w = jnp.asarray(rng.standard_normal((n, n)) * 0.05, DTYPE)
        pv = kref.pack_params(constants.default_params(DTYPE), e, DTYPE)
        m = ops.to_planes(
            jnp.broadcast_to(constants.initial_magnetization(n, DTYPE), (e, n, 3))
        )
        h_block = jnp.asarray(rng.standard_normal((k, n, e)) * 0.1, DTYPE)
        mask = np.ones((k, e), bool)
        mask[0, 3:9] = False
        oracle = kref.rk4_chunk_planes(m, w, pv, 1e-11, hold, h_block, jnp.asarray(mask))
        kernel = sto_step.rk4_chunk(
            m, w, pv, 1e-11, hold, h_block,
            jnp.asarray(mask, DTYPE), interpret=True,
        )
        np.testing.assert_allclose(
            np.asarray(oracle[0]), np.asarray(kernel[0]), atol=1e-6
        )
        np.testing.assert_allclose(
            np.asarray(oracle[1]), np.asarray(kernel[1]), atol=1e-6
        )

    def test_tick_chunk_entry_auto_resolves_precision_key(self):
        """sto_rk4_tick_chunk_planes consults the precision-keyed table."""
        spec = _spec()
        m0, _, mask = _chunk_inputs(spec)
        h_block = jnp.zeros((K, spec.n, E), DTYPE)
        pv = kref.pack_params(constants.default_params(DTYPE), E, DTYPE)
        try:
            ops.register_impl_choice(
                spec.n, E, "chunk", platform=jax.default_backend(),
                precision="bf16_coupling",
            )
            out = ops.sto_rk4_tick_chunk_planes(
                m0, spec.w_cp, pv, float(spec.dt), HOLD, h_block, mask,
                impl="auto", precision="bf16_coupling",
            )
            assert out[0].shape == m0.shape
            assert out[1].shape == (K, spec.n, E)
        finally:
            ops._LATENCY_TABLE.clear()


class TestReducedPrecision:
    @pytest.mark.parametrize("impl", ["ref", "chunk"])
    @pytest.mark.parametrize("precision", ["bf16_coupling", "mixed"])
    def test_reduced_close_but_not_required_equal(self, impl, precision):
        spec = _spec()
        m0, u_block, mask = _chunk_inputs(spec)
        f32 = compile_plan(
            spec, ExecPlan(impl=impl, ensemble=E, chunk_ticks=K)
        ).tick_chunk(m0, u_block, mask)
        red = compile_plan(
            spec, ExecPlan(impl=impl, ensemble=E, chunk_ticks=K, precision=precision)
        ).tick_chunk(m0, u_block, mask)
        # reduced-precision coupling perturbs a ~1 Oe field against ~600 Oe
        # local terms: states stay close at bf16 scale over a few ticks
        np.testing.assert_allclose(
            np.asarray(f32[1]), np.asarray(red[1]), atol=2e-3
        )
        # the state carry stays f32
        assert red[0].dtype == DTYPE

    def test_mixed_learn_close_to_f32(self):
        spec = _spec()
        m0, u_block, mask = _chunk_inputs(spec)
        targets = np.random.default_rng(9).uniform(0, 0.5, (K, E, 1)).astype(np.float32)
        outs = {}
        for precision in (None, "mixed"):
            sim = compile_plan(
                spec,
                ExecPlan(impl="ref", ensemble=E, chunk_ticks=K, learn="rls",
                         learn_reg=1e-2, precision=precision),
            )
            outs[precision] = sim.tick_chunk(
                m0, u_block, mask, targets=targets,
                learn_state=sim.init_learn_state(),
            )
        w_f32 = np.asarray(outs[None][2][1])
        w_mix = np.asarray(outs["mixed"][2][1])
        assert np.all(np.isfinite(w_mix))
        np.testing.assert_allclose(w_f32, w_mix, atol=2e-3)

    def test_rls_update_upcasts_reduced_features(self):
        p0, w0 = krls.rls_init(2, 5, 1, 1e-2, jnp.float32)
        x = jnp.ones((2, 5), jnp.bfloat16)
        y = jnp.ones((2, 1), jnp.bfloat16)
        p1, w1, pred = krls.rls_update(p0, w0, x, y, jnp.ones(2, bool), 1.0)
        assert p1.dtype == jnp.float32 and w1.dtype == jnp.float32
        assert pred.dtype == jnp.float32

    def test_narma10_nmse_guardrail_mixed_within_10pct(self):
        """The acceptance guardrail: NARMA-10 NMSE under "mixed" within 10%
        of the f32 pipeline (same spec, same readout protocol)."""
        from repro.core.constants import default_params
        from repro.core.reservoir import fit_ridge, nmse, predict
        from repro.core import tasks

        params = default_params(DTYPE)._replace(a_in=jnp.float32(300.0))
        spec = make_spec(
            n=24, n_in=1, hold_steps=20, dtype=DTYPE, params=params
        )
        train, test, washout = 260, 80, 40
        u, y = tasks.narma_series(train + test, order=10, seed=0)
        u = u.astype(np.float32)[:, None]
        y = y.astype(np.float32)[:, None]
        scores = {}
        for precision in (None, "mixed"):
            sim = compile_plan(
                spec, ExecPlan(impl="ref", ensemble=1, precision=precision)
            )
            m_end, states = sim.drive_batch(u[:train])
            states = states[:, 0, :]
            # held-out evaluation resumes from the training endpoint
            _, test_states = sim.drive_batch(u[train:], m0=m_end)
            ro = fit_ridge(states, y[:train], washout=washout, reg=1e-2)
            pred = predict(ro._replace(washout=0), test_states[:, 0, :])
            scores[precision] = float(nmse(pred, jnp.asarray(y[train:])))
        assert scores[None] < 1.0, scores  # the task is actually learned
        assert scores["mixed"] <= scores[None] * 1.10, scores


class TestMeasureAndDispatch:
    def test_measure_impl_latency_records_failures(self):
        try:
            with pytest.warns(RuntimeWarning, match="excluded from dispatch"):
                t = ops.measure_impl_latency(
                    8, 4, n_steps=2, reps=1,
                    candidates=("ref", "fused"),  # fused cannot run on CPU
                )
            assert isinstance(t["ref"], float)
            assert "fused" in t["failed"]
            assert "ref" not in t["failed"]
            # the winner registration skipped the failed impl
            assert ops.choose_impl(8, 4) == "ref"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_measure_all_failed_registers_nothing(self):
        try:
            with pytest.warns(RuntimeWarning):
                t = ops.measure_impl_latency(
                    8, 4, n_steps=2, reps=1, candidates=("fused", "tiled")
                )
            assert set(t) == {"failed"}
            assert ops.latency_table() == {}
        finally:
            ops._LATENCY_TABLE.clear()

    def test_precision_keyed_choice_with_fallback(self):
        try:
            ops.register_impl_choice(64, 8, "tiled", platform="faux")
            # unmeasured reduced precision falls back to the bit-exact entry
            assert ops.choose_impl(64, 8, platform="faux", precision="mixed") == "tiled"
            ops.register_impl_choice(64, 8, "chunk", platform="faux", precision="mixed")
            assert ops.choose_impl(64, 8, platform="faux", precision="mixed") == "chunk"
            # and the bit-exact entry is untouched
            assert ops.choose_impl(64, 8, platform="faux") == "tiled"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_v1_table_migrates_without_drops_or_collisions(self, tmp_path):
        """Satellite: the old (pre-precision) table format keeps loading —
        entries land on the bit-exact default key, round-trip to v2, and
        coexist with new precision-aware entries at the same shape."""
        v1 = {
            "format": "repro-dispatch-table-v1",
            "platform": "faux",
            "entries": [
                {"n_pad": 128, "e_pad": 128, "itemsize": 4, "impl": "ref"},
                {"n_pad": 1024, "e_pad": 256, "itemsize": 4, "impl": "tiled"},
            ],
        }
        path = tmp_path / "dispatch_table.faux.json"
        path.write_text(json.dumps(v1))
        try:
            assert dispatch_table.load_table(str(path), platform="faux") == 2
            table = ops.latency_table()
            assert table[("faux", 128, 128, 4, "highest")] == "ref"
            assert table[("faux", 1024, 256, 4, "highest")] == "tiled"
            # a precision-aware entry at the same shape must NOT collide
            ops.register_impl_choice(
                1024, 256, "chunk", platform="faux", precision="mixed"
            )
            out = tmp_path / "dispatch_table.faux.v2.json"
            dispatch_table.save_table(str(out), platform="faux")
            payload = json.loads(out.read_text())
            assert payload["format"] == "repro-dispatch-table-v2"
            assert len(payload["entries"]) == 3
            ops._LATENCY_TABLE.clear()
            assert dispatch_table.load_table(str(out), platform="faux") == 3
            table = ops.latency_table()
            assert table[("faux", 1024, 256, 4, "highest")] == "tiled"
            assert table[("faux", 1024, 256, 4, "mixed")] == "chunk"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_unknown_table_format_rejected(self, tmp_path):
        path = tmp_path / "dispatch_table.faux.json"
        path.write_text(json.dumps({"format": "repro-dispatch-table-v99",
                                    "platform": "faux", "entries": []}))
        with pytest.raises(ValueError, match="unknown dispatch-table format"):
            dispatch_table.load_table(str(path), platform="faux")

    def test_committed_cpu_table_still_loads(self):
        """The committed v1 dispatch_table.cpu.json (or its v2 refresh)
        keeps loading through the migration path."""
        committed = dispatch_table.table_path("cpu")
        assert os.path.exists(committed)
        try:
            ops._LATENCY_TABLE.clear()
            dispatch_table.reset_loaded()
            n = dispatch_table.ensure_loaded("cpu")
            assert n > 0
            assert all(len(k) == 5 for k in ops.latency_table())
        finally:
            ops._LATENCY_TABLE.clear()
            dispatch_table.reset_loaded()


class TestServingWithPrecision:
    def test_engine_serves_mixed_precision_sessions(self):
        from repro.serve.reservoir import ReservoirEngine, StreamSession

        spec = _spec()
        rng = np.random.default_rng(13)
        results = {}
        for precision in (None, "mixed"):
            eng = ReservoirEngine(
                compile_plan(
                    spec,
                    ExecPlan(impl="chunk", ensemble=E, chunk_ticks=K,
                             precision=precision),
                )
            )
            assert eng.precision == ("highest" if precision is None else "mixed")
            sessions = [
                StreamSession(
                    sid=i,
                    u_seq=np.random.default_rng(i).uniform(
                        0, 0.5, (6, N_IN)
                    ).astype(np.float32),
                )
                for i in range(E + 2)  # forces a retire/admit wave
            ]
            results[precision] = eng.run(sessions)
        assert set(results[None]) == set(results["mixed"])
        for sid in results[None]:
            np.testing.assert_allclose(
                results[None][sid].states, results["mixed"][sid].states,
                atol=2e-3,
            )

    def test_engine_precision_is_a_plan_decision(self):
        from repro.serve.reservoir import ReservoirEngine

        sim = compile_plan(_spec(), ExecPlan(ensemble=2))
        with pytest.raises(ValueError, match="ExecPlan decisions"):
            ReservoirEngine(sim, precision="mixed")

    def test_engine_template_route_accepts_precision(self):
        from repro.serve.reservoir import ReservoirEngine

        eng = ReservoirEngine(
            _spec(), num_slots=2, backend="ref", precision="bf16_coupling"
        )
        assert eng.precision == "bf16_coupling"
