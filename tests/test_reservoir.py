"""Reservoir-computing pipeline tests: drive -> states -> ridge readout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core import (
    drive,
    fit_ridge,
    make_reservoir,
    nmse,
    norm_error,
    predict,
)
from repro.core import tasks


class TestDrive:
    def test_states_shape_and_sanity(self):
        res = make_reservoir(n=16, n_in=1, hold_steps=20, dtype=jnp.float64)
        u = np.random.default_rng(0).uniform(0, 0.5, size=(50, 1))
        mT, states = drive(res, jnp.asarray(u))
        assert states.shape == (50, 16)
        assert mT.shape == (16, 3)
        assert float(norm_error(mT)) < 5e-6
        # node states are x-magnetizations, bounded by 1
        assert float(jnp.max(jnp.abs(states))) <= 1.0 + 1e-9

    def test_input_drives_dynamics(self):
        res = make_reservoir(n=8, n_in=1, hold_steps=20, dtype=jnp.float64)
        u0 = jnp.zeros((30, 1))
        u1 = jnp.ones((30, 1)) * 0.5
        _, s0 = drive(res, u0)
        _, s1 = drive(res, u1)
        assert not np.allclose(np.asarray(s0), np.asarray(s1))


class TestRidge:
    def test_exact_linear_recovery(self):
        """Ridge with tiny reg recovers an exact linear map of the states."""
        rng = np.random.default_rng(1)
        states = jnp.asarray(rng.standard_normal((200, 10)))
        w_true = rng.standard_normal((10, 2))
        b_true = rng.standard_normal(2)
        y = states @ w_true + b_true
        ro = fit_ridge(states, jnp.asarray(y), washout=0, reg=1e-12)
        pred = predict(ro, states)
        np.testing.assert_allclose(np.asarray(pred), np.asarray(y), atol=1e-6)

    def test_normal_equations_property(self):
        """The ridge solution satisfies (X^T X + reg I) W = X^T Y exactly."""
        rng = np.random.default_rng(2)
        states = jnp.asarray(rng.standard_normal((64, 7)))
        y = jnp.asarray(rng.standard_normal((64, 3)))
        reg = 0.37
        ro = fit_ridge(states, y, washout=0, reg=reg)
        xb = np.concatenate([np.asarray(states), np.ones((64, 1))], axis=1)
        lhs = (xb.T @ xb + reg * np.eye(8)) @ np.asarray(ro.w_out)
        rhs = xb.T @ np.asarray(y)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-6)

    def test_washout_applied(self):
        rng = np.random.default_rng(3)
        states = jnp.asarray(rng.standard_normal((50, 4)))
        y = jnp.asarray(rng.standard_normal((50, 1)))
        ro = fit_ridge(states, y, washout=10)
        assert predict(ro, states).shape == (40, 1)


class TestEndToEnd:
    def test_narma_beats_trivial_baseline(self):
        """A small STO reservoir must beat the mean-predictor on NARMA-2.

        (NARMA-10 needs longer sequences/washout than a unit test allows; the
        full-scale version lives in examples/narma_benchmark.py.)
        """
        u, y = tasks.narma_series(300, order=2, seed=0)
        res = make_reservoir(n=32, n_in=1, hold_steps=50, dtype=jnp.float64)
        _, states = drive(res, jnp.asarray(u[:, None]))
        washout = 50
        ro = fit_ridge(states, jnp.asarray(y[:, None]), washout=washout, reg=1e-8)
        pred = predict(ro, states)
        err = nmse(pred, jnp.asarray(y[washout:, None]))
        assert err < 1.0  # mean predictor has NMSE ~ 1
        assert np.isfinite(err)

    def test_memory_capacity_positive(self):
        rng = np.random.default_rng(4)
        u = rng.uniform(-1, 1, 400)
        res = make_reservoir(n=24, n_in=1, hold_steps=30, dtype=jnp.float64)
        _, states = drive(res, jnp.asarray(u[:, None]))
        targets = tasks.delay_memory_targets(u, max_delay=5)
        washout = 60
        ro = fit_ridge(states, jnp.asarray(targets), washout=washout, reg=1e-8)
        pred = np.asarray(predict(ro, states))
        mc = tasks.memory_capacity(pred, targets[washout:])
        assert mc > 0.3


class TestTasks:
    def test_narma_bounded(self):
        u, y = tasks.narma_series(500, order=10, seed=1)
        assert np.all(np.isfinite(y))
        assert len(u) == len(y) == 500

    def test_delay_targets(self):
        u = np.arange(10.0)
        tg = tasks.delay_memory_targets(u, 3)
        assert tg.shape == (10, 3)
        assert tg[5, 0] == u[4] and tg[5, 2] == u[2]
