"""Component-level property tests: RoPE, Mamba chunk invariance, MoE
determinism, norms, chunked-CE equivalence, q-chunked attention parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config, reduce_config
from repro.models import layers
from repro.models.attention import grouped_attend, _grouped_attend_dense
from repro.models import mamba as mamba_mod


class TestRope:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), d=st.sampled_from([16, 32, 64]))
    def test_rope_preserves_norm(self, seed, d):
        """Rotation: per-pair norms (hence vector norm) are invariant."""
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (2, 8, 4, d))
        pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
        ang = layers.rope_freqs(pos, d, 10_000.0)
        y = layers.apply_rope(x, ang)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j (the RoPE identity)."""
        d = 32
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (d,))
        k = jax.random.normal(jax.random.PRNGKey(1), (d,))

        def dot_at(i, j):
            pos = jnp.array([[i, j]])
            ang = layers.rope_freqs(pos, d, 10_000.0)
            qk = jnp.stack([q, k])[None, :, None, :]  # (1,2,1,d)
            r = layers.apply_rope(qk, ang)
            return float(jnp.dot(r[0, 0, 0], r[0, 1, 0]))

        a = dot_at(3, 7)
        b = dot_at(10, 14)  # same offset 4
        np.testing.assert_allclose(a, b, rtol=1e-4)

    def test_position_zero_is_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 2, 16))
        ang = layers.rope_freqs(jnp.zeros((1, 1), jnp.int32), 16, 10_000.0)
        np.testing.assert_allclose(
            np.asarray(layers.apply_rope(x, ang)), np.asarray(x), atol=1e-7
        )


class TestMambaChunks:
    @pytest.mark.parametrize("chunk", [4, 8, 16, 64])
    def test_chunk_size_invariance(self, chunk):
        """The chunked associative scan must be exactly independent of the
        chunk size (including the non-divisible remainder path)."""
        import dataclasses

        cfg0 = reduce_config(get_config("jamba-1.5-large-398b"))
        cfg = dataclasses.replace(
            cfg0, mamba=dataclasses.replace(cfg0.mamba, chunk=chunk)
        )
        p = mamba_mod.make_mamba(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 22, cfg.d_model))
        out = mamba_mod.mamba_forward(p, cfg, x)
        cfg_ref = dataclasses.replace(
            cfg0, mamba=dataclasses.replace(cfg0.mamba, chunk=22)
        )
        ref = mamba_mod.mamba_forward(p, cfg_ref, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


class TestAttentionChunking:
    def test_qchunk_equals_dense(self):
        """The q-chunked scan path must equal the dense path exactly."""
        import repro.models.attention as attn

        key = jax.random.PRNGKey(0)
        b, h, kvh, s, d = 1, 4, 2, 64, 16
        q = jax.random.normal(key, (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, d))
        dense = _grouped_attend_dense(q, k, v, causal=True, q_offset=0)
        old_t, old_c = attn.Q_CHUNK_THRESHOLD, attn.Q_CHUNK
        try:
            attn.Q_CHUNK_THRESHOLD, attn.Q_CHUNK = 32, 16
            chunked = grouped_attend(q, k, v, causal=True, q_offset=0)
        finally:
            attn.Q_CHUNK_THRESHOLD, attn.Q_CHUNK = old_t, old_c
        np.testing.assert_allclose(
            np.asarray(chunked), np.asarray(dense), atol=1e-5
        )


class TestLossAndNorms:
    def test_chunked_ce_equals_dense(self):
        v, d, b, s = 50, 16, 2, 23
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (b, s, d))
        w = jax.random.normal(jax.random.PRNGKey(1), (d, 64))  # padded vocab
        labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
        dense_logits = jnp.einsum("bsd,dv->bsv", x, w)
        ref = layers.cross_entropy_loss(dense_logits, labels, v)
        chunked = layers.cross_entropy_from_features(x, w, labels, v, chunk=7)
        np.testing.assert_allclose(float(chunked), float(ref), rtol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_rmsnorm_scale_invariance(self, seed):
        """rmsnorm(a*x) == rmsnorm(x) for a > 0 (the defining property)."""
        x = jax.random.normal(jax.random.PRNGKey(seed), (4, 32))
        p = layers.make_norm("rmsnorm", 32, jnp.float32)
        a = layers.apply_norm(p, x)
        b = layers.apply_norm(p, 3.7 * x)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)

    def test_layernorm_moments(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 5 + 3
        p = layers.make_norm("layernorm", 64, jnp.float32)
        y = np.asarray(layers.apply_norm(p, x))
        np.testing.assert_allclose(y.mean(-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(y.std(-1), 1.0, atol=1e-2)


class TestMoEDeterminism:
    def test_routing_is_permutation_stable(self):
        """Routing decisions are per-token: permuting the batch permutes
        outputs identically (no cross-token leakage except capacity, which
        the dropless reduced config disables)."""
        from repro.models import moe as moe_mod

        cfg = reduce_config(get_config("deepseek-v2-lite-16b"))
        p = moe_mod.make_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        y, _ = moe_mod.apply_moe(p, cfg, x)
        perm = jnp.array([2, 0, 3, 1])
        y_p, _ = moe_mod.apply_moe(p, cfg, x[perm])
        np.testing.assert_allclose(
            np.asarray(y[perm]), np.asarray(y_p), atol=2e-5
        )
