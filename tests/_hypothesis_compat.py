"""Hypothesis import shim for mixed test modules.

Modules that are *mostly* property-based guard themselves with
pytest.importorskip("hypothesis") (tests/test_property_based.py). Modules
that mix a few property tests into otherwise-plain suites import the
decorators from here instead: with hypothesis installed they get the real
thing; without it the @given tests become individually-skipped tests and the
rest of the module still collects and runs.
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAS_HYPOTHESIS = True
except ModuleNotFoundError:
    HAS_HYPOTHESIS = False

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return lambda f: pytest.mark.skip(
            "hypothesis not installed (pip install -r requirements-dev.txt)"
        )(f)

    class _StrategyStub:
        """Evaluates strategy expressions in decorator args to inert Nones."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
