"""Per-architecture smoke tests (REDUCED configs, same family/topology):
forward + train-step shapes & finiteness, and prefill+decode == full forward
(validates KV caches, MLA absorption, mamba/xlstm recurrences, SWA masks,
cross-attention)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs, reduce_config
from repro.models import build_model, concrete_batch, count_params
from repro.models import transformer
from repro.models.layers import embed_tokens

ARCHS = list_configs()
T, T0, B = 24, 20, 2


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduce_config(get_config(name))
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, m, params)
        return cache[name]

    return get


def _train_batch(cfg, key, seq=T, batch=B):
    cell = dataclasses.replace(SHAPES["train_4k"], seq_len=seq, global_batch=batch)
    return concrete_batch(cfg, cell, key, enc_seq=16)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_loss(built, name):
    cfg, m, params = built(name)
    batch = _train_batch(cfg, jax.random.PRNGKey(1))
    logits = m.forward(params, batch)
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = m.loss_fn(params, batch)
    assert bool(jnp.isfinite(loss))
    # near-uniform init: CE should be close to ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(metrics["ce"]) < 2.5 * np.log(
        cfg.vocab_size
    )


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_gradients(built, name):
    """One SGD step: grads exist, are finite, and change the loss."""
    cfg, m, params = built(name)
    batch = _train_batch(cfg, jax.random.PRNGKey(2))
    (loss0, _), grads = jax.value_and_grad(m.loss_fn, has_aux=True)(params, batch)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    params2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype), params, grads)
    loss1, _ = m.loss_fn(params2, batch)
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("name", ARCHS)
def test_decode_matches_forward(built, name):
    cfg, m, params = built(name)
    key = jax.random.PRNGKey(3)
    batch = _train_batch(cfg, key)
    batch.pop("labels", None)
    batch.pop("loss_mask", None)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    if cfg.input_mode == "embeddings" and not cfg.encoder_layers:
        batch.pop("inputs_embeds", None)
        batch["inputs_embeds"] = embed_tokens(params["embed"], tokens)
    else:
        batch["tokens"] = tokens

    full = m.forward(params, batch)
    pre = dict(batch)
    if "inputs_embeds" in batch:
        pre["inputs_embeds"] = batch["inputs_embeds"][:, :T0]
    else:
        pre["tokens"] = tokens[:, :T0]
    last, caches = m.prefill(params, pre)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full[:, T0 - 1]), atol=5e-4
    )
    caches = transformer.pad_caches(cfg, caches, T)
    for i in range(T0, T):
        pos = jnp.full((B,), i, jnp.int32)
        lg, caches = m.decode_step(params, tokens[:, i : i + 1], caches, pos)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, i]), atol=5e-4
        )


@pytest.mark.parametrize("name", ARCHS)
def test_param_count_matches_analytic(built, name):
    """models/counting.py must agree with the real init (reduced config)."""
    cfg, m, params = built(name)
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = count_params(cfg)
    # counting.py approximates small norm params; demand < 2% discrepancy
    assert abs(actual - analytic) / actual < 0.02, (actual, analytic)


@pytest.mark.parametrize(
    "name,expected_b",
    [
        ("phi4-mini-3.8b", 3.8e9),
        ("gemma-7b", 8.5e9),  # gemma-7b counts 8.5B with embeddings
        ("command-r-plus-104b", 104e9),
        ("h2o-danube-1.8b", 1.8e9),
        ("jamba-1.5-large-398b", 398e9),
        ("deepseek-v2-lite-16b", 16e9),
        ("qwen2-moe-a2.7b", 14e9),  # A2.7B *active*; total ~14B
        ("llava-next-mistral-7b", 7e9),
        ("xlstm-125m", 125e6),
    ],
)
def test_full_config_param_counts(name, expected_b):
    """Analytic full-size counts land near the advertised sizes."""
    cfg = get_config(name)
    n = count_params(cfg)
    assert 0.6 * expected_b < n < 1.6 * expected_b, f"{name}: {n/1e9:.1f}B"


def test_causality_property():
    """Future tokens must not affect earlier logits (causal masking)."""
    cfg = reduce_config(get_config("phi4-mini-3.8b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, 16), 0, cfg.vocab_size)
    a = m.forward(params, {"tokens": tokens})
    tokens2 = tokens.at[0, -1].set((tokens[0, -1] + 7) % cfg.vocab_size)
    b = m.forward(params, {"tokens": tokens2})
    np.testing.assert_allclose(
        np.asarray(a[0, :-1]), np.asarray(b[0, :-1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(a[0, -1]), np.asarray(b[0, -1]))


def test_moe_router_mass_conserved():
    """Combine weights per token sum to <= 1 (== 1 when nothing dropped)."""
    from repro.models import moe as moe_mod

    cfg = reduce_config(get_config("qwen2-moe-a2.7b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(5), (2, 16, cfg.d_model))
    p_moe = jax.tree.map(lambda l: l[0], params["stack"][0]["mlp"])
    y, aux = moe_mod.apply_moe(p_moe, cfg, x.astype(jnp.float32))
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0.0  # switch aux loss is positive by construction
