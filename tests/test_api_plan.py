"""Unified SimSpec/ExecPlan execution API: equivalence + dispatch contracts.

The acceptance bar for the api_redesign: `repro.api.compile_plan` is the
single place execution decisions are made, the legacy entry points are
shims over it with NUMERICALLY IDENTICAL results (bit-exact for the scan
paths), sharded plans match unsharded on a 1-device mesh, and the
measured-latency dispatch table survives a process restart via JSON.
"""

import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import (
    DT,
    broadcast_params,
    default_params,
    drive,
    fit_ridge,
    initial_magnetization,
    integrate_ensemble,
    integrate_ensemble_sharded,
    make_coupling_matrix,
    make_reservoir,
)
from repro.kernels import dispatch_table, ops
from repro.serve.reservoir import ReservoirEngine, StreamSession

ATOL = 5e-5  # the kernel test suite's f32 cross-impl tolerance

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spec(n=8, hold_steps=5, dtype=jnp.float32, **kw):
    return api.make_spec(n=n, n_in=1, hold_steps=hold_steps, dtype=dtype, **kw)


def _u(t=6, n_in=1, seed=0):
    return np.random.default_rng(seed).uniform(0.0, 0.5, (t, n_in)).astype(np.float32)


class TestCompiledEquivalence:
    def test_scan_drive_bitexact_with_legacy_drive(self):
        """The legacy drive shim and an explicit impl='scan' plan run the
        same jit'd op sequence — results are bit-identical."""
        res = make_reservoir(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        u = _u()
        sim = api.compile_plan(api.SimSpec.from_reservoir(res), impl="scan")
        mT_a, s_a = sim.drive(u)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            mT_b, s_b = drive(res, u)
        np.testing.assert_array_equal(np.asarray(s_a), np.asarray(s_b))
        np.testing.assert_array_equal(np.asarray(mT_a), np.asarray(mT_b))

    def test_scan_drive_resume_m0(self):
        sim = api.compile_plan(_spec(), impl="scan")
        u = _u(10)
        _, full = sim.drive(u)
        m_half, s_a = sim.drive(u[:5])
        _, s_b = sim.drive(u[5:], m0=m_half)
        np.testing.assert_array_equal(
            np.asarray(jnp.concatenate([s_a, s_b])), np.asarray(full)
        )

    @pytest.mark.parametrize("impl,interpret", [("ref", False), ("fused", True), ("tiled", True)])
    def test_planes_impls_match_scan(self, impl, interpret):
        spec = _spec()
        u = _u()
        _, s_scan = api.compile_plan(spec, impl="scan").drive(u)
        _, s = api.compile_plan(spec, impl=impl, interpret=interpret).drive(u)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_scan), atol=ATOL)

    def test_drive_batch_matches_per_lane_solo_drive(self):
        """Each lane of a swept-parameter batch drive == a solo drive with
        that lane's params (the integrate_ensemble-based driving contract)."""
        spec = _spec(hold_steps=4)
        e = 3
        currents = [1e-3, 2.5e-3, 4e-3]
        pe = broadcast_params(spec.params, e, current=jnp.asarray(currents))
        u = _u(5)
        sim = api.compile_plan(spec._replace(params=pe), impl="scan", ensemble=e)
        _, states = sim.drive_batch(u)  # (T, E, N)
        for i, cur in enumerate(currents):
            solo_spec = spec._replace(
                params=spec.params._replace(current=jnp.asarray(cur, jnp.float32))
            )
            _, s_solo = api.compile_plan(solo_spec, impl="scan").drive(u)
            np.testing.assert_allclose(
                np.asarray(states[:, i]), np.asarray(s_solo), atol=ATOL,
                err_msg=f"lane {i}",
            )

    def test_drive_batch_per_lane_inputs(self):
        """(T, E, N_in) per-lane input: each lane == solo drive of its series."""
        spec = _spec(hold_steps=4)
        e = 2
        u_lanes = [_u(5, seed=1), _u(5, seed=2)]
        u_e = np.stack(u_lanes, axis=1)  # (T, E, 1)
        sim = api.compile_plan(spec, impl="scan", ensemble=e)
        solo = api.compile_plan(spec, impl="scan")
        _, states = sim.drive_batch(u_e)
        for i in range(e):
            _, s_solo = solo.drive(u_lanes[i])
            np.testing.assert_allclose(
                np.asarray(states[:, i]), np.asarray(s_solo), atol=ATOL
            )

    def test_integrate_bitexact_with_legacy_ensemble(self):
        n, e = 8, 4
        p = default_params(jnp.float32)
        pe = broadcast_params(p, e, current=jnp.linspace(1e-3, 4e-3, e))
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float32), (e, n, 3))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ref, traj_ref = integrate_ensemble(pe, w, m0, DT, 20, save_every=10)
        spec = api.SimSpec(
            params=pe, w_cp=w, w_in=jnp.zeros((n, 1), jnp.float32),
            m0=m0[0], dt=DT, hold_steps=1,
        )
        sim = api.compile_plan(spec, impl="scan", ensemble=e)
        out, traj = sim.integrate(20, m0=m0, save_every=10)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(traj), np.asarray(traj_ref))

    def test_integrate_planes_impl_close(self):
        n, e = 8, 4
        spec = _spec(n)
        pe = broadcast_params(spec.params, e)
        m0 = jnp.broadcast_to(spec.m0, (e, n, 3))
        sspec = spec._replace(params=pe)
        ref, _ = api.compile_plan(sspec, impl="scan", ensemble=e).integrate(20, m0=m0)
        out, _ = api.compile_plan(sspec, impl="ref", ensemble=e).integrate(20, m0=m0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=ATOL)

    def test_drive_requires_solo_plan(self):
        sim = api.compile_plan(_spec(), impl="scan", ensemble=4)
        with pytest.raises(ValueError, match="drive_batch"):
            sim.drive(_u())

    def test_batch_u_shape_contract(self):
        sim = api.compile_plan(_spec(), impl="scan", ensemble=4)
        with pytest.raises(ValueError, match=r"\(T, 4, 1\)"):
            sim.drive_batch(np.zeros((5, 3, 1), np.float32))

    def test_non_rk4_tableau_rejected_on_kernel_impls(self):
        with pytest.raises(ValueError, match="RK4"):
            api.compile_plan(_spec(tableau="heun"), impl="fused")
        # ...but fine on the core-layout path
        api.compile_plan(_spec(tableau="heun"), impl="scan").drive(_u())


class TestShardedPlans:
    def _mesh(self):
        return jax.make_mesh((1, 1), ("data", "model"))

    def test_sharded_matches_unsharded_1device(self):
        spec = _spec(hold_steps=4)
        e, u = 4, _u(5)
        mesh = self._mesh()
        sh = api.compile_plan(spec, api.ExecPlan(ensemble=e, mesh=mesh))
        un = api.compile_plan(spec, impl="scan", ensemble=e)
        mT_s, s_s = sh.drive_batch(u)
        mT_u, s_u = un.drive_batch(u)
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_u), atol=1e-6)
        np.testing.assert_allclose(np.asarray(mT_s), np.asarray(mT_u), atol=1e-6)
        out_s, _ = sh.integrate(20)
        out_u, _ = un.integrate(20)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_u), atol=1e-6)

    def test_sharded_tick_masks_and_matches(self):
        spec = _spec(hold_steps=4)
        e = 4
        mesh = self._mesh()
        sh = api.compile_plan(spec, api.ExecPlan(ensemble=e, mesh=mesh))
        un = api.compile_plan(spec, impl="scan", ensemble=e)
        m = jnp.broadcast_to(jnp.transpose(spec.m0)[:, :, None], (3, spec.n, e))
        u_t = jnp.asarray(_u(e).reshape(e, 1))
        mask = jnp.asarray([True, False, True, True])
        m_s, st_s = sh.tick(m, u_t, mask)
        m_u, st_u = un.tick(m, u_t, mask)
        np.testing.assert_allclose(np.asarray(m_s), np.asarray(m_u), atol=1e-6)
        np.testing.assert_allclose(np.asarray(st_s), np.asarray(st_u), atol=1e-6)
        # frozen lane is bit-identical to its input
        np.testing.assert_array_equal(np.asarray(m_s[:, :, 1]), np.asarray(m[:, :, 1]))

    def test_sharded_serving_engine(self):
        """Sharded serving falls out as ExecPlan(mesh=...): the engine on a
        1-device mesh serves streams that match solo drive references."""
        spec = _spec(n=8, hold_steps=5)
        sim = api.compile_plan(spec, api.ExecPlan(ensemble=3, mesh=self._mesh()))
        eng = ReservoirEngine(sim)
        solo = api.compile_plan(spec, impl="scan")
        rng = np.random.default_rng(3)
        sessions, refs = [], {}
        for sid in range(5):
            u = rng.uniform(0.0, 0.5, size=(4 + sid, 1)).astype(np.float32)
            _, states = solo.drive(jnp.asarray(u))
            refs[sid] = states
            sessions.append(StreamSession(sid=sid, u_seq=u))
        results = eng.run(sessions)
        assert set(results) == set(refs)
        for sid, r in results.items():
            np.testing.assert_allclose(
                np.asarray(r.states), np.asarray(refs[sid]), atol=ATOL,
                err_msg=f"session {sid}",
            )

    def test_sharded_plan_rejects_kernel_impls(self):
        with pytest.raises(ValueError, match="mesh"):
            api.ExecPlan(impl="fused", mesh=self._mesh())


class TestEngineCompiledSim:
    def test_engine_from_compiled_sim_matches_solo(self):
        spec = _spec(n=8, hold_steps=5)
        eng = ReservoirEngine(api.compile_plan(spec, impl="scan", ensemble=3))
        assert eng.backend == "scan"
        solo = api.compile_plan(spec, impl="scan")
        u = _u(6, seed=7)
        _, ref = solo.drive(u)
        r = eng.run([StreamSession(sid=0, u_seq=u)])[0]
        np.testing.assert_allclose(np.asarray(r.states), np.asarray(ref), atol=ATOL)

    def test_num_slots_must_match_plan(self):
        sim = api.compile_plan(_spec(), ensemble=4)
        with pytest.raises(ValueError, match="ensemble width"):
            ReservoirEngine(sim, num_slots=8)

    def test_template_path_requires_num_slots(self):
        with pytest.raises(TypeError, match="num_slots"):
            ReservoirEngine(make_reservoir(n=8, n_in=1))

    def test_compiled_sim_rejects_exec_args(self):
        """backend/measure/interpret belong to the ExecPlan — passing them
        alongside a CompiledSim raises instead of being silently dropped."""
        sim = api.compile_plan(_spec(), ensemble=2)
        with pytest.raises(ValueError, match="ExecPlan"):
            ReservoirEngine(sim, backend="scan")
        with pytest.raises(ValueError, match="ExecPlan"):
            ReservoirEngine(sim, interpret=True)


class TestDeprecationShims:
    def test_drive_warns(self):
        res = make_reservoir(n=8, n_in=1, hold_steps=4, dtype=jnp.float32)
        with pytest.warns(DeprecationWarning, match="compile_plan"):
            drive(res, _u())

    def test_integrate_ensemble_warns(self):
        n, e = 8, 2
        pe = broadcast_params(default_params(jnp.float32), e)
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float32), (e, n, 3))
        with pytest.warns(DeprecationWarning, match="compile_plan"):
            integrate_ensemble(pe, w, m0, DT, 2)

    def test_integrate_ensemble_sharded_warns_and_matches(self):
        n, e = 8, 2
        pe = broadcast_params(default_params(jnp.float32), e)
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = jnp.broadcast_to(initial_magnetization(n, jnp.float32), (e, n, 3))
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.warns(DeprecationWarning, match="compile_plan"):
            out = integrate_ensemble_sharded(mesh, pe, w, m0, DT, 10)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ref, _ = integrate_ensemble(pe, w, m0, DT, 10)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


class TestDispatchTablePersistence:
    def test_round_trip_survives_table_clear(self, tmp_path):
        """register -> save -> clear (process restart stand-in) -> load ->
        choose_impl returns the persisted choice."""
        path = str(tmp_path / "dispatch_table.test.json")
        try:
            dispatch_table.ensure_loaded("cpu")  # committed entries out of the way
            ops._LATENCY_TABLE.clear()
            ops.register_impl_choice(640, 24, "tiled", platform="cpu")
            dispatch_table.save_table(path, platform="cpu")
            ops._LATENCY_TABLE.clear()
            assert ops.choose_impl(640, 24, platform="cpu") == "ref"  # heuristic
            n = dispatch_table.load_table(path, platform="cpu")
            assert n == 1
            assert ops.choose_impl(640, 24, platform="cpu") == "tiled"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_in_process_measurements_beat_persisted(self, tmp_path):
        path = str(tmp_path / "dispatch_table.test.json")
        try:
            ops.register_impl_choice(640, 24, "tiled", platform="cpu")
            dispatch_table.save_table(path, platform="cpu")
            ops._LATENCY_TABLE.clear()
            ops.register_impl_choice(640, 24, "fused", platform="cpu")
            dispatch_table.load_table(path, platform="cpu")
            assert ops.choose_impl(640, 24, platform="cpu") == "fused"
            dispatch_table.load_table(path, platform="cpu", override=True)
            assert ops.choose_impl(640, 24, platform="cpu") == "tiled"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_committed_cpu_table_loads_on_choose_impl(self):
        """The committed dispatch_table.cpu.json is picked up lazily by
        choose_impl — the dispatch table survives process restart."""
        committed = dispatch_table.table_path("cpu")
        assert os.path.exists(committed), committed
        try:
            ops._LATENCY_TABLE.clear()
            dispatch_table.reset_loaded()
            ops.choose_impl(128, 64, platform="cpu")
            table = ops.latency_table()
            # N=128, E=64 pads to (128, 128); the serve bench measures f32
            # at the bit-exact default precision. The winning impl is
            # whatever the committed bench run measured fastest — assert
            # it's a real CPU-runnable impl, not a specific name (the
            # ref/chunk ranking sits inside the host's noise band).
            key = ("cpu", 128, 128, 4, ops.PRECISION_DEFAULT)
            assert key in table
            assert table[key] in ("ref", "chunk")
        finally:
            ops._LATENCY_TABLE.clear()

    def test_seed_from_bench(self):
        bench = os.path.join(_REPO_ROOT, "BENCH_serve.json")
        try:
            ops._LATENCY_TABLE.clear()
            n = dispatch_table.seed_from_bench(bench)
            with open(bench) as f:
                cells = json.load(f)["cells"]
            impls = ("ref", "fused", "tiled", "chunk")
            keys = set()
            for c in cells:
                if c["backend"] in impls:
                    keys.add((ops._round_up(c["n"], ops.LANE),
                              ops._round_up(c["e"], ops.LANE),
                              ops.normalize_precision(c.get("precision"))))
                # mixed twins seed only when they beat the default in-run
                if (
                    c.get("backend_mixed") in impls
                    and c.get("precision_speedup", 0.0) > 1.0
                ):
                    keys.add((ops._round_up(c["n"], ops.LANE),
                              ops._round_up(c["e"], ops.LANE), "mixed"))
            assert n == len(keys)  # one entry per distinct padded key
            assert len(ops.latency_table()) == n
        finally:
            ops._LATENCY_TABLE.clear()

    def test_seed_from_bench_conflict_prefers_largest_cell(self, tmp_path):
        """Cells colliding on one padded key: the least-padded (largest n*e)
        measurement wins instead of silent last-write-wins."""
        bench = tmp_path / "bench.json"
        bench.write_text(json.dumps({
            "backend_platform": "tpu",
            "cells": [
                {"n": 128, "e": 64, "backend": "tiled"},
                {"n": 16, "e": 8, "backend": "fused"},
            ],
        }))
        try:
            ops._LATENCY_TABLE.clear()
            n = dispatch_table.seed_from_bench(str(bench))
            assert n == 1
            assert ops.latency_table()[
                ("tpu", 128, 128, 4, ops.PRECISION_DEFAULT)
            ] == "tiled"
        finally:
            ops._LATENCY_TABLE.clear()

    def test_compile_plan_consults_persisted_choice(self):
        try:
            ops.register_impl_choice(8, 4, "fused")
            # the table's word is final for auto plans at this padded shape;
            # interpret=True keeps the Pallas kernel runnable on CPU
            sim = api.compile_plan(_spec(), ensemble=4, interpret=True)
            assert sim.impl == "fused"
        finally:
            ops._LATENCY_TABLE.clear()


class TestFitRidgeContract:
    def test_1d_targets_equal_column(self):
        rng = np.random.default_rng(0)
        states = jnp.asarray(rng.standard_normal((20, 4)))
        y = rng.standard_normal(20)
        a = fit_ridge(states, jnp.asarray(y))
        b = fit_ridge(states, jnp.asarray(y[:, None]))
        np.testing.assert_array_equal(np.asarray(a.w_out), np.asarray(b.w_out))

    def test_rejects_row_vector(self):
        states = jnp.asarray(np.random.default_rng(1).standard_normal((20, 4)))
        with pytest.raises(ValueError, match="row vector"):
            fit_ridge(states, jnp.zeros((1, 20)))

    def test_rejects_length_mismatch(self):
        states = jnp.asarray(np.random.default_rng(2).standard_normal((20, 4)))
        with pytest.raises(ValueError, match=r"\(20, n_out\)"):
            fit_ridge(states, jnp.zeros((19, 1)))

    def test_single_sample_multioutput_no_longer_transposed(self):
        """(1, n_out) targets against a single state sample used to be
        silently transposed into (n_out, 1); now they fit as declared."""
        states = jnp.asarray(np.random.default_rng(3).standard_normal((1, 4)))
        ro = fit_ridge(states, jnp.asarray([[1.0, 2.0, 3.0]]), reg=1e-3)
        assert ro.w_out.shape == (5, 3)
