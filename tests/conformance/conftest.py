"""Fixtures for the cross-backend conformance matrix (tests/conformance/).

One place defines the family specs, the scan-impl oracle runners, and the
per-cell summary collector every matrix test reports through. Each test is
one CELL: (topology, impl, precision, learn, sharded) pinned against the
family's oracle with an explicit exactness policy:

  scan          the family ORACLE — the core (E, N, 3)-layout lax.scan that
                reproduces solo `drive` math; cells check its invariants.
  ref == chunk  BIT-EXACT: both execute the same planes-layout chunk body
                (kernels/ref.py), so equality is by construction.
  scan ~ ref    tolerance: the two layouts order FMA contractions
                differently (XLA fusion), a ~1-ulp-per-step effect.
  fused/tiled   Pallas kernels run in interpret mode off-TPU; tolerance.
  bf16/mixed    reduced precision tracks "highest" to a loose relative L2.

Set CONFORMANCE_MATRIX_OUT=<path.json> to write the per-cell summary
artifact (the CI nightly leg uploads it): one record per reported cell
with the measured deviation, so a regression shows WHERE in the matrix it
landed, not just that some assert tripped.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    ExecPlan,
    compile_plan,
    make_array_transient_spec,
    make_spec,
    make_time_multiplexed_spec,
)

TOPOLOGIES = ("coupled_array", "time_multiplexed", "array_transient")

# Small-but-nontrivial family shapes: enough nodes/substeps that layout or
# masking bugs cannot hide in degenerate axes, small enough that the whole
# matrix stays unit-test fast.
_SPEC_BUILDERS = {
    "coupled_array": lambda: make_spec(6, hold_steps=4, seed=0),
    "time_multiplexed": lambda: make_time_multiplexed_spec(
        5, hold_steps=3, seed=0
    ),
    "array_transient": lambda: make_array_transient_spec(
        6, readout_window=2, hold_steps=4, seed=0
    ),
}
_SPECS: Dict[str, object] = {}


def family_spec(topology: str):
    """The matrix's canonical small spec for one physics family (memoized —
    every cell of a topology row sees the SAME spec object)."""
    if topology not in _SPECS:
        _SPECS[topology] = _SPEC_BUILDERS[topology]()
    return _SPECS[topology]


def drive_states(
    spec,
    impl: str,
    u: np.ndarray,
    *,
    precision: Optional[str] = None,
    interpret: bool = False,
    chunk_ticks: int = 4,
):
    """Run one cell's sim over the stream; returns host (final_m, states)."""
    sim = compile_plan(
        spec,
        ExecPlan(
            impl=impl,
            ensemble=1,
            chunk_ticks=chunk_ticks,
            precision=precision,
            interpret=interpret,
        ),
    )
    m, states = sim.drive(jnp.asarray(u, spec.dtype))
    return np.asarray(m), np.asarray(states)


def rel_l2(a: np.ndarray, b: np.ndarray) -> float:
    """Relative L2 deviation of a from b (0.0 when bit-identical)."""
    denom = float(np.linalg.norm(b.astype(np.float64)))
    if denom == 0.0:
        return float(np.linalg.norm(a.astype(np.float64)))
    return float(np.linalg.norm(a.astype(np.float64) - b.astype(np.float64))) / denom


@pytest.fixture(scope="session")
def input_stream() -> np.ndarray:
    """The matrix's shared 10-tick input stream (deterministic)."""
    return np.random.default_rng(7).uniform(0.0, 1.0, 10).astype(np.float32)


# ---------------------------------------------------------------------------
# Per-cell summary artifact
# ---------------------------------------------------------------------------

_CELLS: list = []


def record_cell(**cell) -> None:
    """Append one matrix-cell record to the session summary. Tests call
    this with at least topology/impl plus whatever was measured."""
    _CELLS.append(cell)


@pytest.fixture
def matrix_cell():
    return record_cell


def pytest_sessionfinish(session, exitstatus):
    out = os.environ.get("CONFORMANCE_MATRIX_OUT")
    if not out or not _CELLS:
        return
    payload = {
        "cells": sorted(
            _CELLS,
            key=lambda c: (
                str(c.get("topology")),
                str(c.get("impl")),
                str(c.get("kind")),
            ),
        ),
        "count": len(_CELLS),
        "exit_status": int(exitstatus),
    }
    with open(out, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
