"""PlanCache structural-hash guard (referenced by repro/api/cache.py).

Two invariants keep the process-wide compile cache collision-free as the
physics grows:

  1. FENCE — `spec_structural_hash` refuses (TypeError) any spec whose
     field set it does not cover. Adding a SimSpec field without deciding
     its hash treatment fails at the first cache lookup instead of
     silently serving one family's executable for another's spec.
  2. SEPARATION — specs differing ONLY in a physics field (topology tag,
     readout_window, coupling contents, ...) hash differently, while
     scalar param VALUES (lane-resident runtime inputs) do not move the
     hash at all.
"""

import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    ExecPlan,
    PlanCache,
    make_array_transient_spec,
    make_spec,
    make_time_multiplexed_spec,
    spec_structural_hash,
)


class TestFence:
    def test_uncovered_field_raises_typerror(self):
        """A spec with a field the hash doesn't know is rejected loudly."""
        spec = make_spec(4, hold_steps=3)
        plus = collections.namedtuple(
            "SimSpecPlus", spec._fields + ("stray_physics_knob",)
        )
        fake = plus(*spec, 0.5)
        with pytest.raises(TypeError, match="stray_physics_knob"):
            spec_structural_hash(fake)

    def test_error_names_the_fix(self):
        spec = make_spec(4, hold_steps=3)
        plus = collections.namedtuple("SimSpecPlus", spec._fields + ("zz",))
        with pytest.raises(TypeError, match="_STRUCTURAL_FIELDS"):
            spec_structural_hash(plus(*spec, None))


class TestSeparation:
    def test_families_hash_apart(self):
        """The three families over comparable shapes never share a line."""
        hashes = {
            spec_structural_hash(make_spec(6, hold_steps=4)),
            spec_structural_hash(
                make_time_multiplexed_spec(6, hold_steps=4)
            ),
            spec_structural_hash(
                make_array_transient_spec(6, readout_window=2, hold_steps=4)
            ),
        }
        assert len(hashes) == 3

    def test_topology_tag_alone_moves_the_hash(self):
        """Same arrays, same scalars, same window — ONLY the family tag
        differs (time_multiplexed shares coupled_array's readout_window=0,
        so a field-for-field _replace isolates the tag)."""
        ca = make_spec(6, hold_steps=4)
        tm = ca._replace(topology="time_multiplexed")
        assert spec_structural_hash(ca) != spec_structural_hash(tm)

    def test_readout_window_alone_moves_the_hash(self):
        a = make_array_transient_spec(6, readout_window=2, hold_steps=4)
        b = make_array_transient_spec(6, readout_window=3, hold_steps=4)
        assert spec_structural_hash(a) != spec_structural_hash(b)

    def test_scalar_param_values_do_not_move_the_hash(self):
        spec = make_spec(6, hold_steps=4)
        tweaked = spec.with_knobs(a_cp=0.123, a_in=4.56)
        assert spec_structural_hash(spec) == spec_structural_hash(tweaked)

    def test_coupling_contents_move_the_hash(self):
        a = make_spec(6, hold_steps=4, seed=0)
        b = make_spec(6, hold_steps=4, seed=1)
        assert spec_structural_hash(a) != spec_structural_hash(b)

    def test_hash_is_host_device_agnostic(self):
        """numpy-leaved and jnp-leaved twins (checkpoint transport) agree."""
        spec = make_time_multiplexed_spec(5, hold_steps=3)
        host = spec._replace(
            params=type(spec.params)(
                *[np.asarray(leaf) for leaf in spec.params]
            ),
            w_cp=np.asarray(spec.w_cp),
            w_in=np.asarray(spec.w_in),
            m0=np.asarray(spec.m0),
        )
        assert spec_structural_hash(spec) == spec_structural_hash(host)


class TestCacheEndToEnd:
    def test_families_never_share_a_cache_line(self):
        """get_or_compile on two same-shape, different-family specs yields
        two distinct CompiledSims under one plan key — the collision the
        fence + separation invariants exist to prevent."""
        cache = PlanCache(capacity=8)
        plan = ExecPlan(impl="ref", ensemble=1, chunk_ticks=2)
        ca = make_spec(5, hold_steps=3)
        tm = make_time_multiplexed_spec(5, hold_steps=3)
        sim_ca = cache.get_or_compile(ca, plan)
        sim_tm = cache.get_or_compile(tm, plan)
        assert sim_ca is not sim_tm
        assert len(cache) == 2
        assert cache.stats.misses == 2 and cache.stats.hits == 0
        # and the same spec again IS the cached object
        assert cache.get_or_compile(ca, plan) is sim_ca
        assert cache.stats.hits == 1
