"""The unified cross-backend conformance matrix.

Every physics family (SimSpec.topology) x implementation x precision x
learn combination the repo claims to support is pinned here as one cell,
each against the family's scan oracle (see conftest for the exactness
policy). Guard cells pin the REFUSALS — combinations the plan/spec layer
must reject loudly (time_multiplexed x Pallas, families x mesh, scan x
reduced precision, readout_window misuse) — so an accidental silent
acceptance is as much a regression as a numerical drift.

Fast cells run on every push; @pytest.mark.slow cells (Pallas interpret
mode, reduced precision, the wider learn grid) join on the nightly /
full-matrix CI leg. The driver's plain `pytest -x -q` runs both.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import TOPOLOGIES, drive_states, family_spec, rel_l2
from jax.sharding import Mesh

from repro.api import ExecPlan, compile_plan, make_array_transient_spec, make_spec
from repro.core.reservoir import fit_lms, fit_rls
from repro.serve.reservoir import ReservoirEngine, StreamSession


class TestInferCells:
    """topology x impl inference cells, states + final magnetization."""

    @pytest.mark.parametrize("topology", TOPOLOGIES)
    def test_scan_oracle_invariants(self, topology, input_stream, matrix_cell):
        """The oracle itself: finite, |m| = 1 preserved, right shapes."""
        spec = family_spec(topology)
        m, states = drive_states(spec, "scan", input_stream)
        assert states.shape == (len(input_stream), spec.n)
        assert m.shape == (spec.n, 3)
        assert np.isfinite(states).all() and np.isfinite(m).all()
        norms = np.linalg.norm(m, axis=-1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-4)
        matrix_cell(
            topology=topology, impl="scan", kind="oracle",
            max_norm_err=float(np.abs(norms - 1.0).max()),
        )

    @pytest.mark.parametrize("topology", TOPOLOGIES)
    def test_ref_tracks_scan(self, topology, input_stream, matrix_cell):
        """Planes layout vs core layout: same math, different FMA order."""
        spec = family_spec(topology)
        m0, s0 = drive_states(spec, "scan", input_stream)
        m1, s1 = drive_states(spec, "ref", input_stream)
        np.testing.assert_allclose(s1, s0, rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose(m1, m0, rtol=2e-5, atol=2e-6)
        matrix_cell(
            topology=topology, impl="ref", kind="infer-vs-scan",
            rel_l2=rel_l2(s1, s0),
        )

    @pytest.mark.parametrize("topology", TOPOLOGIES)
    def test_chunk_bitexact_with_ref(self, topology, input_stream, matrix_cell):
        """ref and chunk share ONE planes chunk body off-TPU — equality is
        by construction, so the cell pins it bit-for-bit."""
        spec = family_spec(topology)
        m1, s1 = drive_states(spec, "ref", input_stream)
        m2, s2 = drive_states(spec, "chunk", input_stream)
        np.testing.assert_array_equal(s2, s1)
        np.testing.assert_array_equal(m2, m1)
        matrix_cell(
            topology=topology, impl="chunk", kind="infer-vs-ref", rel_l2=0.0,
        )

    @pytest.mark.slow
    @pytest.mark.parametrize("impl", ("fused", "tiled"))
    @pytest.mark.parametrize(
        "topology", ("coupled_array", "array_transient")
    )
    def test_pallas_interpret_tracks_ref(
        self, topology, impl, input_stream, matrix_cell
    ):
        """Pallas kernels (interpret mode off-TPU) vs the planes reference.
        time_multiplexed is ABSENT by design — its cell is the refusal
        guard below."""
        spec = family_spec(topology)
        _, s1 = drive_states(spec, "ref", input_stream)
        _, s2 = drive_states(spec, impl, input_stream, interpret=True)
        np.testing.assert_allclose(s2, s1, rtol=1e-5, atol=1e-6)
        matrix_cell(
            topology=topology, impl=impl, kind="infer-vs-ref",
            rel_l2=rel_l2(s2, s1), interpret=True,
        )


class TestEndpointCells:
    """Family limit points that must coincide with the coupled array."""

    @pytest.mark.parametrize("impl", ("scan", "chunk"))
    def test_transient_window1_is_coupled_array(
        self, impl, input_stream, matrix_cell
    ):
        """readout_window=1 averages exactly one substep — the hold-window
        endpoint — so array_transient degenerates to coupled_array. Pinned
        bit-exactly through the serving chunk path (both topologies
        execute tick_chunk with identical graph shapes there)."""
        ca = make_spec(6, hold_steps=4, seed=0)
        at = make_array_transient_spec(6, readout_window=1, hold_steps=4, seed=0)
        results = {}
        for name, spec in (("ca", ca), ("at", at)):
            eng = ReservoirEngine(
                spec, num_slots=2, backend=impl, chunk_ticks=4
            )
            eng.submit(StreamSession(sid=1, u_seq=input_stream))
            results[name] = eng.run()[1]
        np.testing.assert_array_equal(
            results["at"].states, results["ca"].states
        )
        np.testing.assert_array_equal(
            results["at"].final_m, results["ca"].final_m
        )
        matrix_cell(
            topology="array_transient", impl=impl,
            kind="endpoint-w1-vs-coupled", rel_l2=0.0,
        )


class TestPrecisionCells:
    @pytest.mark.slow
    @pytest.mark.parametrize("precision", ("bf16_coupling", "mixed"))
    @pytest.mark.parametrize("topology", TOPOLOGIES)
    def test_reduced_precision_tracks_highest(
        self, topology, precision, input_stream, matrix_cell
    ):
        """Reduced-precision GEMM policies on the ref impl stay within a
        loose relative L2 of the bit-exact run, for every family (the
        family bodies route their coupling/input GEMMs through the same
        `_coupling_operand` / input-field casts the coupled array uses)."""
        spec = family_spec(topology)
        _, s_hi = drive_states(spec, "ref", input_stream)
        _, s_lo = drive_states(spec, "ref", input_stream, precision=precision)
        assert np.isfinite(s_lo).all()
        dev = rel_l2(s_lo, s_hi)
        assert dev < 5e-2, f"{topology}/{precision}: rel L2 {dev:.3e}"
        matrix_cell(
            topology=topology, impl="ref", kind="precision",
            precision=precision, rel_l2=dev,
        )


class TestLearnCells:
    """Streamed on-device learning vs the offline oracle, per family.

    The learn tails are topology-blind — they consume the (K, N, E) states
    block whatever physics produced it — so the streamed weights must
    reproduce `fit_rls(states, y, block=K)` / `fit_lms(states, y)` run on
    the SAME states. Bit-exact on the scan backend; the planes backends
    get a tight tolerance (layout-order FMA differences in the states
    feed the recursion).
    """

    def _served(self, topology, impl, learn, seed=11, t=12, k=4):
        spec = family_spec(topology)
        rng = np.random.default_rng(seed)
        u = rng.uniform(0.0, 1.0, t).astype(np.float32)
        y = rng.uniform(0.0, 1.0, t).astype(np.float32)
        eng = ReservoirEngine(
            spec, num_slots=2, backend=impl, chunk_ticks=k,
            learn=learn, learn_reg=1e-6, learn_mu=0.4,
        )
        eng.submit(StreamSession(sid=1, u_seq=u, targets=y))
        res = eng.run()[1]
        states = jnp.asarray(res.states)
        if learn == "rls":
            w_ref = fit_rls(states, jnp.asarray(y), reg=1e-6, block=k).w_out
        else:
            w_ref = fit_lms(states, jnp.asarray(y), mu=0.4).w_out
        return np.asarray(res.learned_readout.w_out), np.asarray(w_ref)

    @pytest.mark.parametrize("topology", TOPOLOGIES)
    def test_rls_scan_bitmatches_offline(self, topology, matrix_cell):
        w, w_ref = self._served(topology, "scan", "rls")
        np.testing.assert_array_equal(w, w_ref)
        matrix_cell(
            topology=topology, impl="scan", kind="learn", learn="rls",
            rel_l2=0.0,
        )

    @pytest.mark.slow
    @pytest.mark.parametrize("topology", TOPOLOGIES)
    @pytest.mark.parametrize(
        "impl,learn",
        [("chunk", "rls"), ("scan", "lms"), ("ref", "lms")],
    )
    def test_learn_grid_tracks_offline(self, topology, impl, learn, matrix_cell):
        w, w_ref = self._served(topology, impl, learn)
        np.testing.assert_allclose(w, w_ref, rtol=1e-5, atol=1e-6)
        matrix_cell(
            topology=topology, impl=impl, kind="learn", learn=learn,
            rel_l2=rel_l2(w, w_ref),
        )


class TestGuardCells:
    """Refusal cells: the matrix's unsupported combinations must raise."""

    @pytest.mark.parametrize("impl", ("fused", "tiled"))
    def test_time_multiplexed_refuses_pallas(self, impl, matrix_cell):
        spec = family_spec("time_multiplexed")
        with pytest.raises(ValueError, match="cannot execute topology"):
            compile_plan(spec, ExecPlan(impl=impl, ensemble=1))
        matrix_cell(
            topology="time_multiplexed", impl=impl, kind="guard-refused",
        )

    def test_time_multiplexed_auto_falls_back(self, input_stream):
        """impl='auto' must RESOLVE around the refusal, not die on it."""
        spec = family_spec("time_multiplexed")
        sim = compile_plan(spec, ExecPlan(impl="auto", ensemble=1))
        assert sim.impl in ("scan", "ref", "chunk")
        _, states = sim.drive(jnp.asarray(input_stream, spec.dtype))
        assert np.isfinite(np.asarray(states)).all()

    @pytest.mark.parametrize(
        "topology", ("time_multiplexed", "array_transient")
    )
    def test_families_refuse_mesh(self, topology, matrix_cell):
        spec = family_spec(topology)
        mesh = Mesh(
            np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model")
        )
        with pytest.raises(ValueError, match="mesh"):
            compile_plan(spec, ExecPlan(ensemble=1, mesh=mesh))
        matrix_cell(topology=topology, impl="mesh", kind="guard-refused")

    def test_scan_refuses_reduced_precision(self):
        spec = family_spec("coupled_array")
        with pytest.raises(ValueError):
            compile_plan(
                spec, ExecPlan(impl="scan", ensemble=1, precision="mixed")
            )

    def test_time_multiplexed_refuses_integrate(self):
        """integrate() free-runs the coupled array; a TM reservoir has no
        meaning without the per-tick input mask."""
        spec = family_spec("time_multiplexed")
        sim = compile_plan(spec, ExecPlan(impl="ref", ensemble=1))
        with pytest.raises(ValueError, match="time_multiplexed"):
            sim.integrate(n_steps=2)

    def test_coupled_refuses_readout_window(self):
        with pytest.raises(ValueError, match="readout_window"):
            make_spec(6, hold_steps=4, readout_window=2)

    @pytest.mark.parametrize("window", (0, 5, -1))
    def test_transient_window_bounds(self, window):
        with pytest.raises(ValueError, match="readout_window"):
            make_array_transient_spec(6, readout_window=window, hold_steps=4)

    def test_unknown_topology_refused(self):
        with pytest.raises(ValueError, match="topology"):
            make_spec(6, hold_steps=4, topology="ring")

    def test_family_spec_refuses_legacy_reservoir(self):
        spec = family_spec("time_multiplexed")
        with pytest.raises(ValueError, match="topology"):
            spec.to_reservoir()
