"""Spec-level multi-tenancy: one engine, tenants with DIFFERENT SimSpecs.

A StreamSession may carry its own SimSpec; the engine routes by structural
hash — same hash serves in a primary lane (the spec's scalar params become
the lane values), a different hash (other family / other shapes) lands on
an internal sub-engine drawn through the shared PLAN_CACHE. The pinned
property: every tenant's streamed result is BIT-IDENTICAL to running its
spec alone on a dedicated engine — tenancy is an execution arrangement,
never a numerical one.
"""

import pickle

import numpy as np
import pytest

from repro.api import (
    make_array_transient_spec,
    make_spec,
    make_time_multiplexed_spec,
)
from repro.serve.reservoir import ReservoirEngine, StreamSession


def _solo(spec, sid, u, **session_kw):
    eng = ReservoirEngine(spec, num_slots=4, backend="scan", chunk_ticks=4)
    eng.submit(StreamSession(sid=sid, u_seq=u, **session_kw))
    return eng.run()[sid]


class TestMixedSpecs:
    def test_three_families_one_engine_bitexact_vs_solo(self):
        rng = np.random.default_rng(0)
        spec_ca = make_spec(8, hold_steps=5)
        spec_tm = make_time_multiplexed_spec(6, hold_steps=4)
        spec_at = make_array_transient_spec(
            8, readout_window=3, hold_steps=5, seed=3
        )
        u1 = rng.uniform(0, 1, 13).astype(np.float32)
        u2 = rng.uniform(0, 1, 17).astype(np.float32)
        u3 = rng.uniform(0, 1, 11).astype(np.float32)

        eng = ReservoirEngine(
            spec_ca, num_slots=4, backend="scan", chunk_ticks=4
        )
        eng.submit(StreamSession(sid=1, u_seq=u1))
        eng.submit(StreamSession(sid=2, u_seq=u2, spec=spec_tm))
        eng.submit(StreamSession(sid=3, u_seq=u3, spec=spec_at))
        res = eng.run()
        assert sorted(res) == [1, 2, 3]
        assert eng.stats().sub_engines == 2

        for sid, spec, u in ((1, spec_ca, u1), (2, spec_tm, u2), (3, spec_at, u3)):
            solo = _solo(spec, sid, u)
            np.testing.assert_array_equal(res[sid].states, solo.states)
            np.testing.assert_array_equal(res[sid].final_m, solo.final_m)

    def test_same_hash_spec_rides_a_primary_lane(self):
        """A session spec that structurally matches the template routes to
        the primary batch — its scalar params become the lane values, and
        no sub-engine is built."""
        rng = np.random.default_rng(1)
        base = make_spec(8, hold_steps=5)
        tweaked = base.with_knobs(a_cp=0.7, a_in=1.3)
        u = rng.uniform(0, 1, 13).astype(np.float32)

        eng = ReservoirEngine(base, num_slots=4, backend="scan", chunk_ticks=4)
        eng.submit(StreamSession(sid=9, u_seq=u, spec=tweaked))
        res = eng.run()[9]
        assert eng.stats().sub_engines == 0
        solo = _solo(tweaked, 9, u)
        np.testing.assert_array_equal(res.states, solo.states)

    def test_explicit_session_params_beat_spec_params(self):
        base = make_spec(8, hold_steps=5)
        tweaked = base.with_knobs(a_cp=0.7)
        u = np.random.default_rng(2).uniform(0, 1, 9).astype(np.float32)
        eng = ReservoirEngine(base, num_slots=2, backend="scan", chunk_ticks=4)
        # params pinned explicitly: the spec's scalars must NOT override
        eng.submit(
            StreamSession(sid=1, u_seq=u, params=base.params, spec=tweaked)
        )
        res = eng.run()[1]
        solo = _solo(base, 1, u)
        np.testing.assert_array_equal(res.states, solo.states)

    def test_one_subengine_per_distinct_hash(self):
        rng = np.random.default_rng(3)
        spec_ca = make_spec(8, hold_steps=5)
        spec_tm = make_time_multiplexed_spec(6, hold_steps=4)
        eng = ReservoirEngine(
            spec_ca, num_slots=4, backend="scan", chunk_ticks=4
        )
        for sid in (1, 2, 3):
            eng.submit(
                StreamSession(
                    sid=sid,
                    u_seq=rng.uniform(0, 1, 8).astype(np.float32),
                    spec=spec_tm,
                )
            )
        res = eng.run()
        assert sorted(res) == [1, 2, 3]
        assert eng.stats().sub_engines == 1

    def test_ensemble_leaved_session_spec_refused(self):
        from repro.core.ensemble import broadcast_params

        spec_ca = make_spec(8, hold_steps=5)
        swept = spec_ca._replace(
            params=broadcast_params(spec_ca.params, 4)
        )
        eng = ReservoirEngine(spec_ca, num_slots=2, backend="scan", chunk_ticks=4)
        with pytest.raises(ValueError, match="scalar-leaved"):
            eng.submit(
                StreamSession(
                    sid=1,
                    u_seq=np.ones(4, np.float32),
                    spec=swept,
                )
            )

    def test_per_tick_step_refuses_mixed_specs(self):
        spec_ca = make_spec(8, hold_steps=5)
        spec_tm = make_time_multiplexed_spec(6, hold_steps=4)
        eng = ReservoirEngine(spec_ca, num_slots=2, backend="scan")
        eng.submit(
            StreamSession(
                sid=1, u_seq=np.ones(4, np.float32), spec=spec_tm
            )
        )
        with pytest.raises(RuntimeError, match="chunked path"):
            eng.step()


class TestMixedSpecLifecycle:
    def test_learning_tenant_checkpoint_migrates_bitexact(self):
        """A learning time_multiplexed tenant on a coupled-array engine,
        checkpointed mid-stream, pickled, restored into a FRESH engine —
        the whole stream (states, predictions, learned weights) matches a
        never-migrated solo run bit-for-bit."""
        rng = np.random.default_rng(1)
        spec_ca = make_spec(8, hold_steps=5)
        spec_tm = make_time_multiplexed_spec(6, hold_steps=4)
        u = rng.uniform(0, 1, 16).astype(np.float32)
        y = rng.uniform(0, 1, 16).astype(np.float32)

        src = ReservoirEngine(
            spec_ca, num_slots=4, backend="scan", chunk_ticks=4, learn="rls"
        )
        src.submit(
            StreamSession(
                sid=5, u_seq=u, targets=y, learn_washout=2, spec=spec_tm
            )
        )
        for _ in range(3):
            src.step_chunk()
        ckpt = pickle.loads(pickle.dumps(src.checkpoint_session(5)))
        assert ckpt.spec is not None and ckpt.spec.topology == "time_multiplexed"
        assert 0 < ckpt.t < len(u)

        dst = ReservoirEngine(
            spec_ca, num_slots=4, backend="scan", chunk_ticks=4, learn="rls"
        )
        dst.restore_session(ckpt)
        res = dst.run()[5]

        solo_eng = ReservoirEngine(
            spec_tm, num_slots=4, backend="scan", chunk_ticks=4, learn="rls"
        )
        solo_eng.submit(
            StreamSession(sid=5, u_seq=u, targets=y, learn_washout=2)
        )
        solo = solo_eng.run()[5]
        np.testing.assert_array_equal(res.states, solo.states)
        np.testing.assert_array_equal(res.predictions, solo.predictions)
        np.testing.assert_array_equal(
            np.asarray(res.learned_readout.w_out),
            np.asarray(solo.learned_readout.w_out),
        )

    def test_push_stream_reaches_subengine_tenant(self):
        rng = np.random.default_rng(4)
        spec_ca = make_spec(8, hold_steps=5)
        spec_tm = make_time_multiplexed_spec(6, hold_steps=4)
        u_all = rng.uniform(0, 1, 12).astype(np.float32)

        eng = ReservoirEngine(
            spec_ca, num_slots=2, backend="scan", chunk_ticks=4
        )
        eng.submit(
            StreamSession(sid=7, u_seq=u_all[:6], open=True, spec=spec_tm)
        )
        for _ in range(3):
            eng.step_chunk()
        eng.append_ticks(7, u_all[6:])
        eng.close_session(7)
        res = eng.run()[7]

        solo = _solo(spec_tm, 7, u_all)
        np.testing.assert_array_equal(res.states, solo.states)
        np.testing.assert_array_equal(res.final_m, solo.final_m)
