"""Task-level parity for the time-multiplexed family (slow / nightly leg).

The cheap matrix cells pin numerics; these pin that the family actually
COMPUTES — a Riou-style time-multiplexed reservoir must beat memoryless
linear baselines on the literature's standard tasks (NARMA-10, delay
memory capacity), and the family's backends must agree on the scores.
Thresholds carry slack below measured values (NMSE 0.88 vs 0.98 baseline,
MC 0.58 vs 0.04 baseline at this configuration) so they pin capability,
not ULPs.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecPlan, compile_plan, make_time_multiplexed_spec
from repro.core import fit_ridge, nmse, predict
from repro.core.tasks import (
    delay_memory_targets,
    memory_capacity,
    narma_series,
)

pytestmark = pytest.mark.slow

T, WASHOUT = 300, 50


@pytest.fixture(scope="module")
def tm_sim():
    """A task-capable TM reservoir: 24 virtual nodes, a 30-substep hold
    window, moderate feedback gain (empirically calibrated)."""
    spec = make_time_multiplexed_spec(
        24, hold_steps=30, seed=0, dtype=jnp.float32
    ).with_knobs(a_in=1.0, a_cp=0.3)
    return compile_plan(spec, ExecPlan(impl="ref", ensemble=1, chunk_ticks=8))


class TestNarma10:
    def test_beats_memoryless_linear_baseline(self, tm_sim, matrix_cell):
        u, y = narma_series(T, order=10, seed=0)
        u32 = u.astype(np.float32)
        _, states = tm_sim.drive(jnp.asarray(u32))
        st = jnp.asarray(np.asarray(states))
        ro = fit_ridge(st, jnp.asarray(y[:, None]), washout=WASHOUT, reg=1e-6)
        err = float(nmse(predict(ro, st), jnp.asarray(y[WASHOUT:, None])))

        rb = fit_ridge(
            jnp.asarray(u32[:, None]), jnp.asarray(y[:, None]),
            washout=WASHOUT, reg=1e-6,
        )
        base = float(
            nmse(
                predict(rb, jnp.asarray(u32[:, None])),
                jnp.asarray(y[WASHOUT:, None]),
            )
        )
        assert np.isfinite(err)
        assert err < 0.95  # mean predictor scores ~1
        assert err < base  # and the reservoir must beat u[t] alone
        matrix_cell(
            topology="time_multiplexed", impl="ref", kind="task-narma10",
            nmse=err, baseline_nmse=base,
        )


class TestMemoryCapacity:
    def test_recalls_past_inputs(self, tm_sim, matrix_cell):
        rng = np.random.default_rng(4)
        u = rng.uniform(-1, 1, T).astype(np.float32)
        _, states = tm_sim.drive(jnp.asarray(u))
        st = jnp.asarray(np.asarray(states))
        targets = delay_memory_targets(u, max_delay=5)
        ro = fit_ridge(st, jnp.asarray(targets), washout=WASHOUT, reg=1e-6)
        mc = memory_capacity(
            np.asarray(predict(ro, st)), targets[WASHOUT:]
        )

        rb = fit_ridge(
            jnp.asarray(u[:, None]), jnp.asarray(targets),
            washout=WASHOUT, reg=1e-6,
        )
        mc_base = memory_capacity(
            np.asarray(predict(rb, jnp.asarray(u[:, None]))),
            targets[WASHOUT:],
        )
        assert mc > 0.3  # measured 0.58
        assert mc > mc_base + 0.2  # memoryless input measures ~0.04
        matrix_cell(
            topology="time_multiplexed", impl="ref", kind="task-memory",
            mc=mc, baseline_mc=mc_base,
        )


class TestBackendTaskAgreement:
    def test_scan_and_ref_agree_on_the_narma_score(self, tm_sim, matrix_cell):
        """The task score is a property of the PHYSICS, not the backend:
        scan (core layout) and ref (planes) land on the same NMSE to well
        under the threshold's slack."""
        u, y = narma_series(T, order=10, seed=0)
        u32 = jnp.asarray(u.astype(np.float32))
        scan_sim = compile_plan(
            tm_sim.spec, ExecPlan(impl="scan", ensemble=1, chunk_ticks=8)
        )
        scores = {}
        for name, sim in (("ref", tm_sim), ("scan", scan_sim)):
            _, states = sim.drive(u32)
            st = jnp.asarray(np.asarray(states))
            ro = fit_ridge(
                st, jnp.asarray(y[:, None]), washout=WASHOUT, reg=1e-6
            )
            scores[name] = float(
                nmse(predict(ro, st), jnp.asarray(y[WASHOUT:, None]))
            )
        assert scores["ref"] == pytest.approx(scores["scan"], abs=2e-2)
        matrix_cell(
            topology="time_multiplexed", impl="scan", kind="task-agreement",
            nmse_ref=scores["ref"], nmse_scan=scores["scan"],
        )
