"""Process-wide plan cache: key anatomy, hit/rebind/evict semantics,
background pre-warm thread safety, and the compile-once contracts the
serving and tune layers rely on.

Key invariants pinned here (docs/ARCHITECTURE.md "Compile cache"):

- `spec_structural_hash` covers only what changes the compiled program
  (shape/dtype/topology/dt/hold_steps/tableau) — scalar param VALUES ride
  in lanes at call time, so specs differing only in values share a hash.
- `plan_cache_key` separates every executable-changing ExecPlan axis
  (impl/ensemble/precision/learn family/chunk_ticks/mesh decomposition),
  while `aot` and `compilation_cache_dir` — pure policy, same executable —
  are excluded.
- A cache hit is the SAME CompiledSim object (bit-exactness by
  construction); a hit under different param values is a near-free rebind
  of the requested spec onto the cached executable.
- One compile per key even under concurrency: a miss in flight parks
  later requesters on an event instead of duplicating the XLA work.

The module-level PLAN_CACHE is shared by the whole pytest process, so
tests against it assert stat DELTAS and use unique spec seeds (9xx_xxx
range) — never absolute counts.
"""

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    PLAN_CACHE,
    ExecPlan,
    PlanCache,
    compile_plan,
    make_spec,
    plan_cache_key,
    spec_structural_hash,
)
from repro.serve.reservoir import ReservoirEngine, StreamSession


def _scaled_params(spec, factor):
    """Same structure, different scalar values (lane-resident at runtime)."""
    return jax.tree_util.tree_map(lambda x: x * factor, spec.params)


def _one_device_mesh():
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def _sessions(rng, count, ticks=6, base_sid=0):
    return [
        StreamSession(
            sid=base_sid + i,
            u_seq=rng.uniform(0, 0.5, (ticks, 1)).astype(np.float32),
            collect_states=False,
        )
        for i in range(count)
    ]


# ---------------------------------------------------------------------------
# key anatomy
# ---------------------------------------------------------------------------


def test_structural_hash_ignores_param_values():
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_001, dtype=jnp.float32)
    revalued = spec._replace(params=_scaled_params(spec, 1.5))
    assert spec_structural_hash(spec) == spec_structural_hash(revalued)


def test_structural_hash_sees_structure():
    base = make_spec(n=12, n_in=1, hold_steps=4, seed=900_002, dtype=jnp.float32)
    variants = [
        base._replace(dt=base.dt * 2.0),
        base._replace(hold_steps=5),
        base._replace(tableau="heun"),
        make_spec(n=12, n_in=1, hold_steps=4, seed=900_003, dtype=jnp.float32),
        make_spec(n=14, n_in=1, hold_steps=4, seed=900_002, dtype=jnp.float32),
    ]
    h0 = spec_structural_hash(base)
    hashes = [spec_structural_hash(v) for v in variants]
    assert all(h != h0 for h in hashes), hashes
    assert len(set(hashes)) == len(hashes)


def test_plan_key_separates_executable_axes():
    plans = [
        ExecPlan(impl="scan"),
        ExecPlan(impl="chunk"),
        ExecPlan(impl="chunk", ensemble=8),
        ExecPlan(impl="chunk", ensemble=8, chunk_ticks=4),
        ExecPlan(impl="chunk", ensemble=8, precision="mixed"),
        ExecPlan(impl="chunk", ensemble=8, learn="rls"),
        ExecPlan(impl="chunk", ensemble=8, learn="rls", learn_lam=0.99),
        ExecPlan(impl="chunk", ensemble=8, learn="lms"),
        ExecPlan(impl="chunk", ensemble=8, interpret=True),
        ExecPlan(impl="scan", ensemble=8, mesh=_one_device_mesh()),
    ]
    keys = [plan_cache_key(p) for p in plans]
    assert len(set(keys)) == len(keys), "plan-key collision across variants"


def test_plan_key_excludes_pure_policy_fields():
    base = ExecPlan(impl="chunk", ensemble=4, chunk_ticks=4)
    assert plan_cache_key(base) == plan_cache_key(
        dataclasses.replace(base, aot=True)
    )
    # compilation_cache_dir changes WHERE executables persist, never what
    # they compute — key-equal by design (it is honored at compile time)
    assert plan_cache_key(base) == plan_cache_key(
        dataclasses.replace(base, compilation_cache_dir="/tmp/nonexistent-pc")
    )


def test_auto_impl_key_tracks_dispatch_generation(monkeypatch):
    from repro.kernels import ops

    k0 = plan_cache_key(ExecPlan(impl="auto", ensemble=2))
    ops.register_impl_choice(997, 3, "chunk")
    try:
        k1 = plan_cache_key(ExecPlan(impl="auto", ensemble=2))
        assert k0 != k1, (
            "a new dispatch measurement must invalidate cached auto plans"
        )
    finally:
        # the table entry is in-process only; the bumped generation makes
        # it invisible to every earlier cached key
        ops.register_impl_choice(997, 3, "ref")


# ---------------------------------------------------------------------------
# hit / rebind / evict semantics (local caches: no global interference)
# ---------------------------------------------------------------------------


def test_hit_returns_same_object():
    cache = PlanCache()
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_010, dtype=jnp.float32)
    plan = ExecPlan(impl="scan", ensemble=2, chunk_ticks=2)
    a = cache.get_or_compile(spec, plan)
    b = cache.get_or_compile(spec, plan)
    assert a is b
    s = cache.stats
    assert (s.misses, s.hits, s.compiles, s.rebinds) == (1, 1, 1, 0)
    assert len(cache) == 1


def test_rebind_on_param_value_change_matches_fresh_compile():
    cache = PlanCache()
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_011, dtype=jnp.float32)
    plan = ExecPlan(impl="scan")
    cache.get_or_compile(spec, plan)
    revalued = spec._replace(params=_scaled_params(spec, 1.3))
    rebound = cache.get_or_compile(revalued, plan)
    assert cache.stats.rebinds == 1 and cache.stats.compiles == 1
    assert rebound.spec is revalued

    u = np.random.default_rng(0).uniform(0, 0.5, (5, 1)).astype(np.float32)
    _, states_cached = rebound.drive(u)
    _, states_fresh = compile_plan(revalued, plan).drive(u)
    np.testing.assert_array_equal(
        np.asarray(states_cached), np.asarray(states_fresh)
    ), "rebound executable is not bit-identical to a fresh compile"


def test_eviction_roundtrip_bit_exact():
    cache = PlanCache(capacity=2)
    plan = ExecPlan(impl="scan")
    specs = [
        make_spec(n=12, n_in=1, hold_steps=4, seed=900_020 + i, dtype=jnp.float32)
        for i in range(3)
    ]
    for s in specs:
        cache.get_or_compile(s, plan)
    assert cache.stats.evictions == 1 and len(cache) == 2
    assert not cache.contains(specs[0], plan)  # LRU victim

    u = np.random.default_rng(1).uniform(0, 0.5, (5, 1)).astype(np.float32)
    recompiled = cache.get_or_compile(specs[0], plan)
    assert cache.stats.compiles == 4  # paid the compile again
    _, states_re = recompiled.drive(u)
    _, states_fresh = compile_plan(specs[0], plan).drive(u)
    np.testing.assert_array_equal(np.asarray(states_re), np.asarray(states_fresh))


def test_single_compile_under_concurrent_requests():
    cache = PlanCache()
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_030, dtype=jnp.float32)
    plan = ExecPlan(impl="scan", ensemble=2, chunk_ticks=2)
    sims, errs = [], []

    def work():
        try:
            sims.append(cache.get_or_compile(spec, plan))
        except BaseException as e:  # noqa: BLE001 — surface in main thread
            errs.append(e)

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(sims) == 4 and all(s is sims[0] for s in sims)
    assert cache.stats.compiles == 1, "in-flight event failed to dedupe"


def test_measure_memo():
    cache = PlanCache()
    kw = dict(dt=1.0e-11, n_steps=2, reps=1, candidates=("ref",))
    first = cache.measure(8, 2, **kw)
    second = cache.measure(8, 2, **kw)
    assert second is first
    assert cache.stats.measure_misses == 1 and cache.stats.measure_hits == 1
    # a different shape is a fresh measurement
    cache.measure(8, 4, **kw)
    assert cache.stats.measure_misses == 2


# ---------------------------------------------------------------------------
# serving integration (global PLAN_CACHE: deltas only, unique seeds)
# ---------------------------------------------------------------------------


def test_engine_template_route_shares_compiled_sim():
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_040, dtype=jnp.float32)
    kw = dict(num_slots=2, chunk_ticks=2)
    eng_a = ReservoirEngine(spec, **kw)
    hits0 = PLAN_CACHE.stats.hits
    eng_b = ReservoirEngine(spec, **kw)
    assert eng_b.sim is eng_a.sim
    assert PLAN_CACHE.stats.hits == hits0 + 1


def test_prewarmed_rescale_compiles_nothing():
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_041, dtype=jnp.float32)
    eng = ReservoirEngine(
        PLAN_CACHE.get_or_compile(spec, ExecPlan(ensemble=4, chunk_ticks=2)),
        autoscale=True,
        min_slots=2,
        max_slots=8,
    )
    eng.prewarm(block=True)
    compiles0 = PLAN_CACHE.stats.compiles
    eng._rescale(8)
    eng._rescale(2)
    assert PLAN_CACHE.stats.compiles == compiles0
    st = eng.stats()
    assert st.cold_rescales == 0 and st.warm_rescales == 2
    assert st.rescale_stall_s == 0.0

    # and the engine still serves correctly at the rescaled width
    rng = np.random.default_rng(3)
    results = eng.run(_sessions(rng, 5))
    assert len(results) == 5


def test_concurrent_rescale_during_prewarm():
    """A _rescale racing the background pre-warm must wait on the in-flight
    compile (one compile per key), never crash, and leave a serving-correct
    engine behind."""
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_042, dtype=jnp.float32)
    eng = ReservoirEngine(
        PLAN_CACHE.get_or_compile(spec, ExecPlan(ensemble=4, chunk_ticks=2)),
        autoscale=True,
        min_slots=2,
        max_slots=8,
        prewarm=False,
    )
    misses0 = PLAN_CACHE.stats.misses
    compiles0 = PLAN_CACHE.stats.compiles
    eng.prewarm_buckets(block=False)  # daemon thread compiles 2 and 8
    eng._rescale(8)  # races the thread on the ensemble=8 key
    if eng._prewarm_thread is not None:
        eng._prewarm_thread.join(timeout=60)
    d_miss = PLAN_CACHE.stats.misses - misses0
    d_comp = PLAN_CACHE.stats.compiles - compiles0
    assert d_comp == d_miss, (
        f"{d_comp} compiles for {d_miss} misses — the in-flight event "
        f"duplicated XLA work under the race"
    )
    assert eng.num_slots == 8
    rng = np.random.default_rng(4)
    results = eng.run(_sessions(rng, 6))
    assert len(results) == 6


def test_compile_plan_measure_memoized():
    spec = make_spec(n=13, n_in=1, hold_steps=4, seed=900_043, dtype=jnp.float32)
    plan = ExecPlan(ensemble=2, chunk_ticks=2, measure=True)
    m0 = PLAN_CACHE.stats.measure_misses
    h0 = PLAN_CACHE.stats.measure_hits
    compile_plan(spec, plan)
    assert PLAN_CACHE.stats.measure_misses == m0 + 1
    compile_plan(spec, plan)
    assert PLAN_CACHE.stats.measure_hits == h0 + 1, (
        "repeat measure=True compile re-ran the latency probe"
    )


# ---------------------------------------------------------------------------
# sharded autoscale (lifted restriction)
# ---------------------------------------------------------------------------


def test_sharded_autoscale_allowed_when_widths_divide():
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_050, dtype=jnp.float32)
    sim = PLAN_CACHE.get_or_compile(
        spec,
        ExecPlan(impl="scan", ensemble=4, chunk_ticks=2,
                 mesh=_one_device_mesh()),
    )
    eng = ReservoirEngine(
        sim, autoscale=True, min_slots=2, max_slots=8, prewarm=False
    )
    assert eng.autoscale is not None
    rng = np.random.default_rng(5)
    results = eng.run(_sessions(rng, 3))
    assert len(results) == 3


def test_sharded_autoscale_rejects_indivisible_widths(monkeypatch):
    import repro.serve.reservoir as reservoir_mod

    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_050, dtype=jnp.float32)
    sim = PLAN_CACHE.get_or_compile(
        spec,
        ExecPlan(impl="scan", ensemble=4, chunk_ticks=2,
                 mesh=_one_device_mesh()),
    )
    # a single-host CPU run cannot build a >1-device mesh, so emulate the
    # multi-device decomposition at the validation seam
    monkeypatch.setattr(reservoir_mod, "_ensemble_axis_size", lambda plan: 3)
    with pytest.raises(ValueError, match="incompatible widths"):
        ReservoirEngine(
            sim, autoscale=True, min_slots=2, max_slots=8, prewarm=False
        )


def test_bucket_ladder_and_axis_size_helpers():
    from repro.serve.reservoir import _bucket_ladder, _ensemble_axis_size

    assert _bucket_ladder(2, 8) == [2, 4, 8]
    assert _bucket_ladder(2, 12) == [2, 4, 8, 12]  # non-power-of-two clamp
    assert _bucket_ladder(4, 4) == [4]
    assert _ensemble_axis_size(ExecPlan(impl="chunk")) == 1
    sharded = ExecPlan(impl="scan", mesh=_one_device_mesh())
    assert _ensemble_axis_size(sharded) == 1  # ("data",) axis on 1 device


# ---------------------------------------------------------------------------
# tune integration: one compile per structural combo, across calls
# ---------------------------------------------------------------------------


def test_tune_compiles_each_structural_combo_once():
    from repro.tune import Choice, Float, SearchSpace, narma_task, tune_spec

    task = narma_task(32, order=10, seed=9, learn_washout=8)
    space = SearchSpace({
        "drive_current": Float(0.5e-3, 4.5e-3),
        "hold_steps": Choice((3, 5)),
    })
    plan = ExecPlan(impl="scan", ensemble=4, chunk_ticks=2, learn="rls")
    spec = make_spec(n=12, n_in=1, hold_steps=4, seed=900_060, dtype=jnp.float32)

    def run_once():
        return tune_spec(
            spec, task, space, budget=8, plan=plan, strategy="cmaes", seed=2
        )

    c0 = PLAN_CACHE.stats.compiles
    first = run_once()
    combos = {t.assignment["hold_steps"] for t in first.trials}
    assert PLAN_CACHE.stats.compiles - c0 == len(combos), (
        "a 2-generation CMA-ES run must compile each structural combo "
        "exactly once"
    )
    second = run_once()
    assert PLAN_CACHE.stats.compiles - c0 == len(combos), (
        "revisiting the same structural combos recompiled them"
    )
    assert [t.fitness for t in first.trials] == [
        t.fitness for t in second.trials
    ], "cached engines changed the search's numerics"
