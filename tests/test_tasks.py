"""Edge coverage for core/tasks.py and the readout helpers predict / nmse
(which previously rode along untested).

Pins: NARMA order/length validation and the divergence guard (unstable
orders raise instead of handing a readout inf targets), delay-memory target
alignment, memory_capacity's zero-variance column handling, and the
predict/nmse shape/washout semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fit_ridge, nmse, predict, tasks
from repro.core.reservoir import Readout


class TestNarma:
    def test_narma10_is_stable_and_deterministic(self):
        u, y = tasks.narma_series(300, order=10, seed=0)
        assert u.shape == y.shape == (300,)
        assert np.isfinite(y).all() and np.abs(y).max() < 1e3
        u2, y2 = tasks.narma_series(300, order=10, seed=0)
        np.testing.assert_array_equal(y, y2)

    def test_divergent_order_raises(self):
        # the NARMA feedback term is unstable well before order ~30; the
        # guard turns the inf/NaN series into an actionable error
        with pytest.raises(ValueError, match="diverged"):
            tasks.narma_series(500, order=30, seed=0)

    def test_order_and_length_validation(self):
        with pytest.raises(ValueError, match="order"):
            tasks.narma_series(100, order=0)
        with pytest.raises(ValueError, match="order"):
            tasks.narma_series(100, order=-2)
        with pytest.raises(ValueError, match="order"):
            tasks.narma_series(100, order=2.5)
        with pytest.raises(ValueError, match="t must be"):
            tasks.narma_series(0, order=2)


class TestDelayMemory:
    def test_targets_align(self):
        u = np.arange(1.0, 6.0)  # [1..5]
        out = tasks.delay_memory_targets(u, 3)
        assert out.shape == (5, 3)
        # y_d[t] = u[t - d]
        np.testing.assert_array_equal(out[3], [3.0, 2.0, 1.0])
        np.testing.assert_array_equal(out[:1, 0], [0.0])  # pre-history zero

    def test_rejects_bad_delay(self):
        with pytest.raises(ValueError, match="max_delay"):
            tasks.delay_memory_targets(np.arange(4.0), 0)

    def test_memory_capacity_perfect_and_zero_variance(self):
        rng = np.random.default_rng(0)
        u = rng.normal(size=200)
        tgt = tasks.delay_memory_targets(u, 2)[2:]
        # perfect predictions: each delay contributes corr^2 = 1
        assert tasks.memory_capacity(tgt, tgt) == pytest.approx(2.0)
        # a zero-variance (constant) prediction column contributes 0, no NaN
        pred = tgt.copy()
        pred[:, 1] = 7.0
        mc = tasks.memory_capacity(pred, tgt)
        assert mc == pytest.approx(1.0)
        # constant TARGET column likewise
        tgt2 = tgt.copy()
        tgt2[:, 0] = 0.0
        assert np.isfinite(tasks.memory_capacity(tgt, tgt2))

    def test_sine_task_shapes(self):
        u, y = tasks.sine_task(128, seed=3)
        assert u.shape == y.shape == (128,)
        assert np.abs(y).max() <= 1.0


class TestPredictNmse:
    def test_predict_applies_washout_and_bias(self):
        states = jnp.asarray(np.arange(12.0, dtype=np.float32).reshape(6, 2))
        w = jnp.asarray(np.array([[1.0], [2.0], [10.0]], np.float32))
        ro = Readout(w_out=w, washout=2)
        out = np.asarray(predict(ro, states))
        assert out.shape == (4, 1)
        # row t: s0 + 2 s1 + 10 (bias row is appended ones)
        np.testing.assert_allclose(
            out[:, 0], states[2:, 0] * 1 + states[2:, 1] * 2 + 10.0
        )

    def test_fit_ridge_predict_roundtrip_is_exact_on_linear_data(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(100, 5)).astype(np.float32)
        w = rng.normal(size=(5, 2)).astype(np.float32)
        y = x @ w + 0.5
        ro = fit_ridge(x, y, washout=0, reg=1e-8)
        err = nmse(predict(ro, jnp.asarray(x)), jnp.asarray(y))
        assert err < 1e-6

    def test_nmse_scale(self):
        y = jnp.asarray(np.random.default_rng(2).normal(size=(50, 1)))
        assert nmse(y, y) == 0.0
        # predicting the mean scores ~1
        mean_pred = jnp.full_like(y, float(jnp.mean(y)))
        assert nmse(mean_pred, y) == pytest.approx(1.0, rel=1e-3)

    def test_nmse_reshapes_1d_targets(self):
        p = jnp.asarray(np.ones((4, 1), np.float32))
        t = jnp.asarray(np.ones(4, np.float32))
        assert nmse(p, t) == 0.0
