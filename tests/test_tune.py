"""The tune subsystem (repro/tune): lane-vectorized hyperparameter search.

The contracts this file pins:

  - `SearchSpace` resolves aliases against the tunable-knob registries,
    forces structural knobs (dt/hold_steps/learn_*) to Choice domains,
    and owns a deterministic [0, 1]^d genotype codec (sorted knob order).
  - Strategies are deterministic in (seed, tell order): fixed-seed
    `tune_spec` runs reproduce their trial histories EXACTLY — ids,
    assignments, and fitnesses — for random and CMA-ES.
  - CMA-ES converges on a known quadratic surrogate (pure ask/tell, no
    engine) and respects the generation-buffered ask protocol.
  - Candidate lanes are invisible to co-resident tenants: a tenant served
    next to washout-autotune probe traffic is BIT-IDENTICAL (states,
    learned weights, nmse) to the same tenant served alone — lane
    re-seeding at chunk boundaries goes through the same SlotStore
    admit/retire path ordinary sessions use, and scan-backend lanes are
    independent.
  - `washout_autotune` / `ReservoirEngine.submit_autotuned` runs end to
    end: probes with negative sids never leak into tenant results,
    max_retained is restored, the winner's knobs are frozen into the
    session, and the tuned tenant is served.
  - Structural knobs group candidates into per-combination engines;
    failed candidates rank last but are reported to the strategy as a
    finite penalty.
"""

import numpy as np
import pytest

from repro.api import ExecPlan, compile_plan, make_spec
from repro.core.tasks import narma_series
from repro.serve.reservoir import ReservoirEngine, StreamSession
from repro.tune import (
    CMAES,
    PENALTY_FITNESS,
    Choice,
    Float,
    GridSearch,
    LogFloat,
    RandomSearch,
    SearchSpace,
    TuneTask,
    make_strategy,
    narma_task,
    tune_spec,
    washout_autotune,
)


def _space():
    return SearchSpace({
        "drive_current": Float(0.5e-3, 4.5e-3),
        "spectral_radius": Float(0.2, 1.2),
    })


def _spec(n=24):
    return make_spec(n=n, n_in=1, hold_steps=5, seed=1)


def _plan(e=4, learn="rls"):
    return ExecPlan(impl="scan", ensemble=e, chunk_ticks=8, learn=learn)


class TestSearchSpace:
    def test_sorted_names_and_alias_resolution(self):
        s = _space()
        assert s.names == ("a_cp", "current")  # sorted canonical order
        assert s.dim == 2

    def test_decode_bounds(self):
        s = _space()
        lo = s.decode([0.0, 0.0])
        hi = s.decode([1.0, 1.0])
        assert lo["a_cp"] == 0.2 and hi["a_cp"] == 1.2
        assert lo["current"] == 0.5e-3 and hi["current"] == 4.5e-3

    def test_logfloat_decodes_log_uniform(self):
        s = SearchSpace({"learn_reg": Choice([1e-2]), "current": LogFloat(1e-4, 1e-2)})
        mid = s.decode([0.5, 0.5])["current"]
        assert mid == pytest.approx(1e-3, rel=1e-9)  # geometric midpoint

    def test_choice_bucket_decode_clamps_top(self):
        dom = Choice([10, 20, 30])
        assert dom.decode(0.0) == 10
        assert dom.decode(0.999) == 30
        assert dom.decode(1.0) == 30  # u = 1.0 clamps into the last bucket

    def test_structural_knob_requires_choice(self):
        with pytest.raises(TypeError, match="STRUCTURAL"):
            SearchSpace({"hold_steps": Float(1, 10)})
        with pytest.raises(TypeError, match="STRUCTURAL"):
            SearchSpace({"learn_lam": LogFloat(0.9, 1.0)})
        SearchSpace({"hold_steps": Choice([2, 4])})  # Choice is fine

    def test_unknown_knob_raises_with_valid_list(self):
        with pytest.raises(ValueError, match="valid knobs"):
            SearchSpace({"warp_factor": Float(0, 1)})

    def test_duplicate_via_alias_raises(self):
        with pytest.raises(ValueError, match="duplicate"):
            SearchSpace({"a_cp": Float(0, 1), "spectral_radius": Float(0, 1)})

    def test_split_classifies_lane_struct_plan(self):
        s = SearchSpace({
            "drive_current": Float(1e-3, 4e-3),
            "hold_steps": Choice([2, 4]),
            "learn_lam": Choice([0.99, 1.0]),
        })
        lane, spec_kw, plan_kw = s.split(s.decode([0.0, 0.0, 0.0]))
        assert set(lane) == {"current"}
        assert set(spec_kw) == {"hold_steps"}
        assert set(plan_kw) == {"learn_lam"}

    def test_genotype_validation(self):
        s = _space()
        with pytest.raises(ValueError, match="shape"):
            s.decode([0.5])
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            s.decode([0.5, 1.5])

    def test_grid_sizes(self):
        assert _space().grid_sizes is None
        s = SearchSpace({"current": Choice([1, 2]), "a_cp": Choice([1, 2, 3])})
        assert s.grid_sizes == (3, 2)  # sorted name order: a_cp, current


class TestStrategies:
    def test_random_is_seed_deterministic_and_budget_capped(self):
        a = RandomSearch(_space(), budget=5, seed=7)
        b = RandomSearch(_space(), budget=5, seed=7)
        ga = [g for _, g in a.ask(10)]
        gb = [g for _, g in b.ask(10)]
        assert len(ga) == 5 and a.exhausted
        np.testing.assert_array_equal(np.stack(ga), np.stack(gb))
        assert a.ask(1) == []

    def test_grid_enumerates_choice_product_exactly(self):
        s = SearchSpace({"current": Choice([1e-3, 2e-3]), "a_cp": Choice([0.3, 0.9])})
        g = GridSearch(s, budget=10)
        assert g.grid_size == 4
        out = [s.decode(geno) for _, geno in g.ask(10)]
        assert g.exhausted
        # row-major over sorted names (a_cp outer, current inner)
        assert [(o["a_cp"], o["current"]) for o in out] == [
            (0.3, 1e-3), (0.3, 2e-3), (0.9, 1e-3), (0.9, 2e-3),
        ]

    def test_tell_validates_token_and_finiteness(self):
        s = RandomSearch(_space(), budget=2, seed=0)
        (tok, _), = s.ask(1)
        with pytest.raises(KeyError):
            s.tell(tok + 99, 1.0)
        with pytest.raises(ValueError, match="finite"):
            s.tell(tok, float("nan"))
        s.tell(tok, 1.0)
        with pytest.raises(KeyError):  # double-tell
            s.tell(tok, 1.0)

    def test_cmaes_generation_buffered_ask(self):
        s = CMAES(_space(), budget=20, seed=0, popsize=4)
        first = s.ask(10)
        assert len(first) == 4  # one generation, not the full ask
        assert s.ask(10) == []  # waiting on tells
        for tok, g in first:
            s.tell(tok, float(np.sum(g**2)))
        assert len(s.ask(10)) == 4  # next generation after the update

    def test_cmaes_converges_on_quadratic_surrogate(self):
        # minimize ||g - g*||^2 over the unit cube — pure ask/tell, no
        # engine; CMA-ES must land near the optimum within a small budget
        target = np.array([0.7, 0.3])
        s = CMAES(_space(), budget=120, seed=2, popsize=8)
        best, first_gen_best = np.inf, None
        while not s.exhausted:
            batch = s.ask(8)
            for tok, g in batch:
                f = float(np.sum((g - target) ** 2))
                s.tell(tok, f)
                best = min(best, f)
            if first_gen_best is None and batch:
                first_gen_best = best
        assert best < 1e-3, f"CMA-ES best {best} did not converge"
        assert best < first_gen_best / 10

    def test_cmaes_seed_determinism(self):
        runs = []
        for _ in range(2):
            s = CMAES(_space(), budget=24, seed=5, popsize=6)
            hist = []
            while not s.exhausted:
                for tok, g in s.ask(6):
                    f = float(np.sum((g - 0.4) ** 2))
                    s.tell(tok, f)
                    hist.append((tok, f))
            runs.append(hist)
        assert runs[0] == runs[1]

    def test_make_strategy_passthrough_and_validation(self):
        s = _space()
        st = RandomSearch(s, budget=3, seed=0)
        assert make_strategy(st, s, 3) is st
        other = SearchSpace({"alpha": Float(0.001, 0.1)})
        with pytest.raises(ValueError, match="different search space"):
            make_strategy(st, other, 3)
        with pytest.raises(ValueError, match="unknown strategy"):
            make_strategy("anneal", s, 3)


class TestTuneSpec:
    def test_fixed_seed_trial_history_is_exact(self):
        spec, task = _spec(), narma_task(t=60, seed=0, learn_washout=15)
        runs = []
        for _ in range(2):
            r = tune_spec(spec, task, _space(), budget=6, plan=_plan(), seed=3)
            runs.append([
                (t.trial_id, t.fitness, tuple(sorted(t.assignment.items())))
                for t in r.trials
            ])
        assert runs[0] == runs[1]
        assert len(runs[0]) == 6

    def test_cmaes_history_deterministic_through_engine(self):
        spec, task = _spec(), narma_task(t=60, seed=0, learn_washout=15)
        runs = []
        for _ in range(2):
            r = tune_spec(spec, task, _space(), budget=8, plan=_plan(),
                          strategy="cmaes", seed=1, popsize=4)
            runs.append([(t.trial_id, t.fitness) for t in r.trials])
        assert runs[0] == runs[1]
        assert len(runs[0]) == 8

    def test_structural_knobs_group_engines(self):
        spec, task = _spec(), narma_task(t=40, seed=0, learn_washout=10)
        space = SearchSpace({
            "drive_current": Float(1e-3, 4e-3),
            "hold_steps": Choice([3, 5]),
        })
        r = tune_spec(spec, task, space, budget=6, plan=_plan(), seed=0)
        keys = {t.engine_key for t in r.trials}
        assert keys <= {"hold_steps=3", "hold_steps=5"}
        assert len(keys) == 2  # 6 random draws hit both buckets w.h.p.

    def test_failed_candidates_rank_last_and_tell_penalty(self):
        spec = _spec()
        calls = []

        def score(result):
            calls.append(result.sid)
            return float("inf") if result.sid % 2 == 0 else 1.0

        task = TuneTask(u_seq=np.zeros(24, np.float32), score=score)
        r = tune_spec(spec, task, _space(), budget=4,
                      plan=ExecPlan(impl="scan", ensemble=4, chunk_ticks=8),
                      seed=0)
        ranked = r.ranked()
        assert [t.ok for t in ranked] == [True, True, False, False]
        assert r.best.fitness == 1.0
        assert all(not np.isfinite(t.fitness) for t in ranked[2:])
        assert len(calls) == 4

    def test_rejects_ensemble_leaved_template(self):
        from repro.core import broadcast_params

        spec = _spec()
        wide = spec._replace(params=broadcast_params(spec.params, 4))
        with pytest.raises(ValueError, match="scalar-leaved"):
            tune_spec(wide, narma_task(t=20), _space(), budget=2)

    def test_sequential_flag(self):
        spec, task = _spec(), narma_task(t=40, seed=0, learn_washout=10)
        r = tune_spec(spec, task, _space(), budget=2,
                      plan=ExecPlan(impl="scan", ensemble=1, chunk_ticks=8,
                                    learn="rls"), seed=0)
        assert r.sequential and len(r.trials) == 2

    def test_task_requires_targets_or_score(self):
        with pytest.raises(ValueError, match="targets.*score|score.*targets"):
            TuneTask(u_seq=np.zeros(10))


class TestNonPerturbation:
    def test_probe_traffic_does_not_perturb_cotenant_bitwise(self):
        """The ISSUE's reseed-at-chunk-boundary pin: a tenant co-resident
        with washout-autotune probe lanes is bit-identical to the same
        tenant served alone (scan backend)."""
        spec, plan = _spec(), _plan(e=4)
        u, y = narma_series(96, order=10, seed=0)
        mk = lambda: StreamSession(
            sid=0, u_seq=u.copy(), targets=y.copy(), learn_washout=24
        )

        solo_eng = ReservoirEngine(compile_plan(spec, plan))
        solo_eng.submit(mk())
        while solo_eng.step_chunk():
            pass
        solo = solo_eng.pop_results()[0]

        eng = ReservoirEngine(compile_plan(spec, plan))
        eng.submit(mk())
        u2, y2 = narma_series(96, order=10, seed=5)
        tuned = StreamSession(sid=1, u_seq=u2, targets=y2, learn_washout=24)
        eng.submit_autotuned(tuned, _space(), budget=5, seed=9)
        while eng.step_chunk():
            pass
        shared = eng.pop_results()
        assert set(shared) == {0, 1}  # probe sids (negative) never leak

        assert shared[0].learn_nmse == solo.learn_nmse
        np.testing.assert_array_equal(shared[0].final_m, solo.final_m)
        np.testing.assert_array_equal(shared[0].states, solo.states)
        np.testing.assert_array_equal(
            np.asarray(shared[0].learned_readout.w_out),
            np.asarray(solo.learned_readout.w_out),
        )


class TestWashoutAutotune:
    def test_end_to_end_winner_frozen_and_served(self):
        spec, plan = _spec(), _plan(e=4)
        eng = ReservoirEngine(compile_plan(spec, plan), max_retained=3)
        u, y = narma_series(80, order=10, seed=2)
        session = StreamSession(sid=7, u_seq=u, targets=y, learn_washout=20)
        result = eng.submit_autotuned(session, _space(), budget=5, seed=0)
        assert len(result.trials) == 5
        assert all(t.engine_key == "live" for t in result.trials)
        winner = result.best.assignment
        assert float(session.params.current) == winner["current"]
        assert float(session.params.a_cp) == winner["a_cp"]
        assert eng.max_retained == 3  # restored after the probe phase
        while eng.step_chunk():
            pass
        served = eng.pop_results()
        assert set(served) == {7}
        assert np.isfinite(served[7].learn_nmse)

    def test_probe_history_is_seed_deterministic(self):
        spec, plan = _spec(), _plan(e=4)
        u, y = narma_series(80, order=10, seed=2)
        hists = []
        for _ in range(2):
            eng = ReservoirEngine(compile_plan(spec, plan))
            s = StreamSession(sid=0, u_seq=u.copy(), targets=y.copy(),
                              learn_washout=20)
            r = eng.submit_autotuned(s, _space(), budget=5, seed=4)
            hists.append([(t.trial_id, t.fitness) for t in r.trials])
        assert hists[0] == hists[1]

    def test_validation(self):
        spec = _spec()
        u, y = narma_series(40, order=10, seed=0)

        plain_eng = ReservoirEngine(
            compile_plan(spec, ExecPlan(impl="scan", ensemble=4, chunk_ticks=8))
        )
        with pytest.raises(ValueError, match="learning engine"):
            plain_eng.submit_autotuned(
                StreamSession(sid=0, u_seq=u, targets=y, learn_washout=10),
                _space(), budget=2,
            )

        eng = ReservoirEngine(compile_plan(spec, _plan(e=4)))
        with pytest.raises(ValueError, match="targets"):
            eng.submit_autotuned(
                StreamSession(sid=0, u_seq=u, learn_washout=10), _space(),
                budget=2,
            )
        with pytest.raises(ValueError, match="learn_washout"):
            eng.submit_autotuned(
                StreamSession(sid=0, u_seq=u, targets=y, learn_washout=0),
                _space(), budget=2,
            )
        struct_space = SearchSpace({"hold_steps": Choice([2, 4])})
        with pytest.raises(ValueError, match="lane knobs only"):
            eng.submit_autotuned(
                StreamSession(sid=0, u_seq=u, targets=y, learn_washout=10),
                struct_space, budget=2,
            )
        with pytest.raises(ValueError, match="shorter than"):
            washout_autotune(
                eng,
                StreamSession(sid=0, u_seq=u[:5], targets=y[:5],
                              learn_washout=10),
                _space(), budget=2,
            )
