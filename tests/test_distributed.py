"""Distribution-layer tests (subprocess with 8 virtual devices):
sharding rules + divisibility fallbacks, compressed DP psum correctness,
elastic checkpoint restore across DIFFERENT mesh shapes, and a small
end-to-end sharded train-step lowering."""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduce_config
from repro.distributed import sharding as shd
from repro.distributed.collectives import dp_mean_grads_compressed
from repro.launch import steps as steps_mod
from repro.models import build_model
from repro.train import checkpoint as ckpt

out = {}
mesh = jax.make_mesh((2, 4), ("data", "model"))

# --- 1. sharding rules: divisible dims shard, indivisible replicate -------
cfg = reduce_config(get_config("qwen2-moe-a2.7b"))   # moe: experts=8 % 4 == 0
m = build_model(cfg)
specs = jax.eval_shape(m.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
shardings = shd.param_shardings(mesh, specs)

flat = jax.tree_util.tree_flatten_with_path(shardings)[0]
by_path = {jax.tree_util.keystr(p): s for p, s in flat}
def spec_of(*frags):
    for k, v in by_path.items():
        if all(f in k for f in frags):
            return str(v.spec)
    raise KeyError(frags)

# embed (vocab 512 % 4 == 0) -> vocab sharded
out["embed_spec"] = str(spec_of("embed"))
# stacked attention wq kernel: leading periods axis None, out dim sharded
out["wq_spec"] = str(spec_of("wq", "kernel"))
# moe experts (8, d, f): experts sharded over model
out["moe_spec"] = str(spec_of("mlp", "w_gate"))

# --- 2. compressed psum == plain mean within int8 tolerance ----------------
grads = {"a": jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)), jnp.float32),
         "b": jnp.asarray(np.random.default_rng(1).standard_normal((4,)), jnp.float32)}
dp_mesh = jax.make_mesh((8,), ("data",))
red = dp_mean_grads_compressed(dp_mesh, grads, axis_name="data")
# all shards identical here (replicated input) -> mean == value
err = max(float(jnp.max(jnp.abs(red[k] - grads[k]))) for k in grads)
out["psum_err"] = err

# --- 3. elastic restore: save under mesh (2,4), restore under (4,2) -------
params = m.init(jax.random.PRNGKey(0))
train_step, opt, _ = steps_mod.make_train_step(cfg)
opt_state = opt.init(params)
ckpt.save_checkpoint("/tmp/elastic_ckpt", 3, params, opt_state)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
p_t = jax.eval_shape(m.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
o_t = jax.eval_shape(opt.init, p_t)
p_sh2 = shd.param_shardings(mesh2, p_t)
o_sh2 = opt.state_shardings(mesh2, p_sh2, p_t)
p2, o2, extra, step = ckpt.restore_checkpoint(
    "/tmp/elastic_ckpt", None, p_t, o_t, shardings=(p_sh2, o_sh2))
out["elastic_step"] = step
leaf0 = jax.tree.leaves(params)[0]
leaf2 = jax.tree.leaves(p2)[0]
out["elastic_exact"] = bool(jnp.array_equal(leaf0, leaf2))
out["elastic_sharded"] = str(jax.tree.leaves(p2)[0].sharding.mesh.shape)

# --- 4. sharded train step lowers + runs on the small mesh ----------------
shd.enable_constraints(mesh)
b_batch = {
    "tokens": jnp.zeros((8, 16), jnp.int32),
    "labels": jnp.zeros((8, 16), jnp.int32),
    "loss_mask": jnp.ones((8, 16), jnp.float32),
}
b_sh = shd.batch_shardings(mesh, jax.eval_shape(lambda: b_batch))
p_sh = shd.param_shardings(mesh, params)
o_sh = opt.state_shardings(mesh, p_sh, params)
params_d = jax.tree.map(jax.device_put, params, p_sh)
opt_d = jax.tree.map(jax.device_put, opt_state, o_sh)
step_fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh, None),
                  out_shardings=(p_sh, o_sh, None))
p_new, o_new, metrics = step_fn(params_d, opt_d, b_batch, jnp.asarray(0))
out["train_loss"] = float(metrics["loss"])
shd.enable_constraints(None)

# --- 5. kv-seq-shard rule flips the cache spec ------------------------------
leafK = jax.ShapeDtypeStruct((8, 32, 4, 16), jnp.float32)
spec_default = shd.cache_spec_for("caches/stack/0/self/k",
    jax.ShapeDtypeStruct((2, 8, 32, 4, 16), jnp.float32), mesh)
os.environ["REPRO_KV_SEQ_SHARD"] = "1"
spec_seq = shd.cache_spec_for("caches/stack/0/self/k",
    jax.ShapeDtypeStruct((2, 8, 32, 4, 16), jnp.float32), mesh)
os.environ["REPRO_KV_SEQ_SHARD"] = "0"
out["cache_default"] = str(spec_default)
out["cache_seq"] = str(spec_seq)

print(json.dumps(out))
"""


@pytest.mark.slow
def test_distribution_layer():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("REPRO_KV_SEQ_SHARD", None)
    res = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=1200,
    )
    assert res.returncode == 0, res.stderr[-4000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # 1. rules
    assert "model" in out["embed_spec"]
    # stacked wq: leading periods axis unsharded, out dim on model
    assert out["wq_spec"].startswith("PartitionSpec(None,") and "model" in out["wq_spec"]
    assert "model" in out["moe_spec"]
    # 2. compressed psum: identical shards -> reconstruction within q-step
    assert out["psum_err"] < 0.05
    # 3. elastic restore
    assert out["elastic_step"] == 3
    assert out["elastic_exact"]
    assert "4" in out["elastic_sharded"]
    # 4. sharded train step executes
    assert out["train_loss"] > 0 and out["train_loss"] < 20
    # 5. cache layout knob
    assert "model" in out["cache_seq"]
    assert out["cache_seq"] != out["cache_default"]
