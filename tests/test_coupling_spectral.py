"""core.coupling.spectral_radius beyond the exact-eigvals regime.

Above `_EXACT_EIG_MAX_N` (2048) the builder switches from dense eigvals to
the circular-law estimate refined by power iteration on W^2 — a path that
was previously untested. These tests pin it via the `exact_max_n` override
(same code path, tractable sizes):

  - at a boundary N just past the cutoff, the estimate agrees with the
    exact eigvals within a few percent (iid U[-1,1] matrices are exactly
    its design case);
  - `make_coupling_matrix` built through the estimate path actually lands
    near the requested spectral radius;
  - the divergence fallback: a matrix far from the circular law (where the
    refinement would wander) falls back to the circular-law estimate
    instead of returning the diverged value.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import coupling


def _iid_matrix(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1.0, 1.0, size=(n, n))
    np.fill_diagonal(w, 0.0)
    return w


class TestSpectralRadiusEstimate:
    def test_boundary_crossing_changes_path_not_answer(self):
        """N just below the cutoff runs exact eigvals, N just above runs the
        estimate; both must describe the same matrix within tolerance."""
        n = 257
        w = _iid_matrix(n, seed=1)
        exact = coupling.spectral_radius(w, exact_max_n=n)  # dense eigvals
        est = coupling.spectral_radius(w, exact_max_n=n - 1)  # estimate path
        assert exact > 0
        assert abs(est - exact) <= 0.10 * exact, (est, exact)

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_estimate_tracks_exact_across_seeds(self, seed):
        n = 300
        w = _iid_matrix(n, seed=seed)
        exact = float(np.max(np.abs(np.linalg.eigvals(w))))
        est = coupling.spectral_radius(w, exact_max_n=64)
        assert abs(est - exact) <= 0.10 * exact, (seed, est, exact)

    def test_default_cutoff_is_2048(self):
        assert coupling._EXACT_EIG_MAX_N == 2048

    def test_make_coupling_matrix_estimate_path_hits_target_rho(self):
        """Build through the large-N path (forced small cutoff via a direct
        rescale check): rho(W_scaled) must land near target_rho."""
        n = 300
        w = _iid_matrix(n, seed=3)
        est = coupling.spectral_radius(w, exact_max_n=64)
        w_scaled = w * (1.0 / est)
        true_rho = float(np.max(np.abs(np.linalg.eigvals(w_scaled))))
        assert abs(true_rho - 1.0) <= 0.10, true_rho

    def test_divergence_fallback_returns_circular_law(self):
        """A rank-1 matrix is maximally far from the circular law: its true
        spectral radius (~n/3 for outer(u, u) of U[-1,1] entries) is far
        from sigma*sqrt(n), so the refinement 'diverges wildly' from the
        estimate and the guard must fall back to the estimate itself."""
        n = 300
        rng = np.random.default_rng(4)
        u = rng.uniform(-1.0, 1.0, size=n)
        w = np.outer(u, u)  # rho = |u|^2 ~ n/3 >> sigma*sqrt(n) ~ sqrt(n)/3
        sigma = float(np.std(w))
        circ = sigma * np.sqrt(n)
        got = coupling.spectral_radius(w, exact_max_n=64)
        assert got == pytest.approx(circ, rel=1e-12)
        # sanity: the fallback really did discard a diverged refinement
        true_rho = float(np.max(np.abs(np.linalg.eigvals(w))))
        assert true_rho > 2 * circ

    def test_zero_matrix_estimate_path(self):
        w = np.zeros((300, 300))
        assert coupling.spectral_radius(w, exact_max_n=64) == 0.0
