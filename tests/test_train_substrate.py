"""Training substrate: data determinism, optimizer math, checkpoint
roundtrip, and the fault-tolerance restart path (failure injection)."""

import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduce_config
from repro.data import DataConfig, SyntheticTokens
from repro.models import build_model
from repro.optim import (
    cosine_schedule,
    global_norm,
    make_adafactor,
    make_adamw,
    make_compressor,
)
from repro.train import LoopConfig, latest_step, restore_checkpoint, save_checkpoint, train


class TestData:
    def test_deterministic_by_step(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=3)
        a = SyntheticTokens(cfg).batch(7)
        b = SyntheticTokens(cfg).batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = SyntheticTokens(cfg).batch(8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
        b = SyntheticTokens(cfg).batch(0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_any_host_can_slice(self):
        """Straggler story: a shard equals the slice of the global batch."""
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=8)
        src = SyntheticTokens(cfg)
        full = src.batch(3)
        part = src.batch(3, batch_slice=slice(2, 6))
        np.testing.assert_array_equal(full["tokens"][2:6], part["tokens"])


class TestOptimizers:
    def _quad(self):
        params = {"w": jnp.array([1.0, -2.0, 3.0])}
        grad_fn = jax.grad(lambda p: jnp.sum(p["w"] ** 2))
        return params, grad_fn

    @pytest.mark.parametrize("make", [make_adamw, make_adafactor])
    def test_descends_quadratic(self, make):
        opt = make(lr_fn=lambda s: 0.05)
        params, grad_fn = self._quad()
        state = opt.init(params)
        for step in range(120):
            g = grad_fn(params)
            params, state = opt.update(params, g, state, step)
        assert float(jnp.sum(params["w"] ** 2)) < 0.2

    def test_adafactor_states_are_factored(self):
        opt = make_adafactor()
        params = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
        st = opt.init(params)
        assert st["w"]["vr"].shape == (8,)
        assert st["w"]["vc"].shape == (16,)
        assert st["b"]["v"].shape == (16,)

    def test_cosine_schedule_shape(self):
        assert float(cosine_schedule(0, warmup=100)) < float(cosine_schedule(99, warmup=100))
        assert float(cosine_schedule(100)) > float(cosine_schedule(9000))

    def test_int8_compression_bounded_error(self):
        comp = make_compressor("int8")
        g = {"a": jnp.array([1.0, -0.5, 0.001, 0.7])}
        out = comp(g)
        err = jnp.max(jnp.abs(out["a"] - g["a"]))
        assert float(err) <= 1.0 / 127.0 + 1e-6


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3, jnp.bfloat16)}
        opt = {"mu": jax.tree.map(jnp.zeros_like, params)}
        save_checkpoint(tmp_path, 5, params, opt, extra={"x": 1})
        p2, o2, extra, step = restore_checkpoint(tmp_path, None, params, opt)
        assert step == 5 and extra == {"x": 1}
        np.testing.assert_array_equal(np.asarray(p2["w"]), np.asarray(params["w"]))
        assert p2["b"].dtype == jnp.bfloat16

    def test_gc_keeps_latest(self, tmp_path):
        params = {"w": jnp.zeros(2)}
        for s in [1, 2, 3, 4, 5]:
            save_checkpoint(tmp_path, s, params, {}, keep=2)
        assert latest_step(tmp_path) == 5
        steps = sorted(d.name for d in tmp_path.glob("step_*"))
        assert len(steps) == 2


class TestTrainLoop:
    def _loop_cfg(self, tmp_path, **kw):
        return LoopConfig(
            total_steps=6,
            seq_len=16,
            global_batch=2,
            ckpt_every=2,
            log_every=0,
            ckpt_dir=str(tmp_path),
            **kw,
        )

    def test_loss_decreases(self, tmp_path):
        cfg = reduce_config(get_config("phi4-mini-3.8b"))
        loop = LoopConfig(
            total_steps=30, seq_len=32, global_batch=4, ckpt_every=0,
            log_every=0, ckpt_dir=str(tmp_path), lr=3e-3, warmup=5,
        )
        hist = train(cfg, loop)
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.2, (first, last)

    def test_restart_resumes_exactly(self, tmp_path):
        """Crash at step 4, relaunch, and the combined trajectory matches an
        uninterrupted run (bit-level determinism of resume)."""
        cfg = reduce_config(get_config("xlstm-125m"))
        # uninterrupted reference
        ref = train(cfg, self._loop_cfg(tmp_path / "ref", resume=False))
        # crash + restart
        with pytest.raises(RuntimeError, match="injected failure"):
            train(cfg, self._loop_cfg(tmp_path / "ft", fail_at_step=4))
        hist2 = train(cfg, self._loop_cfg(tmp_path / "ft"))
        # resumed run starts after the last checkpoint (step 3 ckpt -> 4)
        assert hist2[0]["step"] == 4
        ref_by_step = {h["step"]: h["loss"] for h in ref}
        for h in hist2:
            np.testing.assert_allclose(h["loss"], ref_by_step[h["step"]], rtol=2e-4)

    def test_grad_compression_trains(self, tmp_path):
        cfg = reduce_config(get_config("phi4-mini-3.8b"))
        loop = LoopConfig(
            total_steps=12, seq_len=16, global_batch=2, ckpt_every=0,
            log_every=0, ckpt_dir=str(tmp_path), grad_compression="int8",
            lr=2e-3, warmup=2,
        )
        hist = train(cfg, loop)
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.05
        assert all(np.isfinite(h["loss"]) for h in hist)
