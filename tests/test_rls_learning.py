"""Streaming online readout learning (ExecPlan.learn="rls" / "lms").

The contracts this file pins:

  - `fit_rls(lam=1)` solves the same regularized normal equations as
    `fit_ridge` (close to float roundoff), and lam < 1 forgets.
  - The RLS update is reduction-order stable across batch widths, which is
    what makes the next contract possible at all.
  - Streaming RLS fused into `CompiledSim.tick_chunk` BIT-MATCHES the
    offline `fit_rls` oracle run over the session's harvested states on the
    scan backend — for sessions served solo, slot-batched next to other
    tenants, admitted/retired mid-chunk, and migrated by autoscale resizes.
  - Online learning on NARMA-10 reaches NMSE within 5% of batch
    `fit_ridge` on the same states.
  - The planes backends and sharded plans learn tolerance-equal to scan.
  - ExecPlan validates the learn knobs; the engine validates target
    submission and refuses learning on the per-tick step() path.
  - learn="lms" (TestLMS) pins the same contracts for the O(S) NLMS
    learner: batch-width bit stability, streaming == `fit_lms` oracle,
    chunk-size independence (no P block), and P-free checkpoints.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExecPlan, compile_plan, make_spec
from repro.core import (
    default_params,
    fit_lms,
    fit_ridge,
    fit_rls,
    nmse,
    predict,
    tasks,
)
from repro.kernels import ops
from repro.kernels import rls as krls
from repro.serve.reservoir import ReservoirEngine, StreamSession
from repro.serve.scheduler import QueueDepthPolicy

ATOL = 5e-5  # tests/test_kernels_sto.py's f32 tolerance


class TestFitRLS:
    def test_lam_one_matches_ridge(self):
        rng = np.random.default_rng(0)
        states = rng.normal(size=(400, 12)).astype(np.float32)
        targets = rng.normal(size=(400, 2)).astype(np.float32)
        ridge = fit_ridge(states, targets, washout=20, reg=1e-2)
        rls = fit_rls(states, targets, washout=20, reg=1e-2, lam=1.0)
        np.testing.assert_allclose(
            np.asarray(rls.w_out), np.asarray(ridge.w_out), atol=2e-3
        )
        assert rls.washout == 20

    def test_forgetting_tracks_a_switch(self):
        """lam < 1 adapts to a mid-stream target flip; lam = 1 averages.

        (Horizon/lam chosen inside f32's comfort zone: aggressive
        forgetting over very long f32 streams loses P's conditioning —
        see the numerical note in kernels/rls.py.)"""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(400, 4)).astype(np.float32)
        w_a = rng.normal(size=(4,)).astype(np.float32)
        w_b = rng.normal(size=(4,)).astype(np.float32)
        y = np.concatenate([x[:200] @ w_a, x[200:] @ w_b]).astype(np.float32)
        forgetful = fit_rls(x, y, reg=1e-2, lam=0.98)
        stubborn = fit_rls(x, y, reg=1e-2, lam=1.0)
        pred_f = predict(forgetful._replace(washout=0), jnp.asarray(x[350:]))
        pred_s = predict(stubborn._replace(washout=0), jnp.asarray(x[350:]))
        err_f = nmse(pred_f[:, 0], jnp.asarray(y[350:]))
        err_s = nmse(pred_s[:, 0], jnp.asarray(y[350:]))
        assert err_f < 0.1 * err_s

    def test_warm_start_is_exact_for_zero_history(self):
        """w0 with no (unmasked) samples comes back unchanged."""
        states = np.ones((3, 4), np.float32)
        targets = np.ones((3, 1), np.float32)
        w0 = np.arange(5, dtype=np.float32)[:, None]
        ro = fit_rls(states, targets, washout=3, reg=1e-2, w0=w0)
        np.testing.assert_array_equal(np.asarray(ro.w_out), w0)

    def test_rejects_bad_shapes_and_lam(self):
        s = np.zeros((5, 3), np.float32)
        with pytest.raises(ValueError, match="targets"):
            fit_rls(s, np.zeros((1, 5), np.float32))
        with pytest.raises(ValueError, match="lam"):
            fit_rls(s, np.zeros(5, np.float32), lam=0.0)
        with pytest.raises(ValueError, match="lam"):
            fit_rls(s, np.zeros(5, np.float32), lam=1.5)

    def test_update_batch_width_bit_stability(self):
        """The lane-0 result of an E-lane update equals the E=1 update bit
        for bit — the property the streaming-vs-oracle bit-match rests on."""
        rng = np.random.default_rng(2)
        s, o, e = 9, 2, 7
        p = rng.normal(size=(1, s, s)).astype(np.float32)
        w = rng.normal(size=(1, s, o)).astype(np.float32)
        x = rng.normal(size=(1, s)).astype(np.float32)
        y = rng.normal(size=(1, o)).astype(np.float32)
        upd = jax.jit(krls.rls_update, static_argnames=("lam",))
        a = upd(*map(jnp.asarray, (p, w, x, y, np.ones(1, bool))), lam=0.99)
        b = upd(
            *map(lambda z: jnp.asarray(np.repeat(z, e, 0)), (p, w, x, y)),
            jnp.ones(e, bool),
            lam=0.99,
        )
        for one, many in zip(a, b):
            np.testing.assert_array_equal(
                np.asarray(one)[0], np.asarray(many)[0]
            )

    @pytest.mark.parametrize("lam", [1.0, 0.99])
    def test_chunked_blocks_match_sequential_solution(self, lam):
        """fit_rls(block=K) — the serving chunk formulation — solves the
        same problem as the sequential block=1 recursion (float-tolerance
        equal; op order differs by construction)."""
        rng = np.random.default_rng(4)
        states = rng.normal(size=(203, 10)).astype(np.float32)
        targets = rng.normal(size=(203, 1)).astype(np.float32)
        seq = fit_rls(states, targets, washout=7, reg=1e-2, lam=lam)
        blk = fit_rls(states, targets, washout=7, reg=1e-2, lam=lam, block=8)
        np.testing.assert_allclose(
            np.asarray(blk.w_out), np.asarray(seq.w_out), atol=2e-3
        )

    def test_chunk_batch_width_bit_stability(self):
        """rls_chunk lane 0 at E lanes == the E=1 run, bit for bit — the
        property the streaming-vs-oracle bit-match rests on."""
        rng = np.random.default_rng(5)
        k, s, o, e = 6, 9, 2, 5
        p = rng.normal(size=(1, s, s)).astype(np.float32)
        w = rng.normal(size=(1, s, o)).astype(np.float32)
        x = rng.normal(size=(k, 1, s)).astype(np.float32)
        y = rng.normal(size=(k, 1, o)).astype(np.float32)
        mask = np.ones((k, 1), bool)
        mask[4] = False
        chunk = jax.jit(krls.rls_chunk, static_argnames=("lam",))
        a = chunk(*map(jnp.asarray, (p, w, x, y, mask)), lam=0.99)
        b = chunk(
            jnp.asarray(np.repeat(p, e, 0)),
            jnp.asarray(np.repeat(w, e, 0)),
            jnp.asarray(np.repeat(x, e, 1)),
            jnp.asarray(np.repeat(y, e, 1)),
            jnp.asarray(np.repeat(mask, e, 1)),
            lam=0.99,
        )
        for one, many in zip(a[:2], b[:2]):
            np.testing.assert_array_equal(
                np.asarray(one)[0], np.asarray(many)[0]
            )
        np.testing.assert_array_equal(
            np.asarray(a[2])[:, 0], np.asarray(b[2])[:, 0]
        )

    def test_masked_update_is_bit_frozen(self):
        rng = np.random.default_rng(3)
        p = rng.normal(size=(2, 4, 4)).astype(np.float32)
        w = rng.normal(size=(2, 4, 1)).astype(np.float32)
        x = rng.normal(size=(2, 4)).astype(np.float32)
        y = rng.normal(size=(2, 1)).astype(np.float32)
        mask = jnp.asarray([True, False])
        p2, w2, pred = krls.rls_update(
            jnp.asarray(p), jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
            mask, 1.0,
        )
        np.testing.assert_array_equal(np.asarray(p2)[1], p[1])
        np.testing.assert_array_equal(np.asarray(w2)[1], w[1])
        assert not np.array_equal(np.asarray(p2)[0], p[0])
        # masked lanes still answer (frozen weights applied to x)
        np.testing.assert_allclose(
            np.asarray(pred)[1], (w[1].T @ x[1]), rtol=1e-6
        )


def _learn_sessions(rng, count, lengths, n_out=1, washout=2):
    sessions = []
    for sid in range(count):
        t = lengths[sid % len(lengths)]
        sessions.append(
            StreamSession(
                sid=sid,
                u_seq=rng.uniform(0, 0.5, (t, 1)).astype(np.float32),
                targets=rng.normal(size=(t, n_out)).astype(np.float32),
                learn_washout=washout,
            )
        )
    return sessions


class TestStreamingBitMatchesOracle:
    def test_engine_learned_readout_matches_fit_rls(self):
        """Every served session's learned readout == fit_rls over its
        harvested states, bit for bit (scan backend), across slot turnover
        and mid-chunk finishes."""
        spec = make_spec(n=10, n_in=1, hold_steps=6, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        sessions = _learn_sessions(rng, 8, (5, 9, 14))
        eng = ReservoirEngine(
            spec, num_slots=3, backend="scan", chunk_ticks=4,
            learn="rls", learn_reg=1e-2,
        )
        results = eng.run([dataclasses.replace(s) for s in sessions])
        assert len(results) == 8
        for sid, r in results.items():
            oracle = fit_rls(
                r.states, sessions[sid].targets, washout=2, reg=1e-2, block=4
            )
            np.testing.assert_array_equal(
                np.asarray(r.learned_readout.w_out), np.asarray(oracle.w_out)
            )

    def test_chunk_ticks_one_matches_block_one_oracle(self):
        """The template route's default chunk_ticks=1 learning engine still
        bit-matches fit_rls(block=1): the oracle routes every block size
        through rls_chunk, exactly like the engine."""
        spec = make_spec(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        rng = np.random.default_rng(7)
        sessions = _learn_sessions(rng, 3, (6, 9))
        eng = ReservoirEngine(
            spec, num_slots=2, backend="scan", chunk_ticks=1,
            learn="rls", learn_reg=1e-2,
        )
        results = eng.run([dataclasses.replace(s) for s in sessions])
        for s in sessions:
            oracle = fit_rls(
                results[s.sid].states, s.targets, washout=2, reg=1e-2, block=1
            )
            np.testing.assert_array_equal(
                np.asarray(results[s.sid].learned_readout.w_out),
                np.asarray(oracle.w_out),
            )

    def test_survives_autoscale_resize(self):
        """Learning state migrates with the session through grow AND shrink
        resizes; the learned weights still bit-match the oracle."""
        spec = make_spec(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        rng = np.random.default_rng(1)
        sessions = _learn_sessions(rng, 16, (6, 10, 18))
        eng = ReservoirEngine(
            spec, num_slots=2, backend="scan", chunk_ticks=4,
            learn="rls", learn_reg=1e-2,
            autoscale=QueueDepthPolicy(), min_slots=2, max_slots=16,
        )
        results = dict(eng.run([dataclasses.replace(s) for s in sessions]))
        assert eng.scheduler.stats.grows >= 1
        # a low-demand second wave forces the hysteretic shrink while two
        # learning sessions are mid-stream
        tail = [
            StreamSession(
                sid=100 + i,
                u_seq=rng.uniform(0, 0.5, (26, 1)).astype(np.float32),
                targets=rng.normal(size=(26, 1)).astype(np.float32),
                learn_washout=2,
            )
            for i in range(2)
        ]
        results.update(eng.run([dataclasses.replace(s) for s in tail]))
        assert eng.scheduler.stats.shrinks >= 1
        assert len(results) == 18
        for s in sessions + tail:
            r = results[s.sid]
            oracle = fit_rls(r.states, s.targets, washout=2, reg=1e-2, block=4)
            np.testing.assert_array_equal(
                np.asarray(r.learned_readout.w_out), np.asarray(oracle.w_out)
            )

    def test_mixed_learning_and_inference_tenants(self):
        """Inference-only sessions ride a learning engine untouched; their
        chunked results still bit-match a non-learning engine's."""
        spec = make_spec(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        rng = np.random.default_rng(2)
        u_inf = rng.uniform(0, 0.5, (9, 1)).astype(np.float32)
        learners = _learn_sessions(rng, 3, (7, 12))
        mixed = [StreamSession(sid=100, u_seq=u_inf.copy())] + [
            dataclasses.replace(s) for s in learners
        ]
        eng = ReservoirEngine(
            spec, num_slots=2, backend="scan", chunk_ticks=3,
            learn="rls", learn_reg=1e-2,
        )
        res = eng.run(mixed)
        assert res[100].learned_readout is None
        assert res[100].predictions is None
        plain = ReservoirEngine(spec, num_slots=2, backend="scan", chunk_ticks=3)
        ref = plain.run([StreamSession(sid=100, u_seq=u_inf.copy())])
        np.testing.assert_array_equal(
            np.asarray(res[100].states[: ref[100].states.shape[0]]),
            np.asarray(ref[100].states),
        )
        for s in learners:
            oracle = fit_rls(
                res[s.sid].states, s.targets, washout=2, reg=1e-2, block=3
            )
            np.testing.assert_array_equal(
                np.asarray(res[s.sid].learned_readout.w_out),
                np.asarray(oracle.w_out),
            )

    def test_warm_start_from_readout(self):
        """A learning session's provided readout seeds the learned lane:
        oracle parity with fit_rls(w0=...)."""
        spec = make_spec(n=6, n_in=1, hold_steps=4, dtype=jnp.float32)
        rng = np.random.default_rng(3)
        u = rng.uniform(0, 0.5, (8, 1)).astype(np.float32)
        y = rng.normal(size=(8, 1)).astype(np.float32)
        from repro.core.reservoir import Readout

        w0 = rng.normal(size=(7, 1)).astype(np.float32)
        sess = StreamSession(
            sid=0, u_seq=u, targets=y,
            readout=Readout(w_out=jnp.asarray(w0), washout=0),
        )
        eng = ReservoirEngine(
            spec, num_slots=1, backend="scan", chunk_ticks=4,
            learn="rls", learn_reg=1e-2,
        )
        r = eng.run([sess])[0]
        oracle = fit_rls(r.states, y, reg=1e-2, w0=w0, block=4)
        np.testing.assert_array_equal(
            np.asarray(r.learned_readout.w_out), np.asarray(oracle.w_out)
        )
        assert r.outputs is not None  # static readout still applied


class TestNarmaOnlineLearning:
    def test_nmse_within_5pct_of_batch_ridge(self):
        """Online RLS learned while streaming NARMA-10 predicts within 5%
        of the batch ridge readout fit on the same states."""
        params = default_params(jnp.float32)._replace(a_in=jnp.float32(300.0))
        spec = make_spec(
            n=24, n_in=1, hold_steps=20, dtype=jnp.float32, params=params
        )
        train, test, washout = 260, 80, 40
        u, y = tasks.narma_series(train + test, order=10, seed=0)
        u = u.astype(np.float32)[:, None]
        y = y.astype(np.float32)[:, None]
        eng = ReservoirEngine(
            spec, num_slots=1, backend="scan", chunk_ticks=8,
            learn="rls", learn_reg=1e-2,
        )
        r = eng.run(
            [
                StreamSession(
                    sid=0, u_seq=u[:train], targets=y[:train],
                    learn_washout=washout,
                )
            ]
        )[0]
        # held-out evaluation: resume the reservoir, apply both readouts
        sim = compile_plan(spec, impl="scan")
        _, test_states = sim.drive(jnp.asarray(u[train:]), m0=r.final_m)
        ridge = fit_ridge(r.states, y[:train], washout=washout, reg=1e-2)
        pred_rls = predict(r.learned_readout, test_states)
        pred_ridge = predict(ridge._replace(washout=0), test_states)
        err_rls = nmse(pred_rls, jnp.asarray(y[train:]))
        err_ridge = nmse(pred_ridge, jnp.asarray(y[train:]))
        assert err_ridge < 1.0  # readout beats the mean predictor
        assert err_rls <= err_ridge * 1.05
        # the engine's own online NMSE is finite and recorded
        assert r.learn_nmse is not None and np.isfinite(r.learn_nmse)


class TestOtherBackends:
    @pytest.mark.parametrize(
        "impl,interpret", [("ref", False), ("fused", True), ("tiled", True)]
    )
    def test_planes_backends_learn_close_to_scan(self, impl, interpret):
        spec = make_spec(n=8, n_in=1, hold_steps=3, dtype=jnp.float32)
        rng = np.random.default_rng(0)
        k, e = 4, 3
        u = rng.uniform(0, 0.5, (k, e, 1)).astype(np.float32)
        y = rng.normal(size=(k, e, 1)).astype(np.float32)
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (e, 8, 3)))
        outs = {}
        for which, plan in {
            "scan": ExecPlan(impl="scan", ensemble=e, chunk_ticks=k,
                             learn="rls", learn_reg=1e-2),
            impl: ExecPlan(impl=impl, ensemble=e, chunk_ticks=k,
                           learn="rls", learn_reg=1e-2, interpret=interpret),
        }.items():
            sim = compile_plan(spec, plan)
            p0, w0 = sim.init_learn_state()
            outs[which] = sim.tick_chunk(
                m0, u, targets=y, learn_state=(p0, w0)
            )
        for a, b in zip(outs["scan"][2], outs[impl][2]):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-2
            )
        np.testing.assert_allclose(
            np.asarray(outs["scan"][3]), np.asarray(outs[impl][3]), atol=1e-3
        )

    def test_sharded_learn_bitexact_on_one_device_mesh(self):
        from jax.sharding import Mesh

        spec = make_spec(n=8, n_in=1, hold_steps=4, dtype=jnp.float32)
        e, k = 4, 3
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
        rng = np.random.default_rng(0)
        u = rng.uniform(0, 0.5, (k, e, 1)).astype(np.float32)
        y = rng.normal(size=(k, e, 1)).astype(np.float32)
        mask = np.ones((k, e), bool)
        mask[2, 1] = False
        unsh = compile_plan(
            spec,
            ExecPlan(impl="scan", ensemble=e, chunk_ticks=k,
                     learn="rls", learn_reg=1e-2),
        )
        sh = compile_plan(
            spec,
            ExecPlan(ensemble=e, chunk_ticks=k, learn="rls",
                     learn_reg=1e-2, mesh=mesh),
        )
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (e, 8, 3)))
        p0, w0 = unsh.init_learn_state()
        a = unsh.tick_chunk(m0, u, jnp.asarray(mask), targets=y,
                            learn_state=(p0, w0))
        b = sh.tick_chunk(m0, u, jnp.asarray(mask), targets=y,
                          learn_state=(p0, w0))
        for x, z in [(a[0], b[0]), (a[1], b[1]), (a[2][0], b[2][0]),
                     (a[2][1], b[2][1]), (a[3], b[3])]:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


class TestValidation:
    def test_plan_learn_knobs(self):
        with pytest.raises(ValueError, match="learn must be"):
            ExecPlan(learn="sgd")
        with pytest.raises(ValueError, match="learn_lam"):
            ExecPlan(learn="rls", learn_lam=0.0)
        with pytest.raises(ValueError, match="learn_lam"):
            ExecPlan(learn="rls", learn_lam=1.5)
        with pytest.raises(ValueError, match="learn_reg"):
            ExecPlan(learn="rls", learn_reg=0.0)
        with pytest.raises(ValueError, match="learn_reg"):
            ExecPlan(learn="rls", learn_reg=-1e-3)
        plan = ExecPlan(learn="rls", learn_lam=0.99, learn_reg=1e-2)
        assert dataclasses.replace(plan, ensemble=8).learn == "rls"

    def test_tick_chunk_rejects_mismatched_learn_args(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        m0 = ops.to_planes(jnp.broadcast_to(spec.m0, (2, 6, 3)))
        u = jnp.zeros((3, 2, 1), jnp.float32)
        infer = compile_plan(spec, ExecPlan(impl="scan", ensemble=2, chunk_ticks=3))
        with pytest.raises(ValueError, match="inference-only"):
            infer.tick_chunk(m0, u, targets=jnp.zeros((3, 2, 1)))
        learner = compile_plan(
            spec, ExecPlan(impl="scan", ensemble=2, chunk_ticks=3, learn="rls")
        )
        with pytest.raises(ValueError, match="learn_state"):
            learner.tick_chunk(m0, u)
        p0, w0 = learner.init_learn_state()
        with pytest.raises(ValueError, match="targets"):
            learner.tick_chunk(
                m0, u, targets=jnp.zeros((3, 2, 4)), learn_state=(p0, w0)
            )
        with pytest.raises(ValueError, match="init_learn_state"):
            infer.init_learn_state()

    def test_engine_validates_target_submission(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        u = np.zeros((4, 1), np.float32)
        plain = ReservoirEngine(spec, num_slots=1, backend="scan")
        with pytest.raises(ValueError, match="learning"):
            plain.submit(
                StreamSession(sid=0, u_seq=u, targets=np.zeros((4, 1)))
            )
        eng = ReservoirEngine(
            spec, num_slots=1, backend="scan", chunk_ticks=2, learn="rls"
        )
        with pytest.raises(ValueError, match="targets"):
            eng.submit(
                StreamSession(sid=1, u_seq=u, targets=np.zeros((3, 1)))
            )
        with pytest.raises(ValueError, match="learn_washout"):
            eng.submit(
                StreamSession(
                    sid=2, u_seq=u, targets=np.zeros((4, 1)), learn_washout=-1
                )
            )

    def test_step_refuses_learning_engine(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        eng = ReservoirEngine(
            spec, num_slots=1, backend="scan", chunk_ticks=2, learn="rls"
        )
        with pytest.raises(RuntimeError, match="chunked"):
            eng.step()

    def test_engine_rejects_learn_kwargs_with_compiled_sim(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        sim = compile_plan(spec, ExecPlan(impl="scan", ensemble=2))
        with pytest.raises(ValueError, match="ExecPlan"):
            ReservoirEngine(sim, learn="rls")


class TestLMS:
    """ExecPlan.learn="lms": the O(S)-per-tick normalized-LMS twin of the
    RLS contracts above — same bit-stability and streaming-vs-oracle pins,
    no inverse-Gram block anywhere."""

    def test_update_batch_width_bit_stability(self):
        rng = np.random.default_rng(11)
        s, o, e = 9, 2, 7
        w = rng.normal(size=(1, s, o)).astype(np.float32)
        x = rng.normal(size=(1, s)).astype(np.float32)
        y = rng.normal(size=(1, o)).astype(np.float32)
        upd = jax.jit(krls.lms_update, static_argnames=("mu",))
        a = upd(*map(jnp.asarray, (w, x, y, np.ones(1, bool))), mu=0.5)
        b = upd(
            *map(lambda z: jnp.asarray(np.repeat(z, e, 0)), (w, x, y)),
            jnp.ones(e, bool),
            mu=0.5,
        )
        for one, many in zip(a, b):
            np.testing.assert_array_equal(np.asarray(one)[0], np.asarray(many)[0])

    def test_masked_update_is_bit_frozen(self):
        rng = np.random.default_rng(12)
        w = rng.normal(size=(2, 4, 1)).astype(np.float32)
        x = rng.normal(size=(2, 4)).astype(np.float32)
        y = rng.normal(size=(2, 1)).astype(np.float32)
        w2, pred = krls.lms_update(
            jnp.asarray(w), jnp.asarray(x), jnp.asarray(y),
            jnp.asarray([True, False]), 0.5,
        )
        np.testing.assert_array_equal(np.asarray(w2)[1], w[1])
        assert not np.array_equal(np.asarray(w2)[0], w[0])
        # masked lanes still answer (frozen weights applied to x)
        np.testing.assert_allclose(np.asarray(pred)[1], w[1].T @ x[1], rtol=1e-6)

    def test_fit_lms_learns_a_linear_map(self):
        rng = np.random.default_rng(13)
        x = rng.normal(size=(600, 6)).astype(np.float32)
        w_true = rng.normal(size=(6, 1)).astype(np.float32)
        y = x @ w_true
        readout = fit_lms(x, y, washout=10, mu=0.5)
        pred = predict(readout._replace(washout=0), x[300:])
        assert float(nmse(pred, y[300:])) < 0.05

    def test_fit_lms_is_chunk_size_independent(self):
        """lms_chunk is a per-tick-local scan — no block parameter exists,
        and the engine's chunk_ticks cannot change the recursion. Pinned by
        running the same stream through chunk_ticks 1 and 4 engines."""
        spec = make_spec(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        rng = np.random.default_rng(14)
        sessions = _learn_sessions(rng, 3, (6, 9))
        outs = []
        for ct in (1, 4):
            eng = ReservoirEngine(
                spec, num_slots=2, backend="scan", chunk_ticks=ct,
                learn="lms", learn_mu=0.5,
            )
            rs = eng.run([dataclasses.replace(s) for s in sessions])
            outs.append({
                sid: np.asarray(r.learned_readout.w_out) for sid, r in rs.items()
            })
        for sid in outs[0]:
            np.testing.assert_array_equal(outs[0][sid], outs[1][sid])

    def test_engine_learned_readout_matches_fit_lms(self):
        """Streaming LMS fused into tick_chunk bit-matches the offline
        fit_lms oracle over the harvested states (scan backend), across
        slot turnover and mid-chunk finishes — the learn="rls" contract,
        same words, cheaper learner."""
        spec = make_spec(n=10, n_in=1, hold_steps=6, dtype=jnp.float32)
        rng = np.random.default_rng(15)
        sessions = _learn_sessions(rng, 8, (5, 9, 14))
        eng = ReservoirEngine(
            spec, num_slots=3, backend="scan", chunk_ticks=4,
            learn="lms", learn_mu=0.7,
        )
        results = eng.run([dataclasses.replace(s) for s in sessions])
        assert len(results) == 8
        for sid, r in results.items():
            oracle = fit_lms(r.states, sessions[sid].targets, washout=2, mu=0.7)
            np.testing.assert_array_equal(
                np.asarray(r.learned_readout.w_out), np.asarray(oracle.w_out)
            )

    def test_checkpoint_carries_no_P_and_resumes_bitexact(self):
        """An LMS checkpoint has weights but no inverse-Gram; restoring it
        on a fresh engine continues the stream bit-exactly."""
        spec = make_spec(n=8, n_in=1, hold_steps=5, dtype=jnp.float32)
        rng = np.random.default_rng(16)
        u = rng.uniform(0, 0.5, (12, 1)).astype(np.float32)
        y = rng.normal(size=(12, 1)).astype(np.float32)
        mk = lambda sid: StreamSession(
            sid=sid, u_seq=u.copy(), targets=y.copy(), learn_washout=2
        )
        ref_eng = ReservoirEngine(
            spec, num_slots=2, backend="scan", chunk_ticks=4,
            learn="lms", learn_mu=0.5,
        )
        ref = ref_eng.run([mk(0)])[0]

        eng = ReservoirEngine(
            spec, num_slots=2, backend="scan", chunk_ticks=4,
            learn="lms", learn_mu=0.5,
        )
        eng.submit(mk(1))
        eng.step_chunk()  # 4 of 12 ticks
        ck = eng.checkpoint_session(1)
        assert ck.P is None and ck.Wl is not None
        eng2 = ReservoirEngine(
            spec, num_slots=2, backend="scan", chunk_ticks=4,
            learn="lms", learn_mu=0.5,
        )
        eng2.restore_session(ck)
        while eng2.step_chunk():
            pass
        resumed = eng2.pop_results()[1]
        np.testing.assert_array_equal(
            np.asarray(resumed.learned_readout.w_out),
            np.asarray(ref.learned_readout.w_out),
        )

    def test_validation(self):
        spec = make_spec(n=6, n_in=1, hold_steps=3, dtype=jnp.float32)
        with pytest.raises(ValueError, match="learn_mu"):
            ExecPlan(learn="lms", learn_mu=0.0)
        with pytest.raises(ValueError, match="learn_mu"):
            ExecPlan(learn="lms", learn_mu=2.0)
        with pytest.raises(ValueError, match="learn"):
            ExecPlan(learn="nlms")
        with pytest.raises(ValueError, match="mu"):
            fit_lms(np.zeros((4, 3)), np.zeros((4, 1)), mu=2.5)
        # an LMS engine refuses RLS inverse-Gram resume state
        eng = ReservoirEngine(
            spec, num_slots=1, backend="scan", chunk_ticks=2,
            learn="lms", learn_mu=0.5,
        )
        u = np.zeros((4, 1), np.float32)
        with pytest.raises(ValueError, match="learn_P0"):
            eng.submit(
                StreamSession(
                    sid=0, u_seq=u, targets=np.zeros((4, 1), np.float32),
                    learn_P0=np.eye(7, dtype=np.float32),
                )
            )
        with pytest.raises(ValueError, match="inverse-Gram|rls"):
            eng.store.learn_P_columns([0])
