"""Property-based invariants for serve/state_store.SlotStore.

The slot store is the serving engine's ground truth: whatever interleaving
of admissions, retirements, and autoscale resizes the scheduler produces,
every resident session's lane must keep exactly ITS state — magnetization
column, params column, readout row, and (learning stores) P/Wl learning
columns — and the active mask must agree with occupancy. A violated
invariant here is a cross-tenant data leak in production.

The harness drives a SlotStore and a pure-python mirror model through the
same operation script and compares bit-for-bit after every step. With
hypothesis installed (`pip install -r requirements-dev.txt`) the scripts
are drawn from the strategy below; without it those tests skip and the
deterministic replays (fixed scripts through the same harness) still run.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.api import make_spec
from repro.serve.state_store import SlotStore

E0 = 4  # initial store width
_SPEC = make_spec(3, hold_steps=2, seed=0)
_TEMPLATE_M = np.asarray(_SPEC.m0)


def _payload(sid: int, learn):
    """Session sid's unique, recognizable lane contents."""
    rng = np.random.default_rng(100 + sid)
    m0 = rng.standard_normal((_SPEC.n, 3)).astype(np.float32)
    a_cp = np.float32(0.1 + 0.01 * sid)
    params = _SPEC.params._replace(a_cp=jnp.asarray(a_cp, _SPEC.dtype))
    w = np.full((_SPEC.n + 1, 1), float(sid), np.float32)
    if learn is None:
        return (m0, params, w, None, None), a_cp
    lw = np.full((_SPEC.n + 1, 1), sid + 0.5, np.float32)
    lp = (
        np.eye(_SPEC.n + 1, dtype=np.float32) * (sid + 1)
        if learn == "rls"
        else None
    )
    return (m0, params, w, lw, lp), a_cp


def _check(store: SlotStore, model: dict, payloads: dict, learn) -> None:
    """Every invariant, bit-for-bit, after one operation."""
    expected_mask = [s in model for s in range(store.num_slots)]
    assert np.asarray(store.active_mask).tolist() == expected_mask
    assert store.num_active == len(model)
    assert store.free_slots() == [
        s for s in range(store.num_slots) if s not in model
    ]
    w_out = np.asarray(store.w_out)
    params_e = store.params_ensemble
    wl = None if store.Wl is None else np.asarray(store.Wl)
    p = None if store.P is None else np.asarray(store.P)
    eye_reg = np.eye(store.n_state, dtype=np.float32) / store.learn_reg
    for slot in range(store.num_slots):
        if slot in model:
            (m0, _, w, lw, lp), a_cp = payloads[model[slot]]
            np.testing.assert_array_equal(
                np.asarray(store.state_column(slot)), m0
            )
            assert np.float32(params_e.a_cp[slot, 0]) == a_cp
            np.testing.assert_array_equal(w_out[slot], w)
            if learn is not None:
                np.testing.assert_array_equal(wl[slot], lw)
            if learn == "rls":
                np.testing.assert_array_equal(p[slot], lp)
        else:
            # retired / never-admitted lanes carry the template, always
            np.testing.assert_array_equal(
                np.asarray(store.state_column(slot)), _TEMPLATE_M
            )
            assert np.float32(params_e.a_cp[slot, 0]) == np.float32(
                np.asarray(_SPEC.params.a_cp)
            )
            np.testing.assert_array_equal(
                w_out[slot], np.zeros((store.n + 1, 1), np.float32)
            )
            if learn is not None:
                np.testing.assert_array_equal(
                    wl[slot], np.zeros((store.n_state, 1), np.float32)
                )
            if learn == "rls":
                np.testing.assert_array_equal(p[slot], eye_reg)


def run_script(script, learn) -> None:
    """Drive store + mirror model through (op, arg) steps, checking after
    each. Ops: 'admit' (1-2 sessions into free slots), 'retire' (1-2
    residents), 'resize' (toggle width, compacting occupied lanes low —
    exactly the engine's autoscale slot_map)."""
    store = SlotStore(_SPEC, E0, n_out=1, learn=learn)
    model: dict = {}  # slot -> sid
    payloads: dict = {}  # sid -> (payload, a_cp)
    next_sid = 0
    for op, arg in script:
        if op == "admit":
            free = store.free_slots()
            take = free[: 1 + arg % 2]
            items = []
            for slot in take:
                payloads[next_sid] = _payload(next_sid, learn)
                (m0, params, w, lw, lp), _ = payloads[next_sid]
                items.append((slot, m0, params, w, lw, lp))
                model[slot] = next_sid
                next_sid += 1
            store.admit_many(items)
        elif op == "retire":
            occupied = sorted(model)
            if not occupied:
                continue
            start = arg % len(occupied)
            victims = occupied[start : start + 1 + arg % 2]
            store.retire_many(victims)
            for slot in victims:
                del model[slot]
        elif op == "resize":
            new_e = E0 if store.num_slots != E0 else 2 * E0
            if len(model) > new_e:
                continue
            slot_map = {old: new for new, old in enumerate(sorted(model))}
            store = store.resized(new_e, slot_map)
            model = {slot_map[old]: sid for old, sid in model.items()}
        _check(store, model, payloads, learn)


# -- deterministic replays (run with or without hypothesis) -----------------

_FIXED_SCRIPTS = [
    [("admit", 1), ("admit", 0), ("retire", 0), ("admit", 1), ("resize", 0)],
    [
        ("admit", 1), ("resize", 0), ("admit", 1), ("retire", 1),
        ("admit", 0), ("resize", 0), ("retire", 0), ("admit", 1),
        ("resize", 0), ("retire", 2), ("admit", 1),
    ],
    [("retire", 0), ("resize", 0), ("resize", 1), ("admit", 1), ("admit", 1)],
    [
        ("admit", 1), ("admit", 1), ("resize", 3), ("admit", 1),
        ("retire", 3), ("retire", 1), ("resize", 0), ("admit", 0),
        ("retire", 0), ("retire", 1), ("admit", 1), ("resize", 1),
    ],
]


@pytest.mark.parametrize("learn", [None, "rls", "lms"])
@pytest.mark.parametrize("script_i", range(len(_FIXED_SCRIPTS)))
def test_fixed_interleavings_preserve_lane_session_mapping(script_i, learn):
    run_script(_FIXED_SCRIPTS[script_i], learn)


# -- hypothesis-drawn scripts (skip when hypothesis is absent) --------------

if HAS_HYPOTHESIS:
    script_strategy = st.lists(
        st.tuples(
            st.sampled_from(["admit", "retire", "resize"]),
            st.integers(min_value=0, max_value=7),
        ),
        min_size=1,
        max_size=12,
    )
else:  # the stub's @given skips these tests individually
    script_strategy = None


@settings(max_examples=25, deadline=None)
@given(script=script_strategy)
def test_arbitrary_interleavings_inference_store(script):
    run_script(script, None)


@settings(max_examples=25, deadline=None)
@given(script=script_strategy)
def test_arbitrary_interleavings_rls_store(script):
    run_script(script, "rls")


@settings(max_examples=15, deadline=None)
@given(script=script_strategy)
def test_arbitrary_interleavings_lms_store(script):
    run_script(script, "lms")
