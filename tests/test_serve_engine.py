"""Continuous-batching engine: ragged requests scheduled through a fixed
slot pool must generate BIT-IDENTICAL tokens to per-request serving
(validates cache splicing, per-slot positions, stale-cache masking, and
recurrent-state refill for hybrid archs)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduce_config
from repro.models import build_model, transformer
from repro.serve.engine import Engine, Request


def _reference(cfg, m, params, req, capacity):
    last, caches = m.prefill(params, {"tokens": req.prompt[None]})
    caches = transformer.pad_caches(cfg, caches, capacity)
    tok = int(jnp.argmax(last[0, -1, : cfg.vocab_size]))
    out = [tok]
    pos0 = req.prompt.shape[0]
    for j in range(req.max_new - 1):
        lg, caches = m.decode_step(
            params, jnp.asarray([[tok]], jnp.int32), caches,
            jnp.asarray([pos0 + j], jnp.int32),
        )
        tok = int(jnp.argmax(lg[0, -1, : cfg.vocab_size]))
        out.append(tok)
    return out


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "jamba-1.5-large-398b"])
def test_engine_matches_per_request(arch):
    cfg = reduce_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    reqs = []
    for i, (length, gen) in enumerate([(8, 4), (12, 3), (6, 5), (10, 2)]):
        key, k = jax.random.split(key)
        reqs.append(
            Request(
                i,
                jax.random.randint(k, (length,), 0, cfg.vocab_size).astype(jnp.int32),
                gen,
            )
        )
    capacity = 20
    eng = Engine(cfg, params, num_slots=2, capacity=capacity)
    results = eng.run(list(reqs))
    for r in reqs:
        assert results[r.rid] == _reference(cfg, m, params, r, capacity), r.rid


def test_more_requests_than_slots_all_served():
    cfg = reduce_config(get_config("phi4-mini-3.8b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    reqs = [
        Request(
            i,
            jax.random.randint(jax.random.PRNGKey(i), (5 + i,), 0, cfg.vocab_size)
            .astype(jnp.int32),
            3,
        )
        for i in range(7)
    ]
    eng = Engine(cfg, params, num_slots=3, capacity=16)
    results = eng.run(list(reqs))
    assert sorted(results) == list(range(7))
    assert all(len(v) == 3 for v in results.values())
