"""Docs health check, run by CI (and tests/test_docs.py).

Two checks:

  1. Internal links in the repo's markdown docs (README.md, docs/*.md,
     ROADMAP.md) resolve: every relative `[text](path)` target must exist
     on disk (anchors are stripped; external http(s)/mailto links are
     skipped). Docs that point at moved/renamed files rot silently —
     this turns the rot into a red CI leg.
  2. Docstring examples execute: `doctest` over the modules listed in
     DOCTEST_MODULES (kept explicit so a slow import can't sneak into the
     docs leg unnoticed).

Exit code 0 = healthy. Run from the repo root:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import doctest
import importlib
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

MARKDOWN = [
    "README.md",
    "ROADMAP.md",
    *sorted(str(p.relative_to(REPO)) for p in (REPO / "docs").glob("*.md")),
]

DOCTEST_MODULES = [
    "repro.core.tasks",
    "repro.tune.space",
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list:
    """Every relative markdown link target must exist. Returns failures."""
    failures = []
    for md in MARKDOWN:
        path = REPO / md
        if not path.exists():
            failures.append(f"{md}: file listed for checking does not exist")
            continue
        text = path.read_text()
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                failures.append(f"{md}: broken link -> {target}")
    return failures


def check_doctests() -> list:
    """Run doctest over the allow-listed modules. Returns failures."""
    failures = []
    for name in DOCTEST_MODULES:
        try:
            mod = importlib.import_module(name)
        except Exception as e:  # pragma: no cover - import rot is a failure
            failures.append(f"{name}: import failed ({e})")
            continue
        result = doctest.testmod(mod, verbose=False)
        if result.failed:
            failures.append(
                f"{name}: {result.failed}/{result.attempted} doctest(s) failed"
            )
    return failures


def main() -> int:
    failures = check_links() + check_doctests()
    for f in failures:
        print(f"DOCS FAIL: {f}")
    if not failures:
        n_md = len(MARKDOWN)
        print(f"docs check OK: {n_md} markdown file(s), "
              f"{len(DOCTEST_MODULES)} doctest module(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
