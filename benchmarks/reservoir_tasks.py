"""Reservoir-quality benchmark (supports the paper's application context):
NARMA-2 NMSE and memory capacity for a small STO reservoir — the numbers a
parameter sweep optimizes, produced end-to-end by this framework."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.api import compile_plan, make_spec
from repro.core import fit_ridge, nmse, predict, tasks


def run(print_fn=print):
    rows = []
    u, y = tasks.narma_series(400, order=2, seed=0)
    spec = make_spec(n=32, n_in=1, hold_steps=30, dtype=jnp.float64)
    sim = compile_plan(spec, impl="scan")

    t = time_fn(lambda: sim.drive(jnp.asarray(u[:, None]))[1], reps=2)
    _, states = sim.drive(jnp.asarray(u[:, None]))
    rows.append(csv_row("reservoir_drive_400samples", t * 1e6,
                        f"us_per_sample_{t/400*1e6:.1f}"))
    print_fn(rows[-1])

    washout = 60
    ro = fit_ridge(states, jnp.asarray(y[:, None]), washout=washout, reg=1e-8)
    err = nmse(predict(ro, states), jnp.asarray(y[washout:, None]))
    rows.append(csv_row("reservoir_narma2_nmse", err * 1e6, "nmse_x1e6_lower_better"))
    print_fn(rows[-1])

    rng = np.random.default_rng(1)
    u2 = rng.uniform(-1, 1, 400)
    _, st2 = sim.drive(jnp.asarray(u2[:, None]))
    tg = tasks.delay_memory_targets(u2, 8)
    ro2 = fit_ridge(st2, jnp.asarray(tg), washout=washout, reg=1e-8)
    mc = tasks.memory_capacity(np.asarray(predict(ro2, st2)), tg[washout:])
    rows.append(csv_row("reservoir_memory_capacity_d8", mc, "sum_corr2_8_delays"))
    print_fn(rows[-1])
    return rows


if __name__ == "__main__":
    run()
