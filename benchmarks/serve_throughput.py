"""Serving throughput: pipelined chunked engine vs synchronous vs solo.

For each (N, E) cell the same workload — WAVES generations of E concurrent
length-TICKS streams, so admit/retire churn is part of the bill — runs
through three serving modes:

  sequential   one-at-a-time baseline: a single-slot engine's per-tick cost
               measured once and charged per session-tick
  sync         slot-batched per-tick serving (`engine.step()` loop): one
               `CompiledSim.tick` dispatch + per-tick harvest
  pipelined    chunked double-buffered serving (`engine.run()` with
               `ExecPlan(chunk_ticks=K)`): one dispatch and ONE bulk
               device->host transfer per K ticks, host assembly overlapped
               with device execution

plus, on every row, the PR-5 per-precision / chunk-impl twins: the same
steady workload re-served through the OTHER member of the
{default, chunk-resident} impl pair (reported as
`sessions_per_sec_chunk_impl` + the within-run `chunk_impl_speedup`
ratio; symmetric, so a previously seeded "chunk" winner keeps getting
challenged by "ref" and vice versa) and through `"mixed"` reduced
precision on the chunk impl (`sessions_per_sec_mixed` +
`precision_speedup`). Their steady reps interleave with the default
engine's so the container's ±40% noise bills every column equally —
`kernels.dispatch_table.seed_from_bench` registers a twin's entry for a
shape only when its within-run ratio beat the default;

plus, on the smaller grid rows, autoscale-vs-fixed: the same burst served
by a fixed E-slot engine and by an autoscaling engine that starts at E/4
and grows through the bucketed plan cache, and — at N <= LEARN_MAX_N —
learn-on vs learn-off: the steady workload re-served with every session
learning its readout online (`ExecPlan(learn="rls")`, per-tick fused RLS
updates + target upload + prediction harvest), reported as
`sessions_per_sec_learn` and the within-run `learn_overhead` ratio.
(Learning at N=1024 would allocate E (N+1)^2 P-matrices — ~1 GB at E=256 —
so the column stops at N=128, which is also where the acceptance bar for
the overhead lives.)

Reported per cell:

    ticks_per_sec     aggregate session-ticks per second, pipelined, from a
                      STEADY run (one wave of E long streams — boundary
                      churn amortizes to ~nothing, matching the warm-tick
                      methodology behind the earlier trajectory numbers)
    sessions_per_sec  ticks_per_sec / REF_STREAM_TICKS — completions/sec of
                      a reference 7-tick stream; 7 is the stream length
                      behind the PR-2 trajectory, so this column is
                      comparable across BENCH_serve.json history
    sessions_per_sec_sync  the same PR-2 formula from the per-tick median
    ticks_per_sec_burst / pipelined_speedup  the BURST workload (WAVES
                      generations, churn billed): pipelined vs step() wall
    speedup_vs_sequential  steady pipelined aggregate over sequential

Engines are built through the unified execution API: one SimSpec per N,
compiled against ExecPlans of different ensemble widths — so the backend
each cell reports is exactly what `repro.api.compile_plan` resolved from
the measured-latency dispatch table / platform gate for that (N, E).

plus a TUNE section (`bench_tune`): the same seeded hyperparameter search
over (drive current, spectral radius) on NARMA-10 run lane-vectorized
(candidates = ensemble lanes of one CompiledSim, fitness from the fused
online learner) and sequentially (ensemble=1) — `tune_speedup` is the
within-run wall-clock ratio and `best_match_sequential` pins that lane
width cannot change the winner.

plus a COMPILE section (`bench_compile`): cold vs warm engine spin-up
through the process-wide PlanCache, pure-AOT `lower().compile()` seconds,
and a two-subprocess probe of the JAX persistent compilation cache
(cross-restart cold-start) — `BENCH_serve.json["compile"]`, refreshable
alone via `--compile-only`.

Emits the shared `name,us_per_call,derived` CSV rows and writes
BENCH_serve.json (benchmarks/run.py wires it into the suite) so future PRs
can track the serving-perf trajectory. `kernels.dispatch_table
.seed_from_bench` turns that JSON back into persisted dispatch entries
(`benchmarks/run.py --save-dispatch-table` commits them).

    PYTHONPATH=src python -m benchmarks.serve_throughput [--quick]
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.api import ExecPlan, compile_plan, make_spec
from repro.serve.reservoir import ReservoirEngine, StreamSession
from repro.serve.scheduler import QueueDepthPolicy

NS = (16, 128, 1024)
ES = (8, 64, 256)
HOLD_STEPS = 5
CHUNK_TICKS = 8
TICKS = 32  # burst stream length: 4 chunks, boundary churn amortizes realistically
STEADY_TICKS = 56  # steady-median stream length: 7 chunks (warm 2 + median 3 + drain)
STEADY_REPS = 5  # best-of, like the per-tick median: noise spikes don't bill
WAVES = 2  # stream generations per burst measurement -> full-batch turnover
REF_STREAM_TICKS = 7  # PR-2 trajectory's stream length; sessions/sec anchor
WARM_TICKS = 2
MEASURED_TICKS = 3
AUTOSCALE_MAX_N = 128  # autoscale columns only where the grid row is cheap
LEARN_MAX_N = 128  # learn-on column: P is (E, N+1, N+1) — skip the 1 GB row


def _mk_sessions(num, t, n_in, rng, base_sid=0, learn=False):
    return [
        StreamSession(
            sid=base_sid + i,
            u_seq=rng.uniform(0.0, 0.5, size=(t, n_in)).astype(np.float32),
            collect_states=False,
            targets=(
                rng.uniform(0.0, 0.5, size=(t, 1)).astype(np.float32)
                if learn
                else None
            ),
        )
        for i in range(num)
    ]


def _tick_time(engine, sessions) -> float:
    """Median wall time of engine.step() once the batch is warm/compiled."""
    for s in sessions:
        engine.submit(s)
    for _ in range(WARM_TICKS):
        engine.step()
    jax.block_until_ready(engine.store.m)
    times = []
    for _ in range(MEASURED_TICKS):
        t0 = time.perf_counter()
        engine.step()
        jax.block_until_ready(engine.store.m)
        times.append(time.perf_counter() - t0)
    while engine.scheduler.has_work():  # drain
        engine.step()
    times.sort()
    return times[len(times) // 2]


def _steady_chunk_time(engine, sessions, warm=WARM_TICKS, measured=MEASURED_TICKS):
    """Median wall time of one mid-run CHUNK once the batch is warm.

    The chunked analogue of `_tick_time` — same estimator (median of a few
    warm samples, churn excluded) as the per-tick trajectory numbers this
    file has always reported, so sessions/sec stays comparable across
    BENCH_serve.json history. Each sample blocks on the chunk, which is
    the pessimistic (unpipelined) bound for steady throughput."""
    for s in sessions:
        engine.submit(s)
    times = []
    for _ in range(warm + measured):
        t0 = time.perf_counter()
        more = engine.step_chunk()
        jax.block_until_ready(engine.store.m)
        times.append(time.perf_counter() - t0)
        if not more:
            break
    engine.run([])  # drain the remainder through the public path
    times = sorted(times[warm:])
    return times[len(times) // 2]


def _drain_time(engine, sessions, pipelined: bool):
    """(wall seconds, session-ticks served) for a full drain of sessions."""
    ticks0 = engine.scheduler.stats.session_ticks
    t0 = time.perf_counter()
    if pipelined:
        engine.run(sessions)
    else:
        for s in sessions:
            engine.submit(s)
        while engine.scheduler.has_work():
            engine.step()
    jax.block_until_ready(engine.store.m)
    dt = time.perf_counter() - t0
    return dt, engine.scheduler.stats.session_ticks - ticks0


def bench_cell(n: int, e: int, print_fn=print):
    spec = make_spec(n=n, n_in=1, hold_steps=HOLD_STEPS, dtype=jnp.float32)
    rng = np.random.default_rng(0)

    # -- pipelined chunked serving (the headline path) ---------------------
    pipe_eng = ReservoirEngine(
        compile_plan(spec, ExecPlan(ensemble=e, chunk_ticks=CHUNK_TICKS)),
        max_retained=e,
    )
    backend = pipe_eng.backend
    _drain_time(pipe_eng, _mk_sessions(e, CHUNK_TICKS, 1, rng), pipelined=True)  # warm
    # per-precision / chunk-impl twin engines: same workload re-served
    # through (a) the other member of the {default, chunk-resident} impl
    # pair and (b) "mixed" precision on the chunk-resident impl. Their
    # steady reps INTERLEAVE with the default engine's below, so the ±40%
    # container noise bills all columns equally and the speedup ratios are
    # honest within-run comparisons. The twin is symmetric — when the
    # dispatch table already resolves the default to "chunk", the twin is
    # "ref" — so a once-seeded winner keeps being challenged by later
    # bench runs instead of ratcheting in place (seed_from_bench replaces
    # the default entry whenever the twin's within-run ratio beat it).
    twin_impl = "ref" if backend == "chunk" else "chunk"
    chunk_eng = ReservoirEngine(
        compile_plan(
            spec, ExecPlan(impl=twin_impl, ensemble=e, chunk_ticks=CHUNK_TICKS)
        ),
        max_retained=e,
    )
    _drain_time(
        chunk_eng, _mk_sessions(e, CHUNK_TICKS, 1, rng, base_sid=90_000),
        pipelined=True,
    )  # warm
    mixed_eng = ReservoirEngine(
        compile_plan(
            spec,
            ExecPlan(
                impl="chunk", ensemble=e, chunk_ticks=CHUNK_TICKS,
                precision="mixed",
            ),
        ),
        max_retained=e,
    )
    _drain_time(
        mixed_eng, _mk_sessions(e, CHUNK_TICKS, 1, rng, base_sid=95_000),
        pipelined=True,
    )  # warm
    # learn-on twin engine (N <= LEARN_MAX_N): same plan + learn="rls";
    # its steady reps INTERLEAVE with the learn-off reps below so a slow
    # container episode bills both sides of the overhead ratio equally
    learn_eng = None
    if n <= LEARN_MAX_N:
        learn_eng = ReservoirEngine(
            compile_plan(
                spec,
                ExecPlan(
                    impl=backend, ensemble=e, chunk_ticks=CHUNK_TICKS,
                    learn="rls", learn_reg=1e-2,
                ),
            ),
            max_retained=e,
        )
        _drain_time(
            learn_eng,
            _mk_sessions(e, CHUNK_TICKS, 1, rng, base_sid=70_000, learn=True),
            pipelined=True,
        )  # warm
    # steady chunk median: one wave of E long streams — the trajectory metric
    chunk_reps, learn_reps, chunkimpl_reps, mixed_reps = [], [], [], []
    for r in range(STEADY_REPS):
        chunk_reps.append(
            _steady_chunk_time(
                pipe_eng,
                _mk_sessions(e, STEADY_TICKS, 1, rng, base_sid=60_000 + 1000 * r),
            )
        )
        chunkimpl_reps.append(
            _steady_chunk_time(
                chunk_eng,
                _mk_sessions(e, STEADY_TICKS, 1, rng, base_sid=91_000 + 1000 * r),
            )
        )
        mixed_reps.append(
            _steady_chunk_time(
                mixed_eng,
                _mk_sessions(e, STEADY_TICKS, 1, rng, base_sid=96_000 + 1000 * r),
            )
        )
        if learn_eng is not None:
            learn_reps.append(
                _steady_chunk_time(
                    learn_eng,
                    _mk_sessions(
                        e, STEADY_TICKS, 1, rng,
                        base_sid=80_000 + 1000 * r, learn=True,
                    ),
                )
            )
    t_chunk = min(chunk_reps)
    # burst run: WAVES generations, admit/retire churn billed
    t_pipe, ticks_pipe = _drain_time(
        pipe_eng, _mk_sessions(WAVES * e, TICKS, 1, rng, base_sid=20_000), pipelined=True
    )

    # -- synchronous per-tick serving (the PR-2 path), same workload -------
    sync_eng = ReservoirEngine(
        compile_plan(spec, ExecPlan(impl=backend, ensemble=e)), max_retained=e
    )
    t_tick_sync = _tick_time(sync_eng, _mk_sessions(e, WARM_TICKS + MEASURED_TICKS + 2, 1, rng))
    t_sync, ticks_sync = _drain_time(
        sync_eng, _mk_sessions(WAVES * e, TICKS, 1, rng, base_sid=30_000), pipelined=False
    )

    # -- sequential baseline: E streams = E solo ticks per aggregate tick --
    solo = ReservoirEngine(compile_plan(spec, ExecPlan(impl=backend, ensemble=1)))
    t_solo = _tick_time(solo, _mk_sessions(1, WARM_TICKS + MEASURED_TICKS + 2, 1, rng, base_sid=10_000))

    ticks_per_sec = e * CHUNK_TICKS / t_chunk
    ticks_per_sec_burst = ticks_pipe / t_pipe
    ticks_per_sec_sync = ticks_sync / t_sync
    agg_solo = 1.0 / t_solo
    med = lambda xs: sorted(xs)[len(xs) // 2]
    cell = {
        "n": n,
        "e": e,
        "backend": backend,
        "precision": pipe_eng.precision,
        "chunk_ticks": CHUNK_TICKS,
        "stream_ticks": TICKS,
        "steady_ticks": STEADY_TICKS,
        "waves": WAVES,
        "steady_chunk_s": t_chunk,
        "pipelined_drain_s": t_pipe,
        "sync_drain_s": t_sync,
        "batched_tick_s": t_tick_sync,
        "solo_tick_s": t_solo,
        "ticks_per_sec": ticks_per_sec,
        "ticks_per_sec_burst": ticks_per_sec_burst,
        "ticks_per_sec_sync": ticks_per_sec_sync,
        "sessions_per_sec": ticks_per_sec / REF_STREAM_TICKS,
        "sessions_per_sec_sync": (e / t_tick_sync) / REF_STREAM_TICKS,
        "pipelined_speedup": t_sync / t_pipe,
        "speedup_vs_sequential": ticks_per_sec / agg_solo,
        "hold_steps": HOLD_STEPS,
    }

    # -- per-precision / chunk-impl columns (reps interleaved above) -------
    # ratios use MEDIANS of the rep samples, not mins: a single outlier-
    # fast rep on either side would otherwise swing the ratio by the
    # container's full ±40% noise band. Judge perf PRs by THESE within-run
    # ratio columns, never across-run absolutes (ROADMAP caveat).
    t_ci = min(chunkimpl_reps)
    cell.update(
        backend_chunk_impl=chunk_eng.backend,
        steady_chunk_chunkimpl_s=t_ci,
        ticks_per_sec_chunk_impl=e * CHUNK_TICKS / t_ci,
        sessions_per_sec_chunk_impl=(e * CHUNK_TICKS / t_ci) / REF_STREAM_TICKS,
        chunk_impl_speedup=med(chunk_reps) / med(chunkimpl_reps),
    )
    t_mixed = min(mixed_reps)
    cell.update(
        backend_mixed=mixed_eng.backend,
        precision_mixed=mixed_eng.precision,
        steady_chunk_mixed_s=t_mixed,
        ticks_per_sec_mixed=e * CHUNK_TICKS / t_mixed,
        sessions_per_sec_mixed=(e * CHUNK_TICKS / t_mixed) / REF_STREAM_TICKS,
        precision_speedup=med(chunk_reps) / med(mixed_reps),
    )

    # -- learn-on vs learn-off columns (reps measured interleaved above) ---
    if learn_eng is not None:
        t_chunk_learn = min(learn_reps)
        cell.update(
            steady_chunk_learn_s=t_chunk_learn,
            ticks_per_sec_learn=e * CHUNK_TICKS / t_chunk_learn,
            sessions_per_sec_learn=(e * CHUNK_TICKS / t_chunk_learn)
            / REF_STREAM_TICKS,
            # within-run ratio (the ROADMAP's ±40% container-noise caveat:
            # judge learn overhead by THIS column, not absolute numbers)
            learn_overhead=med(learn_reps) / med(chunk_reps),
        )

    # -- autoscale vs fixed: the same burst through the bucketed cache -----
    if n <= AUTOSCALE_MAX_N and e >= 16:
        start = max(8, e // 4)
        auto = ReservoirEngine(
            compile_plan(spec, ExecPlan(impl=backend, ensemble=start, chunk_ticks=CHUNK_TICKS)),
            autoscale=QueueDepthPolicy(),
            min_slots=start,
            max_slots=e,
            max_retained=e,
        )
        # warm the start-width compile out of the timed region (the fixed
        # engine got the same courtesy); growth-bucket compiles during the
        # burst stay billed — they ARE autoscale's cost
        _drain_time(auto, _mk_sessions(start, CHUNK_TICKS, 1, rng, base_sid=45_000), pipelined=True)
        t_auto, _ = _drain_time(
            auto, _mk_sessions(WAVES * e, TICKS, 1, rng, base_sid=50_000), pipelined=True
        )
        cell.update(
            autoscale_start_slots=start,
            autoscale_final_slots=auto.num_slots,
            autoscale_grows=auto.scheduler.stats.grows,
            fixed_burst_s=t_pipe,
            autoscale_burst_s=t_auto,
            autoscale_vs_fixed=t_pipe / t_auto,
        )

    print_fn(
        csv_row(
            f"serve_n{n}_e{e}",
            (t_pipe / max(1, ticks_pipe)) * e * 1e6,  # us per aggregate tick
            f"backend_{backend}_pipelined_{cell['pipelined_speedup']:.1f}x"
            f"_vs_seq_{cell['speedup_vs_sequential']:.1f}x",
        )
    )
    return cell


# ---------------------------------------------------------------------------
# tune tier: lane-vectorized hyperparameter search vs sequential
# ---------------------------------------------------------------------------

TUNE_N = 16
TUNE_BUDGET = 64  # candidates per search
TUNE_LANES = 32  # candidates per pass, vectorized config
TUNE_TICKS = 200  # NARMA ticks per candidate evaluation
TUNE_CHUNK_TICKS = 2  # small chunks: per-dispatch overhead is what E amortizes


def bench_tune(
    print_fn=print,
    budget: int = TUNE_BUDGET,
    lanes: int = TUNE_LANES,
    ticks: int = TUNE_TICKS,
) -> dict:
    """Tune columns, two measurements sharing one NARMA-10 task:

    SPEEDUP — the same seeded random search over (drive current, spectral
    radius), run lane-VECTORIZED (ExecPlan ensemble = `lanes` candidates
    per simulation pass, fitness from the fused online learner) and
    SEQUENTIAL (ensemble=1, one pass per candidate — the methodology the
    pre-tune examples/parameter_sweep.py hand-rolled). `tune_speedup` is
    the within-run wall-clock ratio; judge IT, never the absolute seconds
    (container ±40% noise, ROADMAP caveat). Both configs pay a warm-up
    search first so jit compiles stay out of the measured walls.

    WINNER MATCH — a grid search over well-separated points in the
    DYNAMICALLY STABLE regime, vectorized vs sequential; the winner must
    not depend on lane width (`grid_winner_match`). The stable-regime
    restriction is load-bearing: near the chaotic high-current edge a
    last-ulp difference between the E-wide and solo matmuls grows
    exponentially along the trajectory, so per-candidate fitness there is
    only reproducible at FIXED width (that bit-pin lives in
    tests/test_tune.py) — which is also why the random-search columns
    record best fitness per config rather than asserting equality."""
    from repro.tune import Choice, Float, SearchSpace, narma_task, tune_spec

    spec = make_spec(n=TUNE_N, n_in=1, hold_steps=HOLD_STEPS, dtype=jnp.float32)
    space = SearchSpace({
        "drive_current": Float(0.5e-3, 4.5e-3),
        "spectral_radius": Float(0.2, 1.2),
    })
    task = narma_task(t=ticks, order=10, seed=0, learn_washout=50)
    vec_plan = ExecPlan(
        impl="scan", ensemble=lanes, chunk_ticks=TUNE_CHUNK_TICKS, learn="rls"
    )
    seq_plan = ExecPlan(
        impl="scan", ensemble=1, chunk_ticks=TUNE_CHUNK_TICKS, learn="rls"
    )
    # warm both shapes' jit caches out of the measured region
    tune_spec(spec, task, space, budget=min(lanes, budget), plan=vec_plan, seed=99)
    tune_spec(spec, task, space, budget=1, plan=seq_plan, seed=99)

    vec = tune_spec(spec, task, space, budget=budget, plan=vec_plan, seed=0)
    seq = tune_spec(spec, task, space, budget=budget, plan=seq_plan, seed=0)
    speedup = seq.wall_s / vec.wall_s

    grid_space = SearchSpace({
        "drive_current": Choice([1e-3, 2e-3, 3e-3]),
        "spectral_radius": Choice([0.3, 0.6, 0.9]),
    })
    grid_budget = 9
    gv = tune_spec(spec, task, grid_space, budget=grid_budget, plan=vec_plan,
                   strategy="grid")
    gs = tune_spec(spec, task, grid_space, budget=grid_budget, plan=seq_plan,
                   strategy="grid")
    match = gv.best.assignment == gs.best.assignment

    tune = {
        "n": TUNE_N,
        "budget": budget,
        "lanes": lanes,
        "ticks": ticks,
        "chunk_ticks": TUNE_CHUNK_TICKS,
        "strategy": "random",
        "task": task.name,
        "wall_vectorized_s": vec.wall_s,
        "wall_sequential_s": seq.wall_s,
        "tune_speedup": speedup,
        "best_nmse": vec.best.fitness,
        "best_nmse_sequential": seq.best.fitness,
        "best_assignment": {k: float(v) for k, v in vec.best.assignment.items()},
        "grid_budget": grid_budget,
        "grid_winner": {k: float(v) for k, v in gv.best.assignment.items()},
        "grid_winner_match": match,
    }
    print_fn(
        csv_row(
            f"tune_b{budget}_l{lanes}",
            vec.wall_s * 1e6,
            f"speedup_{speedup:.1f}x_gridmatch_{str(match).lower()}"
            f"_nmse_{vec.best.fitness:.3f}",
        )
    )
    return tune


# ---------------------------------------------------------------------------
# fleet tier: multi-replica bursty mixed-N workload
# ---------------------------------------------------------------------------

FLEET_POOLS = ((16, 8), (128, 8))  # (N, slots per replica) per pool
FLEET_BURSTS = 2
FLEET_SESSIONS_PER_POOL_BURST = 16  # 2x replica slot width -> queueing


def _run_fleet_config(replicas: int, transport: str, pools, sessions_pp,
                      bursts: int, rng) -> tuple:
    """Serve the bursty mixed-N workload on `replicas` replicas per pool;
    returns (drain seconds, sessions served, session-ticks served).

    Bursts land mid-serve (a full wave of every pool's sessions at once,
    injected every few pump rounds) so the measurement includes the
    queueing/refill behavior the fleet exists for — not just a pre-loaded
    batch. Compile time is warmed out per pool first."""
    from repro.serve.fleet import FleetRouter, start_fleet

    router = FleetRouter()
    for n, e in pools:
        for r in start_fleet(
            replicas, transport, n=n, num_slots=e,
            hold_steps=HOLD_STEPS, chunk_ticks=CHUNK_TICKS,
        ):
            router.add_replica(r)
    # warm the full shape repertoire out of the timed region: the chunk
    # plan AND the admit/retire scatter shapes that wave turnover hits
    # (each distinct admission/retirement count is its own jit trace) —
    # same burst pattern, one-chunk streams
    sid = 900_000
    for _ in range(bursts):
        for n, _ in pools:
            for s in _mk_sessions(sessions_pp, CHUNK_TICKS, 1, rng, base_sid=sid):
                router.submit(n, s)
            sid += sessions_pp
        router.drain()

    burst_list = []
    for b in range(bursts):
        burst = []
        for n, _ in pools:
            for s in _mk_sessions(sessions_pp, TICKS, 1, rng, base_sid=sid):
                burst.append((n, s))
            sid += sessions_pp
        burst_list.append(burst)

    served = 0
    ticks0 = sum(
        st.session_ticks for pool in router.stats().values() for st in pool
    )
    t0 = time.perf_counter()
    bi = 0
    rounds = 0
    while True:
        if bi < len(burst_list) and rounds % 3 == 0:
            for n, s in burst_list[bi]:
                router.submit(n, s)
            bi += 1
        worked = router.run_for(1)
        served += len(router.results())
        rounds += 1
        if not worked and bi >= len(burst_list):
            break
    dt = time.perf_counter() - t0
    ticks = (
        sum(st.session_ticks for pool in router.stats().values() for st in pool)
        - ticks0
    )
    router.close()
    return dt, served, ticks


def bench_fleet(
    bench_payload: dict,
    replicas: int = 2,
    transport: str = None,
    print_fn=print,
) -> dict:
    """Fleet scaling column: R replicas per pool vs 1, same bursty mixed-N
    workload, plus the capacity planner's predicted-vs-measured error.

    The honest metric is the WITHIN-RUN ratio (fleet vs single replica on
    this host, minutes apart) — absolute sessions/sec moves with
    container noise. Replicas time-share cores, so the planner predicts
    the ratio as min(R, cores): near-linear on multi-core hosts, ~1.0 on
    a single-core host (where the fleet buys capacity and isolation, not
    FLOPs). Both prediction and measurement are recorded."""
    from repro.serve.fleet import CapacityModel, measure_probe_rates, usable_cores

    cores = usable_cores()
    if transport is None:
        # pipes only pay off when children get their own core
        transport = "process" if cores > 1 else "local"
    rng = np.random.default_rng(7)
    t1, m1, ticks1 = _run_fleet_config(
        1, transport, FLEET_POOLS, FLEET_SESSIONS_PER_POOL_BURST,
        FLEET_BURSTS, rng,
    )
    tr, mr, ticksr = _run_fleet_config(
        replicas, transport, FLEET_POOLS, FLEET_SESSIONS_PER_POOL_BURST,
        FLEET_BURSTS, rng,
    )
    assert m1 == mr, f"configs served different workloads: {m1} vs {mr}"
    speedup = (ticksr / tr) / (ticks1 / t1)
    predicted_speedup = float(min(replicas, cores))

    # planner absolute check: predicted drain time of the single-replica
    # config from the grid-calibrated SUSTAINED model (per pool: churn-
    # billed drain seconds; pools time-share the host, so times add). The
    # grid's absolute scale is only valid on the host state it was
    # recorded under (±40% container noise, ROADMAP caveat), so the
    # planner first recalibrates from a same-run probe: each pool cell
    # re-measured ONCE with the grid's own burst methodology on a bare
    # engine. Non-circular — the probe never touches the fleet stack the
    # measurement goes through, so the error still bills router/replica
    # overhead and the bursty-injection queueing. The probe engines draw
    # from the process-wide PlanCache (`measure_probe_rates`), so the
    # replicas that just served the workload above already paid every
    # compile the probe needs — recalibration costs pure measurement.
    planner = CapacityModel.from_bench(bench_payload)
    probe = measure_probe_rates(
        FLEET_POOLS,
        hold_steps=HOLD_STEPS,
        chunk_ticks=CHUNK_TICKS,
        stream_ticks=TICKS,
        waves=WAVES,
    )
    host_scale = planner.recalibrate(probe)
    sessions_total = FLEET_BURSTS * FLEET_SESSIONS_PER_POOL_BURST
    pred_t1 = sum(
        planner.drain_seconds(n, e, sessions_total, TICKS, replicas=1)
        for n, e in FLEET_POOLS
    )
    planner_err = abs(pred_t1 - t1) / t1
    fleet = {
        "replicas": replicas,
        "transport": transport,
        "cores": cores,
        "pools": [{"n": n, "slots": e} for n, e in FLEET_POOLS],
        "bursts": FLEET_BURSTS,
        "sessions": m1,
        "stream_ticks": TICKS,
        "single_drain_s": t1,
        "fleet_drain_s": tr,
        "sessions_per_sec_single": (ticks1 / t1) / REF_STREAM_TICKS,
        "sessions_per_sec_fleet": (ticksr / tr) / REF_STREAM_TICKS,
        "fleet_speedup": speedup,
        "predicted_speedup": predicted_speedup,
        "planner_host_scale": host_scale,
        "planner_predicted_single_drain_s": pred_t1,
        "planner_vs_measured_err": planner_err,
        "planner_fit_err": planner.prediction_error()["max"],
    }
    print_fn(
        csv_row(
            f"serve_fleet_x{replicas}",
            tr * 1e6,
            f"speedup_{speedup:.2f}x_predicted_{predicted_speedup:.1f}x"
            f"_planner_err_{planner_err:.0%}",
        )
    )
    return fleet


def fleet_smoke(replicas: int = 2, min_ratio: float = 1.5, print_fn=print) -> bool:
    """CI fleet smoke: bursty mixed-N workload through the ASYNC front-end
    (admission control in the loop), 2 replicas vs 1. Asserts the fleet
    drains cleanly everywhere; asserts the >= min_ratio session-throughput
    scaling only where the host has the cores to show it (replicas
    time-share cores, so a 1-core runner caps the honest ratio at ~1.0)."""
    import asyncio

    from repro.serve.fleet import FleetFrontend, FleetRouter, start_fleet, usable_cores

    pools = ((16, 8), (32, 8))
    sessions_pp = 12
    cores = usable_cores()
    transport = "process" if cores > 1 else "local"

    async def serve(n_replicas: int) -> tuple:
        rng = np.random.default_rng(11)
        router = FleetRouter()
        for n, e in pools:
            for r in start_fleet(
                n_replicas, transport, n=n, num_slots=e,
                hold_steps=HOLD_STEPS, chunk_ticks=CHUNK_TICKS,
            ):
                router.add_replica(r)
        async with FleetFrontend(router) as fleet:
            # warm compiles out of the timed region
            for n, _ in pools:
                await fleet.submit_stream(
                    n, rng.uniform(0.0, 0.5, (CHUNK_TICKS, 1)).astype(np.float32),
                    collect_states=False,
                )
            await fleet.drain_results()
            t0 = time.perf_counter()
            for _ in range(2):  # two bursts
                for n, _ in pools:
                    for _ in range(sessions_pp):
                        await fleet.submit_stream(
                            n,
                            rng.uniform(0.0, 0.5, (TICKS, 1)).astype(np.float32),
                            collect_states=False,
                        )
            results = await fleet.drain_results()
            dt = time.perf_counter() - t0
        return dt, len(results)

    want = 2 * sessions_pp * len(pools)
    t1, m1 = asyncio.run(serve(1))
    tr, mr = asyncio.run(serve(replicas))
    clean = m1 == want and mr == want
    ratio = (mr / tr) / (m1 / t1)
    print_fn(
        f"fleet smoke: {replicas} replicas vs 1 -> {ratio:.2f}x session "
        f"throughput ({cores} cores, transport={transport}); "
        f"drained {mr}/{want} and {m1}/{want}"
    )
    ok = clean
    if cores >= 2:
        ok = ok and ratio >= min_ratio
    else:
        print_fn(
            f"fleet smoke: single-core host — ratio gate (>= {min_ratio}x) "
            f"skipped, clean-drain gate enforced"
        )
    return ok


def _compile_probe_child(conn, n, e, k, hold_steps, cache_dir):
    """Spawn target for the persistent-cache columns: build + warm ONE
    engine config in a fresh process and report wall seconds. With both
    probes pointed at the same `cache_dir`, the first populates the JAX
    persistent compilation cache and the second reads its XLA executables
    off disk — the cross-restart cold-start the ExecPlan flag buys."""
    try:
        import time as _time

        import jax.numpy as _jnp

        from repro.api import ExecPlan, compile_plan, make_spec

        t0 = _time.perf_counter()
        spec = make_spec(n=n, n_in=1, hold_steps=hold_steps, dtype=_jnp.float32)
        sim = compile_plan(
            spec,
            ExecPlan(
                ensemble=e, chunk_ticks=k, compilation_cache_dir=cache_dir
            ),
        )
        sim.warmup()
        conn.send(("ok", _time.perf_counter() - t0))
    except Exception as exc:  # noqa: BLE001 — report, don't hang the parent
        conn.send(("err", f"{type(exc).__name__}: {exc}"))


def bench_compile(quick: bool = False, print_fn=print) -> dict:
    """Compile-path columns: what the PlanCache and the persistent disk
    cache each buy, in seconds, on this host.

      cold_s            PLAN_CACHE.ensure_warm of a fresh structural spec:
                        XLA compile + first chunk execution
      warm_s            the identical call again — cache hit, zero compiles
      warm_speedup      cold_s / warm_s (the autoscale / fleet spin-up win;
                        benchmarks/run.py --smoke gates >= 5x)
      aot_s             lower().compile() of a second structural variant:
                        pure ahead-of-time compile seconds, no execution
      persistent_cold_s / persistent_warm_s / persistent_speedup
                        two spawned subprocesses against one shared
                        on-disk JAX compilation cache: the first pays the
                        compile and populates disk, the second reads it
                        back — process-restart cold-start. None when the
                        persistent cache is unavailable on this jaxlib.
    """
    import multiprocessing as mp
    import tempfile

    from repro.api import PLAN_CACHE, ExecPlan, compile_plan, make_spec

    # Deliberately off-grid N: the tick workers are module-level jit
    # functions, so any (shape, statics) signature another section already
    # ran would make "cold" a JAX-level jit hit instead of a real XLA
    # compile. An N no other section uses guarantees cold pays the
    # compile; the unique seed keeps the PlanCache entry fresh too.
    n, e = (19, 8) if quick else (131, 16)
    plan = ExecPlan(ensemble=e, chunk_ticks=CHUNK_TICKS)
    spec_cold = make_spec(
        n=n, n_in=1, hold_steps=HOLD_STEPS, seed=91_001, dtype=jnp.float32
    )

    compiles0 = PLAN_CACHE.stats.compiles
    t0 = time.perf_counter()
    PLAN_CACHE.ensure_warm(spec_cold, plan)
    cold_s = time.perf_counter() - t0
    cold_compiles = PLAN_CACHE.stats.compiles - compiles0
    t0 = time.perf_counter()
    PLAN_CACHE.ensure_warm(spec_cold, plan)
    warm_s = time.perf_counter() - t0
    warm_compiles = PLAN_CACHE.stats.compiles - compiles0 - cold_compiles

    # AOT column on a distinct structural variant so it pays a real lower
    spec_aot = make_spec(
        n=n, n_in=1, hold_steps=HOLD_STEPS + 2, seed=91_002, dtype=jnp.float32
    )
    t0 = time.perf_counter()
    compile_plan(spec_aot, plan).aot_compile()
    aot_s = time.perf_counter() - t0

    persistent_cold_s = persistent_warm_s = None
    try:
        ctx = mp.get_context("spawn")
        with tempfile.TemporaryDirectory(prefix="jaxcache-") as cache_dir:
            times = []
            for _ in range(2):
                parent, child = ctx.Pipe()
                proc = ctx.Process(
                    target=_compile_probe_child,
                    args=(child, n, e, CHUNK_TICKS, HOLD_STEPS, cache_dir),
                    daemon=True,
                )
                proc.start()
                child.close()
                status, payload = parent.recv()
                proc.join(timeout=60)
                parent.close()
                if status != "ok":
                    raise RuntimeError(payload)
                times.append(payload)
            persistent_cold_s, persistent_warm_s = times
    except Exception as exc:  # noqa: BLE001 — column is best-effort
        print_fn(f"compile bench: persistent-cache probe skipped ({exc})")

    out = {
        "n": n,
        "slots": e,
        "chunk_ticks": CHUNK_TICKS,
        "cold_s": cold_s,
        "cold_compiles": cold_compiles,
        "warm_s": warm_s,
        "warm_compiles": warm_compiles,
        "warm_speedup": cold_s / max(warm_s, 1e-9),
        "aot_s": aot_s,
        "persistent_cold_s": persistent_cold_s,
        "persistent_warm_s": persistent_warm_s,
        "persistent_speedup": (
            persistent_cold_s / max(persistent_warm_s, 1e-9)
            if persistent_cold_s is not None
            else None
        ),
        "cache_stats": PLAN_CACHE.stats.snapshot(),
    }
    print_fn(
        csv_row(
            "serve_compile_cold",
            cold_s * 1e6,
            f"warm_{out['warm_speedup']:.0f}x_aot_{aot_s:.2f}s",
        )
    )
    if persistent_cold_s is not None:
        print_fn(
            csv_row(
                "serve_compile_persistent",
                persistent_warm_s * 1e6,
                f"vs_cold_{out['persistent_speedup']:.2f}x",
            )
        )
    return out


def run(
    out_path: str = "BENCH_serve.json",
    quick: bool = False,
    fleet: bool = True,
    replicas: int = 2,
    tune: bool = True,
    print_fn=print,
):
    ns = (16, 128) if quick else NS
    es = (8, 64) if quick else ES
    cells = [bench_cell(n, e, print_fn=print_fn) for n in ns for e in es]
    payload = {
        "benchmark": "serve_throughput",
        "backend_platform": jax.default_backend(),
        "hold_steps": HOLD_STEPS,
        "chunk_ticks": CHUNK_TICKS,
        "stream_ticks": TICKS,
        "ref_stream_ticks": REF_STREAM_TICKS,
        "cells": cells,
    }
    if fleet:
        # planner calibrates from the cells just measured — same run, same
        # host, so the predicted-vs-measured column is apples to apples
        payload["fleet"] = bench_fleet(
            payload, replicas=replicas, print_fn=print_fn
        )
    if tune:
        payload["tune"] = bench_tune(print_fn=print_fn)
    payload["compile"] = bench_compile(quick=quick, print_fn=print_fn)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print_fn(csv_row("serve_json", 0.0, out_path))
    return cells


def run_fleet_only(
    out_path: str = "BENCH_serve.json", replicas: int = 2, print_fn=print
):
    """Re-measure ONLY the fleet section, merging into the existing grid
    file (the 9-cell grid takes minutes; the fleet column takes seconds)."""
    with open(out_path) as f:
        payload = json.load(f)
    payload["fleet"] = bench_fleet(payload, replicas=replicas, print_fn=print_fn)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print_fn(csv_row("serve_json", 0.0, out_path))
    return payload["fleet"]


def run_compile_only(out_path: str = "BENCH_serve.json", print_fn=print):
    """Re-measure ONLY the compile section, merging into the existing grid
    file (the compile columns take seconds, the grid takes minutes)."""
    with open(out_path) as f:
        payload = json.load(f)
    payload["compile"] = bench_compile(print_fn=print_fn)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print_fn(csv_row("serve_json", 0.0, out_path))
    return payload["compile"]


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--no-fleet", action="store_true",
                    help="skip the fleet scaling column")
    ap.add_argument("--no-tune", action="store_true",
                    help="skip the tune (vectorized search) columns")
    ap.add_argument("--fleet-only", action="store_true",
                    help="re-measure only the fleet column, merge into --out")
    ap.add_argument("--compile-only", action="store_true",
                    help="re-measure only the cold/warm/persistent compile "
                         "columns, merge into --out")
    ap.add_argument("--fleet-smoke", action="store_true",
                    help="CI gate: 2-replica bursty mixed-N smoke through "
                         "the async front-end; exits nonzero on failure")
    args = ap.parse_args()
    if args.fleet_smoke:
        raise SystemExit(0 if fleet_smoke(replicas=args.replicas) else 1)
    elif args.fleet_only:
        run_fleet_only(out_path=args.out, replicas=args.replicas)
    elif args.compile_only:
        run_compile_only(out_path=args.out)
    else:
        run(out_path=args.out, quick=args.quick, fleet=not args.no_fleet,
            replicas=args.replicas, tune=not args.no_tune)
