"""Serving throughput: slot-batched reservoir engine vs one-at-a-time.

For each (N, E) cell the batched engine serves E concurrent streams with one
integrate per tick; the baseline serves the same streams through a
single-slot engine, one session at a time (its per-tick cost measured once
and charged E times — sequential serving is exactly E solo ticks per
aggregate tick). Reported:

    ticks/sec   aggregate session-ticks per second (E / batched tick time)
    sessions/sec  streams completed per second for length-TICKS streams
    speedup     batched aggregate throughput over sequential aggregate

Engines are built through the unified execution API: one SimSpec per N,
compiled against ExecPlans of different ensemble widths — so the backend
each cell reports is exactly what `repro.api.compile_plan` resolved from
the measured-latency dispatch table / platform gate for that (N, E).

Emits the shared `name,us_per_call,derived` CSV rows and writes
BENCH_serve.json (benchmarks/run.py wires it into the suite) so future PRs
can track the serving-perf trajectory. `kernels.dispatch_table
.seed_from_bench` turns that JSON back into persisted dispatch entries.

    PYTHONPATH=src python -m benchmarks.serve_throughput [--quick]
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.api import ExecPlan, compile_plan, make_spec
from repro.serve.reservoir import ReservoirEngine, StreamSession

NS = (16, 128, 1024)
ES = (8, 64, 256)
HOLD_STEPS = 5
WARM_TICKS = 2
MEASURED_TICKS = 3


def _mk_sessions(num, t, n_in, rng, base_sid=0):
    return [
        StreamSession(
            sid=base_sid + i,
            u_seq=rng.uniform(0.0, 0.5, size=(t, n_in)).astype(np.float32),
            collect_states=False,
        )
        for i in range(num)
    ]


def _tick_time(engine, sessions) -> float:
    """Median wall time of engine.step() once the batch is warm/compiled."""
    for s in sessions:
        engine.submit(s)
    for _ in range(WARM_TICKS):
        engine.step()
    jax.block_until_ready(engine.store.m)
    times = []
    for _ in range(MEASURED_TICKS):
        t0 = time.perf_counter()
        engine.step()
        jax.block_until_ready(engine.store.m)
        times.append(time.perf_counter() - t0)
    while engine.scheduler.has_work():  # drain
        engine.step()
    times.sort()
    return times[len(times) // 2]


def bench_cell(n: int, e: int, print_fn=print):
    spec = make_spec(n=n, n_in=1, hold_steps=HOLD_STEPS, dtype=jnp.float32)
    rng = np.random.default_rng(0)
    ticks = WARM_TICKS + MEASURED_TICKS + 2

    batched = ReservoirEngine(compile_plan(spec, ensemble=e))
    t_batched = _tick_time(batched, _mk_sessions(e, ticks, 1, rng))

    solo = ReservoirEngine(compile_plan(spec, ExecPlan(impl=batched.backend, ensemble=1)))
    t_solo = _tick_time(solo, _mk_sessions(1, ticks, 1, rng, base_sid=10_000))

    # sequential serving of E streams costs E solo ticks per aggregate tick
    agg_batched = e / t_batched
    agg_solo = 1.0 / t_solo
    speedup = agg_batched / agg_solo
    cell = {
        "n": n,
        "e": e,
        "backend": batched.backend,
        "batched_tick_s": t_batched,
        "solo_tick_s": t_solo,
        "ticks_per_sec": agg_batched,
        "sessions_per_sec": agg_batched / ticks,
        "speedup_vs_sequential": speedup,
        "hold_steps": HOLD_STEPS,
    }
    print_fn(
        csv_row(
            f"serve_n{n}_e{e}",
            t_batched * 1e6,
            f"backend_{batched.backend}_speedup_{speedup:.1f}x",
        )
    )
    return cell


def run(out_path: str = "BENCH_serve.json", quick: bool = False, print_fn=print):
    ns = (16, 128) if quick else NS
    es = (8, 64) if quick else ES
    cells = [bench_cell(n, e, print_fn=print_fn) for n in ns for e in es]
    payload = {
        "benchmark": "serve_throughput",
        "backend_platform": jax.default_backend(),
        "hold_steps": HOLD_STEPS,
        "cells": cells,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print_fn(csv_row("serve_json", 0.0, out_path))
    return cells


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    run(out_path=args.out, quick=args.quick)
