"""Benchmark harness: one module per paper table/figure + the LM roofline.

Prints ``name,us_per_call,derived`` CSV rows (shared format). Individual
modules run standalone too:  python -m benchmarks.table2_timing

``--smoke`` runs a minutes-not-hours subset for CI: a quick serving-
throughput grid (written to a scratch file, NOT BENCH_serve.json) plus a
compile-and-drive pass through every unified-API entry point, so the CI
leg exercises plan compilation, dispatch-table loading, and the serving
engine end-to-end without paying for the full grids.
"""

from __future__ import annotations

import argparse
import os
import tempfile


def smoke() -> None:
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import serve_throughput
    from repro.api import ExecPlan, compile_plan, make_spec
    from repro.kernels import dispatch_table

    print("name,us_per_call,derived")

    # unified API end-to-end: compile (consults the persisted dispatch
    # table), then touch each entry point once
    spec = make_spec(n=16, n_in=1, hold_steps=5, dtype=jnp.float32)
    sim = compile_plan(spec, ensemble=4)
    u = np.random.default_rng(0).uniform(0.0, 0.5, size=(6, 1)).astype(np.float32)
    sim.drive_batch(u)
    compile_plan(spec, ExecPlan(impl="scan")).drive(u)
    sim_solo = compile_plan(spec)
    sim_solo.drive(u)
    print(f"smoke_compile_plan,0.0,impl_{sim.impl}")
    loaded = dispatch_table.ensure_loaded()  # 0 if already loaded: fine
    print(f"smoke_dispatch_table,0.0,loaded_{loaded}_entries")

    # quick serving grid to a scratch path so the committed trajectory
    # (BENCH_serve.json) only changes when the full benchmark runs
    out = os.path.join(tempfile.gettempdir(), "BENCH_serve.smoke.json")
    serve_throughput.run(out_path=out, quick=True)


def main() -> None:
    from benchmarks import (
        fig2_vectorfield,
        reservoir_tasks,
        roofline_lm,
        serve_throughput,
        table2_timing,
        table3_factors,
    )

    print("name,us_per_call,derived")
    fig2_vectorfield.run()
    _, per_step = table2_timing.run()
    table3_factors.run(per_step=per_step)
    reservoir_tasks.run()
    roofline_lm.run()
    # serving-perf trajectory: sessions/sec + ticks/sec over the (N, E) grid,
    # persisted to BENCH_serve.json for PR-over-PR comparison
    serve_throughput.run()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="fast CI subset: quick serving grid + unified-API compile/drive",
    )
    args = ap.parse_args()
    smoke() if args.smoke else main()
