"""Benchmark harness: one module per paper table/figure + the LM roofline.

Prints ``name,us_per_call,derived`` CSV rows (shared format). Individual
modules run standalone too:  python -m benchmarks.table2_timing

``--smoke`` runs a minutes-not-hours subset for CI: a quick serving-
throughput grid (written to a scratch file, NOT BENCH_serve.json) plus a
compile-and-drive pass through every unified-API entry point — including
the chunked `tick_chunk` serving path, an autoscaling engine, an online-
learning engine bit-checked against the fit_rls oracle, and a "mixed"-
precision serve asserted against the f32 accuracy guardrail — so the CI
leg exercises plan compilation, dispatch-table loading, precision
policies, and the serving engine end-to-end without paying for the full
grids — plus the tune subsystem: an LMS engine bit-checked against the
fit_lms oracle, a washout auto-tune that serves a tuned tenant end-to-end,
and the lane-vectorized-vs-sequential search ratio. The smoke grid's
WITHIN-RUN ratio columns are the perf gate (pipelined/sync,
fleet/single-replica, planner predicted-vs-measured,
tune vectorized/sequential); absolute sessions/sec is never asserted —
the container's ±40% noise owns that axis.

``--save-dispatch-table`` persists measured dispatch choices after the
run: the fresh serving grid is seeded into the in-process table
(`kernels.dispatch_table.seed_from_bench`) alongside anything
`ExecPlan(measure=True)` recorded, then written out via
`dispatch_table.save_table()` — the workflow for committing a
GPU/TPU-measured `dispatch_table.<platform>.json`.
"""

from __future__ import annotations

import argparse
import os
import tempfile


def _save_dispatch_table(bench_json: str, print_fn=print) -> None:
    from benchmarks.common import csv_row
    from repro.kernels import dispatch_table

    if os.path.exists(bench_json):
        seeded = dispatch_table.seed_from_bench(bench_json)
        print_fn(csv_row("dispatch_table_seeded", 0.0, f"{seeded}_entries"))
    path = dispatch_table.save_table()
    print_fn(csv_row("dispatch_table_saved", 0.0, path))


def smoke(save_dispatch_table: bool = False) -> None:
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import serve_throughput
    from repro.api import ExecPlan, compile_plan, make_spec
    from repro.kernels import dispatch_table

    print("name,us_per_call,derived")

    # unified API end-to-end: compile (consults the persisted dispatch
    # table), then touch each entry point once
    spec = make_spec(n=16, n_in=1, hold_steps=5, dtype=jnp.float32)
    sim = compile_plan(spec, ensemble=4)
    u = np.random.default_rng(0).uniform(0.0, 0.5, size=(6, 1)).astype(np.float32)
    sim.drive_batch(u)
    compile_plan(spec, ExecPlan(impl="scan")).drive(u)
    sim_solo = compile_plan(spec)
    sim_solo.drive(u)
    print(f"smoke_compile_plan,0.0,impl_{sim.impl}")

    # chunked serving path: one tick_chunk dispatch + an autoscaling engine
    from repro.serve.reservoir import ReservoirEngine, StreamSession

    chunked = compile_plan(spec, ExecPlan(ensemble=4, chunk_ticks=4))
    eng = ReservoirEngine(chunked, autoscale=True, min_slots=2, max_slots=8)
    sessions = [
        StreamSession(
            sid=i,
            u_seq=np.random.default_rng(i).uniform(0, 0.5, (6, 1)).astype(np.float32),
            collect_states=False,
        )
        for i in range(6)
    ]
    results = eng.run(sessions)
    print(f"smoke_serve_chunked,0.0,served_{len(results)}_chunk_{eng.chunk_ticks}")

    # physics families + mixed-spec tenancy: a time-multiplexed and an
    # array-transient tenant ride the coupled-array engine above; each
    # stream must be bit-identical to a dedicated engine for its spec
    from repro.api import make_array_transient_spec, make_time_multiplexed_spec

    spec_tm = make_time_multiplexed_spec(6, hold_steps=4)
    spec_at = make_array_transient_spec(8, readout_window=3, hold_steps=5)
    fam_u = {
        1: np.random.default_rng(21).uniform(0, 1, 9).astype(np.float32),
        2: np.random.default_rng(22).uniform(0, 1, 9).astype(np.float32),
    }
    mixed_eng = ReservoirEngine(spec, num_slots=2, backend="scan", chunk_ticks=4)
    mixed_eng.submit(StreamSession(sid=1, u_seq=fam_u[1], spec=spec_tm))
    mixed_eng.submit(StreamSession(sid=2, u_seq=fam_u[2], spec=spec_at))
    mixed = mixed_eng.run()
    for sid, fam_spec in ((1, spec_tm), (2, spec_at)):
        solo_eng = ReservoirEngine(fam_spec, num_slots=2, backend="scan", chunk_ticks=4)
        solo_eng.submit(StreamSession(sid=sid, u_seq=fam_u[sid]))
        solo = solo_eng.run()[sid]
        assert np.array_equal(mixed[sid].states, solo.states), (
            f"smoke: mixed-spec tenant {sid} ({fam_spec.topology}) deviates "
            "from its dedicated engine"
        )
    print(
        "smoke_families_tenancy,0.0,"
        f"subengines_{mixed_eng.stats().sub_engines}_bitmatch_solo"
    )

    # online learning end-to-end: a learning engine trains per-tenant
    # readouts while streaming; the learned weights must match the offline
    # fit_rls oracle run over the harvested states (scan backend: bitwise)
    from repro.core.reservoir import fit_rls

    learn_eng = ReservoirEngine(
        compile_plan(
            spec, ExecPlan(impl="scan", ensemble=4, chunk_ticks=4, learn="rls",
                           learn_reg=1e-2)
        )
    )
    rng = np.random.default_rng(7)
    learners = [
        StreamSession(
            sid=i,
            u_seq=rng.uniform(0, 0.5, (10, 1)).astype(np.float32),
            targets=rng.uniform(0, 0.5, (10, 1)).astype(np.float32),
            learn_washout=2,
        )
        for i in range(6)
    ]
    targets = {s.sid: s.targets for s in learners}
    learned = learn_eng.run(learners)
    for sid, r in learned.items():
        oracle = fit_rls(r.states, targets[sid], washout=2, reg=1e-2, block=4)
        assert np.array_equal(
            np.asarray(r.learned_readout.w_out), np.asarray(oracle.w_out)
        ), f"smoke: session {sid} learned readout != fit_rls oracle"
    print(f"smoke_serve_learn,0.0,trained_{len(learned)}_bitmatch_oracle")

    # LMS twin of the RLS oracle check: an ExecPlan(learn="lms") engine's
    # learned weights must bit-match the offline fit_lms oracle (same
    # normalized-LMS recursion over the harvested states, scan backend)
    from repro.core.reservoir import fit_lms

    lms_eng = ReservoirEngine(
        compile_plan(
            spec, ExecPlan(impl="scan", ensemble=4, chunk_ticks=4, learn="lms",
                           learn_mu=0.5)
        )
    )
    rng_lms = np.random.default_rng(8)
    lms_learners = [
        StreamSession(
            sid=i,
            u_seq=rng_lms.uniform(0, 0.5, (10, 1)).astype(np.float32),
            targets=rng_lms.uniform(0, 0.5, (10, 1)).astype(np.float32),
            learn_washout=2,
        )
        for i in range(5)
    ]
    lms_targets = {s.sid: s.targets for s in lms_learners}
    lms_learned = lms_eng.run(lms_learners)
    for sid, r in lms_learned.items():
        oracle = fit_lms(r.states, lms_targets[sid], washout=2, mu=0.5)
        assert np.array_equal(
            np.asarray(r.learned_readout.w_out), np.asarray(oracle.w_out)
        ), f"smoke: session {sid} LMS readout != fit_lms oracle"
    print(f"smoke_serve_lms,0.0,trained_{len(lms_learned)}_bitmatch_oracle")

    # washout auto-tune end-to-end: a live learning engine probes the
    # search space on spare lanes during a tenant's washout window, then
    # serves the tenant with the winning parameters (the tune subsystem's
    # serving entry point)
    from repro.core.tasks import narma_series
    from repro.tune import Float, SearchSpace

    tune_space = SearchSpace({
        "drive_current": Float(0.5e-3, 4.5e-3),
        "spectral_radius": Float(0.2, 1.2),
    })
    at_eng = ReservoirEngine(
        compile_plan(
            spec, ExecPlan(impl="scan", ensemble=4, chunk_ticks=4, learn="rls")
        )
    )
    u_at, y_at = narma_series(60, order=10, seed=3)
    tenant = StreamSession(sid=1, u_seq=u_at, targets=y_at, learn_washout=20)
    probe = at_eng.submit_autotuned(tenant, tune_space, budget=4, seed=0)
    while at_eng.step_chunk():
        pass
    served = at_eng.pop_results()
    assert len(probe.trials) == 4, f"expected 4 probe trials, got {len(probe.trials)}"
    assert 1 in served and served[1].learn_nmse is not None
    assert np.isfinite(served[1].learn_nmse)
    assert float(tenant.params.current) == probe.best.assignment["current"], (
        "smoke: tenant was not served with the probe winner's parameters"
    )
    print(
        f"smoke_washout_autotune,0.0,probed_{len(probe.trials)}"
        f"_tenant_nmse_{served[1].learn_nmse:.3f}"
    )

    # mixed-precision serving end-to-end + the accuracy guardrail: the same
    # sessions served by a bit-exact chunk-impl engine and a "mixed" one
    # (reduced-precision coupling/input GEMMs, f32 state carry) must agree
    # to reduced-precision scale — a broken precision path shows up as a
    # blown tolerance here before it ever reaches a readout benchmark
    precision_sessions = lambda: [
        StreamSession(
            sid=i,
            u_seq=np.random.default_rng(100 + i)
            .uniform(0, 0.5, (8, 1))
            .astype(np.float32),
        )
        for i in range(4)
    ]
    exact_eng = ReservoirEngine(
        compile_plan(spec, ExecPlan(impl="chunk", ensemble=4, chunk_ticks=4))
    )
    mixed_eng = ReservoirEngine(
        compile_plan(
            spec,
            ExecPlan(impl="chunk", ensemble=4, chunk_ticks=4, precision="mixed"),
        )
    )
    exact_r = exact_eng.run(precision_sessions())
    mixed_r = mixed_eng.run(precision_sessions())
    max_dev = max(
        float(np.max(np.abs(exact_r[sid].states - mixed_r[sid].states)))
        for sid in exact_r
    )
    assert max_dev < 5e-3, (
        f"smoke: mixed-precision serve deviates {max_dev:.2e} from f32 — "
        f"the precision guardrail is blown"
    )
    assert all(np.isfinite(r.states).all() for r in mixed_r.values())
    print(f"smoke_serve_mixed,0.0,served_{len(mixed_r)}_maxdev_{max_dev:.1e}")

    loaded = dispatch_table.ensure_loaded()  # 0 if already loaded: fine
    print(f"smoke_dispatch_table,0.0,loaded_{loaded}_entries")

    # quick serving grid to a scratch path so the committed trajectory
    # (BENCH_serve.json) only changes when the full benchmark runs
    out = os.path.join(tempfile.gettempdir(), "BENCH_serve.smoke.json")
    serve_throughput.run(out_path=out, quick=True)

    # perf gates on the WITHIN-RUN ratio columns — never on absolute
    # sessions/sec, which the container's ±40% noise owns (ROADMAP
    # caveat). Both sides of each ratio were measured minutes apart in
    # the same process, so a blown gate is a real regression:
    #   pipelined/sync   >= 1.5 on the quick cells (true ratio >= 3.3;
    #                    the floor leaves the full noise band of slack)
    #   fleet/single     within [0.6, 1.67] of the predicted min(R, cores)
    #                    scaling — the UPPER gate catches measurement bugs
    #                    (e.g. compile time billed to one config only)
    #   planner          predicted-vs-measured drain within 50% after the
    #                    same-run recalibration probe
    import json

    with open(out) as f:
        smoke_bench = json.load(f)
    for c in smoke_bench["cells"]:
        r = c["pipelined_speedup"]
        assert r >= 1.5, (
            f"smoke: pipelined/sync ratio {r:.2f} at N={c['n']} E={c['e']} "
            f"below the 1.5x gate — chunked serving has regressed"
        )
    fl = smoke_bench["fleet"]
    ratio, pred = fl["fleet_speedup"], fl["predicted_speedup"]
    assert 0.6 * pred <= ratio <= 1.67 * pred, (
        f"smoke: fleet/single ratio {ratio:.2f} outside ±40% of the "
        f"predicted {pred:.1f}x (replicas={fl['replicas']}, "
        f"cores={fl['cores']})"
    )
    assert fl["planner_vs_measured_err"] <= 0.5, (
        f"smoke: planner predicted-vs-measured drain error "
        f"{fl['planner_vs_measured_err']:.0%} exceeds the 50% gate"
    )
    # tune leg, armed like the fleet gate (within-run ratios, never
    # absolutes):
    #   vectorized/sequential >= 6.0 (acceptance target is 10x; the floor
    #                         leaves the container's ±40% noise band)
    #   grid winner           identical across lane widths (stable-regime
    #                         grid — see bench_tune)
    tu = smoke_bench["tune"]
    assert tu["tune_speedup"] >= 6.0, (
        f"smoke: vectorized search only {tu['tune_speedup']:.1f}x over "
        f"sequential (budget={tu['budget']}, lanes={tu['lanes']}) — below "
        f"the 6x gate; lane-vectorized tuning has regressed"
    )
    assert tu["grid_winner_match"], (
        f"smoke: grid search winner changed with lane width "
        f"({tu['grid_winner']}) — vectorized fitness is off"
    )
    # compile-path gates (process-wide PlanCache). Unlike the throughput
    # ratios these are NOT noise-limited: the warm side of each ratio is a
    # dictionary hit and the "zero new compiles" assertions read the
    # cache's own counters, so the gates are exact.
    #   warm construction >= 5x cold   (quick grid's compile section; the
    #                                  true ratio is ~1000x — 5x leaves
    #                                  room for a pathologically slow host)
    #   warm construction compiles     exactly zero (counter, not timing)
    #   warm autoscale rescale         zero new XLA compiles after the
    #                                  adjacent buckets were pre-warmed
    from repro.api import PLAN_CACHE

    co = smoke_bench["compile"]
    assert co["cold_compiles"] >= 1, (
        "smoke: compile bench's cold probe never compiled — the probe "
        "spec collides with an earlier section's cache entry"
    )
    assert co["warm_compiles"] == 0, (
        f"smoke: warm construction recompiled ({co['warm_compiles']}x) — "
        f"the PlanCache key is unstable across identical requests"
    )
    assert co["warm_speedup"] >= 5.0, (
        f"smoke: warm engine construction only {co['warm_speedup']:.1f}x "
        f"faster than cold ({co['cold_s']:.2f}s -> {co['warm_s']:.4f}s) — "
        f"below the 5x gate; the plan cache has regressed"
    )
    spec_rs = make_spec(n=16, n_in=1, hold_steps=5, seed=81_001,
                        dtype=jnp.float32)
    eng_rs = ReservoirEngine(
        compile_plan(spec_rs, ExecPlan(ensemble=4, chunk_ticks=4)),
        autoscale=True, min_slots=2, max_slots=8,
    )
    # warm current width + adjacent buckets synchronously: 2, 4 and 8 are
    # now all warm-marked, so neither the rescale nor its trailing
    # background pre-warm round has any compile left to race the counter
    eng_rs.prewarm(block=True)
    compiles_before = PLAN_CACHE.stats.compiles
    eng_rs._rescale(8)
    rescale_compiles = PLAN_CACHE.stats.compiles - compiles_before
    st = eng_rs.stats()
    assert rescale_compiles == 0, (
        f"smoke: rescale into a pre-warmed bucket triggered "
        f"{rescale_compiles} XLA compile(s) — zero-stall autoscale is "
        f"broken"
    )
    assert st.cold_rescales == 0 and st.warm_rescales >= 1, (
        f"smoke: pre-warmed rescale accounted as cold "
        f"(cold={st.cold_rescales}, warm={st.warm_rescales})"
    )
    print(
        f"smoke_compile_gates,0.0,warm_{co['warm_speedup']:.0f}x"
        f"_rescale_compiles_{rescale_compiles}"
    )
    # revisiting-structural tune gate: the same CMA-ES search over a
    # structural knob run twice — the second run draws every per-combo
    # CompiledSim out of the shared PlanCache (zero compiles), must be
    # >= 2x faster wall-clock, and must reproduce the first run's trial
    # fitnesses bit-for-bit (cached engines are the same executables)
    import time as _time

    from repro.tune import Choice, Float, SearchSpace, narma_task, tune_spec

    tune_task = narma_task(48, order=10, seed=5)
    revisit_space = SearchSpace({
        "drive_current": Float(0.5e-3, 4.5e-3),
        "hold_steps": Choice((4, 6)),
    })
    revisit_plan = ExecPlan(impl="scan", ensemble=4, chunk_ticks=4,
                            learn="rls")

    def _revisit():
        t0 = _time.perf_counter()
        res = tune_spec(
            make_spec(n=16, n_in=1, hold_steps=5, seed=82_001,
                      dtype=jnp.float32),
            tune_task, revisit_space, budget=8, plan=revisit_plan,
            strategy="cmaes", seed=4,
        )
        return _time.perf_counter() - t0, res

    compiles_before = PLAN_CACHE.stats.compiles
    t_first, res_first = _revisit()
    first_compiles = PLAN_CACHE.stats.compiles - compiles_before
    t_second, res_second = _revisit()
    second_compiles = PLAN_CACHE.stats.compiles - compiles_before - first_compiles
    assert second_compiles == 0, (
        f"smoke: revisiting tune run recompiled {second_compiles} "
        f"structural combo(s) the first run already cached"
    )
    fits_first = [t.fitness for t in res_first.trials]
    fits_second = [t.fitness for t in res_second.trials]
    assert fits_first == fits_second, (
        "smoke: revisiting tune run's fitnesses differ from the first — "
        "cached engines are not bit-identical to fresh compiles"
    )
    tune_revisit_speedup = t_first / max(t_second, 1e-9)
    assert tune_revisit_speedup >= 2.0, (
        f"smoke: revisiting structural tune only {tune_revisit_speedup:.1f}x "
        f"faster ({t_first:.2f}s -> {t_second:.2f}s, first run compiled "
        f"{first_compiles}) — below the 2x gate"
    )
    print(
        f"smoke_tune_revisit,0.0,speedup_{tune_revisit_speedup:.1f}x"
        f"_combo_compiles_{first_compiles}_then_{second_compiles}"
    )
    # chaos gate: a fleet drain with one replica crashed mid-stream must
    # lose zero sessions and return every output — states, predictions,
    # learned readout weights — bit-identical to an unfaulted fleet. A
    # within-run correctness gate (no timings), so container noise cannot
    # touch it; the crash is injected deterministically via FaultPlan.
    from repro.serve.fleet import Fault, FaultPlan, FleetRouter, LocalReplica

    chaos_kw = dict(n=16, num_slots=4, hold_steps=5, seed=83_001,
                    backend="scan", chunk_ticks=4, learn="rls")
    chaos_rng = np.random.default_rng(9)

    def _chaos_sessions():
        out = []
        for i in range(6):
            u = chaos_rng.uniform(0, 0.5, (18, 1)).astype(np.float32)
            y = chaos_rng.uniform(0, 0.5, (18, 1)).astype(np.float32)
            out.append((i, u, y))
        return out

    chaos_streams = _chaos_sessions()

    def _chaos_drain(faulted: bool):
        router = FleetRouter(checkpoint_every=2)
        plan = FaultPlan((Fault("crash", at_chunk=3),)) if faulted else None
        router.add_replica(
            LocalReplica(faults=plan, **chaos_kw),
            respawn=lambda: LocalReplica(**chaos_kw),
        )
        router.add_replica(LocalReplica(**chaos_kw))
        for sid, u, y in chaos_streams:
            router.submit(chaos_kw["n"], StreamSession(
                sid=sid, u_seq=u.copy(), targets=y.copy(), learn_washout=2))
        try:
            results = router.drain()
            return results, router.fault_stats()
        finally:
            router.close()

    chaos_clean, _ = _chaos_drain(faulted=False)
    chaos_hit, chaos_faults = _chaos_drain(faulted=True)
    assert chaos_faults["replica_deaths"] == 1, (
        "smoke: the injected replica crash never fired"
    )
    assert chaos_faults["failovers"] == 1 and chaos_faults["sessions_lost"] == 0, (
        f"smoke: chaos drain lost sessions "
        f"(failovers={chaos_faults['failovers']}, "
        f"lost={chaos_faults['sessions_lost']})"
    )
    assert sorted(chaos_hit) == sorted(chaos_clean)
    for sid in chaos_clean:
        assert np.array_equal(chaos_hit[sid].states, chaos_clean[sid].states), (
            f"smoke: recovered session {sid} states deviate from the "
            f"unfaulted fleet — failover is not bit-exact"
        )
        assert np.array_equal(
            chaos_hit[sid].predictions, chaos_clean[sid].predictions
        ), f"smoke: recovered session {sid} predictions deviate"
        assert np.array_equal(
            np.asarray(chaos_hit[sid].learned_readout.w_out),
            np.asarray(chaos_clean[sid].learned_readout.w_out),
        ), f"smoke: recovered session {sid} learned weights deviate"
    print(
        f"smoke_chaos,0.0,crashed_1_recovered_"
        f"{chaos_faults['sessions_recovered']}_lost_"
        f"{chaos_faults['sessions_lost']}_replayed_"
        f"{chaos_faults['replayed_ticks']}_bitmatch_clean"
    )
    print(
        f"smoke_perf_gates,0.0,pipelined_min_"
        f"{min(c['pipelined_speedup'] for c in smoke_bench['cells']):.1f}x"
        f"_fleet_{ratio:.2f}x_planner_err_"
        f"{fl['planner_vs_measured_err']:.0%}"
        f"_tune_{tu['tune_speedup']:.1f}x"
        f"_revisit_{tune_revisit_speedup:.1f}x"
    )
    if save_dispatch_table:
        _save_dispatch_table(out)


def main(save_dispatch_table: bool = False) -> None:
    from benchmarks import (
        fig2_vectorfield,
        reservoir_tasks,
        roofline_lm,
        serve_throughput,
        table2_timing,
        table3_factors,
    )

    print("name,us_per_call,derived")
    fig2_vectorfield.run()
    _, per_step = table2_timing.run()
    table3_factors.run(per_step=per_step)
    reservoir_tasks.run()
    roofline_lm.run()
    # serving-perf trajectory: sessions/sec + ticks/sec over the (N, E) grid,
    # persisted to BENCH_serve.json for PR-over-PR comparison
    serve_throughput.run()
    if save_dispatch_table:
        _save_dispatch_table("BENCH_serve.json")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="fast CI subset: quick serving grid + unified-API compile/drive",
    )
    ap.add_argument(
        "--save-dispatch-table",
        action="store_true",
        help="after the run, persist measured dispatch choices for this "
        "platform via kernels.dispatch_table.save_table() (commit the "
        "resulting dispatch_table.<platform>.json from a GPU/TPU host)",
    )
    args = ap.parse_args()
    if args.smoke:
        smoke(save_dispatch_table=args.save_dispatch_table)
    else:
        main(save_dispatch_table=args.save_dispatch_table)
