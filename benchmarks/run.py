"""Benchmark harness: one module per paper table/figure + the LM roofline.

Prints ``name,us_per_call,derived`` CSV rows (shared format). Individual
modules run standalone too:  python -m benchmarks.table2_timing
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import (
        fig2_vectorfield,
        reservoir_tasks,
        roofline_lm,
        serve_throughput,
        table2_timing,
        table3_factors,
    )

    print("name,us_per_call,derived")
    fig2_vectorfield.run()
    _, per_step = table2_timing.run()
    table3_factors.run(per_step=per_step)
    reservoir_tasks.run()
    roofline_lm.run()
    # serving-perf trajectory: sessions/sec + ticks/sec over the (N, E) grid,
    # persisted to BENCH_serve.json for PR-over-PR comparison
    serve_throughput.run()


if __name__ == "__main__":
    main()
