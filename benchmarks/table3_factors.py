"""Paper Table 3: speed factor vs base (factor = t_base / t_method).

Derived from table2 per-step timings. The paper's qualitative claims we
check: (1) compiled-sequential beats per-step dispatch most at SMALL N
(factor O(10)); (2) the advantage decreases as N grows and the O(N^2)
matmul dominates both; (3) best factor >= 2.6 across the N range.
"""

from __future__ import annotations

from benchmarks.common import csv_row
from benchmarks import table2_timing


def run(print_fn=print, per_step=None):
    if per_step is None:
        _, per_step = table2_timing.run(print_fn=lambda *_: None)
    rows = []
    best_factors = {}
    for (method, n), t in sorted(per_step.items()):
        if method == "base":
            continue
        base = per_step.get(("base", n))
        if base is None:
            continue
        f = base / t
        best_factors[n] = max(best_factors.get(n, 0.0), f)
        rows.append(csv_row(f"table3_factor_{method}_n{n}", f, "t_base/t_method"))
        print_fn(rows[-1])
    if best_factors:
        worst_best = min(best_factors.values())
        rows.append(csv_row("table3_min_best_factor", worst_best,
                            "paper_claims_>=2.6"))
        print_fn(rows[-1])
    return rows


if __name__ == "__main__":
    run()
