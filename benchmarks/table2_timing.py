"""Paper Table 2: integration wall-time across implementations x N.

The paper's ladder maps to ours (DESIGN.md §2):
    numpy-base      -> base      per-step jit dispatched from Python
    numba-vanilla   -> scan      jit + lax.scan whole trajectory
    numba-parallel  -> (scan is already vectorized; the sharded variant
                        needs >1 device and is covered by dry-run/tests)
    torch-gpu       -> kernel    fused Pallas step (interpret=True on CPU:
                        correctness-path; MXU path on real TPU)

Wall-times are measured per RK4 step on this container's CPU and reported
as us/step; the paper's 5e5-step total = us/step * 5e5. Steps are scaled
down (the paper's protocol at N=1e4 runs ~minutes/implementation; the
relative ladder is what reproduces).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import (
    DT,
    default_params,
    initial_magnetization,
    integrate_python_loop,
    integrate_scan,
    llg_field,
    make_coupling_matrix,
)
from repro.kernels import ops
from repro.kernels.ref import pack_params

NS = [1, 10, 100, 1000, 2500]
SCAN_STEPS = 200
BASE_STEPS = 50
KERNEL_STEPS = 16  # interpret mode is a Python emulation: keep it short
KERNEL_NS = [1, 10, 100]


def run(print_fn=print):
    p = default_params(jnp.float32)
    rows = []
    per_step = {}
    for n in NS:
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = initial_magnetization(n, jnp.float32)
        field = lambda m, _: llg_field(m, p, w)

        # base: per-step dispatch (paper's numpy-base analogue)
        t_base = time_fn(
            lambda: integrate_python_loop(field, m0, DT, BASE_STEPS), reps=3
        ) / BASE_STEPS

        # scan: whole-trajectory compile
        scan_fn = jax.jit(
            lambda m: integrate_scan(field, m, DT, SCAN_STEPS)[0]
        )
        t_scan = time_fn(scan_fn, m0, reps=3) / SCAN_STEPS

        per_step[("base", n)] = t_base
        per_step[("scan", n)] = t_scan
        rows.append(csv_row(f"table2_base_n{n}", t_base * 1e6,
                            f"total_5e5_steps_{t_base*5e5:.1f}s"))
        rows.append(csv_row(f"table2_scan_n{n}", t_scan * 1e6,
                            f"total_5e5_steps_{t_scan*5e5:.1f}s"))
        print_fn(rows[-2])
        print_fn(rows[-1])

    # fused kernel (interpret mode: correctness path, not TPU wall-clock)
    for n in KERNEL_NS:
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m0 = initial_magnetization(n, jnp.float32)[None]
        pv = pack_params(p, 1, jnp.float32)
        kern_fn = jax.jit(
            lambda m: ops.sto_rk4_integrate(
                m, w, pv, float(DT), KERNEL_STEPS, impl="fused", n_inner=8,
                interpret=True,
            )
        )
        t_kern = time_fn(kern_fn, m0, reps=2) / KERNEL_STEPS
        per_step[("kernel", n)] = t_kern
        rows.append(csv_row(f"table2_kernel-interp_n{n}", t_kern * 1e6,
                            "interpret_mode_not_tpu_wallclock"))
        print_fn(rows[-1])
    return rows, per_step


if __name__ == "__main__":
    run()
