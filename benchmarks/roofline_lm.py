"""Roofline summary for the assigned (arch x shape) cells: reads the JSON
artifacts produced by launch/dryrun.py + launch/roofline.py and emits the
per-cell terms as CSV (also the source of the EXPERIMENTS.md table)."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import csv_row

ROOT = Path(__file__).resolve().parents[1] / "experiments"


def run(print_fn=print):
    rows = []
    roof = sorted((ROOT / "roofline").glob("*.json")) if (ROOT / "roofline").exists() else []
    for f in roof:
        r = json.loads(f.read_text())
        name = f"roofline_{r['arch']}_{r['shape']}"
        dom = r["dominant"]
        t_dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        rows.append(
            csv_row(name, t_dom * 1e6,
                    f"dominant={dom};roofline={r['roofline_fraction']:.3f};"
                    f"useful={r['useful_ratio']:.3f}")
        )
        print_fn(rows[-1])
    dr = sorted((ROOT / "dryrun").glob("*.json")) if (ROOT / "dryrun").exists() else []
    ok = sum(1 for _ in dr)
    rows.append(csv_row("dryrun_cells_compiled", ok, "json_artifacts"))
    print_fn(rows[-1])
    return rows


if __name__ == "__main__":
    run()
