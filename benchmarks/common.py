"""Shared benchmark utilities: timing, CSV output."""

from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, reps: int = 3, warmup: int = 1) -> float:
    """Median wall time (s) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.2f},{derived}"
