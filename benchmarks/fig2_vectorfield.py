"""Paper Figure 2: vector-field evaluation time vs N is O(N^2).

Times a single LLG field evaluation (with coupling) for random m across N,
fits the log-log slope, and emits CSV. The paper's figure shows the same
quadratic growth for its NumPy implementation.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import csv_row, time_fn
from repro.core import default_params, llg_field, make_coupling_matrix

NS = [64, 128, 256, 512, 1024, 2048, 4096]


def run(print_fn=print):
    p = default_params(jnp.float32)
    rows, times = [], []
    for n in NS:
        w = jnp.asarray(make_coupling_matrix(n, seed=0), jnp.float32)
        m = jax.random.normal(jax.random.PRNGKey(0), (n, 3), jnp.float32)
        m = m / jnp.linalg.norm(m, axis=-1, keepdims=True)
        f = jax.jit(lambda mm: llg_field(mm, p, w))
        t = time_fn(f, m, reps=5, warmup=2)
        times.append(t)
        rows.append(csv_row(f"fig2_field_eval_n{n}", t * 1e6, "o_n2_scaling"))
        print_fn(rows[-1])
    # log-log slope over the largest Ns (small Ns are overhead-dominated)
    slope = np.polyfit(np.log(NS[-4:]), np.log(times[-4:]), 1)[0]
    rows.append(csv_row("fig2_loglog_slope", slope, "expect_~2_quadratic"))
    print_fn(rows[-1])
    return rows


if __name__ == "__main__":
    run()
