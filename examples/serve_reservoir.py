"""Multi-tenant reservoir serving quickstart.

Three tenants share one slot-batched engine, each with their OWN task,
trained readout, and physical device parameters (drive current) — the
scenario-diversity the engine exists for:

  tenant 0: NARMA-2 system identification
  tenant 1: sine approximation (nonlinear map of the integrated input)
  tenant 2: delay-line memory (u[t-2]) at a different drive current

Each tenant's readout is trained offline with CompiledSim.drive +
fit_ridge (the unified execution API), then the engine streams fresh
inputs through all tenants concurrently — pipelined in chunks of
`chunk_ticks=8` input ticks, so one batched RK4 dispatch (and one bulk
device->host transfer) covers 8 ticks of every session. Outputs are
checked against running each stream solo.

Run:  PYTHONPATH=src python examples/serve_reservoir.py
"""

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan, make_spec
from repro.core import fit_ridge, nmse, predict, tasks
from repro.serve.reservoir import ReservoirEngine, StreamSession

N = 64
HOLD = 20
T_TRAIN = 400
T_SERVE = 120
WASHOUT = 40


def train_readout(sim, u, y):
    _, states = sim.drive(jnp.asarray(u[:, None], jnp.float32))
    return fit_ridge(
        states, jnp.asarray(y[:, None], jnp.float32), washout=WASHOUT, reg=1e-6
    )


def main():
    spec = make_spec(n=N, n_in=1, hold_steps=HOLD, dtype=jnp.float32)
    sim = compile_plan(spec, impl="scan")
    hot_params = spec.params._replace(current=jnp.asarray(4e-3, jnp.float32))
    hot_sim = compile_plan(spec._replace(params=hot_params), impl="scan")

    # --- offline: each tenant trains a readout for its task ---------------
    u_n, y_n = tasks.narma_series(T_TRAIN, order=2, seed=0)
    ro_narma = train_readout(sim, u_n, y_n)

    u_s, y_s = tasks.sine_task(T_TRAIN, seed=1)
    ro_sine = train_readout(sim, u_s, y_s)

    rng = np.random.default_rng(2)
    u_d = rng.uniform(0.0, 0.5, T_TRAIN)
    y_d = tasks.delay_memory_targets(u_d, max_delay=2)[:, 1]  # u[t-2]
    ro_delay = train_readout(hot_sim, u_d, y_d)

    # --- online: stream the tasks through the shared engine ---------------
    # (a 64-node reservoir has little out-of-sample skill — the point here
    # is the serving mechanics, so we stream the tasks the readouts know)
    u1, y1 = u_n[:T_SERVE], y_n[:T_SERVE]
    u2, y2 = u_s[:T_SERVE], y_s[:T_SERVE]
    u3, y3 = u_d[:T_SERVE], y_d[:T_SERVE]

    sessions = [
        StreamSession(sid=0, u_seq=u1.astype(np.float32)[:, None], readout=ro_narma),
        StreamSession(sid=1, u_seq=u2.astype(np.float32)[:, None], readout=ro_sine),
        StreamSession(
            sid=2, u_seq=u3.astype(np.float32)[:, None], readout=ro_delay,
            params=hot_params,
        ),
    ]

    eng = ReservoirEngine(compile_plan(spec, ensemble=4, chunk_ticks=8))
    results = eng.run(sessions)
    print(f"backend={eng.backend}  slots=4  chunk_ticks={eng.chunk_ticks}  "
          f"tenants={len(results)}")

    for sid, (tenant_sim, ro, y) in {
        0: (sim, ro_narma, y1), 1: (sim, ro_sine, y2), 2: (hot_sim, ro_delay, y3)
    }.items():
        r = results[sid]
        err = nmse(r.outputs, jnp.asarray(y[WASHOUT:, None], jnp.float32))
        # solo check: the same stream alone gives the same outputs
        u = sessions[sid].u_seq
        _, states = tenant_sim.drive(jnp.asarray(u))
        solo = predict(ro, states)
        dev = float(jnp.max(jnp.abs(r.outputs - solo)))
        print(f"  tenant {sid}: NMSE={err:.3f}  |engine - solo|={dev:.2e}")
        assert dev < 1e-3
    print("OK")


if __name__ == "__main__":
    main()
