"""Online learning while serving: tenants train readouts mid-stream.

Where examples/serve_reservoir.py trains every readout OFFLINE
(drive + fit_ridge) before serving, this demo closes the loop on device:
three tenants stream NARMA-2 inputs WITH targets through a learning engine
(`ExecPlan(learn="rls")`), and the engine fuses one recursive-least-
squares readout update per tick into the same chunked dispatch that
integrates the physics — no host round-trips, no offline training pass.

What it shows:

  - per-tenant online learning: each tenant learns its own readout in its
    own slot lane, concurrently, with per-tick a-priori predictions and an
    online NMSE reported on the SessionResult
  - RLS(lam=1) == ridge: the streamed readout evaluates within a whisker
    of a batch fit_ridge readout trained on the same states
  - the offline oracle: core.fit_rls(states, targets, block=chunk_ticks)
    reproduces the streamed weights bit-for-bit (scan backend)
  - adaptation: a forgetting factor lam < 1 tracks a mid-stream target
    flip that lam = 1 averages over (run in float64 — aggressive
    forgetting over long streams of correlated reservoir states is
    numerically delicate in f32; see the note in kernels/rls.py)

Run:  PYTHONPATH=src python examples/serve_online_learning.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import ExecPlan, compile_plan, make_spec
from repro.core import default_params, fit_ridge, fit_rls, nmse, predict, tasks

from repro.serve.reservoir import ReservoirEngine, StreamSession

N = 48
HOLD = 20
T_TRAIN = 500
T_TEST = 150
WASHOUT = 60
CHUNK = 8
REG = 1e-2


def main():
    params = default_params(jnp.float32)._replace(a_in=jnp.float32(300.0))
    spec = make_spec(n=N, n_in=1, hold_steps=HOLD, dtype=jnp.float32, params=params)
    eng = ReservoirEngine(
        compile_plan(
            spec,
            ExecPlan(impl="scan", ensemble=4, chunk_ticks=CHUNK,
                     learn="rls", learn_reg=REG),
        )
    )

    # --- three learners stream NARMA-2 with targets -----------------------
    sessions, series = [], {}
    for sid in range(3):
        u, y = tasks.narma_series(T_TRAIN + T_TEST, order=2, seed=sid)
        u = u.astype(np.float32)[:, None]
        y = y.astype(np.float32)[:, None]
        series[sid] = (u, y)
        sessions.append(
            StreamSession(
                sid=sid, u_seq=u[:T_TRAIN], targets=y[:T_TRAIN],
                learn_washout=WASHOUT,
            )
        )
    results = eng.run(sessions)

    print(f"{'tenant':>6} {'online NMSE':>12} {'test NMSE':>10} "
          f"{'ridge test':>10} {'oracle bit-match':>16}")
    for sid, r in sorted(results.items()):
        u, y = series[sid]
        # held-out evaluation: resume the reservoir state, apply the
        # readout the tenant learned WHILE streaming
        sim = compile_plan(spec, impl="scan")
        _, test_states = sim.drive(jnp.asarray(u[T_TRAIN:]), m0=r.final_m)
        err = nmse(predict(r.learned_readout, test_states),
                   jnp.asarray(y[T_TRAIN:]))
        # batch ridge on the same streamed states: the offline ceiling
        ridge = fit_ridge(r.states, y[:T_TRAIN], washout=WASHOUT, reg=REG)
        err_ridge = nmse(predict(ridge._replace(washout=0), test_states),
                         jnp.asarray(y[T_TRAIN:]))
        # the offline oracle reproduces the streamed weights exactly
        oracle = fit_rls(r.states, y[:T_TRAIN], washout=WASHOUT, reg=REG,
                         block=CHUNK)
        match = bool(
            np.array_equal(np.asarray(r.learned_readout.w_out),
                           np.asarray(oracle.w_out))
        )
        print(f"{sid:>6} {r.learn_nmse:>12.4f} {float(err):>10.4f} "
              f"{float(err_ridge):>10.4f} {str(match):>16}")
        assert match, "streamed readout must bit-match the fit_rls oracle"
        assert float(err) < 1.0, "learned readout must beat the mean predictor"

    # --- forgetting: track a mid-stream target flip (float64) -------------
    # the delay-1 target flips sign halfway through the stream: a lam = 1
    # learner converges to the average of both regimes (exactly the wrong
    # sign for the tail), lam < 1 re-converges to the new regime
    params64 = default_params(jnp.float64)._replace(a_in=jnp.float64(300.0))
    spec64 = make_spec(
        n=N, n_in=1, hold_steps=HOLD, dtype=jnp.float64, params=params64
    )
    half = 300
    rng = np.random.default_rng(9)
    u = rng.uniform(0.0, 0.5, (2 * half, 1))
    y1 = tasks.delay_memory_targets(u[:, 0], max_delay=1)[:, :1]
    y = np.concatenate([y1[:half], -y1[half:]])
    tail = slice(2 * half - 150, 2 * half)
    errs = {}
    for lam in (1.0, 0.98):
        eng_l = ReservoirEngine(
            spec64, num_slots=1, backend="scan", chunk_ticks=CHUNK,
            learn="rls", learn_lam=lam, learn_reg=REG,
        )
        r = eng_l.run(
            [StreamSession(sid=0, u_seq=u, targets=y, learn_washout=WASHOUT)]
        )[0]
        errs[lam] = float(
            nmse(jnp.asarray(r.predictions[tail]), jnp.asarray(y[tail]))
        )
    print(f"\nsign-flipped target, last-150-tick NMSE: "
          f"lam=1.0 -> {errs[1.0]:.4f}   lam=0.98 -> {errs[0.98]:.4f}")
    assert errs[0.98] < errs[1.0], "forgetting must track the flip better"
    print("OK")


if __name__ == "__main__":
    main()
