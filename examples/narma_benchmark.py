"""End-to-end reservoir computing (the paper's application, cf. [AKT+22]):

  NARMA input series -> drive N-coupled STO reservoir -> ridge readout
  -> NMSE on held-out data.

This is the full pipeline whose expensive stage (the drive) the paper
accelerates, built on the unified execution API (make_spec + compile_plan;
docs/ARCHITECTURE.md). A few hundred reservoir updates train the readout
end-to-end. `--online` additionally trains the readout with recursive
least squares (`fit_rls` — the offline form of the serving engine's
streaming `ExecPlan.learn="rls"`) and shows it matches batch ridge.

Run:  PYTHONPATH=src python examples/narma_benchmark.py [--n 64] [--order 2]
      [--online]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import compile_plan, make_spec
from repro.core import default_params, fit_ridge, fit_rls, nmse, predict, tasks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48, help="reservoir nodes")
    ap.add_argument("--order", type=int, default=2, help="NARMA order")
    ap.add_argument("--train", type=int, default=700)
    ap.add_argument("--test", type=int, default=200)
    ap.add_argument("--washout", type=int, default=100)
    ap.add_argument("--hold", type=int, default=50, help="RK4 steps per sample")
    ap.add_argument("--a-in", type=float, default=300.0,
                    help="input amplitude [Oe]; the paper's 1 Oe is for the "
                         "u=0 benchmark — the RC application needs a strong "
                         "drive relative to H_appl=200 Oe (cf. [AKT+22])")
    ap.add_argument("--online", action="store_true",
                    help="also train the readout online (recursive least "
                         "squares, one update per sample) and compare to "
                         "batch ridge")
    args = ap.parse_args()

    total = args.train + args.test
    u, y = tasks.narma_series(total, order=args.order, seed=0)
    params = default_params(jnp.float64)._replace(a_in=jnp.float64(args.a_in))
    spec = make_spec(
        n=args.n, n_in=1, hold_steps=args.hold, dtype=jnp.float64, params=params
    )
    sim = compile_plan(spec, impl="scan")
    print(f"driving N={args.n} reservoir over {total} samples "
          f"({total * args.hold} RK4 steps)...")
    _, states = sim.drive(jnp.asarray(u[:, None]))
    # readout features: node states + their squares + the raw input
    # (standard for STO reservoirs; the readout stays linear-in-features)
    feats = jnp.concatenate(
        [states, states**2, jnp.asarray(u[:, None])], axis=1
    )

    tr = slice(0, args.train)
    te = slice(args.train, total)
    ro = fit_ridge(feats[tr], jnp.asarray(y[tr, None]), washout=args.washout, reg=1e-2)
    err_tr = nmse(predict(ro, feats[tr]), jnp.asarray(y[args.washout : args.train, None]))

    # test: reuse the same readout on unseen samples (washout=0: reservoir
    # state is already warmed up)
    pred_te = predict(ro._replace(washout=0), feats[te])
    err_te = nmse(pred_te, jnp.asarray(y[te][:, None]))
    print(f"NARMA-{args.order}: train NMSE = {err_tr:.4f}   test NMSE = {err_te:.4f}")
    assert err_te < 1.0, "reservoir must beat the mean predictor"

    if args.online:
        # recursive least squares over the same features: one update per
        # sample, converging to the batch ridge solution (lam = 1) — the
        # offline form of what the serving engine fuses into tick_chunk
        ro_rls = fit_rls(
            feats[tr], jnp.asarray(y[tr, None]), washout=args.washout, reg=1e-2
        )
        err_rls = nmse(
            predict(ro_rls._replace(washout=0), feats[te]),
            jnp.asarray(y[te][:, None]),
        )
        print(f"online RLS:   test NMSE = {err_rls:.4f}  "
              f"(batch ridge: {err_te:.4f})")
        assert err_rls < err_te * 1.05, "RLS(lam=1) must match batch ridge"
    print("OK")


if __name__ == "__main__":
    main()
