"""Batched serving demo: prefill a batch of prompts, then decode tokens
step-by-step with per-sequence KV caches (the serve path the decode_32k /
long_500k dry-run cells lower at production shapes).

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch h2o-danube-1.8b]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.models import build_model, transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = reduce_config(get_config(args.arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    print(f"{cfg.name}: prefill {args.batch} x {args.prompt_len}, "
          f"decode {args.gen} tokens")

    last_logits, caches = m.prefill(params, {"tokens": prompts})
    capacity = args.prompt_len + args.gen
    caches = transformer.pad_caches(cfg, caches, capacity)

    decode = jax.jit(m.decode_step)
    tok = jnp.argmax(last_logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
    out = [tok]
    for i in range(args.gen - 1):
        pos = jnp.full((args.batch,), args.prompt_len + i, jnp.int32)
        logits, caches = decode(params, tok.astype(jnp.int32), caches, pos)
        tok = jnp.argmax(logits[:, -1, : cfg.vocab_size], axis=-1)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    for b in range(args.batch):
        print(f"  seq{b}: prompt[-5:]={list(map(int, prompts[b,-5:]))} "
              f"-> gen={list(map(int, gen[b]))}")
    assert gen.shape == (args.batch, args.gen)
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab_size))
    print("OK")


if __name__ == "__main__":
    main()
