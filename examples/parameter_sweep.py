"""Ensemble parameter sweep — the paper's motivating workload (§2: "finding
optimal physical parameters ... is a time-consuming effort").

Sweeps the drive current I across an ensemble of E reservoirs SIMULTANEOUSLY
through the unified execution API: one SimSpec carrying the swept (E, 1)
parameter leaves, compiled against an ExecPlan of width E. On TPU the
coupling becomes an (N x N) @ (N x E) MXU matmul instead of E sequential
mat-vecs (DESIGN.md §2.1). Reports a per-member signal-variance proxy for
dynamic richness.

Run:  PYTHONPATH=src python examples/parameter_sweep.py [--n 32] [--e 8]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import SimSpec, compile_plan
from repro.core import (
    DT,
    broadcast_params,
    default_params,
    initial_magnetization,
    make_coupling_matrix,
    norm_error,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--e", type=int, default=8)
    ap.add_argument("--steps", type=int, default=3000)
    args = ap.parse_args()

    currents = np.linspace(0.5e-3, 4.5e-3, args.e)
    base = default_params(jnp.float64)
    pe = broadcast_params(base, args.e, current=jnp.asarray(currents))
    w = jnp.asarray(make_coupling_matrix(args.n, seed=0), jnp.float64)
    m0 = jnp.broadcast_to(
        initial_magnetization(args.n, jnp.float64), (args.e, args.n, 3)
    )

    print(f"sweeping I over {args.e} ensemble members x N={args.n} oscillators")
    spec = SimSpec(
        params=pe, w_cp=w, w_in=jnp.zeros((args.n, 1), jnp.float64),
        m0=m0[0], dt=DT, hold_steps=1,
    )
    sim = compile_plan(spec, impl="scan", ensemble=args.e)
    mT, traj = sim.integrate(args.steps, m0=m0, save_every=args.steps // 50)
    assert float(norm_error(mT)) < 1e-5

    print(f"{'I [mA]':>8s} {'var(m^x)':>10s} {'mean osc amp':>13s}")
    for i, cur in enumerate(currents):
        mx = np.asarray(traj[:, i, :, 0])  # (T, N)
        var = float(mx.var())
        amp = float(np.mean(mx.max(0) - mx.min(0)))
        print(f"{cur*1e3:8.2f} {var:10.4f} {amp:13.4f}")
    print("OK")


if __name__ == "__main__":
    main()
