"""Ensemble parameter sweep — the paper's motivating workload (§2: "finding
optimal physical parameters ... is a time-consuming effort") — expressed on
the tune API.

The sweep IS a hyperparameter search: a grid over the drive current I,
evaluated lane-vectorized through `repro.tune.tune_spec` — E grid points
ride the ensemble lanes of ONE CompiledSim (on TPU the coupling becomes an
(N x N) @ (N x E) MXU matmul instead of E sequential mat-vecs, DESIGN.md
§2.1). Fitness is a signal-variance proxy for dynamic richness computed
from each candidate's streamed states (a TuneTask `score` callback — no
targets, no learner). The `--baseline` flag re-runs the same grid with
ensemble=1 (one candidate per pass — the sequential sweep this example
used to hand-roll) and reports the wall-clock ratio.

Run:  PYTHONPATH=src python examples/parameter_sweep.py [--n 32] [--e 8]
"""

import argparse

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.api import ExecPlan, SimSpec
from repro.core import DT, default_params, initial_magnetization, make_coupling_matrix, norm_error
from repro.tune import Choice, SearchSpace, TuneTask, tune_spec


def richness(result) -> float:
    """Fitness (minimized): negative variance of the streamed m^x states —
    higher variance = richer dynamics = better sweep point."""
    return -float(np.var(np.asarray(result.states)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=32)
    ap.add_argument("--e", type=int, default=8, help="grid points = lanes per pass")
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--baseline", action="store_true",
                    help="also run the ensemble=1 sequential sweep and report the ratio")
    args = ap.parse_args()

    currents = np.linspace(0.5e-3, 4.5e-3, args.e)
    spec = SimSpec(
        params=default_params(jnp.float64),
        w_cp=jnp.asarray(make_coupling_matrix(args.n, seed=0), jnp.float64),
        w_in=jnp.zeros((args.n, 1), jnp.float64),
        m0=initial_magnetization(args.n, jnp.float64),
        dt=DT, hold_steps=1,
    )
    space = SearchSpace({"drive_current": Choice([float(c) for c in currents])})
    task = TuneTask(
        u_seq=np.zeros(args.steps),  # autonomous dynamics: w_in is zero anyway
        score=richness,
        name="richness",
    )

    print(f"sweeping I over {args.e} grid points x N={args.n} oscillators "
          f"({args.steps} ticks each), all lanes in one pass")
    result = tune_spec(
        spec, task, space,
        budget=args.e,
        plan=ExecPlan(impl="scan", ensemble=args.e, chunk_ticks=64),
        strategy="grid",
    )

    print(f"{'I [mA]':>8s} {'var(m^x)':>10s}")
    for trial in result.trials:
        print(f"{trial.assignment['current']*1e3:8.2f} {-trial.fitness:10.4f}")
    best = result.best
    print(f"best: I = {best.assignment['current']*1e3:.2f} mA "
          f"(var {-best.fitness:.4f})  [{result.wall_s:.2f} s]")

    if args.baseline:
        seq = tune_spec(
            spec, task, space,
            budget=args.e,
            plan=ExecPlan(impl="scan", ensemble=1, chunk_ticks=64),
            strategy="grid",
        )
        assert seq.best.assignment == best.assignment, "winner must not depend on lane width"
        print(f"sequential sweep (ensemble=1): {seq.wall_s:.2f} s "
              f"-> vectorized speedup {seq.wall_s / max(result.wall_s, 1e-9):.1f}x")
    print("OK")


if __name__ == "__main__":
    main()
