"""End-to-end LM training driver on the shared substrate (data pipeline ->
model -> optimizer -> checkpoint/restart).

Defaults are CPU-sized (a reduced config, a few hundred steps). On real
hardware the same command trains the full configs, e.g.:

    python examples/train_lm.py --arch xlstm-125m --full --steps 300 \
        --batch 64 --seq 1024

Run (CPU):  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.configs import get_config, reduce_config
from repro.train import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--full", action="store_true", help="use the full config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduce_config(cfg, d_model=128, n_heads=4, vocab=2048, periods=2)
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size} ({cfg.param_count()/1e6:.1f}M params)")

    loop = LoopConfig(
        total_steps=args.steps,
        seq_len=args.seq,
        global_batch=args.batch,
        ckpt_every=args.ckpt_every,
        log_every=10,
        ckpt_dir=args.ckpt_dir,
        optimizer=args.optimizer,
        grad_compression=args.grad_compression,
    )
    hist = train(cfg, loop)
    import numpy as np

    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(resume-capable: rerun the same command to continue)")
    assert last < first
    print("OK")


if __name__ == "__main__":
    main()
