"""Hyperparameter search on NARMA-10 with on-device fitness — the tune
subsystem end to end.

Searches drive current and effective spectral radius for the reservoir
that best learns NARMA-10 ONLINE: every candidate is a lane of one
CompiledSim, the fused RLS learner trains each lane's readout while it
streams, and fitness is the engine's own learn_nmse — evaluating a whole
population costs one simulation pass, and the search never leaves the
device except to pick the next generation.

Three runs on the same space and budget:
  random   seeded uniform baseline
  cmaes    the adaptive strategy (dependency-free CMA-ES on the unit cube)
  random @ ensemble=1   the sequential baseline — same trials, one lane
                        per pass; quotes the vectorization speedup

Also demos the SERVING feature: `engine.submit_autotuned` probes the
search space on a live engine during a tenant's washout window and
submits the tenant with the winning parameters.

Run:  PYTHONPATH=src python examples/tune_narma.py [--budget 16] [--lanes 8]
"""

import argparse

import numpy as np

from repro.api import ExecPlan, compile_plan, make_spec
from repro.serve.reservoir import ReservoirEngine, StreamSession
from repro.core.tasks import narma_series
from repro.tune import Float, SearchSpace, narma_task, tune_spec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--t", type=int, default=300, help="NARMA ticks per trial")
    ap.add_argument("--budget", type=int, default=16)
    ap.add_argument("--lanes", type=int, default=8, help="candidates per pass")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = make_spec(n=args.n, hold_steps=10, seed=1)
    task = narma_task(t=args.t, order=10, seed=args.seed, learn_washout=50)
    space = SearchSpace({
        "drive_current": Float(0.5e-3, 4.5e-3),
        "spectral_radius": Float(0.2, 1.2),
    })
    plan = ExecPlan(ensemble=args.lanes, chunk_ticks=25)

    print(f"NARMA-10 search: {args.budget} trials, {args.lanes} lanes/pass, "
          f"N={args.n}, {args.t} ticks/trial, online-RLS fitness")
    for strategy in ("random", "cmaes"):
        r = tune_spec(spec, task, space, budget=args.budget, plan=plan,
                      strategy=strategy, seed=args.seed)
        print(f"\n[{strategy}]  {r.wall_s:.2f} s")
        for t in r.ranked()[:5]:
            a = t.assignment
            print(f"  nmse {t.fitness:8.4f}  I = {a['current']*1e3:.3f} mA  "
                  f"a_cp = {a['a_cp']:.3f}")

    seq = tune_spec(spec, task, space, budget=args.budget,
                    plan=ExecPlan(ensemble=1, chunk_ticks=25),
                    strategy="random", seed=args.seed)
    print(f"\nsequential baseline (ensemble=1): {seq.wall_s:.2f} s")

    # serving feature: tune a tenant on a live engine during its washout
    # (the live engine needs the fused learner compiled in — probe fitness
    # is its online NMSE; tune_spec arranges this itself, an engine doesn't)
    engine = ReservoirEngine(
        compile_plan(spec, ExecPlan(ensemble=args.lanes, chunk_ticks=25,
                                    learn="rls"))
    )
    u, y = narma_series(args.t, order=10, seed=args.seed + 1)
    session = StreamSession(sid=1, u_seq=u, targets=y, learn_washout=50)
    probe = engine.submit_autotuned(session, space, budget=args.lanes,
                                    strategy="random", seed=args.seed)
    while engine.step_chunk():
        pass
    tuned = engine.pop_results()[1]
    print(f"\nwashout autotune: probed {len(probe.trials)} candidates on the "
          f"live engine; tenant served with "
          f"I = {float(session.params.current)*1e3:.3f} mA, "
          f"a_cp = {float(session.params.a_cp):.3f} "
          f"-> full-stream nmse {tuned.learn_nmse:.4f}")
    print("OK")


if __name__ == "__main__":
    main()
