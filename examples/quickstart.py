"""Quickstart: simulate a coupled-STO reservoir (the paper's system).

Builds an N-coupled spin-torque oscillator reservoir with the paper's
Table-1 parameters and integrates it with RK4 (dt=1e-11 as in §3.2)
through the unified execution API: the SAME SimSpec compiled against two
ExecPlans — the core-layout scan path and the fused Pallas kernel
(interpret mode on CPU; native on TPU) — then verifies they agree and
conserve |m| = 1.

This is the repo's API in one screen: physics in `make_spec`, execution
in `ExecPlan`, `compile_plan` marrying the two exactly once
(docs/ARCHITECTURE.md).

Run:  PYTHONPATH=src python examples/quickstart.py [--n 64] [--steps 2000]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.api import ExecPlan, compile_plan, make_spec
from repro.core import DT, norm_error


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=2000)
    args = ap.parse_args()

    spec = make_spec(n=args.n, n_in=1, dt=DT, dtype=jnp.float32)
    print(f"N={args.n} coupled STOs, {args.steps} RK4 steps, dt={DT:.0e}s")

    # tier 1: jit + lax.scan in the core layout (the paper's Numba analogue)
    scan_sim = compile_plan(spec, ExecPlan(impl="scan"))
    t0 = time.time()
    m_scan, _ = scan_sim.integrate(args.steps)
    m_scan = jax.block_until_ready(m_scan)[0]
    t_scan = time.time() - t0
    print(f"scan    : {t_scan:.3f}s   |m|-1 err = {float(norm_error(m_scan)):.2e}")

    # tier 2: fused Pallas kernel (interpret mode on CPU; native on TPU)
    kern_sim = compile_plan(
        spec, ExecPlan(impl="fused", n_inner=8, interpret=True)
    )
    t0 = time.time()
    m_kern, _ = kern_sim.integrate(args.steps)
    m_kern = jax.block_until_ready(m_kern)[0]
    t_kern = time.time() - t0
    err = float(jnp.max(jnp.abs(m_kern - m_scan)))
    print(f"kernel  : {t_kern:.3f}s   max diff vs scan = {err:.2e}")

    # sample trajectory: show the oscillation the readout taps
    _, traj = scan_sim.integrate(args.steps, save_every=args.steps // 10)
    print("m_0^x samples:", [f"{float(v):+.3f}" for v in traj[:, 0, 0, 0]])
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
