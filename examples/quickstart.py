"""Quickstart: simulate a coupled-STO reservoir (the paper's system).

Builds an N-coupled spin-torque oscillator reservoir with the paper's
Table-1 parameters, integrates it with RK4 (dt=1e-11 as in §3.2) through
the three implementation tiers, and verifies they agree + conserve |m|=1.

Run:  PYTHONPATH=src python examples/quickstart.py [--n 64] [--steps 2000]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (
    DT,
    default_params,
    initial_magnetization,
    integrate_scan,
    llg_field,
    make_coupling_matrix,
    norm_error,
)
from repro.kernels import ops
from repro.kernels.ref import pack_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--steps", type=int, default=2000)
    args = ap.parse_args()

    p = default_params(jnp.float32)
    w = jnp.asarray(make_coupling_matrix(args.n, seed=0), jnp.float32)
    m0 = initial_magnetization(args.n, jnp.float32)
    print(f"N={args.n} coupled STOs, {args.steps} RK4 steps, dt={DT:.0e}s")

    # tier 1: jit + lax.scan (the paper's Numba analogue)
    field = lambda m, _: llg_field(m, p, w)
    t0 = time.time()
    m_scan, _ = jax.block_until_ready(integrate_scan(field, m0, DT, args.steps))
    t_scan = time.time() - t0
    print(f"scan    : {t_scan:.3f}s   |m|-1 err = {float(norm_error(m_scan)):.2e}")

    # tier 2: fused Pallas kernel (interpret mode on CPU; native on TPU)
    pv = pack_params(p, 1, jnp.float32)
    t0 = time.time()
    m_kern = jax.block_until_ready(
        ops.sto_rk4_integrate(
            m0[None], w, pv, float(DT), args.steps, impl="fused",
            n_inner=8, interpret=True,
        )
    )[0]
    t_kern = time.time() - t0
    err = float(jnp.max(jnp.abs(m_kern - m_scan)))
    print(f"kernel  : {t_kern:.3f}s   max diff vs scan = {err:.2e}")

    # sample trajectory: show the oscillation the readout taps
    _, traj = integrate_scan(field, m0, DT, args.steps, save_every=args.steps // 10)
    print("m_0^x samples:", [f"{float(v):+.3f}" for v in traj[:, 0, 0]])
    assert err < 1e-4
    print("OK")


if __name__ == "__main__":
    main()
