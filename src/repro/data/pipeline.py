"""Deterministic synthetic data pipeline.

Index-based and stateless-by-construction: batch `i` is a pure function of
(seed, i), so
  - any host can materialize any shard (straggler mitigation: a replacement
    host resumes mid-epoch from just the step counter),
  - checkpoints store only (seed, step) — no pipeline state,
  - elastic restarts with a different host count re-partition cleanly.

Two sources: a token stream (mixture of Zipf-distributed unigrams and
repeated n-gram motifs — enough structure that CE demonstrably decreases)
and the reservoir input-signal generators from core/tasks.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    motif_prob: float = 0.35


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks**-a
    return p / p.sum()


class SyntheticTokens:
    """batch(i) -> {tokens, labels, loss_mask} for step i (global batch)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._probs = _zipf_probs(cfg.vocab_size, cfg.zipf_a)
        # fixed motif bank: repeated n-grams give the model learnable
        # structure (tests assert the loss drops on this data)
        self._motif_len = min(cfg.motif_len, cfg.seq_len)
        rng = np.random.default_rng(cfg.seed + 7)
        self._motifs = rng.integers(
            0, cfg.vocab_size, size=(64, self._motif_len), dtype=np.int32
        )

    def batch(self, step: int, batch_slice: Optional[slice] = None) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b = cfg.global_batch
        toks = rng.choice(
            cfg.vocab_size, size=(b, cfg.seq_len + 1), p=self._probs
        ).astype(np.int32)
        # paste motifs at random offsets
        ml = self._motif_len
        n_paste = int(cfg.motif_prob * b * cfg.seq_len / ml)
        rows = rng.integers(0, b, n_paste)
        offs = rng.integers(0, max(cfg.seq_len + 1 - ml, 1), n_paste)
        ids = rng.integers(0, len(self._motifs), n_paste)
        for r, o, i in zip(rows, offs, ids):
            toks[r, o : o + ml] = self._motifs[i]
        out = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "loss_mask": np.ones((b, cfg.seq_len), np.float32),
        }
        if batch_slice is not None:
            out = {k: v[batch_slice] for k, v in out.items()}
        return out

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        i = start_step
        while True:
            yield self.batch(i)
            i += 1


def shard_batch(batch, mesh, batch_shardings):
    """Host numpy batch -> sharded jax arrays (device_put with shardings)."""
    return jax.tree.map(
        lambda x, s: jax.device_put(jnp.asarray(x), s), batch, batch_shardings
    )
