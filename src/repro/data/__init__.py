from repro.data.pipeline import DataConfig, SyntheticTokens, shard_batch
