"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 1000 --batch 64 --seq 1024 [--mesh 4x2] [--reduced]

On a real fleet this binary runs per-host under the cluster scheduler with
jax.distributed.initialize(); on this container it runs single-process
(optionally with virtual devices via --virtual-devices N, applied BEFORE
jax initializes). Resume is automatic: rerunning the same command continues
from the latest checkpoint (fault-tolerance path exercised by tests).
"""

import argparse
import os
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--mesh", default=None, help="e.g. 4x2 => (data=4, model=2)")
    ap.add_argument("--virtual-devices", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--grad-compression", default="none", choices=["none", "int8"])
    ap.add_argument("--lr", type=float, default=3e-4)
    # failure injection for watchdog/restart testing
    ap.add_argument("--fail-at-step", type=int, default=None, help=argparse.SUPPRESS)
    args = ap.parse_args(argv)

    if args.virtual_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.virtual_devices} "
            + os.environ.get("XLA_FLAGS", "")
        )

    # import jax only after XLA_FLAGS is final
    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.mesh import make_mesh
    from repro.train import LoopConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[: len(dims)] if len(dims) <= 2 else (
            "pod", "data", "model"
        )
        mesh = make_mesh(dims, axes)
        print(f"mesh: {dict(zip(axes, dims))} over {mesh.size} devices")

    loop = LoopConfig(
        total_steps=args.steps,
        seq_len=args.seq,
        global_batch=args.batch,
        microbatch=args.microbatch,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        optimizer=args.optimizer,
        grad_compression=args.grad_compression,
        lr=args.lr,
        fail_at_step=args.fail_at_step,
    )
    hist = train(cfg, loop, mesh=mesh)
    print(f"done: final loss {hist[-1]['loss']:.4f} at step {hist[-1]['step']}")


if __name__ == "__main__":
    main()
