import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis per (arch x shape) from compiled dry-run artifacts.

Methodology (EXPERIMENTS.md §Roofline):
  XLA's HloCostAnalysis visits a while-loop body ONCE, so the whole-model
  numbers from dryrun.py undercount scanned layers by their trip count. We
  therefore lower the PERIOD BODY in isolation — same shardings, same mesh —
  take its per-device cost_analysis and collective bytes exactly, and scale:

    flops_total = n_micro * (num_periods * body + prefix + embed/loss)
    coll_total  = full_model_parse + (n_micro * num_periods - 1) * body_coll

  (the full-model parse from dryrun.py contributes the once-per-step
  collectives: gradient reduction, input scatter, etc.)

  Terms per chip (TPU v5e):
    compute   = flops / 197e12         [s]
    memory    = bytes / 819e9          [s]
    collective= coll_bytes / 50e9      [s]

  MODEL_FLOPS = 6 * N_active * tokens (train) / 2 * N_active * batch
  (decode); the ratio MODEL_FLOPS / HLO_FLOPS exposes remat & redundancy.
"""

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells_for, get_config, list_configs
from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch.dryrun import MICROBATCH, OUT_DIR, collect_collectives
from repro.models import counting, transformer
from repro.models.transformer import _dtype_of, _init_layer, _layer_decode, _layer_forward

HW = mesh_mod.HW
ROOF_DIR = OUT_DIR.parent / "roofline"


def _period_param_specs(cfg: ModelConfig):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    dtype = _dtype_of(cfg)
    cross = cfg.encoder_layers > 0

    def init_one(k):
        import jax.random as jr

        sk = jr.split(k, len(cfg.period))
        return [
            _init_layer(sk[i], cfg, spec, dtype, cross)
            for i, spec in enumerate(cfg.period)
        ]

    return jax.eval_shape(init_one, key)


def lower_period_body(cfg: ModelConfig, cell: ShapeCell, mesh, batch_override=None):
    """Lower one scan-period body with production shardings; returns
    (flops, bytes, coll) per device per execution."""
    shd.enable_constraints(mesh)
    dtype = _dtype_of(cfg)
    b = batch_override or cell.global_batch
    # NOTE: the body params are UNSTACKED (single period, no leading periods
    # axis) so they must not live under a "stack/" path — the sharder's
    # stacked-leaf offset would misfire and silently replicate everything.
    param_specs = _period_param_specs(cfg)
    p_sh = shd.param_shardings(mesh, {"body": param_specs})["body"]

    if cell.kind == "train":
        s = cell.seq_len
        x_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
        pos_spec = jax.ShapeDtypeStruct((b, s), jnp.int32)

        seq_par = os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"

        def body(x, positions, lp):
            # same block-boundary constraint as transformer._run_stack
            if seq_par:
                x = shd.constrain(x, shd.BATCH, shd.MODEL, None)
            else:
                x = shd.constrain(x, shd.BATCH, None, None)
            aux = jnp.zeros((), jnp.float32)
            for i, spec in enumerate(cfg.period):
                x, a, _ = _layer_forward(lp[i], cfg, spec, x, positions, mode="train")
                aux += a
            if seq_par:
                x = shd.constrain(x, shd.BATCH, shd.MODEL, None)
            return x, aux

        def scalar_body(x, positions, lp):
            y, aux = body(x, positions, lp)
            return jnp.sum(y.astype(jnp.float32)) + aux

        fn = jax.grad(scalar_body, argnums=(0, 2))
        x_sh = shd.batch_shardings(mesh, x_spec)
        lowered = jax.jit(fn, in_shardings=(x_sh, None, p_sh)).lower(
            x_spec, pos_spec, param_specs
        )
    elif cell.kind == "prefill":
        s = cell.seq_len
        x_spec = jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)
        pos_spec = jax.ShapeDtypeStruct((b, s), jnp.int32)

        def fn(x, positions, lp):
            for i, spec in enumerate(cfg.period):
                x, _, c = _layer_forward(lp[i], cfg, spec, x, positions, mode="prefill")
            return x

        x_sh = shd.batch_shardings(mesh, x_spec)
        lowered = jax.jit(fn, in_shardings=(x_sh, None, p_sh)).lower(
            x_spec, pos_spec, param_specs
        )
    else:  # decode
        s = cell.seq_len
        x_spec = jax.ShapeDtypeStruct((b, 1, cfg.d_model), dtype)
        pos_spec = jax.ShapeDtypeStruct((b,), jnp.int32)
        cache_spec = [
            transformer._layer_cache_spec(
                cfg, spec, b, s, dtype, cross=cfg.encoder_layers > 0
            )
            for spec in cfg.period
        ]
        c_sh = shd.batch_shardings(mesh, {"caches": cache_spec})["caches"]

        def fn(x, pos, lp, caches):
            for i, spec in enumerate(cfg.period):
                x, caches[i] = _layer_decode(lp[i], cfg, spec, x, caches[i], pos)
            return x, caches

        x_sh = shd.batch_shardings(mesh, x_spec)
        lowered = jax.jit(fn, in_shardings=(x_sh, None, p_sh, c_sh)).lower(
            x_spec, pos_spec, param_specs, cache_spec
        )

    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    coll = collect_collectives(compiled.as_text())
    shd.enable_constraints(None)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def analyze_cell(arch: str, shape: str, mesh_tag: str = "pod16x16"):
    cfg = get_config(arch)
    cell = SHAPES[shape]
    mesh = mesh_mod.make_production_mesh(multi_pod=(mesh_tag != "pod16x16"))
    chips = mesh.size

    full_path = OUT_DIR / f"{arch}_{shape}_{mesh_tag}.json"
    full = json.loads(full_path.read_text()) if full_path.exists() else {}

    n_micro = 1
    batch_override = None
    if cell.kind == "train":
        mb = MICROBATCH.get(arch, 64)
        if mb < cell.global_batch:
            n_micro = cell.global_batch // mb
            batch_override = mb

    body = lower_period_body(cfg, cell, mesh, batch_override=batch_override)
    periods = cfg.num_periods

    # per-device totals
    flops = n_micro * periods * body["flops"]
    bytes_ = n_micro * periods * body["bytes"]
    body_coll = sum(v["bytes"] for v in body["coll"].values())
    full_coll = sum(
        v["bytes"] for v in full.get("collectives", {}).values()
    )
    coll = full_coll + max(n_micro * periods - 1, 0) * body_coll

    # embed/loss/prefix adjustments: approximate with the full-model lowered
    # numbers (counted once there)
    flops += full.get("hlo_flops", 0.0)
    bytes_ += full.get("hlo_bytes", 0.0)

    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_ / HW["hbm_bw"]
    t_coll = coll / HW["ici_bw"]

    # analytic model flops (global; convert to per-device)
    if cell.kind == "train":
        model_flops = counting.train_step_flops(cfg, cell.global_batch, cell.seq_len)
    elif cell.kind == "prefill":
        model_flops = 2.0 * cfg.active_param_count() * cell.global_batch * cell.seq_len
        # + attention quadratic term
        attn_layers = sum(
            1 for s in cfg.layer_kinds() if s.mixer in ("attn", "swa", "mla")
        )
        win = cfg.sliding_window or 0
        s_eff = min(cell.seq_len, win) if win else cell.seq_len
        model_flops += (
            2.0 * attn_layers * cell.global_batch * cell.seq_len * s_eff
            * cfg.num_heads * cfg.head_dim
        )
    else:
        model_flops = counting.decode_step_flops(cfg, cell.global_batch, cell.seq_len)
    model_flops_dev = model_flops / chips

    dominant = max(
        [("compute", t_compute), ("memory", t_memory), ("collective", t_coll)],
        key=lambda kv: kv[1],
    )[0]
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_tag,
        "chips": chips,
        "n_micro": n_micro,
        "periods": periods,
        "body": body,
        "flops_dev": flops,
        "bytes_dev": bytes_,
        "coll_bytes_dev": coll,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_dev": model_flops_dev,
        "useful_ratio": model_flops_dev / flops if flops else 0.0,
        "roofline_fraction": (
            model_flops_dev / HW["peak_flops_bf16"]
        ) / max(t_compute, t_memory, t_coll) if max(t_compute, t_memory, t_coll) else 0.0,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    ROOF_DIR.mkdir(parents=True, exist_ok=True)
    if args.all:
        jobs = [
            (a, s)
            for a in list_configs()
            for s, ok in cells_for(get_config(a)).items()
            if ok
        ]
    else:
        jobs = [(args.arch, args.shape)]

    for arch, shape in jobs:
        out = ROOF_DIR / f"{arch}_{shape}_{args.mesh}.json"
        if out.exists() and not args.force:
            print(f"skip cached {out.name}")
            continue
        try:
            rec = analyze_cell(arch, shape, args.mesh)
            out.write_text(json.dumps(rec, indent=1))
            print(
                f"{arch:26s} {shape:12s} compute={rec['t_compute_s']:.3e}s "
                f"memory={rec['t_memory_s']:.3e}s coll={rec['t_collective_s']:.3e}s "
                f"dominant={rec['dominant']:10s} useful={rec['useful_ratio']:.2f} "
                f"roofline={rec['roofline_fraction']:.2%}"
            )
        except Exception as e:
            import traceback

            print(f"FAIL {arch} {shape}: {e}")
            traceback.print_exc(limit=4)


if __name__ == "__main__":
    main()
