"""Step functions lowered by the dry-run and used by the real launcher:
train_step (loss + grads + optimizer update, microbatched) and serve steps
(prefill / decode). Kept separate from dryrun.py so tests can reuse them on
small meshes."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import build_model
from repro.models import transformer
from repro.optim import optimizer as opt_mod


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optional[str] = None,
    microbatch: int = 0,
    grad_compression: str = "none",
    lr: float = 3e-4,
    warmup: int = 200,
    total_steps: int = 10_000,
):
    """Returns (train_step, opt_init_specs_fn).

    train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)

    microbatch > 0 splits the global batch into chunks accumulated with a
    lax.scan — activation memory drops by batch/microbatch while the DP
    gradient all-reduce still happens once per step (XLA overlaps the
    per-microbatch reduce-scatter with the next microbatch's compute).
    """
    model = build_model(cfg)
    optimizer = optimizer or default_optimizer(cfg)
    lr_fn = functools.partial(
        opt_mod.cosine_schedule, base_lr=lr, warmup=warmup, total=total_steps
    )
    opt = opt_mod.make_optimizer(optimizer, cfg, lr_fn=lr_fn)
    compress = opt_mod.make_compressor(grad_compression)

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    def compute_grads(params, batch):
        if microbatch and microbatch < _batch_size(batch):
            n = _batch_size(batch) // microbatch

            def mb_body(acc, mb):
                (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                acc_g, acc_l = acc
                acc_g = jax.tree.map(lambda a, b: a + b, acc_g, g)
                return (acc_g, acc_l + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            mbs = jax.tree.map(
                lambda x: x.reshape(n, microbatch, *x.shape[1:]), batch
            )
            (g, loss), _ = jax.lax.scan(mb_body, (zero, jnp.zeros(())), mbs)
            g = jax.tree.map(lambda x: x / n, g)
            return loss / n, g
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, g

    def train_step(params, opt_state, batch, step):
        loss, grads = compute_grads(params, batch)
        grads = compress(grads)
        gnorm = opt_mod.global_norm(grads)
        grads = opt_mod.clip_by_global_norm(grads, 1.0, gnorm)
        params, opt_state = opt.update(params, grads, opt_state, step)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt, model


def default_optimizer(cfg: ModelConfig) -> str:
    """Adafactor for >=90B params so optimizer state fits one v5e pod
    (DESIGN.md §4); AdamW otherwise."""
    return "adafactor" if cfg.param_count() >= 90e9 else "adamw"


def _batch_size(batch) -> int:
    return jax.tree.leaves(batch)[0].shape[0]


def make_serve_steps(cfg: ModelConfig):
    """(prefill_step, decode_step) closures over the model."""
    model = build_model(cfg)

    def prefill_step(params, batch):
        last_logits, caches = model.prefill(params, batch)
        return last_logits, caches

    def decode_step(params, batch):
        logits, caches = model.decode_step(
            params, batch["tokens"], batch["caches"], batch["pos"]
        )
        # greedy next token (serving loop uses it; dry-run lowers it)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, logits, caches

    return prefill_step, decode_step
