"""Batched serving launcher on the continuous-batching engine
(repro/serve/engine.py): requests stream through a fixed slot pool;
finished slots refill immediately via prefill + cache splice.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --requests 8 --slots 4 --gen 16

This is the loop whose one-step bodies the decode_* dry-run cells lower at
production shape; the engine's outputs are bit-identical to per-request
decoding (tests/test_serve_engine.py).
"""

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        # ragged prompt lengths exercise the scheduler
        length = args.prompt_len - (i % 4)
        reqs.append(
            Request(
                i,
                jax.random.randint(k, (length,), 0, cfg.vocab_size).astype(jnp.int32),
                args.gen,
            )
        )

    capacity = args.prompt_len + args.gen
    eng = Engine(cfg, params, num_slots=args.slots, capacity=capacity)
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    total_toks = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"  req{rid}: {results[rid]}")
    print(f"served {len(results)} requests / {total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s incl. compile) with {args.slots} slots")


if __name__ == "__main__":
    main()
