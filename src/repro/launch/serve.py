"""Batched serving launchers.

Two engines share the slot-batching idea:

LM mode (default) — continuous batching on the transformer engine
(repro/serve/engine.py): requests stream through a fixed slot pool;
finished slots refill immediately via prefill + cache splice.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --requests 8 --slots 4 --gen 16

Reservoir mode — the multi-tenant streaming reservoir engine
(repro/serve/reservoir.py): client streams are slot-batched onto the
ensemble axis so one batched RK4 integrate advances every session per
tick. `--chunk-ticks K` serves K ticks per dispatch through the pipelined
chunked path (one bulk transfer per chunk); `--autoscale` grows/shrinks
the slot count under load through the bucketed plan cache.

    PYTHONPATH=src python -m repro.launch.serve --mode reservoir \
        --n 128 --slots 64 --sessions 96 --ticks 50 --backend auto \
        --chunk-ticks 8 --autoscale --max-slots 256

`--learn rls|lms` turns the tenants into online-learning NARMA streams
(per-tenant readouts train on device while serving), and
`--autotune-budget B` washout-auto-tunes the first tenant's physical
parameters on the live engine before it streams (repro/tune):

    PYTHONPATH=src python -m repro.launch.serve --mode reservoir \
        --n 64 --slots 8 --sessions 12 --ticks 120 --learn rls \
        --autotune-budget 8

Fleet mode — `--fleet` lifts reservoir serving onto the fleet tier
(repro/serve/fleet/): `--replicas R` engine replicas per N-pool behind
the asyncio front-end, with sessions placed least-loaded, capacity
planned from BENCH_serve.json when present, and `--transport process`
putting each replica in its own OS process.

    PYTHONPATH=src python -m repro.launch.serve --mode reservoir --fleet \
        --replicas 2 --n 16 --slots 8 --sessions 48 --ticks 50 \
        --transport local
"""

import argparse
import time


def main_lm(args):
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(args.requests):
        key, k = jax.random.split(key)
        # ragged prompt lengths exercise the scheduler
        length = args.prompt_len - (i % 4)
        reqs.append(
            Request(
                i,
                jax.random.randint(k, (length,), 0, cfg.vocab_size).astype(jnp.int32),
                args.gen,
            )
        )

    capacity = args.prompt_len + args.gen
    eng = Engine(cfg, params, num_slots=args.slots, capacity=capacity)
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    total_toks = sum(len(v) for v in results.values())
    for rid in sorted(results):
        print(f"  req{rid}: {results[rid]}")
    print(f"served {len(results)} requests / {total_toks} tokens in {dt:.2f}s "
          f"({total_toks / dt:.1f} tok/s incl. compile) with {args.slots} slots")


#: default search ranges for --autotune-budget knobs (lane knobs only — a
#: live engine cannot recompile; see repro.tune.washout_autotune)
AUTOTUNE_RANGES = {
    "drive_current": (0.5e-3, 4.5e-3),
    "spectral_radius": (0.2, 1.2),
    "input_gain": (0.1, 2.0),
}


def main_reservoir(args):
    import jax.numpy as jnp
    import numpy as np

    from repro.api import ExecPlan, compile_plan, make_spec
    from repro.core import fit_ridge, tasks
    from repro.serve.reservoir import ReservoirEngine, StreamSession

    spec = make_spec(
        n=args.n, n_in=1, hold_steps=args.hold_steps, dtype=jnp.float32
    )
    rng = np.random.default_rng(1)
    if args.learn:
        # online-learning tenants: every session trains its readout on
        # device against its own NARMA-2 targets while it streams
        sessions = []
        for i in range(args.sessions):
            u_i, y_i = tasks.narma_series(args.ticks, order=2, seed=i)
            sessions.append(
                StreamSession(
                    sid=i,
                    u_seq=u_i[:, None].astype(np.float32),
                    targets=y_i[:, None].astype(np.float32),
                    learn_washout=args.learn_washout,
                    collect_states=False,
                )
            )
    else:
        # one shared trained readout per task flavor (NARMA here); tenants
        # could each bring their own — see examples/serve_reservoir.py
        u_tr, y_tr = tasks.narma_series(args.ticks * 4, order=2, seed=0)
        _, states_tr = compile_plan(spec, impl="scan").drive(
            jnp.asarray(u_tr[:, None], jnp.float32)
        )
        readout = fit_ridge(
            states_tr, jnp.asarray(y_tr[:, None], jnp.float32), washout=10,
            reg=1e-6,
        )
        sessions = [
            StreamSession(
                sid=i,
                u_seq=rng.uniform(0.0, 0.5, size=(args.ticks, 1)).astype(np.float32),
                readout=readout,
                collect_states=False,
            )
            for i in range(args.sessions)
        ]

    autoscale_kw = {}
    if args.autoscale:
        autoscale_kw = dict(
            autoscale=True,
            min_slots=args.min_slots or args.slots,
            max_slots=args.max_slots or args.slots,
        )
    eng = ReservoirEngine(
        compile_plan(
            spec,
            ExecPlan(
                impl=args.backend,
                ensemble=args.slots,
                measure=args.measure,
                chunk_ticks=args.chunk_ticks,
                precision=args.precision,
                learn=args.learn,
                compilation_cache_dir=args.compilation_cache_dir,
            ),
        ),
        **autoscale_kw,
    )

    probe = None
    if args.autotune_budget:
        # washout auto-tune the FIRST tenant on the live engine: probes
        # stream its washout prefix on spare lanes, the winner's knobs are
        # frozen into the session, and it queues tuned (repro.tune)
        from repro.tune import Float, SearchSpace

        knobs = [k.strip() for k in args.autotune_knobs.split(",") if k.strip()]
        bad = [k for k in knobs if k not in AUTOTUNE_RANGES]
        if bad:
            raise SystemExit(
                f"--autotune-knobs: unknown {bad}; choose from "
                f"{sorted(AUTOTUNE_RANGES)}"
            )
        space = SearchSpace({k: Float(*AUTOTUNE_RANGES[k]) for k in knobs})
        tuned, rest = sessions[0], sessions[1:]
        probe = eng.submit_autotuned(
            tuned, space, budget=args.autotune_budget, seed=0
        )
        sessions = rest

    t0 = time.time()
    results = eng.run(sessions)
    dt = time.time() - t0
    st = eng.scheduler.stats
    print(f"backend={eng.backend} precision={eng.precision} "
          f"slots={eng.num_slots} N={args.n} "
          f"hold_steps={args.hold_steps} chunk_ticks={eng.chunk_ticks}"
          + (f" learn={eng.learn}" if eng.learn else ""))
    print(f"served {len(results)} sessions / {st.session_ticks} session-ticks "
          f"in {dt:.2f}s ({st.session_ticks / dt:.1f} ticks/s incl. compile; "
          f"{st.ticks} wall ticks, occupancy {eng.scheduler.occupancy():.2f}, "
          f"mean queue wait {eng.scheduler.mean_queue_wait():.1f} ticks"
          + (f", grows {st.grows} shrinks {st.shrinks}" if args.autoscale else "")
          + ")")
    if args.learn:
        nmses = [r.learn_nmse for r in results.values() if r.learn_nmse is not None]
        print(f"online learning: mean nmse {float(np.mean(nmses)):.4f} "
              f"over {len(nmses)} tenants")
    if probe is not None:
        best = probe.best
        print(f"washout autotune: {len(probe.trials)} probes on the live "
              f"engine; tenant 0 served with "
              + ", ".join(f"{k}={v:.4g}" for k, v in best.assignment.items())
              + f" (probe nmse {best.fitness:.4f}, "
                f"full-stream nmse {results[0].learn_nmse:.4f})")


def main_fleet(args):
    import asyncio
    import os

    import numpy as np

    from repro.serve.fleet import (
        CapacityModel,
        FleetFrontend,
        FleetRouter,
        start_fleet,
        usable_cores,
    )

    planner = None
    bench = args.bench or "BENCH_serve.json"
    if os.path.exists(bench):
        planner = CapacityModel.from_bench(bench)
        err = planner.prediction_error()
        print(
            f"planner: calibrated from {bench} "
            f"(fit err median {err['median']:.0%} max {err['max']:.0%})"
        )
    else:
        print(f"planner: {bench} not found — admission control disabled")

    router = FleetRouter(
        planner=planner,
        checkpoint_every=args.checkpoint_every or None,
    )
    fleet_kw = dict(
        transport=args.transport,
        rpc_timeout_s=args.rpc_timeout,
        rpc_retries=args.rpc_retries,
        n=args.n,
        num_slots=args.slots,
        hold_steps=args.hold_steps,
        backend=args.backend,
        chunk_ticks=args.chunk_ticks,
        precision=args.precision,
        compilation_cache_dir=args.compilation_cache_dir,
    )
    replicas = start_fleet(args.replicas, **fleet_kw)

    def respawn():
        # failover replacement: same config, drawn warm through the
        # process-wide plan cache (or the persistent compile cache for
        # process transports pointed at --compilation-cache-dir)
        (r,) = start_fleet(1, **fleet_kw)
        return r

    for r in replicas:
        router.add_replica(r, respawn=respawn if args.checkpoint_every else None)

    rng = np.random.default_rng(1)
    streams = [
        rng.uniform(0.0, 0.5, size=(args.ticks, 1)).astype(np.float32)
        for _ in range(args.sessions)
    ]

    async def serve():
        async with FleetFrontend(router) as fleet:
            t0 = time.time()
            for u in streams:
                await fleet.submit_stream(args.n, u, collect_states=False)
            results = await fleet.drain_results()
            dt = time.time() - t0
            stats = fleet.stats()[args.n]
            return results, dt, stats, fleet.fault_stats()

    results, dt, stats, faults = asyncio.run(serve())
    ticks = sum(s.session_ticks for s in stats)
    print(
        f"fleet: {args.replicas}x(N={args.n}, E={args.slots}) "
        f"transport={args.transport} cores={usable_cores()}"
    )
    if planner is not None:
        pred = planner.fleet_sessions_per_sec(
            args.n, args.slots, replicas=args.replicas
        )
        print(f"planner-predicted capacity: {pred:.1f} ref-sessions/s")
    print(
        f"served {len(results)} sessions / {ticks} session-ticks in "
        f"{dt:.2f}s ({ticks / dt:.1f} ticks/s incl. compile; per-replica "
        f"occupancy {[round(s.occupancy, 2) for s in stats]})"
    )
    if args.checkpoint_every or any(faults.values()):
        print(
            "fault tolerance: "
            + ", ".join(f"{k}={v}" for k, v in sorted(faults.items()))
        )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "reservoir"], default="lm")
    # lm mode
    ap.add_argument("--arch")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    # reservoir mode
    ap.add_argument("--n", type=int, default=128)
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--ticks", type=int, default=50)
    ap.add_argument("--hold-steps", type=int, default=20)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--precision", default=None,
                    choices=["highest", "bf16_coupling", "mixed"],
                    help="numerical policy for the compute-bound GEMMs "
                    "(default: bit-exact; see ExecPlan.precision)")
    ap.add_argument("--measure", action="store_true",
                    help="time backend candidates for this (N, E) first")
    ap.add_argument("--chunk-ticks", type=int, default=8,
                    help="input ticks per serving dispatch (pipelined chunks)")
    ap.add_argument("--learn", default=None, choices=["rls", "lms"],
                    help="online per-tenant readout learning: sessions "
                         "stream NARMA-2 targets and train on device "
                         "(ExecPlan.learn)")
    ap.add_argument("--learn-washout", type=int, default=20,
                    help="ticks before the first on-device learner update")
    ap.add_argument("--autotune-budget", type=int, default=0,
                    help="washout auto-tune the first tenant on the live "
                         "engine with this many probe candidates "
                         "(requires --learn; repro.tune)")
    ap.add_argument("--autotune-knobs", default="drive_current,spectral_radius",
                    help="comma-separated lane knobs to search "
                         f"(from {sorted(AUTOTUNE_RANGES)})")
    ap.add_argument("--autoscale", action="store_true",
                    help="grow/shrink the slot count under load "
                         "(bucketed plan cache, QueueDepthPolicy)")
    ap.add_argument("--min-slots", type=int, default=None,
                    help="autoscale floor (default: --slots)")
    ap.add_argument("--max-slots", type=int, default=None,
                    help="autoscale ceiling (default: --slots)")
    # fleet tier (reservoir mode only)
    ap.add_argument("--fleet", action="store_true",
                    help="serve through the fleet tier (replicated engines "
                         "behind the asyncio front-end)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="engine replicas in the N-pool (fleet mode)")
    ap.add_argument("--transport", choices=["local", "process"],
                    default="local",
                    help="replica transport: in-process event-loop tasks or "
                         "one OS process per replica (pipe, chunk batches)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="auto-checkpoint live fleet sessions every K "
                         "router rounds (0: failover off); a crashed "
                         "replica's sessions then restore bit-identically "
                         "onto a respawned replacement")
    ap.add_argument("--rpc-timeout", type=float, default=120.0,
                    help="per-RPC reply deadline for process replicas; a "
                         "hung child trips it and is treated as dead")
    ap.add_argument("--rpc-retries", type=int, default=3,
                    help="send-side RPC retries (exponential backoff) "
                         "before a process replica is declared dead")
    ap.add_argument("--bench", default=None,
                    help="BENCH_serve.json to calibrate the capacity planner "
                         "from (default: ./BENCH_serve.json if present)")
    ap.add_argument("--compilation-cache-dir", default=None,
                    help="directory for JAX's persistent compilation cache: "
                         "XLA executables round-trip through disk, so a "
                         "restarted server (and every process replica "
                         "pointed at the same directory) skips its "
                         "cold-start compiles")
    args = ap.parse_args(argv)

    if args.autotune_budget and not args.learn:
        ap.error("--autotune-budget requires --learn (probe fitness is the "
                 "on-device learner's nmse)")
    if args.mode == "reservoir":
        if args.fleet:
            main_fleet(args)
        else:
            main_reservoir(args)
    elif args.fleet:
        ap.error("--fleet requires --mode reservoir")
    else:
        if not args.arch:
            ap.error("--arch is required in lm mode")
        main_lm(args)


if __name__ == "__main__":
    main()
