"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax init; tests and benches
see the default single device).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small virtual-device meshes)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


# TPU v5e hardware model used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link (~per axis direction)
    "hbm_bytes": 16 * 1024**3,
    "vmem_bytes": 16 * 1024**2,
}
