import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, print memory/cost analysis, and dump the
artifacts launch/roofline.py consumes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached as JSON under experiments/dryrun/ (one file per cell) so
re-runs skip completed cells; --force recompiles.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells_for, get_config, list_configs
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import build_model

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# global-batch microbatch sizes for the train cells (activation memory knob;
# chosen so remat-saved activations fit v5e HBM — see EXPERIMENTS.md §Dry-run)
MICROBATCH = {
    "command-r-plus-104b": 32,
    "jamba-1.5-large-398b": 32,
    "gemma-7b": 64,
    "llava-next-mistral-7b": 64,
    "phi4-mini-3.8b": 64,
    "deepseek-v2-lite-16b": 64,
    "qwen2-moe-a2.7b": 64,
    "h2o-danube-1.8b": 64,
    "whisper-base": 128,
    "xlstm-125m": 128,
}


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def collect_collectives(hlo_text: str):
    """Sum operand bytes per collective kind from optimized HLO.

    Counts each op once; ops inside while bodies must be scaled by trip
    count by the caller (roofline.py does this with the known scan lengths —
    see EXPERIMENTS.md §Roofline methodology).
    """
    sizes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
             "pred": 1, "f64": 8, "s64": 8, "u64": 8, "bf8": 1}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: {"count": 0, "bytes": 0} for k in kinds}
    # e.g.:  %all-reduce.1 = f32[16,1024]{1,0} all-reduce(...)
    pat = re.compile(
        r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?\b(" + "|".join(kinds) + r")\("
    )
    for m in pat.finditer(hlo_text):
        dt, dims, kind = m.groups()
        if dt not in sizes:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[kind]["count"] += 1
        out[kind]["bytes"] += n * sizes[dt]
    return out


def lower_cell(arch: str, shape: str, multi_pod: bool, mesh_override=None):
    """Lower + compile one cell; returns the result record.

    mesh_override: (shape tuple, axes tuple) — small-mesh testing hook
    (tests/test_dryrun_small.py); production meshes otherwise."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if mesh_override is not None:
        mesh = mesh_mod.make_mesh(*mesh_override)
    else:
        mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    shd.enable_constraints(mesh)
    model = build_model(cfg)

    rec = {
        "arch": arch, "shape": shape, "mesh": _mesh_tag(multi_pod),
        "devices": int(mesh.size), "kind": cell.kind,
    }
    t0 = time.time()

    # NamedShardings carry the mesh explicitly; no mesh context is needed.
    if True:
        if cell.kind == "train":
            train_step, opt, _ = steps_mod.make_train_step(
                cfg, microbatch=MICROBATCH.get(arch, 64)
            )
            param_specs = jax.eval_shape(
                model.init, jax.ShapeDtypeStruct((2,), jnp.uint32)
            )
            opt_specs = jax.eval_shape(opt.init, param_specs)
            batch_specs = model.input_specs(cell)
            p_sh = shd.param_shardings(mesh, param_specs)
            o_sh = opt.state_shardings(mesh, p_sh, param_specs)
            b_sh = shd.batch_shardings(mesh, batch_specs)
            step_spec = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),
            ).lower(param_specs, opt_specs, batch_specs, step_spec)
        elif cell.kind == "prefill":
            prefill_step, _ = steps_mod.make_serve_steps(cfg)
            param_specs = jax.eval_shape(
                model.init, jax.ShapeDtypeStruct((2,), jnp.uint32)
            )
            batch_specs = model.input_specs(cell)
            p_sh = shd.param_shardings(mesh, param_specs)
            b_sh = shd.batch_shardings(mesh, batch_specs)
            lowered = jax.jit(
                prefill_step, in_shardings=(p_sh, b_sh)
            ).lower(param_specs, batch_specs)
        else:  # decode
            _, decode_step = steps_mod.make_serve_steps(cfg)
            param_specs = jax.eval_shape(
                model.init, jax.ShapeDtypeStruct((2,), jnp.uint32)
            )
            batch_specs = model.input_specs(cell)
            p_sh = shd.param_shardings(mesh, param_specs)
            b_sh = shd.batch_shardings(mesh, batch_specs)
            lowered = jax.jit(
                decode_step,
                in_shardings=(p_sh, b_sh),
                donate_argnums=(1,),
            ).lower(param_specs, batch_specs)

        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        if mem is not None:
            for field in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            ):
                v = getattr(mem, field, None)
                if v is not None:
                    rec[field] = int(v)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax wraps the dict
            cost = cost[0] if cost else None
        if cost:
            rec["hlo_flops"] = float(cost.get("flops", -1))
            rec["hlo_bytes"] = float(cost.get("bytes accessed", -1))
            rec["cost_keys"] = sorted(cost.keys())[:40]
        hlo = compiled.as_text()
        rec["collectives"] = collect_collectives(hlo)
        rec["hlo_len"] = len(hlo)
        print(f"[{arch} x {shape} x {rec['mesh']}] "
              f"compile={rec['compile_s']}s flops={rec.get('hlo_flops', 0):.3e} "
              f"temp={rec.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"args={rec.get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
        print("  memory_analysis:", mem)
        coll_str = ", ".join(
            f"{k}:{v['count']}x/{v['bytes']/2**20:.1f}MiB"
            for k, v in rec["collectives"].items() if v["count"]
        )
        print("  collectives:", coll_str or "none")
    shd.enable_constraints(None)
    return rec


def run_reservoir_dryrun(multi_pod: bool, variant: str = "base"):
    """The paper's own workload on the production mesh: sharded ensemble
    integration (E over data axes, N over model).

    §Perf C variants:
      base        N over model, f32 all-gather of m^x per stage
      bf16gather  same, but the per-stage wire traffic is bf16 (half bytes)
      eonly       E-only sharding (W replicated; zero collectives, but the
                  per-device matmul lane dim drops to E/devices)
    """
    from repro.core.ensemble import lower_sharded_ensemble

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    ens_axes = ("pod", "data") if multi_pod else ("data",)
    kw = dict(model_axis="model")
    if variant == "bf16gather":
        kw["gather_dtype"] = jnp.bfloat16
    elif variant == "eonly":
        ens_axes = (
            ("pod", "data", "model") if multi_pod else ("data", "model")
        )
        kw["model_axis"] = None
    rec = {
        "arch": "sto-reservoir", "shape": f"n16384-e8192-{variant}",
        "mesh": _mesh_tag(multi_pod),
        "devices": int(mesh.size), "kind": "reservoir",
    }
    t0 = time.time()
    lowered = lower_sharded_ensemble(
        mesh, n=16_384, e=8_192, dt=1e-11, n_steps=100,
        ensemble_axes=ens_axes, dtype=jnp.float32, **kw,
    )
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    for field in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes"):
        v = getattr(mem, field, None)
        if v is not None:
            rec[field] = int(v)
    cost = compiled.cost_analysis()
    if cost:
        rec["hlo_flops"] = float(cost.get("flops", -1))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", -1))
    rec["collectives"] = collect_collectives(compiled.as_text())
    print(f"[sto-reservoir x {rec['mesh']}] compile={rec['compile_s']}s "
          f"flops={rec.get('hlo_flops', 0):.3e}")
    print("  memory_analysis:", mem)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reservoir", action="store_true")
    ap.add_argument("--variant", default="base",
                    choices=["base", "bf16gather", "eonly"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.reservoir:
        for mp in meshes:
            rec = run_reservoir_dryrun(mp, variant=args.variant)
            suffix = "" if args.variant == "base" else f"_{args.variant}"
            path = OUT_DIR / f"sto-reservoir{suffix}_{_mesh_tag(mp)}.json"
            path.write_text(json.dumps(rec, indent=1))
        return

    if args.all:
        jobs = [
            (a, s)
            for a in list_configs()
            for s, ok in cells_for(get_config(a)).items()
            if ok
        ]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        jobs = [(args.arch, args.shape)]

    failures = []
    for arch, shape in jobs:
        for mp in meshes:
            path = OUT_DIR / f"{arch}_{shape}_{_mesh_tag(mp)}.json"
            if path.exists() and not args.force:
                print(f"skip cached {path.name}")
                continue
            try:
                rec = lower_cell(arch, shape, mp)
                path.write_text(json.dumps(rec, indent=1))
            except Exception as e:
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAIL [{arch} x {shape} x {_mesh_tag(mp)}]: {e}")
                traceback.print_exc(limit=5)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested cells compiled OK")


if __name__ == "__main__":
    main()
