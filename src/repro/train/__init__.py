from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.train.train_loop import LoopConfig, train
