"""Fault-tolerant training loop.

Responsibilities:
  - jit the train step (with shardings when a mesh is provided),
  - stream the index-based data pipeline (any host can compute any shard),
  - checkpoint every `ckpt_every` steps (atomic commit) and RESUME from the
    latest checkpoint on startup — a crashed/preempted run relaunched with
    the same command continues bit-exact from the last checkpoint,
  - write a heartbeat file per step (the watchdog/straggler story: an
    external supervisor fences a host whose heartbeat stalls and relaunches;
    the pipeline's statelessness makes the replacement trivial),
  - optional failure injection (tests exercise the restart path).
"""

from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokens
from repro.distributed import sharding as shd
from repro.launch import steps as steps_mod
from repro.models import build_model
from repro.train import checkpoint as ckpt_mod


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_ckpts: int = 3
    microbatch: int = 0
    optimizer: Optional[str] = None
    grad_compression: str = "none"
    lr: float = 3e-4
    warmup: int = 50
    data_seed: int = 0
    fail_at_step: Optional[int] = None  # failure injection (tests)
    resume: bool = True


def train(cfg: ModelConfig, loop: LoopConfig, mesh=None) -> List[Dict[str, float]]:
    model = build_model(cfg)
    train_step, opt, _ = steps_mod.make_train_step(
        cfg,
        optimizer=loop.optimizer,
        microbatch=loop.microbatch,
        grad_compression=loop.grad_compression,
        lr=loop.lr,
        warmup=loop.warmup,
        total_steps=max(loop.total_steps, 100),
    )

    data = SyntheticTokens(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=loop.seq_len,
            global_batch=loop.global_batch,
            seed=loop.data_seed,
        )
    )

    # --- init or resume -----------------------------------------------------
    params_t = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    opt_t = jax.eval_shape(opt.init, params_t)

    p_sh = o_sh = None
    if mesh is not None:
        shd.enable_constraints(mesh)
        p_sh = shd.param_shardings(mesh, params_t)
        o_sh = opt.state_shardings(mesh, p_sh, params_t)

    start_step = 0
    resumed = False
    if loop.resume and ckpt_mod.latest_step(loop.ckpt_dir) is not None:
        params, opt_state, extra, start_step = ckpt_mod.restore_checkpoint(
            loop.ckpt_dir, None, params_t, opt_t,
            shardings=(p_sh, o_sh) if mesh is not None else None,
        )
        start_step += 1  # checkpoint stores the completed step
        resumed = True
    else:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        if mesh is not None:
            params = jax.tree.map(jax.device_put, params, p_sh)
            opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)

    step_fn = jax.jit(
        train_step,
        in_shardings=(p_sh, o_sh, None, None) if mesh is not None else None,
        out_shardings=(p_sh, o_sh, None) if mesh is not None else None,
        donate_argnums=(0, 1),
    )

    hb_path = Path(loop.ckpt_dir) / "heartbeat.json"
    hb_path.parent.mkdir(parents=True, exist_ok=True)

    history: List[Dict[str, float]] = []
    for step in range(start_step, loop.total_steps):
        if loop.fail_at_step is not None and step == loop.fail_at_step and not resumed:
            raise RuntimeError(f"injected failure at step {step}")

        batch = jax.tree.map(jnp.asarray, data.batch(step))
        params, opt_state, metrics = step_fn(
            params, opt_state, batch, jnp.asarray(step, jnp.int32)
        )
        rec = {
            "step": step,
            "loss": float(metrics["loss"]),
            "grad_norm": float(metrics["grad_norm"]),
        }
        history.append(rec)
        hb_path.write_text(json.dumps({"step": step, "t": time.time()}))
        if loop.log_every and step % loop.log_every == 0:
            print(f"step {step:6d}  loss {rec['loss']:.4f}  |g| {rec['grad_norm']:.3f}")
        if loop.ckpt_every and (step + 1) % loop.ckpt_every == 0:
            ckpt_mod.save_checkpoint(
                loop.ckpt_dir, step, params, opt_state,
                extra={"data_seed": loop.data_seed, "loop_step": step},
                keep=loop.keep_ckpts,
            )
    # final checkpoint
    if loop.ckpt_every:
        ckpt_mod.save_checkpoint(
            loop.ckpt_dir, loop.total_steps - 1, params, opt_state,
            extra={"data_seed": loop.data_seed}, keep=loop.keep_ckpts,
        )
    if mesh is not None:
        shd.enable_constraints(None)
    return history
