"""Checkpoint/restart with elastic resharding.

Format: one directory per step containing
    manifest.json   step, names, shapes, dtypes, tree structure, rng, data
                    cursor — LOGICAL state only, no device layout
    arrays.npz      flattened leaves (gathered; host-level)

Why logical-only: a restart may come up with a different mesh (elastic
scaling, failed pod fenced off). Restore device_puts each leaf against the
sharding rules computed for the *current* mesh, so the same checkpoint
serves any topology.

Write protocol is crash-safe: write to  <dir>.tmp, fsync, atomic rename —
a partially-written checkpoint is never visible under its final name; the
(optional) `keep` knob garbage-collects old steps. On a real fleet the same
protocol runs per-host on per-shard files; here the container is one host,
so arrays are gathered (documented deviation, same commit semantics).
"""

from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, treedef


def save_checkpoint(
    ckpt_dir: str | Path,
    step: int,
    params,
    opt_state,
    extra: Optional[Dict[str, Any]] = None,
    keep: int = 3,
) -> Path:
    ckpt_dir = Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    state = {"params": params, "opt_state": opt_state}
    names, leaves, _ = _flatten_with_paths(state)
    arrays = {}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arrays[f"a{i}"] = arr.view(np.uint16)
        else:
            arrays[f"a{i}"] = arr
    np.savez(tmp / "arrays.npz", **arrays)

    manifest = {
        "step": step,
        "names": names,
        "dtypes": [str(l.dtype) for l in leaves],
        "shapes": [list(l.shape) for l in leaves],
        "extra": extra or {},
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit

    # GC old checkpoints
    steps = sorted(d for d in ckpt_dir.glob("step_*") if d.is_dir())
    for old in steps[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(ckpt_dir.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(
    ckpt_dir: str | Path,
    step: Optional[int],
    params_template,
    opt_template,
    shardings: Optional[Tuple[Any, Any]] = None,
):
    """Restore (params, opt_state, extra). Templates provide tree structure;
    `shardings` (param_sh, opt_sh) reshard onto the CURRENT mesh."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints in {ckpt_dir}"
    d = ckpt_dir / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    state_t = {"params": params_template, "opt_state": opt_template}
    names, leaves_t, treedef = _flatten_with_paths(state_t)
    assert names == manifest["names"], "checkpoint/model tree mismatch"

    sh_tree = None
    if shardings is not None:
        sh_state = {"params": shardings[0], "opt_state": shardings[1]}
        _, sh_tree, _ = _flatten_with_paths(sh_state)

    leaves = []
    for i, (name, lt, dt, shp) in enumerate(
        zip(names, leaves_t, manifest["dtypes"], manifest["shapes"])
    ):
        arr = data[f"a{i}"]
        if dt == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        assert list(arr.shape) == shp, (name, arr.shape, shp)
        if sh_tree is not None:
            leaves.append(jax.device_put(arr, sh_tree[i]))
        else:
            leaves.append(jnp.asarray(arr))
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    return state["params"], state["opt_state"], manifest["extra"], step
