"""Training watchdog: supervise a training subprocess, restart on crash or
heartbeat stall.

This is the host-side half of the fault-tolerance story (the in-process
half is the atomic checkpoint + resume in train_loop.py). On a real fleet
the cluster scheduler plays this role per host; the logic is identical:

  - launch the training command,
  - watch the heartbeat file the loop writes every step,
  - if the process dies OR the heartbeat stalls past `stall_s` (hung host,
    straggler), kill and relaunch — the relaunch resumes from the latest
    checkpoint automatically,
  - give up after `max_restarts` (page a human).

Usage:
    python -m repro.train.watchdog --stall-s 120 --max-restarts 3 -- \
        python -m repro.launch.train --arch xlstm-125m --reduced ...
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path
from typing import List, Optional


def run_supervised(
    cmd: List[str],
    heartbeat: Path,
    stall_s: float = 120.0,
    max_restarts: int = 3,
    poll_s: float = 1.0,
    env: Optional[dict] = None,
) -> int:
    """Returns the final exit code (0 = training completed)."""
    restarts = 0
    while True:
        proc = subprocess.Popen(cmd, env=env)
        last_beat = time.time()
        killed_for_stall = False
        while True:
            ret = proc.poll()
            if ret is not None:
                break
            if heartbeat.exists():
                try:
                    beat = json.loads(heartbeat.read_text())
                    last_beat = max(last_beat, float(beat.get("t", 0)))
                except (ValueError, OSError):
                    pass  # mid-write; keep the previous beat
            if time.time() - last_beat > stall_s:
                # straggler/hang: fence and relaunch
                proc.send_signal(signal.SIGKILL)
                proc.wait()
                killed_for_stall = True
                ret = -9
                break
            time.sleep(poll_s)

        if ret == 0:
            return 0
        restarts += 1
        print(
            f"[watchdog] training {'stalled' if killed_for_stall else 'died'} "
            f"(exit {ret}); restart {restarts}/{max_restarts}",
            file=sys.stderr,
        )
        if restarts > max_restarts:
            print("[watchdog] giving up", file=sys.stderr)
            return ret if ret else 1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--heartbeat", default="/tmp/repro_ckpt/heartbeat.json")
    ap.add_argument("--stall-s", type=float, default=120.0)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="-- followed by the training command")
    args = ap.parse_args()
    cmd = args.cmd[1:] if args.cmd and args.cmd[0] == "--" else args.cmd
    assert cmd, "pass the training command after --"
    raise SystemExit(
        run_supervised(cmd, Path(args.heartbeat), args.stall_s, args.max_restarts)
    )


if __name__ == "__main__":
    main()
