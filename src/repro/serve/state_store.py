"""Slot-batched reservoir state store.

The serving engine's device-resident state, laid out exactly like the
kernels want it (kernels/ref.py):

    m      : (3, N, E)      magnetization planes — lane e is serving slot e
    pv     : (NP, E)        packed per-tenant STOParams, one column per slot
    w_out  : (E, N+1, n_out) per-session trained readouts (last row = bias)

Admitting a session SPLICES its state into the batched arrays at a free
slot (column writes via .at); retiring resets the column to the engine's
template so idle lanes keep integrating harmlessly (unit-norm state, zero
input, default params — no NaN sources) until partial-batch masking or the
next admit. W^cp / W^in topology is shared across tenants: the paper's
batched-ensemble speedup comes precisely from every lane contracting
against the same coupling matrix, so per-tenant physics lives in the
params/readout columns, not the topology.
"""

from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp

from repro.core.constants import STOParams
from repro.kernels import ref as kref


class SlotStore:
    def __init__(self, res, num_slots: int, n_out: int = 1):
        # res: the engine's physics template — a repro.api.SimSpec (or the
        # legacy Reservoir tuple; both carry params/w_cp/w_in/m0/dt).
        self.res = res
        self.num_slots = num_slots
        self.n = int(res.m0.shape[0])
        self.n_in = int(res.w_in.shape[1])
        self.n_out = n_out
        self.dtype = res.m0.dtype

        self._m0_col = jnp.transpose(res.m0)  # (3, N) template column
        self.m = jnp.broadcast_to(
            self._m0_col[:, :, None], (3, self.n, num_slots)
        ).astype(self.dtype)
        self._slot_params: List[STOParams] = [res.params] * num_slots
        self.w_out = jnp.zeros((num_slots, self.n + 1, n_out), self.dtype)
        self._active = [False] * num_slots

        # caches derived from _slot_params / _active; rebuilt lazily after
        # admit/retire (rare) so the per-tick hot path reuses device arrays
        self._pv: Optional[jnp.ndarray] = None
        self._params_e: Optional[STOParams] = None
        self._mask: Optional[jnp.ndarray] = None

    # -- slot lifecycle ----------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, a in enumerate(self._active) if not a]

    def admit(
        self,
        slot: int,
        m0: Optional[jnp.ndarray] = None,  # (N, 3); None = reservoir default
        params: Optional[STOParams] = None,  # per-tenant physics
        w_out: Optional[jnp.ndarray] = None,  # (N+1, n_out) trained readout
    ) -> None:
        assert not self._active[slot], f"slot {slot} already occupied"
        col = (
            self._m0_col
            if m0 is None
            else jnp.transpose(jnp.asarray(m0, self.dtype))
        )
        self.m = self.m.at[:, :, slot].set(col)
        self._slot_params[slot] = params if params is not None else self.res.params
        if w_out is not None:
            self.w_out = self.w_out.at[slot].set(
                jnp.asarray(w_out, self.dtype).reshape(self.n + 1, self.n_out)
            )
        self._active[slot] = True
        self._invalidate()

    def retire(self, slot: int) -> None:
        assert self._active[slot], f"slot {slot} not occupied"
        self.m = self.m.at[:, :, slot].set(self._m0_col)
        self._slot_params[slot] = self.res.params
        self.w_out = self.w_out.at[slot].set(0.0)
        self._active[slot] = False
        self._invalidate()

    def _invalidate(self):
        self._pv = None
        self._params_e = None
        self._mask = None

    # -- derived batched views --------------------------------------------

    @property
    def active_mask(self) -> jnp.ndarray:  # (E,) bool
        if self._mask is None:
            self._mask = jnp.asarray(self._active, dtype=bool)
        return self._mask

    @property
    def num_active(self) -> int:
        return sum(self._active)

    @property
    def params_vec(self) -> jnp.ndarray:
        """Packed (NP, E) per-slot parameter columns (kernel backends)."""
        if self._pv is None:
            self._pv = kref.pack_params(
                self.params_ensemble, self.num_slots, dtype=self.dtype
            )
        return self._pv

    @property
    def params_ensemble(self) -> STOParams:
        """STOParams with (E, 1) leaves (scan backend / pack_params input)."""
        if self._params_e is None:
            leaves = {
                f: jnp.stack(
                    [
                        jnp.asarray(getattr(p, f), self.dtype).reshape(())
                        for p in self._slot_params
                    ]
                ).reshape(self.num_slots, 1)
                for f in STOParams._fields
            }
            self._params_e = STOParams(**leaves)
        return self._params_e

    def a_in_row(self) -> jnp.ndarray:
        """(E,) per-slot input gains (A_in is per-tenant, like the rest)."""
        return self.params_ensemble.a_in.reshape(self.num_slots)

    def state_column(self, slot: int) -> jnp.ndarray:
        """Current (N, 3) magnetization of one slot (user layout)."""
        return jnp.transpose(self.m[:, :, slot])
