"""Slot-batched reservoir state store.

The serving engine's device-resident state, laid out exactly like the
kernels want it (kernels/ref.py):

    m      : (3, N, E)      magnetization planes — lane e is serving slot e
    pv     : (NP, E)        packed per-tenant STOParams, one column per slot
    w_out  : (E, N+1, n_out) per-session trained readouts (last row = bias)

and, when the engine learns online (`ExecPlan.learn`):

    P      : (E, S, S)      per-slot RLS inverse-Gram, S = N + 1
                            (learn="rls" only — LMS carries no P; the
                            attribute stays None on learn="lms" stores)
    Wl     : (E, S, n_out)  per-slot LEARNED readout weights

P/Wl lanes reset to the template (I / learn_reg, zeros — or a session's
warm-start readout) on admit, ride every `tick_chunk` dispatch next to the
magnetization, and migrate through `resized` exactly like the state
columns, so autoscaling never perturbs a session's learning trajectory.
The store's `learn` attribute is the learner KIND (None | "rls" | "lms");
the legacy boolean spelling (learn=True) still means "rls".

Admitting a session SPLICES its state into the batched arrays at a free
slot (column writes via .at); retiring resets the column to the engine's
template so idle lanes keep integrating harmlessly (unit-norm state, zero
input, default params — no NaN sources) until partial-batch masking or the
next admit. Admissions and retirements BATCH: the pipelined engine turns a
whole chunk boundary's churn into one gather-scatter per array (per-slot
eager scatters measured ~100x slower at E=64 full turnover). Per-tenant
parameter scalars live in a host-side (NP, E) numpy matrix and only
materialize as device (E, 1) leaves when the cache rebuilds.

W^cp / W^in topology is shared across tenants: the paper's
batched-ensemble speedup comes precisely from every lane contracting
against the same coupling matrix, so per-tenant physics lives in the
params/readout columns, not the topology.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.constants import STOParams
from repro.kernels import ref as kref
from repro.kernels import rls as krls

_NF = len(STOParams._fields)


class SlotStore:
    def __init__(
        self,
        res,
        num_slots: int,
        n_out: int = 1,
        learn=None,  # None/False | True (= "rls") | "rls" | "lms"
        learn_reg: float = 1e-6,
    ):
        # res: the engine's physics template — a repro.api.SimSpec (or the
        # legacy Reservoir tuple; both carry params/w_cp/w_in/m0/dt).
        self.res = res
        self.num_slots = num_slots
        self.n = int(res.m0.shape[0])
        self.n_in = int(res.w_in.shape[1])
        self.n_out = n_out
        self.dtype = res.m0.dtype
        if learn is True:  # legacy boolean spelling
            learn = "rls"
        elif learn is False:
            learn = None
        if learn not in (None, "rls", "lms"):
            raise ValueError(
                f"learn must be None, True, 'rls', or 'lms'; got {learn!r}"
            )
        self.learn = learn
        self.learn_reg = float(learn_reg)
        self.n_state = self.n + 1
        self.P: Optional[jnp.ndarray] = None
        self.Wl: Optional[jnp.ndarray] = None
        if learn == "rls":
            self.P, self.Wl = krls.rls_init(
                num_slots, self.n_state, n_out, self.learn_reg, self.dtype
            )
        elif learn == "lms":
            self.Wl = krls.lms_init(num_slots, self.n_state, n_out, self.dtype)

        self._m0_col = jnp.transpose(res.m0)  # (3, N) template column
        self._m0_col_np = np.asarray(self._m0_col)
        self.m = jnp.broadcast_to(
            self._m0_col[:, :, None], (3, self.n, num_slots)
        ).astype(self.dtype)
        # host-side per-slot parameter scalars, one column per slot;
        # params_ensemble materializes device leaves from these rows in
        # NP transfers instead of NP * E scalar ops
        self._template_params_col = np.asarray(
            [np.asarray(getattr(res.params, f)).reshape(()) for f in STOParams._fields],
            dtype=self.dtype,
        )
        self._params_np = np.tile(
            self._template_params_col[:, None], (1, num_slots)
        )
        self.w_out = jnp.zeros((num_slots, self.n + 1, n_out), self.dtype)
        self._active = [False] * num_slots

        # caches derived from _params_np / _active; rebuilt lazily after
        # admit/retire (chunk boundaries) so the per-tick hot path reuses
        # device arrays
        self._pv: Optional[jnp.ndarray] = None
        self._params_e: Optional[STOParams] = None
        self._mask: Optional[jnp.ndarray] = None

    # -- slot lifecycle ----------------------------------------------------

    def free_slots(self) -> List[int]:
        return [i for i, a in enumerate(self._active) if not a]

    def admit(
        self,
        slot: int,
        m0: Optional[jnp.ndarray] = None,  # (N, 3); None = reservoir default
        params: Optional[STOParams] = None,  # per-tenant physics
        w_out: Optional[jnp.ndarray] = None,  # (N+1, n_out) trained readout
        learn_w0: Optional[jnp.ndarray] = None,  # (N+1, n_out) RLS warm start
        learn_P0: Optional[jnp.ndarray] = None,  # (S, S) inverse-Gram resume
    ) -> None:
        self.admit_many([(slot, m0, params, w_out, learn_w0, learn_P0)])

    def admit_many(
        self,
        items: Sequence[Tuple],
    ) -> None:
        """Splice several sessions in ONE scatter per batched array.

        items: (slot, m0, params, w_out[, learn_w0[, learn_P0]]) per
        admission — the whole chunk boundary's admissions become one column
        write into m, one row write into w_out (and, on learning stores, one
        each into P / Wl), and host-side numpy column writes for the params.
        learn_w0 warm-starts the slot's LEARNED weights (defaults to zeros);
        learn_P0 resumes the slot's inverse-Gram mid-recursion (a migrated
        session's checkpoint; defaults to the fresh I / learn_reg)."""
        if not items:
            return
        idx = np.empty(len(items), dtype=np.int32)
        cols = np.empty((3, self.n, len(items)), self.dtype)
        w_idx: List[int] = []
        w_rows: List[np.ndarray] = []
        lw_cols: List[np.ndarray] = []
        lp_cols: List[Optional[np.ndarray]] = []
        for i, item in enumerate(items):
            slot, m0, params, w_out = item[:4]
            learn_w0 = item[4] if len(item) > 4 else None
            learn_P0 = item[5] if len(item) > 5 else None
            assert not self._active[slot], f"slot {slot} already occupied"
            self._active[slot] = True  # in-loop: a duplicate slot in ONE
            # batch must trip the assert, not silently double-admit
            idx[i] = slot
            cols[:, :, i] = (
                self._m0_col_np
                if m0 is None
                else np.asarray(m0, self.dtype).T
            )
            if params is None:
                self._params_np[:, slot] = self._template_params_col
            else:
                self._params_np[:, slot] = [
                    np.asarray(getattr(params, f)).reshape(())
                    for f in STOParams._fields
                ]
            if w_out is not None:
                w_idx.append(slot)
                w_rows.append(
                    np.asarray(w_out, self.dtype).reshape(self.n + 1, self.n_out)
                )
            if self.learn:
                lw_cols.append(
                    np.zeros((self.n_state, self.n_out), self.dtype)
                    if learn_w0 is None
                    else np.asarray(learn_w0, self.dtype).reshape(
                        self.n_state, self.n_out
                    )
                )
                lp_cols.append(
                    None
                    if learn_P0 is None
                    else np.asarray(learn_P0, self.dtype).reshape(
                        self.n_state, self.n_state
                    )
                )
        self.m = self.m.at[:, :, idx].set(jnp.asarray(cols))
        if w_idx:
            self.w_out = self.w_out.at[np.asarray(w_idx)].set(
                jnp.asarray(np.stack(w_rows))
            )
        if self.learn:
            self._reset_learn_columns(idx, lw_cols, lp_cols)
        self._invalidate()

    def _reset_learn_columns(
        self,
        idx: np.ndarray,
        w_cols: Optional[List[np.ndarray]] = None,
        p_cols: Optional[List[Optional[np.ndarray]]] = None,
    ) -> None:
        """Restart the learning state of several slots in one scatter each:
        P <- p_cols entry (I / learn_reg when None — the fresh-start
        default; a checkpointed P resumes a migrated recursion), Wl <-
        w_cols (zeros when None/omitted). LMS stores have no P: p_cols
        entries must all be None there."""
        if self.P is None:  # learn="lms"
            if p_cols and any(p is not None for p in p_cols):
                raise ValueError(
                    "learn_P0 was passed to a learn='lms' store — LMS "
                    "carries no inverse-Gram block to resume"
                )
        else:
            eye_np = np.asarray(
                np.eye(self.n_state, dtype=self.dtype) / self.learn_reg
            )
            if p_cols and any(p is not None for p in p_cols):
                self.P = self.P.at[idx].set(
                    jnp.asarray(
                        np.stack([eye_np if p is None else p for p in p_cols])
                    )
                )
            else:
                self.P = self.P.at[idx].set(
                    jnp.broadcast_to(
                        jnp.asarray(eye_np)[None],
                        (len(idx), self.n_state, self.n_state),
                    )
                )
        if w_cols:
            self.Wl = self.Wl.at[idx].set(jnp.asarray(np.stack(w_cols)))
        else:
            self.Wl = self.Wl.at[idx].set(0.0)

    def retire(self, slot: int) -> None:
        self.retire_many([slot])

    def retire_many(self, slots: Sequence[int]) -> None:
        """Reset several columns to the template in one scatter each."""
        if not len(slots):
            return
        idx = np.asarray(slots, dtype=np.int32)
        for slot in slots:
            assert self._active[slot], f"slot {slot} not occupied"
            self._params_np[:, slot] = self._template_params_col
            self._active[slot] = False
        self.m = self.m.at[:, :, idx].set(
            jnp.broadcast_to(self._m0_col[:, :, None], (3, self.n, len(idx)))
        )
        self.w_out = self.w_out.at[idx].set(0.0)
        if self.learn:
            self._reset_learn_columns(idx)
        self._invalidate()

    def _invalidate(self):
        self._pv = None
        self._params_e = None
        self._mask = None

    def resized(self, new_num_slots: int, slot_map: Dict[int, int]) -> "SlotStore":
        """A new store of width `new_num_slots` with occupied columns moved
        per slot_map (old slot -> new slot) — the autoscale migration.

        One gather-scatter moves every occupied magnetization column and
        readout lane between ensemble widths; unmapped new slots start from
        the template (exactly like freshly-retired lanes). Column moves are
        pure data movement, so a migrated session's dynamics are
        bit-identical to never having moved (pinned by
        tests/test_serve_chunked.py).
        """
        new = SlotStore(
            self.res,
            new_num_slots,
            n_out=self.n_out,
            learn=self.learn,
            learn_reg=self.learn_reg,
        )
        if slot_map:
            old_idx = np.asarray(list(slot_map.keys()))
            new_idx = np.asarray(list(slot_map.values()))
            if max(new_idx) >= new_num_slots:
                raise ValueError(
                    f"slot_map targets slot {max(new_idx)} but the resized "
                    f"store has only {new_num_slots} slots"
                )
            new.m = new.m.at[:, :, new_idx].set(self.m[:, :, old_idx])
            new.w_out = new.w_out.at[new_idx].set(self.w_out[old_idx])
            new._params_np[:, new_idx] = self._params_np[:, old_idx]
            if self.learn:
                # learning state moves with the session: mid-stream learn
                # trajectories survive the autoscale bit-identically
                if self.P is not None:
                    new.P = new.P.at[new_idx].set(self.P[old_idx])
                new.Wl = new.Wl.at[new_idx].set(self.Wl[old_idx])
            for old, tgt in slot_map.items():
                new._active[tgt] = self._active[old]
        return new

    # -- derived batched views --------------------------------------------

    @property
    def active_mask(self) -> jnp.ndarray:  # (E,) bool
        if self._mask is None:
            self._mask = jnp.asarray(self._active, dtype=bool)
        return self._mask

    @property
    def num_active(self) -> int:
        return sum(self._active)

    @property
    def params_vec(self) -> jnp.ndarray:
        """Packed (NP, E) per-slot parameter columns (kernel backends)."""
        if self._pv is None:
            self._pv = kref.pack_params(
                self.params_ensemble, self.num_slots, dtype=self.dtype
            )
        return self._pv

    @property
    def params_ensemble(self) -> STOParams:
        """STOParams with (E, 1) leaves (scan backend / pack_params input)."""
        if self._params_e is None:
            self._params_e = STOParams(
                *(
                    jnp.asarray(self._params_np[i]).reshape(self.num_slots, 1)
                    for i in range(_NF)
                )
            )
        return self._params_e

    def a_in_row(self) -> jnp.ndarray:
        """(E,) per-slot input gains (A_in is per-tenant, like the rest)."""
        return self.params_ensemble.a_in.reshape(self.num_slots)

    def state_column(self, slot: int) -> jnp.ndarray:
        """Current (N, 3) magnetization of one slot (user layout)."""
        return jnp.transpose(self.m[:, :, slot])

    def state_columns(self, slots: Sequence[int]) -> jnp.ndarray:
        """(k, N, 3) magnetization of several slots in one gather — the
        chunked engine snapshots a whole boundary's finishers at once."""
        return jnp.transpose(self.m[:, :, np.asarray(slots, dtype=np.int32)], (2, 1, 0))

    def learn_w_columns(self, slots: Sequence[int]) -> jnp.ndarray:
        """(k, S, n_out) LEARNED readout weights of several slots in one
        gather — the finishers' trained readouts, snapshotted lazily like
        `state_columns` (the slice pins the in-flight chunk's result)."""
        return self.Wl[np.asarray(slots, dtype=np.int32)]

    def learn_P_columns(self, slots: Sequence[int]) -> jnp.ndarray:
        """(k, S, S) inverse-Gram blocks of several slots in one gather —
        the checkpoint/migration path snapshots a mid-recursion learner so
        the destination replica resumes it bit-identically. RLS stores
        only: an LMS learner's whole resumable state is its Wl lanes."""
        if self.P is None:
            raise ValueError(
                "learn_P_columns() on a learn='lms' store — LMS has no "
                "inverse-Gram block; checkpoint the Wl lanes only"
            )
        return self.P[np.asarray(slots, dtype=np.int32)]
