"""Multi-tenant streaming reservoir inference engine.

The reservoir analogue of continuous batching (serve/engine.py): concurrent
client streams map onto slots of the ensemble axis E, so ONE batched
integrate — `rk4_fused` / `field_tiled` on TPU, a jit'd `lax.scan` on CPU —
advances every active session per input tick. Admitting a session splices
its magnetization state m (N, 3) and per-tenant STOParams lane into the
batched (3, N, E) planes (serve/state_store.py); finished or idle sessions
free their slot without stalling the batch (serve/scheduler.py). Each
session carries its own trained Readout and input stream (NARMA, parity,
sine-approx, ... — anything the reservoir was trained for); readout
application is itself slot-batched (one einsum over E).

Execution rides on the unified API (repro/api): the engine holds a
CompiledSim and its per-tick hot path is `CompiledSim.tick`, so every
impl-dispatch / padding / sharding decision is made once, in
`repro.api.compile_plan`. Construct from a Reservoir/SimSpec (the engine
compiles an ExecPlan for you; backend="auto" consults the measured-latency
dispatch table, persisted per-platform JSON included, then the VMEM
heuristic) or hand the engine an already-compiled sim — including a
sharded one (`ExecPlan(mesh=...)`), which serves the slot batch across the
device mesh with E on the data axes and N on the model axis. The extra "scan" backend
integrates in the core (E, N, 3) layout with exactly `reservoir.drive`'s
math, so per-session streamed states are numerically indistinguishable
from running the stream alone; every other backend agrees with solo runs
to the kernel test suite's tolerance (tests/test_serve_reservoir.py pins
all of them).

This is the serving front for time-multiplexed STO reservoir hardware
(Riou et al., arXiv:1904.11236; Kanao et al., arXiv:1905.07937): each
tenant's device parameters ride in a params lane, the shared simulator
advances all of them in lockstep.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CompiledSim, ExecPlan, SimSpec, compile_plan
from repro.core.constants import STOParams
from repro.core.reservoir import Readout, Reservoir, coerce_input_series
from repro.serve.scheduler import SlotScheduler
from repro.serve.state_store import SlotStore

BACKENDS = ("auto", "scan", "ref", "fused", "tiled")


@dataclasses.dataclass
class StreamSession:
    """One tenant's streaming request.

    u_seq follows drive()'s explicit (T, N_in) contract ((T,) for
    n_in == 1). params overrides the engine reservoir's physical parameters
    for this tenant's lane; readout is the tenant's trained linear readout
    (None = state-collection only, e.g. to fit a readout afterwards); m0
    resumes from a previous session's final state.
    """

    sid: int
    u_seq: np.ndarray
    params: Optional[STOParams] = None
    readout: Optional[Readout] = None
    m0: Optional[jnp.ndarray] = None
    collect_states: bool = True

    # engine-internal bookkeeping (set on admit)
    _slot: int = dataclasses.field(default=-1, repr=False)
    _t: int = dataclasses.field(default=0, repr=False)
    _states: list = dataclasses.field(default_factory=list, repr=False)
    _outs: list = dataclasses.field(default_factory=list, repr=False)
    _admitted_tick: int = dataclasses.field(default=-1, repr=False)


@dataclasses.dataclass
class SessionResult:
    sid: int
    states: Optional[jnp.ndarray]  # (T, N) streamed node states
    outputs: Optional[jnp.ndarray]  # (T - washout, n_out) readout outputs
    final_m: jnp.ndarray  # (N, 3) — resumable via StreamSession.m0 / drive(m0=)
    admitted_tick: int
    finished_tick: int
    slot: int


# ---------------------------------------------------------------------------
# jit'd per-tick readout (the integrate tick itself lives in repro/api)
# ---------------------------------------------------------------------------


@jax.jit
def _apply_readouts(states_plane, w_out):
    """Slot-batched readout: (N, E) states x (E, N+1, n_out) -> (E, n_out)."""
    e = states_plane.shape[1]
    xb = jnp.concatenate(
        [states_plane, jnp.ones((1, e), states_plane.dtype)], axis=0
    )
    return jnp.einsum("ne,eno->eo", xb, w_out)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ReservoirEngine:
    """Serve many concurrent reservoir streams from one batched simulator.

    Construct either from a reservoir template (Reservoir or SimSpec —
    topology W^cp/W^in, dt, hold_steps, default params) plus num_slots (the
    ensemble capacity E), in which case the engine compiles an ExecPlan
    itself; or from an already-compiled `repro.api.CompiledSim` (num_slots
    defaults to the plan's ensemble width) — the route to sharded serving:

        sim = compile_plan(spec, ExecPlan(ensemble=64, mesh=mesh))
        eng = ReservoirEngine(sim)
    """

    def __init__(
        self,
        res: Union[Reservoir, SimSpec, CompiledSim],
        num_slots: Optional[int] = None,
        backend: str = "auto",
        n_out: int = 1,
        measure: bool = False,
        interpret: bool = False,
    ):
        if isinstance(res, CompiledSim):
            sim = res
            if num_slots is not None and num_slots != sim.plan.ensemble:
                raise ValueError(
                    f"num_slots ({num_slots}) must match the compiled plan's "
                    f"ensemble width ({sim.plan.ensemble}); omit num_slots to "
                    f"use the plan's"
                )
            if backend != "auto" or measure or interpret:
                raise ValueError(
                    "backend/measure/interpret are ExecPlan decisions; when "
                    "constructing from a CompiledSim, set them on the plan "
                    "passed to compile_plan instead"
                )
            num_slots = sim.plan.ensemble
        else:
            if num_slots is None:
                raise TypeError("num_slots is required when constructing from a reservoir template")
            if backend not in BACKENDS:
                raise ValueError(f"backend must be one of {BACKENDS}; got {backend!r}")
            spec = res if isinstance(res, SimSpec) else SimSpec.from_reservoir(res)
            # backend="auto" resolves inside compile_plan: measured-latency
            # dispatch table (in-process + persisted JSON) > platform gate >
            # VMEM heuristic. On CPU that lands on "ref" — the plain-lax.scan
            # XLA path over the planes layout (unpadded, measured faster than
            # the core-layout scan at every (N, E)); "scan" remains available
            # as the core-layout mode that reproduces solo drive() bit-for-bit.
            sim = compile_plan(
                spec,
                ExecPlan(
                    impl=backend,
                    ensemble=num_slots,
                    interpret=interpret,
                    measure=measure,
                ),
            )
        self.sim = sim
        self.res = sim.spec
        self.store = SlotStore(sim.spec, num_slots, n_out=n_out)
        self.scheduler = SlotScheduler(num_slots)
        self.tick_count = 0
        self.results: Dict[int, SessionResult] = {}
        self.backend = sim.impl

    # -- session lifecycle -------------------------------------------------

    def submit(self, session: StreamSession) -> None:
        u = coerce_input_series(
            session.u_seq, self.store.n_in, self.store.dtype
        )
        if u.shape[0] == 0:
            raise ValueError(f"session {session.sid}: empty input stream")
        session.u_seq = np.asarray(u)
        if session.readout is not None:
            w = np.asarray(session.readout.w_out)
            if w.shape != (self.store.n + 1, self.store.n_out):
                raise ValueError(
                    f"session {session.sid}: readout w_out shape {w.shape} "
                    f"!= ({self.store.n + 1}, {self.store.n_out})"
                )
        self.scheduler.submit(session)

    def _admit_pending(self) -> None:
        for slot, sess in self.scheduler.admissions(self.store.free_slots()):
            self.store.admit(
                slot,
                m0=sess.m0,
                params=sess.params,
                w_out=None if sess.readout is None else sess.readout.w_out,
            )
            sess._slot = slot
            sess._t = 0
            sess._states = []
            sess._outs = []
            sess._admitted_tick = self.tick_count

    def _retire(self, slot: int) -> None:
        sess = self.scheduler.retire(slot)
        states = (
            jnp.stack(sess._states) if sess.collect_states else None
        )  # (T, N)
        outputs = None
        if sess.readout is not None:
            outputs = jnp.stack(sess._outs)[sess.readout.washout :]
        self.results[sess.sid] = SessionResult(
            sid=sess.sid,
            states=states,
            outputs=outputs,
            final_m=self.store.state_column(slot),
            admitted_tick=sess._admitted_tick,
            finished_tick=self.tick_count,
            slot=slot,
        )
        self.store.retire(slot)

    # -- the batched tick --------------------------------------------------

    def _advance(self, u: jnp.ndarray) -> jnp.ndarray:
        """One input tick for every slot; returns the (N, E) states plane."""
        store = self.store
        store.m, states_plane = self.sim.tick(
            store.m,
            u,
            lane_mask=store.active_mask,
            params=store.params_ensemble,
        )
        return states_plane

    def step(self) -> bool:
        """Admit, advance one tick, harvest. Returns False when drained."""
        self._admit_pending()
        running = self.scheduler.running
        if not running:
            return self.scheduler.has_work()

        u = np.zeros((self.store.num_slots, self.store.n_in), self.store.dtype)
        any_readout = False
        for slot, sess in running.items():
            u[slot] = sess.u_seq[sess._t]
            any_readout = any_readout or sess.readout is not None
        states_plane = self._advance(jnp.asarray(u))
        outs = (
            _apply_readouts(states_plane, self.store.w_out)  # (E, n_out)
            if any_readout
            else None
        )
        self.scheduler.on_tick()

        for slot, sess in list(running.items()):
            if sess.collect_states:
                sess._states.append(states_plane[:, slot])
            if sess.readout is not None:
                sess._outs.append(outs[slot])
            sess._t += 1
            if sess._t >= sess.u_seq.shape[0]:
                self._retire(slot)
        self.tick_count += 1
        return True

    def run(
        self, sessions: Optional[List[StreamSession]] = None
    ) -> Dict[int, SessionResult]:
        """Serve sessions to completion; returns sid -> SessionResult."""
        for s in sessions or []:
            self.submit(s)
        while self.scheduler.has_work():
            self.step()
        return self.results
