"""Multi-tenant streaming reservoir inference engine.

The reservoir analogue of continuous batching (serve/engine.py): concurrent
client streams map onto slots of the ensemble axis E, so ONE batched
integrate — `rk4_fused` / `field_tiled` on TPU, a jit'd `lax.scan` on CPU —
advances every active session per input tick. Admitting a session splices
its magnetization state m (N, 3) and per-tenant STOParams lane into the
batched (3, N, E) planes (serve/state_store.py); finished or idle sessions
free their slot without stalling the batch (serve/scheduler.py). Each
session carries its own trained Readout and input stream (NARMA, parity,
sine-approx, ... — anything the reservoir was trained for); readout
application is itself slot-batched (one einsum over E).

Execution rides on the unified API (repro/api): the engine holds a
CompiledSim and its hot path is `CompiledSim.tick_chunk` — a lax.scan over
`ExecPlan.chunk_ticks` input ticks whose states stay in a device-side
buffer until ONE bulk transfer per chunk. `run()` is a double-buffered
pipeline: while the device executes the current chunk (JAX async
dispatch), the host harvests the previous chunk and assembles the next
K-tick u block, applying admissions/retirements to the staging slot store
at chunk boundaries. `step()` keeps the synchronous per-tick path (one
`CompiledSim.tick` + per-slot harvest per call) for externally-clocked
callers and as the pipelined path's baseline.

Under load the engine AUTOSCALES the slot count: a bucketed plan cache
(one `compile_plan` per power-of-two ensemble width between min_slots and
max_slots) lets a chunk boundary grow or shrink the batch by migrating the
occupied SlotStore columns between cached CompiledSims. The decision rule
is a pluggable `serve.scheduler.AutoscalePolicy` fed by the scheduler's
occupancy / queue-depth / queue-wait stats (default: `QueueDepthPolicy`,
grow-on-demand + hysteretic shrink).

With `ExecPlan(learn="rls")` the engine also LEARNS: a session that
submits `targets` next to its inputs gets its readout trained on device
while it streams — per-slot RLS inverse-Gram/weight lanes live in the
SlotStore next to the magnetization, the chunked update rides the same
`tick_chunk` dispatch as the integration (kernels/rls.py), and the
finished session's `SessionResult` carries the trained Readout, the
per-tick a-priori predictions, and the online NMSE. Learning state
migrates through admit/retire and autoscale resizes with the other slot
columns; `core.reservoir.fit_rls(states, targets, block=chunk_ticks)` is
the offline oracle the streamed result bit-matches on the scan backend
(tests/test_rls_learning.py).

Construct from a Reservoir/SimSpec (the engine compiles an ExecPlan for
you; backend="auto" consults the measured-latency dispatch table, persisted
per-platform JSON included, then the VMEM heuristic) or hand the engine an
already-compiled sim — including a sharded one (`ExecPlan(mesh=...)`),
which serves the slot batch across the device mesh with E on the data axes
and N on the model axis. The extra "scan" backend integrates in the core
(E, N, 3) layout with exactly `reservoir.drive`'s math, so per-session
streamed states are numerically indistinguishable from running the stream
alone — chunked or per-tick (tests/test_serve_chunked.py pins the K>1 /
K=1 bit-equality); every other backend agrees with solo runs to the kernel
test suite's tolerance (tests/test_serve_reservoir.py pins all of them).

Tenancy is SPEC-LEVEL, not just params-level: a StreamSession may carry
its own SimSpec. Sessions whose spec structurally matches the engine's
template (same `repro.api.spec_structural_hash` — shapes, dtype, topology
contents, physics family; scalar param values excluded) serve in a primary
lane with the spec's params riding the lane. Sessions whose spec hashes
differently — another physics family (`topology="time_multiplexed"` /
"array_transient"), another N, dt, hold window, coupling matrix — land on
an internal per-hash sub-engine compiled through the shared PLAN_CACHE, so
a coupled-array tenant and a time-multiplexed tenant stream through ONE
engine concurrently, each bit-identical to a solo run of its own spec
(tests/conformance/test_mixed_tenants.py). Sub-engine sessions ride the
same results map, push/append, checkpoint/restore, and stats surface.

This is the serving front for time-multiplexed STO reservoir hardware
(Riou et al., arXiv:1904.11236; Kanao et al., arXiv:1905.07937): each
tenant's device parameters ride in a params lane, the shared simulator
advances all of them in lockstep.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (
    FAMILY_IMPLS,
    PLAN_CACHE,
    CompiledSim,
    ExecPlan,
    SimSpec,
    compile_plan,
    spec_structural_hash,
)
from repro.api.cache import _params_equal
from repro.core.constants import STOParams
from repro.core.reservoir import Readout, Reservoir, coerce_input_series
from repro.serve.scheduler import AutoscalePolicy, QueueDepthPolicy, SlotScheduler
from repro.serve.state_store import SlotStore

BACKENDS = ("auto", "scan", "ref", "fused", "tiled", "chunk")


@dataclasses.dataclass
class StreamSession:
    """One tenant's streaming request.

    u_seq follows drive()'s explicit (T, N_in) contract ((T,) for
    n_in == 1). params overrides the engine reservoir's physical parameters
    for this tenant's lane; readout is the tenant's trained linear readout
    (None = state-collection only, e.g. to fit a readout afterwards); m0
    resumes from a previous session's final state.

    On a learning engine (`ExecPlan.learn="rls"`), `targets` turns the
    session into an ONLINE-LEARNING stream: one (T, n_out) target row per
    input row ((T,) for n_out == 1), and the engine trains this tenant's
    readout on device while it streams — every tick's RLS update rides the
    same `tick_chunk` dispatch as the integration. `learn_washout` skips
    the update for the first ticks (reservoir warm-up; predictions are
    still recorded). If `readout` is also set, it WARM-STARTS the learned
    weights (and still drives the static `outputs` column). The trained
    readout, per-tick a-priori predictions, and online NMSE come back on
    the SessionResult.

    Per-session output width: a session's n_out is inferred from its
    readout / targets column count and may be anything in
    [1, engine n_out] — the engine pads the narrow session onto its
    store-width readout lanes with zero columns (RLS weight columns evolve
    independently given the shared gain, so padding is exact) and slices
    results back to the session's own width.

    `open=True` marks a PUSH stream: the session stays resident after its
    current input is exhausted (its lane idles, state frozen) until
    `engine.append_ticks(sid, ...)` supplies more rows or
    `engine.close_session(sid)` lets it finish. The fleet front-end's
    `push_ticks` rides this.

    `learn_w0` / `learn_P0` resume an RLS recursion mid-stream (weights +
    inverse-Gram) — the checkpoint/migration path; fresh sessions leave
    them None (`readout` alone warm-starts weights with a fresh P).
    """

    sid: int
    u_seq: np.ndarray
    params: Optional[STOParams] = None
    readout: Optional[Readout] = None
    m0: Optional[jnp.ndarray] = None
    collect_states: bool = True
    targets: Optional[np.ndarray] = None  # (T, n_out) online-learning targets
    learn_washout: int = 0  # ticks before the first RLS update
    open: bool = False  # True: idle (don't finish) when input runs dry
    learn_w0: Optional[np.ndarray] = None  # (N+1, n_out) RLS weight resume
    learn_P0: Optional[np.ndarray] = None  # (N+1, N+1) inverse-Gram resume
    # Spec-level multi-tenancy: a session that carries its OWN SimSpec is
    # routed by structural hash — same hash as the engine's template means
    # same compiled physics (the spec's scalar params become the session's
    # lane values, unless `params` was set explicitly); a different hash
    # (other topology family, other N/dt/hold_steps/w_cp/...) lands on an
    # internal sub-engine compiled for that spec through the shared
    # PLAN_CACHE. None = classic behavior: the engine's template spec.
    spec: Optional[SimSpec] = None

    # engine-internal bookkeeping (set on admit)
    _slot: int = dataclasses.field(default=-1, repr=False)
    _t: int = dataclasses.field(default=0, repr=False)
    _states: list = dataclasses.field(default_factory=list, repr=False)
    _outs: list = dataclasses.field(default_factory=list, repr=False)
    _preds: list = dataclasses.field(default_factory=list, repr=False)
    _admitted_tick: int = dataclasses.field(default=-1, repr=False)
    _finished_tick: int = dataclasses.field(default=-1, repr=False)
    _n_out: int = dataclasses.field(default=1, repr=False)  # session width
    _restored: bool = dataclasses.field(default=False, repr=False)
    # set by the nan guard when this tenant's lane went non-finite; the
    # session is force-retired at the next boundary with the message on
    # its SessionResult.error
    _error: Optional[str] = dataclasses.field(default=None, repr=False)


@dataclasses.dataclass
class SessionResult:
    sid: int
    # states/outputs are host (numpy) arrays — harvested device->host once;
    # final_m resumes a stream via StreamSession.m0 / drive(m0=) (host on
    # the chunked path, device on the per-tick path; both coerce on use)
    states: Optional[np.ndarray]  # (T, N) streamed node states
    outputs: Optional[np.ndarray]  # (T - washout, n_out) readout outputs
    final_m: np.ndarray  # (N, 3)
    admitted_tick: int
    finished_tick: int
    slot: int
    # online learning (sessions submitted with targets on a learning engine)
    predictions: Optional[np.ndarray] = None  # (T, n_out) a-priori per tick
    learned_readout: Optional[Readout] = None  # final trained W (washout=0)
    learn_nmse: Optional[float] = None  # online NMSE after learn_washout
    # structured failure: set when the engine quarantined this tenant's
    # lane (non-finite state/outputs detected). The harvested arrays above
    # then hold the clean prefix BEFORE the offending chunk; co-tenant
    # lanes are untouched (tests/test_fleet_faults.py pins bit-equality).
    error: Optional[str] = None


@dataclasses.dataclass
class SessionCheckpoint:
    """A mid-stream session frozen for migration between engines/replicas.

    Every field is a host (numpy) array or plain scalar, so a checkpoint
    pickles across a process-transport pipe unchanged. `u_seq`/`targets`
    carry the FULL stream (targets at the session's own n_out width, not
    the source store's padded width); `t` marks how far the source engine
    got; `states`/`outs`/`preds` are the already-harvested prefix. `m` is
    the magnetization at tick t, and `P`/`Wl` the in-flight RLS learner
    (None for inference sessions) — restoring injects them back into the
    destination SlotStore columns, so the resumed stream is bit-identical
    to one that never moved (tests/test_fleet.py pins this)."""

    sid: int
    u_seq: np.ndarray  # (T, N_in) full input stream
    t: int  # ticks already served by the source engine
    m: Optional[np.ndarray]  # (N, 3) at tick t (None: queued, never ran)
    params: Optional[STOParams]
    readout_w: Optional[np.ndarray]  # (N+1, q) static readout, unpadded
    readout_washout: int
    collect_states: bool
    targets: Optional[np.ndarray]  # (T, q) full targets, unpadded
    learn_washout: int
    open: bool
    n_out: int  # the session's own output width q
    states: Optional[np.ndarray]  # (t, N) harvested prefix
    outs: Optional[np.ndarray]  # (t, q) harvested prefix
    preds: Optional[np.ndarray]  # (t, q) harvested prefix
    P: Optional[np.ndarray]  # (S, S) in-flight RLS inverse-Gram
    Wl: Optional[np.ndarray]  # (S, q) in-flight learned weights, unpadded
    # mixed-spec tenants: the session's own SimSpec (host-numpy leaves so
    # the checkpoint still pickles); restore_session re-routes from it
    spec: Optional[SimSpec] = None


@dataclasses.dataclass
class EngineStats:
    """One engine's load/latency snapshot — plain scalars only, so it
    pickles across the replica transport. The fleet router compares these
    live measurements against the capacity planner's predictions."""

    n: int
    num_slots: int
    active: int
    queued: int
    backend: str
    precision: Optional[str]
    learn: Optional[str]
    chunk_ticks: int
    ticks: int
    session_ticks: int
    occupancy: float
    queue_depth: int
    mean_queue_wait: float
    grows: int
    shrinks: int
    detached: int
    # rescale compile behavior (see SchedulerStats): cold = bucket had to
    # compile at the boundary, stalling rescale_stall_s total seconds
    cold_rescales: int
    warm_rescales: int
    rescale_stall_s: float
    chunk_median_s: Optional[float]  # median wall time of recent chunks
    chunks_timed: int
    ticks_per_sec: Optional[float]  # E * K / chunk_median_s
    # spec-level multi-tenancy: internal sub-engines serving sessions whose
    # SimSpec hash differs from the template's (appended with a default so
    # stats pickled by older replicas still unpickle)
    sub_engines: int = 0
    # fault tolerance: tenant lanes the nan guard quarantined (sub-engines
    # included), and the owning replica's health (`healthy | degraded |
    # dead` — stamped by the replica transport, "healthy" for a bare
    # engine). Defaults keep older pickled stats loadable.
    quarantined_lanes: int = 0
    health: str = "healthy"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _ChunkPlan:
    """One launched chunk's host-side record: who occupied which slot for
    how many of the K ticks, plus the device handles to harvest."""

    # (session, slot, n_ticks served in rows [0, n_ticks) of the chunk)
    entries: List[Tuple[StreamSession, int, int]]
    u: np.ndarray  # (K, E, N_in) assembled input block
    mask: np.ndarray  # (K, E) per-tick lane activity
    any_readout: bool
    states_block: Optional[jnp.ndarray] = None  # (K, N, E) device
    outs_block: Optional[jnp.ndarray] = None  # (K, E, n_out) device
    # learning engines only
    targets: Optional[np.ndarray] = None  # (K, E, n_out) target rows
    lmask: Optional[np.ndarray] = None  # (K, E) who LEARNS which tick
    any_learn: bool = False
    preds_block: Optional[jnp.ndarray] = None  # (K, E, n_out) device


# ---------------------------------------------------------------------------
# jit'd readout application (the integrate tick itself lives in repro/api)
# ---------------------------------------------------------------------------


@jax.jit
def _apply_readouts(states_plane, w_out):
    """Slot-batched readout: (N, E) states x (E, N+1, n_out) -> (E, n_out)."""
    e = states_plane.shape[1]
    xb = jnp.concatenate(
        [states_plane, jnp.ones((1, e), states_plane.dtype)], axis=0
    )
    return jnp.einsum("ne,eno->eo", xb, w_out)


def _apply_readouts_chunk(states_block, w_out):
    """Chunked readout: (K, N, E) x (E, N+1, n_out) -> (K, E, n_out).

    K dispatches of the SAME compiled `_apply_readouts` the per-tick path
    uses, stacked on device — a single batched einsum ("kne,eno->keo")
    contracts in a different order and drifts from the per-tick outputs by
    a ULP, and chunked serving pins bit-equality with per-tick serving.
    The stack stays device-side until the once-per-chunk harvest."""
    return jnp.stack(
        [_apply_readouts(states_block[t], w_out) for t in range(states_block.shape[0])]
    )


def _spec_host(spec: Optional[SimSpec]) -> Optional[SimSpec]:
    """A SimSpec with every array leaf pulled to host numpy, so it rides a
    SessionCheckpoint across the pickling replica transport unchanged.
    Structural hashes are byte-identical (the hash canonicalizes through
    numpy), so routing on restore lands on the same sub-engine key."""
    if spec is None:
        return None
    params = type(spec.params)(*[np.asarray(leaf) for leaf in spec.params])
    return spec._replace(
        params=params,
        w_cp=np.asarray(spec.w_cp),
        w_in=np.asarray(spec.w_in),
        m0=np.asarray(spec.m0),
    )


def _bucket_slots(demand: int, min_slots: int, max_slots: int) -> int:
    """Smallest cached bucket covering demand: min_slots * 2^k, clamped.

    Power-of-two widths keep the plan cache tiny (log2 of the range) and —
    for buckets >= the kernels' LANE — MXU-aligned, so every bucket's padded
    shapes are ones the dispatch table already knows."""
    b = min_slots
    while b < demand and b < max_slots:
        b *= 2
    return min(b, max_slots)


def _bucket_ladder(min_slots: int, max_slots: int) -> List[int]:
    """Every width `_bucket_slots` can return: min_slots * 2^k while below
    max_slots, plus the clamp bucket max_slots itself (which need not be a
    power-of-two multiple)."""
    ladder = []
    b = min_slots
    while b < max_slots:
        ladder.append(b)
        b *= 2
    ladder.append(max_slots)
    return ladder


def _ensemble_axis_size(plan: ExecPlan) -> int:
    """Devices the ensemble axis spans on a sharded plan (1 if unsharded)."""
    if plan.mesh is None:
        return 1
    size = 1
    for a in plan.ensemble_axes:
        size *= int(plan.mesh.shape[a])
    return size


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


class ReservoirEngine:
    """Serve many concurrent reservoir streams from one batched simulator.

    Construct either from a reservoir template (Reservoir or SimSpec —
    topology W^cp/W^in, dt, hold_steps, default params) plus num_slots (the
    ensemble capacity E), in which case the engine compiles an ExecPlan
    itself; or from an already-compiled `repro.api.CompiledSim` (num_slots
    defaults to the plan's ensemble width) — the route to sharded serving:

        sim = compile_plan(spec, ExecPlan(ensemble=64, mesh=mesh, chunk_ticks=16))
        eng = ReservoirEngine(sim)

    Serving knobs:
      chunk_ticks   (template route; CompiledSim route: set on the ExecPlan)
                    K ticks per dispatch — `run()` pipelines K-tick chunks.
      max_retained  cap on finished SessionResults kept in `results`; oldest
                    are evicted. Pair with `pop_results()` for long-running
                    serving so retired-session state can't accumulate.
      autoscale     an AutoscalePolicy (or True for QueueDepthPolicy()):
                    grow/shrink the slot count between min_slots and
                    max_slots at chunk boundaries via the bucketed plan
                    cache (powers of two from min_slots).
      learn         "rls" or "lms" (template route; CompiledSim route: set
                    on the ExecPlan) enables online readout learning for
                    sessions that submit targets; learn_lam / learn_reg are
                    the RLS forgetting factor and regularization, learn_mu
                    the NLMS step size (see repro.api.plan.ExecPlan).
                    Learning engines serve through the chunked path
                    (run()/step_chunk()) only.
      precision     numerical policy for the compute-bound GEMMs (template
                    route; CompiledSim route: set on the ExecPlan):
                    None/"highest" bit-exact, "bf16_coupling"/"mixed"
                    reduced — see repro.api.plan.ExecPlan.precision.
      compilation_cache_dir  (template route) opt into JAX's persistent
                    compilation cache so cold-start survives restarts —
                    see repro.api.plan.ExecPlan.compilation_cache_dir.
      prewarm       autoscale engines pre-compile + warm the adjacent
                    buckets in a background daemon thread (at construction
                    and after every rescale), so `_rescale` at a chunk
                    boundary finds its bucket ready in the process-wide
                    PlanCache — zero XLA stall. prewarm=False disables the
                    thread (deterministic compile counting in tests);
                    `prewarm_buckets(block=True)` warms explicitly.

    Compilation is shared: the template route and every rescale draw from
    `repro.api.PLAN_CACHE`, so repeated engines over the same topology and
    plan (fleet replicas, tune combos) compile once per process.
    """

    def __init__(
        self,
        res: Union[Reservoir, SimSpec, CompiledSim],
        num_slots: Optional[int] = None,
        backend: str = "auto",
        n_out: int = 1,
        measure: bool = False,
        interpret: bool = False,
        chunk_ticks: Optional[int] = None,
        max_retained: Optional[int] = None,
        autoscale: Union[AutoscalePolicy, bool, None] = None,
        min_slots: Optional[int] = None,
        max_slots: Optional[int] = None,
        learn: Optional[str] = None,
        learn_lam: Optional[float] = None,
        learn_reg: Optional[float] = None,
        learn_mu: Optional[float] = None,
        precision: Optional[str] = None,
        compilation_cache_dir: Optional[str] = None,
        prewarm: bool = True,
        nan_guard: bool = True,
    ):
        if isinstance(res, CompiledSim):
            sim = res
            if num_slots is not None and num_slots != sim.plan.ensemble:
                raise ValueError(
                    f"num_slots ({num_slots}) must match the compiled plan's "
                    f"ensemble width ({sim.plan.ensemble}); omit num_slots to "
                    f"use the plan's"
                )
            if (
                backend != "auto"
                or measure
                or interpret
                or chunk_ticks is not None
                or learn is not None
                or learn_lam is not None
                or learn_reg is not None
                or learn_mu is not None
                or precision is not None
                or compilation_cache_dir is not None
            ):
                raise ValueError(
                    "backend/measure/interpret/chunk_ticks/learn*/precision/"
                    "compilation_cache_dir are ExecPlan decisions; when "
                    "constructing from a CompiledSim, set them on the plan "
                    "passed to compile_plan instead"
                )
            num_slots = sim.plan.ensemble
        else:
            if num_slots is None:
                raise TypeError("num_slots is required when constructing from a reservoir template")
            if backend not in BACKENDS:
                raise ValueError(f"backend must be one of {BACKENDS}; got {backend!r}")
            spec = res if isinstance(res, SimSpec) else SimSpec.from_reservoir(res)
            # backend="auto" resolves inside compile_plan: measured-latency
            # dispatch table (in-process + persisted JSON) > platform gate >
            # VMEM heuristic. On CPU that lands on "ref" — the plain-lax.scan
            # XLA path over the planes layout (unpadded, measured faster than
            # the core-layout scan at every (N, E)); "scan" remains available
            # as the core-layout mode that reproduces solo drive() bit-for-bit.
            # Drawn through the process-wide PlanCache: engines built from
            # the same topology + plan (fleet replicas, repeated spin-ups)
            # share one CompiledSim instead of re-tracing it.
            sim = PLAN_CACHE.get_or_compile(
                spec,
                ExecPlan(
                    impl=backend,
                    ensemble=num_slots,
                    interpret=interpret,
                    measure=measure,
                    chunk_ticks=1 if chunk_ticks is None else chunk_ticks,
                    learn=learn,
                    learn_lam=1.0 if learn_lam is None else learn_lam,
                    learn_reg=1e-6 if learn_reg is None else learn_reg,
                    learn_mu=0.5 if learn_mu is None else learn_mu,
                    precision=precision,
                    compilation_cache_dir=compilation_cache_dir,
                ),
            )
        self.sim = sim
        self.res = sim.spec
        self._spec_hash = spec_structural_hash(sim.spec)
        self.chunk_ticks = sim.plan.chunk_ticks
        self.learn = sim.plan.learn
        self.store = SlotStore(
            sim.spec,
            num_slots,
            n_out=n_out,
            learn=self.learn,
            learn_reg=sim.plan.learn_reg,
        )
        self.scheduler = SlotScheduler(num_slots)
        self.tick_count = 0
        self.results: Dict[int, SessionResult] = {}
        self.max_retained = max_retained
        self.backend = sim.impl
        # the plan's numerical policy ("highest" = bit-exact default) — the
        # serve bench reports it per cell alongside the backend
        self.precision = sim.precision

        # -- autoscaling: bucketed plan cache over ensemble widths ---------
        if autoscale is True:
            autoscale = QueueDepthPolicy()
        self.autoscale: Optional[AutoscalePolicy] = autoscale or None
        self.min_slots = num_slots if min_slots is None else min_slots
        self.max_slots = num_slots if max_slots is None else max_slots
        if self.autoscale is not None:
            if not (1 <= self.min_slots <= num_slots <= self.max_slots):
                raise ValueError(
                    f"autoscale bounds must satisfy 1 <= min_slots <= "
                    f"num_slots <= max_slots; got min={self.min_slots} "
                    f"num={num_slots} max={self.max_slots}"
                )
            if sim.plan.sharded:
                # every reachable bucket width must divide evenly across
                # the mesh's ensemble axis, or a rescale would strand lanes
                # on a decomposition the shard_map body can't express
                axis = _ensemble_axis_size(sim.plan)
                widths = [num_slots] + _bucket_ladder(self.min_slots, self.max_slots)
                bad = sorted({w for w in widths if w % axis})
                if bad:
                    raise ValueError(
                        "autoscale on a sharded plan requires every bucket "
                        "width to be divisible by the ensemble-axis size "
                        f"{axis} (mesh axes {tuple(sim.plan.ensemble_axes)}); "
                        f"min_slots={self.min_slots} / max_slots="
                        f"{self.max_slots} reach incompatible widths {bad}"
                    )
            leaf = jnp.asarray(sim.spec.params.gamma)
            if leaf.ndim != 0:
                raise ValueError(
                    "autoscale requires scalar-leaved spec params (per-tenant "
                    "params ride in session lanes, not the spec)"
                )
        self._sims: Dict[int, CompiledSim] = {num_slots: sim}
        # background pre-warm of adjacent autoscale buckets (daemon thread;
        # advisory — _rescale compiles on demand if the thread hasn't won)
        self._prewarm_enabled = bool(prewarm)
        self._prewarm_thread: Optional[threading.Thread] = None
        if self._prewarm_enabled and self.autoscale is not None:
            self.prewarm_buckets()

        # -- pipelined-chunk bookkeeping ------------------------------------
        # sessions whose final tick was served by the most recently LAUNCHED
        # chunk (slot still holds their state until the next boundary)
        self._finishing: List[Tuple[int, StreamSession]] = []
        # one boundary's retired sessions awaiting their last chunk's
        # harvest: ([(slot, session), ...], (k, N, 3) final-m device block,
        # (k, S, n_out) learned-W device block or None)
        self._awaiting: Optional[
            Tuple[
                List[Tuple[int, StreamSession]],
                jnp.ndarray,
                Optional[jnp.ndarray],
            ]
        ] = None
        # device copy of the last chunk's lane-mask block; steady-state
        # chunks repeat the same mask, so skip the re-upload (same for the
        # learn mask — constant once every learner is past washout)
        self._mask_np: Optional[np.ndarray] = None
        self._mask_dev: Optional[jnp.ndarray] = None
        self._lmask_np: Optional[np.ndarray] = None
        self._lmask_dev: Optional[jnp.ndarray] = None
        # -- tenant lane quarantine -----------------------------------------
        # nan_guard=True: every harvested chunk's state/output/prediction
        # blocks are scanned for non-finite values (one aggregate isfinite
        # per block on the cheap path); an offending tenant's lane is
        # QUARANTINED — force-retired at the next boundary with a
        # structured SessionResult.error — while co-tenant lanes stream on
        # bit-identically (lanes are independent columns of the E axis).
        self.nan_guard = bool(nan_guard)
        self._quarantine: List[Tuple[int, StreamSession]] = []
        # the launched-but-unharvested chunk (the pipeline's second buffer)
        self._pending: Optional[_ChunkPlan] = None
        # wall time of recent step_chunk calls that launched work — the
        # stats() latency signal the fleet planner checks itself against
        self._chunk_times: deque = deque(maxlen=128)
        # -- spec-level multi-tenancy ---------------------------------------
        # sessions whose SimSpec structural hash differs from the template's
        # serve on an internal sub-engine compiled for THEIR spec (one per
        # distinct hash, drawn through the shared PLAN_CACHE); step_chunk
        # advances them in lockstep and drains their results into ours
        self._subengines: Dict[str, "ReservoirEngine"] = {}

    @property
    def num_slots(self) -> int:
        return self.store.num_slots

    # -- session lifecycle -------------------------------------------------

    def _pad_cols(self, a: np.ndarray, what: str, sid: int) -> np.ndarray:
        """Zero-pad the trailing (column) axis to the store's n_out width.

        Per-session n_out: a session whose readout/targets carry q < n_out
        columns rides the store-width lanes with zero columns appended.
        RLS weight columns update independently given the shared gain
        (W' = W + k e^T is column-wise), so the padding columns never
        perturb the real ones and results slice back exactly."""
        q = a.shape[-1]
        if q == self.store.n_out:
            return a
        if q > self.store.n_out:
            raise ValueError(
                f"session {sid}: {what} has {q} output columns but the "
                f"engine was built with n_out={self.store.n_out}; construct "
                f"ReservoirEngine(..., n_out={q}) (or wider) to serve it"
            )
        pad = np.zeros(a.shape[:-1] + (self.store.n_out - q,), a.dtype)
        return np.concatenate([a, pad], axis=-1)

    # -- spec-level multi-tenancy -------------------------------------------

    def _route_spec(self, session: StreamSession) -> Optional["ReservoirEngine"]:
        """Resolve a spec-carrying session to the engine that serves it.

        Returns None when the session belongs on THIS engine (its spec
        structurally matches the template: same shapes/dtype/topology
        contents/family — the hash ignores scalar param values, which ride
        the session's lane instead), or the per-hash sub-engine otherwise.
        """
        spec = session.spec
        leaf = jnp.asarray(spec.params.gamma)
        if leaf.ndim != 0:
            raise ValueError(
                f"session {session.sid}: a session spec must carry "
                f"scalar-leaved params (per-lane values are the lane's job; "
                f"ensemble-leaved sweeps belong on the engine template)"
            )
        h = spec_structural_hash(spec)
        if h == self._spec_hash:
            # structurally the template's physics: serve in a primary lane.
            # The spec's scalar params become the lane values unless the
            # session pinned its own params explicitly (explicit wins).
            if session.params is None and not _params_equal(
                spec.params, self.res.params
            ):
                session.params = spec.params
            return None
        sub = self._subengines.get(h)
        if sub is None:
            sub = self._make_subengine(spec)
            self._subengines[h] = sub
        return sub

    def _make_subengine(self, spec: SimSpec) -> "ReservoirEngine":
        """Compile + wrap a sub-engine for a structurally different spec.

        The sub-plan is the template plan at the engine's min_slots width —
        drawn through the process-wide PLAN_CACHE, so two engines (or two
        lifetimes of one engine) serving the same foreign spec compile it
        once. An impl the spec's physics family cannot execute (e.g. a
        fused Pallas template serving a time_multiplexed tenant) falls back
        to impl="auto", which resolves to a family-capable backend inside
        compile_plan. Sharded templates refuse: families do not shard, and
        silently serving a tenant unsharded on a mesh engine would lie
        about its placement.
        """
        plan = self.sim.plan
        if plan.mesh is not None:
            raise ValueError(
                "mixed-spec tenancy is not supported on sharded engines — "
                "a sub-engine cannot inherit the mesh decomposition; serve "
                f"the {spec.topology!r} spec from an unsharded engine"
            )
        impl = plan.impl
        if impl not in FAMILY_IMPLS.get(spec.topology, ()):
            impl = "auto"
        sub_plan = dataclasses.replace(
            plan, ensemble=self.min_slots, impl=impl
        )
        sim = PLAN_CACHE.get_or_compile(spec, sub_plan)
        return ReservoirEngine(
            sim,
            n_out=self.store.n_out,
            max_retained=self.max_retained,
            prewarm=False,
            nan_guard=self.nan_guard,
        )

    def submit(self, session: StreamSession) -> None:
        if session.spec is not None:
            sub = self._route_spec(session)
            if sub is not None:
                sub.submit(session)
                return
        # xp=np: the engine assembles u blocks host-side, so the series must
        # stay a numpy array — coercing through the device would round-trip
        # every stream through HBM for nothing
        u = coerce_input_series(
            session.u_seq, self.store.n_in, self.store.dtype, xp=np
        )
        if u.shape[0] == 0 and not session.open:
            raise ValueError(f"session {session.sid}: empty input stream")
        session.u_seq = u
        n_out = None  # the session's own width, inferred below
        if session.readout is not None:
            w = np.asarray(session.readout.w_out)
            if w.ndim != 2 or w.shape[0] != self.store.n + 1 or not (
                1 <= w.shape[1] <= self.store.n_out
            ):
                raise ValueError(
                    f"session {session.sid}: readout w_out shape "
                    f"{tuple(w.shape)} must be ({self.store.n + 1}, q) with "
                    f"1 <= q <= {self.store.n_out} (the engine's n_out)"
                )
            n_out = w.shape[1]
        if session.targets is not None:
            if self.learn is None:
                raise ValueError(
                    f"session {session.sid}: targets require a learning "
                    f"engine — compile the plan with ExecPlan(learn='rls') "
                    f"or learn='lms' (or pass learn=... to ReservoirEngine)"
                )
            t = np.asarray(session.targets, dtype=self.store.dtype)
            if t.ndim == 1:
                t = t[:, None]
            if (
                t.ndim != 2
                or t.shape[0] != u.shape[0]
                or not (1 <= t.shape[1] <= self.store.n_out)
            ):
                raise ValueError(
                    f"session {session.sid}: targets must have shape "
                    f"({u.shape[0]}, q) — one row per input row, "
                    f"1 <= q <= {self.store.n_out} — or ({u.shape[0]},) for "
                    f"q == 1; got {tuple(np.shape(session.targets))}"
                )
            if n_out is not None and t.shape[1] != n_out:
                raise ValueError(
                    f"session {session.sid}: targets carry {t.shape[1]} "
                    f"output columns but the readout carries {n_out}; a "
                    f"session has ONE output width"
                )
            n_out = t.shape[1]
            # store-width padded targets: chunk assembly copies rows straight
            # into the (K, E, n_out) block; results slice back to q columns
            session.targets = self._pad_cols(t, "targets", session.sid)
            if (
                isinstance(session.learn_washout, bool)
                or not isinstance(session.learn_washout, int)
                or session.learn_washout < 0
            ):
                raise ValueError(
                    f"session {session.sid}: learn_washout must be an int "
                    f">= 0; got {session.learn_washout!r}"
                )
        session._n_out = self.store.n_out if n_out is None else n_out
        if session.learn_w0 is not None or session.learn_P0 is not None:
            if self.learn is None or session.targets is None:
                raise ValueError(
                    f"session {session.sid}: learn_w0/learn_P0 resume a "
                    f"learn recursion — they require a learning engine and "
                    f"targets"
                )
            if session.learn_P0 is not None and self.learn == "lms":
                raise ValueError(
                    f"session {session.sid}: learn_P0 resumes an RLS "
                    f"inverse-Gram — learn='lms' carries no P; resume LMS "
                    f"sessions with learn_w0 alone"
                )
            if session.learn_w0 is not None:
                w0 = np.asarray(session.learn_w0, self.store.dtype)
                if w0.shape != (self.store.n + 1, session._n_out):
                    raise ValueError(
                        f"session {session.sid}: learn_w0 shape "
                        f"{tuple(w0.shape)} != ({self.store.n + 1}, "
                        f"{session._n_out})"
                    )
                session.learn_w0 = w0
            if session.learn_P0 is not None:
                p0 = np.asarray(session.learn_P0, self.store.dtype)
                s = self.store.n + 1
                if p0.shape != (s, s):
                    raise ValueError(
                        f"session {session.sid}: learn_P0 shape "
                        f"{tuple(p0.shape)} != ({s}, {s})"
                    )
                session.learn_P0 = p0
        self.scheduler.submit(session)

    def _admit_pending(self) -> None:
        placed = self.scheduler.admissions(self.store.free_slots())
        if not placed:
            return
        items = []
        for slot, sess in placed:
            w_out = None
            if sess.readout is not None:
                w_out = self._pad_cols(
                    np.asarray(sess.readout.w_out, self.store.dtype),
                    "readout",
                    sess.sid,
                )
            # a learning session's lane warm-starts from (priority order)
            # a migration checkpoint's in-flight weights, else its provided
            # readout, else zeros; learn_P0 resumes the inverse-Gram
            w_learn = None
            p_learn = None
            if sess.targets is not None:
                if sess.learn_w0 is not None:
                    w_learn = self._pad_cols(
                        sess.learn_w0, "learn_w0", sess.sid
                    )
                else:
                    w_learn = w_out
                if sess.learn_P0 is not None:
                    p_learn = sess.learn_P0
            items.append(
                (slot, sess.m0, sess.params, w_out, w_learn, p_learn)
            )
            sess._slot = slot
            if sess._restored:
                # a migrated session resumes mid-stream: _t and the
                # harvested prefix were seeded by restore_session()
                sess._restored = False
            else:
                sess._t = 0
                sess._states = []
                sess._outs = []
                sess._preds = []
            sess._admitted_tick = self.tick_count
        self.store.admit_many(items)  # one scatter per array, not per session

    def _record_result(
        self,
        sess: StreamSession,
        slot: int,
        final_m: jnp.ndarray,
        learned_w: Optional[np.ndarray] = None,
    ) -> None:
        """Assemble a SessionResult from the session's harvested pieces.

        The per-tick path accumulates (N,) device state rows / (n_out,)
        output rows; the chunked path accumulates host (n, N) / (n, n_out)
        blocks — both concatenate to the same (T, N) / (T, n_out).
        Assembly is numpy: the chunked path's blocks were already bulk
        device->host transfers, and re-uploading the history just so the
        caller can pull it back down would round-trip every finished
        session's full state through the device."""
        # empty accumulators (a lane quarantined before its first harvest)
        # yield (0, width) arrays so error results keep uniform shapes
        states = None
        if sess.collect_states:
            states = (
                np.concatenate([np.atleast_2d(np.asarray(s)) for s in sess._states])
                if sess._states
                else np.zeros((0, self.store.n), self.store.dtype)
            )
        outputs = None
        if sess.readout is not None:
            outs = (
                np.concatenate([np.atleast_2d(np.asarray(o)) for o in sess._outs])
                if sess._outs
                else np.zeros((0, sess._n_out), self.store.dtype)
            )
            outputs = outs[sess.readout.washout :]
        predictions = None
        learned_readout = None
        learn_nmse = None
        if sess.targets is not None:
            q = sess._n_out
            predictions = (
                np.concatenate([np.atleast_2d(np.asarray(p)) for p in sess._preds])
                if sess._preds
                else np.zeros((0, q), self.store.dtype)
            )
            if learned_w is not None:
                # washout=0: the trained readout applies to arbitrary
                # states; padding columns (store width > session width)
                # slice off so the tenant gets back exactly its shape
                learned_readout = Readout(
                    w_out=jnp.asarray(learned_w[:, :q]), washout=0
                )
            wo = sess.learn_washout
            if predictions.shape[0] > wo:
                p, y = predictions[wo:], sess.targets[wo:, :q]
                learn_nmse = float(
                    np.mean((p - y) ** 2) / (np.var(y) + 1e-30)
                )
        self.results[sess.sid] = SessionResult(
            sid=sess.sid,
            states=states,
            outputs=outputs,
            final_m=final_m,
            admitted_tick=sess._admitted_tick,
            finished_tick=sess._finished_tick,
            slot=slot,
            predictions=predictions,
            learned_readout=learned_readout,
            learn_nmse=learn_nmse,
            error=sess._error,
        )
        sess._states = []
        sess._outs = []
        sess._preds = []
        if self.max_retained is not None:
            while len(self.results) > self.max_retained:
                self.results.pop(next(iter(self.results)))

    def submit_autotuned(
        self,
        session: StreamSession,
        space,
        budget: int = 8,
        strategy="random",
        seed: int = 0,
        **kwargs,
    ):
        """Auto-tune this session's lane knobs during its washout window,
        then submit it with the winning parameters.

        `space` is a `repro.tune.SearchSpace` over LANE knobs (STOParams
        fields — structural knobs would need a recompile, which a live
        engine cannot do). Probe sessions stream the tenant's washout
        prefix on spare lanes with negative sids, scored by the fused
        online learner; the best assignment is frozen into
        `session.params` and the session submits normally. Returns the
        probe `TuneResult` (trial history + winner). Requires a learning
        engine and a learning session with learn_washout >= 2.

        Thin delegate to `repro.tune.washout_autotune` (imported lazily:
        serve must not depend on tune at import time — tune drives serve).
        """
        from repro.tune.driver import washout_autotune

        return washout_autotune(
            self, session, space,
            budget=budget, strategy=strategy, seed=seed, **kwargs,
        )

    def pop_results(self) -> Dict[int, SessionResult]:
        """Drain finished-session results: returns sid -> SessionResult and
        clears the retained map. Long-running serving loops should call this
        (or set max_retained) so retired-session state cannot accumulate."""
        out = self.results
        self.results = {}
        return out

    def _retire(self, slot: int) -> None:
        """Per-tick path: retire immediately (state column is current)."""
        sess = self.scheduler.retire(slot)
        sess._finished_tick = self.tick_count
        final_m = self.store.state_column(slot)
        self._record_result(sess, slot, final_m)
        self.store.retire(slot)

    # -- autoscaling --------------------------------------------------------

    def _maybe_autoscale(self) -> None:
        sched = self.scheduler
        active = len(sched.running)
        target = self.autoscale.target_slots(
            active=active,
            queued=len(sched.queue),
            num_slots=self.num_slots,
            min_slots=self.min_slots,
            max_slots=self.max_slots,
        )
        target = max(target, active, 1)
        bucket = _bucket_slots(target, self.min_slots, self.max_slots)
        if bucket != self.num_slots:
            self._rescale(bucket)

    def _rescale(self, new_e: int) -> None:
        """Migrate serving onto the cached CompiledSim of width new_e.

        Occupied slots compact into the low lanes of the new store (one
        gather-scatter of the (3, N, E) planes + readout lanes); running
        sessions keep streaming across the boundary bit-identically.

        The bucket is drawn from the process-wide PlanCache. A bucket the
        background pre-warm thread (prewarm_buckets) already compiled AND
        executed costs zero XLA work here (warm_rescales); otherwise the
        boundary pays the compile NOW — warmed synchronously so the stall
        is measured here (cold_rescales / rescale_stall_s) instead of
        surfacing as one mysteriously slow chunk."""
        stats = self.scheduler.stats
        sim = self._sims.get(new_e)
        if sim is not None:
            stats.warm_rescales += 1
        else:
            spec = self.sim.spec
            plan_b = dataclasses.replace(self.sim.plan, ensemble=new_e)
            n_out = self.store.n_out
            warm = PLAN_CACHE.contains(spec, plan_b) and PLAN_CACHE.is_warm(
                spec, plan_b, n_out=n_out
            )
            t0 = time.perf_counter()
            sim = PLAN_CACHE.get_or_compile(spec, plan_b)
            PLAN_CACHE.warm(sim, n_out=n_out)
            if warm:
                stats.warm_rescales += 1
            else:
                stats.cold_rescales += 1
                stats.rescale_stall_s += time.perf_counter() - t0
            self._sims[new_e] = sim
        slot_map = {old: new for new, old in enumerate(sorted(self.scheduler.running))}
        self.store = self.store.resized(new_e, slot_map)
        self.scheduler.remap(slot_map, new_e)
        for slot, sess in self.scheduler.running.items():
            sess._slot = slot
        self.sim = sim
        self.backend = sim.impl
        self.precision = sim.precision
        if self._prewarm_enabled:
            self.prewarm_buckets()

    def prewarm(self, block: bool = True) -> None:
        """Warm-start the engine: force XLA compilation of the current
        width's serving hot path (one masked zero chunk through the shared
        PlanCache) plus the adjacent autoscale buckets. The fleet spin-up /
        migration warm-start entry point — after this, the first real
        chunk and the next rescale both dispatch pre-compiled executables."""
        PLAN_CACHE.warm(self.sim, n_out=self.store.n_out)
        self.prewarm_buckets(block=block)

    def prewarm_buckets(self, block: bool = False) -> Tuple[int, ...]:
        """Pre-compile the autoscale buckets adjacent to the current width.

        Runs in a daemon thread so a later `_rescale` at a chunk boundary
        finds its bucket already compiled AND warmed in the shared
        PlanCache — the serving loop never stalls on XLA. The compile runs
        outside the cache lock with per-key in-flight events, so a
        concurrent `_rescale` racing the pre-warm waits for that one
        compile rather than duplicating it. Advisory: failures are
        swallowed (the rescale path compiles on demand), and a still-busy
        previous pre-warm skips this round. Returns the widths scheduled;
        block=True waits for completion (tests, explicit warm spin-up)."""
        if self.autoscale is None:
            return ()
        if self._prewarm_thread is not None and self._prewarm_thread.is_alive():
            if not block:
                return ()
            self._prewarm_thread.join()
        ladder = _bucket_ladder(self.min_slots, self.max_slots)
        below = [b for b in ladder if b < self.num_slots]
        above = [b for b in ladder if b > self.num_slots]
        spec, plan = self.sim.spec, self.sim.plan
        n_out = self.store.n_out
        targets = tuple(
            b
            for b in ([below[-1]] if below else []) + ([above[0]] if above else [])
            if not PLAN_CACHE.is_warm(
                spec, dataclasses.replace(plan, ensemble=b), n_out=n_out
            )
        )
        if not targets:
            return ()

        def work():
            for b in targets:
                try:
                    sim = PLAN_CACHE.ensure_warm(
                        spec, dataclasses.replace(plan, ensemble=b), n_out=n_out
                    )
                    self._sims.setdefault(b, sim)
                except Exception:  # advisory: the serving loop compiles on demand
                    pass

        t = threading.Thread(target=work, daemon=True, name="plan-prewarm")
        self._prewarm_thread = t
        t.start()
        if block:
            t.join()
        return targets

    # -- the synchronous per-tick path --------------------------------------

    def _advance(self, u: jnp.ndarray) -> jnp.ndarray:
        """One input tick for every slot; returns the (N, E) states plane."""
        store = self.store
        store.m, states_plane = self.sim.tick(
            store.m,
            u,
            lane_mask=store.active_mask,
            params=store.params_ensemble,
        )
        return states_plane

    def step(self) -> bool:
        """Admit, advance one tick, harvest. Returns False when drained.

        The synchronous baseline: one `CompiledSim.tick` dispatch and one
        per-slot harvest per input tick. `run()` is the pipelined chunked
        path; both produce identical per-session results on the scan
        backend (bit-exact) and tolerance-equal elsewhere."""
        if self.learn is not None:
            raise RuntimeError(
                "online learning (ExecPlan.learn) runs on the chunked "
                "serving path only — drive the engine with run() or "
                "step_chunk() (chunk_ticks=1 preserves per-tick semantics)"
            )
        if self._subengines:
            raise RuntimeError(
                "mixed-spec tenants are served on the chunked path only — "
                "drive the engine with run() or step_chunk()"
            )
        self._admit_pending()
        running = self.scheduler.running
        if not running:
            return self.scheduler.has_work()

        u = np.zeros((self.store.num_slots, self.store.n_in), self.store.dtype)
        any_readout = False
        for slot, sess in running.items():
            if sess.open:
                raise RuntimeError(
                    "open (push) streams are served on the chunked path "
                    "only — drive the engine with run() or step_chunk()"
                )
            u[slot] = sess.u_seq[sess._t]
            any_readout = any_readout or sess.readout is not None
        states_plane = self._advance(jnp.asarray(u))
        outs = (
            _apply_readouts(states_plane, self.store.w_out)  # (E, n_out)
            if any_readout
            else None
        )
        self.scheduler.on_tick()
        self.tick_count += 1

        for slot, sess in list(running.items()):
            if sess.collect_states:
                sess._states.append(states_plane[:, slot])
            if sess.readout is not None:
                sess._outs.append(outs[slot, : sess._n_out])
            sess._t += 1
            if sess._t >= sess.u_seq.shape[0]:
                self._retire(slot)
        return True

    # -- the pipelined chunked path -----------------------------------------

    def _retire_finishers(self) -> None:
        """Snapshot + free the slots of sessions that finished inside the
        launched chunk. store.m already points at that chunk's (possibly
        still in-flight) result; jnp arrays are immutable, so slicing now
        snapshots it lazily. One gather snapshots every finisher's final
        state (and trained Wl column on learning engines); one scatter
        frees the slots. Results materialize at `_finalize_awaiting`."""
        if not self._finishing:
            return
        slots = [slot for slot, _ in self._finishing]
        finals = self.store.state_columns(slots)  # (k, N, 3) device, lazy
        w_finals = (
            self.store.learn_w_columns(slots)
            if self.learn is not None
            else None
        )
        for slot, sess in self._finishing:
            self.scheduler.retire(slot)
        self._awaiting = (self._finishing, finals, w_finals)
        self.store.retire_many(slots)
        self._finishing = []

    def _scan_for_nonfinite(
        self,
        plan: _ChunkPlan,
        states_np: Optional[np.ndarray],
        outs_np: Optional[np.ndarray],
        preds_np: Optional[np.ndarray],
    ) -> None:
        """Per-chunk nan guard over the harvested blocks. The cheap path is
        one aggregate isfinite per block; only when that trips does the
        per-lane isolation run. An offending tenant is marked for
        quarantine — its lane retires at the next boundary with a
        structured error, its already-harvested prefix intact. Co-tenant
        lanes are untouched by construction: every lane is an independent
        column of the ensemble axis (the batched GEMMs never mix columns),
        so a NaN cannot cross lanes and the guard itself performs no
        device work. A session with no harvested block at all (no states
        collected, no readout, no targets) has no surface to scan — its
        divergence shows up in final_m instead."""
        blocks = [b for b in (states_np, outs_np, preds_np) if b is not None]
        if not blocks or all(np.isfinite(b).all() for b in blocks):
            return
        for sess, slot, n in plan.entries:
            if n == 0 or sess._error is not None:
                continue
            bad = []
            if (
                states_np is not None
                and sess.collect_states
                and not np.isfinite(states_np[:n, :, slot]).all()
            ):
                bad.append("states")
            if (
                outs_np is not None
                and sess.readout is not None
                and not np.isfinite(outs_np[:n, slot, : sess._n_out]).all()
            ):
                bad.append("outputs")
            if (
                preds_np is not None
                and sess.targets is not None
                and not np.isfinite(preds_np[:n, slot, : sess._n_out]).all()
            ):
                bad.append("predictions")
            if bad:
                sess._error = (
                    f"non_finite_state: session {sess.sid} (lane {slot}) "
                    f"produced non-finite {'/'.join(bad)} in the chunk "
                    f"ending at tick {sess._t}; tenant quarantined "
                    f"(co-tenant lanes unaffected)"
                )
                self.scheduler.stats.quarantined_lanes += 1
                self._quarantine.append((slot, sess))

    def _retire_quarantined(self) -> None:
        """Force-retire lanes the nan guard flagged: record an error-bearing
        SessionResult (clean harvested prefix + structured error) and free
        the slot. A flagged session that also finished naturally was
        already retired by the finisher path — its result still carries
        the error via `_record_result`."""
        if not self._quarantine:
            return
        for slot, sess in self._quarantine:
            if self.scheduler.running.get(slot) is not sess:
                continue  # finished (or detached) since it was flagged
            self.scheduler.retire(slot)
            sess._finished_tick = self.tick_count
            final_m = np.asarray(self.store.state_column(slot)).copy()
            learned_w = None
            if self.learn is not None and sess.targets is not None:
                learned_w = np.asarray(
                    self.store.learn_w_columns([slot])[0]
                ).copy()
            self._record_result(sess, slot, final_m, learned_w=learned_w)
            self.store.retire(slot)
        self._quarantine = []

    def _assemble_chunk(self) -> Optional[_ChunkPlan]:
        """Host-side boundary work: finalize the previous chunk's finishers,
        autoscale, admit, and build the next K-tick u/mask block.

        Returns None when nothing is left to serve. Runs while the device
        executes the previously launched chunk — this is the overlap the
        pipeline exists for."""
        # 1) sessions that finished inside the launched chunk: their lanes
        # were masked off after their last tick, so the chunk-output column
        # IS their final state — snapshot + free in one gather/scatter pair.
        self._retire_finishers()

        # 1b) lanes the nan guard flagged at the last harvest: force-retire
        # them (error result) before admissions so their slots refill
        self._retire_quarantined()

        # 2) resize at the boundary (slots now reflect retirements)
        if self.autoscale is not None:
            self._maybe_autoscale()

        # 3) refill freed slots
        self._admit_pending()
        running = self.scheduler.running
        if not running:
            return None

        # 4) K-tick input block + per-tick lane masks (mid-chunk retires
        # mask a lane's trailing rows off; the slot refills next boundary),
        # plus — on learning engines — the target block and learn mask
        # (False rows: washout ticks, inference-only tenants, idle lanes)
        k = self.chunk_ticks
        e, n_in = self.store.num_slots, self.store.n_in
        u = np.zeros((k, e, n_in), self.store.dtype)
        mask = np.zeros((k, e), dtype=bool)
        learning = self.learn is not None
        y = np.zeros((k, e, self.store.n_out), self.store.dtype) if learning else None
        lmask = np.zeros((k, e), dtype=bool) if learning else None
        entries = []
        any_readout = False
        any_learn = False
        session_ticks = 0
        for slot, sess in running.items():
            t0 = sess._t
            # an idle OPEN session (input exhausted, not closed) serves
            # n == 0 ticks: its lane mask stays False for the whole chunk,
            # so tick_chunk freezes the state until append_ticks refills it
            n = min(k, sess.u_seq.shape[0] - t0)
            u[:n, slot] = sess.u_seq[t0 : t0 + n]
            mask[:n, slot] = True
            if learning and sess.targets is not None:
                y[:n, slot] = sess.targets[t0 : t0 + n]
                # update only from the session's learn_washout tick onward
                start = max(0, sess.learn_washout - t0)
                lmask[start:n, slot] = True
                # a-priori predictions are recorded even during washout, so
                # any served tick of a learning session needs the preds block
                any_learn = any_learn or n > 0
            sess._t = t0 + n
            entries.append((sess, slot, n))
            session_ticks += n
            any_readout = any_readout or (sess.readout is not None and n > 0)
            if sess._t >= sess.u_seq.shape[0] and not sess.open:
                sess._finished_tick = self.tick_count + n
                self._finishing.append((slot, sess))
        if session_ticks == 0:
            # every resident is an idle open stream: nothing to launch, and
            # the clock must NOT advance (a push stream parked for a million
            # boundaries would otherwise distort occupancy/throughput
            # stats). quiesce() drains the in-flight chunk first, so a
            # just-closed exhausted stream retires with every harvested row.
            self.quiesce()
            return None
        self.scheduler.on_ticks(k, session_ticks)
        self.tick_count += k

        return _ChunkPlan(
            entries=entries, u=u, mask=mask, any_readout=any_readout,
            targets=y, lmask=lmask, any_learn=any_learn,
        )

    def _launch_chunk(self, plan: _ChunkPlan) -> None:
        """Dispatch the chunk; returns immediately (JAX async dispatch)."""
        store = self.store
        if self._mask_np is None or not (
            self._mask_np.shape == plan.mask.shape
            and np.array_equal(self._mask_np, plan.mask)
        ):
            self._mask_np = plan.mask
            self._mask_dev = jnp.asarray(plan.mask)
        if self.learn is not None:
            if self._lmask_np is None or not (
                self._lmask_np.shape == plan.lmask.shape
                and np.array_equal(self._lmask_np, plan.lmask)
            ):
                self._lmask_np = plan.lmask
                self._lmask_dev = jnp.asarray(plan.lmask)
            # one dispatch advances physics AND learning: P/Wl lanes ride
            # the chunk, a-priori predictions come back in the same result
            store.m, states_block, (store.P, store.Wl), preds = (
                self.sim.tick_chunk(
                    store.m,
                    jnp.asarray(plan.u),
                    lane_mask=self._mask_dev,
                    params=store.params_ensemble,
                    targets=jnp.asarray(plan.targets),
                    learn_state=(store.P, store.Wl),
                    learn_mask=self._lmask_dev,
                )
            )
            plan.preds_block = preds
        else:
            store.m, states_block = self.sim.tick_chunk(
                store.m,
                jnp.asarray(plan.u),
                lane_mask=self._mask_dev,
                params=store.params_ensemble,
            )
        plan.states_block = states_block
        if plan.any_readout:
            plan.outs_block = _apply_readouts_chunk(states_block, store.w_out)

    def _harvest_chunk(self, plan: _ChunkPlan) -> None:
        """ONE bulk device->host transfer for the chunk, then host-side
        per-session masking/slicing — replaces per-tick per-slot slicing.

        When nobody in the chunk collects states, the (K, N, E) block never
        leaves the device (at N=1024, E=256, K=8 that is an 8 MB transfer
        per chunk saved)."""
        states_np = (
            np.asarray(plan.states_block)  # (K, N, E)
            if any(sess.collect_states for sess, _, _ in plan.entries)
            else None
        )
        outs_np = (
            np.asarray(plan.outs_block) if plan.outs_block is not None else None
        )
        preds_np = (
            np.asarray(plan.preds_block)  # (K, E, n_out)
            if plan.any_learn and plan.preds_block is not None
            else None
        )
        if self.nan_guard:
            self._scan_for_nonfinite(plan, states_np, outs_np, preds_np)
        # .copy(): a bare slice is a VIEW pinning the whole (K, N, E) block
        # for the session's lifetime — a long-running collector would retain
        # every chunk block it ever touched instead of its own lane.
        # Columns beyond the session's own n_out are padding lanes — sliced
        # off here so accumulators stay at session width.
        for sess, slot, n in plan.entries:
            if n == 0:  # idle open stream — nothing served this chunk
                continue
            if sess._error is not None:
                # quarantined: keep the clean prefix, drop the poisoned rows
                continue
            if sess.collect_states:
                sess._states.append(states_np[:n, :, slot].copy())  # (n, N)
            if sess.readout is not None:
                sess._outs.append(outs_np[:n, slot, : sess._n_out].copy())
            if preds_np is not None and sess.targets is not None:
                sess._preds.append(preds_np[:n, slot, : sess._n_out].copy())
        # sessions retired at the last boundary: their final chunk is now
        # harvested, so their results are complete
        self._finalize_awaiting()

    def _finalize_awaiting(self) -> None:
        """Record results for sessions retired at the previous boundary
        (their final states/weights arrive as one bulk transfer, handed out
        as copied rows). Safe to call with nothing awaiting."""
        if self._awaiting is None:
            return
        finishers, finals, w_finals = self._awaiting
        finals_np = np.asarray(finals)  # (k, N, 3)
        w_np = np.asarray(w_finals) if w_finals is not None else None
        for i, (slot, sess) in enumerate(finishers):
            # .copy(): a row view would pin the whole boundary's finals
            # block per retained result
            self._record_result(
                sess,
                slot,
                finals_np[i].copy(),
                learned_w=(
                    w_np[i].copy()
                    if w_np is not None and sess.targets is not None
                    else None
                ),
            )
        self._awaiting = None

    def step_chunk(self) -> bool:
        """Advance the pipeline by one chunk. Returns False when drained.

        One call = assemble + launch the next K-tick chunk, then harvest
        the PREVIOUSLY launched one (which the device finished while the
        host assembled). The final call launches nothing and harvests the
        trailing chunk. Callers driving this directly (benchmarks, external
        event loops) must keep calling until it returns False — or hand
        control back to `run()` — so no launched chunk is left unharvested;
        don't interleave with per-tick `step()` while a chunk is in flight.
        """
        t0 = time.perf_counter()
        plan = self._assemble_chunk()
        if plan is not None:
            self._launch_chunk(plan)
        if self._pending is not None:
            self._harvest_chunk(self._pending)
        else:
            # nothing in flight, but the boundary may still have snapshot
            # finals to hand out (all-idle open streams after a finisher)
            self._finalize_awaiting()
        self._pending = plan
        if plan is not None:
            self._chunk_times.append(time.perf_counter() - t0)
        progress = plan is not None
        # advance mixed-spec tenants in lockstep; their finished sessions
        # surface through OUR results map so callers have one drain point
        for sub in self._subengines.values():
            if sub.step_chunk():
                progress = True
            if sub.results:
                self.results.update(sub.pop_results())
        if self._subengines and self.max_retained is not None:
            while len(self.results) > self.max_retained:
                self.results.pop(next(iter(self.results)))
        return progress

    def run(
        self, sessions: Optional[List[StreamSession]] = None
    ) -> Dict[int, SessionResult]:
        """Serve sessions to completion; returns sid -> SessionResult.

        Double-buffered chunk pipeline: assemble chunk C+1 and harvest
        chunk C on the host while the device executes chunk C+1's
        predecessor — admissions, retirements, and autoscaling all happen
        at chunk boundaries. With chunk_ticks == 1 this degenerates to
        per-tick serving with bulk harvest (still one transfer per tick,
        never per slot)."""
        for s in sessions or []:
            self.submit(s)
        while self.step_chunk():
            pass
        return self.results

    # -- fleet lifecycle: push streams, checkpoint/migration, stats --------

    def _find_session(self, sid: int) -> Tuple[Optional[int], StreamSession]:
        """Locate a live session by sid: (slot, session) if resident,
        (None, session) if still queued. Raises KeyError when unknown
        (finished sessions live in `results`, not here)."""
        for slot, sess in self.scheduler.running.items():
            if sess.sid == sid:
                return slot, sess
        for sess in self.scheduler.queue:
            if sess.sid == sid:
                return None, sess
        raise KeyError(f"no live session with sid {sid}")

    def _owner(self, sid: int) -> "ReservoirEngine":
        """The engine actually holding sid: self, or the sub-engine its
        spec routed it to. Raises KeyError when no engine knows it."""
        try:
            self._find_session(sid)
            return self
        except KeyError:
            pass
        for sub in self._subengines.values():
            try:
                sub._find_session(sid)
                return sub
            except KeyError:
                continue
        raise KeyError(f"no live session with sid {sid}")

    def append_ticks(
        self,
        sid: int,
        u: np.ndarray,
        targets: Optional[np.ndarray] = None,
    ) -> None:
        """Feed more input rows to an OPEN (push) stream.

        The rows join the session's stream at its tail; an idle lane picks
        them up at the next chunk boundary. Learning sessions must push
        matching target rows (and inference sessions must not)."""
        eng = self._owner(sid)
        if eng is not self:
            return eng.append_ticks(sid, u, targets)
        _, sess = self._find_session(sid)
        if not sess.open:
            raise ValueError(
                f"session {sid} is not an open stream — submit it with "
                f"open=True to push ticks"
            )
        u = coerce_input_series(u, self.store.n_in, self.store.dtype, xp=np)
        if sess.targets is not None:
            if targets is None:
                raise ValueError(
                    f"session {sid} is a learning stream — push target rows "
                    f"alongside the inputs"
                )
            t = np.asarray(targets, dtype=self.store.dtype)
            if t.ndim == 1:
                t = t[:, None]
            if t.shape != (u.shape[0], sess._n_out):
                raise ValueError(
                    f"session {sid}: pushed targets shape "
                    f"{tuple(np.shape(targets))} != ({u.shape[0]}, "
                    f"{sess._n_out})"
                )
            sess.targets = np.concatenate(
                [sess.targets, self._pad_cols(t, "targets", sid)]
            )
        elif targets is not None:
            raise ValueError(
                f"session {sid} is inference-only; it cannot take targets"
            )
        sess.u_seq = np.concatenate([sess.u_seq, u])

    def close_session(self, sid: int) -> None:
        """End an open stream: once its pushed input is exhausted the
        session finishes like any closed-stream session (result in
        `results`/`pop_results`)."""
        _, sess = self._owner(sid)._find_session(sid)
        sess.open = False

    def quiesce(self) -> None:
        """Drain the pipeline without launching new work: harvest the
        in-flight chunk, retire + record any finishers. Afterwards the
        SlotStore columns are current for every resident session — the
        precondition for `checkpoint_session`. Serving resumes with the
        next `step_chunk()`/`run()`."""
        if self._pending is not None:
            self._harvest_chunk(self._pending)
            self._pending = None
        self._retire_finishers()
        self._finalize_awaiting()
        for sub in self._subengines.values():
            sub.quiesce()
            if sub.results:
                self.results.update(sub.pop_results())

    def _freeze_session(
        self, slot: Optional[int], sess: StreamSession, detach: bool
    ) -> SessionCheckpoint:
        """Build a host-side SessionCheckpoint of one live session (the
        pipeline must be quiesced: slot columns current, nothing in
        flight). detach=True removes the session from this engine (the
        migration path); detach=False leaves it serving untouched — every
        array that could later mutate is copied or replaced-on-write
        (u_seq/targets only ever grow by reassignment in append_ticks;
        prefix blocks concatenate into fresh arrays), so a non-destructive
        snapshot never aliases live engine state."""
        q = sess._n_out
        learning = self.learn is not None and sess.targets is not None
        if slot is None:
            # still queued: nothing on device yet
            if detach:
                self.scheduler.remove_queued(sess)
            m = None if sess.m0 is None else np.asarray(sess.m0)
            P = Wl = None
        else:
            m = np.asarray(self.store.state_column(slot))
            if learning:
                # LMS learners have no inverse-Gram: Wl IS the whole
                # resumable learn state (SessionCheckpoint.P stays None)
                P = (
                    np.asarray(self.store.learn_P_columns([slot])[0])
                    if self.learn == "rls"
                    else None
                )
                # padding columns stay zero for the session's whole life
                # (zero targets + zero init), so slicing to q is exact
                Wl = np.asarray(self.store.learn_w_columns([slot])[0])[:, :q]
            else:
                P = Wl = None
            if detach:
                self.scheduler.detach(slot)
                self.store.retire(slot)

        def cat(blocks):
            if not blocks:
                return None
            return np.concatenate([np.atleast_2d(np.asarray(b)) for b in blocks])

        ckpt = SessionCheckpoint(
            sid=sess.sid,
            u_seq=np.asarray(sess.u_seq),
            t=sess._t,
            m=m,
            params=sess.params,
            readout_w=(
                None
                if sess.readout is None
                else np.asarray(sess.readout.w_out)
            ),
            readout_washout=(
                0 if sess.readout is None else sess.readout.washout
            ),
            collect_states=sess.collect_states,
            targets=(
                None if sess.targets is None else sess.targets[:, :q].copy()
            ),
            learn_washout=sess.learn_washout,
            open=sess.open,
            n_out=q,
            states=cat(sess._states) if sess.collect_states else None,
            outs=cat(sess._outs) if sess.readout is not None else None,
            preds=cat(sess._preds) if learning else None,
            P=P,
            Wl=Wl,
            spec=_spec_host(sess.spec),
        )
        if detach:
            sess._states = []
            sess._outs = []
            sess._preds = []
        return ckpt

    def checkpoint_session(self, sid: int) -> SessionCheckpoint:
        """Freeze a live session into a host-side SessionCheckpoint and
        remove it from this engine (detach — not a retirement; no
        SessionResult is recorded here). The checkpoint restores into any
        engine compiled for the same reservoir spec via
        `restore_session`, resuming bit-identically on the scan backend.
        Quiesces the pipeline first."""
        self.quiesce()
        eng = self._owner(sid)
        if eng is not self:
            return eng.checkpoint_session(sid)
        slot, sess = self._find_session(sid)
        return self._freeze_session(slot, sess, detach=True)

    def snapshot_sessions(self) -> List[SessionCheckpoint]:
        """Non-destructive checkpoints of EVERY live session (running and
        queued, sub-engines included) — the periodic auto-checkpoint the
        fleet failover layer rides: the router calls this every
        `checkpoint_every` pump rounds and keeps the checkpoints PARENT
        side, so they survive the replica process dying. Quiesces the
        pipeline first; every session keeps serving afterwards, and its
        stream is bit-identical to one that was never snapshotted
        (tests/test_fleet_faults.py pins this). Sessions already flagged
        by the nan guard are excluded — failover must not resurrect a
        poisoned stream."""
        self.quiesce()
        out: List[SessionCheckpoint] = []
        for slot, sess in list(self.scheduler.running.items()):
            if sess._error is None:
                out.append(self._freeze_session(slot, sess, detach=False))
        for sess in list(self.scheduler.queue):
            if sess._error is None:
                out.append(self._freeze_session(None, sess, detach=False))
        for sub in self._subengines.values():
            out.extend(sub.snapshot_sessions())
        return out

    def restore_session(self, ckpt: SessionCheckpoint) -> StreamSession:
        """Resume a checkpointed session on THIS engine: re-submit it with
        the frozen magnetization as m0 and the in-flight RLS learner
        injected into the destination slot's P/Wl columns, then seed the
        already-served prefix so the final SessionResult covers the whole
        stream. The resumed stream is bit-identical to one that never
        migrated (scan backend; tests/test_fleet.py)."""
        readout = None
        if ckpt.readout_w is not None:
            readout = Readout(
                w_out=jnp.asarray(ckpt.readout_w),
                washout=ckpt.readout_washout,
            )
        sess = StreamSession(
            sid=ckpt.sid,
            u_seq=ckpt.u_seq,
            params=ckpt.params,
            readout=readout,
            m0=None if ckpt.m is None else jnp.asarray(ckpt.m),
            collect_states=ckpt.collect_states,
            targets=ckpt.targets,
            learn_washout=ckpt.learn_washout,
            open=ckpt.open,
            learn_w0=ckpt.Wl,
            learn_P0=ckpt.P,
            spec=ckpt.spec,
        )
        # submit() re-routes a spec-carrying session (possibly onto a
        # sub-engine of THIS engine) and validates/pads against whichever
        # store it lands in
        self.submit(sess)
        if ckpt.t:
            sess._t = ckpt.t
            sess._states = [] if ckpt.states is None else [ckpt.states]
            sess._outs = [] if ckpt.outs is None else [ckpt.outs]
            sess._preds = [] if ckpt.preds is None else [ckpt.preds]
            sess._restored = True  # _admit_pending keeps the seeded prefix
        return sess

    def stats(self) -> EngineStats:
        """Load/latency snapshot for the fleet planner and router — plain
        scalars only (pickles across the replica transport)."""
        sched = self.scheduler
        timed = sorted(self._chunk_times)
        median = timed[len(timed) // 2] if timed else None
        return EngineStats(
            n=self.res.n,
            num_slots=self.num_slots,
            active=len(sched.running),
            queued=len(sched.queue),
            backend=self.backend,
            precision=self.precision,
            learn=self.learn,
            chunk_ticks=self.chunk_ticks,
            ticks=sched.stats.ticks,
            session_ticks=sched.stats.session_ticks,
            occupancy=sched.occupancy(),
            queue_depth=sched.queue_depth(),
            mean_queue_wait=sched.mean_queue_wait(),
            grows=sched.stats.grows,
            shrinks=sched.stats.shrinks,
            detached=sched.stats.detached,
            cold_rescales=sched.stats.cold_rescales,
            warm_rescales=sched.stats.warm_rescales,
            rescale_stall_s=sched.stats.rescale_stall_s,
            chunk_median_s=median,
            chunks_timed=len(timed),
            ticks_per_sec=(
                None
                if not median
                else self.num_slots * self.chunk_ticks / median
            ),
            sub_engines=len(self._subengines),
            quarantined_lanes=(
                sched.stats.quarantined_lanes
                + sum(
                    s.scheduler.stats.quarantined_lanes
                    for s in self._subengines.values()
                )
            ),
        )
