"""Serving engines.

- engine.py: continuous-batching LLM engine (prefill + KV-cache splice).
- reservoir.py (+ scheduler.py, state_store.py): multi-tenant streaming
  reservoir engine — client streams slot-batched onto the ensemble axis E.

Submodules are imported directly (repro.serve.reservoir, ...) rather than
re-exported here: the LLM engine pulls in the model stack, which reservoir
serving doesn't need.
"""
