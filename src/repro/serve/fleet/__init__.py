"""Fleet serving tier: replicated reservoir engines behind one front door.

The layer ABOVE `ReservoirEngine`: replicas (`replica.py`) each wrap one
engine in-process or in a spawned worker process, the router
(`router.py`) places sessions onto per-N replica pools with sticky
affinity and bit-exact checkpoint migration, the asyncio front-end
(`frontend.py`) adds submit/push/drain verbs with planner-driven
admission control, and the capacity planner (`planner.py`) turns the
measured BENCH_serve.json grid into an analytical
`sessions_per_sec(N, E, ...)` model for sizing all of it.

Fault tolerance is layered onto the same pieces (docs/ARCHITECTURE.md
"Failure domains"): replica supervision (RPC deadlines, send retries,
health states) detects failures, the router's checkpoint-based failover
(`FleetRouter(checkpoint_every=...)`) recovers sessions bit-identically
onto respawned replicas, the engine's nan guard quarantines a divergent
tenant's lane without touching co-tenants, and `faults.py` injects
deterministic crash/hang/delay/drop/NaN faults so every one of those
paths is tested.

Rule of thumb (docs/ARCHITECTURE.md): execution capabilities are
ExecPlan fields; PLACEMENT — which replica, which pool, how many — is
fleet fields.
"""

from .faults import CRASH_EXIT_CODE, FAULT_KINDS, Fault, FaultPlan, FaultRuntime
from .frontend import AdmissionError, FleetFrontend, OverloadError
from .planner import (
    CapacityModel,
    FleetPlan,
    ReplicaSpec,
    WorkloadClass,
    measure_probe_rates,
    usable_cores,
)
from .replica import (
    HEALTH_DEAD,
    HEALTH_DEGRADED,
    HEALTH_HEALTHY,
    LocalReplica,
    ProcessReplica,
    ReplicaError,
    make_engine,
    start_fleet,
    validate_supervision,
)
from .router import FleetFaultStats, FleetRouter

__all__ = [
    "AdmissionError",
    "CRASH_EXIT_CODE",
    "CapacityModel",
    "FAULT_KINDS",
    "Fault",
    "FaultPlan",
    "FaultRuntime",
    "FleetFaultStats",
    "FleetFrontend",
    "FleetPlan",
    "FleetRouter",
    "HEALTH_DEAD",
    "HEALTH_DEGRADED",
    "HEALTH_HEALTHY",
    "LocalReplica",
    "OverloadError",
    "ProcessReplica",
    "ReplicaError",
    "ReplicaSpec",
    "WorkloadClass",
    "make_engine",
    "measure_probe_rates",
    "start_fleet",
    "usable_cores",
    "validate_supervision",
]
