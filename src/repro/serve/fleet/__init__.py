"""Fleet serving tier: replicated reservoir engines behind one front door.

The layer ABOVE `ReservoirEngine`: replicas (`replica.py`) each wrap one
engine in-process or in a spawned worker process, the router
(`router.py`) places sessions onto per-N replica pools with sticky
affinity and bit-exact checkpoint migration, the asyncio front-end
(`frontend.py`) adds submit/push/drain verbs with planner-driven
admission control, and the capacity planner (`planner.py`) turns the
measured BENCH_serve.json grid into an analytical
`sessions_per_sec(N, E, ...)` model for sizing all of it.

Rule of thumb (docs/ARCHITECTURE.md): execution capabilities are
ExecPlan fields; PLACEMENT — which replica, which pool, how many — is
fleet fields.
"""

from .frontend import AdmissionError, FleetFrontend
from .planner import (
    CapacityModel,
    FleetPlan,
    ReplicaSpec,
    WorkloadClass,
    measure_probe_rates,
    usable_cores,
)
from .replica import (
    LocalReplica,
    ProcessReplica,
    ReplicaError,
    make_engine,
    start_fleet,
)
from .router import FleetRouter

__all__ = [
    "AdmissionError",
    "CapacityModel",
    "FleetFrontend",
    "FleetPlan",
    "FleetRouter",
    "LocalReplica",
    "ProcessReplica",
    "ReplicaError",
    "ReplicaSpec",
    "WorkloadClass",
    "make_engine",
    "measure_probe_rates",
    "start_fleet",
    "usable_cores",
]
