"""Fleet replica workers: one `ReservoirEngine` each, uniform RPC surface.

Two transports, one protocol:

- `LocalReplica` wraps the engine in-process — zero copy, deterministic,
  the transport for correctness tests and single-core hosts (where extra
  processes only add context switches).
- `ProcessReplica` spawns the engine into its own OS process and speaks
  the same protocol over a `multiprocessing` pipe. Commands are
  CHUNK-GRANULARITY: the parent says "run_for(k)" and the child advances
  up to k pipeline chunks before replying, so the pipe carries one small
  message per chunk, never per tick. `run_for_async`/`run_for_wait` split
  the round trip so a router can launch every replica's chunk first and
  collect second — on a multi-core host the children genuinely overlap.

Everything that crosses the pipe is numpy/scalars (StreamSession input
streams are host numpy by engine contract; `SessionCheckpoint` is
host-only by construction), so a session can be submitted to either
transport, checkpointed out of one replica and restored into another —
process boundaries included — bit-identically.

The engine factory handed to a replica must be a module-level callable
(`make_engine` below is the default) because the spawn context pickles it
into the child.
"""

from __future__ import annotations

import multiprocessing as mp
from typing import Any, Dict, List, Optional, Tuple

from repro.core.reservoir import make_reservoir
from repro.serve.reservoir import (
    EngineStats,
    ReservoirEngine,
    SessionCheckpoint,
    SessionResult,
    StreamSession,
)


class ReplicaError(RuntimeError):
    """An engine-side exception surfaced across the replica transport."""


def make_engine(
    n: int = 16,
    num_slots: int = 8,
    n_in: int = 1,
    hold_steps: int = 5,
    seed: int = 0,
    backend: str = "auto",
    chunk_ticks: int = 8,
    n_out: int = 1,
    learn: Optional[str] = None,
    precision: Optional[str] = None,
    autoscale: bool = False,
    min_slots: Optional[int] = None,
    max_slots: Optional[int] = None,
    compilation_cache_dir: Optional[str] = None,
) -> ReservoirEngine:
    """Default replica engine factory (module-level: pickles into spawn).

    The engine's template route draws from the process-wide PlanCache, so
    local replicas of one config share a single CompiledSim; process
    replicas each compile in their own process — point
    `compilation_cache_dir` at a shared directory and their XLA
    executables come off disk instead (JAX persistent compilation cache),
    which is what makes `start_fleet(transport="process")` spin-up warm
    across restarts."""
    res = make_reservoir(n=n, n_in=n_in, hold_steps=hold_steps, seed=seed)
    return ReservoirEngine(
        res,
        num_slots=num_slots,
        backend=backend,
        chunk_ticks=chunk_ticks,
        n_out=n_out,
        learn=learn,
        precision=precision,
        autoscale=autoscale or None,
        min_slots=min_slots,
        max_slots=max_slots,
        compilation_cache_dir=compilation_cache_dir,
    )


class LocalReplica:
    """In-process replica: the engine lives on this event loop/thread."""

    transport = "local"

    def __init__(self, factory=make_engine, **engine_kw):
        self.engine = factory(**engine_kw)
        self.n = self.engine.res.n
        self.num_slots = self.engine.num_slots
        # live sessions this replica currently owns (admission signal for
        # the router's least-loaded placement)
        self.pending = 0
        self._last_worked = False

    # -- session lifecycle --------------------------------------------------

    def submit(self, session: StreamSession) -> None:
        self.engine.submit(session)
        self.pending += 1

    def append_ticks(self, sid, u, targets=None) -> None:
        self.engine.append_ticks(sid, u, targets)

    def close_session(self, sid) -> None:
        self.engine.close_session(sid)

    def checkpoint_session(self, sid) -> SessionCheckpoint:
        ckpt = self.engine.checkpoint_session(sid)
        self.pending -= 1
        return ckpt

    def restore_session(self, ckpt: SessionCheckpoint) -> None:
        self.engine.restore_session(ckpt)
        self.pending += 1

    # -- serving ------------------------------------------------------------

    def run_for(self, max_chunks: int = 1) -> bool:
        """Advance up to max_chunks pipeline chunks; True if any ran."""
        worked = False
        for _ in range(max_chunks):
            if not self.engine.step_chunk():
                break
            worked = True
        return worked

    # split-phase pump (uniform with ProcessReplica; local = immediate)
    def run_for_async(self, max_chunks: int = 1) -> None:
        self._last_worked = self.run_for(max_chunks)

    def run_for_wait(self) -> bool:
        return self._last_worked

    def results(self) -> List[SessionResult]:
        out = list(self.engine.pop_results().values())
        self.pending -= len(out)
        return out

    def stats(self) -> EngineStats:
        return self.engine.stats()

    def prewarm(self) -> None:
        """Warm-start: compile + execute the serving hot path (and adjacent
        autoscale buckets) before traffic arrives — the router calls this
        on a migration destination so a restored session's first chunk
        never stalls on XLA."""
        self.engine.prewarm(block=True)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# process transport
# ---------------------------------------------------------------------------


def _child_main(conn, factory, engine_kw: Dict[str, Any]) -> None:
    """Replica child: build the engine, answer one reply per command."""
    try:
        engine = factory(**engine_kw)
        conn.send(("ok", None))  # ready handshake (after JAX import/compile)
    except Exception as e:  # noqa: BLE001 — report, don't die silently
        conn.send(("err", f"{type(e).__name__}: {e}"))
        return
    while True:
        op, *args = conn.recv()
        try:
            if op == "run_for":
                worked = False
                for _ in range(args[0]):
                    if not engine.step_chunk():
                        break
                    worked = True
                conn.send(("ok", worked))
            elif op == "submit":
                engine.submit(args[0])
                conn.send(("ok", None))
            elif op == "results":
                conn.send(("ok", list(engine.pop_results().values())))
            elif op == "append":
                engine.append_ticks(*args)
                conn.send(("ok", None))
            elif op == "close_session":
                engine.close_session(args[0])
                conn.send(("ok", None))
            elif op == "checkpoint":
                conn.send(("ok", engine.checkpoint_session(args[0])))
            elif op == "restore":
                engine.restore_session(args[0])
                conn.send(("ok", None))
            elif op == "stats":
                conn.send(("ok", engine.stats()))
            elif op == "prewarm":
                engine.prewarm(block=True)
                conn.send(("ok", None))
            elif op == "stop":
                conn.send(("ok", None))
                return
            else:
                conn.send(("err", f"unknown op {op!r}"))
        except Exception as e:  # noqa: BLE001 — RPC error channel
            conn.send(("err", f"{type(e).__name__}: {e}"))


class ProcessReplica:
    """A replica in its own OS process, driven over a pipe.

    Spawn (not fork): JAX runtimes don't survive forking, and spawn gives
    the child a clean import so parent and child each own their XLA
    threadpool. Construction blocks until the child's engine is built —
    callers should start several replicas before waiting if they want the
    compiles to overlap (see `start_fleet`)."""

    transport = "process"

    def __init__(self, factory=make_engine, _defer_ready: bool = False, **engine_kw):
        ctx = mp.get_context("spawn")
        self._conn, child_conn = ctx.Pipe()
        self._proc = ctx.Process(
            target=_child_main,
            args=(child_conn, factory, engine_kw),
            daemon=True,
        )
        self._proc.start()
        child_conn.close()
        self.n = engine_kw.get("n", 16)
        self.num_slots = engine_kw.get("num_slots", 8)
        self.pending = 0
        self._ready = False
        if not _defer_ready:
            self.wait_ready()

    def wait_ready(self) -> None:
        if not self._ready:
            self._recv()  # the build handshake
            self._ready = True

    def _recv(self):
        status, payload = self._conn.recv()
        if status == "err":
            raise ReplicaError(payload)
        return payload

    def _rpc(self, *msg):
        self._conn.send(msg)
        return self._recv()

    # -- session lifecycle --------------------------------------------------

    def submit(self, session: StreamSession) -> None:
        self._rpc("submit", session)
        self.pending += 1

    def append_ticks(self, sid, u, targets=None) -> None:
        self._rpc("append", sid, u, targets)

    def close_session(self, sid) -> None:
        self._rpc("close_session", sid)

    def checkpoint_session(self, sid) -> SessionCheckpoint:
        ckpt = self._rpc("checkpoint", sid)
        self.pending -= 1
        return ckpt

    def restore_session(self, ckpt: SessionCheckpoint) -> None:
        self._rpc("restore", ckpt)
        self.pending += 1

    # -- serving ------------------------------------------------------------

    def run_for(self, max_chunks: int = 1) -> bool:
        return self._rpc("run_for", max_chunks)

    def run_for_async(self, max_chunks: int = 1) -> None:
        self._conn.send(("run_for", max_chunks))

    def run_for_wait(self) -> bool:
        return self._recv()

    def results(self) -> List[SessionResult]:
        out = self._rpc("results")
        self.pending -= len(out)
        return out

    def stats(self) -> EngineStats:
        return self._rpc("stats")

    def prewarm(self) -> None:
        """Warm-start the child's engine (see LocalReplica.prewarm)."""
        self._rpc("prewarm")

    def close(self) -> None:
        if self._proc.is_alive():
            try:
                self._rpc("stop")
            except (EOFError, BrokenPipeError, OSError):
                pass
            self._proc.join(timeout=10)
            if self._proc.is_alive():
                self._proc.terminate()
        self._conn.close()


def start_fleet(
    count: int,
    transport: str = "local",
    factory=make_engine,
    **engine_kw,
) -> List[Any]:
    """Start `count` replicas of one engine config. Process replicas are
    all spawned before any ready-handshake is awaited, so their JAX
    imports/compiles overlap instead of serializing."""
    if transport == "local":
        return [LocalReplica(factory, **engine_kw) for _ in range(count)]
    if transport == "process":
        reps = [
            ProcessReplica(factory, _defer_ready=True, **engine_kw)
            for _ in range(count)
        ]
        for r in reps:
            r.wait_ready()
        return reps
    raise ValueError(f"transport must be 'local' or 'process'; got {transport!r}")
