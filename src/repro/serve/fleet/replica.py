"""Fleet replica workers: one `ReservoirEngine` each, uniform RPC surface.

Two transports, one protocol:

- `LocalReplica` wraps the engine in-process — zero copy, deterministic,
  the transport for correctness tests and single-core hosts (where extra
  processes only add context switches).
- `ProcessReplica` spawns the engine into its own OS process and speaks
  the same protocol over a `multiprocessing` pipe. Commands are
  CHUNK-GRANULARITY: the parent says "run_for(k)" and the child advances
  up to k pipeline chunks before replying, so the pipe carries one small
  message per chunk, never per tick. `run_for_async`/`run_for_wait` split
  the round trip so a router can launch every replica's chunk first and
  collect second — on a multi-core host the children genuinely overlap.

Everything that crosses the pipe is numpy/scalars (StreamSession input
streams are host numpy by engine contract; `SessionCheckpoint` is
host-only by construction), so a session can be submitted to either
transport, checkpointed out of one replica and restored into another —
process boundaries included — bit-identically.

Supervision: every replica carries a health state, one of
`healthy | degraded | dead`. The process transport polls the pipe with a
deadline instead of blocking, checks the child's liveness each poll step,
and retries SEND-side failures with capped exponential backoff — a dead
or hung child raises `ReplicaError` (with the child's exit code when
known) instead of blocking the parent forever. Reply timeouts are NOT
retried: the pipe's replies are strictly ordered and the parent cannot
know whether a slow child executed the request, so resending would risk
double-executing a non-idempotent op. A reply timeout is terminal — the
replica is marked dead and the router fails the sessions over. Once a
retry fired, health degrades (sticky) so routers and the frontend can
shed load before the replica dies outright.

Fault injection: pass `faults=FaultPlan(...)` to either transport and the
scheduled events fire deterministically — crash/hang in the serving loop,
delay/drop on the parent's send path, NaN into a tenant's input at
submit (see `fleet/faults.py`).

The engine factory handed to a replica must be a module-level callable
(`make_engine` below is the default) because the spawn context pickles it
into the child.
"""

from __future__ import annotations

import multiprocessing as mp
import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.reservoir import make_reservoir
from repro.serve.fleet.faults import CRASH_EXIT_CODE, FaultPlan
from repro.serve.reservoir import (
    EngineStats,
    ReservoirEngine,
    SessionCheckpoint,
    SessionResult,
    StreamSession,
)

HEALTH_HEALTHY = "healthy"
HEALTH_DEGRADED = "degraded"
HEALTH_DEAD = "dead"

# pipe poll step while awaiting a reply: short enough to notice a dead
# child quickly, long enough not to spin
_POLL_STEP_S = 0.05

# how long an injected hang sleeps in the child (far past any rpc
# deadline a test would configure; the parent kills the child on reap)
_HANG_SLEEP_S = 3600.0


class ReplicaError(RuntimeError):
    """A replica-level failure surfaced to the caller: an engine-side
    exception relayed over the transport, or the transport itself failing
    (dead child, hung child, exhausted send retries). `exit_code` carries
    the child's exit status when the failure was a death."""

    def __init__(self, message: str, exit_code: Optional[int] = None):
        super().__init__(message)
        self.exit_code = exit_code


def validate_supervision(
    rpc_timeout_s: Optional[float],
    rpc_retries: int,
    rpc_backoff_s: float,
) -> None:
    """Reject non-positive supervision knobs up front — a zero timeout or
    backoff silently degenerates to busy-spinning or instant death."""
    if rpc_timeout_s is not None and not rpc_timeout_s > 0:
        raise ValueError(f"rpc_timeout_s must be > 0 or None; got {rpc_timeout_s!r}")
    if not isinstance(rpc_retries, int) or isinstance(rpc_retries, bool) or rpc_retries < 0:
        raise ValueError(f"rpc_retries must be an int >= 0; got {rpc_retries!r}")
    if not rpc_backoff_s > 0:
        raise ValueError(f"rpc_backoff_s must be > 0; got {rpc_backoff_s!r}")


def make_engine(
    n: int = 16,
    num_slots: int = 8,
    n_in: int = 1,
    hold_steps: int = 5,
    seed: int = 0,
    backend: str = "auto",
    chunk_ticks: int = 8,
    n_out: int = 1,
    learn: Optional[str] = None,
    precision: Optional[str] = None,
    autoscale: bool = False,
    min_slots: Optional[int] = None,
    max_slots: Optional[int] = None,
    compilation_cache_dir: Optional[str] = None,
) -> ReservoirEngine:
    """Default replica engine factory (module-level: pickles into spawn).

    The engine's template route draws from the process-wide PlanCache, so
    local replicas of one config share a single CompiledSim; process
    replicas each compile in their own process — point
    `compilation_cache_dir` at a shared directory and their XLA
    executables come off disk instead (JAX persistent compilation cache),
    which is what makes `start_fleet(transport="process")` spin-up warm
    across restarts."""
    res = make_reservoir(n=n, n_in=n_in, hold_steps=hold_steps, seed=seed)
    return ReservoirEngine(
        res,
        num_slots=num_slots,
        backend=backend,
        chunk_ticks=chunk_ticks,
        n_out=n_out,
        learn=learn,
        precision=precision,
        autoscale=autoscale or None,
        min_slots=min_slots,
        max_slots=max_slots,
        compilation_cache_dir=compilation_cache_dir,
    )


class LocalReplica:
    """In-process replica: the engine lives on this event loop/thread."""

    transport = "local"

    def __init__(self, factory=make_engine, faults: Optional[FaultPlan] = None, **engine_kw):
        self.engine = factory(**engine_kw)
        self.n = self.engine.res.n
        self.num_slots = self.engine.num_slots
        # live sessions this replica currently owns (admission signal for
        # the router's least-loaded placement)
        self.pending = 0
        self._last_worked = False
        self.health = HEALTH_HEALTHY
        self.rpc_retries_total = 0  # uniform with ProcessReplica (always 0)
        # local transport has no pipe: crash/hang both fail-stop, nan
        # poisons at submit, delay/drop are process-transport faults
        self._faults = faults.runtime() if faults is not None else None

    def _check_alive(self) -> None:
        if self.health == HEALTH_DEAD:
            raise ReplicaError(
                "replica is dead (injected crash)", exit_code=CRASH_EXIT_CODE
            )

    def _die(self) -> None:
        self.health = HEALTH_DEAD
        self.engine = None  # the "process" is gone; drop its state with it
        raise ReplicaError(
            "injected crash (local transport)", exit_code=CRASH_EXIT_CODE
        )

    # -- session lifecycle --------------------------------------------------

    def submit(self, session: StreamSession) -> None:
        self._check_alive()
        if self._faults is not None:
            self._faults.poison_session(session)
        self.engine.submit(session)
        self.pending += 1

    def append_ticks(self, sid, u, targets=None) -> None:
        self._check_alive()
        self.engine.append_ticks(sid, u, targets)

    def close_session(self, sid) -> None:
        self._check_alive()
        self.engine.close_session(sid)

    def checkpoint_session(self, sid) -> SessionCheckpoint:
        self._check_alive()
        ckpt = self.engine.checkpoint_session(sid)
        self.pending -= 1
        return ckpt

    def restore_session(self, ckpt: SessionCheckpoint) -> None:
        self._check_alive()
        self.engine.restore_session(ckpt)
        self.pending += 1

    def snapshot(self) -> List[SessionCheckpoint]:
        """Non-destructive checkpoints of every live session (failover)."""
        self._check_alive()
        return self.engine.snapshot_sessions()

    # -- serving ------------------------------------------------------------

    def run_for(self, max_chunks: int = 1) -> bool:
        """Advance up to max_chunks pipeline chunks; True if any ran."""
        self._check_alive()
        worked = False
        for _ in range(max_chunks):
            if self._faults is not None and self._faults.on_chunk() in ("crash", "hang"):
                self._die()
            if not self.engine.step_chunk():
                break
            worked = True
        return worked

    # split-phase pump (uniform with ProcessReplica; local = immediate)
    def run_for_async(self, max_chunks: int = 1) -> None:
        self._last_worked = self.run_for(max_chunks)

    def run_for_wait(self) -> bool:
        return self._last_worked

    def results(self) -> List[SessionResult]:
        self._check_alive()
        out = list(self.engine.pop_results().values())
        self.pending -= len(out)
        return out

    def stats(self) -> EngineStats:
        self._check_alive()
        st = self.engine.stats()
        st.health = self.health
        return st

    def prewarm(self) -> None:
        """Warm-start: compile + execute the serving hot path (and adjacent
        autoscale buckets) before traffic arrives — the router calls this
        on a migration destination so a restored session's first chunk
        never stalls on XLA."""
        self._check_alive()
        self.engine.prewarm(block=True)

    def close(self) -> None:
        self.health = HEALTH_DEAD


# ---------------------------------------------------------------------------
# process transport
# ---------------------------------------------------------------------------


def _child_main(
    conn,
    factory,
    engine_kw: Dict[str, Any],
    fault_plan: Optional[FaultPlan] = None,
) -> None:
    """Replica child: build the engine, answer one reply per command."""
    faults = fault_plan.runtime() if fault_plan is not None else None
    try:
        engine = factory(**engine_kw)
        conn.send(("ok", None))  # ready handshake (after JAX import/compile)
    except Exception as e:  # noqa: BLE001 — report, don't die silently
        conn.send(("err", f"{type(e).__name__}: {e}"))
        return
    while True:
        op, *args = conn.recv()
        try:
            if op == "run_for":
                worked = False
                for _ in range(args[0]):
                    if faults is not None:
                        action = faults.on_chunk()
                        if action == "crash":
                            conn.close()
                            os._exit(CRASH_EXIT_CODE)
                        if action == "hang":
                            time.sleep(_HANG_SLEEP_S)
                    if not engine.step_chunk():
                        break
                    worked = True
                conn.send(("ok", worked))
            elif op == "submit":
                if faults is not None:
                    faults.poison_session(args[0])
                engine.submit(args[0])
                conn.send(("ok", None))
            elif op == "results":
                conn.send(("ok", list(engine.pop_results().values())))
            elif op == "append":
                engine.append_ticks(*args)
                conn.send(("ok", None))
            elif op == "close_session":
                engine.close_session(args[0])
                conn.send(("ok", None))
            elif op == "checkpoint":
                conn.send(("ok", engine.checkpoint_session(args[0])))
            elif op == "restore":
                engine.restore_session(args[0])
                conn.send(("ok", None))
            elif op == "snapshot":
                conn.send(("ok", engine.snapshot_sessions()))
            elif op == "stats":
                conn.send(("ok", engine.stats()))
            elif op == "prewarm":
                engine.prewarm(block=True)
                conn.send(("ok", None))
            elif op == "stop":
                conn.send(("ok", None))
                return
            else:
                conn.send(("err", f"unknown op {op!r}"))
        except Exception as e:  # noqa: BLE001 — RPC error channel
            conn.send(("err", f"{type(e).__name__}: {e}"))


class ProcessReplica:
    """A replica in its own OS process, driven over a pipe.

    Spawn (not fork): JAX runtimes don't survive forking, and spawn gives
    the child a clean import so parent and child each own their XLA
    threadpool. Construction blocks until the child's engine is built —
    callers should start several replicas before waiting if they want the
    compiles to overlap (see `start_fleet`).

    Supervision knobs:
      rpc_timeout_s  deadline for a reply once a request is on the pipe
                     (None = wait for the child as long as it stays
                     alive; a death is still detected immediately).
      rpc_retries    max re-sends of a request that failed to go out
                     (injected drop / transient send failure). Replies
                     are never re-requested — see module docstring.
      rpc_backoff_s  initial backoff between send retries (doubles per
                     attempt, capped at 1s)."""

    transport = "process"

    def __init__(
        self,
        factory=make_engine,
        _defer_ready: bool = False,
        rpc_timeout_s: Optional[float] = 120.0,
        rpc_retries: int = 3,
        rpc_backoff_s: float = 0.05,
        faults: Optional[FaultPlan] = None,
        **engine_kw,
    ):
        validate_supervision(rpc_timeout_s, rpc_retries, rpc_backoff_s)
        self.rpc_timeout_s = rpc_timeout_s
        self.rpc_retries = rpc_retries
        self.rpc_backoff_s = rpc_backoff_s
        self.rpc_retries_total = 0
        self.health = HEALTH_HEALTHY
        self._faults = faults.runtime() if faults is not None else None
        ctx = mp.get_context("spawn")
        self._conn, child_conn = ctx.Pipe()
        self._proc = ctx.Process(
            target=_child_main,
            args=(child_conn, factory, engine_kw, faults),
            daemon=True,
        )
        self._proc.start()
        child_conn.close()
        self.n = engine_kw.get("n", 16)
        self.num_slots = engine_kw.get("num_slots", 8)
        self.pending = 0
        self._ready = False
        if not _defer_ready:
            self.wait_ready()

    def wait_ready(self) -> None:
        if not self._ready:
            # no deadline: engine builds legitimately take long (JAX
            # import + compile), but a child that dies building still
            # raises immediately via the liveness poll
            self._recv(timeout=None, op="ready")
            self._ready = True

    # -- supervised transport ------------------------------------------------

    def _die(self, reason: str, exit_code: Optional[int] = None) -> None:
        """Mark this replica dead and surface the failure. The child (if
        still running — e.g. hung) is left for `close()` to reap; callers
        route through `FleetRouter._reap` which calls it."""
        self.health = HEALTH_DEAD
        raise ReplicaError(reason, exit_code=exit_code)

    def _send(self, msg: Tuple, op: str) -> None:
        """Put one request on the pipe, retrying send-side failures
        (injected drops, transient pipe errors) with capped exponential
        backoff. Safe to retry: a request that never reached the pipe
        cannot have been executed."""
        if self.health == HEALTH_DEAD:
            raise ReplicaError(f"replica is dead; cannot send {op!r}")
        attempt = 0
        while True:
            dropped = False
            if self._faults is not None:
                dropped, delay = self._faults.before_send(op)
                if delay > 0:
                    time.sleep(delay)
            if not dropped:
                try:
                    self._conn.send(msg)
                    return
                except (BrokenPipeError, OSError) as e:
                    if not self._proc.is_alive():
                        self._die(
                            f"replica child died before {op!r} was sent "
                            f"(exit code {self._proc.exitcode})",
                            exit_code=self._proc.exitcode,
                        )
                    # transient: fall through to the retry path
                    dropped = True
            attempt += 1
            self.rpc_retries_total += 1
            if self.health == HEALTH_HEALTHY:
                self.health = HEALTH_DEGRADED  # sticky: a retry happened
            if attempt > self.rpc_retries:
                self._die(
                    f"rpc {op!r} failed to send after {attempt} attempts "
                    f"(retry budget {self.rpc_retries} exhausted)"
                )
            time.sleep(min(self.rpc_backoff_s * (2 ** (attempt - 1)), 1.0))

    def _recv(self, timeout: Optional[float], op: str):
        """Await one reply, polling so a dead child is detected instead of
        blocking forever; a live-but-silent child past `timeout` is hung
        and equally terminal (the reply stream is ordered, so a late
        reply could never be matched to a new request safely)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            step = _POLL_STEP_S
            if deadline is not None:
                step = max(0.0, min(step, deadline - time.monotonic()))
            try:
                if self._conn.poll(step):
                    status, payload = self._conn.recv()
                    if status == "err":
                        raise ReplicaError(payload)
                    return payload
            except (EOFError, OSError) as e:
                self._proc.join(timeout=1.0)
                self._die(
                    f"replica pipe closed mid-{op} "
                    f"(exit code {self._proc.exitcode}): {e}",
                    exit_code=self._proc.exitcode,
                )
            if not self._proc.is_alive():
                if self._conn.poll(0):
                    continue  # reply landed just before the exit; drain it
                self._die(
                    f"replica child died mid-{op} "
                    f"(exit code {self._proc.exitcode})",
                    exit_code=self._proc.exitcode,
                )
            if deadline is not None and time.monotonic() >= deadline:
                self._die(
                    f"rpc {op!r} timed out after {timeout:.1f}s: "
                    f"child alive but unresponsive (hung)"
                )

    def _rpc(self, op: str, *args):
        self._send((op, *args), op)
        return self._recv(self.rpc_timeout_s, op)

    # -- session lifecycle --------------------------------------------------

    def submit(self, session: StreamSession) -> None:
        self._rpc("submit", session)
        self.pending += 1

    def append_ticks(self, sid, u, targets=None) -> None:
        self._rpc("append", sid, u, targets)

    def close_session(self, sid) -> None:
        self._rpc("close_session", sid)

    def checkpoint_session(self, sid) -> SessionCheckpoint:
        ckpt = self._rpc("checkpoint", sid)
        self.pending -= 1
        return ckpt

    def restore_session(self, ckpt: SessionCheckpoint) -> None:
        self._rpc("restore", ckpt)
        self.pending += 1

    def snapshot(self) -> List[SessionCheckpoint]:
        """Non-destructive checkpoints of every live session (failover)."""
        return self._rpc("snapshot")

    # -- serving ------------------------------------------------------------

    def run_for(self, max_chunks: int = 1) -> bool:
        return self._rpc("run_for", max_chunks)

    def run_for_async(self, max_chunks: int = 1) -> None:
        self._send(("run_for", max_chunks), "run_for")

    def run_for_wait(self) -> bool:
        return self._recv(self.rpc_timeout_s, "run_for")

    def results(self) -> List[SessionResult]:
        out = self._rpc("results")
        self.pending -= len(out)
        return out

    def stats(self) -> EngineStats:
        st = self._rpc("stats")
        st.health = self.health
        return st

    def prewarm(self) -> None:
        """Warm-start the child's engine (see LocalReplica.prewarm)."""
        self._rpc("prewarm")

    def close(self) -> None:
        """Stop the child, escalating stop → terminate → kill so no zombie
        survives (join() after each signal reaps the process entry)."""
        if self._proc.is_alive() and self.health != HEALTH_DEAD:
            try:
                self._conn.send(("stop",))
            except (BrokenPipeError, OSError):
                pass
            self._proc.join(timeout=5)
        if self._proc.is_alive():
            self._proc.terminate()
            self._proc.join(timeout=5)
        if self._proc.is_alive():
            self._proc.kill()
            self._proc.join(timeout=5)
        try:
            self._conn.close()
        except OSError:
            pass
        self.health = HEALTH_DEAD


def start_fleet(
    count: int,
    transport: str = "local",
    factory=make_engine,
    faults: Optional[FaultPlan] = None,
    rpc_timeout_s: Optional[float] = 120.0,
    rpc_retries: int = 3,
    rpc_backoff_s: float = 0.05,
    **engine_kw,
) -> List[Any]:
    """Start `count` replicas of one engine config. Process replicas are
    all spawned before any ready-handshake is awaited, so their JAX
    imports/compiles overlap instead of serializing. A `faults` plan, if
    given, is threaded into EVERY replica (build per-replica plans by
    constructing replicas directly)."""
    if transport == "local":
        return [LocalReplica(factory, faults=faults, **engine_kw) for _ in range(count)]
    if transport == "process":
        reps = [
            ProcessReplica(
                factory,
                _defer_ready=True,
                rpc_timeout_s=rpc_timeout_s,
                rpc_retries=rpc_retries,
                rpc_backoff_s=rpc_backoff_s,
                faults=faults,
                **engine_kw,
            )
            for _ in range(count)
        ]
        for r in reps:
            r.wait_ready()
        return reps
    raise ValueError(f"transport must be 'local' or 'process'; got {transport!r}")
