"""Session router for the fleet tier: per-bucket pools + sticky affinity.

Placement happens at TWO granularities, and the split is the point:

- POOLS are keyed by reservoir size N. A replica only ever serves one
  compiled spec, so an N=16 tenant physically cannot queue behind an
  N=1024 tenant — head-of-line isolation is structural, not scheduled.
- WITHIN a pool, a new session goes to the least-loaded replica (live
  `pending` count), and every later interaction with that session —
  pushed ticks, close, results — follows the AFFINITY map to the replica
  that owns its slot state.

Affinity is sticky but not permanent: `migrate(sid)` checkpoints the
session out of its replica (`ReservoirEngine.checkpoint_session`, which
snapshots the SlotStore magnetization column and in-flight RLS P/Wl
lanes) and restores it into another, after which the stream continues
bit-identically — the mechanism behind drain-for-maintenance and
rebalancing, and it works across process transports because checkpoints
are host-only numpy.

FAILOVER rides the same checkpoint machinery, automatically. With
`checkpoint_every=k` the router keeps a PARENT-SIDE checkpoint of every
live session: an initial t=0 checkpoint at submit, refreshed by a
non-destructive `snapshot()` of every replica each k pump rounds, plus a
replay buffer of ticks pushed since the last snapshot. When a replica is
detected dead (its transport raises `ReplicaError` with
health == "dead" — child exited, hung past the RPC deadline, or send
retries exhausted), `_reap` removes it from the pool, reaps the process,
respawns a replacement through the registered factory (warm via the
process-wide PlanCache), restores every checkpointed session the dead
replica owned — in-flight RLS/LMS lanes included — and replays the
buffered ticks. Because checkpoint/restore is bit-exact and the replay
re-offers exactly the rows the checkpoint had not yet seen, a recovered
stream's full output is bit-identical to an uninterrupted run (scan
backend; tests/test_fleet_faults.py pins predictions AND learned
weights). Without `checkpoint_every`, a death still reaps the replica
but its sessions are lost (counted in `fault_stats`).

The router is transport-agnostic and synchronous; `fleet.frontend` wraps
it in asyncio and adds planner-driven admission control.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.reservoir import (
    SessionCheckpoint,
    SessionResult,
    StreamSession,
    _spec_host,
)

from .planner import CapacityModel
from .replica import HEALTH_DEAD, ReplicaError


@dataclasses.dataclass
class FleetFaultStats:
    """Failover observability counters, accumulated by the router."""

    replica_deaths: int = 0  # replicas reaped after a detected death
    failovers: int = 0  # reap events that recovered at least one session
    sessions_recovered: int = 0  # sessions restored from a parent-side ckpt
    sessions_lost: int = 0  # orphans with no checkpoint / no live pool
    replayed_ticks: int = 0  # buffered push rows re-applied after restore
    rpc_retries: int = 0  # send retries accumulated from reaped replicas

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _initial_checkpoint(session: StreamSession) -> SessionCheckpoint:
    """A t=0 checkpoint synthesized host-side from a session ABOUT to be
    submitted — the failover floor until the first periodic snapshot
    lands. Copies every array the engine (or the tenant) could later
    mutate, so the checkpoint is immune to both."""
    u = np.array(session.u_seq, copy=True)
    targets = None if session.targets is None else np.array(session.targets, copy=True)
    readout_w = None
    washout = 0
    if session.readout is not None:
        readout_w = np.array(np.asarray(session.readout.w_out), copy=True)
        washout = session.readout.washout
    if readout_w is not None:
        n_out = int(readout_w.shape[1])
    elif targets is not None and targets.ndim == 2:
        n_out = int(targets.shape[1])
    else:
        n_out = 1
    return SessionCheckpoint(
        sid=session.sid,
        u_seq=u,
        t=0,
        m=None if session.m0 is None else np.array(np.asarray(session.m0), copy=True),
        params=session.params,
        readout_w=readout_w,
        readout_washout=washout,
        collect_states=session.collect_states,
        targets=targets,
        learn_washout=session.learn_washout,
        open=session.open,
        n_out=n_out,
        states=None,
        outs=None,
        preds=None,
        P=None if session.learn_P0 is None else np.array(session.learn_P0, copy=True),
        Wl=None if session.learn_w0 is None else np.array(session.learn_w0, copy=True),
        spec=_spec_host(session.spec),
    )


class FleetRouter:
    def __init__(
        self,
        planner: Optional[CapacityModel] = None,
        checkpoint_every: Optional[int] = None,
    ):
        if checkpoint_every is not None and (
            not isinstance(checkpoint_every, int)
            or isinstance(checkpoint_every, bool)
            or checkpoint_every < 1
        ):
            raise ValueError(
                f"checkpoint_every must be an int >= 1 (pump rounds between "
                f"fleet snapshots) or None to disable failover; got "
                f"{checkpoint_every!r}"
            )
        self.planner = planner
        self.checkpoint_every = checkpoint_every
        self.pools: Dict[int, List] = {}  # reservoir size N -> replicas
        self._affinity: Dict[int, object] = {}  # sid -> owning replica
        self._sids = itertools.count(1)
        self.faults = FleetFaultStats()
        # failover state, all PARENT side so it survives replica death:
        # sid -> last checkpoint; sid -> ticks pushed since that checkpoint
        self._ckpts: Dict[int, SessionCheckpoint] = {}
        self._replay: Dict[int, List[Tuple[np.ndarray, Optional[np.ndarray]]]] = {}
        self._respawn: Dict[object, Callable[[], object]] = {}
        self._rounds = 0

    # -- fleet membership ---------------------------------------------------

    def add_replica(self, replica, respawn: Optional[Callable[[], object]] = None) -> None:
        """Register a replica; `respawn` is a zero-arg factory the failover
        path calls to build its replacement (same config — typically a
        `start_fleet(1, ...)` or replica-constructor closure). Replacement
        engines draw from the process-wide PlanCache, so respawn after a
        death is warm, not a cold compile."""
        self.pools.setdefault(replica.n, []).append(replica)
        if respawn is not None:
            self._respawn[replica] = respawn

    def replicas(self) -> List:
        return [r for pool in self.pools.values() for r in pool]

    def pool(self, n: int) -> List:
        if n not in self.pools:
            raise KeyError(
                f"no replica pool for reservoir size N={n}; pools exist for "
                f"{sorted(self.pools)}"
            )
        return self.pools[n]

    @staticmethod
    def _is_dead(replica) -> bool:
        return getattr(replica, "health", None) == HEALTH_DEAD

    # -- placement ----------------------------------------------------------

    def next_sid(self) -> int:
        return next(self._sids)

    def select(self, n: int):
        """Least-loaded replica in the N-pool (live pending count)."""
        pool = self.pool(n)
        if not pool:
            raise ReplicaError(f"pool N={n} has no live replicas")
        return min(pool, key=lambda r: r.pending)

    def submit(self, n: int, session: StreamSession):
        """Place a session in the N-pool; returns the owning replica. With
        failover enabled the session's t=0 checkpoint is taken BEFORE the
        replica sees it, so even a replica that dies on its very first
        chunk loses nothing. A placement that lands on a dying replica
        fails over and retries on the survivors."""
        if session.sid in self._affinity:
            raise ValueError(f"sid {session.sid} is already being served")
        if self.checkpoint_every is not None:
            self._ckpts[session.sid] = _initial_checkpoint(session)
        while True:
            replica = self.select(n)
            try:
                replica.submit(session)
            except ReplicaError:
                if self._is_dead(replica):
                    self._reap(replica)
                    continue
                if self.checkpoint_every is not None:
                    self._ckpts.pop(session.sid, None)
                raise
            self._affinity[session.sid] = replica
            return replica

    def replica_for(self, sid: int):
        try:
            return self._affinity[sid]
        except KeyError:
            raise KeyError(f"no live session with sid {sid}") from None

    # -- per-session forwarding (affinity-routed) ---------------------------

    def append_ticks(self, sid: int, u, targets=None) -> None:
        replica = self.replica_for(sid)
        try:
            replica.append_ticks(sid, u, targets)
        except ReplicaError:
            if not self._is_dead(replica):
                raise
            # the owner died under this push: fail its sessions over, then
            # re-offer the rows to the recovered owner (the dead child
            # never applied them — its last checkpoint predates this call)
            self._reap(replica)
            self.replica_for(sid).append_ticks(sid, u, targets)
        if self.checkpoint_every is not None and sid in self._ckpts:
            self._replay.setdefault(sid, []).append(
                (
                    np.array(u, copy=True),
                    None if targets is None else np.array(targets, copy=True),
                )
            )

    def close_session(self, sid: int) -> None:
        replica = self.replica_for(sid)
        try:
            replica.close_session(sid)
        except ReplicaError:
            if not self._is_dead(replica):
                raise
            self._reap(replica)
            self.replica_for(sid).close_session(sid)
        if self.checkpoint_every is not None and sid in self._ckpts:
            # the recovery path must not resurrect the stream as open
            self._ckpts[sid] = dataclasses.replace(self._ckpts[sid], open=False)

    def migrate(self, sid: int, dst=None):
        """Move a live session to another replica in its pool (or to an
        explicit `dst`, which may live in a different process). The
        checkpoint/restore round trip is bit-exact, so the tenant sees one
        uninterrupted stream. Returns the destination replica."""
        src = self.replica_for(sid)
        if dst is None:
            others = [r for r in self.pool(src.n) if r is not src]
            if not others:
                raise ValueError(
                    f"pool N={src.n} has no other replica to migrate sid "
                    f"{sid} to"
                )
            dst = min(others, key=lambda r: r.pending)
        if dst is src:
            return src
        # warm-start the destination BEFORE moving the stream: dst's hot
        # path (and adjacent autoscale buckets) compile through the shared
        # plan cache now, so the restored session's next chunk dispatches
        # a ready executable instead of stalling mid-migration on XLA.
        # getattr-guarded: third-party replica objects without prewarm
        # migrate exactly as before.
        dst_prewarm = getattr(dst, "prewarm", None)
        if dst_prewarm is not None:
            dst_prewarm()
        try:
            ckpt = src.checkpoint_session(sid)
        except ReplicaError:
            if not self._is_dead(src):
                raise
            # the source died under us — failover already moves the
            # session (from its last parent-side checkpoint)
            self._reap(src)
            return self._affinity.get(sid)
        dst.restore_session(ckpt)
        self._affinity[sid] = dst
        return dst

    # -- failover -----------------------------------------------------------

    def snapshot(self) -> int:
        """Refresh the parent-side checkpoint of every live session via a
        non-destructive `snapshot()` RPC to each replica (sessions keep
        serving, bit-identically). Returns the number of sessions
        checkpointed. Called automatically every `checkpoint_every` pump
        rounds; callable explicitly for a pre-maintenance fence."""
        count = 0
        for r in list(self.replicas()):
            try:
                ckpts = r.snapshot()
            except ReplicaError:
                if self._is_dead(r):
                    self._reap(r)
                    continue
                raise
            for ckpt in ckpts:
                if self._affinity.get(ckpt.sid) is r:
                    self._ckpts[ckpt.sid] = ckpt
                    # rows pushed before this snapshot are inside its u_seq
                    # (append_ticks applies to the engine first): buffer resets
                    self._replay.pop(ckpt.sid, None)
                    count += 1
        return count

    def _reap(self, replica) -> None:
        """Handle a detected replica death: remove it from its pool, reap
        the child (terminate-then-join — no zombies), respawn a
        replacement through the registered factory, and restore every
        session the dead replica owned from its parent-side checkpoint,
        replaying ticks buffered since. Sessions without a checkpoint
        (failover disabled) or without a surviving pool are counted lost."""
        self.faults.replica_deaths += 1
        self.faults.rpc_retries += getattr(replica, "rpc_retries_total", 0)
        pool = self.pools.get(replica.n, [])
        if replica in pool:
            pool.remove(replica)
        respawn = self._respawn.pop(replica, None)
        try:
            replica.close()
        except Exception:  # noqa: BLE001 — reaping a corpse; nothing to save
            pass
        replacement = None
        if respawn is not None:
            replacement = respawn()
            self.add_replica(replacement, respawn=respawn)
        orphans = [sid for sid, r in self._affinity.items() if r is replica]
        if not orphans:
            return
        recovered = 0
        warmed = set()
        for sid in orphans:
            ckpt = self._ckpts.get(sid)
            dst = None
            pool_now = self.pools.get(replica.n, [])
            if ckpt is not None and pool_now:
                dst = (
                    replacement
                    if replacement is not None
                    else min(pool_now, key=lambda r: r.pending)
                )
            if dst is None:
                self._affinity.pop(sid, None)
                self._ckpts.pop(sid, None)
                self._replay.pop(sid, None)
                self.faults.sessions_lost += 1
                continue
            if id(dst) not in warmed:
                dst_prewarm = getattr(dst, "prewarm", None)
                if dst_prewarm is not None:
                    dst_prewarm()
                warmed.add(id(dst))
            # rows pushed after the checkpoint but before a close_session
            # still have to land: restore as open, replay, then re-close
            rows = self._replay.get(sid, ())
            reopen = bool(rows) and not ckpt.open
            dst.restore_session(
                dataclasses.replace(ckpt, open=True) if reopen else ckpt
            )
            for u, targets in rows:
                dst.append_ticks(sid, u, targets)
                self.faults.replayed_ticks += int(np.shape(u)[0]) if np.ndim(u) else 1
            if reopen:
                dst.close_session(sid)
            self._affinity[sid] = dst
            recovered += 1
        self.faults.sessions_recovered += recovered
        if recovered:
            self.faults.failovers += 1

    # -- serving ------------------------------------------------------------

    def run_for(self, max_chunks: int = 1) -> bool:
        """One overlapped pump round: LAUNCH max_chunks on every replica,
        then collect. Process replicas genuinely run their chunks in
        parallel between the send and recv phases; local replicas execute
        inline. True while any replica still has work. A replica that dies
        in either phase is reaped (and its sessions failed over) after the
        survivors' round completes."""
        self._rounds += 1
        if (
            self.checkpoint_every is not None
            and self._rounds % self.checkpoint_every == 0
        ):
            self.snapshot()
        reps = self.replicas()
        dead: List = []
        for r in reps:
            try:
                r.run_for_async(max_chunks)
            except ReplicaError:
                if not self._is_dead(r):
                    raise
                dead.append(r)
        worked = False
        for r in reps:
            if any(r is d for d in dead):
                continue
            try:
                worked = r.run_for_wait() or worked
            except ReplicaError:
                if not self._is_dead(r):
                    raise
                dead.append(r)
        for r in dead:
            self._reap(r)
            worked = True  # recovered sessions still need serving
        return worked

    def results(self) -> Dict[int, SessionResult]:
        """Drain finished results from every replica; affinity entries (and
        failover checkpoints) for finished sessions are released."""
        out: Dict[int, SessionResult] = {}
        for r in list(self.replicas()):
            try:
                batch = r.results()
            except ReplicaError:
                if self._is_dead(r):
                    self._reap(r)
                    continue
                raise
            for res in batch:
                out[res.sid] = res
                self._affinity.pop(res.sid, None)
                self._ckpts.pop(res.sid, None)
                self._replay.pop(res.sid, None)
        return out

    def drain(self, max_rounds: int = 100_000) -> Dict[int, SessionResult]:
        """Pump until no replica has work; returns everything that
        finished. Open (push) streams idle rather than finish — they stay
        resident and keep their affinity."""
        out = self.results()
        for _ in range(max_rounds):
            if not self.run_for(1):
                break
            out.update(self.results())
        out.update(self.results())
        return out

    # -- observability ------------------------------------------------------

    def stats(self) -> Dict[int, List]:
        """Pool -> per-replica EngineStats, the live side of the planner's
        predicted-vs-measured comparison."""
        out: Dict[int, List] = {}
        for n in list(self.pools):
            col = []
            for r in list(self.pools[n]):
                try:
                    col.append(r.stats())
                except ReplicaError:
                    if self._is_dead(r):
                        self._reap(r)
                        continue
                    raise
            out[n] = col
        return out

    def fault_stats(self) -> dict:
        """Failover/quarantine counters: the router's own recovery tally
        plus live-replica send retries and engine-side quarantined lanes
        (the latter via a stats round trip)."""
        d = self.faults.to_dict()
        d["rpc_retries"] += sum(
            getattr(r, "rpc_retries_total", 0) for r in self.replicas()
        )
        quarantined = 0
        for col in self.stats().values():
            quarantined += sum(st.quarantined_lanes for st in col)
        d["quarantined_lanes"] = quarantined
        return d

    def close(self) -> None:
        for r in self.replicas():
            r.close()
        self._ckpts.clear()
        self._replay.clear()
        self._respawn.clear()
