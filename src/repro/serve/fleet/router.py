"""Session router for the fleet tier: per-bucket pools + sticky affinity.

Placement happens at TWO granularities, and the split is the point:

- POOLS are keyed by reservoir size N. A replica only ever serves one
  compiled spec, so an N=16 tenant physically cannot queue behind an
  N=1024 tenant — head-of-line isolation is structural, not scheduled.
- WITHIN a pool, a new session goes to the least-loaded replica (live
  `pending` count), and every later interaction with that session —
  pushed ticks, close, results — follows the AFFINITY map to the replica
  that owns its slot state.

Affinity is sticky but not permanent: `migrate(sid)` checkpoints the
session out of its replica (`ReservoirEngine.checkpoint_session`, which
snapshots the SlotStore magnetization column and in-flight RLS P/Wl
lanes) and restores it into another, after which the stream continues
bit-identically — the mechanism behind drain-for-maintenance and
rebalancing, and it works across process transports because checkpoints
are host-only numpy.

The router is transport-agnostic and synchronous; `fleet.frontend` wraps
it in asyncio and adds planner-driven admission control.
"""

from __future__ import annotations

import itertools
from typing import Dict, List, Optional

from repro.serve.reservoir import SessionResult, StreamSession

from .planner import CapacityModel


class FleetRouter:
    def __init__(self, planner: Optional[CapacityModel] = None):
        self.planner = planner
        self.pools: Dict[int, List] = {}  # reservoir size N -> replicas
        self._affinity: Dict[int, object] = {}  # sid -> owning replica
        self._sids = itertools.count(1)

    # -- fleet membership ---------------------------------------------------

    def add_replica(self, replica) -> None:
        self.pools.setdefault(replica.n, []).append(replica)

    def replicas(self) -> List:
        return [r for pool in self.pools.values() for r in pool]

    def pool(self, n: int) -> List:
        if n not in self.pools:
            raise KeyError(
                f"no replica pool for reservoir size N={n}; pools exist for "
                f"{sorted(self.pools)}"
            )
        return self.pools[n]

    # -- placement ----------------------------------------------------------

    def next_sid(self) -> int:
        return next(self._sids)

    def select(self, n: int):
        """Least-loaded replica in the N-pool (live pending count)."""
        return min(self.pool(n), key=lambda r: r.pending)

    def submit(self, n: int, session: StreamSession):
        """Place a session in the N-pool; returns the owning replica."""
        if session.sid in self._affinity:
            raise ValueError(f"sid {session.sid} is already being served")
        replica = self.select(n)
        replica.submit(session)
        self._affinity[session.sid] = replica
        return replica

    def replica_for(self, sid: int):
        try:
            return self._affinity[sid]
        except KeyError:
            raise KeyError(f"no live session with sid {sid}") from None

    # -- per-session forwarding (affinity-routed) ---------------------------

    def append_ticks(self, sid: int, u, targets=None) -> None:
        self.replica_for(sid).append_ticks(sid, u, targets)

    def close_session(self, sid: int) -> None:
        self.replica_for(sid).close_session(sid)

    def migrate(self, sid: int, dst=None):
        """Move a live session to another replica in its pool (or to an
        explicit `dst`, which may live in a different process). The
        checkpoint/restore round trip is bit-exact, so the tenant sees one
        uninterrupted stream. Returns the destination replica."""
        src = self.replica_for(sid)
        if dst is None:
            others = [r for r in self.pool(src.n) if r is not src]
            if not others:
                raise ValueError(
                    f"pool N={src.n} has no other replica to migrate sid "
                    f"{sid} to"
                )
            dst = min(others, key=lambda r: r.pending)
        if dst is src:
            return src
        # warm-start the destination BEFORE moving the stream: dst's hot
        # path (and adjacent autoscale buckets) compile through the shared
        # plan cache now, so the restored session's next chunk dispatches
        # a ready executable instead of stalling mid-migration on XLA.
        # getattr-guarded: third-party replica objects without prewarm
        # migrate exactly as before.
        dst_prewarm = getattr(dst, "prewarm", None)
        if dst_prewarm is not None:
            dst_prewarm()
        ckpt = src.checkpoint_session(sid)
        dst.restore_session(ckpt)
        self._affinity[sid] = dst
        return dst

    # -- serving ------------------------------------------------------------

    def run_for(self, max_chunks: int = 1) -> bool:
        """One overlapped pump round: LAUNCH max_chunks on every replica,
        then collect. Process replicas genuinely run their chunks in
        parallel between the send and recv phases; local replicas execute
        inline. True while any replica still has work."""
        reps = self.replicas()
        for r in reps:
            r.run_for_async(max_chunks)
        worked = False
        for r in reps:
            worked = r.run_for_wait() or worked
        return worked

    def results(self) -> Dict[int, SessionResult]:
        """Drain finished results from every replica; affinity entries for
        finished sessions are released."""
        out: Dict[int, SessionResult] = {}
        for r in self.replicas():
            for res in r.results():
                out[res.sid] = res
                self._affinity.pop(res.sid, None)
        return out

    def drain(self, max_rounds: int = 100_000) -> Dict[int, SessionResult]:
        """Pump until no replica has work; returns everything that
        finished. Open (push) streams idle rather than finish — they stay
        resident and keep their affinity."""
        out = self.results()
        for _ in range(max_rounds):
            if not self.run_for(1):
                break
            out.update(self.results())
        out.update(self.results())
        return out

    # -- observability ------------------------------------------------------

    def stats(self) -> Dict[int, List]:
        """Pool -> per-replica EngineStats, the live side of the planner's
        predicted-vs-measured comparison."""
        return {
            n: [r.stats() for r in pool] for n, pool in self.pools.items()
        }

    def close(self) -> None:
        for r in self.replicas():
            r.close()
