"""Deterministic fault injection for the fleet tier.

Every failure path the supervision/failover layer claims to handle —
replica crash, hung child, delayed or dropped RPC, a tenant lane going
non-finite — is exercised by TESTS, not hoped for. A `FaultPlan` is a
frozen schedule of `Fault` events threaded through the replica
transports (`LocalReplica(faults=...)` / `ProcessReplica(faults=...)`);
the plan is pure data (pickles into a spawned child unchanged), and a
`FaultRuntime` holds the mutable firing counters, one per transport
side, so parent and child each consume their own events:

- `crash` / `hang` fire in the replica's serving loop when its served
  chunk counter reaches `at_chunk` (child side: `os._exit` /
  sleep-without-replying; local transport: both fail-stop — there is no
  pipe to hang).
- `delay` / `drop` fire on the parent's transport send path for the
  matching RPC `op`, `count` times: delay sleeps `delay_s` before the
  send; drop discards the request BEFORE it reaches the pipe, which is
  what makes the retry-with-backoff path deterministic (the child never
  sees the dropped request, so a retry cannot double-execute it).
- `nan` poisons one input row of session `sid` at submit time (the row
  becomes NaN before the engine coerces it), driving the engine's lane
  quarantine without touching any co-tenant lane.

Determinism is the contract: the same plan produces the same firing
sequence every run, and `FaultPlan.random(seed)` builds the same
schedule for the same seed (tests/test_fleet_faults.py pins both).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("crash", "hang", "delay", "drop", "nan")

#: exit code a fault-injected child crashes with (visible in the
#: ReplicaError a parent raises after detecting the death)
CRASH_EXIT_CODE = 57


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled failure event.

    kind      one of FAULT_KINDS.
    at_chunk  crash/hang trigger: fire when the replica's served-chunk
              counter reaches this value (0 = before the first chunk).
    op        delay/drop: which RPC op to hit ("*" = any op).
    count     delay/drop: how many sends are affected before the fault
              is spent.
    delay_s   delay: seconds added before the matching send.
    sid       nan: the target session id.
    tick      nan: the input row poisoned at submit.
    """

    kind: str
    at_chunk: int = 0
    op: str = "*"
    count: int = 1
    delay_s: float = 0.0
    sid: Optional[int] = None
    tick: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}; got {self.kind!r}"
            )
        if not isinstance(self.at_chunk, int) or isinstance(self.at_chunk, bool) or self.at_chunk < 0:
            raise ValueError(f"at_chunk must be an int >= 0; got {self.at_chunk!r}")
        if not isinstance(self.count, int) or isinstance(self.count, bool) or self.count < 1:
            raise ValueError(f"count must be an int >= 1; got {self.count!r}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0; got {self.delay_s!r}")
        if self.kind == "delay" and self.delay_s == 0:
            raise ValueError("a delay fault needs delay_s > 0")
        if self.kind == "nan":
            if self.sid is None:
                raise ValueError("a nan fault needs a target sid")
            if not isinstance(self.tick, int) or isinstance(self.tick, bool) or self.tick < 0:
                raise ValueError(f"tick must be an int >= 0; got {self.tick!r}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A frozen, deterministic schedule of faults for one replica.

    Construct explicitly from `Fault` events, or draw a reproducible
    schedule from a seed via `FaultPlan.random(seed)`. The plan itself
    never mutates; call `runtime()` for the per-transport-side firing
    state (parent and child each hold their own runtime, so a plan
    pickled into a spawned child fires its child-side events exactly
    once regardless of what the parent consumed)."""

    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "faults", tuple(self.faults))
        for f in self.faults:
            if not isinstance(f, Fault):
                raise TypeError(f"FaultPlan takes Fault events; got {f!r}")

    @classmethod
    def random(
        cls,
        seed: int,
        *,
        n_faults: int = 3,
        kinds: Tuple[str, ...] = ("delay", "drop"),
        ops: Tuple[str, ...] = ("run_for", "stats"),
        max_delay_s: float = 0.02,
        max_count: int = 2,
        max_chunk: int = 8,
    ) -> "FaultPlan":
        """A reproducible schedule: the same seed yields the same plan."""
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []
        for _ in range(n_faults):
            kind = kinds[int(rng.integers(len(kinds)))]
            if kind in ("crash", "hang"):
                faults.append(Fault(kind, at_chunk=int(rng.integers(max_chunk))))
            elif kind == "delay":
                faults.append(
                    Fault(
                        "delay",
                        op=ops[int(rng.integers(len(ops)))],
                        count=int(rng.integers(1, max_count + 1)),
                        delay_s=float(rng.uniform(1e-4, max_delay_s)),
                    )
                )
            elif kind == "drop":
                faults.append(
                    Fault(
                        "drop",
                        op=ops[int(rng.integers(len(ops)))],
                        count=int(rng.integers(1, max_count + 1)),
                    )
                )
            else:  # nan
                faults.append(
                    Fault("nan", sid=int(rng.integers(64)), tick=int(rng.integers(16)))
                )
        return cls(faults=tuple(faults), seed=seed)

    def runtime(self) -> "FaultRuntime":
        return FaultRuntime(self)


class FaultRuntime:
    """Mutable firing state over one FaultPlan (one per transport side)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._chunks = 0  # replica-side served-chunk counter
        # remaining fire budget per delay/drop event (index into plan.faults)
        self._remaining: Dict[int, int] = {
            i: f.count
            for i, f in enumerate(plan.faults)
            if f.kind in ("delay", "drop")
        }
        self._fired_chunk_events: set = set()
        self.delays_fired = 0
        self.drops_fired = 0

    # -- replica (serving-loop) side ----------------------------------------

    def on_chunk(self) -> Optional[str]:
        """Called before each chunk the replica serves; returns "crash" or
        "hang" when a scheduled event's at_chunk is reached (each event
        fires once), else None. Increments the chunk counter."""
        action = None
        for i, f in enumerate(self.plan.faults):
            if (
                f.kind in ("crash", "hang")
                and i not in self._fired_chunk_events
                and self._chunks >= f.at_chunk
            ):
                self._fired_chunk_events.add(i)
                action = f.kind
                break
        self._chunks += 1
        return action

    def poison_session(self, session) -> None:
        """Apply scheduled nan injections to a session at submit time: the
        matching input row is replaced with NaN (on a private copy — the
        caller's array is never mutated)."""
        ticks = [
            f.tick
            for f in self.plan.faults
            if f.kind == "nan" and f.sid == session.sid
        ]
        if not ticks:
            return
        u = np.array(session.u_seq, dtype=np.asarray(session.u_seq).dtype, copy=True)
        for t in ticks:
            if t < u.shape[0]:
                u[t] = np.nan
        session.u_seq = u

    # -- transport (parent send-path) side ----------------------------------

    def before_send(self, op: str) -> Tuple[bool, float]:
        """Consult the plan before sending RPC `op`: returns
        (drop_this_send, seconds_of_injected_delay). Each matching
        delay/drop event decrements its remaining count."""
        drop = False
        delay = 0.0
        for i, f in enumerate(self.plan.faults):
            if f.kind not in ("delay", "drop") or self._remaining.get(i, 0) <= 0:
                continue
            if f.op != "*" and f.op != op:
                continue
            self._remaining[i] -= 1
            if f.kind == "delay":
                delay += f.delay_s
                self.delays_fired += 1
            else:
                drop = True
                self.drops_fired += 1
        return drop, delay
