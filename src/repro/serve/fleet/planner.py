"""Analytical capacity planner for the fleet serving tier.

Sizes replica fleets from a MODEL, not from reactive queue depth: a
roofline-flavored per-chunk cost

    t_chunk(N, E) = c0 + c1 * E + c2 * N^2*K*H + c3 * N*E*K*H + c4 * N^2*E*K*H

whose five terms are the fixed dispatch overhead, the per-lane host
assembly cost, an ensemble-independent weight-traffic term, the
elementwise/bytes term (LLGS physics, ~N*E state touched K*H times per
chunk), and the coupling-GEMM FLOPs term (the 4*2*N^2*E dipole field per
hold step — the same operand `launch/roofline.py` counts). The
coefficients are calibrated by non-negative least squares over the
measured `BENCH_serve.json` grid (relative-error weighting, so the 1 ms
N=16 cells count as much as the 2 s N=1024 cells); non-negativity keeps
every term a COST, so the model extrapolates monotonically to widths the
grid never measured. This is analytical performance modeling in the
Lumos tradition — closed-form capacity from a handful of calibrated
hardware terms — applied to the virtual-reservoir serving tier
(arXiv:2312.01121's thesis, continued past a single device).

Capacity follows from the chunk model: a replica at width E serves
E*K / t_chunk slot-ticks/sec, i.e. sessions/sec for the benchmark's
reference stream length; `learn` and reduced `precision` apply
median-ratio multipliers measured in the same grid. A FLEET of R
replicas on a host with C usable cores scales by min(R, C) — replicas
time-share cores, so scaling is linear exactly until R hits C (the
planner says so rather than pretending pipes add FLOPs).

TWO coefficient families are fit from the same grid, because the grid
records two estimators: `steady_chunk_s` (best-of-reps mid-run chunk —
the optimistic peak a warm, saturated replica can touch) and
`ticks_per_sec_burst` (full drain with admit/retire churn billed — what
a serving drain actually sustains). Peak sizes admission ceilings;
SUSTAINED predicts drain times (`drain_seconds`) and is what
`benchmarks/serve_throughput.bench_fleet` checks against measurement.
Absolute scale drifts with the host (the container's ±40% noise band,
ROADMAP caveat), so `recalibrate()` rescales both families from a cheap
same-run probe: shape offline, scale online.

`plan_fleet(workload)` inverts the model: given per-class offered load
(sessions/sec at a given N, learn, precision), it picks the replica
width and count per N-bucket with the requested headroom, and
`prediction_error()` reports how far the fit sits from the measurements
it was calibrated on — the router compares the same predictions against
live `EngineStats` at serve time.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

# replica widths the planner will propose (matches the engine's bucketed
# plan cache: powers of two keep the compile cache small)
_WIDTHS = (8, 16, 32, 64, 128, 256)


def usable_cores() -> int:
    """Cores this process may actually run on (affinity-aware)."""
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # non-Linux
        return os.cpu_count() or 1


def measure_probe_rates(
    pools: Sequence,
    hold_steps: int,
    chunk_ticks: int,
    stream_ticks: int,
    waves: int = 2,
    seed: int = 7,
) -> Dict[int, Dict[int, float]]:
    """Same-run host probe for `recalibrate`: each (n, e) pool cell
    re-measured ONCE as sustained ticks/sec of a full churn-billed drain
    on a bare engine — the grid's own burst methodology, outside the
    fleet stack, so the planner error still bills router/replica overhead.

    Probe engines draw from the process-wide plan cache
    (`repro.api.PLAN_CACHE`), so a probe that runs alongside fleet
    spin-up over the same pool shapes — `benchmarks/serve_throughput.
    bench_fleet` does exactly that — re-traces nothing. The warm pass
    still executes: recalibration wants execution-speed truth, and that
    is unaffected by where the compile came from. Returns the
    `{n: {e: rate}}` mapping `recalibrate` consumes."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.api import PLAN_CACHE, ExecPlan, make_spec
    from repro.serve.reservoir import ReservoirEngine, StreamSession

    rng = np.random.default_rng(seed)
    probe: Dict[int, Dict[int, float]] = {}
    for n, e in pools:
        spec = make_spec(n=n, n_in=1, hold_steps=hold_steps, dtype=jnp.float32)
        eng = ReservoirEngine(
            PLAN_CACHE.get_or_compile(
                spec, ExecPlan(ensemble=e, chunk_ticks=chunk_ticks)
            ),
            max_retained=e,
        )

        def _drain(num: int, ticks: int, base_sid: int):
            sessions = [
                StreamSession(
                    sid=base_sid + i,
                    u_seq=rng.uniform(0.0, 0.5, size=(ticks, 1)).astype(
                        np.float32
                    ),
                    collect_states=False,
                )
                for i in range(num)
            ]
            t0_ticks = eng.scheduler.stats.session_ticks
            t0 = time.perf_counter()
            eng.run(sessions)
            jax.block_until_ready(eng.store.m)
            dt = time.perf_counter() - t0
            return dt, eng.scheduler.stats.session_ticks - t0_ticks

        # warm the full admit/retire shape repertoire before timing
        _drain(waves * e, chunk_ticks, 0)
        dt, served = _drain(waves * e, stream_ticks, 600_000)
        eng.pop_results()
        probe.setdefault(n, {})[e] = served / dt
    return probe


def _nnls(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Non-negative least squares by active-set pruning: solve, drop
    negative coefficients, re-solve on the survivors. Small fixed feature
    count (5), so the loop terminates in <= 5 rounds."""
    active = list(range(x.shape[1]))
    while active:
        coef, *_ = np.linalg.lstsq(x[:, active], y, rcond=None)
        if (coef >= 0).all():
            full = np.zeros(x.shape[1])
            full[active] = coef
            return full
        active = [a for a, c in zip(active, coef) if c > 0]
    return np.zeros(x.shape[1])


@dataclasses.dataclass
class WorkloadClass:
    """One tenant class of the offered load."""

    n: int  # reservoir size
    rate: float  # offered sessions/sec
    learn: bool = False
    precision: Optional[str] = None  # None/"highest" or "mixed"/"bf16_coupling"


@dataclasses.dataclass
class ReplicaSpec:
    """One pool's sizing decision: `count` replicas of width `num_slots`."""

    n: int
    num_slots: int
    count: int
    learn: bool
    precision: Optional[str]
    sessions_per_sec: float  # predicted per-replica capacity


@dataclasses.dataclass
class FleetPlan:
    replicas: List[ReplicaSpec]
    total_capacity: float  # predicted sessions/sec across the fleet
    offered: float  # total offered sessions/sec
    headroom: float
    cores: int

    @property
    def utilization(self) -> float:
        return self.offered / max(self.total_capacity, 1e-30)


@dataclasses.dataclass
class CapacityModel:
    """sessions_per_sec(N, E, ...) calibrated from BENCH_serve.json."""

    coef: np.ndarray  # (5,) nonneg peak chunk-cost coefficients, seconds
    chunk_ticks: int
    hold_steps: int
    ref_stream_ticks: int
    platform: str
    learn_overhead: float  # median measured t_learn / t ratio (>= 1)
    precision_speedup: float  # median measured t / t_mixed ratio
    cells: List[dict]  # the grid the model was calibrated on
    # sustained family: fit on burst-drain rates (churn billed); None when
    # the grid predates the ticks_per_sec_burst column
    burst_coef: Optional[np.ndarray] = None
    # host-speed multiplier from recalibrate(): predictions assume the
    # calibration host until a same-run probe says otherwise
    host_scale: float = 1.0

    # -- calibration --------------------------------------------------------

    @staticmethod
    def _features(n, e, k: int, h: int) -> np.ndarray:
        return np.array(
            [
                np.ones_like(np.asarray(n, float)),
                np.asarray(e, float),
                np.asarray(n, float) ** 2 * k * h,
                np.asarray(n, float) * np.asarray(e, float) * k * h,
                np.asarray(n, float) ** 2 * np.asarray(e, float) * k * h,
            ]
        ).T

    @classmethod
    def from_bench(cls, bench: Union[str, dict]) -> "CapacityModel":
        """Calibrate from a BENCH_serve.json path or its parsed dict."""
        if isinstance(bench, str):
            with open(bench) as f:
                bench = json.load(f)
        cells = [c for c in bench["cells"] if "steady_chunk_s" in c]
        if len(cells) < 3:
            raise ValueError(
                f"need >= 3 measured grid cells to calibrate; got {len(cells)}"
            )
        k = int(bench["chunk_ticks"])
        h = int(bench["hold_steps"])
        x = cls._features(
            np.array([c["n"] for c in cells]),
            np.array([c["e"] for c in cells]),
            k,
            h,
        )
        y = np.array([c["steady_chunk_s"] for c in cells])
        # relative-error weighting: divide each row by its observation so
        # the fit minimizes (pred/obs - 1)^2 instead of absolute seconds
        coef = _nnls(x / y[:, None], np.ones_like(y))
        burst_coef = None
        burst = [c for c in cells if c.get("ticks_per_sec_burst")]
        if len(burst) >= 3:
            xb = cls._features(
                np.array([c["n"] for c in burst]),
                np.array([c["e"] for c in burst]),
                k,
                h,
            )
            # sustained effective chunk time: E*K ticks / drain rate
            yb = np.array(
                [c["e"] * k / c["ticks_per_sec_burst"] for c in burst]
            )
            burst_coef = _nnls(xb / yb[:, None], np.ones_like(yb))
        learn = [c["learn_overhead"] for c in cells if "learn_overhead" in c]
        mixed = [
            c["precision_speedup"] for c in cells if "precision_speedup" in c
        ]
        return cls(
            coef=coef,
            chunk_ticks=k,
            hold_steps=h,
            ref_stream_ticks=int(bench.get("ref_stream_ticks", 1)),
            platform=str(bench.get("backend_platform", "cpu")),
            learn_overhead=float(np.median(learn)) if learn else 1.0,
            precision_speedup=float(np.median(mixed)) if mixed else 1.0,
            cells=cells,
            burst_coef=burst_coef,
        )

    # -- the forward model --------------------------------------------------

    def t_chunk(
        self,
        n: int,
        e: int,
        learn: bool = False,
        precision: Optional[str] = None,
        sustained: bool = False,
    ) -> float:
        """Predicted wall seconds per K-tick chunk: the peak (steady
        mid-run) estimate by default, the sustained (churn-billed)
        estimate with `sustained=True` (falls back to peak when the grid
        had no burst column)."""
        coef = (
            self.burst_coef
            if sustained and self.burst_coef is not None
            else self.coef
        )
        t = float(
            self._features(n, e, self.chunk_ticks, self.hold_steps) @ coef
        )
        if learn:
            t *= self.learn_overhead
        if precision not in (None, "highest"):
            t /= max(self.precision_speedup, 1e-30)
        return t / max(self.host_scale, 1e-30)

    def sessions_per_sec(
        self,
        n: int,
        e: int,
        platform: Optional[str] = None,
        precision: Optional[str] = None,
        learn: bool = False,
        sustained: bool = False,
    ) -> float:
        """Predicted reference-stream sessions/sec of ONE replica at width
        E. `platform` must match the calibration platform (a model fit on
        CPU timings says nothing about a GPU fleet)."""
        if platform is not None and platform != self.platform:
            raise ValueError(
                f"model calibrated on {self.platform!r}; re-run the serve "
                f"benchmark on {platform!r} to plan for it"
            )
        ticks = e * self.chunk_ticks / self.t_chunk(
            n, e, learn, precision, sustained=sustained
        )
        return ticks / self.ref_stream_ticks

    def drain_seconds(
        self,
        n: int,
        e: int,
        sessions: int,
        stream_ticks: int,
        replicas: int = 1,
        cores: Optional[int] = None,
        **kw,
    ) -> float:
        """Predicted wall seconds for one pool to drain `sessions` streams
        of `stream_ticks` ticks — the SUSTAINED family (admit/retire churn
        billed), which is the estimator serving drains actually follow."""
        cores = usable_cores() if cores is None else cores
        rate = (
            e * self.chunk_ticks
            / self.t_chunk(n, e, sustained=True, **kw)
            * min(replicas, max(cores, 1))
        )
        return sessions * stream_ticks / rate

    def recalibrate(
        self, measured_ticks_per_sec: Dict[int, Dict[int, float]]
    ) -> float:
        """Rescale BOTH families from a same-run probe: `{n: {e: rate}}`
        of sustained ticks/sec measured NOW with the grid's own burst
        methodology. Sets `host_scale` to the median measured/modeled
        ratio (shape stays from the offline grid; absolute speed follows
        the probe) and returns it. Ratios far from 1 mean the host has
        drifted since BENCH_serve.json was recorded — exactly the
        cross-run noise the ROADMAP says not to trust."""
        self.host_scale = 1.0  # model rates at calibration scale
        ratios = [
            rate / (
                e * self.chunk_ticks / self.t_chunk(n, e, sustained=True)
            )
            for n, by_e in measured_ticks_per_sec.items()
            for e, rate in by_e.items()
        ]
        if not ratios:
            raise ValueError("probe is empty — nothing to recalibrate from")
        self.host_scale = float(np.median(ratios))
        return self.host_scale

    def fleet_sessions_per_sec(
        self,
        n: int,
        e: int,
        replicas: int,
        cores: Optional[int] = None,
        **kw,
    ) -> float:
        """Fleet capacity: replicas time-share cores, so throughput scales
        by min(replicas, cores) — linear until the host runs out."""
        cores = usable_cores() if cores is None else cores
        return self.sessions_per_sec(n, e, **kw) * min(replicas, max(cores, 1))

    def degraded_fleet_sessions_per_sec(
        self,
        n: int,
        e: int,
        replicas: int,
        cores: Optional[int] = None,
        **kw,
    ) -> float:
        """Sustained capacity with ONE replica removed — the admission
        ceiling a pool should enforce while a replica is unhealthy or
        being respawned (the fleet frontend's degraded mode sheds new
        streams above it rather than queueing behind the recovery)."""
        kw.setdefault("sustained", True)
        return self.fleet_sessions_per_sec(
            n, e, replicas=max(replicas - 1, 1), cores=cores, **kw
        )

    # -- self-assessment ----------------------------------------------------

    def prediction_error(self) -> dict:
        """Relative |pred - measured| / measured on the calibration grid.

        The honest number to publish next to any plan: if the model is off
        by 20% on cells it has SEEN, trust fleet sizing to no better.
        Errors are evaluated at calibration scale (host_scale factored
        out), so recalibrating doesn't flatter or damn the fit."""
        scale = self.host_scale
        errs = {}
        errs_sustained = {}
        for c in self.cells:
            pred = self.t_chunk(c["n"], c["e"]) * scale
            errs[f"n{c['n']}_e{c['e']}"] = abs(pred - c["steady_chunk_s"]) / c[
                "steady_chunk_s"
            ]
            if self.burst_coef is not None and c.get("ticks_per_sec_burst"):
                meas = c["e"] * self.chunk_ticks / c["ticks_per_sec_burst"]
                pred = self.t_chunk(c["n"], c["e"], sustained=True) * scale
                errs_sustained[f"n{c['n']}_e{c['e']}"] = abs(pred - meas) / meas
        vals = np.array(list(errs.values()))
        out = {
            "per_cell": errs,
            "median": float(np.median(vals)),
            "max": float(vals.max()),
        }
        if errs_sustained:
            vals = np.array(list(errs_sustained.values()))
            out.update(
                per_cell_sustained=errs_sustained,
                sustained_median=float(np.median(vals)),
                sustained_max=float(vals.max()),
            )
        return out

    # -- planning -----------------------------------------------------------

    def best_width(
        self,
        n: int,
        widths: Sequence[int] = _WIDTHS,
        **kw,
    ) -> int:
        """Replica width maximizing predicted sessions/sec at this N (the
        chunk cost is dispatch-dominated at small N, so wider wins there;
        at large N the FLOPs term flattens the curve)."""
        return max(widths, key=lambda e: self.sessions_per_sec(n, e, **kw))

    def plan_fleet(
        self,
        workload: Sequence[WorkloadClass],
        headroom: float = 0.2,
        cores: Optional[int] = None,
        max_width: int = 256,
    ) -> FleetPlan:
        """Size one replica pool per workload class: the width that
        maximizes per-replica capacity, then enough replicas to cover the
        offered rate with `headroom` to spare. Replica counts are demand
        math; whether min(R, cores) lets them all run full-rate is the
        fleet-wide capacity number reported back."""
        cores = usable_cores() if cores is None else cores
        replicas: List[ReplicaSpec] = []
        offered = 0.0
        for w in workload:
            offered += w.rate
            kw = dict(learn=w.learn, precision=w.precision)
            widths = [e for e in _WIDTHS if e <= max_width]
            e = self.best_width(w.n, widths, **kw)
            cap = self.sessions_per_sec(w.n, e, **kw)
            count = max(1, math.ceil(w.rate * (1.0 + headroom) / cap))
            replicas.append(
                ReplicaSpec(
                    n=w.n,
                    num_slots=e,
                    count=count,
                    learn=w.learn,
                    precision=w.precision,
                    sessions_per_sec=cap,
                )
            )
        total_replicas = sum(r.count for r in replicas)
        share = min(total_replicas, max(cores, 1)) / max(total_replicas, 1)
        total = sum(r.count * r.sessions_per_sec for r in replicas) * share
        return FleetPlan(
            replicas=replicas,
            total_capacity=total,
            offered=offered,
            headroom=headroom,
            cores=cores,
        )
