"""Asyncio front-end for the fleet: submit/push/drain with admission control.

The front-end owns a background PUMP task that advances every replica one
chunk round at a time (via the router's overlapped `run_for`) and folds
finished results into an awaitable map. Client coroutines see three
verbs:

    sid = await fleet.submit_stream(n=16, u_seq=u)        # place
    await fleet.push_ticks(sid, more_u)                   # feed (open)
    result = await fleet.result(sid)                      # harvest

Admission control is PLANNER-DRIVEN, not reactive: the per-pool inflight
ceiling is what the calibrated `CapacityModel` says the pool can retire
within `admit_window_s` seconds (floored at the pool's slot capacity —
the planner never starves a pool below what its hardware holds).
`submit_stream` applies BACKPRESSURE by awaiting until the pool dips
below its ceiling; with `max_waiters` set, submissions beyond that
ceiling-plus-queue fail fast with `AdmissionError` instead of building an
unbounded wait line. Both behaviors exist so a bursty tenant slows down
at the door rather than inflating every resident tenant's latency.

Engine/replica calls run in a dedicated SINGLE-THREADED executor: local
replicas release the GIL inside XLA compute, and process replicas spend
the time blocked on a pipe, so the loop stays responsive either way —
but router access must never overlap, because a ProcessReplica pipe has
exactly one reply stream (two threads interleaving send/recv would steal
each other's replies). One worker serializes pump rounds, submissions,
and pushes; the replica children still run their chunks in parallel via
the router's split-phase launch/collect pump.

Fault tolerance: client-facing router calls retry `ReplicaError` with
capped exponential backoff (the router fails dead replicas over
synchronously; the retry bridges recoveries that need a pump round), the
pump itself survives replica failures, and DEGRADED mode — forced via
`set_degraded(True)` or automatic while any pool replica's health is not
"healthy" — sheds new streams with a structured `OverloadError` instead
of queueing unboundedly behind a recovery. `shed_streams` and
`fault_stats()` expose the tally.
"""

from __future__ import annotations

import asyncio
import math
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional

import numpy as np

from repro.serve.reservoir import SessionResult, StreamSession

from .planner import CapacityModel
from .replica import HEALTH_HEALTHY, ReplicaError
from .router import FleetRouter


class AdmissionError(RuntimeError):
    """Submission rejected: the pool is at capacity and its wait line is
    full. Retry later or grow the fleet (`CapacityModel.plan_fleet`)."""


class OverloadError(AdmissionError):
    """Structured DEGRADED-mode rejection: the pool is running with
    reduced capacity (a replica unhealthy/respawning, or degraded mode
    forced) and new streams are shed at the door instead of queueing
    behind a recovery. Carries machine-readable fields so a client can
    back off or re-target without parsing the message."""

    def __init__(self, n: int, inflight: int, limit: int, reason: str):
        self.n = n
        self.inflight = inflight
        self.limit = limit
        self.reason = reason
        super().__init__(
            f"pool N={n} degraded ({reason}): shedding new streams at "
            f"{inflight}/{limit} inflight — retry with backoff"
        )

    def to_dict(self) -> dict:
        return {
            "error": "overload",
            "n": self.n,
            "inflight": self.inflight,
            "limit": self.limit,
            "reason": self.reason,
        }


class FleetFrontend:
    def __init__(
        self,
        router: FleetRouter,
        planner: Optional[CapacityModel] = None,
        admit_window_s: float = 1.0,
        max_waiters: Optional[int] = None,
        idle_sleep_s: float = 0.002,
        degraded: bool = False,
        rpc_retries: int = 2,
        rpc_backoff_s: float = 0.05,
        rpc_backoff_max_s: float = 1.0,
    ):
        if not isinstance(rpc_retries, int) or isinstance(rpc_retries, bool) or rpc_retries < 0:
            raise ValueError(f"rpc_retries must be an int >= 0; got {rpc_retries!r}")
        if not rpc_backoff_s > 0:
            raise ValueError(f"rpc_backoff_s must be > 0; got {rpc_backoff_s!r}")
        if not rpc_backoff_max_s >= rpc_backoff_s:
            raise ValueError(
                f"rpc_backoff_max_s ({rpc_backoff_max_s!r}) must be >= "
                f"rpc_backoff_s ({rpc_backoff_s!r})"
            )
        self.router = router
        self.planner = planner if planner is not None else router.planner
        self.admit_window_s = admit_window_s
        self.max_waiters = max_waiters
        self.idle_sleep_s = idle_sleep_s
        # in-flight RPC resilience: a router call that still fails after
        # the router's own synchronous failover (ReplicaError) is retried
        # with capped exponential backoff — recovery may need a pump round
        self.rpc_retries = rpc_retries
        self.rpc_backoff_s = rpc_backoff_s
        self.rpc_backoff_max_s = rpc_backoff_max_s
        # degraded mode: shed new streams with a structured OverloadError
        # instead of queueing unboundedly. Entered explicitly
        # (set_degraded) or automatically while any pool replica's health
        # is not "healthy".
        self._degraded = bool(degraded)
        self.shed_streams = 0
        self._inflight: Dict[int, int] = {}  # pool N -> live sessions
        self._waiters: Dict[int, int] = {}  # pool N -> queued submitters
        self._sid_pool: Dict[int, int] = {}  # sid -> pool N (accounting)
        self._results: Dict[int, SessionResult] = {}
        self._cond: Optional[asyncio.Condition] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._stopping = False
        # ONE worker: replica pipes carry one reply stream each, so router
        # calls (pump / submit / push / close) must never overlap
        self._exec: Optional[ThreadPoolExecutor] = None

    # -- capacity -----------------------------------------------------------

    def pool_limit(self, n: int, degraded: bool = False) -> Optional[int]:
        """Planner-estimated inflight ceiling for pool N (None: unlimited,
        no planner given). Sessions the pool can retire in admit_window_s,
        never below the pool's aggregate slot count. degraded=True prices
        the pool at one replica fewer — the ceiling to SHED above while a
        replica is being respawned, so recovery capacity isn't promised
        to new streams."""
        if self.planner is None:
            if degraded:
                # no planner: the pool's structural slot capacity is the
                # shed line — degraded admission is never unlimited
                pool = self.router.pool(n)
                return sum(r.num_slots for r in pool) if pool else None
            return None
        pool = self.router.pool(n)
        slots = sum(r.num_slots for r in pool)
        # sustained family: what the pool actually retires under churn,
        # not the optimistic mid-run peak
        e = max(r.num_slots for r in pool)
        if degraded:
            cap = self.planner.degraded_fleet_sessions_per_sec(
                n, e, replicas=len(pool)
            )
        else:
            cap = self.planner.fleet_sessions_per_sec(
                n, e, replicas=len(pool), sustained=True
            )
        return max(slots, math.ceil(cap * self.admit_window_s))

    # -- degraded mode -------------------------------------------------------

    @property
    def degraded(self) -> bool:
        return self._degraded

    def set_degraded(self, flag: bool) -> None:
        """Force degraded admission on/off (ops override; health-driven
        degradation is automatic per pool)."""
        self._degraded = bool(flag)

    def pool_degraded(self, n: int) -> bool:
        """True when pool N should shed: degraded mode forced, or any of
        its replicas reports non-healthy (a cheap local attribute — no
        RPC; the supervision layer stamps health on retry/death)."""
        if self._degraded:
            return True
        return any(
            getattr(r, "health", HEALTH_HEALTHY) != HEALTH_HEALTHY
            for r in self.router.pool(n)
        )

    # -- lifecycle ----------------------------------------------------------

    async def start(self) -> None:
        self._cond = asyncio.Condition()
        self._stopping = False
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="fleet-frontend"
        )
        self._pump_task = asyncio.create_task(self._pump())

    async def aclose(self) -> None:
        self._stopping = True
        if self._pump_task is not None:
            await self._pump_task
            self._pump_task = None
        if self._exec is not None:
            self._exec.shutdown(wait=True)
            self._exec = None
        self.router.close()

    async def __aenter__(self) -> "FleetFrontend":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.aclose()

    async def _call(self, fn, *args):
        """Run a router call on the serialized executor, retrying
        `ReplicaError` with capped exponential backoff. The router already
        fails dead replicas over synchronously; an error surviving that
        means recovery needs time (respawn, pool rebuild) — backoff gives
        it pump rounds instead of failing the client's first retry."""
        loop = asyncio.get_running_loop()
        delay = self.rpc_backoff_s
        attempt = 0
        while True:
            try:
                return await loop.run_in_executor(self._exec, fn, *args)
            except ReplicaError:
                attempt += 1
                if attempt > self.rpc_retries:
                    raise
                await asyncio.sleep(min(delay, self.rpc_backoff_max_s))
                delay *= 2

    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        while not self._stopping:
            try:
                worked = await loop.run_in_executor(
                    self._exec, self.router.run_for, 1
                )
                finished = await loop.run_in_executor(
                    self._exec, self.router.results
                )
            except ReplicaError:
                # a replica failure the router could not absorb this round
                # (e.g. no respawn registered yet) must not kill the pump —
                # surviving pools keep serving; retry next round
                await asyncio.sleep(self.idle_sleep_s)
                continue
            if finished:
                async with self._cond:
                    self._results.update(finished)
                    for sid in finished:
                        n = self._sid_pool.pop(sid, None)
                        if n is not None:
                            self._inflight[n] -= 1
                    self._cond.notify_all()
            if not worked:
                # idle (everything drained or parked open streams): yield
                # so submitters/pushers get the loop, then poll again
                await asyncio.sleep(self.idle_sleep_s)

    # -- client verbs -------------------------------------------------------

    async def submit_stream(
        self,
        n: int,
        u_seq: np.ndarray,
        *,
        targets: Optional[np.ndarray] = None,
        readout=None,
        params=None,
        m0=None,
        collect_states: bool = True,
        learn_washout: int = 0,
        open: bool = False,
        sid: Optional[int] = None,
    ) -> int:
        """Admit one stream into the N-pool; returns its sid.

        Blocks (backpressure) while the pool is at its planner ceiling;
        raises AdmissionError when `max_waiters` submitters are already
        blocked on that pool."""
        if self._cond is None:
            raise RuntimeError("frontend not started — use `async with`")
        degraded = self.pool_degraded(n)
        limit = self.pool_limit(n, degraded=degraded)
        async with self._cond:
            if degraded and limit is not None and self._inflight.get(n, 0) >= limit:
                self.shed_streams += 1
                raise OverloadError(
                    n=n,
                    inflight=self._inflight.get(n, 0),
                    limit=limit,
                    reason=(
                        "degraded mode forced"
                        if self._degraded
                        else "replica unhealthy (failover in progress)"
                    ),
                )
            if (
                limit is not None
                and self.max_waiters is not None
                and self._inflight.get(n, 0) >= limit
                and self._waiters.get(n, 0) >= self.max_waiters
            ):
                raise AdmissionError(
                    f"pool N={n} at capacity ({limit} inflight, "
                    f"{self._waiters[n]} waiting); offered load exceeds the "
                    f"planned fleet — re-plan with CapacityModel.plan_fleet"
                )
            self._waiters[n] = self._waiters.get(n, 0) + 1
            try:
                while (
                    limit is not None and self._inflight.get(n, 0) >= limit
                ):
                    await self._cond.wait()
            finally:
                self._waiters[n] -= 1
            sid = self.router.next_sid() if sid is None else sid
            session = StreamSession(
                sid=sid,
                u_seq=u_seq,
                params=params,
                readout=readout,
                m0=m0,
                collect_states=collect_states,
                targets=targets,
                learn_washout=learn_washout,
                open=open,
            )
            await self._call(self.router.submit, n, session)
            self._inflight[n] = self._inflight.get(n, 0) + 1
            self._sid_pool[sid] = n
        return sid

    async def push_ticks(self, sid: int, u, targets=None) -> None:
        """Feed more rows to an open stream (affinity-routed; retried with
        backoff across a failover)."""
        await self._call(self.router.append_ticks, sid, u, targets)

    async def close_stream(self, sid: int) -> None:
        """Let an open stream finish once its pushed input is exhausted."""
        await self._call(self.router.close_session, sid)

    async def result(self, sid: int) -> SessionResult:
        """Await one stream's finished SessionResult."""
        async with self._cond:
            while sid not in self._results:
                await self._cond.wait()
            return self._results.pop(sid)

    async def drain_results(self) -> Dict[int, SessionResult]:
        """Await every inflight (non-open) stream, then hand back all
        finished results collected so far."""
        async with self._cond:
            while any(self._inflight.get(n, 0) > 0 for n in self._inflight):
                await self._cond.wait()
            out, self._results = self._results, {}
            return out

    def stats(self):
        """Live per-pool EngineStats (the planner's measured side)."""
        return self.router.stats()

    def fault_stats(self) -> dict:
        """Failover/quarantine counters (router + replicas) plus the
        streams this frontend shed while degraded."""
        d = self.router.fault_stats()
        d["shed_streams"] = self.shed_streams
        return d
