"""Slot scheduler for the streaming reservoir engine.

The reservoir analogue of continuous batching (serve/engine.py): a FIFO
admission queue feeds a pool of ensemble-lane slots. Admission and
retirement happen between ticks (between CHUNKS on the pipelined path) —
the batched integrate never stalls on a straggler session, and a freed
slot is refilled at the very next boundary.

The scheduler also keeps the load signals the autoscaler consumes:
occupancy (served session-ticks over offered slot-ticks), queue depth, and
queue wait (ticks a session sat queued before admission). `AutoscalePolicy`
is the pluggable decision rule — given those signals it returns a target
slot count, which the engine rounds to its bucketed plan cache
(power-of-two ensemble widths) and applies by migrating the slot store.

Admission stays deliberately dumb (FIFO + first-free-slot): policies like
shortest-stream-first or tenant fairness plug in by overriding `pick`.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    admitted: int = 0
    retired: int = 0
    ticks: int = 0
    # aggregate session-ticks actually served (for throughput accounting)
    session_ticks: int = 0
    # aggregate slot-ticks offered (num_slots summed per tick) — occupancy
    # denominator; tracks resizes because num_slots is sampled per update
    slot_ticks: int = 0
    # total ticks sessions spent queued before admission
    queue_wait_ticks: int = 0
    max_queue_len: int = 0
    # autoscale events applied via remap()
    grows: int = 0
    shrinks: int = 0
    # rescale compile behavior: warm_rescales drew an already-compiled
    # bucket from the process-wide plan cache (zero XLA work at the chunk
    # boundary); cold_rescales had to compile, stalling the serving loop
    # for rescale_stall_s total seconds — a nonzero cold count with the
    # background pre-warm enabled means demand outran the prewarm thread
    cold_rescales: int = 0
    warm_rescales: int = 0
    rescale_stall_s: float = 0.0
    # sessions detached mid-stream (fleet checkpoint/migration) — they
    # leave without counting as retired, so occupancy stays honest
    detached: int = 0
    # tenant lanes the engine's nan guard force-retired (non-finite
    # state/output detected in a harvested chunk; the session's result
    # carries a structured error and co-tenant lanes are untouched)
    quarantined_lanes: int = 0


class SlotScheduler:
    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.queue: Deque = deque()
        self.running: Dict[int, object] = {}  # slot -> session
        self.stats = SchedulerStats()
        self._enq_tick: Dict[int, int] = {}  # id(session) -> tick at submit

    def submit(self, session) -> None:
        self.queue.append(session)
        self.stats.submitted += 1
        self._enq_tick[id(session)] = self.stats.ticks
        self.stats.max_queue_len = max(self.stats.max_queue_len, len(self.queue))

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    def pick(self) -> Optional[object]:
        """Next session to admit; override for non-FIFO policies."""
        return self.queue.popleft() if self.queue else None

    def admissions(self, free_slots: List[int]) -> List[Tuple[int, object]]:
        """Pair queued sessions with free slots (called between ticks)."""
        placed = []
        for slot in free_slots:
            session = self.pick()
            if session is None:
                break
            self.running[slot] = session
            placed.append((slot, session))
            self.stats.admitted += 1
            enq = self._enq_tick.pop(id(session), self.stats.ticks)
            self.stats.queue_wait_ticks += self.stats.ticks - enq
        return placed

    def retire(self, slot: int) -> object:
        session = self.running.pop(slot)
        self.stats.retired += 1
        return session

    def detach(self, slot: int) -> object:
        """Remove a RUNNING session without retiring it: the fleet tier's
        checkpoint/migration path — the session continues elsewhere, so it
        is neither finished nor abandoned."""
        session = self.running.pop(slot)
        self.stats.detached += 1
        return session

    def remove_queued(self, session) -> bool:
        """Drop a not-yet-admitted session from the queue (migration of a
        queued session is just moving it). Returns False if absent."""
        try:
            self.queue.remove(session)
        except ValueError:
            return False
        self._enq_tick.pop(id(session), None)
        self.stats.detached += 1
        return True

    def remap(self, slot_map: Dict[int, int], num_slots: int) -> None:
        """Apply an autoscale resize: running sessions move old -> new slot."""
        if num_slots > self.num_slots:
            self.stats.grows += 1
        elif num_slots < self.num_slots:
            self.stats.shrinks += 1
        self.running = {slot_map[s]: sess for s, sess in self.running.items()}
        self.num_slots = num_slots

    def on_tick(self) -> None:
        self.on_ticks(1, len(self.running))

    def on_ticks(self, n_ticks: int, session_ticks: int) -> None:
        """Account a served chunk: n_ticks wall ticks, session_ticks of
        actual per-session work (sessions may finish mid-chunk)."""
        self.stats.ticks += n_ticks
        self.stats.session_ticks += session_ticks
        self.stats.slot_ticks += n_ticks * self.num_slots

    # -- load signals (autoscaler inputs) ----------------------------------

    def occupancy(self) -> float:
        """Served session-ticks / offered slot-ticks, lifetime aggregate."""
        return self.stats.session_ticks / max(1, self.stats.slot_ticks)

    def queue_depth(self) -> int:
        return len(self.queue)

    def mean_queue_wait(self) -> float:
        """Mean ticks an admitted session waited in the queue."""
        return self.stats.queue_wait_ticks / max(1, self.stats.admitted)


# ---------------------------------------------------------------------------
# Autoscale policies
# ---------------------------------------------------------------------------


class AutoscalePolicy:
    """Decide a target slot count from the scheduler's load signals.

    Called by the engine at every chunk boundary (after retirements, before
    admissions). Return a desired slot count in [min_slots, max_slots]; the
    engine rounds UP to its next cached bucket (power-of-two widths from
    min_slots) and never shrinks below the number of running sessions.
    Stateful policies (hysteresis, EWMAs) are fine — one policy instance
    belongs to one engine.
    """

    def target_slots(
        self,
        *,
        active: int,
        queued: int,
        num_slots: int,
        min_slots: int,
        max_slots: int,
    ) -> int:
        raise NotImplementedError


@dataclasses.dataclass
class QueueDepthPolicy(AutoscalePolicy):
    """Default policy: grow to cover demand, shrink on sustained idleness.

    Grow: whenever active + queued exceeds the current width, target the
    demand (the engine buckets it upward), so a burst is absorbed within
    one chunk. Shrink: only after `hysteresis` consecutive boundary checks
    with demand at or below `shrink_occupancy` of the width — brief lulls
    between bursts don't thrash the plan cache.
    """

    shrink_occupancy: float = 0.25
    hysteresis: int = 2
    _low_streak: int = dataclasses.field(default=0, repr=False)

    def target_slots(self, *, active, queued, num_slots, min_slots, max_slots):
        demand = active + queued
        if demand > num_slots:
            self._low_streak = 0
            return min(max_slots, demand)
        if num_slots > min_slots and demand <= self.shrink_occupancy * num_slots:
            self._low_streak += 1
            if self._low_streak >= self.hysteresis:
                self._low_streak = 0
                return max(min_slots, demand)
            return num_slots
        self._low_streak = 0
        return num_slots
