"""Slot scheduler for the streaming reservoir engine.

The reservoir analogue of continuous batching (serve/engine.py): a FIFO
admission queue feeds a fixed pool of ensemble-lane slots. Admission and
retirement happen between ticks — the batched integrate never stalls on a
straggler session, and a freed slot is refilled on the very next tick.

Kept deliberately dumb (FIFO + first-free-slot): policies like
shortest-stream-first or tenant fairness plug in by overriding `pick`.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class SchedulerStats:
    submitted: int = 0
    admitted: int = 0
    retired: int = 0
    ticks: int = 0
    # aggregate session-ticks actually served (for throughput accounting)
    session_ticks: int = 0


class SlotScheduler:
    def __init__(self, num_slots: int):
        self.num_slots = num_slots
        self.queue: Deque = deque()
        self.running: Dict[int, object] = {}  # slot -> session
        self.stats = SchedulerStats()

    def submit(self, session) -> None:
        self.queue.append(session)
        self.stats.submitted += 1

    def has_work(self) -> bool:
        return bool(self.queue) or bool(self.running)

    def pick(self) -> Optional[object]:
        """Next session to admit; override for non-FIFO policies."""
        return self.queue.popleft() if self.queue else None

    def admissions(self, free_slots: List[int]) -> List[Tuple[int, object]]:
        """Pair queued sessions with free slots (called between ticks)."""
        placed = []
        for slot in free_slots:
            session = self.pick()
            if session is None:
                break
            self.running[slot] = session
            placed.append((slot, session))
            self.stats.admitted += 1
        return placed

    def retire(self, slot: int) -> object:
        session = self.running.pop(slot)
        self.stats.retired += 1
        return session

    def on_tick(self) -> None:
        self.stats.ticks += 1
        self.stats.session_ticks += len(self.running)
