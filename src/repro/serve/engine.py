"""Continuous-batching serving engine.

vLLM-style slot scheduling on top of the model's prefill/decode steps:
a fixed decode batch of `num_slots` sequences; whenever a sequence
finishes (max tokens here; EOS in a tokenizer world), its slot is refilled
by prefilling the next queued request and SPLICING its KV cache into the
batched cache at that slot — decode never stalls on stragglers in the
batch (the decode_32k dry-run cells lower exactly this step function at
production shape).

Correctness contract (tested): every request's greedy continuation is
bit-identical to running it alone through prefill+decode.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.models import transformer


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray  # (L,) int32
    max_new: int


@dataclasses.dataclass
class _Slot:
    rid: Optional[int] = None
    pos: int = 0  # next write position in the cache
    remaining: int = 0
    out: Optional[List[int]] = None


def _splice_cache(batch_cache, seq_cache, slot: int):
    """Write a single-sequence cache into slot `slot` of the batched cache.

    After pad_caches, src and dst differ ONLY on the batch axis (axis 0 for
    prefix-layer caches, axis 1 for period-stacked caches): src has size 1
    there, dst has num_slots (>= 2, enforced by Engine)."""

    def put(dst, src):
        b_axis = None
        for i in range(dst.ndim):
            if src.shape[i] == 1 and dst.shape[i] != 1:
                b_axis = i
                break
        assert b_axis is not None, (dst.shape, src.shape)
        assert all(
            s == d for i, (s, d) in enumerate(zip(src.shape, dst.shape))
            if i != b_axis
        ), (dst.shape, src.shape)
        start = [0] * dst.ndim
        start[b_axis] = slot
        return jax.lax.dynamic_update_slice(
            dst, src.astype(dst.dtype), tuple(start)
        )

    return jax.tree.map(put, batch_cache, seq_cache)


class Engine:
    def __init__(self, cfg: ModelConfig, params, num_slots: int, capacity: int):
        assert num_slots >= 2, "splice axis detection needs num_slots >= 2"
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.num_slots = num_slots
        self.capacity = capacity
        self.slots = [_Slot() for _ in range(num_slots)]
        self._decode = jax.jit(self.model.decode_step)
        # batched cache template: zeros at full capacity
        spec = self.model.cache_specs(num_slots, capacity)
        self.caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
        self.next_tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self.results: Dict[int, List[int]] = {}

    def _admit(self, req: Request, slot_idx: int):
        """Prefill one request and splice it into `slot_idx`."""
        last, seq_cache = self.model.prefill(
            self.params, {"tokens": req.prompt[None]}
        )
        seq_cache = transformer.pad_caches(self.cfg, seq_cache, self.capacity)
        self.caches = _splice_cache(self.caches, seq_cache, slot_idx)
        tok = int(jnp.argmax(last[0, -1, : self.cfg.vocab_size]))
        s = self.slots[slot_idx]
        s.rid, s.pos = req.rid, int(req.prompt.shape[0])
        s.remaining, s.out = req.max_new - 1, [tok]
        self.next_tokens = self.next_tokens.at[slot_idx, 0].set(tok)
        if s.remaining == 0:
            self._finish(slot_idx)

    def _finish(self, slot_idx: int):
        s = self.slots[slot_idx]
        self.results[s.rid] = s.out
        self.slots[slot_idx] = _Slot()

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        """Serve all requests to completion; returns rid -> generated ids."""
        queue = list(requests)
        while queue or any(s.rid is not None for s in self.slots):
            # admit into free slots
            for i, s in enumerate(self.slots):
                if s.rid is None and queue:
                    self._admit(queue.pop(0), i)
            if not any(s.rid is not None for s in self.slots):
                continue
            # one lock-step decode over all slots (idle slots compute and
            # are ignored — the continuous-batching trade)
            pos = jnp.asarray([s.pos for s in self.slots], jnp.int32)
            logits, self.caches = self._decode(
                self.params, self.next_tokens, self.caches, pos
            )
            toks = jnp.argmax(
                logits[:, -1, : self.cfg.vocab_size], axis=-1
            ).astype(jnp.int32)
            self.next_tokens = toks[:, None]
            for i, s in enumerate(self.slots):
                if s.rid is None:
                    continue
                s.out.append(int(toks[i]))
                s.pos += 1
                s.remaining -= 1
                if s.remaining <= 0:
                    self._finish(i)
        return self.results
