"""Public model API: build_model(cfg) -> Model with init / loss / prefill /
decode plus ShapeDtypeStruct input specs for every shape cell (the dry-run
lowers these — no allocation ever happens for full-size configs).

Modality frontends are STUBS per the assignment: [audio]/[vlm] archs receive
precomputed frame/patch embeddings through input_specs().
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import layers, transformer


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable  # (key) -> params
    loss_fn: Callable  # (params, batch) -> (loss, metrics)
    forward: Callable  # (params, batch) -> logits
    prefill: Callable  # (params, batch) -> (last_logits, caches)
    decode_step: Callable  # (params, tokens, caches, pos) -> (logits, caches)
    input_specs: Callable  # (cell) -> batch pytree of ShapeDtypeStruct
    cache_specs: Callable  # (batch, seq) -> cache pytree of ShapeDtypeStruct


def build_model(cfg: ModelConfig) -> Model:
    def init(key):
        return transformer.init_params(key, cfg)

    def forward(params, batch):
        logits, _, _ = transformer.forward_logits(params, cfg, batch, mode="train")
        return logits

    def loss_fn(params, batch):
        feats, aux, _ = transformer.forward_logits(
            params, cfg, batch, mode="features"
        )
        w = (
            params["embed"]["embed"].T
            if cfg.tie_embeddings
            else params["lm_head"]["kernel"]
        )
        ce = layers.cross_entropy_from_features(
            feats, w, batch["labels"], cfg.vocab_size, batch.get("loss_mask")
        )
        aux_w = cfg.moe.aux_loss_weight if cfg.moe else 0.0
        loss = ce + aux_w * aux
        return loss, {"ce": ce, "aux": aux}

    def prefill(params, batch):
        logits, _, caches = transformer.forward_logits(
            params, cfg, batch, mode="prefill"
        )
        return logits[:, -1:], caches

    def decode_step(params, tokens, caches, pos):
        return transformer.decode_step(params, cfg, tokens, caches, pos)

    def input_specs(cell: ShapeCell, enc_seq: int = 4096) -> Dict[str, Any]:
        return make_input_specs(cfg, cell, enc_seq)

    def cache_specs(batch, seq, enc_seq: int = 4096):
        return transformer.cache_specs(cfg, batch, seq, enc_seq)

    return Model(cfg, init, loss_fn, forward, prefill, decode_step, input_specs, cache_specs)


def make_input_specs(cfg: ModelConfig, cell: ShapeCell, enc_seq: int = 4096):
    """Batch pytree (ShapeDtypeStructs) for one (arch x shape) cell.

    train/prefill carry the full sequence; decode carries one token + cache
    + per-sequence positions. Embedding-mode archs receive stubbed
    (B, S, d_model) frontend outputs instead of tokens.
    """
    b, s = cell.global_batch, cell.seq_len
    dtype = transformer._dtype_of(cfg)
    tok = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)
    emb = lambda *shape: jax.ShapeDtypeStruct(shape, dtype)

    if cell.kind == "train":
        batch: Dict[str, Any] = {}
        if cfg.encoder_layers:
            batch["encoder_frames"] = emb(b, s, cfg.d_model)
            batch["tokens"] = tok(b, s)
        elif cfg.input_mode == "embeddings":
            batch["inputs_embeds"] = emb(b, s, cfg.d_model)
        else:
            batch["tokens"] = tok(b, s)
        batch["labels"] = tok(b, s)
        batch["loss_mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
        return batch

    if cell.kind == "prefill":
        batch = {}
        if cfg.encoder_layers:
            batch["encoder_frames"] = emb(b, min(s, enc_seq), cfg.d_model)
            batch["tokens"] = tok(b, s)
        elif cfg.input_mode == "embeddings":
            batch["inputs_embeds"] = emb(b, s, cfg.d_model)
        else:
            batch["tokens"] = tok(b, s)
        return batch

    if cell.kind == "decode":
        return {
            "tokens": tok(b, 1),
            "caches": transformer.cache_specs(cfg, b, s, enc_seq),
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    raise ValueError(cell.kind)


def concrete_batch(cfg: ModelConfig, cell: ShapeCell, key, enc_seq: int = 256):
    """Materialize a random batch matching input_specs (smoke tests only)."""
    specs = make_input_specs(cfg, cell, enc_seq)

    def mk(spec):
        if spec.dtype == jnp.int32:
            return jax.random.randint(key, spec.shape, 0, max(cfg.vocab_size, 2))
        return 0.02 * jax.random.normal(key, spec.shape, spec.dtype)

    batch = jax.tree.map(mk, specs)
    if "loss_mask" in batch:
        batch["loss_mask"] = jnp.ones_like(batch["loss_mask"])
    if "pos" in batch:
        batch["pos"] = jnp.full(batch["pos"].shape, cell.seq_len - 1, jnp.int32)
    return batch
