from repro.models.model import Model, build_model, make_input_specs, concrete_batch
from repro.models.counting import count_params, train_step_flops, decode_step_flops
