"""Attention mixers: MHA/GQA/MQA, sliding-window, cross-attention, and
DeepSeek-style MLA (latent KV) with the absorbed decode path.

Three execution modes share one math core:
    train    full-sequence self-attention (no cache)
    prefill  full-sequence + returns the KV cache
    decode   one token against a cache of capacity S (positions per sequence)

Layouts: activations (B, S, D); q/k/v (B, S, H, head_dim); caches
(B, S, KVH, head_dim) — batch shards over `data`, heads/head_dim over
`model` (divisibility-aware; see distributed/sharding.py).

The XLA einsum path below is what multi-pod dry-runs lower; kernels/
flash_attention.py is the TPU kernel counterpart (validated in interpret
mode), switchable via use_flash for real-TPU runs.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import apply_rope, dense, make_dense, rope_freqs

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# standard (GQA) attention
# ---------------------------------------------------------------------------


def make_attention(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    bias = cfg.attn_bias or cfg.qkv_bias
    out_scale = (h * hd) ** -0.5 / (2.0 * cfg.num_layers) ** 0.5
    return {
        "wq": make_dense(ks[0], d, h * hd, dtype, bias=bias),
        "wk": make_dense(ks[1], d, kvh * hd, dtype, bias=bias),
        "wv": make_dense(ks[2], d, kvh * hd, dtype, bias=bias),
        "wo": make_dense(ks[3], h * hd, d, dtype, scale=out_scale, bias=cfg.attn_bias),
    }


def _split_heads(x, n_heads, head_dim):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, head_dim)


# Sequences at/above this length use the q-chunked scan path so the (Sq, Sk)
# logits tensor never materializes whole (the XLA analogue of flash
# attention's memory behavior; the Pallas kernel is the TPU-native version).
# Env overrides are the §Perf A/B knobs.
import os as _os

Q_CHUNK_THRESHOLD = int(_os.environ.get("REPRO_ATTN_QCHUNK_THRESHOLD", 8192))
Q_CHUNK = int(_os.environ.get("REPRO_ATTN_QCHUNK", 1024))
# store softmax probabilities in bf16 for the PV matmul (halves the probs
# read traffic; logsumexp/max still f32)
PROBS_BF16 = _os.environ.get("REPRO_ATTN_PROBS_BF16", "0") == "1"


def grouped_attend(
    q: jnp.ndarray,  # (B, Sq, H, D)
    k: jnp.ndarray,  # (B, Sk, KVH, D)
    v: jnp.ndarray,  # (B, Sk, KVH, D)
    *,
    causal: bool,
    window: int = 0,
    q_offset=None,  # (B,) or scalar global position of q[0]; default Sk - Sq
    kv_len=None,  # (B,) or scalar #valid cache entries (decode); default Sk
    softcap: float = 0.0,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    sq = q.shape[1]
    if sq >= Q_CHUNK_THRESHOLD and sq % Q_CHUNK == 0:
        if q_offset is None:
            q_offset = k.shape[1] - sq

        # scan over q chunks; each chunk is a plain grouped attention with
        # its own q_offset
        n = sq // Q_CHUNK
        qs = q.reshape(q.shape[0], n, Q_CHUNK, *q.shape[2:]).swapaxes(0, 1)
        off0 = jnp.asarray(q_offset)
        offs = off0[None, ...] + Q_CHUNK * jnp.arange(n).reshape(
            (n,) + (1,) * off0.ndim
        )

        def body(_, xs):
            qc, off = xs
            out = _grouped_attend_dense(
                qc, k, v, causal=causal, window=window, q_offset=off,
                kv_len=kv_len, softcap=softcap, scale=scale,
            )
            return None, out

        _, outs = jax.lax.scan(body, None, (qs, offs))
        return outs.swapaxes(0, 1).reshape(q.shape)
    return _grouped_attend_dense(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_len=kv_len, softcap=softcap, scale=scale,
    )


def _grouped_attend_dense(
    q, k, v, *, causal, window=0, q_offset=None, kv_len=None,
    softcap=0.0, scale=None,
) -> jnp.ndarray:
    """Grouped-query attention core (einsum path, f32 softmax)."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    g = h // kvh
    if scale is None:
        scale = d**-0.5
    qg = q.reshape(b, sq, kvh, g, d)
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)

    # positions
    if q_offset is None:
        q_offset = sk - sq
    qpos = jnp.asarray(q_offset)[..., None] + jnp.arange(sq)  # (B?, Sq)
    qpos = jnp.broadcast_to(qpos, (b, sq))
    kpos = jnp.arange(sk)[None, :]  # (1, Sk)

    mask = jnp.ones((b, sq, sk), dtype=bool)
    if kv_len is not None:
        kl = jnp.broadcast_to(jnp.asarray(kv_len), (b,))
        mask = mask & (kpos < kl[:, None])[:, None, :]  # (B,1,Sk) over Sq
    if causal:
        mask = mask & (kpos[None] <= qpos[..., None])
    if window > 0:
        mask = mask & (kpos[None] > qpos[..., None] - window)

    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if PROBS_BF16:
        probs = probs.astype(jnp.bfloat16)
        out = jnp.einsum(
            "bhgqk,bkhd->bqhgd", probs, v.astype(jnp.bfloat16),
            preferred_element_type=jnp.float32,
        )
    else:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attn_forward(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, S, D)
    positions: jnp.ndarray,  # (B, S) int32
    *,
    causal: bool = True,
    window: int = 0,
    kv_x: Optional[jnp.ndarray] = None,  # cross-attention source
    return_cache: bool = False,
):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    from repro.distributed.sharding import BATCH, MODEL, constrain

    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    # TP layout: q heads sharded over model (divisibility-checked inside
    # constrain); k/v replicated across model within a head group — the
    # logits einsum then needs no resharding of the (Sq, Sk) tensor.
    q = constrain(_split_heads(dense(p["wq"], x), h, hd), BATCH, None, MODEL, None)
    k = constrain(_split_heads(dense(p["wk"], src), kvh, hd), BATCH, None, None, None)
    v = constrain(_split_heads(dense(p["wv"], src), kvh, hd), BATCH, None, None, None)
    if cfg.pos_type == "rope" and kv_x is None:
        ang = rope_freqs(positions, hd, cfg.rope_theta)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
    out = grouped_attend(
        q, k, v, causal=causal and kv_x is None, window=window,
        q_offset=0, softcap=cfg.attn_logit_softcap,
    )
    y = dense(p["wo"], out.reshape(*x.shape[:-1], h * hd))
    y = constrain(y, BATCH, None, None)
    if return_cache:
        return y, {"k": k, "v": v}
    return y


def attn_decode(
    p,
    cfg: ModelConfig,
    x: jnp.ndarray,  # (B, 1, D) new-token activations
    cache: dict,  # {"k": (B, S, KVH, D), "v": ...}
    pos: jnp.ndarray,  # (B,) index to write; attends to <= pos
    *,
    window: int = 0,
    cross: bool = False,
) -> Tuple[jnp.ndarray, dict]:
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    b = x.shape[0]
    q = _split_heads(dense(p["wq"], x), h, hd)  # (B,1,H,D)
    if cross:
        # cross-attention cache is static (encoder output); no update
        out = grouped_attend(
            q, cache["k"], cache["v"], causal=False,
            softcap=cfg.attn_logit_softcap,
        )
        y = dense(p["wo"], out.reshape(b, 1, h * hd))
        return y, cache

    k_new = _split_heads(dense(p["wk"], x), kvh, hd)
    v_new = _split_heads(dense(p["wv"], x), kvh, hd)
    if cfg.pos_type == "rope":
        ang = rope_freqs(pos[:, None], hd, cfg.rope_theta)  # (B,1,hd/2)
        q = apply_rope(q, ang)
        k_new = apply_rope(k_new, ang)
    from repro.distributed.sharding import BATCH, MODEL, constrain, want_kv_seq_shard

    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, pos].set(k_new[:, 0])
    v_cache = cache["v"].at[bidx, pos].set(v_new[:, 0])
    if want_kv_seq_shard(kvh):
        # flash-decode layout: cache sequence over model axis; attention
        # computes per-shard partial softmax and XLA reduces the (tiny)
        # per-head stats instead of all-gathering the cache (§Perf B)
        k_cache = constrain(k_cache, BATCH, MODEL, None, None)
        v_cache = constrain(v_cache, BATCH, MODEL, None, None)
    out = grouped_attend(
        q, k_cache, v_cache, causal=True, window=window,
        q_offset=pos, kv_len=pos + 1, softcap=cfg.attn_logit_softcap,
    )
    y = dense(p["wo"], out.reshape(b, 1, h * hd))
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def make_mla(key, cfg: ModelConfig, dtype):
    mla = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = (
        mla.kv_lora_rank,
        mla.qk_nope_head_dim,
        mla.qk_rope_head_dim,
        mla.v_head_dim,
    )
    ks = jax.random.split(key, 5)
    out_scale = (h * dv) ** -0.5 / (2.0 * cfg.num_layers) ** 0.5
    return {
        "wq": make_dense(ks[0], d, h * (dn + dr), dtype),
        "wkv_a": make_dense(ks[1], d, r + dr, dtype),  # latent + shared rope key
        "kv_norm": layers.make_norm("rmsnorm", r, dtype),
        "w_uk": (jax.random.normal(ks[2], (r, h, dn)) * r**-0.5).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (r, h, dv)) * r**-0.5).astype(dtype),
        "wo": make_dense(ks[4], h * dv, d, dtype, scale=out_scale),
    }


def _mla_qsplit(p, cfg, x, positions):
    mla = cfg.mla
    h = cfg.num_heads
    dn, dr = mla.qk_nope_head_dim, mla.qk_rope_head_dim
    q = dense(p["wq"], x).reshape(*x.shape[:-1], h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    ang = rope_freqs(positions, dr, cfg.rope_theta)
    q_rope = apply_rope(q_rope, ang)
    return q_nope, q_rope


def mla_forward(p, cfg: ModelConfig, x, positions, *, return_cache=False):
    """Train/prefill MLA: decompress k/v and run standard attention.

    The decoupled-rope logits q_nope.k_nope + q_rope.k_rope are folded into
    one grouped_attend call by concatenating the nope/rope components per
    head — this reuses the q-chunked long-sequence path. v is zero-padded to
    the concat width and sliced back (the extra columns contribute nothing).
    """
    from repro.distributed.sharding import BATCH, MODEL, constrain

    mla = cfg.mla
    b, s, _ = x.shape
    r, dn, dr, dv = (
        mla.kv_lora_rank,
        mla.qk_nope_head_dim,
        mla.qk_rope_head_dim,
        mla.v_head_dim,
    )
    h = cfg.num_heads
    q_nope, q_rope = _mla_qsplit(p, cfg, x, positions)

    kv_a = dense(p["wkv_a"], x)  # (B,S,r+dr)
    c_kv = layers.apply_norm(p["kv_norm"], kv_a[..., :r])
    k_rope = kv_a[..., r:].reshape(b, s, 1, dr)
    k_rope = apply_rope(k_rope, rope_freqs(positions, dr, cfg.rope_theta))[:, :, 0]

    k_nope = constrain(
        jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uk"]), BATCH, None, MODEL, None
    )
    v = constrain(
        jnp.einsum("bsr,rhd->bshd", c_kv, p["w_uv"]), BATCH, None, MODEL, None
    )

    qq = jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,dn+dr)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None], (b, s, h, dr))], axis=-1
    )
    vv = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dn + dr - dv)))
    out = grouped_attend(qq, kk, vv, causal=True, q_offset=0)[..., :dv]
    y = dense(p["wo"], out.reshape(b, s, -1))
    if return_cache:
        return y, {"c_kv": c_kv, "k_rope": k_rope}
    return y


def mla_decode(p, cfg: ModelConfig, x, cache, pos):
    """Absorbed-matrix MLA decode: attend IN LATENT SPACE — the cache holds
    only (r + dr) floats/token (DeepSeek's serving trick), and W_uk/W_uv are
    folded into the query/output instead of decompressing the cache."""
    mla = cfg.mla
    b = x.shape[0]
    r, dn, dr, dv = (
        mla.kv_lora_rank,
        mla.qk_nope_head_dim,
        mla.qk_rope_head_dim,
        mla.v_head_dim,
    )
    q_nope, q_rope = _mla_qsplit(p, cfg, x, pos[:, None])  # (B,1,H,*)

    kv_a = dense(p["wkv_a"], x)  # (B,1,r+dr)
    c_new = layers.apply_norm(p["kv_norm"], kv_a[..., :r])[:, 0]  # (B,r)
    k_rope_new = kv_a[..., r:].reshape(b, 1, 1, dr)
    k_rope_new = apply_rope(k_rope_new, rope_freqs(pos[:, None], dr, cfg.rope_theta))[:, 0, 0]

    from repro.distributed.sharding import BATCH, MODEL, constrain, want_kv_seq_shard

    bidx = jnp.arange(b)
    c_cache = cache["c_kv"].at[bidx, pos].set(c_new)  # (B,S,r)
    r_cache = cache["k_rope"].at[bidx, pos].set(k_rope_new)  # (B,S,dr)
    if want_kv_seq_shard(0):
        # flash-decode layout for the MLA latent cache (§Perf B)
        c_cache = constrain(c_cache, BATCH, MODEL, None)
        r_cache = constrain(r_cache, BATCH, MODEL, None)

    # absorb W_uk into q: (B,1,H,dn) x (r,H,dn) -> (B,H,r)
    q_lat = jnp.einsum("bqhd,rhd->bhr", q_nope.astype(jnp.float32), p["w_uk"].astype(jnp.float32))
    lg = jnp.einsum("bhr,bsr->bhs", q_lat, c_cache.astype(jnp.float32))
    lg += jnp.einsum("bqhd,bsd->bhs", q_rope.astype(jnp.float32), r_cache.astype(jnp.float32))
    lg *= (dn + dr) ** -0.5
    mask = jnp.arange(c_cache.shape[1])[None, :] <= pos[:, None]  # (B,S)
    lg = jnp.where(mask[:, None], lg, NEG_INF)
    pr = jax.nn.softmax(lg, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", pr, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", ctx, p["w_uv"].astype(jnp.float32)).astype(x.dtype)
    y = dense(p["wo"], out.reshape(b, 1, -1))
    return y, {"c_kv": c_cache, "k_rope": r_cache}
