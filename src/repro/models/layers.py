"""Shared model layers: norms, MLPs, RoPE, embeddings.

Params are plain nested dicts of jnp arrays (no framework dependency);
weight-name conventions are what distributed/sharding.py pattern-matches:

    kernel shapes: (in, out) for projections, (vocab, d) for embeddings,
    (experts, in, out) for MoE. Names: w_in/w_gate/w_out (mlp), wq/wk/wv/wo
    (attention), embed, lm_head.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def make_dense(key, d_in, d_out, dtype, scale: Optional[float] = None, bias=False):
    if scale is None:
        scale = d_in**-0.5
    p = {"kernel": (scale * jax.random.normal(key, (d_in, d_out))).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["kernel"]
    if "bias" in p:
        y = y + p["bias"]
    return y


# --- norms -----------------------------------------------------------------


def make_norm(norm_type: str, d: int, dtype):
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    elif norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    raise ValueError(norm_type)


def apply_norm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --- MLPs ------------------------------------------------------------------


def make_mlp(key, d_model, d_ff, mlp_type, dtype, bias=False, out_scale=None):
    ks = jax.random.split(key, 3)
    p = {}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = make_dense(ks[0], d_model, d_ff, dtype, bias=bias)
        p["w_in"] = make_dense(ks[1], d_model, d_ff, dtype, bias=bias)
    else:  # gelu
        p["w_in"] = make_dense(ks[1], d_model, d_ff, dtype, bias=bias)
    p["w_out"] = make_dense(ks[2], d_ff, d_model, dtype, scale=out_scale, bias=bias)
    return p


def apply_mlp(p, x, mlp_type):
    if mlp_type == "swiglu":
        h = jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_in"], x)
    elif mlp_type == "geglu":
        h = jax.nn.gelu(dense(p["w_gate"], x), approximate=True) * dense(p["w_in"], x)
    elif mlp_type == "gelu":
        h = jax.nn.gelu(dense(p["w_in"], x), approximate=True)
    else:
        raise ValueError(mlp_type)
    return dense(p["w_out"], h)


# --- rotary embeddings -------------------------------------------------------


def rope_freqs(positions: jnp.ndarray, dim: int, theta: float) -> jnp.ndarray:
    """(..., S) int positions -> (..., S, dim/2) angles."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); angles: (..., S, D/2). Interleaved-pair rotation
    done in float32 (numerics) and cast back."""
    d = x.shape[-1]
    x1 = x[..., : d // 2].astype(jnp.float32)
    x2 = x[..., d // 2 :].astype(jnp.float32)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- positional embeddings ----------------------------------------------------


def sinusoidal_positions(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angle))
    pe = pe.at[:, 1::2].set(jnp.cos(angle))
    return pe


# --- embeddings ----------------------------------------------------------------


def make_embedding(key, vocab_padded: int, d: int, dtype):
    return {"embed": (0.02 * jax.random.normal(key, (vocab_padded, d))).astype(dtype)}


def embed_tokens(p, tokens: jnp.ndarray, scale: bool = False):
    x = jnp.take(p["embed"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), x.dtype)
    return x


def lm_logits(p_head, x, tied_embed=None, softcap: float = 0.0):
    """Project to (padded) vocab logits in f32, vocab-sharded over the model
    axis (the CE logsumexp then reduces locally + one scalar all-reduce,
    and no device ever holds a full (B, S, V) tensor)."""
    from repro.distributed.sharding import BATCH, MODEL, constrain

    w = tied_embed["embed"].T if tied_embed is not None else p_head["kernel"]
    logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32), w.astype(jnp.float32))
    logits = constrain(logits, BATCH, *([None] * (logits.ndim - 2)), MODEL)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy_from_features(
    x, w, labels, vocab_size: int, mask=None, chunk: int = 1024
):
    """Sequence-chunked CE: logits for `chunk` positions at a time (memory
    O(B*chunk*V/model_axis) instead of O(B*S*V)). w: (d, V_pad)."""
    b, s, d = x.shape
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk
    from repro.distributed.sharding import BATCH, MODEL, constrain

    def ce_sum(xc, lc, mc):
        logits = jnp.einsum(
            "bsd,dv->bsv", xc.astype(jnp.float32), w.astype(jnp.float32)
        )
        logits = constrain(logits, BATCH, None, MODEL)
        vpad = logits.shape[-1]
        if vpad > vocab_size:
            logits = logits.at[..., vocab_size:].set(-1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        mc = mc.astype(logz.dtype)
        return jnp.sum((logz - gold) * mc), jnp.sum(mc)

    def body(carry, xs):
        xc, lc, mc = xs
        ls, ms = ce_sum(xc, lc, mc)
        return (carry[0] + ls, carry[1] + ms), None

    resh = lambda a: a[:, : n * chunk].reshape(b, n, chunk, *a.shape[2:]).swapaxes(0, 1)
    (loss_sum, m_sum), _ = jax.lax.scan(
        body, (jnp.zeros(()), jnp.zeros(())), (resh(x), resh(labels), resh(mask))
    )
    if rem:
        ls, ms = ce_sum(x[:, n * chunk :], labels[:, n * chunk :], mask[:, n * chunk :])
        loss_sum, m_sum = loss_sum + ls, m_sum + ms
    return loss_sum / jnp.maximum(m_sum, 1.0)


def cross_entropy_loss(logits, labels, vocab_size: int, mask=None):
    """Mean CE over valid tokens; padded-vocab columns are excluded by
    masking them to -inf before the softmax."""
    v_pad = logits.shape[-1]
    if v_pad > vocab_size:
        neg = jnp.full((v_pad - vocab_size,), -1e30, logits.dtype)
        logits = logits.at[..., vocab_size:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
