"""Mixture-of-Experts channel mixer: shared + routed top-k experts.

Dispatch is token-chunked capacity-based (MaxText-style einsum dispatch,
but over chunks of `router_chunk` tokens so the one-hot dispatch tensor is
(chunk, E, C) instead of (B*S, E, C) — this is what keeps the 32k-seq MoE
cells memory-sane). Tokens beyond an expert's per-chunk capacity are
dropped (contribute zero), standard for capacity-based routing; the
auxiliary load-balance loss pushes the router away from that regime.

Expert weights are (E, d_in, d_out) so expert-parallel sharding is a leading
-dim PartitionSpec; when E % mesh_model != 0 the sharder falls back to the
d_ff dimension (distributed/sharding.py).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers


def make_moe(key, cfg: ModelConfig, dtype):
    moe = cfg.moe
    d, f, e = cfg.d_model, moe.d_ff_expert, moe.num_experts
    ks = jax.random.split(key, 5)
    scale_in = d**-0.5
    scale_out = f**-0.5 / (2.0 * cfg.num_layers) ** 0.5
    p = {
        "router": layers.make_dense(ks[0], d, e, jnp.float32),  # router in f32
        "w_gate": (scale_in * jax.random.normal(ks[1], (e, d, f))).astype(dtype),
        "w_in": (scale_in * jax.random.normal(ks[2], (e, d, f))).astype(dtype),
        "w_out": (scale_out * jax.random.normal(ks[3], (e, f, d))).astype(dtype),
    }
    if moe.num_shared:
        p["shared"] = layers.make_mlp(
            ks[4], d, f * moe.num_shared, "swiglu", dtype, out_scale=scale_out
        )
    return p


def _route_chunk(p, moe, x):  # x: (T, D)
    """Top-k routing + capacity dispatch for one token chunk."""
    t, d = x.shape
    e, k = moe.num_experts, moe.top_k
    cap = max(1, int(math.ceil(t * k / e * moe.capacity_factor)))

    logits = x.astype(jnp.float32) @ p["router"]["kernel"] + p["router"].get(
        "bias", 0.0
    )  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # position of each (token, k) within its expert, chunk-local
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.int32)  # (T, K, E)
    flat = onehot.reshape(t * k, e)
    pos_in_e = jnp.cumsum(flat, axis=0) - flat  # exclusive (T*K, E)
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(t, k)  # (T, K)
    keep = pos < cap

    # dispatch tensor (T, E, C): one-hot over expert and capacity slot
    disp = (
        jax.nn.one_hot(top_e, e, dtype=x.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1, dtype=x.dtype)[..., None, :]
    )  # (T, K, E, C+1)
    disp = jnp.sum(disp[..., :cap], axis=1)  # (T, E, C)

    # combine weights: router prob scattered onto the same (expert, slot)
    comb = disp * jnp.einsum(
        "tk,tke->te", top_p.astype(x.dtype), onehot.astype(x.dtype)
    )[..., None]

    # expert compute: gather (E, C, D), swiglu per expert, scatter back
    xe = jnp.einsum("tec,td->ecd", disp, x)  # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_in"]
    )
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])  # (E, C, D)
    y = jnp.einsum("tec,ecd->td", comb, ye)  # (T, D)

    # switch-style aux loss: E * sum_e f_e * P_e
    frac = jnp.mean(jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=0)
    prob = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * prob)
    return y, aux


def apply_moe(p, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y, aux_loss). Token dim is chunk-scanned."""
    moe = cfg.moe
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    chunk = min(moe.router_chunk, b * s)
    n = flat.shape[0] // chunk
    rem = flat.shape[0] - n * chunk

    def body(carry, xc):
        y, aux = _route_chunk(p, moe, xc)
        return carry + aux, y

    aux_total, ys = jax.lax.scan(
        body, jnp.zeros((), jnp.float32), flat[: n * chunk].reshape(n, chunk, d)
    )
    y = ys.reshape(n * chunk, d)
    if rem:
        y_rem, aux_rem = _route_chunk(p, moe, flat[n * chunk :])
        y = jnp.concatenate([y, y_rem], axis=0)
        aux_total = aux_total + aux_rem
        n += 1
    y = y.reshape(b, s, d)

    if moe.num_shared:
        y = y + layers.apply_mlp(p["shared"], x, "swiglu")
    return y, aux_total / jnp.asarray(max(n, 1), jnp.float32)
