"""Model assembly: heterogeneous layer stacks compiled as scan-over-periods.

A config's layer plan is `prefix` (unstacked) + `period` x num_periods.
Period parameters are STACKED (leading axis = num_periods) and executed with
lax.scan — one period body in the HLO regardless of depth, which is what
keeps 72-layer/512-device dry-run compiles tractable and is also the right
shape for real fleets. jax.checkpoint (remat) wraps the period body.

Caches for decode are pytrees mirroring the stacks (leading num_periods axis
on stacked layers), threaded through the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention, layers, mamba, moe, xlstm


# ---------------------------------------------------------------------------
# per-layer init / forward / decode
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype, cross: bool):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    if spec.mixer in ("attn", "swa"):
        p["mixer_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        p["mixer"] = attention.make_attention(ks[0], cfg, dtype)
    elif spec.mixer == "mla":
        p["mixer_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        p["mixer"] = attention.make_mla(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mixer_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        p["mixer"] = mamba.make_mamba(ks[0], cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mixer"] = xlstm.make_mlstm(ks[0], cfg, dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xlstm.make_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)

    if cross:
        p["cross_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        p["cross"] = attention.make_attention(ks[2], cfg, dtype)

    if spec.mlp == "mlp":
        p["mlp_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        out_scale = cfg.d_ff**-0.5 / (2.0 * cfg.num_layers) ** 0.5
        p["mlp"] = layers.make_mlp(
            ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype,
            bias=cfg.attn_bias, out_scale=out_scale,
        )
    elif spec.mlp == "moe":
        p["mlp_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)
        p["mlp"] = moe.make_moe(ks[1], cfg, dtype)
    return p


def _layer_forward(
    p,
    cfg: ModelConfig,
    spec: LayerSpec,
    x,
    positions,
    *,
    mode: str,  # "train" | "prefill"
    causal: bool = True,
    enc_out=None,
):
    """Full-sequence layer. Returns (x, aux, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    want_cache = mode == "prefill"

    if spec.mixer in ("attn", "swa", "mla"):
        window = cfg.sliding_window if spec.mixer == "swa" else 0
        xn = layers.apply_norm(p["mixer_norm"], x)
        if spec.mixer == "mla":
            out = attention.mla_forward(
                p["mixer"], cfg, xn, positions, return_cache=want_cache
            )
        else:
            out = attention.attn_forward(
                p["mixer"], cfg, xn, positions,
                causal=causal, window=window, return_cache=want_cache,
            )
        if want_cache:
            y_attn, cache["self"] = out
        else:
            y_attn = out
        if cfg.parallel_block and spec.mlp != "none":
            y_mlp = layers.apply_mlp(p["mlp"], xn, cfg.mlp_type)
            x = x + y_attn + y_mlp
            return x, aux, (cache or None)
        x = x + y_attn
    elif spec.mixer == "mamba":
        xn = layers.apply_norm(p["mixer_norm"], x)
        out = mamba.mamba_forward(p["mixer"], cfg, xn, return_cache=want_cache)
        if want_cache:
            y, cache["self"] = out
        else:
            y = out
        x = x + y
    elif spec.mixer == "mlstm":
        out = xlstm.mlstm_forward(p["mixer"], cfg, x, return_cache=want_cache)
        x, c = out if want_cache else (out, None)
        if want_cache:
            cache["self"] = c
    elif spec.mixer == "slstm":
        out = xlstm.slstm_forward(p["mixer"], cfg, x, return_cache=want_cache)
        x, c = out if want_cache else (out, None)
        if want_cache:
            cache["self"] = c

    if enc_out is not None and "cross" in p:
        xn = layers.apply_norm(p["cross_norm"], x)
        if want_cache:
            # cache cross K/V once (static across decode steps)
            h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
            k = attention.dense(p["cross"]["wk"], enc_out)
            v = attention.dense(p["cross"]["wv"], enc_out)
            b, se, _ = enc_out.shape
            cache["cross"] = {
                "k": k.reshape(b, se, kvh, hd),
                "v": v.reshape(b, se, kvh, hd),
            }
        y = attention.attn_forward(
            p["cross"], cfg, xn, positions, causal=False, kv_x=enc_out
        )
        x = x + y

    if spec.mlp in ("mlp", "moe"):
        xn = layers.apply_norm(p["mlp_norm"], x)
        if spec.mlp == "mlp":
            y = layers.apply_mlp(p["mlp"], xn, cfg.mlp_type)
        else:
            y, aux = moe.apply_moe(p["mlp"], cfg, xn)
        x = x + y
    return x, aux, (cache or None)


def _layer_decode(p, cfg: ModelConfig, spec: LayerSpec, x, cache, pos):
    """One-token layer step. Returns (x, new_cache)."""
    new_cache = dict(cache) if cache else {}
    if spec.mixer in ("attn", "swa"):
        window = cfg.sliding_window if spec.mixer == "swa" else 0
        xn = layers.apply_norm(p["mixer_norm"], x)
        y, new_cache["self"] = attention.attn_decode(
            p["mixer"], cfg, xn, cache["self"], pos, window=window
        )
        if cfg.parallel_block and "mlp" in p:
            y_mlp = layers.apply_mlp(p["mlp"], xn, cfg.mlp_type)
            x = x + y + y_mlp
            return x, new_cache
        x = x + y
    elif spec.mixer == "mla":
        xn = layers.apply_norm(p["mixer_norm"], x)
        y, new_cache["self"] = attention.mla_decode(
            p["mixer"], cfg, xn, cache["self"], pos
        )
        x = x + y
    elif spec.mixer == "mamba":
        xn = layers.apply_norm(p["mixer_norm"], x)
        y, new_cache["self"] = mamba.mamba_decode(p["mixer"], cfg, xn, cache["self"])
        x = x + y
    elif spec.mixer == "mlstm":
        x, new_cache["self"] = xlstm.mlstm_decode(p["mixer"], cfg, x, cache["self"])
    elif spec.mixer == "slstm":
        x, new_cache["self"] = xlstm.slstm_decode(p["mixer"], cfg, x, cache["self"])

    if "cross" in (cache or {}):
        xn = layers.apply_norm(p["cross_norm"], x)
        y, _ = attention.attn_decode(
            p["cross"], cfg, xn, cache["cross"], pos, cross=True
        )
        x = x + y
        new_cache["cross"] = cache["cross"]

    if "mlp" in p and not (cfg.parallel_block and spec.mixer in ("attn", "swa")):
        xn = layers.apply_norm(p["mlp_norm"], x)
        if isinstance(p["mlp"], dict) and "router" in p["mlp"]:
            y, _ = moe.apply_moe(p["mlp"], cfg, xn)
        else:
            y = layers.apply_mlp(p["mlp"], xn, cfg.mlp_type)
        x = x + y
    return x, new_cache


def _layer_cache_spec(cfg: ModelConfig, spec: LayerSpec, batch, seq, dtype, cross):
    out = {}
    if spec.mixer in ("attn", "swa"):
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        sd = jax.ShapeDtypeStruct((batch, seq, kvh, hd), dtype)
        out["self"] = {"k": sd, "v": sd}
    elif spec.mixer == "mla":
        mla = cfg.mla
        out["self"] = {
            "c_kv": jax.ShapeDtypeStruct((batch, seq, mla.kv_lora_rank), dtype),
            "k_rope": jax.ShapeDtypeStruct((batch, seq, mla.qk_rope_head_dim), dtype),
        }
    elif spec.mixer == "mamba":
        out["self"] = mamba.mamba_cache_spec(cfg, batch, dtype)
    elif spec.mixer == "mlstm":
        out["self"] = xlstm.mlstm_cache_spec(cfg, batch, dtype)
    elif spec.mixer == "slstm":
        out["self"] = xlstm.slstm_cache_spec(cfg, batch, dtype)
    if cross:
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        sd = jax.ShapeDtypeStruct((batch, seq, kvh, hd), dtype)
        out["cross"] = {"k": sd, "v": sd}
    return out


# ---------------------------------------------------------------------------
# whole-model init / apply
# ---------------------------------------------------------------------------


def _dtype_of(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


def init_params(key, cfg: ModelConfig):
    dtype = _dtype_of(cfg)
    keys = jax.random.split(key, 8)
    cross = cfg.encoder_layers > 0
    p: Dict[str, Any] = {}
    p["embed"] = layers.make_embedding(keys[0], cfg.padded_vocab, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = layers.make_dense(
            keys[1], cfg.d_model, cfg.padded_vocab, dtype, scale=cfg.d_model**-0.5
        )
    p["final_norm"] = layers.make_norm(cfg.norm_type, cfg.d_model, dtype)

    # prefix layers (unstacked)
    if cfg.prefix:
        pk = jax.random.split(keys[2], len(cfg.prefix))
        p["prefix"] = [
            _init_layer(pk[i], cfg, spec, dtype, cross)
            for i, spec in enumerate(cfg.prefix)
        ]

    # period stack: vmapped init over periods
    if cfg.num_periods > 0:
        period_keys = jax.random.split(keys[3], cfg.num_periods)

        def init_period(k):
            sk = jax.random.split(k, len(cfg.period))
            return [
                _init_layer(sk[i], cfg, spec, dtype, cross)
                for i, spec in enumerate(cfg.period)
            ]

        p["stack"] = jax.vmap(init_period)(period_keys)

    # encoder (whisper)
    if cross:
        ek = jax.random.split(keys[4], cfg.encoder_layers + 1)
        enc_spec = LayerSpec("attn", "mlp")
        p["encoder"] = {
            "layers": [
                _init_layer(ek[i], cfg, enc_spec, dtype, False)
                for i in range(cfg.encoder_layers)
            ],
            "final_norm": layers.make_norm(cfg.norm_type, cfg.d_model, dtype),
        }
        # decoder learned positions (whisper style)
        p["dec_pos"] = (
            0.02 * jax.random.normal(keys[5], (cfg.max_position_embeddings, cfg.d_model))
        ).astype(dtype)
    return p


def _embed_inputs(p, cfg: ModelConfig, batch):
    """Returns (x, positions). batch carries either tokens or inputs_embeds."""
    if "inputs_embeds" in batch:
        x = batch["inputs_embeds"]
        b, s, _ = x.shape
    else:
        tokens = batch["tokens"]
        x = layers.embed_tokens(p["embed"], tokens, scale=cfg.embed_scale)
        b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.pos_type == "sinusoidal":
        x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
    return x, positions


def encode(p, cfg: ModelConfig, frames):
    """Whisper encoder over stubbed frame embeddings (B, S, D)."""
    x = frames + layers.sinusoidal_positions(frames.shape[1], cfg.d_model).astype(
        frames.dtype
    )
    positions = jnp.broadcast_to(
        jnp.arange(frames.shape[1], dtype=jnp.int32), frames.shape[:2]
    )
    spec = LayerSpec("attn", "mlp")
    for lp in p["encoder"]["layers"]:
        x, _, _ = _layer_forward(lp, cfg, spec, x, positions, mode="train", causal=False)
    return layers.apply_norm(p["encoder"]["final_norm"], x)


def _run_stack(p, cfg: ModelConfig, x, positions, mode, enc_out=None):
    """prefix layers + scanned periods. Returns (x, aux, caches)."""
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {}

    if cfg.prefix:
        pc = []
        for lp, spec in zip(p["prefix"], cfg.prefix):
            x, aux, c = _layer_forward(
                lp, cfg, spec, x, positions, mode=mode, enc_out=enc_out
            )
            aux_total += aux
            pc.append(c)
        if mode == "prefill":
            caches["prefix"] = pc

    if cfg.num_periods > 0:
        import os

        from repro.distributed.sharding import BATCH, MODEL, constrain

        # §Perf knob: sequence parallelism — activations between blocks are
        # sharded over the model axis along seq, so norms/residuals run on
        # 1/|model| of the tokens and the Megatron all-reduce pair becomes
        # reduce-scatter + all-gather (half the wire bytes).
        seq_par = os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1"

        def period_body(carry, lp):
            x, aux = carry
            if seq_par and x.shape[1] > 1:
                x = constrain(x, BATCH, MODEL, None)
            else:
                x = constrain(x, BATCH, None, None)
            cs = []
            for i, spec in enumerate(cfg.period):
                x, a, c = _layer_forward(
                    lp[i], cfg, spec, x, positions, mode=mode, enc_out=enc_out
                )
                aux = aux + a
                cs.append(c)
            return (x, aux), (cs if mode == "prefill" else None)

        body = period_body
        if cfg.remat:
            import os

            # §Perf A/B knob: "dots" saves matmul outputs (no recompute of
            # the MXU work in the backward pass, more residency); default
            # saves only the carry (recompute everything).
            policy = None
            if os.environ.get("REPRO_REMAT_POLICY", "") == "dots":
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(period_body, prevent_cse=False, policy=policy)
        (x, aux_total), stack_caches = jax.lax.scan(
            body, (x, aux_total), p["stack"], unroll=cfg.scan_unroll
        )
        if mode == "prefill":
            caches["stack"] = stack_caches
    return x, aux_total, caches


def forward_logits(p, cfg: ModelConfig, batch, mode="train"):
    """Full-sequence forward to (padded-vocab) logits. Returns
    (logits, aux, caches)."""
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(p, cfg, batch["encoder_frames"])
        tokens = batch["tokens"]
        x = layers.embed_tokens(p["embed"], tokens, scale=cfg.embed_scale)
        b, s = tokens.shape
        x = x + p["dec_pos"][:s][None].astype(x.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    else:
        x, positions = _embed_inputs(p, cfg, batch)
    x, aux, caches = _run_stack(
        p, cfg, x, positions, "train" if mode == "features" else mode,
        enc_out=enc_out,
    )
    x = layers.apply_norm(p["final_norm"], x)
    if mode == "features":
        return x, aux, caches
    if mode == "prefill":
        # only the last position's logits are needed — never materialize the
        # (B, S, vocab) tensor for a 32k prefill
        x = x[:, -1:]
    logits = layers.lm_logits(
        p.get("lm_head"), x,
        tied_embed=p["embed"] if cfg.tie_embeddings else None,
        softcap=0.0,
    )
    return logits, aux, caches


def decode_step(p, cfg: ModelConfig, tokens, caches, pos):
    """One decode step. tokens: (B, 1) int32; pos: (B,) write position."""
    x = layers.embed_tokens(p["embed"], tokens, scale=cfg.embed_scale)
    if cfg.encoder_layers:
        x = x + jnp.take(p["dec_pos"], pos, axis=0)[:, None].astype(x.dtype)

    new_caches = dict(caches)
    if cfg.prefix:
        pc = []
        for lp, spec, c in zip(p["prefix"], cfg.prefix, caches["prefix"]):
            x, c2 = _layer_decode(lp, cfg, spec, x, c, pos)
            pc.append(c2)
        new_caches["prefix"] = pc

    if cfg.num_periods > 0:

        def period_body(x, scan_in):
            lp, cache_p = scan_in
            c_new = []
            for i, spec in enumerate(cfg.period):
                x, c2 = _layer_decode(lp[i], cfg, spec, x, cache_p[i], pos)
                c_new.append(c2)
            return x, c_new

        x, stack_caches = jax.lax.scan(
            period_body, x, (p["stack"], caches["stack"])
        )
        new_caches["stack"] = stack_caches

    x = layers.apply_norm(p["final_norm"], x)
    logits = layers.lm_logits(
        p.get("lm_head"), x,
        tied_embed=p["embed"] if cfg.tie_embeddings else None,
    )
    return logits, new_caches


_SEQ_CACHE_KEYS = ("k", "v", "c_kv", "k_rope")


def pad_caches(cfg: ModelConfig, caches, capacity: int):
    """Grow prefill caches (seq axis) to `capacity` so decode can append.

    Only sequence-indexed caches (attention KV, MLA latents) are padded;
    recurrent states (mamba/xlstm) are O(1) and pass through. Self caches in
    the period stack carry a leading num_periods axis (seq axis = 2)."""

    def pad_layer(c, stacked):
        if c is None:
            return None
        out = {}
        for part, sub in c.items():
            if part == "cross" or sub is None:
                out[part] = sub
                continue
            o = {}
            for k, v in sub.items():
                if k in _SEQ_CACHE_KEYS:
                    axis = 2 if stacked else 1
                    pad = [(0, 0)] * v.ndim
                    pad[axis] = (0, capacity - v.shape[axis])
                    o[k] = jnp.pad(v, pad)
                else:
                    o[k] = v
            out[part] = o
        return out

    out = {}
    if "prefix" in caches:
        out["prefix"] = [pad_layer(c, stacked=False) for c in caches["prefix"]]
    if "stack" in caches:
        out["stack"] = [pad_layer(c, stacked=True) for c in caches["stack"]]
    return out


def cache_specs(cfg: ModelConfig, batch: int, seq: int, enc_seq: int = 4096):
    """ShapeDtypeStruct pytree for a decode cache of capacity `seq`.

    enc_seq sizes the (static) cross-attention cache for enc-dec archs."""
    dtype = _dtype_of(cfg)
    cross = cfg.encoder_layers > 0

    def spec_for(layer_spec):
        s = _layer_cache_spec(cfg, layer_spec, batch, seq, dtype, cross=False)
        if cross:
            kvh, hd = cfg.num_kv_heads, cfg.head_dim
            sd = jax.ShapeDtypeStruct((batch, enc_seq, kvh, hd), dtype)
            s["cross"] = {"k": sd, "v": sd}
        return s

    out: Dict[str, Any] = {}
    if cfg.prefix:
        out["prefix"] = [spec_for(spec) for spec in cfg.prefix]
    if cfg.num_periods > 0:
        per = [spec_for(spec) for spec in cfg.period]
        out["stack"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((cfg.num_periods,) + s.shape, s.dtype), per
        )
    return out
