"""Mamba-1 selective SSM mixer (jamba's sequence mixer).

Train/prefill: chunked associative scan over time — the outer lax.scan
carries the (B, d_inner, d_state) SSM state across chunks, the inner
jax.lax.associative_scan parallelizes within a chunk; `chunk` bounds the
materialized (B, chunk, d_inner, d_state) discretized tensors (the classic
Mamba memory blow-up knob).

Decode: O(1) recurrent step carrying {ssm state h, conv tail}.

Connection to the paper (DESIGN.md §Arch-applicability): this is exactly an
explicitly-stepped state evolution — the decode path is driven by the same
scan machinery as the reservoir integrator.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import dense, make_dense


def _dims(cfg: ModelConfig):
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)  # ceil(d/16)
    return mc, d_inner, dt_rank


def make_mamba(key, cfg: ModelConfig, dtype):
    mc, di, dtr = _dims(cfg)
    ds = mc.d_state
    ks = jax.random.split(key, 8)
    out_scale = di**-0.5 / (2.0 * cfg.num_layers) ** 0.5
    # S4-style A init: A_log = log(1..d_state) broadcast over channels
    a_init = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
    return {
        "in_proj": make_dense(ks[0], cfg.d_model, 2 * di, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (mc.d_conv, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": make_dense(ks[2], di, dtr + 2 * ds, dtype),
        "dt_proj": {
            "kernel": (dtr**-0.5 * jax.random.normal(ks[3], (dtr, di))).astype(dtype),
            "bias": jnp.log(
                jnp.exp(
                    jnp.clip(
                        jnp.exp(
                            jax.random.uniform(ks[4], (di,))
                            * (jnp.log(0.1) - jnp.log(0.001))
                            + jnp.log(0.001)
                        ),
                        min=1e-4,
                    )
                )
                - 1.0
                + 1e-9
            ).astype(dtype),  # inverse-softplus of dt_init
        },
        "a_log": jnp.broadcast_to(a_init, (di, ds)).astype(jnp.float32),
        "d_skip": jnp.ones((di,), dtype),
        # jamba normalizes dt/B/C
        "dt_norm": layers.make_norm("rmsnorm", dtr, dtype),
        "b_norm": layers.make_norm("rmsnorm", ds, dtype),
        "c_norm": layers.make_norm("rmsnorm", ds, dtype),
        "out_proj": make_dense(ks[5], di, cfg.d_model, dtype, scale=out_scale),
    }


def _conv_causal(w, b, x, tail=None):
    """Depthwise causal conv along S. x: (B, S, di); w: (K, di).

    tail: (B, K-1, di) previous inputs for decode continuity (None = zeros).
    Returns (y, new_tail)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail, x], axis=1)  # (B, S+K-1, di)
    y = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(k)) + b
    new_tail = xp[:, -(k - 1) :] if k > 1 else tail
    return y, new_tail


def _ssm_inputs(p, cfg, xc):
    """Shared discretization: xc (B,S,di) -> (dA, dBx, Cc).

    Only ever called on short windows (decode: S=1; train: one chunk at a
    time) so the (B, S, di, ds) tensors stay chunk-sized.
    """
    mc, di, dtr = _dims(cfg)
    ds = mc.d_state
    xdb = dense(p["x_proj"], xc)  # (B,S,dtr+2ds)
    dt = layers.apply_norm(p["dt_norm"], xdb[..., :dtr])
    bc = layers.apply_norm(p["b_norm"], xdb[..., dtr : dtr + ds])
    cc = layers.apply_norm(p["c_norm"], xdb[..., dtr + ds :])
    dt = jax.nn.softplus(dense(p["dt_proj"], dt).astype(jnp.float32))  # (B,S,di)
    a = -jnp.exp(p["a_log"])  # (di, ds)
    da = jnp.exp(dt[..., None] * a)  # (B,S,di,ds)
    dbx = (dt * xc.astype(jnp.float32))[..., None] * bc.astype(jnp.float32)[
        ..., None, :
    ]  # (B,S,di,ds)
    return da, dbx, cc.astype(jnp.float32)


def mamba_forward(p, cfg: ModelConfig, x, *, return_cache=False):
    """x: (B,S,D) -> (B,S,D) (+ decode cache {h, conv_tail}).

    Discretization, the associative scan, and the C-projection all live
    INSIDE the chunk scan so nothing of shape (B, S, di, ds) ever
    materializes — peak extra memory is (B, chunk, di, ds). d_inner
    activations are sharded over the model axis.
    """
    from repro.distributed.sharding import BATCH, MODEL, constrain

    mc, di, _ = _dims(cfg)
    ds = mc.d_state
    b, s, _ = x.shape
    xz = dense(p["in_proj"], x)
    x1 = constrain(xz[..., :di], BATCH, None, MODEL)
    z = constrain(xz[..., di:], BATCH, None, MODEL)
    xc, tail = _conv_causal(p["conv_w"], p["conv_b"], x1)
    xc = jax.nn.silu(xc)

    chunk = min(mc.chunk, s)
    s_pad = -(-s // chunk) * chunk
    xc_p = jnp.pad(xc, ((0, 0), (0, s_pad - s), (0, 0))) if s_pad != s else xc
    nch = s_pad // chunk
    h0 = jnp.zeros((b, di, ds), jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    valid = (jnp.arange(s_pad) < s).reshape(nch, chunk)

    def body(h, xs):  # xc_c: (B, chunk, di); val: (chunk,)
        xc_c, val = xs
        da, dbx, cc = _ssm_inputs(p, cfg, xc_c)
        # padded steps are identity transitions: h_t = 1*h + 0
        vm = val[None, :, None, None]
        da = jnp.where(vm, da, 1.0)
        dbx = jnp.where(vm, dbx, 0.0)
        ca, cb = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        h_all = ca * h[:, None] + cb  # (B, chunk, di, ds)
        y_c = jnp.einsum("bsdn,bsn->bsd", h_all, cc)  # (B, chunk, di)
        return h_all[:, -1], y_c

    hT, ys = jax.lax.scan(
        body, h0, (xc_p.reshape(b, nch, chunk, di).swapaxes(0, 1), valid)
    )
    y = ys.swapaxes(0, 1).reshape(b, s_pad, di)[:, :s]
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = dense(p["out_proj"], y)
    if return_cache:
        return out, {"h": hT, "conv_tail": tail}
    return out


def mamba_decode(p, cfg: ModelConfig, x, cache) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrent step. x: (B,1,D)."""
    mc, di, _ = _dims(cfg)
    xz = dense(p["in_proj"], x)
    x1, z = xz[..., :di], xz[..., di:]
    xc, tail = _conv_causal(p["conv_w"], p["conv_b"], x1, cache["conv_tail"])
    xc = jax.nn.silu(xc)
    da, dbx, cc = _ssm_inputs(p, cfg, xc)  # (B,1,di,ds)
    h = da[:, 0] * cache["h"] + dbx[:, 0]  # (B,di,ds)
    y = jnp.einsum("bdn,bn->bd", h, cc[:, 0])[:, None]  # (B,1,di)
    y = y + p["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return dense(p["out_proj"], y), {"h": h, "conv_tail": tail}


def mamba_cache_spec(cfg: ModelConfig, batch: int, dtype):
    mc, di, _ = _dims(cfg)
    return {
        "h": jax.ShapeDtypeStruct((batch, di, mc.d_state), jnp.float32),
        "conv_tail": jax.ShapeDtypeStruct((batch, mc.d_conv - 1, di), dtype),
    }
