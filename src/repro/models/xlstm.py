"""xLSTM blocks: mLSTM (matrix memory, attention-like parallel train form,
O(1) recurrent decode) and sLSTM (scalar memory with recurrent gating,
sequential scan) — following arXiv:2405.04517's stabilized exponential
gating.

Both blocks carry their own projections (the xLSTM "block" includes the
up/down projection sandwich), so the transformer assembly uses mlp="none".

DESIGN.md §Arch-applicability: these recurrences are explicit state-stepping
— the same execution pattern as the paper's reservoir: the decode path is a
compiled scan over an explicitly-stepped state, which is why xlstm-125m is
the closest relative of the STO engine among the assigned archs.
"""

from __future__ import annotations

import functools

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import dense, make_dense

NEG_INF = -1e30


def _heads(cfg):
    h = cfg.num_heads
    return h, cfg.d_model // h  # mLSTM head dim over d_inner handled below


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def make_mlstm(key, cfg: ModelConfig, dtype):
    xc = cfg.xlstm
    d = cfg.d_model
    di = int(xc.mlstm_proj_factor * d)
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": layers.make_norm(cfg.norm_type, d, dtype),
        "up_proj": make_dense(ks[0], d, 2 * di, dtype),
        "conv_w": (0.1 * jax.random.normal(ks[1], (xc.conv_kernel, di))).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": make_dense(ks[2], di, di, dtype),
        "wk": make_dense(ks[3], di, di, dtype),
        "wv": make_dense(ks[4], di, di, dtype),
        "w_if": make_dense(ks[5], di, 2 * h, dtype),  # input & forget gates/head
        "hnorm": layers.make_norm("rmsnorm", di, dtype),
        "down_proj": make_dense(
            ks[6], di, d, dtype, scale=di**-0.5 / (2.0 * cfg.num_layers) ** 0.5
        ),
    }


def _mlstm_qkvgates(p, cfg, x_in, conv_tail=None):
    from repro.models.mamba import _conv_causal

    xc = cfg.xlstm
    di = p["wq"]["kernel"].shape[0]
    h = cfg.num_heads
    dh = di // h
    up = dense(p["up_proj"], x_in)
    xm, z = up[..., :di], up[..., di:]
    xcv, tail = _conv_causal(p["conv_w"], p["conv_b"], xm, conv_tail)
    xcv = jax.nn.silu(xcv)
    b, s, _ = xm.shape
    q = dense(p["wq"], xcv).reshape(b, s, h, dh)
    k = dense(p["wk"], xcv).reshape(b, s, h, dh) * dh**-0.5
    v = dense(p["wv"], xm).reshape(b, s, h, dh)
    gates = dense(p["w_if"], xm).astype(jnp.float32)  # (B,S,2H)
    i_pre, f_pre = gates[..., :h], gates[..., h:]
    return q, k, v, i_pre, f_pre, z, tail, (h, dh)


import os as _os

_MLSTM_CHUNK_THRESHOLD = int(
    _os.environ.get("REPRO_MLSTM_CHUNK_THRESHOLD", 8192)
)
_MLSTM_CHUNK = int(_os.environ.get("REPRO_MLSTM_CHUNK", 1024))


def mlstm_forward(p, cfg: ModelConfig, x, *, return_cache=False):
    """Parallel (quadratic) stabilized form for train/prefill; the (T, S')
    gate/score tensors are q-chunked above _MLSTM_CHUNK_THRESHOLD so long
    prefills never materialize (S x S)."""
    xn = layers.apply_norm(p["norm"], x)
    q, k, v, i_pre, f_pre, z, tail, (h, dh) = _mlstm_qkvgates(p, cfg, xn)
    b, s = q.shape[:2]

    logf = jax.nn.log_sigmoid(f_pre)  # (B,S,H)
    cumf = jnp.cumsum(logf, axis=1)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def hid_chunk(q_c, cumf_c, t0, ct):
        """Stabilized mLSTM rows for global q positions [t0, t0+ct)."""
        ld = (
            cumf_c[:, :, None, :] - cumf[:, None, :, :] + i_pre[:, None, :, :]
        )  # (B, ct, S', H)
        tpos = t0 + jnp.arange(ct)
        spos = jnp.arange(s)
        ld = jnp.where(
            (tpos[None, :, None, None] >= spos[None, None, :, None]), ld, NEG_INF
        )
        m = jnp.max(ld, axis=2, keepdims=True)  # (B,ct,1,H)
        dmat = jnp.exp(ld - m)
        scores = jnp.einsum("bthd,bshd->btsh", q_c.astype(jnp.float32), kf)
        w = scores * dmat
        norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=2)), jnp.exp(-m[:, :, 0]))
        return jnp.einsum("btsh,bshd->bthd", w, vf) / norm[..., None]

    if s >= _MLSTM_CHUNK_THRESHOLD and s % _MLSTM_CHUNK == 0:
        n = s // _MLSTM_CHUNK

        def body(_, xs):
            q_c, cumf_c, idx = xs
            return None, hid_chunk(q_c, cumf_c, idx * _MLSTM_CHUNK, _MLSTM_CHUNK)

        qs = q.reshape(b, n, _MLSTM_CHUNK, h, dh).swapaxes(0, 1)
        cs = cumf.reshape(b, n, _MLSTM_CHUNK, h).swapaxes(0, 1)
        _, hids = jax.lax.scan(body, None, (qs, cs, jnp.arange(n)))
        hid = hids.swapaxes(0, 1).reshape(b, s, h, dh)
    else:
        hid = hid_chunk(q, cumf, 0, s)

    hid = hid.reshape(b, s, h * dh).astype(x.dtype)
    hid = layers.apply_norm(p["hnorm"], hid) * jax.nn.silu(z)
    out = x + dense(p["down_proj"], hid)
    if not return_cache:
        return out
    # build the recurrent state equivalent to having consumed the sequence
    cache = _mlstm_state_from_seq(q, k, v, i_pre, f_pre, tail)
    return out, cache


def _mlstm_state_from_seq(q, k, v, i_pre, f_pre, tail):
    """Fold a full sequence into the recurrent (C, n, m) state (prefill)."""
    b, s, h, dh = q.shape
    logf = jax.nn.log_sigmoid(f_pre)
    cumf = jnp.cumsum(logf, axis=1)
    total = cumf[:, -1]  # (B,H)
    # weight of step t in the final state: exp(totalF - cumF_t + i_t - mT)
    lw = total[:, None] - cumf + i_pre  # (B,S,H)
    mT = jnp.max(lw, axis=1)  # (B,H)
    wgt = jnp.exp(lw - mT[:, None])
    c = jnp.einsum("bsh,bshd,bshe->bhde", wgt, k.astype(jnp.float32), v.astype(jnp.float32))
    n = jnp.einsum("bsh,bshd->bhd", wgt, k.astype(jnp.float32))
    return {"c": c, "n": n, "m": mT, "conv_tail": tail}


def mlstm_decode(p, cfg: ModelConfig, x, cache) -> Tuple[jnp.ndarray, dict]:
    xn = layers.apply_norm(p["norm"], x)
    q, k, v, i_pre, f_pre, z, tail, (h, dh) = _mlstm_qkvgates(
        p, cfg, xn, cache["conv_tail"]
    )
    b = x.shape[0]
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # (B,H,dh)
    i_pre, f_pre = i_pre[:, 0], f_pre[:, 0]  # (B,H)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + cache["m"], i_pre)
    fw = jnp.exp(logf + cache["m"] - m_new)[..., None]
    iw = jnp.exp(i_pre - m_new)[..., None]
    c = fw[..., None] * cache["c"] + iw[..., None] * jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = fw * cache["n"] + iw * k.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", c, q.astype(jnp.float32))
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.astype(jnp.float32))), jnp.exp(-m_new)
    )
    hid = (num / den[..., None]).reshape(b, 1, h * dh).astype(x.dtype)
    hid = layers.apply_norm(p["hnorm"], hid) * jax.nn.silu(z)
    out = x + dense(p["down_proj"], hid)
    return out, {"c": c, "n": n, "m": m_new, "conv_tail": tail}


def mlstm_cache_spec(cfg: ModelConfig, batch: int, dtype):
    xc = cfg.xlstm
    di = int(xc.mlstm_proj_factor * cfg.d_model)
    h = cfg.num_heads
    dh = di // h
    return {
        "c": jax.ShapeDtypeStruct((batch, h, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, h, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, h), jnp.float32),
        "conv_tail": jax.ShapeDtypeStruct((batch, xc.conv_kernel - 1, di), dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def make_slstm(key, cfg: ModelConfig, dtype):
    xc = cfg.xlstm
    d = cfg.d_model
    h, dh = cfg.num_heads, d // cfg.num_heads
    df = int(xc.slstm_proj_factor * d)
    ks = jax.random.split(key, 5)
    return {
        "norm": layers.make_norm(cfg.norm_type, d, dtype),
        "w_gates": make_dense(ks[0], d, 4 * d, dtype),  # i,f,z,o pre-acts
        # per-head recurrent matrices (block-diagonal R)
        "r_gates": (dh**-0.5 * jax.random.normal(ks[1], (4, h, dh, dh))).astype(dtype),
        "b_gates": jnp.zeros((4, d), dtype),
        "hnorm": layers.make_norm("rmsnorm", d, dtype),
        "ffn_norm": layers.make_norm(cfg.norm_type, d, dtype),
        "ffn": layers.make_mlp(
            ks[2], d, df, "gelu", dtype,
            out_scale=df**-0.5 / (2.0 * cfg.num_layers) ** 0.5,
        ),
    }


def _slstm_step(p, cfg, wx_t, state):
    """wx_t: (B, 4, H, dh) input pre-activations; state: (c,n,m,h_prev)."""
    c, n, m, h_prev = state
    rh = jnp.einsum("ghde,bhe->bghd", p["r_gates"].astype(jnp.float32), h_prev)
    pre = wx_t + rh + p["b_gates"].astype(jnp.float32).reshape(
        1, 4, cfg.num_heads, -1
    )
    i_p, f_p, z_p, o_p = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    logf = jax.nn.log_sigmoid(f_p)
    m_new = jnp.maximum(logf + m, i_p)
    i_w = jnp.exp(i_p - m_new)
    f_w = jnp.exp(logf + m - m_new)
    z = jnp.tanh(z_p)
    o = jax.nn.sigmoid(o_p)
    c_new = f_w * c + i_w * z
    n_new = f_w * n + i_w
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new)


def _slstm_init_state(b, h, dh):
    z = jnp.zeros((b, h, dh), jnp.float32)
    return (z, z, jnp.full((b, h, dh), 0.0, jnp.float32), z)


# sLSTM backward-pass memory knob: the sequential scan over S saves its
# carry per step for the backward pass (O(S) states). Chunking the scan and
# rematerializing within chunks stores only chunk-boundary states
# (O(S/chunk) saved + O(chunk) recompute) — §Perf D measures the effect on
# the xlstm train_4k dry-run.
import os as _os

SLSTM_CHUNK = int(_os.environ.get("REPRO_SLSTM_CHUNK", 0))  # 0 = unchunked


def slstm_forward(p, cfg: ModelConfig, x, *, return_cache=False):
    b, s, d = x.shape
    h, dh = cfg.num_heads, d // cfg.num_heads
    xn = layers.apply_norm(p["norm"], x)
    wx = dense(p["w_gates"], xn).astype(jnp.float32).reshape(b, s, 4, h, dh)

    def step(state, wx_t):
        new = _slstm_step(p, cfg, wx_t, state)
        return new, new[3]

    state0 = _slstm_init_state(b, h, dh)
    if SLSTM_CHUNK and s > SLSTM_CHUNK:
        chunk = SLSTM_CHUNK
        s_pad = -(-s // chunk) * chunk
        wx_p = jnp.pad(wx, ((0, 0), (0, s_pad - s), (0, 0), (0, 0), (0, 0)))
        valid = jnp.arange(s_pad) < s

        def masked_step(state, xs):
            wx_t, ok = xs
            new = _slstm_step(p, cfg, wx_t, state)
            # padded steps are identity on the state
            new = jax.tree.map(
                lambda a, b_: jnp.where(ok, a, b_), new, state
            )
            return new, new[3]

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def chunk_body(state, xs):
            wx_c, ok_c = xs  # (chunk, B, 4, H, dh), (chunk,)
            return jax.lax.scan(masked_step, state, (wx_c, ok_c))

        nch = s_pad // chunk
        wx_r = wx_p.swapaxes(0, 1).reshape(nch, chunk, b, 4, h, dh)
        ok_r = valid.reshape(nch, chunk)
        stateT, hs = jax.lax.scan(chunk_body, state0, (wx_r, ok_r))
        hs = hs.reshape(s_pad, b, h, dh)[:s]
    else:
        stateT, hs = jax.lax.scan(step, state0, wx.swapaxes(0, 1))
    hid = hs.swapaxes(0, 1).reshape(b, s, d).astype(x.dtype)
    hid = layers.apply_norm(p["hnorm"], hid)
    y = x + hid
    y = y + layers.apply_mlp(p["ffn"], layers.apply_norm(p["ffn_norm"], y), "gelu")
    if not return_cache:
        return y
    c, n, m, hp = stateT
    return y, {"c": c, "n": n, "m": m, "h": hp}


def slstm_decode(p, cfg: ModelConfig, x, cache) -> Tuple[jnp.ndarray, dict]:
    b, _, d = x.shape
    h, dh = cfg.num_heads, d // cfg.num_heads
    xn = layers.apply_norm(p["norm"], x)
    wx = dense(p["w_gates"], xn).astype(jnp.float32).reshape(b, 4, h, dh)
    state = (cache["c"], cache["n"], cache["m"], cache["h"])
    c, n, m, hp = _slstm_step(p, cfg, wx, state)
    hid = hp.reshape(b, 1, d).astype(x.dtype)
    hid = layers.apply_norm(p["hnorm"], hid)
    y = x + hid
    y = y + layers.apply_mlp(p["ffn"], layers.apply_norm(p["ffn_norm"], y), "gelu")
    return y, {"c": c, "n": n, "m": m, "h": hp}


def slstm_cache_spec(cfg: ModelConfig, batch: int, dtype):
    h, dh = cfg.num_heads, cfg.d_model // cfg.num_heads
    sd = jax.ShapeDtypeStruct((batch, h, dh), jnp.float32)
    return {"c": sd, "n": sd, "m": sd, "h": sd}
