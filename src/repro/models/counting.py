"""Analytic parameter / FLOP counting per config — used by rooflines
(MODEL_FLOPS = 6*N*D dense / 6*N_active*D MoE) and sanity-checked against
jax.eval_shape of the real init in tests."""

from __future__ import annotations

from repro.configs.base import LayerSpec, ModelConfig


def _attn_params(cfg: ModelConfig) -> int:
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    n = d * h * hd + 2 * d * kvh * hd + h * hd * d
    if cfg.attn_bias or cfg.qkv_bias:
        n += h * hd + 2 * kvh * hd
    if cfg.attn_bias:
        n += d
    return n


def _mla_params(cfg: ModelConfig) -> int:
    mla = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    r, dn, dr, dv = (
        mla.kv_lora_rank,
        mla.qk_nope_head_dim,
        mla.qk_rope_head_dim,
        mla.v_head_dim,
    )
    return d * h * (dn + dr) + d * (r + dr) + r + r * h * dn + r * h * dv + h * dv * d


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mats = 3 if cfg.mlp_type in ("swiglu", "geglu") else 2
    return mats * cfg.d_model * d_ff


def _moe_params(cfg: ModelConfig, active_only: bool) -> int:
    moe = cfg.moe
    d, f = cfg.d_model, moe.d_ff_expert
    router = d * moe.num_experts
    shared = moe.num_shared * 3 * d * f
    experts = (moe.top_k if active_only else moe.num_experts) * 3 * d * f
    return router + shared + experts


def _mamba_params(cfg: ModelConfig) -> int:
    mc = cfg.mamba
    d = cfg.d_model
    di = mc.expand * d
    dtr = mc.dt_rank or -(-d // 16)
    ds = mc.d_state
    return (
        d * 2 * di  # in_proj
        + mc.d_conv * di + di  # conv
        + di * (dtr + 2 * ds)  # x_proj
        + dtr * di + di  # dt_proj
        + di * ds  # a_log
        + di  # d_skip
        + dtr + 2 * ds  # norms
        + di * d  # out_proj
    )


def _mlstm_params(cfg: ModelConfig) -> int:
    xc = cfg.xlstm
    d = cfg.d_model
    di = int(xc.mlstm_proj_factor * d)
    h = cfg.num_heads
    return d * 2 * di + xc.conv_kernel * di + di + 3 * di * di + di * 2 * h + di * d


def _slstm_params(cfg: ModelConfig) -> int:
    xc = cfg.xlstm
    d = cfg.d_model
    h, dh = cfg.num_heads, d // cfg.num_heads
    df = int(xc.slstm_proj_factor * d)
    return d * 4 * d + 4 * h * dh * dh + 4 * d + 2 * d * df + df * d


def _layer_params(cfg: ModelConfig, spec: LayerSpec, active_only: bool) -> int:
    n = 0
    if spec.mixer in ("attn", "swa"):
        n += _attn_params(cfg)
    elif spec.mixer == "mla":
        n += _mla_params(cfg)
    elif spec.mixer == "mamba":
        n += _mamba_params(cfg)
    elif spec.mixer == "mlstm":
        n += _mlstm_params(cfg)
    elif spec.mixer == "slstm":
        n += _slstm_params(cfg)
    if spec.mlp == "mlp":
        n += _mlp_params(cfg, cfg.d_ff)
    elif spec.mlp == "moe":
        n += _moe_params(cfg, active_only)
    # norms (approximate: 2 per layer)
    n += 2 * cfg.d_model
    return n


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    n = cfg.padded_vocab * cfg.d_model  # embeddings
    if not cfg.tie_embeddings:
        n += cfg.padded_vocab * cfg.d_model
    for spec in cfg.layer_kinds():
        n += _layer_params(cfg, spec, active_only)
    if cfg.encoder_layers:
        for _ in range(cfg.encoder_layers):
            n += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * cfg.d_model
        # decoder cross-attention + learned decoder positions
        n += cfg.num_layers * (_attn_params(cfg) + cfg.d_model)
        n += cfg.max_position_embeddings * cfg.d_model
    return n


def train_step_flops(cfg: ModelConfig, batch: int, seq: int) -> float:
    """MODEL_FLOPS: 6*N*D with N = active params (fwd 2ND + bwd 4ND)."""
    return 6.0 * cfg.active_param_count() * batch * seq


def decode_step_flops(cfg: ModelConfig, batch: int, context: int) -> float:
    """Per-token decode: 2*N_active matmul flops + attention-cache reads.

    Attention score/value FLOPs: 4 * d_head * heads * context per attn layer.
    """
    flops = 2.0 * cfg.active_param_count() * batch
    attn_layers = sum(1 for s in cfg.layer_kinds() if s.mixer in ("attn", "swa", "mla"))
    window = cfg.sliding_window or 0
    eff_ctx = min(context, window) if window else context
    flops += 4.0 * cfg.num_heads * cfg.head_dim * eff_ctx * attn_layers * batch
    return flops
