"""repro.tune: on-device vectorized hyperparameter search as a service.

The ensemble axis E of one CompiledSim is the search population: candidates
ride per-lane STOParams columns through the serving engine, the fused
online learner scores them as they stream, and strategies (seeded random,
grid, dependency-free CMA-ES) re-seed lanes at chunk boundaries through
the existing SlotStore admit/retire path. Batch entry point `tune_spec`;
serving entry point `ReservoirEngine.submit_autotuned` (washout-window
autotune, implemented by `washout_autotune`).

    from repro.tune import SearchSpace, Float, narma_task, tune_spec
    space = SearchSpace({"drive_current": Float(1e-3, 4e-3),
                         "spectral_radius": Float(0.2, 1.2)})
    result = tune_spec(spec, narma_task(300), space, budget=32)
    print(result.best.assignment, result.best.fitness)
"""

from repro.tune.space import ALIASES, Choice, Float, LogFloat, SearchSpace
from repro.tune.strategies import (
    CMAES,
    STRATEGIES,
    GridSearch,
    RandomSearch,
    Strategy,
    make_strategy,
)
from repro.tune.results import Trial, TuneResult
from repro.tune.driver import (
    PENALTY_FITNESS,
    TuneTask,
    narma_task,
    tune_spec,
    washout_autotune,
)

__all__ = [
    "ALIASES",
    "Choice",
    "Float",
    "LogFloat",
    "SearchSpace",
    "Strategy",
    "RandomSearch",
    "GridSearch",
    "CMAES",
    "STRATEGIES",
    "make_strategy",
    "Trial",
    "TuneResult",
    "TuneTask",
    "narma_task",
    "tune_spec",
    "washout_autotune",
    "PENALTY_FITNESS",
]
