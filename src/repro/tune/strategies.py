"""Search strategies: seeded random, exhaustive grid, and a small CMA-ES.

All strategies speak one async ask/tell protocol, designed around the
driver's lane-vectorized evaluation (candidates finish in chunk-boundary
batches, not one by one):

    ask(n)        up to n new (token, genotype) pairs to evaluate next.
                  genotype is a point in [0, 1]^d (see tune.space). An
                  EMPTY list means "nothing to hand out right now" — either
                  the strategy is waiting on outstanding tells (CMA-ES
                  finishes a generation before sampling the next) or it is
                  exhausted; the driver keeps draining in-flight trials
                  either way.
    tell(token, fitness)
                  report a finished evaluation. fitness is MINIMIZED and
                  must be finite (the driver maps failed candidates to a
                  large penalty before telling).
    exhausted     True once no future ask() will ever yield candidates.

Determinism: a strategy's proposals depend only on (seed, the sequence of
tells) — the driver tells finished trials in trial-id order at each
harvest, so a fixed-seed tune run reproduces its trial history exactly.

CMA-ES follows Hansen's (mu/mu_w, lambda) tutorial form with rank-1 +
rank-mu covariance updates and CSA step-size control, on the unit cube
with boundary repair (samples clip to [0, 1]^d and the update uses the
repaired points). Dependency-free: a handful of numpy ops per generation
on a d x d matrix, d = a few knobs.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.tune.space import Choice, Float, LogFloat, SearchSpace


class Strategy:
    """Base: token bookkeeping shared by every strategy."""

    name = "base"

    def __init__(self, space: SearchSpace, budget: int, seed: int = 0):
        if budget < 1:
            raise ValueError(f"budget must be >= 1; got {budget}")
        self.space = space
        self.budget = int(budget)
        self.seed = int(seed)
        self._next_token = 0
        self._outstanding: Dict[int, np.ndarray] = {}
        self.told = 0

    def _issue(self, genotype: np.ndarray) -> Tuple[int, np.ndarray]:
        token = self._next_token
        self._next_token += 1
        g = np.asarray(genotype, dtype=np.float64)
        self._outstanding[token] = g
        return token, g

    def ask(self, n: int) -> List[Tuple[int, np.ndarray]]:
        raise NotImplementedError

    def tell(self, token: int, fitness: float) -> None:
        if token not in self._outstanding:
            raise KeyError(f"unknown or already-told token {token}")
        if not np.isfinite(fitness):
            raise ValueError(
                f"fitness must be finite (drivers map failures to a "
                f"penalty); got {fitness!r}"
            )
        g = self._outstanding.pop(token)
        self.told += 1
        self._observe(token, g, float(fitness))

    def _observe(self, token: int, genotype: np.ndarray, fitness: float) -> None:
        pass  # random/grid don't adapt

    @property
    def issued(self) -> int:
        return self._next_token

    @property
    def exhausted(self) -> bool:
        raise NotImplementedError


class RandomSearch(Strategy):
    """Seeded uniform sampling of the unit cube — the embarrassingly
    parallel baseline: every candidate is independent, so ask(n) always
    fills the caller's lanes up to the budget."""

    name = "random"

    def __init__(self, space: SearchSpace, budget: int, seed: int = 0):
        super().__init__(space, budget, seed)
        self._rng = np.random.default_rng(seed)

    def ask(self, n: int) -> List[Tuple[int, np.ndarray]]:
        n = min(n, self.budget - self.issued)
        return [
            self._issue(self._rng.uniform(0.0, 1.0, self.space.dim))
            for _ in range(max(n, 0))
        ]

    @property
    def exhausted(self) -> bool:
        return self.issued >= self.budget


class GridSearch(Strategy):
    """Exhaustive grid in deterministic order — the sequential-sweep
    workload (examples/parameter_sweep.py) expressed as a strategy.

    Choice knobs enumerate their values; continuous knobs (Float/LogFloat)
    take `points` evenly spaced cube coordinates (so LogFloat grids are
    log-spaced in value). The full product enumerates in row-major order
    over the space's sorted knob names; budget truncates.
    """

    name = "grid"

    def __init__(
        self,
        space: SearchSpace,
        budget: int,
        seed: int = 0,  # unused; kept for the common constructor signature
        points: int = 5,
    ):
        super().__init__(space, budget, seed)
        if points < 1:
            raise ValueError(f"points must be >= 1; got {points}")
        axes = []
        for name in space.names:
            dom = space.knobs[name]
            if isinstance(dom, Choice):
                k = len(dom.values)
                # bucket midpoints decode back to exactly values[i]
                axes.append((np.arange(k) + 0.5) / k)
            else:
                axes.append(
                    np.linspace(0.0, 1.0, points)
                    if points > 1
                    else np.asarray([0.5])
                )
        self._axes = axes
        self.grid_size = int(np.prod([len(a) for a in axes]))
        self._count = min(self.grid_size, self.budget)

    def _genotype(self, i: int) -> np.ndarray:
        g = np.empty(len(self._axes))
        for ax in range(len(self._axes) - 1, -1, -1):
            k = len(self._axes[ax])
            g[ax] = self._axes[ax][i % k]
            i //= k
        return g

    def ask(self, n: int) -> List[Tuple[int, np.ndarray]]:
        out = []
        while len(out) < n and self.issued < self._count:
            out.append(self._issue(self._genotype(self.issued)))
        return out

    @property
    def exhausted(self) -> bool:
        return self.issued >= self._count


class CMAES(Strategy):
    """(mu/mu_w, lambda)-CMA-ES on the unit cube, generation-buffered.

    ask() hands out the current generation's unsampled candidates; once
    every member is told, the distribution updates and the next generation
    samples. While a generation is partially outstanding, ask() returns []
    — the driver keeps draining lanes and comes back. popsize defaults to
    the textbook 4 + floor(3 ln d), but passing popsize = the engine's
    lane width fills every lane per generation.
    """

    name = "cmaes"

    def __init__(
        self,
        space: SearchSpace,
        budget: int,
        seed: int = 0,
        sigma0: float = 0.3,
        popsize: Optional[int] = None,
        x0: Optional[np.ndarray] = None,
    ):
        super().__init__(space, budget, seed)
        d = space.dim
        self._rng = np.random.default_rng(seed)
        self.lam = int(popsize) if popsize else 4 + int(3 * math.log(max(d, 2)))
        if self.lam < 2:
            raise ValueError(f"popsize must be >= 2; got {self.lam}")
        self.mu = self.lam // 2
        w = math.log(self.mu + 0.5) - np.log(np.arange(1, self.mu + 1))
        self.w = w / w.sum()
        self.mu_eff = 1.0 / float(np.sum(self.w**2))
        self.c_sigma = (self.mu_eff + 2.0) / (d + self.mu_eff + 5.0)
        self.d_sigma = (
            1.0
            + 2.0 * max(0.0, math.sqrt((self.mu_eff - 1.0) / (d + 1.0)) - 1.0)
            + self.c_sigma
        )
        self.c_c = (4.0 + self.mu_eff / d) / (d + 4.0 + 2.0 * self.mu_eff / d)
        self.c_1 = 2.0 / ((d + 1.3) ** 2 + self.mu_eff)
        self.c_mu = min(
            1.0 - self.c_1,
            2.0 * (self.mu_eff - 2.0 + 1.0 / self.mu_eff)
            / ((d + 2.0) ** 2 + self.mu_eff),
        )
        self.chi_d = math.sqrt(d) * (1.0 - 1.0 / (4.0 * d) + 1.0 / (21.0 * d * d))

        self.m = (
            np.full(d, 0.5) if x0 is None else np.clip(np.asarray(x0, float), 0, 1)
        )
        self.sigma = float(sigma0)
        self.C = np.eye(d)
        self.p_sigma = np.zeros(d)
        self.p_c = np.zeros(d)
        self.generation = 0

        self._queue: List[np.ndarray] = []  # sampled, not yet asked out
        self._gen_tokens: Dict[int, int] = {}  # token -> index in generation
        self._gen_x: List[Optional[np.ndarray]] = []
        self._gen_f: List[Optional[float]] = []

    def _sample_generation(self) -> None:
        n = min(self.lam, self.budget - self.issued)
        if n <= 0:
            return
        d = self.space.dim
        # eigendecomposition of C once per generation (d is tiny)
        evals, B = np.linalg.eigh(self.C)
        D = np.sqrt(np.maximum(evals, 1e-20))
        z = self._rng.standard_normal((n, d))
        x = self.m[None, :] + self.sigma * (z * D[None, :]) @ B.T
        x = np.clip(x, 0.0, 1.0)  # boundary repair; update uses repaired x
        self._queue = [x[i] for i in range(n)]
        self._gen_x = [None] * n
        self._gen_f = [None] * n
        self._gen_tokens = {}
        self.generation += 1

    def ask(self, n: int) -> List[Tuple[int, np.ndarray]]:
        if not self._queue and not self._outstanding:
            self._sample_generation()
        out = []
        while len(out) < n and self._queue:
            g = self._queue.pop(0)
            token, g = self._issue(g)
            self._gen_tokens[token] = len(self._gen_tokens)
            out.append((token, g))
        return out

    def _observe(self, token: int, genotype: np.ndarray, fitness: float) -> None:
        i = self._gen_tokens[token]
        self._gen_x[i] = genotype
        self._gen_f[i] = fitness
        if self._queue or self._outstanding:
            return  # generation still in flight
        self._update(
            [x for x in self._gen_x if x is not None],
            [f for f in self._gen_f if f is not None],
        )

    def _update(self, xs: List[np.ndarray], fs: List[float]) -> None:
        if len(xs) < 2:
            return  # a truncated final generation can't rank parents
        d = self.space.dim
        order = np.argsort(fs, kind="stable")
        mu = min(self.mu, len(xs))
        w = self.w[:mu] / self.w[:mu].sum()
        mu_eff = 1.0 / float(np.sum(w**2))
        x_sel = np.stack([xs[order[i]] for i in range(mu)])
        y_sel = (x_sel - self.m[None, :]) / self.sigma
        y_w = w @ y_sel  # (d,)
        m_new = self.m + self.sigma * y_w

        evals, B = np.linalg.eigh(self.C)
        D_inv = 1.0 / np.sqrt(np.maximum(evals, 1e-20))
        c_inv_half = (B * D_inv[None, :]) @ B.T
        self.p_sigma = (1.0 - self.c_sigma) * self.p_sigma + math.sqrt(
            self.c_sigma * (2.0 - self.c_sigma) * mu_eff
        ) * (c_inv_half @ y_w)
        ps_norm = float(np.linalg.norm(self.p_sigma))
        h_sigma = float(
            ps_norm
            / math.sqrt(1.0 - (1.0 - self.c_sigma) ** (2 * self.generation))
            < (1.4 + 2.0 / (d + 1.0)) * self.chi_d
        )
        self.p_c = (1.0 - self.c_c) * self.p_c + h_sigma * math.sqrt(
            self.c_c * (2.0 - self.c_c) * mu_eff
        ) * y_w
        rank1 = np.outer(self.p_c, self.p_c)
        rank_mu = (y_sel.T * w[None, :]) @ y_sel
        delta_h = (1.0 - h_sigma) * self.c_c * (2.0 - self.c_c)
        self.C = (
            (1.0 - self.c_1 - self.c_mu) * self.C
            + self.c_1 * (rank1 + delta_h * self.C)
            + self.c_mu * rank_mu
        )
        self.C = (self.C + self.C.T) / 2.0  # keep symmetric under roundoff
        self.sigma *= math.exp(
            (self.c_sigma / self.d_sigma) * (ps_norm / self.chi_d - 1.0)
        )
        self.sigma = float(np.clip(self.sigma, 1e-8, 2.0))
        self.m = np.clip(m_new, 0.0, 1.0)

    @property
    def exhausted(self) -> bool:
        return (
            self.issued >= self.budget
            and not self._queue
            and not self._outstanding
        )


STRATEGIES = {
    "random": RandomSearch,
    "grid": GridSearch,
    "cmaes": CMAES,
}


def make_strategy(
    strategy, space: SearchSpace, budget: int, seed: int = 0, **kwargs
) -> Strategy:
    """Resolve a strategy name ("random" | "grid" | "cmaes") or pass an
    already-built Strategy through (it must wrap the same space)."""
    if isinstance(strategy, Strategy):
        if strategy.space is not space and strategy.space.names != space.names:
            raise ValueError(
                "the provided strategy wraps a different search space"
            )
        return strategy
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown strategy {strategy!r}; choose from "
            f"{sorted(STRATEGIES)} or pass a Strategy instance"
        )
    return STRATEGIES[strategy](space, budget, seed=seed, **kwargs)
