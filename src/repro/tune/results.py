"""Trial records and ranked tune results.

Everything here is host-side plain data (floats, dicts, numpy genotypes)
so a TuneResult serializes/compares across runs: fixed-seed tune runs
produce identical trial histories (pinned by tests/test_tune.py), which is
what makes hyperparameter search results reviewable artifacts instead of
one-off printouts.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Trial:
    """One evaluated candidate.

    fitness is MINIMIZED (the online learn_nmse by default; whatever the
    task's score callback returns otherwise). Non-finite fitness marks a
    failed/diverged candidate — ranked last, and reported to the strategy
    as a large penalty so CMA-ES steers away instead of crashing.
    """

    trial_id: int
    assignment: Dict[str, object]  # knob name -> concrete value
    fitness: float
    genotype: np.ndarray  # the [0, 1]^d point that decoded to `assignment`
    engine_key: str  # which structural engine group evaluated it
    ticks: int  # input ticks the evaluation consumed

    @property
    def ok(self) -> bool:
        return bool(np.isfinite(self.fitness))

    def to_dict(self) -> dict:
        return {
            "trial_id": self.trial_id,
            "assignment": {
                k: (v.item() if isinstance(v, np.generic) else v)
                for k, v in self.assignment.items()
            },
            "fitness": float(self.fitness),
            "genotype": [float(g) for g in self.genotype],
            "engine_key": self.engine_key,
            "ticks": self.ticks,
        }


@dataclasses.dataclass
class TuneResult:
    """A finished search: every trial in evaluation order, plus provenance.

    `ranked()` sorts best-first (finite fitness ascending, failures last);
    `best` is ranked()[0]. trials keep SUBMISSION order — the fixed-seed
    determinism contract is over this list, ids and fitnesses included.
    """

    trials: List[Trial]
    strategy: str
    space_names: tuple
    budget: int
    seed: int
    wall_s: float  # wall-clock of the whole search
    sequential: bool = False  # True when evaluated one candidate at a time

    def ranked(self) -> List[Trial]:
        return sorted(
            self.trials,
            key=lambda t: (not t.ok, t.fitness if t.ok else 0.0, t.trial_id),
        )

    @property
    def best(self) -> Trial:
        if not self.trials:
            raise ValueError("no trials were evaluated")
        return self.ranked()[0]

    def to_dict(self) -> dict:
        return {
            "strategy": self.strategy,
            "space": list(self.space_names),
            "budget": self.budget,
            "seed": self.seed,
            "wall_s": self.wall_s,
            "sequential": self.sequential,
            "trials": [t.to_dict() for t in self.trials],
            "best": self.best.to_dict() if self.trials else None,
        }
