"""Search spaces over SimSpec/ExecPlan knobs, encoded for lane-vectorized
evaluation.

A `SearchSpace` maps knob names to `Float` / `LogFloat` / `Choice` domains
and owns the genotype encoding every strategy speaks: a candidate is a
point in the unit cube [0, 1]^d (one coordinate per knob, in the space's
sorted name order), and `decode` turns it into a concrete knob assignment.
Strategies never see knob semantics — random search samples the cube,
CMA-ES adapts a Gaussian on it — and the space alone knows how a
coordinate becomes a drive current or a hold_steps value.

Knob names resolve against the unified API's tunable-leaf registry
(repro.api.spec / repro.api.plan):

  LANE knobs    any STOParams field (current, a_cp, a_in, alpha, ...).
                These vary PER ENSEMBLE LANE of one CompiledSim — E
                candidates with different values ride ONE dispatch through
                the serving engine's per-tenant params columns. a_cp is the
                effective spectral radius (W^cp is normalized to rho = 1).
                Aliases: spectral_radius -> a_cp, drive_current -> current,
                input_gain -> a_in.
  STRUCT knobs  dt / hold_steps (SimSpec) and learn_lam / learn_reg /
                learn_mu (ExecPlan). Structural: static in the compiled
                workers, so each distinct value means a different compiled
                engine. They must be `Choice` domains — the tune driver
                groups candidates per structural combination and compiles
                one engine per group, so a continuous structural knob would
                explode the compile cache one engine per trial.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.api.plan import PLAN_TUNABLE
from repro.api.spec import LANE_TUNABLE, STRUCT_TUNABLE

#: friendly name -> STOParams field
ALIASES = {
    "spectral_radius": "a_cp",
    "drive_current": "current",
    "input_gain": "a_in",
}


@dataclasses.dataclass(frozen=True)
class Float:
    """Uniform continuous domain [lo, hi]."""

    lo: float
    hi: float

    def __post_init__(self):
        if not (math.isfinite(self.lo) and math.isfinite(self.hi)):
            raise ValueError(f"Float bounds must be finite; got [{self.lo}, {self.hi}]")
        if not self.lo < self.hi:
            raise ValueError(f"Float needs lo < hi; got [{self.lo}, {self.hi}]")

    def decode(self, u: float) -> float:
        # convex form: exact at both endpoints (u=0 -> lo, u=1 -> hi)
        u = float(u)
        return (1.0 - u) * self.lo + u * self.hi


@dataclasses.dataclass(frozen=True)
class LogFloat:
    """Log-uniform continuous domain [lo, hi] (lo > 0) — the natural scale
    for knobs spanning decades (learn_reg-style regularizers, currents)."""

    lo: float
    hi: float

    def __post_init__(self):
        if not 0.0 < self.lo < self.hi:
            raise ValueError(
                f"LogFloat needs 0 < lo < hi; got [{self.lo}, {self.hi}]"
            )

    def decode(self, u: float) -> float:
        return float(
            math.exp(math.log(self.lo) + float(u) * (math.log(self.hi) - math.log(self.lo)))
        )


@dataclasses.dataclass(frozen=True)
class Choice:
    """Discrete domain: a fixed tuple of values. The only legal domain for
    structural knobs (dt, hold_steps, learn_*) — see the module docstring."""

    values: Tuple

    def __init__(self, values: Sequence):
        object.__setattr__(self, "values", tuple(values))
        if not self.values:
            raise ValueError("Choice needs at least one value")

    def decode(self, u: float):
        # u in [0, 1] -> bucket index; u == 1.0 clamps into the last bucket
        i = min(int(float(u) * len(self.values)), len(self.values) - 1)
        return self.values[i]


class SearchSpace:
    """An ordered set of named knob domains + the [0, 1]^d genotype codec.

    >>> space = SearchSpace({"current": Float(1e-3, 4e-3),
    ...                      "spectral_radius": Float(0.2, 1.2)})
    >>> space.names  # aliases resolve; sorted canonical order is the codec
    ('a_cp', 'current')
    >>> a = space.decode([1.0, 0.0])
    >>> (a["current"], a["a_cp"])
    (0.001, 1.2)
    """

    def __init__(self, knobs: Dict[str, object]):
        if not knobs:
            raise ValueError("SearchSpace needs at least one knob")
        resolved: Dict[str, object] = {}
        for name, dom in knobs.items():
            canon = ALIASES.get(name, name)
            if canon in resolved:
                raise ValueError(
                    f"duplicate knob {name!r} (resolves to {canon!r})"
                )
            if canon in LANE_TUNABLE:
                if not isinstance(dom, (Float, LogFloat, Choice)):
                    raise TypeError(
                        f"knob {name!r} domain must be Float/LogFloat/Choice; "
                        f"got {dom!r}"
                    )
            elif canon in STRUCT_TUNABLE or canon in PLAN_TUNABLE:
                if not isinstance(dom, Choice):
                    raise TypeError(
                        f"knob {name!r} is STRUCTURAL (each value is a "
                        f"separately compiled engine) and must be a Choice "
                        f"of discrete values; got {dom!r}"
                    )
            else:
                valid = sorted(
                    set(LANE_TUNABLE) | set(STRUCT_TUNABLE) | set(PLAN_TUNABLE)
                    | set(ALIASES)
                )
                raise ValueError(
                    f"unknown knob {name!r}; valid knobs: {valid}"
                )
            resolved[canon] = dom
        # sorted order pins the genotype axis assignment independent of dict
        # insertion order — trial histories stay comparable across runs
        self.knobs: Dict[str, object] = {k: resolved[k] for k in sorted(resolved)}
        self.names: Tuple[str, ...] = tuple(self.knobs)

    @property
    def dim(self) -> int:
        return len(self.names)

    def decode(self, genotype: Sequence[float]) -> Dict[str, object]:
        """[0, 1]^d point -> {knob name: concrete value}."""
        g = np.asarray(genotype, dtype=np.float64)
        if g.shape != (self.dim,):
            raise ValueError(
                f"genotype must have shape ({self.dim},) for knobs "
                f"{self.names}; got {tuple(g.shape)}"
            )
        if not ((g >= 0.0) & (g <= 1.0)).all():
            raise ValueError(f"genotype coordinates must lie in [0, 1]; got {g}")
        return {
            name: self.knobs[name].decode(g[i])
            for i, name in enumerate(self.names)
        }

    def split(
        self, assignment: Dict[str, object]
    ) -> Tuple[Dict[str, object], Dict[str, object], Dict[str, object]]:
        """Assignment -> (lane_kw, spec_struct_kw, plan_kw).

        lane_kw are STOParams overrides that ride a candidate's session
        lane; spec_struct_kw (dt/hold_steps) and plan_kw (learn_*) select
        which compiled engine the candidate groups into.
        """
        lane_kw, spec_kw, plan_kw = {}, {}, {}
        for name, value in assignment.items():
            canon = ALIASES.get(name, name)
            if canon in LANE_TUNABLE:
                lane_kw[canon] = value
            elif canon in STRUCT_TUNABLE:
                spec_kw[canon] = value
            elif canon in PLAN_TUNABLE:
                plan_kw[canon] = value
            else:  # pragma: no cover - decode() only emits known names
                raise ValueError(f"unknown knob {name!r}")
        return lane_kw, spec_kw, plan_kw

    @property
    def grid_sizes(self) -> Optional[Tuple[int, ...]]:
        """Per-knob grid cardinality when every knob is a Choice (the grid
        strategy's domain); None if any knob is continuous."""
        sizes = []
        for name in self.names:
            dom = self.knobs[name]
            if not isinstance(dom, Choice):
                return None
            sizes.append(len(dom.values))
        return tuple(sizes)

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v!r}" for k, v in self.knobs.items())
        return f"SearchSpace({inner})"
