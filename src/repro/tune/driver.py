"""The tune driver: lane-vectorized hyperparameter search on the serving engine.

The core trick (ROADMAP item 3, after arXiv:2404.11631): the ensemble axis
E of ONE CompiledSim is a ready-made vectorized population. Every candidate
becomes a StreamSession whose per-tenant STOParams lane carries its knob
values, the engine slot-batches E of them into each `tick_chunk` dispatch,
and the fused online learner (`ExecPlan.learn`) scores them as they stream
— fitness is the `learn_nmse` the engine already harvests per session, so
evaluating E candidates costs ONE simulation pass instead of E. Candidates
re-seed lanes at chunk boundaries through the existing SlotStore
admit/retire machinery: the driver below contains zero device plumbing.

Two entry points:

  tune_spec(spec, task, space, budget, plan=...)
      batch search -> ranked TuneResult. Structural knobs (dt, hold_steps,
      learn_*) group candidates into one compiled engine per combination
      (SearchSpace.split); lane knobs sweep within each engine.

  washout_autotune(engine, session, space, ...)
      the serving feature: before a learning tenant's stream starts, probe
      candidates evaluate ON THE LIVE ENGINE over the tenant's washout
      prefix (spare lanes, negative sids, results popped before tenants
      see them), and the winner's parameters are frozen into the session,
      which then submits normally. Exposed as
      `ReservoirEngine.submit_autotuned`. Lane knobs only — a live engine
      cannot recompile mid-serve.

Determinism: trial ids follow submission order, finished trials are told
to the strategy in trial-id order at each harvest, and strategies are
seeded — so a fixed-seed run reproduces its trial history exactly
(tests/test_tune.py pins this, and pins that probe traffic does not
perturb co-resident tenants bit-wise on the scan backend).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.api import PLAN_CACHE, ExecPlan, SimSpec
from repro.tune.results import Trial, TuneResult
from repro.tune.space import SearchSpace
from repro.tune.strategies import Strategy, make_strategy

#: fitness reported to the strategy for diverged/failed candidates — large
#: enough to rank dead last, finite so CMA-ES ranking still works
PENALTY_FITNESS = 1e9


@dataclasses.dataclass
class TuneTask:
    """What a candidate is evaluated on.

    u_seq/targets follow the serving engine's contracts ((T,) accepted for
    width 1). With targets, fitness is the ONLINE learn_nmse of the
    engine's fused learner — free, no extra passes. Without targets,
    `score(result) -> float` computes fitness from the harvested
    SessionResult (collect_states is forced on); use this for
    non-learning objectives (memory capacity, spectral measures, ...).
    Lower is better either way.
    """

    u_seq: np.ndarray
    targets: Optional[np.ndarray] = None
    learn_washout: int = 0
    score: Optional[Callable] = None
    name: str = ""

    def __post_init__(self):
        if self.targets is None and self.score is None:
            raise ValueError(
                "TuneTask needs targets (online-learning fitness) or a "
                "score callback (custom fitness)"
            )

    @property
    def ticks(self) -> int:
        return int(np.shape(self.u_seq)[0])


def narma_task(
    t: int = 300,
    order: int = 10,
    seed: int = 0,
    learn_washout: int = 40,
    name: str = "",
) -> TuneTask:
    """The paper's benchmark workload as a TuneTask: NARMA-`order` input/
    target series (core.tasks.narma_series), fitness = online NMSE."""
    from repro.core.tasks import narma_series

    u, y = narma_series(t, order=order, seed=seed)
    return TuneTask(
        u_seq=u.astype(np.float32),
        targets=y.astype(np.float32),
        learn_washout=learn_washout,
        name=name or f"narma{order}",
    )


def _engine_key(spec_kw: Dict, plan_kw: Dict) -> str:
    """Canonical label for one structural combination (engine group)."""
    parts = [f"{k}={spec_kw[k]!r}" for k in sorted(spec_kw)]
    parts += [f"{k}={plan_kw[k]!r}" for k in sorted(plan_kw)]
    return ",".join(parts) if parts else "base"


def _candidate_fitness(result, task: TuneTask) -> float:
    """SessionResult -> scalar fitness (may be non-finite for divergence)."""
    if task.score is not None:
        return float(task.score(result))
    if result.learn_nmse is None:
        return float("nan")
    return float(result.learn_nmse)


def tune_spec(
    spec: SimSpec,
    task: TuneTask,
    space: SearchSpace,
    budget: int = 32,
    plan: Optional[ExecPlan] = None,
    strategy="random",
    seed: int = 0,
    **strategy_kwargs,
) -> TuneResult:
    """Search `space` for the spec configuration that minimizes `task`
    fitness, evaluating up to ExecPlan.ensemble candidates per simulation
    pass. Returns the full ranked trial history.

    plan defaults to ExecPlan(ensemble=min(budget, 16), chunk_ticks=8,
    learn="rls") — the ensemble width IS the search parallelism (lanes per
    dispatch); ensemble=1 is the sequential per-candidate baseline the
    acceptance ratio quotes. A plan without `learn` gets learn="rls" when
    the task carries targets. Structural knobs in the space compile one
    engine per value combination; every engine reuses the plan's width.
    """
    leaf = np.asarray(spec.params.gamma)
    if leaf.ndim != 0:
        raise ValueError(
            "tune_spec needs a scalar-leaved template spec — candidates "
            "carry their own per-lane values"
        )
    if plan is None:
        plan = ExecPlan(ensemble=min(budget, 16), chunk_ticks=8)
    if task.targets is not None and plan.learn is None:
        plan = dataclasses.replace(plan, learn="rls")
    if task.targets is None and task.score is None:  # pragma: no cover
        raise ValueError("task carries neither targets nor score")
    strat = make_strategy(strategy, space, budget, seed=seed, **strategy_kwargs)

    u_seq = np.asarray(task.u_seq)
    targets = None if task.targets is None else np.asarray(task.targets)
    collect = task.score is not None

    engines: Dict[str, object] = {}
    inflight: Dict[int, Tuple[int, Dict, str]] = {}  # sid -> (token, asgn, key)
    trials: List[Trial] = []
    next_sid = 0
    ask_batch = max(plan.ensemble, 1) * 2  # keep every lane + queue warm
    t0 = time.perf_counter()

    from repro.serve.reservoir import ReservoirEngine, StreamSession

    def _get_engine(spec_kw: Dict, plan_kw: Dict, key: str):
        # one live engine per structural combo per CALL (lanes/sessions are
        # call-local state), but the CompiledSim underneath comes from the
        # process-wide PLAN_CACHE — a CMA-ES population that revisits a
        # structural combo, or a second tune_spec call over the same space,
        # re-traces nothing (structural hash ignores lane param values)
        eng = engines.get(key)
        if eng is None:
            spec_g = spec.with_knobs(**spec_kw)
            plan_g = plan.with_knobs(**plan_kw) if plan_kw else plan
            eng = ReservoirEngine(PLAN_CACHE.get_or_compile(spec_g, plan_g))
            engines[key] = eng
        return eng

    while True:
        new = strat.ask(ask_batch)
        for token, genotype in new:
            assignment = space.decode(genotype)
            lane_kw, spec_kw, plan_kw = space.split(assignment)
            key = _engine_key(spec_kw, plan_kw)
            eng = _get_engine(spec_kw, plan_kw, key)
            params = eng.res.params._replace(
                **{k: float(v) for k, v in lane_kw.items()}
            )
            sid = next_sid
            next_sid += 1
            eng.submit(
                StreamSession(
                    sid=sid,
                    u_seq=u_seq.copy(),
                    params=params,
                    targets=None if targets is None else targets.copy(),
                    learn_washout=task.learn_washout,
                    collect_states=collect,
                )
            )
            inflight[sid] = (token, assignment, key)

        progressed = False
        for eng in engines.values():
            if eng.step_chunk():
                progressed = True

        finished: List[Tuple[int, object, str]] = []
        for key, eng in engines.items():
            for sid, result in eng.pop_results().items():
                finished.append((sid, result, key))
        # trial-id (submission) order — the strategies' determinism contract
        for sid, result, key in sorted(finished, key=lambda x: x[0]):
            token, assignment, _ = inflight.pop(sid)
            fitness = _candidate_fitness(result, task)
            strat.tell(
                token, fitness if np.isfinite(fitness) else PENALTY_FITNESS
            )
            trials.append(
                Trial(
                    trial_id=sid,
                    assignment=assignment,
                    fitness=fitness,
                    genotype=_genotype_from(assignment, space),
                    engine_key=key,
                    ticks=task.ticks,
                )
            )

        if strat.exhausted and not inflight:
            break
        # a harvest counts as progress even when step_chunk ran dry (the
        # engine's deferred trailing-chunk harvest lands results one
        # iteration after the last productive chunk) — fresh tells mean
        # the next ask() may yield a new generation
        if not new and not progressed and not finished and not inflight:
            # the strategy owes candidates (not exhausted) but returned
            # none with nothing running: a protocol violation, not a hang
            raise RuntimeError(
                f"strategy {strat.name!r} stalled: not exhausted, nothing "
                f"in flight, and ask() returned no candidates"
            )

    return TuneResult(
        trials=trials,
        strategy=strat.name,
        space_names=space.names,
        budget=budget,
        seed=seed,
        wall_s=time.perf_counter() - t0,
        sequential=plan.ensemble == 1,
    )


def _genotype_from(assignment: Dict, space: SearchSpace) -> np.ndarray:
    """Best-effort genotype reconstruction for the trial record (the raw
    per-token genotype is strategy-internal); continuous knobs invert
    exactly, Choice knobs record the bucket midpoint."""
    from repro.tune.space import Choice, Float, LogFloat

    g = np.empty(space.dim)
    for i, name in enumerate(space.names):
        dom = space.knobs[name]
        v = assignment[name]
        if isinstance(dom, Float):
            g[i] = (float(v) - dom.lo) / (dom.hi - dom.lo)
        elif isinstance(dom, LogFloat):
            g[i] = (np.log(float(v)) - np.log(dom.lo)) / (
                np.log(dom.hi) - np.log(dom.lo)
            )
        else:
            assert isinstance(dom, Choice)
            g[i] = (dom.values.index(v) + 0.5) / len(dom.values)
    return np.clip(g, 0.0, 1.0)


# ---------------------------------------------------------------------------
# serving feature: auto-tune a tenant during its washout window
# ---------------------------------------------------------------------------


def washout_autotune(
    engine,
    session,
    space: SearchSpace,
    budget: int = 8,
    strategy="random",
    seed: int = 0,
    probe_washout: Optional[int] = None,
    **strategy_kwargs,
) -> TuneResult:
    """Tune a learning tenant's lane knobs on the LIVE engine, then submit
    the tenant with the winning parameters. Returns the probe TuneResult;
    the tuned session is queued on the engine when this returns.

    The probes stream the tenant's washout prefix (u_seq/targets rows
    [0, learn_washout)) as ordinary learning sessions with NEGATIVE sids on
    spare lanes — admitted, scored by the fused learner, retired and popped
    out of `engine.results` before any caller sees them. Co-resident
    tenants keep streaming normally through the same dispatches; on the
    scan backend their states are bit-identical to a no-tune run (lanes are
    independent — pinned by tests/test_tune.py). Lane knobs only:
    structural knobs need a recompile, which a live engine cannot do.
    """
    from repro.serve.reservoir import StreamSession
    from repro.core.reservoir import coerce_input_series

    if engine.learn is None:
        raise ValueError(
            "washout_autotune needs a learning engine (ExecPlan.learn) — "
            "probe fitness is the fused learner's online NMSE"
        )
    if session.targets is None:
        raise ValueError(
            f"session {session.sid}: washout_autotune needs a learning "
            f"session (targets) — the washout prefix is the probe workload"
        )
    w = session.learn_washout
    if not isinstance(w, int) or isinstance(w, bool) or w < 2:
        raise ValueError(
            f"session {session.sid}: learn_washout ({w!r}) is the tuning "
            f"window — it must be an int >= 2 ticks"
        )
    for name in space.names:
        lane_kw, spec_kw, plan_kw = space.split({name: None})
        if spec_kw or plan_kw:
            raise ValueError(
                f"washout_autotune tunes lane knobs only (a live engine "
                f"cannot recompile); {name!r} is structural — use "
                f"tune_spec for structural searches"
            )

    u = coerce_input_series(
        session.u_seq, engine.store.n_in, engine.store.dtype, xp=np
    )
    y = np.asarray(session.targets, dtype=engine.store.dtype)
    if y.ndim == 1:
        y = y[:, None]
    if u.shape[0] < w or y.shape[0] < w:
        raise ValueError(
            f"session {session.sid}: stream shorter than its learn_washout "
            f"({w}) — nothing to probe on"
        )
    probe_u, probe_y = u[:w], y[:w]
    pw = max(1, w // 4) if probe_washout is None else probe_washout
    if not 0 <= pw < w:
        raise ValueError(f"probe_washout must be in [0, {w}); got {pw}")

    strat = make_strategy(strategy, space, budget, seed=seed, **strategy_kwargs)
    base_params = (
        session.params if session.params is not None else engine.res.params
    )

    # probe sids: negative, engine-unique, invisible to tenant numbering
    probe_sid = getattr(engine, "_tune_probe_sid", 0)
    # probe results must survive until we pop them, whatever max_retained is
    saved_retained, engine.max_retained = engine.max_retained, None

    inflight: Dict[int, Tuple[int, Dict]] = {}
    trials: List[Trial] = []
    order = 0
    t0 = time.perf_counter()
    try:
        while True:
            new = strat.ask(max(engine.num_slots, 1))
            for token, genotype in new:
                assignment = space.decode(genotype)
                lane_kw, _, _ = space.split(assignment)
                probe_sid -= 1
                engine.submit(
                    StreamSession(
                        sid=probe_sid,
                        u_seq=probe_u.copy(),
                        params=base_params._replace(
                            **{k: float(v) for k, v in lane_kw.items()}
                        ),
                        targets=probe_y.copy(),
                        learn_washout=pw,
                        collect_states=False,
                    )
                )
                inflight[probe_sid] = (token, assignment)

            progressed = engine.step_chunk()

            done = [
                sid for sid in list(engine.results) if sid in inflight
            ]
            # most-recent submission order == ascending trial order for
            # negative sids reversed; tell in submission order
            for sid in sorted(done, reverse=True):
                result = engine.results.pop(sid)
                token, assignment = inflight.pop(sid)
                fitness = (
                    float(result.learn_nmse)
                    if result.learn_nmse is not None
                    else float("nan")
                )
                strat.tell(
                    token,
                    fitness if np.isfinite(fitness) else PENALTY_FITNESS,
                )
                trials.append(
                    Trial(
                        trial_id=order,
                        assignment=assignment,
                        fitness=fitness,
                        genotype=_genotype_from(assignment, space),
                        engine_key="live",
                        ticks=w,
                    )
                )
                order += 1

            if strat.exhausted and not inflight:
                break
            if not new and not progressed and not done and not inflight:
                raise RuntimeError(
                    f"strategy {strat.name!r} stalled during washout "
                    f"autotune"
                )
    finally:
        engine.max_retained = saved_retained
        engine._tune_probe_sid = probe_sid

    result = TuneResult(
        trials=trials,
        strategy=strat.name,
        space_names=space.names,
        budget=budget,
        seed=seed,
        wall_s=time.perf_counter() - t0,
    )
    winner_lane_kw, _, _ = space.split(result.best.assignment)
    session.params = base_params._replace(
        **{k: float(v) for k, v in winner_lane_kw.items()}
    )
    engine.submit(session)
    return result
