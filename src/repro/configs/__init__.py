from repro.configs.base import (
    LayerSpec,
    MLAConfig,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    SHAPES,
    ShapeCell,
    XLSTMConfig,
    cells_for,
    get_config,
    list_configs,
    register,
)
from repro.configs.reduced import reduce_config
