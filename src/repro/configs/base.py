"""Config system: one dataclass tree per architecture + a registry.

Every assigned architecture (plus the paper's own reservoir configs) is a
`ModelConfig` selectable via --arch. Layer heterogeneity (hybrid interleave,
MoE periods, first-dense layers) is expressed as `prefix` + repeating
`period` of LayerSpecs, which is also what lets the model assemble into
scan-over-period stacks (small HLO, fast multi-pod compiles).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int  # routed experts
    top_k: int
    d_ff_expert: int
    num_shared: int = 0  # shared (always-on) experts, each d_ff_expert wide
    capacity_factor: float = 1.25
    router_chunk: int = 512  # token-chunked dispatch (memory bound)
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 64  # chunked associative scan length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer = a sequence mixer + a channel mixer."""

    mixer: str  # attn | swa | mla | mamba | mlstm | slstm
    mlp: str  # mlp | moe | none   (xlstm blocks carry their own projections)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer plan
    prefix: Tuple[LayerSpec, ...] = ()
    period: Tuple[LayerSpec, ...] = (LayerSpec("attn", "mlp"),)

    # flavor knobs
    mlp_type: str = "swiglu"  # swiglu | geglu | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    pos_type: str = "rope"  # rope | learned | sinusoidal | none
    rope_theta: float = 10_000.0
    attn_bias: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    sliding_window: int = 0  # >0 enables SWA for "swa" mixers
    attn_logit_softcap: float = 0.0
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    parallel_block: bool = False  # cohere: x + attn(n(x)) + mlp(n(x))

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # enc-dec (whisper): encoder depth; 0 = decoder-only
    encoder_layers: int = 0

    # modality frontend: "tokens" | "embeddings" (stubbed audio/vision)
    input_mode: str = "tokens"

    vocab_pad_multiple: int = 256
    max_position_embeddings: int = 32_768  # learned-position table size
    dtype: str = "bfloat16"
    # training memory knobs (used by train/dryrun)
    remat: bool = True
    scan_unroll: int = 1

    # which serve shapes are valid (sub-quadratic archs run long_500k)
    supports_long_context: bool = False

    def __post_init__(self):
        n_periodic = self.num_layers - len(self.prefix)
        assert n_periodic >= 0
        if self.period:
            assert n_periodic % len(self.period) == 0, (
                f"{self.name}: {n_periodic} periodic layers not divisible by "
                f"period {len(self.period)}"
            )

    @property
    def num_periods(self) -> int:
        return (self.num_layers - len(self.prefix)) // max(len(self.period), 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    def layer_kinds(self):
        """Flat per-layer specs (prefix + repeated period)."""
        return list(self.prefix) + list(self.period) * self.num_periods

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks); used by rooflines."""
        from repro.models.counting import count_params

        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.counting import count_params

        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Shape cells (assigned input shapes; applies to every LM arch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: Dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    assert cfg.name not in _REGISTRY, cfg.name
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def cells_for(cfg: ModelConfig):
    """The (shape -> applicable?) map for one arch; long_500k only for
    sub-quadratic archs (DESIGN.md §Arch-applicability)."""
    out = {}
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            out[s.name] = False
        else:
            out[s.name] = True
    return out


def _ensure_loaded():
    # importing the arch modules populates the registry
    import repro.configs.archs  # noqa: F401
