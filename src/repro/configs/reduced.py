"""Reduced (smoke-test) variants of every architecture: same family and
block structure, tiny dims — one period of layers, small width, few experts,
tiny vocab. Full configs are only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation)."""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig


def reduce_config(
    cfg: ModelConfig,
    d_model: int = 64,
    n_heads: int = 4,
    vocab: int = 512,
    periods: int = 1,
) -> ModelConfig:
    """Shrink a config for CPU smoke tests, preserving its structure."""
    head_dim = max(8, d_model // n_heads)
    if cfg.head_dim > cfg.d_model // cfg.num_heads:
        head_dim = 2 * d_model // n_heads  # gemma-style oversized heads
    kv_heads = min(cfg.num_kv_heads, n_heads)
    if cfg.num_kv_heads == cfg.num_heads:
        kv_heads = n_heads  # preserve MHA

    changes = dict(
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=kv_heads,
        head_dim=head_dim,
        d_ff=4 * d_model if cfg.d_ff else 0,
        vocab_size=vocab,
        vocab_pad_multiple=64,
        num_layers=len(cfg.prefix) + periods * len(cfg.period),
        remat=False,
    )
    if cfg.encoder_layers:
        changes["encoder_layers"] = 2
        changes["max_position_embeddings"] = 64
    if cfg.moe is not None:
        top_k = min(cfg.moe.top_k, 2)
        changes["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=8,
            top_k=top_k,
            d_ff_expert=2 * d_model,
            num_shared=min(cfg.moe.num_shared, 1),
            router_chunk=16,
            # dropless capacity so decode == train routing exactly (tests)
            capacity_factor=8.0 / top_k,
        )
    if cfg.mla is not None:
        changes["mla"] = dataclasses.replace(
            cfg.mla,
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    if cfg.mamba is not None:
        changes["mamba"] = dataclasses.replace(cfg.mamba, d_state=8, chunk=8)
    if cfg.sliding_window:
        changes["sliding_window"] = 16
    changes["name"] = cfg.name + "-reduced"
    changes["dtype"] = "float32"

    reduced = dataclasses.replace(cfg, **changes)
    return reduced
