"""h2o-danube-1.8b [dense]: 24L, d_model=2560, 32H GQA(kv=8), head_dim=80,
d_ff=6912, vocab=32000. Llama+Mistral mix with sliding-window attention
(window 4096) -> sub-quadratic, so it RUNS the long_500k cell.
[arXiv:2401.16818; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

H2O_DANUBE = register(
    ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        head_dim=80,
        d_ff=6912,
        vocab_size=32_000,
        period=(LayerSpec("swa", "mlp"),),
        mlp_type="swiglu",
        norm_type="rmsnorm",
        pos_type="rope",
        rope_theta=10_000.0,
        sliding_window=4096,
        supports_long_context=True,  # SWA: O(S * window) attention
        dtype="bfloat16",
    )
)
