"""command-r-plus-104b [dense]: 64L, d_model=12288, 96H GQA(kv=8),
d_ff=33792, vocab=256000. No biases anywhere; Cohere's parallel residual
block (attn and MLP both read the same pre-norm); LayerNorm (no bias);
tied embeddings. [hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

COMMAND_R_PLUS = register(
    ModelConfig(
        name="command-r-plus-104b",
        family="dense",
        num_layers=64,
        d_model=12_288,
        num_heads=96,
        num_kv_heads=8,
        head_dim=128,
        d_ff=33_792,
        vocab_size=256_000,
        period=(LayerSpec("attn", "mlp"),),
        mlp_type="swiglu",
        norm_type="layernorm",
        pos_type="rope",
        rope_theta=10_000.0,
        attn_bias=False,
        tie_embeddings=True,
        parallel_block=True,
        supports_long_context=False,
        dtype="bfloat16",
    )
)
