"""llava-next-mistral-7b [vlm]: Mistral-7B backbone — 32L, d_model=4096,
32H GQA(kv=8), d_ff=14336, vocab=32000, rope_theta=1e6.

The anyres vision tower + projector is a STUB: input_specs() provides the
fused sequence of precomputed patch+text embeddings (B, S, d_model), per the
assignment ("modality frontend is a STUB").
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

LLAVA_NEXT_MISTRAL = register(
    ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14_336,
        vocab_size=32_000,
        period=(LayerSpec("attn", "mlp"),),
        mlp_type="swiglu",
        norm_type="rmsnorm",
        pos_type="rope",
        rope_theta=1_000_000.0,
        input_mode="embeddings",  # vision frontend stubbed
        supports_long_context=False,
        dtype="bfloat16",
    )
)
