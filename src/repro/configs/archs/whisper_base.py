"""whisper-base [audio]: enc-dec transformer, conv frontend stubbed.

6L encoder + 6L decoder, d_model=512, 8H (kv=8 -> MHA), d_ff=2048,
vocab=51865. LayerNorm, GELU MLP, biases on attention (Whisper uses them),
sinusoidal positions on the encoder / learned on the decoder.
[arXiv:2212.04356; unverified]

The audio frontend (two conv1d + GELU downsampling of log-mel frames) is a
STUB: input_specs() provides precomputed frame embeddings (B, S, d_model),
per the assignment. The decoder is a full causal LM over the token vocab, so
prefill/decode shapes lower the decoder with cross-attention to the stubbed
encoder output.
"""

from repro.configs.base import LayerSpec, ModelConfig, register

WHISPER_BASE = register(
    ModelConfig(
        name="whisper-base",
        family="audio",
        num_layers=6,  # decoder layers; encoder_layers adds the encoder
        encoder_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab_size=51_865,
        period=(LayerSpec("attn", "mlp"),),
        mlp_type="gelu",
        norm_type="layernorm",
        pos_type="sinusoidal",
        attn_bias=True,
        tie_embeddings=True,  # whisper ties decoder embed/proj
        input_mode="embeddings",  # conv frontend stubbed
        supports_long_context=False,  # full attention
        dtype="bfloat16",
    )
)
