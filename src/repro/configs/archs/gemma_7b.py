"""gemma-7b [dense]: 28L, d_model=3072, 16H MHA (kv=16), head_dim=256
(q/k/v project to 4096 != d_model), d_ff=24576, GeGLU, vocab=256000.
Embeddings scaled by sqrt(d_model); tied LM head. (MQA is the 2b variant.)
[arXiv:2403.08295; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

GEMMA_7B = register(
    ModelConfig(
        name="gemma-7b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=16,
        num_kv_heads=16,
        head_dim=256,
        d_ff=24_576,
        vocab_size=256_000,
        period=(LayerSpec("attn", "mlp"),),
        mlp_type="geglu",
        norm_type="rmsnorm",
        pos_type="rope",
        rope_theta=10_000.0,
        tie_embeddings=True,
        embed_scale=True,
        supports_long_context=False,
        dtype="bfloat16",
    )
)
