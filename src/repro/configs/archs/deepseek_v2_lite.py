"""deepseek-v2-lite-16b [moe]: 27L, d_model=2048, 16H with MLA
(kv_lora_rank=512, qk_nope=128, qk_rope=64, v=128), vocab=102400.
Layer 0 is dense (d_ff=10944); layers 1..26 are MoE with 64 routed experts
(top-6) + 2 shared experts, expert d_ff=1408. [arXiv:2405.04434; hf]

Note: the assignment header lists "2 shared+160 routed"; 160 routed is the
full V2 — V2-**Lite** has 64 routed (matching the header's "MoE 64e top-6"),
which is what we implement.
"""

from repro.configs.base import (
    LayerSpec,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    register,
)

DEEPSEEK_V2_LITE = register(
    ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,  # MLA: all heads share the latent kv
        head_dim=128,  # nominal; MLA dims below are authoritative
        d_ff=10_944,  # dense layer-0 MLP
        vocab_size=102_400,
        prefix=(LayerSpec("mla", "mlp"),),
        period=(LayerSpec("mla", "moe"),),
        mla=MLAConfig(
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64,
            top_k=6,
            d_ff_expert=1408,
            num_shared=2,
            router_chunk=512,
        ),
        mlp_type="swiglu",
        norm_type="rmsnorm",
        pos_type="rope",  # applied to the decoupled rope dims only
        rope_theta=10_000.0,
        supports_long_context=False,  # MLA is still full attention
        dtype="bfloat16",
    )
)
