"""xlstm-125m [ssm]: 12 blocks, d_model=768, 4 heads, vocab=50304.

sLSTM + mLSTM blocks at a 3:1 mLSTM:sLSTM interleave (the xLSTM paper's
[m:s] block-ratio notation); xLSTM blocks carry their own up/down
projections, so d_ff=0 and mlp="none". Linear-time recurrence -> runs the
long_500k cell with O(1) decode state. [arXiv:2405.04517; unverified]
"""

from repro.configs.base import LayerSpec, ModelConfig, XLSTMConfig, register

XLSTM_125M = register(
    ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,  # d_model / heads
        d_ff=0,
        vocab_size=50_304,
        period=(
            LayerSpec("mlstm", "none"),
            LayerSpec("mlstm", "none"),
            LayerSpec("mlstm", "none"),
            LayerSpec("slstm", "none"),
        ),
        xlstm=XLSTMConfig(
            mlstm_proj_factor=2.0,
            slstm_proj_factor=4.0 / 3.0,
            conv_kernel=4,
        ),
        norm_type="layernorm",
        pos_type="none",
        supports_long_context=True,
        dtype="bfloat16",
    )
)
