"""Importing this package registers every assigned architecture."""

from repro.configs.archs.whisper_base import WHISPER_BASE
from repro.configs.archs.phi4_mini import PHI4_MINI
from repro.configs.archs.gemma_7b import GEMMA_7B
from repro.configs.archs.command_r_plus import COMMAND_R_PLUS
from repro.configs.archs.h2o_danube import H2O_DANUBE
from repro.configs.archs.xlstm_125m import XLSTM_125M
from repro.configs.archs.jamba_large import JAMBA_LARGE
from repro.configs.archs.deepseek_v2_lite import DEEPSEEK_V2_LITE
from repro.configs.archs.qwen2_moe import QWEN2_MOE
from repro.configs.archs.llava_next_mistral import LLAVA_NEXT_MISTRAL

ALL_ARCHS = [
    WHISPER_BASE,
    PHI4_MINI,
    GEMMA_7B,
    COMMAND_R_PLUS,
    H2O_DANUBE,
    XLSTM_125M,
    JAMBA_LARGE,
    DEEPSEEK_V2_LITE,
    QWEN2_MOE,
    LLAVA_NEXT_MISTRAL,
]
