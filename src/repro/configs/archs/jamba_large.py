"""jamba-1.5-large-398b [hybrid]: 72L, d_model=8192, 64H GQA(kv=8),
d_ff=24576, vocab=65536. Mamba:attention 7:1 interleave (one attention layer
per period of 8), MoE (16 experts, top-2) on every second layer.
Mamba state is O(1) in sequence -> runs long_500k. [arXiv:2403.19887; hf]
"""

from repro.configs.base import (
    LayerSpec,
    MambaConfig,
    MoEConfig,
    ModelConfig,
    register,
)


def _period():
    # period of 8: attention at index 3, the rest Mamba; MoE every 2nd layer
    specs = []
    for i in range(8):
        mixer = "attn" if i == 3 else "mamba"
        mlp = "moe" if i % 2 == 1 else "mlp"
        specs.append(LayerSpec(mixer, mlp))
    return tuple(specs)


JAMBA_LARGE = register(
    ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        num_layers=72,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=24_576,
        vocab_size=65_536,
        period=_period(),
        moe=MoEConfig(
            num_experts=16,
            top_k=2,
            d_ff_expert=24_576,
            num_shared=0,
            router_chunk=512,
        ),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=64),
        mlp_type="swiglu",
        norm_type="rmsnorm",
        pos_type="none",  # jamba uses no positional encoding (Mamba provides order)
        supports_long_context=True,
        dtype="bfloat16",
    )
)
