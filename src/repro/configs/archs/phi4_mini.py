"""phi4-mini-3.8b [dense]: 32L, d_model=3072, 24H GQA(kv=8), d_ff=8192,
vocab=200064. RoPE + SwiGLU + RMSNorm, tied embeddings.
[arXiv:2412.08905; hf]
"""

from repro.configs.base import LayerSpec, ModelConfig, register

PHI4_MINI = register(
    ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200_064,
        period=(LayerSpec("attn", "mlp"),),
        mlp_type="swiglu",
        norm_type="rmsnorm",
        pos_type="rope",
        rope_theta=10_000.0,
        tie_embeddings=True,
        supports_long_context=False,
        dtype="bfloat16",
    )
)
