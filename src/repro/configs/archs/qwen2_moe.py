"""qwen2-moe-a2.7b (Qwen1.5-MoE-A2.7B) [moe]: 24L, d_model=2048, 16H MHA
(kv=16) with qkv bias, vocab=151936. Every layer MoE: 60 routed experts
(top-4) + 4 shared expert units of d_ff=1408 (the HF config's single
5632-wide shared expert == 4 x 1408 in parameters).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""

from repro.configs.base import LayerSpec, MoEConfig, ModelConfig, register

QWEN2_MOE = register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        num_layers=24,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1408,  # expert width (all layers are MoE)
        vocab_size=151_936,
        period=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(
            num_experts=60,
            top_k=4,
            d_ff_expert=1408,
            num_shared=4,
            router_chunk=512,
        ),
        mlp_type="swiglu",
        norm_type="rmsnorm",
        pos_type="rope",
        rope_theta=1_000_000.0,
        qkv_bias=True,
        supports_long_context=False,
        dtype="bfloat16",
    )
)
