"""LLG vector field for N coupled spin-torque oscillators (paper Eq. 1-3).

State layout: m with shape (..., N, 3) — leading axes are ensemble/batch.

  dm_k/dt = -pref * m_k x b_k  -  alpha * pref * m_k x (m_k x b_k)
  pref    = gamma / (1 + alpha^2)
  b_k     = H_total_k + H_s(m_k) * (p x m_k)
  H_total = [Happl + (Hk - 4 pi Ms) m_k^z] e_z
            + A_cp (W^cp m^x)_k e_x  +  A_in (W^in u)_k e_x
  H_s     = hs_coef / (1 + lam * m_k . p)

The coupling term is the only O(N^2) piece; everything else is elementwise
over oscillators. `llg_field` composes them; `local_field_terms` exists so the
Pallas kernel and the sharded ensemble driver can supply their own coupling.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.constants import STOParams
from repro.core.coupling import coupling_field_x


def _cross(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Cross product over the trailing axis of size 3 (explicit, fusable)."""
    ax, ay, az = a[..., 0], a[..., 1], a[..., 2]
    bx, by, bz = b[..., 0], b[..., 1], b[..., 2]
    return jnp.stack(
        [ay * bz - az * by, az * bx - ax * bz, ax * by - ay * bx], axis=-1
    )


def effective_field_b(
    m: jnp.ndarray,
    params: STOParams,
    h_x: jnp.ndarray,
) -> jnp.ndarray:
    """b = H_total + H_s p x m, given the total x-field h_x (coupling+input).

    m: (..., N, 3); h_x: (..., N). Returns (..., N, 3).
    """
    p = jnp.stack(
        [
            jnp.broadcast_to(params.px, m[..., 0].shape),
            jnp.broadcast_to(params.py, m[..., 0].shape),
            jnp.broadcast_to(params.pz, m[..., 0].shape),
        ],
        axis=-1,
    )
    mdotp = jnp.sum(m * p, axis=-1)
    h_s = params.hs_coef / (1.0 + params.lam * mdotp)  # (..., N)
    h_z = params.happl + params.demag_field * m[..., 2]  # (..., N)
    h_field = jnp.stack([h_x, jnp.zeros_like(h_x), h_z], axis=-1)
    return h_field + h_s[..., None] * _cross(p, m)


def llg_rhs_from_b(m: jnp.ndarray, b: jnp.ndarray, params: STOParams) -> jnp.ndarray:
    """dm/dt given the effective field b (paper Eq. 1)."""
    # Params leaves are scalars or (E, 1) ensembles; expand so they broadcast
    # against (..., N, 3) vectors.
    pref = jnp.expand_dims(params.llg_prefactor, -1)
    alpha = jnp.expand_dims(params.alpha, -1)
    m_x_b = _cross(m, b)
    m_x_m_x_b = _cross(m, m_x_b)
    return -pref * m_x_b - alpha * pref * m_x_m_x_b


def llg_field(
    m: jnp.ndarray,
    params: STOParams,
    w_cp: Optional[jnp.ndarray],
    h_in_x: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full vector field: local terms + O(N^2) coupling (+ input drive).

    m:      (..., N, 3)
    w_cp:   (N, N) or None (uncoupled — O(N) evaluation, paper §3.2 remark)
    h_in_x: (..., N) input field A_in W^in u, already projected; or None.
    """
    if w_cp is not None:
        h_x = coupling_field_x(w_cp, m[..., 0], params.a_cp)
    else:
        h_x = jnp.zeros_like(m[..., 0])
    if h_in_x is not None:
        h_x = h_x + h_in_x
    b = effective_field_b(m, params, h_x)
    return llg_rhs_from_b(m, b, params)


def norm_error(m: jnp.ndarray) -> jnp.ndarray:
    """max_k | |m_k| - 1 | — the paper's conservation-law correctness oracle."""
    return jnp.max(jnp.abs(jnp.linalg.norm(m, axis=-1) - 1.0))
