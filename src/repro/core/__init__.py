"""Core library: the paper's contribution (coupled-STO reservoir simulation)
as a composable JAX module."""

from repro.core.constants import (
    STOParams,
    default_params,
    initial_magnetization,
    DT,
    N_STEPS_PAPER,
)
from repro.core.coupling import (
    make_coupling_matrix,
    make_input_matrix,
    coupling_field_x,
    spectral_radius,
)
from repro.core.sto import llg_field, effective_field_b, llg_rhs_from_b, norm_error
from repro.core.integrators import (
    TABLEAUX,
    RK4,
    HEUN,
    EULER,
    BS32,
    make_step,
    integrate_scan,
    integrate_python_loop,
    integrate_adaptive,
    convergence_order,
)
from repro.core.reservoir import (
    Reservoir,
    make_reservoir,
    coerce_input_series,
    drive,
    fit_ridge,
    fit_rls,
    fit_lms,
    predict,
    nmse,
    Readout,
)
from repro.core.ensemble import (
    broadcast_params,
    integrate_ensemble,
    integrate_ensemble_sharded,
)
