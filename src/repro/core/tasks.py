"""Benchmark tasks for reservoir readouts (standard in the RC literature the
paper cites: NARMA, delay memory / memory capacity)."""

from __future__ import annotations

from typing import Tuple

import numpy as np


def narma_series(
    t: int, order: int = 10, seed: int = 0, warmup: int = 200
) -> Tuple[np.ndarray, np.ndarray]:
    """NARMA-`order` input/target series.

    y_{t+1} = 0.3 y_t + 0.05 y_t sum_{i<order} y_{t-i} + 1.5 u_{t-order+1} u_t + 0.1
    with u ~ U[0, 0.5]. Returns (u, y) of length `t` after warmup.

    The recursion is only stable for moderate orders (NARMA-10 is the
    standard benchmark; beyond ~20 the feedback term can blow up for many
    input draws) — a diverging series raises instead of silently handing a
    readout inf/overflowed targets.
    """
    if not isinstance(order, (int, np.integer)) or isinstance(order, bool) or order < 1:
        raise ValueError(f"order must be an int >= 1; got {order!r}")
    if t < 1:
        raise ValueError(f"t must be >= 1; got {t}")
    rng = np.random.default_rng(seed)
    total = t + warmup + order
    u = rng.uniform(0.0, 0.5, size=total)
    y = np.zeros(total)
    with np.errstate(over="ignore", invalid="ignore"):
        for k in range(order, total - 1):
            y[k + 1] = (
                0.3 * y[k]
                + 0.05 * y[k] * np.sum(y[k - order + 1 : k + 1])
                + 1.5 * u[k - order + 1] * u[k]
                + 0.1
            )
    if not np.isfinite(y).all() or np.abs(y).max() > 1e3:
        raise ValueError(
            f"NARMA-{order} series diverged (|y| reached "
            f"{np.abs(y).max():.2e}); the recursion is unstable at this "
            f"order/seed — use order <= 10 or try another seed"
        )
    return u[warmup : warmup + t], y[warmup : warmup + t]


def delay_memory_targets(u: np.ndarray, max_delay: int) -> np.ndarray:
    """Targets y_d[t] = u[t - d] for d = 1..max_delay (memory-capacity task).

    Returns (T, max_delay); the first max_delay rows should be washed out.

    >>> delay_memory_targets(np.array([1.0, 2.0, 3.0, 4.0]), 2)
    array([[0., 0.],
           [1., 0.],
           [2., 1.],
           [3., 2.]])
    """
    if max_delay < 1:
        raise ValueError(f"max_delay must be >= 1; got {max_delay}")
    t = len(u)
    out = np.zeros((t, max_delay), dtype=u.dtype)
    for d in range(1, max_delay + 1):
        out[d:, d - 1] = u[: t - d]
    return out


def memory_capacity(pred: np.ndarray, target: np.ndarray) -> float:
    """MC = sum_d corr^2(pred_d, target_d)  (Jaeger's memory capacity).

    A zero-variance column (constant prediction or constant target — e.g.
    an untrained delay) has no defined correlation; it contributes 0 to the
    capacity instead of propagating NaN.

    >>> memory_capacity(np.array([[1.0, 5.0], [2.0, 5.0], [3.0, 5.0]]),
    ...                 np.array([[1.0, 0.0], [2.0, 1.0], [3.0, 2.0]]))
    1.0
    """
    mc = 0.0
    for d in range(target.shape[1]):
        p, y = pred[:, d], target[:, d]
        with np.errstate(invalid="ignore", divide="ignore"):
            c = np.corrcoef(p, y)[0, 1]
        if np.isfinite(c):
            mc += float(c) ** 2
    return mc


def sine_task(t: int, freq: float = 0.02, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """u = white noise, y = sin of the integrated input — a smooth nonlinear map."""
    rng = np.random.default_rng(seed)
    u = rng.uniform(-0.5, 0.5, size=t)
    phase = np.cumsum(u) * freq
    return u, np.sin(2.0 * np.pi * phase)
