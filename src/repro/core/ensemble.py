"""Ensemble (batched) and sharded execution of the coupled-STO integrator.

This is the paper's technique as a *distributed first-class feature*:

- `broadcast_params` builds an ensemble of parameter sets (the paper's
  motivating use-case: sweeping physical parameters / reservoir hyper-
  parameters is "a computationally expensive task", §2).
- `integrate_ensemble` runs E reservoirs at once. On TPU the coupling becomes
  an (N x N) @ (N x E) matmul — MXU-shaped, unlike the paper's mat-vec.
- `integrate_ensemble_sharded` distributes E over the data/pod mesh axes and N
  over the model axis: W^cp is row-sharded, and each RK stage all-gathers the
  m^x slice (N*E_local floats — negligible next to the O(N^2 E) compute).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.compat import SHARD_MAP_CHECK_KW as _SHARD_MAP_CHECK_KW
from repro.core.compat import shard_map

from repro.core import integrators, sto
from repro.core.constants import STOParams


def broadcast_params(base: STOParams, size: int, **sweeps) -> STOParams:
    """Ensemble of parameter sets with leaves shaped (E, 1).

    The (E, 1) trailing singleton broadcasts against per-oscillator arrays of
    shape (E, N). Any keyword in `sweeps` supplies a length-E array for that
    field; all other fields are tiled from `base`.
    """
    leaves = {}
    for name in base._fields:
        if name in sweeps:
            v = jnp.asarray(sweeps[name], dtype=base.gamma.dtype).reshape(size, 1)
        else:
            v = jnp.broadcast_to(getattr(base, name), (size, 1))
        leaves[name] = v
    unknown = set(sweeps) - set(base._fields)
    if unknown:
        raise ValueError(f"unknown sweep fields: {sorted(unknown)}")
    return STOParams(**leaves)


def integrate_ensemble(
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N), shared topology
    m0: jnp.ndarray,  # (E, N, 3)
    dt: float,
    n_steps: int,
    tableau_name: str = "rk4",
    save_every: int = 0,
):
    """Batched integration of E independent reservoirs (shared W^cp)."""
    tableau = integrators.TABLEAUX[tableau_name]

    def field(m, _):
        return sto.llg_field(m, params, w_cp)

    return integrators.integrate_scan(
        field, m0, dt, n_steps, None, tableau, save_every=save_every
    )


def integrate_ensemble_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    m0: jnp.ndarray,  # (E, N, 3)
    dt: float,
    n_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
):
    """shard_map'd integration: E over `ensemble_axes`, N over `model_axis`.

    Per device: m_local (E/|ens|, N/|model|, 3); W row-shard (N/|model|, N).
    Each field evaluation all-gathers m^x along `model_axis` (tiled), then the
    local coupling rows are one contraction — the paper's Numba-parallel
    decomposition mapped onto mesh collectives.

    gather_dtype (e.g. jnp.bfloat16) runs the COUPLING PATH in reduced
    precision: m^x is cast before the all-gather (half the wire bytes) and
    the coupling matmul runs bf16 x bf16 -> f32 (MXU-native accumulate).
    Consuming bf16 directly in the dot is what keeps XLA from cancelling the
    converts around the collective and silently restoring an f32 gather
    (observed; §Perf C). Physically benign: |H_cp| <= A_cp ~ 1 Oe against
    ~600 Oe local fields, and |m|=1 conservation is structural.
    """
    tableau = integrators.TABLEAUX[tableau_name]
    ens = tuple(ensemble_axes)

    p_params = P(ens)
    p_w = P(model_axis, None)
    p_m = P(ens, model_axis, None)

    def local_run(params_l: STOParams, w_l, m0_l):
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(m, _):
            mx = m[..., 0]  # (E_l, N_l)
            if gather_dtype is not None:
                mx = mx.astype(gather_dtype)
            if model_axis is not None:
                mx_full = jax.lax.all_gather(mx, model_axis, axis=-1, tiled=True)
            else:
                mx_full = mx
            h_x = params_l.a_cp * jnp.einsum(
                "ki,...i->...k", w_mm, mx_full, preferred_element_type=m.dtype
            )
            b = sto.effective_field_b(m, params_l, h_x)
            return sto.llg_rhs_from_b(m, b, params_l)

        yT, _ = integrators.integrate_scan(field, m0_l, dt, n_steps, None, tableau)
        return yT

    fn = shard_map(
        local_run,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: p_params, params), p_w, p_m),
        out_specs=p_m,
        **_SHARD_MAP_CHECK_KW,
    )
    return fn(params, w_cp, m0)


def drive_ensemble_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    w_in: jnp.ndarray,  # (N, N_in)
    m0: jnp.ndarray,  # (E, N, 3)
    u_seq: jnp.ndarray,  # (T, N_in) — shared input series
    dt: float,
    hold_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
):
    """Reservoir DRIVE (input on) for an ensemble, sharded like
    integrate_ensemble_sharded. Returns (mT (E,N,3), states (T,E,N)) with
    states = m^x sampled after each hold window — the full paper
    application (sweep + drive + readout) on the production mesh.

    The input field h_in = A_in * (W_in u_t) depends only on the LOCAL N
    rows, so the input path adds no collectives; only the coupling gathers.
    """
    tableau = integrators.TABLEAUX[tableau_name]
    ens = tuple(ensemble_axes)
    p_params = P(ens)
    p_w = P(model_axis, None)
    p_win = P(model_axis, None)
    p_m = P(ens, model_axis, None)
    p_states = P(None, ens, model_axis)

    def local_run(params_l: STOParams, w_l, win_l, m0_l, u):
        w_mm = w_l.astype(gather_dtype) if gather_dtype is not None else w_l

        def field(m, h_in_x):
            mx = m[..., 0]
            if gather_dtype is not None:
                mx = mx.astype(gather_dtype)
            if model_axis is not None:
                mx_full = jax.lax.all_gather(mx, model_axis, axis=-1, tiled=True)
            else:
                mx_full = mx
            h_x = params_l.a_cp * jnp.einsum(
                "ki,...i->...k", w_mm, mx_full, preferred_element_type=m.dtype
            )
            h_x = h_x + h_in_x
            b = sto.effective_field_b(m, params_l, h_x)
            return sto.llg_rhs_from_b(m, b, params_l)

        step = integrators.make_step(field, tableau)
        dt_c = jnp.asarray(dt, m0_l.dtype)

        def per_sample(m, u_t):
            h_in = params_l.a_in * jnp.einsum("ni,i->n", win_l, u_t)  # (N_l,)
            h_in = jnp.broadcast_to(h_in, m[..., 0].shape)

            def inner(mi, _):
                return step(mi, dt_c, h_in), None

            m, _ = jax.lax.scan(inner, m, None, length=hold_steps)
            return m, m[..., 0]

        mT, states = jax.lax.scan(per_sample, m0_l, u)
        return mT, states

    fn = shard_map(
        local_run,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda _: p_params, params),
            p_w, p_win, p_m, P(None, None),
        ),
        out_specs=(p_m, p_states),
        **_SHARD_MAP_CHECK_KW,
    )
    return fn(params, w_cp, w_in, m0, u_seq)


def fit_ridge_ensemble(states: jnp.ndarray, targets: jnp.ndarray, reg: float = 1e-6,
                       washout: int = 0):
    """Per-member ridge readouts. states: (T, E, N); targets: (T, n_out)
    (shared targets across the sweep). Returns w_out (E, N+1, n_out).

    The (N+1)^2 solves are tiny next to the drive; a vmap suffices even at
    production sizes (the gram matrices live per member)."""
    x = states[washout:]
    y = targets[washout:]

    def fit_one(xe):  # (T', N)
        ones = jnp.ones((xe.shape[0], 1), xe.dtype)
        xb = jnp.concatenate([xe, ones], axis=1)
        gram = xb.T @ xb
        rhs = xb.T @ y.astype(xe.dtype)
        return jnp.linalg.solve(
            gram + reg * jnp.eye(gram.shape[0], dtype=gram.dtype), rhs
        )

    return jax.vmap(fit_one, in_axes=1)(x)


def lower_sharded_ensemble(
    mesh: Mesh,
    n: int,
    e: int,
    dt: float,
    n_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    dtype=jnp.bfloat16,
    gather_dtype=None,
):
    """Dry-run entry: lower+compile the sharded ensemble integrator from
    ShapeDtypeStructs (no allocation). Returns the jax `Lowered`."""
    from repro.core import constants

    base = constants.default_params(dtype)
    params = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((e, 1), x.dtype),
        broadcast_params(base, 1),
    )
    w = jax.ShapeDtypeStruct((n, n), dtype)
    m0 = jax.ShapeDtypeStruct((e, n, 3), dtype)

    ens = tuple(ensemble_axes)
    shardings = (
        jax.tree.map(lambda _: NamedSharding(mesh, P(ens)), params),
        NamedSharding(mesh, P(model_axis, None)),
        NamedSharding(mesh, P(ens, model_axis, None)),
    )

    def run(params_, w_, m0_):
        return integrate_ensemble_sharded(
            mesh, params_, w_, m0_, dt, n_steps,
            ensemble_axes=ensemble_axes, model_axis=model_axis,
            gather_dtype=gather_dtype,
        )

    return jax.jit(run, in_shardings=shardings).lower(params, w, m0)
