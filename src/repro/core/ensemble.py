"""Ensemble (batched) and sharded execution — legacy shims + param helpers.

The execution bodies moved into the unified API (`repro.api`): ensemble
width, impl dispatch, and mesh sharding are ExecPlan decisions resolved by
`repro.api.compile_plan`, and the shard_map decompositions live in
`repro.api.sharded` (PartitionSpecs from
`distributed.sharding.reservoir_specs`). What remains here:

- `broadcast_params` builds an ensemble of parameter sets (the paper's
  motivating use-case: sweeping physical parameters is "a computationally
  expensive task", §2) — pure pytree plumbing, still first-class.
- `fit_ridge_ensemble` per-member ridge readouts.
- `integrate_ensemble` / `integrate_ensemble_sharded`: thin DEPRECATED
  shims over compile_plan, kept signature-compatible (and, for the
  unsharded path, bit-identical — the api's impl="scan" runs the same op
  sequence).
- `drive_ensemble_sharded`: delegates to `repro.api.sharded.drive_sharded`
  (prefer `compile_plan(spec, ExecPlan(mesh=...)).drive_batch(u)`).
"""

from __future__ import annotations

import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.constants import STOParams


def broadcast_params(base: STOParams, size: int, **sweeps) -> STOParams:
    """Ensemble of parameter sets with leaves shaped (E, 1).

    The (E, 1) trailing singleton broadcasts against per-oscillator arrays of
    shape (E, N). Any keyword in `sweeps` supplies a length-E array for that
    field; all other fields are tiled from `base`.
    """
    leaves = {}
    for name in base._fields:
        if name in sweeps:
            v = jnp.asarray(sweeps[name], dtype=base.gamma.dtype).reshape(size, 1)
        else:
            v = jnp.broadcast_to(getattr(base, name), (size, 1))
        leaves[name] = v
    unknown = set(sweeps) - set(base._fields)
    if unknown:
        raise ValueError(f"unknown sweep fields: {sorted(unknown)}")
    return STOParams(**leaves)


def _spec_for(params: STOParams, w_cp, m0, dt, hold_steps, tableau_name):
    """Wrap legacy ensemble arguments in a SimSpec (no input topology)."""
    from repro import api

    n = int(m0.shape[-2])
    return api.SimSpec(
        params=params,
        w_cp=w_cp,
        w_in=jnp.zeros((n, 1), dtype=m0.dtype),
        m0=m0[0] if m0.ndim == 3 else m0,
        dt=dt,
        hold_steps=hold_steps,
        tableau=tableau_name,
    )


def integrate_ensemble(
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N), shared topology
    m0: jnp.ndarray,  # (E, N, 3)
    dt: float,
    n_steps: int,
    tableau_name: str = "rk4",
    save_every: int = 0,
):
    """Batched integration of E independent reservoirs (shared W^cp).

    .. deprecated:: thin shim over `repro.api.compile_plan(spec,
       ensemble=E, impl="scan").integrate(n_steps, ...)` — bit-identical.
    """
    warnings.warn(
        "repro.core.ensemble.integrate_ensemble is deprecated; use "
        "repro.api.compile_plan(spec, ensemble=E).integrate(n_steps, ...)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import api

    sim = api.compile_plan(
        _spec_for(params, w_cp, m0, dt, 1, tableau_name),
        impl="scan",
        ensemble=int(m0.shape[0]),
    )
    return sim.integrate(n_steps, m0=m0, save_every=save_every, params=params)


def integrate_ensemble_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    m0: jnp.ndarray,  # (E, N, 3)
    dt: float,
    n_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
):
    """shard_map'd integration: E over `ensemble_axes`, N over `model_axis`.

    .. deprecated:: thin shim over `repro.api.compile_plan(spec,
       ExecPlan(mesh=...)).integrate(n_steps)`; the shard_map body now lives
       in `repro.api.sharded.integrate_sharded` (same decomposition, same
       gather_dtype semantics — see that module's docstring).
    """
    warnings.warn(
        "repro.core.ensemble.integrate_ensemble_sharded is deprecated; use "
        "repro.api.compile_plan(spec, ExecPlan(mesh=...)).integrate(n_steps)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import api

    sim = api.compile_plan(
        _spec_for(params, w_cp, m0, dt, 1, tableau_name),
        api.ExecPlan(
            ensemble=int(m0.shape[0]),
            mesh=mesh,
            ensemble_axes=tuple(ensemble_axes),
            model_axis=model_axis,
            gather_dtype=gather_dtype,
        ),
    )
    mT, _ = sim.integrate(n_steps, m0=m0, params=params)
    return mT


def drive_ensemble_sharded(
    mesh: Mesh,
    params: STOParams,  # leaves (E, 1)
    w_cp: jnp.ndarray,  # (N, N)
    w_in: jnp.ndarray,  # (N, N_in)
    m0: jnp.ndarray,  # (E, N, 3)
    u_seq: jnp.ndarray,  # (T, N_in) — shared input series
    dt: float,
    hold_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    tableau_name: str = "rk4",
    gather_dtype=None,
):
    """Reservoir DRIVE (input on) for a sharded ensemble. Returns
    (mT (E,N,3), states (T,E,N)). Delegates to the unified API's sharded
    body; prefer `compile_plan(spec, ExecPlan(mesh=...)).drive_batch(u)`.
    """
    from repro.api import sharded

    return sharded.drive_sharded(
        mesh, params, w_cp, w_in, m0, u_seq, dt, hold_steps,
        ensemble_axes=ensemble_axes, model_axis=model_axis,
        tableau_name=tableau_name, gather_dtype=gather_dtype,
    )


def fit_ridge_ensemble(states: jnp.ndarray, targets: jnp.ndarray, reg: float = 1e-6,
                       washout: int = 0):
    """Per-member ridge readouts. states: (T, E, N); targets: (T, n_out)
    (shared targets across the sweep). Returns w_out (E, N+1, n_out).

    The (N+1)^2 solves are tiny next to the drive; a vmap suffices even at
    production sizes (the gram matrices live per member)."""
    x = states[washout:]
    y = targets[washout:]

    def fit_one(xe):  # (T', N)
        ones = jnp.ones((xe.shape[0], 1), xe.dtype)
        xb = jnp.concatenate([xe, ones], axis=1)
        gram = xb.T @ xb
        rhs = xb.T @ y.astype(xe.dtype)
        return jnp.linalg.solve(
            gram + reg * jnp.eye(gram.shape[0], dtype=gram.dtype), rhs
        )

    return jax.vmap(fit_one, in_axes=1)(x)


def lower_sharded_ensemble(
    mesh: Mesh,
    n: int,
    e: int,
    dt: float,
    n_steps: int,
    ensemble_axes: Sequence[str] = ("data",),
    model_axis: Optional[str] = "model",
    dtype=jnp.bfloat16,
    gather_dtype=None,
):
    """Dry-run entry: lower+compile the sharded ensemble integrator from
    ShapeDtypeStructs (no allocation). Returns the jax `Lowered`."""
    from repro.api import sharded
    from repro.core import constants
    from repro.distributed.sharding import reservoir_specs

    base = constants.default_params(dtype)
    params = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct((e, 1), x.dtype),
        broadcast_params(base, 1),
    )
    w = jax.ShapeDtypeStruct((n, n), dtype)
    m0 = jax.ShapeDtypeStruct((e, n, 3), dtype)

    specs = reservoir_specs(tuple(ensemble_axes), model_axis)
    shardings = (
        jax.tree.map(lambda _: NamedSharding(mesh, specs["params"]), params),
        NamedSharding(mesh, specs["w"]),
        NamedSharding(mesh, specs["m"]),
    )

    def run(params_, w_, m0_):
        return sharded.integrate_sharded(
            mesh, params_, w_, m0_, dt, n_steps,
            ensemble_axes=ensemble_axes, model_axis=model_axis,
            gather_dtype=gather_dtype,
        )

    return jax.jit(run, in_shardings=shardings).lower(params, w, m0)
