"""jax version compatibility shims (single source of truth).

shard_map: jax >= 0.6 exports it at the top level and spells the
replication-check kwarg `check_vma`; older jax ships it under
jax.experimental with `check_rep`. Callers do:

    from repro.core.compat import shard_map, SHARD_MAP_CHECK_KW
    shard_map(f, mesh=..., in_specs=..., out_specs=..., **SHARD_MAP_CHECK_KW)
"""

try:
    from jax import shard_map  # noqa: F401

    SHARD_MAP_CHECK_KW = {"check_vma": False}
except ImportError:
    from jax.experimental.shard_map import shard_map  # noqa: F401

    SHARD_MAP_CHECK_KW = {"check_rep": False}
