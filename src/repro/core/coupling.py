"""Coupling / input weight construction (paper §3.1).

W^cp: N x N, zero diagonal (no self-coupling), off-diagonal iid U[-1, 1],
rescaled to spectral radius 1. W^in: N x N_in, iid U[-1, 1].

Spectral radius: exact dense eigvals for moderate N; for large N the circular
law gives rho ~ sigma * sqrt(N) for iid zero-mean entries (sigma^2 = 1/3 for
U[-1,1]), refined by a few power iterations on W W^T pairs to bound the error.
Construction runs once at setup time on the host (NumPy), like the paper's
repository does; the result is device-put by the caller.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Above this N, exact eigvals (O(N^3)) get replaced by the circular-law
# estimate with a power-iteration refinement.
_EXACT_EIG_MAX_N = 2048


def spectral_radius(w: np.ndarray, exact_max_n: int = _EXACT_EIG_MAX_N) -> float:
    """Largest |eigenvalue| of a square matrix."""
    n = w.shape[0]
    if n <= exact_max_n:
        return float(np.max(np.abs(np.linalg.eigvals(w))))
    # Circular law estimate for iid entries: rho ~ sigma sqrt(N).
    sigma = float(np.std(w))
    est = sigma * np.sqrt(n)
    # Refine with power iteration on (W @ W) using a complex start vector:
    # for non-normal random W the dominant eigenvalue may be complex, so we
    # track the Rayleigh-quotient magnitude of W applied twice, which
    # converges in magnitude even for complex-conjugate dominant pairs.
    rng = np.random.default_rng(0)
    v = rng.standard_normal(n) + 1j * rng.standard_normal(n)
    v /= np.linalg.norm(v)
    lam = est
    for _ in range(60):
        v2 = w @ (w @ v)
        nrm = np.linalg.norm(v2)
        if nrm == 0.0:
            break
        lam = np.sqrt(nrm)
        v = v2 / nrm
    # Power iteration on W^2 gives |lambda_max|^2's sqrt = |lambda_max| when
    # it converges; fall back to the circular-law estimate if it diverges
    # from it wildly (non-convergence).
    if not np.isfinite(lam) or lam <= 0 or abs(lam - est) > 0.5 * est:
        lam = est
    return float(lam)


def make_coupling_matrix(
    n: int,
    seed: int = 0,
    target_rho: float = 1.0,
    dtype=np.float32,
) -> np.ndarray:
    """Paper's W^cp: zero diagonal, off-diagonal U[-1,1], rho(W) = target_rho."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(-1.0, 1.0, size=(n, n)).astype(np.float64)
    np.fill_diagonal(w, 0.0)
    if n == 1:
        return w.astype(dtype)  # single oscillator: no coupling at all
    rho = spectral_radius(w)
    if rho > 0:
        w = w * (target_rho / rho)
    return w.astype(dtype)


def make_input_matrix(
    n: int,
    n_in: int,
    seed: int = 1,
    dtype=np.float32,
) -> np.ndarray:
    """Paper's W^in: N x N_in iid U[-1, 1]."""
    rng = np.random.default_rng(seed)
    return rng.uniform(-1.0, 1.0, size=(n, n_in)).astype(dtype)


def coupling_field_x(w_cp: jnp.ndarray, mx: jnp.ndarray, a_cp) -> jnp.ndarray:
    """H^cp x-component: a_cp * (W^cp @ m^x)  — the paper's O(N^2) term.

    mx: (..., N) -> returns (..., N). Batched as a matmul over trailing axis,
    which maps onto the MXU when the batch (ensemble) axis is >= 128.
    """
    return a_cp * jnp.einsum("ki,...i->...k", w_cp, mx)
