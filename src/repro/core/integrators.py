"""Explicit time integrators (Butcher tableaux) + scan drivers.

The paper uses classical RK4 (dt = 1e-11, 5e5 steps). We provide a generic
explicit-RK stepper so "any reservoir whose evolution can be approximated
using an explicit method" (paper §5) plugs in, plus three execution drivers
that mirror the paper's implementation ladder:

  integrate_python_loop : per-step jit dispatched from Python — the paper's
                          NumPy-base analogue (dispatch overhead per step).
  integrate_scan        : jit + lax.scan over the whole trajectory — the
                          Numba analogue (one compilation, no dispatch).
  (kernels/ops.py)      : fused Pallas step — the CUDA/Torch analogue.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Field = Callable[[jnp.ndarray, Any], jnp.ndarray]  # f(y, args) -> dy/dt


class Tableau(NamedTuple):
    a: Tuple[Tuple[float, ...], ...]  # strictly lower-triangular rows
    b: Tuple[float, ...]
    c: Tuple[float, ...]
    order: int


EULER = Tableau(a=((),), b=(1.0,), c=(0.0,), order=1)
HEUN = Tableau(a=((), (1.0,)), b=(0.5, 0.5), c=(0.0, 1.0), order=2)
RK4 = Tableau(
    a=((), (0.5,), (0.0, 0.5), (0.0, 0.0, 1.0)),
    b=(1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0),
    c=(0.0, 0.5, 0.5, 1.0),
    order=4,
)
# Bogacki–Shampine 3(2): embedded pair for the adaptive driver
BS32 = Tableau(
    a=((), (0.5,), (0.0, 0.75), (2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0)),
    b=(2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0),
    c=(0.0, 0.5, 0.75, 1.0),
    order=3,
)
BS32_B_LOW = (7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125)  # 2nd-order embedded

TABLEAUX = {"euler": EULER, "heun": HEUN, "rk4": RK4, "bs32": BS32}


def make_step(field: Field, tableau: Tableau = RK4) -> Callable:
    """Returns step(y, dt, args) -> y_next for an explicit tableau.

    Time-autonomous form: the STO field has no explicit t dependence between
    input samples (input is held piecewise-constant), matching the paper's
    benchmark (u = 0).
    """

    def step(y, dt, args):
        ks = []
        for row in tableau.a:
            yi = y
            for aij, kj in zip(row, ks):
                if aij != 0.0:
                    yi = yi + (dt * aij) * kj
            ks.append(field(yi, args))
        dy = None
        for bi, ki in zip(tableau.b, ks):
            if bi == 0.0:
                continue
            term = (dt * bi) * ki
            dy = term if dy is None else dy + term
        return y + dy

    return step


def integrate_scan(
    field: Field,
    y0: jnp.ndarray,
    dt: float,
    n_steps: int,
    args: Any = None,
    tableau: Tableau = RK4,
    save_every: int = 0,
    unroll: int = 1,
):
    """jit-friendly whole-trajectory integration via lax.scan.

    save_every == 0: return only the final state.
    save_every == k: additionally return y at every k-th step,
                     shape (n_steps // k, *y0.shape).
    """
    step = make_step(field, tableau)
    dt = jnp.asarray(dt, dtype=y0.dtype)

    if save_every:
        assert n_steps % save_every == 0

        def outer(y, _):
            def inner(yi, _):
                return step(yi, dt, args), None

            y, _ = jax.lax.scan(inner, y, None, length=save_every, unroll=unroll)
            return y, y

        yT, ys = jax.lax.scan(outer, y0, None, length=n_steps // save_every)
        return yT, ys

    def body(y, _):
        return step(y, dt, args), None

    yT, _ = jax.lax.scan(body, y0, None, length=n_steps, unroll=unroll)
    return yT, None


def integrate_python_loop(
    field: Field,
    y0: jnp.ndarray,
    dt: float,
    n_steps: int,
    args: Any = None,
    tableau: Tableau = RK4,
):
    """Paper's NumPy-base analogue: one jit'd step, dispatched per step from
    Python. Dispatch overhead dominates at small N exactly as in Table 2."""
    step = jax.jit(make_step(field, tableau), static_argnames=())
    y = y0
    dt = jnp.asarray(dt, dtype=y0.dtype)
    for _ in range(n_steps):
        y = step(y, dt, args)
    return y


def integrate_adaptive(
    field: Field,
    y0: jnp.ndarray,
    t_end: float,
    args: Any = None,
    rtol: float = 1e-6,
    atol: float = 1e-9,
    dt0: float = 1e-12,
    max_steps: int = 100_000,
    safety: float = 0.9,
):
    """Adaptive Bogacki–Shampine 3(2) with PI step control (jit-compatible:
    lax.while_loop). Returns (yT, stats dict).

    The paper fixes dt=1e-11 by hand; the adaptive driver picks dt to a
    tolerance instead — the "any explicit method" generality of paper §5,
    and the natural tool for stiff parameter corners during sweeps.
    Rejected steps don't advance t; dt adapts by err^(-1/3) within [0.2, 5]x.
    """
    step3 = make_step(field, BS32)

    def low_order(y, dt, args):
        ks = []
        for row in BS32.a:
            yi = y
            for aij, kj in zip(row, ks):
                if aij != 0.0:
                    yi = yi + (dt * aij) * kj
            ks.append(field(yi, args))
        out = y
        for bi, ki in zip(BS32_B_LOW, ks):
            out = out + (dt * bi) * ki
        return out

    t_end = jnp.asarray(t_end, y0.dtype)

    def cond(state):
        t, y, dt, n, n_rej = state
        return jnp.logical_and(t < t_end, n < max_steps)

    def body(state):
        t, y, dt, n, n_rej = state
        dt_c = jnp.minimum(dt, t_end - t)
        y_hi = step3(y, dt_c, args)
        y_lo = low_order(y, dt_c, args)
        scale = atol + rtol * jnp.maximum(jnp.abs(y), jnp.abs(y_hi))
        err = jnp.sqrt(jnp.mean(((y_hi - y_lo) / scale) ** 2))
        accept = err <= 1.0
        fac = jnp.clip(safety * err ** (-1.0 / 3.0), 0.2, 5.0)
        t = jnp.where(accept, t + dt_c, t)
        y = jax.tree.map(lambda a, b: jnp.where(accept, a, b), y_hi, y)
        return (t, y, dt_c * fac, n + 1, n_rej + (~accept).astype(jnp.int32))

    t0 = jnp.zeros((), y0.dtype)
    tT, yT, dtT, n, n_rej = jax.lax.while_loop(
        cond, body, (t0, y0, jnp.asarray(dt0, y0.dtype), 0, 0)
    )
    return yT, {"steps": n, "rejected": n_rej, "t": tT, "dt_final": dtT}


def convergence_order(
    field: Field,
    y0: jnp.ndarray,
    t_end: float,
    args: Any = None,
    tableau: Tableau = RK4,
    base_steps: int = 16,
    levels: int = 3,
) -> float:
    """Empirical order via Richardson: error vs a 4x-refined reference.

    Returns the mean observed slope log2(e_h / e_{h/2}); ~tableau.order for a
    smooth field. Used by property tests.
    """
    ref_steps = base_steps * (2 ** (levels + 2))
    ref, _ = integrate_scan(field, y0, t_end / ref_steps, ref_steps, args, tableau)
    errs = []
    for lvl in range(levels):
        n = base_steps * (2**lvl)
        y, _ = integrate_scan(field, y0, t_end / n, n, args, tableau)
        errs.append(float(jnp.max(jnp.abs(y - ref))))
    slopes = [np.log2(errs[i] / errs[i + 1]) for i in range(levels - 1)]
    return float(np.mean(slopes))
