"""Physical constants and the paper's parameter set (Table 1).

Units follow the paper (Gaussian/CGS for magnetics, SI for charge/current):
  gamma   [rad / (Oe s)]      gyromagnetic ratio
  Ms      [emu / cm^3]        saturation magnetization
  fields  [Oe]
  volume  [cm^3]
  current [A]

The spin-transfer field H_s = hbar * eta * I / (2 e (1 + lam m.p) Ms V) mixes
SI (hbar, e, I) and CGS (Ms, V): hbar*I/(2e) is in Joule; Ms*V is in emu =
erg/Oe; 1 J = 1e7 erg, hence the explicit ERG_PER_JOULE factor. With the
paper's values H_s(m.p=0) ~ 135 Oe, comparable to H_appl = 200 Oe.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp

# Fundamental constants (paper Table 1).
HBAR = 1.05457266e-34  # J s
E_CHARGE = 1.60217733e-19  # C
ERG_PER_JOULE = 1.0e7

# Paper Table 1 values.
GAMMA = 1.764e7  # rad / (Oe s)
ALPHA = 0.005
MS = 1448.3  # emu / cm^3
HK = 18.616e3  # Oe (interfacial anisotropy field)
HAPPL = 200.0  # Oe (applied field)
ETA = 0.537  # spin polarization
LAMBDA = 0.288  # spin-transfer torque asymmetry
CURRENT = 2.5e-3  # A
VOLUME = math.pi * 60.0**2 * 2.0 * 1e-21  # cm^3  (pi * 60^2 * 2 nm^3)
P_PINNED = (1.0, 0.0, 6.123234e-17)  # pinned-layer direction (~e_x)
A_CP = 1.0  # Oe, coupling amplitude
A_IN = 1.0  # Oe, input amplitude

# Benchmark protocol (paper §3.2).
DT = 1.0e-11  # s
N_STEPS_PAPER = 500_000
PHI0 = 2.0 * math.pi / 360.0  # initial-condition angle


class STOParams(NamedTuple):
    """LLG/STO parameters as a pytree of scalars (vmap-able for ensembles).

    All leaves are jnp scalars (or broadcastable arrays with a leading
    ensemble axis) so `jax.vmap`/`shard_map` can sweep any subset of them.
    """

    gamma: jnp.ndarray
    alpha: jnp.ndarray
    ms: jnp.ndarray
    hk: jnp.ndarray
    happl: jnp.ndarray
    eta: jnp.ndarray
    lam: jnp.ndarray
    current: jnp.ndarray
    volume: jnp.ndarray
    a_cp: jnp.ndarray
    a_in: jnp.ndarray
    px: jnp.ndarray
    py: jnp.ndarray
    pz: jnp.ndarray

    @property
    def llg_prefactor(self):
        """gamma / (1 + alpha^2)."""
        return self.gamma / (1.0 + self.alpha**2)

    @property
    def hs_coef(self):
        """H_s numerator in Oe: 1e7 * hbar * eta * I / (2 e Ms V).

        H_s(m) = hs_coef / (1 + lam * m.p).
        """
        return (
            ERG_PER_JOULE
            * HBAR
            * self.eta
            * self.current
            / (2.0 * E_CHARGE * self.ms * self.volume)
        )

    @property
    def demag_field(self):
        """Effective perpendicular anisotropy: Hk - 4 pi Ms  [Oe]."""
        return self.hk - 4.0 * math.pi * self.ms


def default_params(dtype=jnp.float32) -> STOParams:
    """The paper's Table 1 parameter set."""
    as_ = lambda v: jnp.asarray(v, dtype=dtype)
    return STOParams(
        gamma=as_(GAMMA),
        alpha=as_(ALPHA),
        ms=as_(MS),
        hk=as_(HK),
        happl=as_(HAPPL),
        eta=as_(ETA),
        lam=as_(LAMBDA),
        current=as_(CURRENT),
        volume=as_(VOLUME),
        a_cp=as_(A_CP),
        a_in=as_(A_IN),
        px=as_(P_PINNED[0]),
        py=as_(P_PINNED[1]),
        pz=as_(P_PINNED[2]),
    )


def initial_magnetization(n: int, dtype=jnp.float32, phi0: float = PHI0) -> jnp.ndarray:
    """Paper Eq. (4): identical unit-norm initial state for every oscillator.

    Returns m0 with shape (n, 3); |m0_k| = 1 exactly (up to dtype rounding).
    """
    m0 = jnp.array(
        [
            math.sin(phi0) * math.cos(phi0),
            math.sin(phi0) * math.sin(phi0),
            math.cos(phi0),
        ],
        dtype=dtype,
    )
    return jnp.broadcast_to(m0, (n, 3))
