"""Reservoir-computing API on top of the coupled-STO integrator.

Pipeline (the paper's application context, [AKT+22]):
  input series u(t)  --drive-->  node states x_t = m^x(t_k)  --fit-->  readout

Only the linear readout is trained, which is what makes reservoir
computing cheap; the expensive part — and the paper's subject — is the
simulation of the reservoir itself (`drive()`, now a shim over
repro.api.compile_plan). Two trainers are provided: `fit_ridge` (batch
ridge regression) and `fit_rls` (recursive least squares — the offline
oracle for the serving engine's streaming online learning,
`ExecPlan.learn="rls"`).
"""

from __future__ import annotations

import warnings
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import constants, coupling
from repro.core.constants import STOParams


class Reservoir(NamedTuple):
    params: STOParams
    w_cp: jnp.ndarray  # (N, N)
    w_in: jnp.ndarray  # (N, N_in)
    m0: jnp.ndarray  # (N, 3)
    dt: float
    hold_steps: int  # integration steps per input sample


def make_reservoir(
    n: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 100,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
) -> Reservoir:
    if params is None:
        params = constants.default_params(dtype)
    w_cp = jnp.asarray(coupling.make_coupling_matrix(n, seed=seed), dtype=dtype)
    w_in = jnp.asarray(coupling.make_input_matrix(n, n_in, seed=seed + 1), dtype=dtype)
    m0 = constants.initial_magnetization(n, dtype=dtype)
    return Reservoir(params, w_cp, w_in, m0, dt, hold_steps)


def coerce_input_series(u_seq: jnp.ndarray, n_in: int, dtype, xp=jnp) -> jnp.ndarray:
    """Validate an input series against the explicit (T, N_in) contract.

    Accepts (T, N_in), or 1-D (T,) when n_in == 1. Anything else — including
    the previously silently-transposed (1, T) — raises with the expected
    shape spelled out. Shared by `drive` and the serving engine so both
    enforce the same contract. xp=numpy keeps the series host-side (the
    serving engine assembles u blocks on host; a device round-trip per
    submit is pure overhead).
    """
    u_seq = xp.asarray(u_seq, dtype=dtype)
    if u_seq.ndim == 1:
        if n_in != 1:
            raise ValueError(
                f"1-D input series is only valid for n_in == 1; this "
                f"reservoir has n_in == {n_in}. Pass shape (T, {n_in})."
            )
        return u_seq[:, None]
    if u_seq.ndim != 2 or u_seq.shape[1] != n_in:
        raise ValueError(
            f"input series must have shape (T, {n_in}) — one row per sample, "
            f"one column per input channel — or (T,) when n_in == 1; got "
            f"{u_seq.shape}. A (1, T) series must be passed as (T, 1)."
        )
    return u_seq


def drive(
    res: Reservoir,
    u_seq: jnp.ndarray,
    m0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the reservoir over an input series. Returns (final m, states (T,N)).

    .. deprecated:: thin shim over the unified execution API. New code:

        sim = repro.api.compile_plan(repro.api.SimSpec.from_reservoir(res),
                                     impl="scan")
        mT, states = sim.drive(u_seq, m0=m0)

    The shim compiles an impl="scan" plan, which runs the exact op sequence
    this function always ran — results are bit-identical. u_seq follows the
    explicit (T, N_in) contract ((T,) allowed for n_in == 1); m0 optionally
    resumes integration from an arbitrary (N, 3) magnetization state, and
    driving in chunks with the carried-over final state is exactly
    equivalent to one long drive.
    """
    warnings.warn(
        "repro.core.reservoir.drive is deprecated; use "
        "repro.api.compile_plan(SimSpec.from_reservoir(res), impl='scan').drive(...)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro import api

    sim = api.compile_plan(api.SimSpec.from_reservoir(res), impl="scan")
    return sim.drive(u_seq, m0=m0)


class Readout(NamedTuple):
    w_out: jnp.ndarray  # (N + 1, n_out) — last row is the bias
    washout: int


def fit_ridge(
    states: jnp.ndarray,  # (T, N)
    targets: jnp.ndarray,  # (T, n_out) or (T,)
    washout: int = 0,
    reg: float = 1e-6,
) -> Readout:
    """Ridge regression readout: solve (X^T X + reg I) W = X^T Y.

    targets follows an explicit shape contract mirroring
    `coerce_input_series`: (T, n_out) — one row per sample, aligned with
    states (T, N) — or 1-D (T,) for a single output. A (1, T) row vector is
    rejected rather than silently transposed (the old auto-transpose also
    mangled legitimate single-sample (1, n_out) targets).

    The Gram matrix is accumulated in f32/f64 regardless of state dtype; the
    solve is tiny ((N+1)^2) next to the simulation cost.
    """
    states = jnp.asarray(states)
    targets = jnp.asarray(targets)
    t = states.shape[0]
    if targets.ndim == 1:
        targets = targets[:, None]
    if targets.ndim != 2 or targets.shape[0] != t:
        raise ValueError(
            f"targets must have shape ({t}, n_out) — one row per state "
            f"sample — or ({t},) for a single output; got "
            f"{tuple(targets.shape)} against states {tuple(states.shape)}. "
            f"A (1, T) row vector must be passed as (T,) or (T, 1)."
        )
    x = states[washout:]
    y = targets[washout:].astype(jnp.float64 if x.dtype == jnp.float64 else jnp.float32)
    x = x.astype(y.dtype)
    ones = jnp.ones((x.shape[0], 1), dtype=x.dtype)
    xb = jnp.concatenate([x, ones], axis=1)  # (T', N+1)
    gram = xb.T @ xb
    rhs = xb.T @ y
    w = jnp.linalg.solve(gram + reg * jnp.eye(gram.shape[0], dtype=gram.dtype), rhs)
    return Readout(w_out=w, washout=washout)


def fit_rls(
    states: jnp.ndarray,  # (T, N)
    targets: jnp.ndarray,  # (T, n_out) or (T,)
    washout: int = 0,
    reg: float = 1e-6,
    lam: float = 1.0,
    w0: Optional[jnp.ndarray] = None,  # (N + 1, n_out) warm start
    block: int = 1,
) -> Readout:
    """Recursive-least-squares readout — the offline oracle for streaming
    online learning (`ExecPlan.learn="rls"`).

    Processes the state rows sequentially with the same update kernels the
    serving engine fuses into `CompiledSim.tick_chunk` (kernels/rls.py), at
    batch width 1: P starts at I / reg, weights at w0 (zeros by default),
    and the first `washout` rows are masked — the update is skipped with
    exactly-zero contributions, mirroring a streaming session's
    `learn_washout` ticks.

    block matches the serving engine's chunk size: `block=K` applies
    `kernels.rls.rls_chunk` to K-row blocks [0, K), [K, 2K), ... — exactly
    how a served session's ticks are blocked (sessions admit at chunk
    boundaries, so their local blocking is origin-aligned regardless of
    global chunk phase). Fed a session's HARVESTED states
    (`SessionResult.states`) with block == the engine's chunk_ticks, this
    reproduces the session's learned readout bit-for-bit on the scan
    backend — the update kernels are reduction-order stable across batch
    widths (see kernels/rls.py) — pinned by tests/test_rls_learning.py.
    (The harvested states, not a solo re-drive: batched integration agrees
    with solo runs only to float tolerance. And the same block size: the
    chunked recursion is mathematically identical to block=1 but orders
    float ops differently.)

    With lam == 1.0 the recursion solves the same regularized normal
    equations as `fit_ridge(states, targets, washout, reg)` — identical up
    to float roundoff (RLS runs in the state dtype; fit_ridge accumulates
    its Gram matrix separately). lam < 1 exponentially forgets old samples
    (non-stationary targets), which batch ridge cannot express.

    targets follows `fit_ridge`'s explicit shape contract: (T, n_out)
    aligned with states, or (T,) for a single output.
    """
    from repro.kernels import rls as krls

    states = jnp.asarray(states)
    targets = jnp.asarray(targets)
    t = states.shape[0]
    if targets.ndim == 1:
        targets = targets[:, None]
    if targets.ndim != 2 or targets.shape[0] != t:
        raise ValueError(
            f"targets must have shape ({t}, n_out) — one row per state "
            f"sample — or ({t},) for a single output; got "
            f"{tuple(targets.shape)} against states {tuple(states.shape)}."
        )
    if not 0.0 < float(lam) <= 1.0:
        raise ValueError(f"lam (forgetting factor) must be in (0, 1]; got {lam}")
    if block < 1:
        raise ValueError(f"block must be an int >= 1; got {block}")
    dtype = states.dtype
    n_state = states.shape[1] + 1
    n_out = targets.shape[1]
    xb = jnp.concatenate([states, jnp.ones((t, 1), dtype)], axis=1)  # (T, S)
    y = targets.astype(dtype)
    mask = jnp.arange(t) >= washout
    p0, w_init = krls.rls_init(1, n_state, n_out, reg, dtype)
    if w0 is not None:
        w_init = jnp.asarray(w0, dtype).reshape(1, n_state, n_out)
    lam_c = float(lam)  # static, like the streaming workers (kernels/rls.py)

    # every block size — including 1 — goes through rls_chunk, because the
    # serving engine does too (tick_chunk's learn tail is rls_chunk at any
    # chunk_ticks): the oracle must run the IDENTICAL op sequence or the
    # bit-match contract would silently fail at chunk_ticks == 1.
    # Pad the tail to a whole block with masked rows (exactly-zero
    # contributions, like a served session's trailing masked chunk rows).
    pad = (-t) % block
    if pad:
        xb = jnp.concatenate([xb, jnp.zeros((pad, n_state), dtype)])
        y = jnp.concatenate([y, jnp.zeros((pad, n_out), dtype)])
        mask = jnp.concatenate([mask, jnp.zeros(pad, bool)])
    nb = xb.shape[0] // block

    def blk(carry, rows):
        p, w = carry
        x_r, y_r, m_r = rows  # (block, S), (block, n_out), (block,)
        p, w, preds = krls.rls_chunk(
            p, w, x_r[:, None, :], y_r[:, None, :], m_r[:, None], lam_c
        )
        return (p, w), preds[:, 0]

    (_, w_fin), _ = jax.lax.scan(
        blk,
        (p0, w_init),
        (
            xb.reshape(nb, block, n_state),
            y.reshape(nb, block, n_out),
            mask.reshape(nb, block),
        ),
    )
    return Readout(w_out=w_fin[0], washout=washout)


def fit_lms(
    states: jnp.ndarray,  # (T, N)
    targets: jnp.ndarray,  # (T, n_out) or (T,)
    washout: int = 0,
    mu: float = 0.5,
    w0: Optional[jnp.ndarray] = None,  # (N + 1, n_out) warm start
) -> Readout:
    """Normalized-LMS readout — the offline oracle for streaming online
    learning with `ExecPlan.learn="lms"`.

    Processes the state rows sequentially with the same update kernel the
    serving engine fuses into `CompiledSim.tick_chunk`
    (kernels/rls.py::lms_chunk) at batch width 1: weights start at w0
    (zeros by default) and the first `washout` rows are masked (exactly-
    zero steps), mirroring a streaming session's `learn_washout` ticks.

    Unlike `fit_rls` there is no `block` parameter: the LMS recursion has
    no cross-tick P block, so chunked application at ANY chunk_ticks runs
    the identical per-tick op sequence — fed a session's HARVESTED states
    (`SessionResult.states`), this reproduces the session's learned
    readout bit-for-bit on the scan backend regardless of the engine's
    chunk size (the update kernel is reduction-order stable across batch
    widths; see kernels/rls.py).

    LMS is a stochastic-gradient approximation: it converges toward the
    ridge solution but does not equal it in finite samples — use it where
    the O(S) per-tick cost matters (large S, or many `repro.tune`
    candidates), and RLS/ridge where exactness does.
    """
    from repro.kernels import rls as krls

    states = jnp.asarray(states)
    targets = jnp.asarray(targets)
    t = states.shape[0]
    if targets.ndim == 1:
        targets = targets[:, None]
    if targets.ndim != 2 or targets.shape[0] != t:
        raise ValueError(
            f"targets must have shape ({t}, n_out) — one row per state "
            f"sample — or ({t},) for a single output; got "
            f"{tuple(targets.shape)} against states {tuple(states.shape)}."
        )
    if not 0.0 < float(mu) < 2.0:
        raise ValueError(f"mu (NLMS step size) must be in (0, 2); got {mu}")
    dtype = states.dtype
    n_state = states.shape[1] + 1
    n_out = targets.shape[1]
    xb = jnp.concatenate([states, jnp.ones((t, 1), dtype)], axis=1)  # (T, S)
    y = targets.astype(dtype)
    mask = jnp.arange(t) >= washout
    w_init = krls.lms_init(1, n_state, n_out, dtype)
    if w0 is not None:
        w_init = jnp.asarray(w0, dtype).reshape(1, n_state, n_out)
    w_fin, _ = krls.lms_chunk(
        w_init, xb[:, None, :], y[:, None, :], mask[:, None], float(mu)
    )
    return Readout(w_out=w_fin[0], washout=washout)


def predict(readout: Readout, states: jnp.ndarray) -> jnp.ndarray:
    x = states[readout.washout :]
    ones = jnp.ones((x.shape[0], 1), dtype=x.dtype)
    xb = jnp.concatenate([x, ones], axis=1).astype(readout.w_out.dtype)
    return xb @ readout.w_out


def nmse(pred: jnp.ndarray, target: jnp.ndarray) -> float:
    target = jnp.reshape(target, pred.shape).astype(pred.dtype)
    num = jnp.mean((pred - target) ** 2)
    den = jnp.var(target) + 1e-30
    return float(num / den)
