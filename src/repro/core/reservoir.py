"""Reservoir-computing API on top of the coupled-STO integrator.

Pipeline (the paper's application context, [AKT+22]):
  input series u(t)  --drive-->  node states x_t = m^x(t_k)  --ridge-->  readout

Only the readout is trained (linear ridge regression), which is what makes
reservoir computing cheap; the expensive part — and the paper's subject — is
the simulation of the reservoir itself, `drive()`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants, coupling, integrators, sto
from repro.core.constants import STOParams


class Reservoir(NamedTuple):
    params: STOParams
    w_cp: jnp.ndarray  # (N, N)
    w_in: jnp.ndarray  # (N, N_in)
    m0: jnp.ndarray  # (N, 3)
    dt: float
    hold_steps: int  # integration steps per input sample


def make_reservoir(
    n: int,
    n_in: int = 1,
    seed: int = 0,
    dt: float = constants.DT,
    hold_steps: int = 100,
    dtype=jnp.float32,
    params: Optional[STOParams] = None,
) -> Reservoir:
    if params is None:
        params = constants.default_params(dtype)
    w_cp = jnp.asarray(coupling.make_coupling_matrix(n, seed=seed), dtype=dtype)
    w_in = jnp.asarray(coupling.make_input_matrix(n, n_in, seed=seed + 1), dtype=dtype)
    m0 = constants.initial_magnetization(n, dtype=dtype)
    return Reservoir(params, w_cp, w_in, m0, dt, hold_steps)


@functools.partial(jax.jit, static_argnames=("hold_steps", "tableau_name"))
def _drive_scan(
    params: STOParams,
    w_cp: jnp.ndarray,
    w_in: jnp.ndarray,
    m0: jnp.ndarray,
    u_seq: jnp.ndarray,  # (T, N_in)
    dt,
    hold_steps: int,
    tableau_name: str = "rk4",
):
    tableau = integrators.TABLEAUX[tableau_name]

    def field(m, h_in_x):
        return sto.llg_field(m, params, w_cp, h_in_x)

    step = integrators.make_step(field, tableau)
    dt = jnp.asarray(dt, dtype=m0.dtype)

    def per_sample(m, u_t):
        # Input held piecewise-constant over the hold window (paper: the
        # input signal is a discrete-point series).
        h_in_x = params.a_in * (w_in @ u_t)  # (N,)

        def inner(mi, _):
            return step(mi, dt, h_in_x), None

        m, _ = jax.lax.scan(inner, m, None, length=hold_steps)
        return m, m[..., 0]  # node states: x-components (paper §3.1)

    mT, states = jax.lax.scan(per_sample, m0, u_seq)
    return mT, states  # states: (T, N)


def coerce_input_series(u_seq: jnp.ndarray, n_in: int, dtype) -> jnp.ndarray:
    """Validate an input series against the explicit (T, N_in) contract.

    Accepts (T, N_in), or 1-D (T,) when n_in == 1. Anything else — including
    the previously silently-transposed (1, T) — raises with the expected
    shape spelled out. Shared by `drive` and the serving engine so both
    enforce the same contract.
    """
    u_seq = jnp.asarray(u_seq, dtype=dtype)
    if u_seq.ndim == 1:
        if n_in != 1:
            raise ValueError(
                f"1-D input series is only valid for n_in == 1; this "
                f"reservoir has n_in == {n_in}. Pass shape (T, {n_in})."
            )
        return u_seq[:, None]
    if u_seq.ndim != 2 or u_seq.shape[1] != n_in:
        raise ValueError(
            f"input series must have shape (T, {n_in}) — one row per sample, "
            f"one column per input channel — or (T,) when n_in == 1; got "
            f"{u_seq.shape}. A (1, T) series must be passed as (T, 1)."
        )
    return u_seq


def drive(
    res: Reservoir,
    u_seq: jnp.ndarray,
    m0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the reservoir over an input series. Returns (final m, states (T,N)).

    u_seq follows the explicit (T, N_in) contract ((T,) allowed for
    n_in == 1). m0 optionally resumes integration from an arbitrary (N, 3)
    magnetization state — e.g. the streamed state of a paused serving
    session — instead of the reservoir's canonical initial state; driving in
    chunks with the carried-over final state is exactly equivalent to one
    long drive.
    """
    u_seq = coerce_input_series(u_seq, res.w_in.shape[1], res.m0.dtype)
    m_start = res.m0 if m0 is None else jnp.asarray(m0, dtype=res.m0.dtype)
    if m_start.shape != res.m0.shape:
        raise ValueError(
            f"m0 must have shape {tuple(res.m0.shape)}; got {tuple(m_start.shape)}"
        )
    return _drive_scan(
        res.params, res.w_cp, res.w_in, m_start, u_seq, res.dt, res.hold_steps
    )


class Readout(NamedTuple):
    w_out: jnp.ndarray  # (N + 1, n_out) — last row is the bias
    washout: int


def fit_ridge(
    states: jnp.ndarray,  # (T, N)
    targets: jnp.ndarray,  # (T, n_out) or (T,)
    washout: int = 0,
    reg: float = 1e-6,
) -> Readout:
    """Ridge regression readout: solve (X^T X + reg I) W = X^T Y.

    The Gram matrix is accumulated in f32/f64 regardless of state dtype; the
    solve is tiny ((N+1)^2) next to the simulation cost.
    """
    targets = jnp.atleast_2d(jnp.asarray(targets))
    if targets.shape[0] == 1:
        targets = targets.T
    x = states[washout:]
    y = targets[washout:].astype(jnp.float64 if x.dtype == jnp.float64 else jnp.float32)
    x = x.astype(y.dtype)
    ones = jnp.ones((x.shape[0], 1), dtype=x.dtype)
    xb = jnp.concatenate([x, ones], axis=1)  # (T', N+1)
    gram = xb.T @ xb
    rhs = xb.T @ y
    w = jnp.linalg.solve(gram + reg * jnp.eye(gram.shape[0], dtype=gram.dtype), rhs)
    return Readout(w_out=w, washout=washout)


def predict(readout: Readout, states: jnp.ndarray) -> jnp.ndarray:
    x = states[readout.washout :]
    ones = jnp.ones((x.shape[0], 1), dtype=x.dtype)
    xb = jnp.concatenate([x, ones], axis=1).astype(readout.w_out.dtype)
    return xb @ readout.w_out


def nmse(pred: jnp.ndarray, target: jnp.ndarray) -> float:
    target = jnp.reshape(target, pred.shape).astype(pred.dtype)
    num = jnp.mean((pred - target) ** 2)
    den = jnp.var(target) + 1e-30
    return float(num / den)
