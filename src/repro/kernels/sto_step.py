"""Pallas TPU kernels for the coupled-STO RK4 step.

Three kernels, specialized by regime — mirroring the paper's finding that
each implementation wins in a different range (Table 2):

1. `rk4_fused`  (small/medium N): the ENTIRE RK4 step — all four field
   evaluations, the coupling matmuls, and the combine — plus `n_inner`
   consecutive time steps run inside one kernel invocation. W^cp, the state
   and all stage slopes stay VMEM-resident; HBM sees one state read + one
   state write (+ one W read) per n_inner steps. Grid tiles only the
   ensemble axis E. This is the TPU answer to the paper's observation that
   per-step dispatch dominates at small N.

2. `field_tiled` (large N): one field evaluation, tiled over (N-rows, E).
   Each row tile contracts its W^cp row block against the full m^x plane
   (the O(N^2) coupling) on the MXU and fuses all elementwise LLG terms in
   the same kernel. The RK4 driver in ops.py calls it four times per step;
   stage algebra y = m + c*k is fused into the kernel (classic RK4 has a
   single-predecessor tableau), so HBM traffic per stage is W-row-tile +
   3 state planes instead of ~13 op-by-op round trips.

3. `rk4_chunk` (chunked serving): the ENTIRE K-tick serving chunk — K
   input ticks x hold_steps x 4 RK4 stages — in one kernel invocation.
   Where `rk4_fused` is re-launched per tick (re-reading W from HBM each
   launch), `rk4_chunk` keeps W and the state planes VMEM-resident across
   the whole chunk: HBM sees one W read + one state read/write + the
   (K, N, be) input and states blocks per chunk per ensemble tile. Per-tick
   lane masks ride in as an f32 0/1 plane so mid-chunk admit/retire works
   inside the kernel.

Reduced-precision coupling (ExecPlan.precision): every kernel accepts a W
operand whose dtype differs from the state's (cast ONCE by ops.py, not per
stage); the coupling dot then consumes reduced operands (bf16 x bf16 ->
f32 is MXU-native) while all elementwise math and the state carry stay in
the state dtype.

Layouts (see kernels/ref.py): m (3, N, E); W (N, N); params (NP, E).
MXU alignment: E and N tiles are multiples of 128 (f32); callers pad via
ops.py (zero-padding is algebraically inert for both N and E axes: padded
W rows/cols are zero and padded lanes are dropped on unpad).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import NP

# MXU/VREG-aligned tile sizes (f32).
LANE = 128
SUBLANE = 8


def _field_planes(mx, my, mz, hx, p):
    """Elementwise LLG slope given the coupling/input x-field hx.

    All inputs (bn, be); p is a dict of (1, be) parameter rows. Returns
    (kx, ky, kz). Pure VPU work; the MXU part (hx) is computed by callers.
    """
    hz = p["happl"] + p["demag"] * mz
    mdotp = p["px"] * mx + p["py"] * my + p["pz"] * mz
    hs = p["hs_coef"] / (1.0 + p["lam"] * mdotp)
    bx = hx + hs * (p["py"] * mz - p["pz"] * my)
    by = hs * (p["pz"] * mx - p["px"] * mz)
    bz = hz + hs * (p["px"] * my - p["py"] * mx)
    cx = my * bz - mz * by
    cy = mz * bx - mx * bz
    cz = mx * by - my * bx
    dx = my * cz - mz * cy
    dy = mz * cx - mx * cz
    dz = mx * cy - my * cx
    napref = -p["pref"]
    al = p["alpha"]
    kx = napref * (cx + al * dx)
    ky = napref * (cy + al * dy)
    kz = napref * (cz + al * dz)
    return kx, ky, kz


def _unpack_rows(params_ref):
    from repro.kernels.ref import PARAM_LAYOUT

    return {name: params_ref[i : i + 1, :] for i, name in enumerate(PARAM_LAYOUT)}


# ---------------------------------------------------------------------------
# Kernel 1: fully fused RK4 (+ multi-step), W and state VMEM-resident
# ---------------------------------------------------------------------------


def _rk4_fused_kernel(params_ref, w_ref, h_ref, m_ref, out_ref, *, dt, n_inner):
    p = _unpack_rows(params_ref)
    w = w_ref[...]  # (N, N) stays in VMEM across inner steps
    h_in = h_ref[...]  # (N, be) input-drive x-field, constant over the window
    acc_t = jnp.float32 if m_ref.dtype == jnp.bfloat16 else m_ref.dtype

    def field(mx, my, mz):
        # reduced-precision coupling (ExecPlan.precision): callers pass W
        # pre-cast (e.g. bf16); the dot consumes the reduced operands and
        # accumulates in the state dtype (MXU-native bf16 x bf16 -> f32)
        mx_cp = mx if w.dtype == m_ref.dtype else mx.astype(w.dtype)
        hx = p["a_cp"] * jnp.dot(w, mx_cp, preferred_element_type=acc_t) + h_in
        return _field_planes(mx, my, mz, hx, p)

    def one_step(state):
        mx, my, mz = state
        h = dt / 2.0
        k1x, k1y, k1z = field(mx, my, mz)
        k2x, k2y, k2z = field(mx + h * k1x, my + h * k1y, mz + h * k1z)
        k3x, k3y, k3z = field(mx + h * k2x, my + h * k2y, mz + h * k2z)
        k4x, k4y, k4z = field(mx + dt * k3x, my + dt * k3y, mz + dt * k3z)
        s = dt / 6.0
        return (
            mx + s * (k1x + 2 * k2x + 2 * k3x + k4x),
            my + s * (k1y + 2 * k2y + 2 * k3y + k4y),
            mz + s * (k1z + 2 * k2z + 2 * k3z + k4z),
        )

    state = (m_ref[0], m_ref[1], m_ref[2])
    state = jax.lax.fori_loop(0, n_inner, lambda _, s: one_step(s), state)
    out_ref[0] = state[0]
    out_ref[1] = state[1]
    out_ref[2] = state[2]


def rk4_fused(
    m: jnp.ndarray,  # (3, N, E), N and E already padded/aligned
    w_cp: jnp.ndarray,  # (N, N)
    params: jnp.ndarray,  # (NP, E)
    dt: float,
    n_inner: int = 1,
    block_e: int = LANE,
    h_in: jnp.ndarray = None,  # (N, E) input-drive x-field; None = undriven
    interpret: bool = False,
) -> jnp.ndarray:
    _, n, e = m.shape
    assert e % block_e == 0, (e, block_e)
    if h_in is None:
        h_in = jnp.zeros((n, e), m.dtype)
    grid = (e // block_e,)
    # dt is a static compile-time constant (the paper fixes dt = 1e-11).
    kernel = functools.partial(_rk4_fused_kernel, dt=float(dt), n_inner=n_inner)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((NP, block_e), lambda i: (0, i)),  # params
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # W resident
            pl.BlockSpec((n, block_e), lambda i: (0, i)),  # input drive
            pl.BlockSpec((3, n, block_e), lambda i: (0, 0, i)),  # m
        ],
        out_specs=pl.BlockSpec((3, n, block_e), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        interpret=interpret,
    )(params, w_cp, h_in, m)


# ---------------------------------------------------------------------------
# Kernel 2: tiled field evaluation (+ fused stage algebra) for large N
# ---------------------------------------------------------------------------


def _field_tiled_kernel(
    params_ref, w_ref, h_ref, yx_ref, m_ref, kprev_ref, out_ref, *, stage_coef
):
    """k_new = f(m + stage_coef * k_prev) for one (N-row, E) tile.

    yx_ref holds the FULL x-plane of the stage state y (all N rows — the
    coupling needs every oscillator), computed cheaply by the caller;
    m_ref/kprev_ref hold this tile's rows of the base state and previous
    slope; h_ref this tile's rows of the input-drive x-field.
    stage_coef = 0 skips the y-algebra (k1).
    """
    p = _unpack_rows(params_ref)
    acc_t = jnp.float32 if m_ref.dtype == jnp.bfloat16 else m_ref.dtype
    # MXU: this row-block of W against the full y-x-plane. For reduced-
    # precision coupling the caller passes W pre-cast; the stage plane is
    # cast to match and the dot accumulates in the state dtype.
    yx = yx_ref[...]
    if w_ref.dtype != m_ref.dtype:
        yx = yx.astype(w_ref.dtype)
    hx = (
        p["a_cp"] * jnp.dot(w_ref[...], yx, preferred_element_type=acc_t)
        + h_ref[...]
    )
    if stage_coef == 0.0:
        yx, yy, yz = m_ref[0], m_ref[1], m_ref[2]
    else:
        yx = m_ref[0] + stage_coef * kprev_ref[0]
        yy = m_ref[1] + stage_coef * kprev_ref[1]
        yz = m_ref[2] + stage_coef * kprev_ref[2]
    kx, ky, kz = _field_planes(yx, yy, yz, hx, p)
    out_ref[0] = kx
    out_ref[1] = ky
    out_ref[2] = kz


def field_tiled(
    m: jnp.ndarray,  # (3, N, E) base state tile source
    yx_full: jnp.ndarray,  # (N, E) x-plane of the stage state y
    k_prev: jnp.ndarray,  # (3, N, E) previous slope (ignored when coef=0)
    w_cp: jnp.ndarray,  # (N, N)
    params: jnp.ndarray,  # (NP, E)
    stage_coef: float,
    block_n: int = LANE,
    block_e: int = LANE,
    h_in: jnp.ndarray = None,  # (N, E) input-drive x-field; None = undriven
    interpret: bool = False,
) -> jnp.ndarray:
    _, n, e = m.shape
    assert n % block_n == 0 and e % block_e == 0, (n, e, block_n, block_e)
    if h_in is None:
        h_in = jnp.zeros((n, e), m.dtype)
    grid = (n // block_n, e // block_e)
    kernel = functools.partial(_field_tiled_kernel, stage_coef=stage_coef)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((NP, block_e), lambda i, j: (0, j)),
            pl.BlockSpec((block_n, n), lambda i, j: (i, 0)),  # W row block
            pl.BlockSpec((block_n, block_e), lambda i, j: (i, j)),  # input drive
            pl.BlockSpec((n, block_e), lambda i, j: (0, j)),  # full y-x plane
            pl.BlockSpec((3, block_n, block_e), lambda i, j: (0, i, j)),
            pl.BlockSpec((3, block_n, block_e), lambda i, j: (0, i, j)),
        ],
        out_specs=pl.BlockSpec((3, block_n, block_e), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct(m.shape, m.dtype),
        interpret=interpret,
    )(params, w_cp, h_in, yx_full, m, k_prev)


def _rk4_chunk_kernel(
    params_ref, w_ref, h_ref, mask_ref, m_ref, out_ref, states_ref,
    *, dt, hold_steps, k_ticks,
):
    """K serving ticks (K hold windows) for one ensemble tile, W resident.

    h_ref: (K, N, be) per-tick input-drive x-fields; mask_ref: (K, 1, be)
    f32 0/1 lane masks (False/0 = lane frozen that tick — comes back
    bit-identical, so mid-chunk admit/retire works without leaving the
    kernel); states_ref: (K, N, be) per-tick x-plane outputs (the serving
    engine's states block).
    """
    p = _unpack_rows(params_ref)
    w = w_ref[...]  # (N, N): ONE HBM->VMEM read for the whole chunk
    acc_t = jnp.float32 if m_ref.dtype == jnp.bfloat16 else m_ref.dtype

    def field(mx, my, mz, h_in):
        mx_cp = mx if w.dtype == m_ref.dtype else mx.astype(w.dtype)
        hx = p["a_cp"] * jnp.dot(w, mx_cp, preferred_element_type=acc_t) + h_in
        return _field_planes(mx, my, mz, hx, p)

    def one_step(state, h_in):
        mx, my, mz = state
        h = dt / 2.0
        k1x, k1y, k1z = field(mx, my, mz, h_in)
        k2x, k2y, k2z = field(mx + h * k1x, my + h * k1y, mz + h * k1z, h_in)
        k3x, k3y, k3z = field(mx + h * k2x, my + h * k2y, mz + h * k2z, h_in)
        k4x, k4y, k4z = field(mx + dt * k3x, my + dt * k3y, mz + dt * k3z, h_in)
        s = dt / 6.0
        return (
            mx + s * (k1x + 2 * k2x + 2 * k3x + k4x),
            my + s * (k1y + 2 * k2y + 2 * k3y + k4y),
            mz + s * (k1z + 2 * k2z + 2 * k3z + k4z),
        )

    state = (m_ref[0], m_ref[1], m_ref[2])
    for t in range(k_ticks):  # K is small and static: unrolled over ticks
        h_in = h_ref[t]
        new = jax.lax.fori_loop(
            0, hold_steps, lambda _, s: one_step(s, h_in), state
        )
        keep = mask_ref[t] > 0.5  # (1, be) broadcasts over (N, be)
        state = tuple(jnp.where(keep, n_, o_) for n_, o_ in zip(new, state))
        states_ref[t] = state[0]
    out_ref[0] = state[0]
    out_ref[1] = state[1]
    out_ref[2] = state[2]


def rk4_chunk(
    m: jnp.ndarray,  # (3, N, E), N and E already padded/aligned
    w_cp: jnp.ndarray,  # (N, N); may be pre-cast (reduced-precision coupling)
    params: jnp.ndarray,  # (NP, E)
    dt: float,
    hold_steps: int,
    h_block: jnp.ndarray,  # (K, N, E) per-tick input-drive x-fields
    mask_block: jnp.ndarray,  # (K, E) f32 0/1 per-tick lane masks
    block_e: int = LANE,
    interpret: bool = False,
):
    """The chunk-resident serving kernel: K ticks x hold_steps x 4 stages
    in one launch, W and state planes VMEM-resident for the whole chunk.

    Returns (m' (3, N, E), states (K, N, E) per-tick x-planes).
    """
    _, n, e = m.shape
    k_ticks = h_block.shape[0]
    assert e % block_e == 0, (e, block_e)
    assert h_block.shape == (k_ticks, n, e), (h_block.shape, (k_ticks, n, e))
    grid = (e // block_e,)
    kernel = functools.partial(
        _rk4_chunk_kernel,
        dt=float(dt), hold_steps=hold_steps, k_ticks=k_ticks,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((NP, block_e), lambda i: (0, i)),  # params
            pl.BlockSpec((n, n), lambda i: (0, 0)),  # W resident per chunk
            pl.BlockSpec((k_ticks, n, block_e), lambda i: (0, 0, i)),  # inputs
            pl.BlockSpec((k_ticks, 1, block_e), lambda i: (0, 0, i)),  # masks
            pl.BlockSpec((3, n, block_e), lambda i: (0, 0, i)),  # m
        ],
        out_specs=[
            pl.BlockSpec((3, n, block_e), lambda i: (0, 0, i)),
            pl.BlockSpec((k_ticks, n, block_e), lambda i: (0, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(m.shape, m.dtype),
            jax.ShapeDtypeStruct((k_ticks, n, e), m.dtype),
        ],
        interpret=interpret,
    )(params, w_cp, h_block, mask_block.reshape(k_ticks, 1, e), m)


def rk4_tiled_step(
    m: jnp.ndarray,
    w_cp: jnp.ndarray,
    params: jnp.ndarray,
    dt: float,
    block_n: int = LANE,
    block_e: int = LANE,
    h_in: jnp.ndarray = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """One RK4 step built from four tiled field-kernel launches.

    The per-stage x-plane updates (y^x = m^x + c k^x) are O(N E) elementwise
    XLA ops — negligible next to the O(N^2 E) in-kernel coupling.
    """
    dt = float(dt)  # static: baked into the stage kernels
    f = functools.partial(
        field_tiled,
        w_cp=w_cp,
        params=params,
        block_n=block_n,
        block_e=block_e,
        h_in=h_in,
        interpret=interpret,
    )
    zeros = jnp.zeros_like(m)
    k1 = f(m, m[0], zeros, stage_coef=0.0)
    k2 = f(m, m[0] + (0.5 * dt) * k1[0], k1, stage_coef=0.5 * dt)
    k3 = f(m, m[0] + (0.5 * dt) * k2[0], k2, stage_coef=0.5 * dt)
    k4 = f(m, m[0] + dt * k3[0], k3, stage_coef=dt)
    return m + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)
