"""Flash-attention (forward) Pallas TPU kernel.

Used by the LM substrate for the 32k prefill path, where materializing
(S x S) logits is the memory-roofline killer. Online-softmax streaming over
KV tiles; causal and sliding-window masking; GQA-aware: the kv-head group
dimension G rides inside the q tile, so K/V are NOT repeated in HBM (the
usual GQA bandwidth win: K/V read once per kv head, not once per q head).

Layouts (ops.py wrappers reshape from user (B, H, S, D)):
    q: (BKV, G, Sq, D)   BKV = batch * kv_heads, G = q_heads / kv_heads
    k: (BKV, Sk, D)
    v: (BKV, Sk, D)
Grid: (BKV, Sq/block_q, Sk/block_k) — KV tiles iterate fastest (minor), so
the running max / denominator / accumulator scratch persists per q tile.

On this CPU-only container the kernel is validated with interpret=True
against kernels/ref.mha_reference; the XLA path (models/attention.py) is
what the dry-run lowers, with a config switch to the kernel on real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = float("-inf")


def _flash_kernel(
    q_ref,  # (1, G, bq, D)
    k_ref,  # (1, bk, D)
    v_ref,  # (1, bk, D)
    o_ref,  # (1, G, bq, D)
    m_scr,  # (G * bq, 1) f32
    l_scr,  # (G * bq, 1) f32
    acc_scr,  # (G * bq, D) f32
    *,
    scale: float,
    causal: bool,
    window: int,
    block_q: int,
    block_k: int,
    seq_q: int,
    seq_k: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    g = q_ref.shape[1]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Tile-level mask pruning: global positions (q offset aligns the last q
    # row with the last k row, the decode/prefill-with-cache convention).
    offset = seq_k - seq_q
    q_lo = qi * block_q + offset  # smallest global q position in tile
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    k_hi = k_lo + block_k - 1

    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_lo <= q_hi)
    if window > 0:
        run = jnp.logical_and(run, k_hi > q_lo - window)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale  # (G, bq, D)
        q2 = q.reshape(g * block_q, q.shape[-1])
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(
            q2, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (G*bq, bk)

        # Static decision: interior-tile mask skipping is a later perf
        # refinement; masked tiles are already pruned by `run` above.
        need_mask = causal or window > 0
        if need_mask:
            qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
            if causal:
                mask = jnp.logical_and(mask, kpos <= qpos)
            if window > 0:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            mask = jnp.broadcast_to(mask[None], (g, block_q, block_k)).reshape(
                g * block_q, block_k
            )
            s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (G*bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # exp(-inf - -inf) guard: rows with everything masked keep m=-inf
        p = jnp.exp(s - m_new)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        corr = jnp.where(jnp.isfinite(m_prev), corr, 0.0)
        l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)  # (bk, D)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = corr * acc_scr[...] + pv
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        out = (acc_scr[...] / l).reshape(g, block_q, acc_scr.shape[-1])
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_grouped(
    q: jnp.ndarray,  # (BKV, G, Sq, D)
    k: jnp.ndarray,  # (BKV, Sk, D)
    v: jnp.ndarray,  # (BKV, Sk, D)
    *,
    causal: bool = True,
    window: int = 0,
    scale=None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    bkv, g, sq, d = q.shape
    _, sk, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    if scale is None:
        scale = d**-0.5

    grid = (bkv, sq // block_q, sk // block_k)
    kernel = functools.partial(
        _flash_kernel,
        scale=float(scale),
        causal=causal,
        window=int(window),
        block_q=block_q,
        block_k=block_k,
        seq_q=sq,
        seq_k=sk,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, block_q, d), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, block_q, d), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, 1), jnp.float32),
            pltpu.VMEM((g * block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, KVH, Sk, D)
    v: jnp.ndarray,  # (B, KVH, Sk, D)
    **kw,
) -> jnp.ndarray:
    """User-layout wrapper: folds GQA groups into the q tile."""
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    qg = q.reshape(b, kvh, g, sq, d).reshape(b * kvh, g, sq, d)
    kg = k.reshape(b * kvh, sk, d)
    vg = v.reshape(b * kvh, sk, d)
    out = flash_attention_grouped(qg, kg, vg, **kw)
    return out.reshape(b, kvh, g, sq, d).reshape(b, h, sq, d)
