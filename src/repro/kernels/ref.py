"""Pure-jnp oracles for every kernel in this package.

Kernel-side state layout (TPU-friendly):
    m        : (3, N, E)  — component-major so each component is a (N, E)
                            VREG-tileable plane; E is the MXU lane dimension.
    w_cp     : (N, N)
    params   : (NP, E)    — per-ensemble-member scalar parameters, VMEM-
                            resident (enables parameter sweeps inside the
                            kernel without re-compilation).

PARAM_LAYOUT defines the packing order shared by kernels and oracles.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.constants import STOParams

PARAM_LAYOUT: Tuple[str, ...] = (
    "pref",  # gamma / (1 + alpha^2)
    "alpha",
    "hs_coef",  # H_s numerator [Oe]
    "lam",
    "happl",
    "demag",  # Hk - 4 pi Ms
    "a_cp",
    "px",
    "py",
    "pz",
)
NP = len(PARAM_LAYOUT)


def pack_params(params: STOParams, e: int, dtype=jnp.float32) -> jnp.ndarray:
    """Pack STOParams into the kernel's (NP, E) layout.

    Accepts scalar leaves or (E, 1)-ensemble leaves (from
    `ensemble.broadcast_params`).
    """
    vals = {
        "pref": params.llg_prefactor,
        "alpha": params.alpha,
        "hs_coef": params.hs_coef,
        "lam": params.lam,
        "happl": params.happl,
        "demag": params.demag_field,
        "a_cp": params.a_cp,
        "px": params.px,
        "py": params.py,
        "pz": params.pz,
    }
    rows = []
    for name in PARAM_LAYOUT:
        v = jnp.asarray(vals[name], dtype=dtype).reshape(-1)  # () or (E,)
        rows.append(jnp.broadcast_to(v, (e,)))
    return jnp.stack(rows, axis=0)


def _unpack(pvec: jnp.ndarray):
    """(NP, E) -> dict of (E,) rows (or (NP,) -> scalars)."""
    return {name: pvec[i] for i, name in enumerate(PARAM_LAYOUT)}


def llg_field_planes(m, w_cp, pvec, h_in=None):
    """Oracle vector field in kernel layout.

    m: (3, N, E); w_cp: (N, N); pvec: (NP, E). Returns k: (3, N, E).
    h_in: optional (N, E) input-drive x-field A_in (W^in u), added to the
    coupling field (input is held piecewise-constant over a hold window, so
    it enters the field as a constant plane).
    This is algebraically identical to core.sto.llg_field — the equivalence
    is itself asserted by tests/test_kernels_sto.py.

    Precision policy (ExecPlan.precision): callers opt into the reduced-
    precision coupling GEMM by passing w_cp ALREADY cast (e.g. bf16, cast
    once outside the integration loop, not per stage). A w_cp dtype that
    differs from the state dtype makes the coupling dot consume reduced
    operands while accumulating in the state dtype; everything else — the
    elementwise LLG math, the state carry, the RK4 combine — stays in the
    state dtype. When dtypes match (the default), this path is untouched
    and bit-exact.
    """
    p = _unpack(pvec)
    mx, my, mz = m[0], m[1], m[2]  # (N, E)
    # coupling: rows of W against the x-plane -> (N, E) matmul on the MXU
    mx_cp = mx if w_cp.dtype == m.dtype else mx.astype(w_cp.dtype)
    hx = p["a_cp"] * jnp.dot(w_cp, mx_cp, preferred_element_type=m.dtype)
    if h_in is not None:
        hx = hx + h_in
    hz = p["happl"] + p["demag"] * mz
    mdotp = p["px"] * mx + p["py"] * my + p["pz"] * mz
    hs = p["hs_coef"] / (1.0 + p["lam"] * mdotp)
    # b = H + hs * (p x m)
    bx = hx + hs * (p["py"] * mz - p["pz"] * my)
    by = hs * (p["pz"] * mx - p["px"] * mz)
    bz = hz + hs * (p["px"] * my - p["py"] * mx)
    # m x b
    cx = my * bz - mz * by
    cy = mz * bx - mx * bz
    cz = mx * by - my * bx
    # m x (m x b)
    dx = my * cz - mz * cy
    dy = mz * cx - mx * cz
    dz = mx * cy - my * cx
    pref = p["pref"]
    al = p["alpha"]
    kx = -pref * cx - al * pref * dx
    ky = -pref * cy - al * pref * dy
    kz = -pref * cz - al * pref * dz
    return jnp.stack([kx, ky, kz], axis=0)


def rk4_step_planes(m, w_cp, pvec, dt, h_in=None):
    """One classical RK4 step in kernel layout (oracle)."""
    k1 = llg_field_planes(m, w_cp, pvec, h_in)
    k2 = llg_field_planes(m + 0.5 * dt * k1, w_cp, pvec, h_in)
    k3 = llg_field_planes(m + 0.5 * dt * k2, w_cp, pvec, h_in)
    k4 = llg_field_planes(m + dt * k3, w_cp, pvec, h_in)
    return m + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)


def rk4_multi_step_planes(m, w_cp, pvec, dt, n_inner: int, h_in=None):
    """n_inner fused RK4 steps (oracle for the VMEM-resident kernel)."""

    def body(_, mm):
        return rk4_step_planes(mm, w_cp, pvec, dt, h_in)

    return jax.lax.fori_loop(0, n_inner, body, m)


def rk4_chunk_planes(
    m,  # (3, N, E) state
    w_cp,  # (N, N) — pre-cast by the caller for reduced-precision coupling
    pvec,  # (NP, E)
    dt,
    hold_steps: int,
    h_block,  # (K, N, E) per-tick input-drive x-fields
    mask_block,  # (K, E) bool; False = lane frozen that tick
):
    """Chunk-resident K-tick integration: the oracle behind impl="chunk".

    The whole K-tick x hold_steps x 4-stage RK4 loop runs as ONE traced
    region: the per-tick input fields arrive as a precomputed (K, N, E)
    block (one input GEMM per chunk instead of one per tick), W is read by
    every stage from the same (optionally reduced-precision) operand cast
    exactly once by the caller, and the per-tick states block (K, N, E)
    stays device-side for the serving engine's bulk harvest. Per-element
    float op order matches the per-tick ref path (`rk4_step_planes` +
    masked where), so precision=None chunks agree with the ref impl to the
    bit on CPU. Returns (m' (3, N, E), states (K, N, E)).

    On TPU the same loop structure is a Pallas kernel
    (`kernels.sto_step.rk4_chunk`) that keeps the state planes VMEM-
    resident and reads W from HBM once per chunk per ensemble tile instead
    of once per tick.
    """
    dt_c = jnp.asarray(dt, m.dtype)

    def per_tick(mm, tick_in):
        h_t, mask_t = tick_in

        def inner(mi, _):
            return rk4_step_planes(mi, w_cp, pvec, dt_c, h_t), None

        m_new, _ = jax.lax.scan(inner, mm, None, length=hold_steps)
        m_new = jnp.where(mask_t[None, None, :], m_new, mm)
        return m_new, m_new[0]

    mT, states = jax.lax.scan(per_tick, m, (h_block, mask_block))
    return mT, states  # (3, N, E), (K, N, E)


# ---------------------------------------------------------------------------
# Physics families (SimSpec.topology) — planes-layout chunk bodies
# ---------------------------------------------------------------------------


def rk4_chunk_planes_window(
    m,  # (3, N, E) state
    w_cp,  # (N, N) — pre-cast by the caller for reduced-precision coupling
    pvec,  # (NP, E)
    dt,
    hold_steps: int,
    readout_window: int,
    h_block,  # (K, N, E) per-tick input-drive x-fields
    mask_block,  # (K, E) bool; False = lane frozen that tick
):
    """topology="array_transient" chunk body (Kanao et al., arXiv:1905.07937).

    Identical coupled-array dynamics to `rk4_chunk_planes`; only the
    emitted per-tick state differs — the mean of the m_x plane over the
    LAST `readout_window` RK substeps of the hold window (the transient the
    array readout samples), instead of the endpoint alone. The hold window
    is split (hold_steps - w) + w with the same per-step op sequence, so
    readout_window=1 is bit-identical to the coupled_array chunk body.
    Returns (m' (3, N, E), states (K, N, E)).
    """
    dt_c = jnp.asarray(dt, m.dtype)
    w = int(readout_window)

    def per_tick(mm, tick_in):
        h_t, mask_t = tick_in

        def inner(mi, _):
            return rk4_step_planes(mi, w_cp, pvec, dt_c, h_t), None

        m_mid = mm
        if hold_steps > w:
            m_mid, _ = jax.lax.scan(inner, mm, None, length=hold_steps - w)

        def tail(mi, _):
            mi2 = rk4_step_planes(mi, w_cp, pvec, dt_c, h_t)
            return mi2, mi2[0]

        m_new, xs = jax.lax.scan(tail, m_mid, None, length=w)  # xs (w, N, E)
        state = jnp.mean(xs, axis=0) if w > 1 else xs[0]
        m_new = jnp.where(mask_t[None, None, :], m_new, mm)
        state = jnp.where(mask_t[None, :], state, mm[0])
        return m_new, state

    mT, states = jax.lax.scan(per_tick, m, (h_block, mask_block))
    return mT, states  # (3, N, E), (K, N, E)


def tm_chunk_planes(
    m,  # (3, N, E) virtual-node snapshots; row N-1 carries the oscillator
    w_cp,  # (N, N) feedback mixing — pre-cast for reduced-precision coupling
    pvec,  # (NP, E)
    dt,
    hold_steps: int,
    h_block,  # (K, N, E) per-tick masked-input x-fields A_in (W^in u)
    mask_block,  # (K, E) bool; False = lane frozen that tick
):
    """topology="time_multiplexed" chunk body (Riou et al., arXiv:1904.11236).

    ONE physical oscillator per lane; N virtual nodes are its snapshots at
    the ends of consecutive hold windows. Per tick the total per-node drive
    is two GEMMs — the masked input field (precomputed h_block) plus the
    delayed feedback a_cp * (W^cp @ x_prev), where x_prev is the PREVIOUS
    tick's snapshot x-plane (w_cp=I is the classic delay-line
    self-feedback) — and then the INNER SCAN IS THE DELAY LINE: sequential
    over the N virtual nodes (each integrating the carried (3, E)
    oscillator state hold_steps RK substeps under its scalar-per-lane
    drive), trivially parallel across ensemble lanes. The reduced-precision
    coupling policy maps onto the feedback GEMM exactly as it maps onto the
    array coupling GEMM. Returns (m' (3, N, E), states (K, N, E)).
    """
    dt_c = jnp.asarray(dt, m.dtype)
    n = m.shape[1]
    p = _unpack(pvec)
    w_zero = jnp.zeros((1, 1), m.dtype)  # single oscillator: no array coupling

    def per_tick(mm, tick_in):
        h_ext_t, mask_t = tick_in
        x_prev = mm[0]  # (N, E) previous tick's snapshots
        x_cp = x_prev if w_cp.dtype == mm.dtype else x_prev.astype(w_cp.dtype)
        h_t = h_ext_t + p["a_cp"] * jnp.dot(
            w_cp, x_cp, preferred_element_type=mm.dtype
        )  # (N, E)
        s0 = mm[:, n - 1 : n, :]  # carried oscillator state (3, 1, E)

        def per_node(s, h_row):  # h_row (E,) — virtual node's drive
            h_j = h_row[None, :]  # (1, E)

            def inner(si, _):
                return rk4_step_planes(si, w_zero, pvec, dt_c, h_j), None

            s_new, _ = jax.lax.scan(inner, s, None, length=hold_steps)
            return s_new, s_new[:, 0, :]  # snapshot (3, E)

        sT, snaps = jax.lax.scan(per_node, s0, h_t)  # snaps (N, 3, E)
        m_new = jnp.transpose(snaps, (1, 0, 2))  # (3, N, E)
        m_new = jnp.where(mask_t[None, None, :], m_new, mm)
        return m_new, m_new[0]

    mT, states = jax.lax.scan(per_tick, m, (h_block, mask_block))
    return mT, states  # (3, N, E), (K, N, E)


# ---------------------------------------------------------------------------
# Flash-attention oracle (LM substrate)
# ---------------------------------------------------------------------------


def mha_reference(q, k, v, causal: bool = True, scale=None, window: int = 0):
    """Plain softmax attention. q,k,v: (B, H, S, D) -> (B, H, S, D).

    window > 0 restricts keys to [i - window + 1, i] (sliding-window attn).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    sq, sk = q.shape[-2], k.shape[-2]
    qi = jnp.arange(sq)[:, None] + (sk - sq)  # align last q with last k
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v).astype(q.dtype)
