"""Pure-jnp oracles for every kernel in this package.

Kernel-side state layout (TPU-friendly):
    m        : (3, N, E)  — component-major so each component is a (N, E)
                            VREG-tileable plane; E is the MXU lane dimension.
    w_cp     : (N, N)
    params   : (NP, E)    — per-ensemble-member scalar parameters, VMEM-
                            resident (enables parameter sweeps inside the
                            kernel without re-compilation).

PARAM_LAYOUT defines the packing order shared by kernels and oracles.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.constants import STOParams

PARAM_LAYOUT: Tuple[str, ...] = (
    "pref",  # gamma / (1 + alpha^2)
    "alpha",
    "hs_coef",  # H_s numerator [Oe]
    "lam",
    "happl",
    "demag",  # Hk - 4 pi Ms
    "a_cp",
    "px",
    "py",
    "pz",
)
NP = len(PARAM_LAYOUT)


def pack_params(params: STOParams, e: int, dtype=jnp.float32) -> jnp.ndarray:
    """Pack STOParams into the kernel's (NP, E) layout.

    Accepts scalar leaves or (E, 1)-ensemble leaves (from
    `ensemble.broadcast_params`).
    """
    vals = {
        "pref": params.llg_prefactor,
        "alpha": params.alpha,
        "hs_coef": params.hs_coef,
        "lam": params.lam,
        "happl": params.happl,
        "demag": params.demag_field,
        "a_cp": params.a_cp,
        "px": params.px,
        "py": params.py,
        "pz": params.pz,
    }
    rows = []
    for name in PARAM_LAYOUT:
        v = jnp.asarray(vals[name], dtype=dtype).reshape(-1)  # () or (E,)
        rows.append(jnp.broadcast_to(v, (e,)))
    return jnp.stack(rows, axis=0)


def _unpack(pvec: jnp.ndarray):
    """(NP, E) -> dict of (E,) rows (or (NP,) -> scalars)."""
    return {name: pvec[i] for i, name in enumerate(PARAM_LAYOUT)}


def llg_field_planes(m, w_cp, pvec, h_in=None):
    """Oracle vector field in kernel layout.

    m: (3, N, E); w_cp: (N, N); pvec: (NP, E). Returns k: (3, N, E).
    h_in: optional (N, E) input-drive x-field A_in (W^in u), added to the
    coupling field (input is held piecewise-constant over a hold window, so
    it enters the field as a constant plane).
    This is algebraically identical to core.sto.llg_field — the equivalence
    is itself asserted by tests/test_kernels_sto.py.
    """
    p = _unpack(pvec)
    mx, my, mz = m[0], m[1], m[2]  # (N, E)
    # coupling: rows of W against the x-plane -> (N, E) matmul on the MXU
    hx = p["a_cp"] * jnp.dot(w_cp, mx, preferred_element_type=m.dtype)
    if h_in is not None:
        hx = hx + h_in
    hz = p["happl"] + p["demag"] * mz
    mdotp = p["px"] * mx + p["py"] * my + p["pz"] * mz
    hs = p["hs_coef"] / (1.0 + p["lam"] * mdotp)
    # b = H + hs * (p x m)
    bx = hx + hs * (p["py"] * mz - p["pz"] * my)
    by = hs * (p["pz"] * mx - p["px"] * mz)
    bz = hz + hs * (p["px"] * my - p["py"] * mx)
    # m x b
    cx = my * bz - mz * by
    cy = mz * bx - mx * bz
    cz = mx * by - my * bx
    # m x (m x b)
    dx = my * cz - mz * cy
    dy = mz * cx - mx * cz
    dz = mx * cy - my * cx
    pref = p["pref"]
    al = p["alpha"]
    kx = -pref * cx - al * pref * dx
    ky = -pref * cy - al * pref * dy
    kz = -pref * cz - al * pref * dz
    return jnp.stack([kx, ky, kz], axis=0)


def rk4_step_planes(m, w_cp, pvec, dt, h_in=None):
    """One classical RK4 step in kernel layout (oracle)."""
    k1 = llg_field_planes(m, w_cp, pvec, h_in)
    k2 = llg_field_planes(m + 0.5 * dt * k1, w_cp, pvec, h_in)
    k3 = llg_field_planes(m + 0.5 * dt * k2, w_cp, pvec, h_in)
    k4 = llg_field_planes(m + dt * k3, w_cp, pvec, h_in)
    return m + (dt / 6.0) * (k1 + 2.0 * k2 + 2.0 * k3 + k4)


def rk4_multi_step_planes(m, w_cp, pvec, dt, n_inner: int, h_in=None):
    """n_inner fused RK4 steps (oracle for the VMEM-resident kernel)."""

    def body(_, mm):
        return rk4_step_planes(mm, w_cp, pvec, dt, h_in)

    return jax.lax.fori_loop(0, n_inner, body, m)


# ---------------------------------------------------------------------------
# Flash-attention oracle (LM substrate)
# ---------------------------------------------------------------------------


def mha_reference(q, k, v, causal: bool = True, scale=None, window: int = 0):
    """Plain softmax attention. q,k,v: (B, H, S, D) -> (B, H, S, D).

    window > 0 restricts keys to [i - window + 1, i] (sliding-window attn).
    """
    if scale is None:
        scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    sq, sk = q.shape[-2], k.shape[-2]
    qi = jnp.arange(sq)[:, None] + (sk - sq)  # align last q with last k
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= ki > qi - window
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v).astype(q.dtype)
