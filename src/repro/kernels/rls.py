"""Batched online readout updates: recursive least squares (RLS) and
normalized least mean squares (LMS).

The device-side learning rule behind `ExecPlan.learn="rls"`: every serving
tick, each ensemble lane e refines its readout weights W[e] against that
tick's target using the classic RLS recursion

    k   = P x / (lam + x^T P x)          gain        (E, S)
    e   = y - W^T x                      a-priori error
    W'  = W + k e^T                      weight update
    P'  = (P - k (P x)^T) / lam          inverse-Gram update

with x the (S,) = (N + 1,) feature vector (node states + bias), lam the
forgetting factor, and P initialized to I / reg. With lam == 1 the
recursion converges to exactly the regularized normal equations batch ridge
solves: after T updates W equals `fit_ridge(states, targets, reg=reg)` up
to float roundoff, so the streaming path has an offline oracle
(`core.reservoir.fit_rls`) it can be pinned against bit-for-bit.

Everything here is plain jnp on (E, ...)-batched operands, so the SAME
update fuses into every tick_chunk backend: the core-layout scan, the
planes-layout ref/fused/tiled paths (the integrate may be a Pallas kernel;
the update is an einsum around it), and the shard_map'd sharded path (P/W
ride lane-sharded, the feature vector is all-gathered like the coupling
field). The P' expression uses the k (P x)^T outer product — not k (x^T P)
— so P stays symmetric by construction instead of drifting.

Per-lane cost is O(S^2) per tick against the integrate's O(N^2 hold_steps),
so learning rides along at a bounded overhead (benchmarked as the learn-on
column of BENCH_serve.json).

Numerical note: the recursion runs in the reservoir's dtype (f32 for
serving). With lam == 1, P shrinks monotonically and f32 is stable for any
stream length. With aggressive forgetting (lam well below 1) over very
long streams, P's conditioning degrades in f32 — the classic RLS
round-off divergence — so keep lam close to 1 for long-lived f32 sessions
(e.g. 0.99+) or run the spec in float64.

Precision policies (ExecPlan.precision) stop HERE: reduced-precision plans
cast the coupling/input GEMMs of the *integration*, but the learn
recursion always runs in P's dtype — P's conditioning is the one place
bf16 noise compounds tick over tick instead of averaging out, and the
bit-match contract with the offline `fit_rls` oracle only holds if the
update math is unpolluted. Both update entry points upcast reduced-dtype
feature vectors to P's dtype defensively.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rls_init(
    e: int, n_state: int, n_out: int, reg: float, dtype
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fresh per-lane learning state: P = I / reg, W = 0.

    Returns (P (E, S, S), W (E, S, n_out)). reg plays exactly the role of
    ridge regression's `reg`: an RLS pass with forgetting factor 1 over T
    samples solves (X^T X + reg I) W = X^T Y.
    """
    if reg <= 0:
        raise ValueError(f"reg must be > 0 (P0 = I / reg); got {reg}")
    p0 = jnp.broadcast_to(
        (jnp.eye(n_state, dtype=dtype) / jnp.asarray(reg, dtype))[None],
        (e, n_state, n_state),
    )
    w0 = jnp.zeros((e, n_state, n_out), dtype)
    return p0, w0


def rls_update(
    p: jnp.ndarray,  # (E, S, S) inverse-Gram per lane
    w: jnp.ndarray,  # (E, S, n_out) readout weights per lane
    x: jnp.ndarray,  # (E, S) this tick's feature vector per lane
    y: jnp.ndarray,  # (E, n_out) this tick's target per lane
    mask: jnp.ndarray,  # (E,) bool; False lanes return (p, w) value-frozen
    lam: float,  # STATIC forgetting factor in (0, 1] (a Python float)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One masked batched RLS step -> (P', W', a-priori predictions (E, n_out)).

    The prediction is computed with the INCOMING weights (before the
    update), i.e. what the lane would have answered for this tick — the
    honest online-learning error signal. Masked-off lanes (idle slots,
    washout ticks, inference-only tenants) keep P and W value-frozen
    (== their previous values; a -0.0 may normalize to +0.0); their
    prediction is still returned (frozen weights applied to the tick's
    states).

    lam is a static Python float, not a traced scalar: the update is fused
    into serving's per-tick scan where every (E, S, S) traversal is billed
    per tick, and the common lam == 1.0 case skips the P rescale entirely.
    Masking folds into the gain (k = 0 -> P - 0, W + 0) rather than a
    jnp.where select over the (E, S, S) P block — two fewer full-P
    traversals per tick, value-identical results.
    """
    # learn math never runs reduced: see the module precision note
    x = x.astype(p.dtype)
    y = y.astype(p.dtype)
    # broadcast-multiply + sum, NOT einsum/dot_general: XLA lowers batched
    # dots with a batch-width-dependent reduction order, while a trailing-
    # axis reduce is bit-identical per lane at any E — that is what lets a
    # served lane bit-match the E=1 offline oracle (core.reservoir.fit_rls)
    px = jnp.sum(p * x[:, None, :], axis=-1)  # (E, S)
    denom = lam + jnp.sum(x * px, axis=-1)  # (E,)
    k = jnp.where(mask[:, None], px / denom[:, None], 0.0)  # (E, S)
    pred = jnp.sum(w * x[:, :, None], axis=1)  # (E, n_out)
    err = y - pred
    w_new = w + k[:, :, None] * err[:, None, :]
    # k (P x)^T, not k (x^T P): symmetric-by-construction P update
    p_new = p - k[:, :, None] * px[:, None, :]
    if lam != 1.0:
        # frozen lanes divide by exactly 1.0 (an IEEE no-op: x / 1.0 == x)
        lam_e = jnp.where(mask, jnp.asarray(lam, p.dtype), p.dtype.type(1.0))
        p_new = p_new / lam_e[:, None, None]
    return p_new, w_new, pred


def rls_chunk(
    p: jnp.ndarray,  # (E, S, S) inverse-Gram per lane
    w: jnp.ndarray,  # (E, S, n_out) readout weights per lane
    xb: jnp.ndarray,  # (K, E, S) feature vectors, one row per tick
    y: jnp.ndarray,  # (K, E, n_out) targets per tick
    mask: jnp.ndarray,  # (K, E) bool; False ticks leave (p, w) value-frozen
    lam: float,  # STATIC forgetting factor in (0, 1]
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """K sequential RLS steps applied with O(1) full-P passes per CHUNK.

    P is the memory giant of RLS — (E, S, S) floats — and the serving chunk
    is K ticks, so the naive per-tick recursion pays ~3K full-P traversals
    per chunk and is memory-bound well past the learn-overhead budget at
    large N. This routine computes the SAME per-tick gain sequence from
    rank-1 algebra on small (E, S) vectors:

        B        = P x_t for all K ticks      ... ONE read of P
        px_t     = cum_t B_t - sum_{j<t} coef_j (px_j . x_t) k_j
        k_t      = mask_t ? px_t / (lam + x_t . px_t) : 0
        W_{t+1}  = W_t + k_t (y_t - W_t^T x_t)^T     (a-priori preds kept)
        P'       = cum_K P - sum_t coef_t k_t px_t^T ... one read + write

    i.e. ~3 full-P traversals per chunk instead of ~3K. The gains are
    mathematically identical to K applications of `rls_update` (exact
    rank-1 expansion of the recursion, with the forgetting/mask factors
    tracked in per-lane scalars); float op order differs, so the offline
    oracle (`core.reservoir.fit_rls(block=K)`) uses THIS routine with the
    same block size to stay bit-matched with serving. Masked ticks
    contribute exactly-zero terms, so frozen lanes stay value-frozen.

    Every reduction is the same broadcast-multiply + trailing-axis sum as
    `rls_update` (batch-width bit-stability), and XLA fuses the multiplies
    into the reduces, so no (E, S, S, K) temporary is materialized.
    """
    k_ticks = xb.shape[0]
    # learn math never runs reduced: see the module precision note
    xb = xb.astype(p.dtype)
    y = y.astype(p.dtype)
    dt_one = p.dtype.type(1.0)
    # B[e, i, t] = sum_j P[e, i, j] x_t[e, j] — one pass over P, as a
    # batched GEMM. Unlike a batched mat-VEC (whose reduction order shifts
    # with the batch width — the reason rls_update is mul+sum), a batched
    # matmul runs one fixed-shape (S, S) x (S, K) GEMM per lane, so lane
    # results are bit-identical at any E (pinned by
    # tests/test_rls_learning.py); the mul+sum spelling of this op
    # materializes a (E, S, K, S) temp on CPU and measured ~27x slower.
    # K == 1 is the degenerate case where the GEMM IS a mat-vec — there the
    # mul+sum spelling is both batch-stable and cheap, so use it.
    if k_ticks == 1:
        b = jnp.sum(p * xb[0][:, None, :], axis=-1)[:, :, None]  # (E, S, 1)
    else:
        xk = jnp.transpose(xb, (1, 0, 2))  # (E, K, S)
        b = jnp.einsum("eij,etj->eit", p, xk)  # (E, S, K)

    # gst / pxst grow one (E, 1, S) row per tick — batching each tick's
    # corrections against ALL prior pairs keeps the unrolled op count O(K)
    # instead of O(K^2) (the small-N regime is op-count-bound, not
    # bandwidth-bound)
    gst = pxst = None  # (E, t, S) stacks of gains / px vectors
    preds = []
    if lam != 1.0:
        inv_lam = p.dtype.type(1.0 / lam)
        cum = jnp.ones(p.shape[0], p.dtype)  # (E,) prod of per-tick 1/lam_e
        coefs = None  # (E, t): current coefficient of each stored pair
    w_t = w
    for t in range(k_ticks):
        x_t = xb[t]  # (E, S)
        px_t = b[:, :, t] if lam == 1.0 else cum[:, None] * b[:, :, t]
        if t:
            c = jnp.sum(pxst * x_t[:, None, :], axis=-1)  # (E, t) px_j . x_t
            if lam != 1.0:
                c = coefs * c
            px_t = px_t - jnp.sum(c[:, :, None] * gst, axis=1)
        denom = lam + jnp.sum(x_t * px_t, axis=-1)  # (E,)
        k_t = jnp.where(mask[t][:, None], px_t / denom[:, None], 0.0)
        pred_t = jnp.sum(w_t * x_t[:, :, None], axis=1)  # (E, n_out)
        w_t = w_t + k_t[:, :, None] * (y[t] - pred_t)[:, None, :]
        preds.append(pred_t)
        if gst is None:
            gst, pxst = k_t[:, None, :], px_t[:, None, :]
        else:
            gst = jnp.concatenate([gst, k_t[:, None, :]], axis=1)
            pxst = jnp.concatenate([pxst, px_t[:, None, :]], axis=1)
        if lam != 1.0:
            u_t = jnp.where(mask[t], inv_lam, dt_one)  # (E,)
            coefs = (
                u_t[:, None]
                if coefs is None
                else jnp.concatenate([coefs * u_t[:, None], u_t[:, None]], axis=1)
            )
            cum = cum * u_t
    # P' = cum P - sum_t coef_t k_t px_t^T: one read + write of P, again as
    # a batched fixed-shape GEMM (lane-stable; the mul+sum spelling fuses
    # catastrophically with the stacked loop outputs — ~8x slower measured)
    if lam != 1.0:
        gst = coefs[:, :, None] * gst
    p_scaled = p if lam == 1.0 else cum[:, None, None] * p
    p_new = p_scaled - jnp.einsum("eti,etj->eij", gst, pxst)
    return p_new, w_t, jnp.stack(preds)  # (E,S,S), (E,S,O), (K,E,O)


# ---------------------------------------------------------------------------
# LMS (normalized least mean squares) — the O(S) learner behind
# ExecPlan.learn="lms"
# ---------------------------------------------------------------------------
#
# RLS pays O(S^2) state (the (E, S, S) inverse-Gram P) and O(S^2) work per
# tick for exact recursive ridge. LMS is the classic cheap alternative: a
# stochastic-gradient step on the instantaneous squared error,
#
#     pred = W^T x
#     e    = y - pred
#     W'   = W + mu * x e^T / (eps + ||x||^2)        (NLMS normalization)
#
# O(S) state per output column and O(S) work per tick — the fitness signal
# the tune/ subsystem wants at large S, where allocating E (N+1)^2 P blocks
# per candidate would dominate the search itself. The ||x||^2 normalization
# (NLMS) makes the stable step-size range input-scale-free: 0 < mu < 2
# regardless of the state magnitudes, the standard result for normalized
# LMS. eps = 1e-8 guards all-zero feature rows (washout-padded ticks).
#
# Like rls_update, every reduction is broadcast-multiply + trailing-axis
# sum, so lane results are bit-identical at any batch width E — that is
# what lets a served lane bit-match the E=1 offline oracle
# (core.reservoir.fit_lms). Masked ticks fold into the gain (step = 0), so
# frozen lanes stay value-frozen, and because the update is per-tick local
# (no cross-tick P recursion), chunked application is the SAME op sequence
# at any chunk size — fit_lms needs no `block` parameter.

_LMS_EPS = 1e-8


def lms_init(e: int, n_state: int, n_out: int, dtype) -> jnp.ndarray:
    """Fresh per-lane LMS weights: W = 0, shape (E, S, n_out)."""
    return jnp.zeros((e, n_state, n_out), dtype)


def lms_update(
    w: jnp.ndarray,  # (E, S, n_out) readout weights per lane
    x: jnp.ndarray,  # (E, S) this tick's feature vector per lane
    y: jnp.ndarray,  # (E, n_out) this tick's target per lane
    mask: jnp.ndarray,  # (E,) bool; False lanes return w value-frozen
    mu: float,  # STATIC step size in (0, 2) (a Python float)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One masked batched NLMS step -> (W', a-priori predictions (E, n_out)).

    Same contract as `rls_update`: predictions use the INCOMING weights;
    masked-off lanes keep W value-frozen but still predict.
    """
    # learn math never runs reduced: see the module precision note
    x = x.astype(w.dtype)
    y = y.astype(w.dtype)
    pred = jnp.sum(w * x[:, :, None], axis=1)  # (E, n_out)
    err = y - pred
    norm = jnp.sum(x * x, axis=-1) + w.dtype.type(_LMS_EPS)  # (E,)
    g = jnp.where(mask, mu / norm, 0.0)  # (E,) masked gain
    w_new = w + (g[:, None] * x)[:, :, None] * err[:, None, :]
    return w_new, pred


def lms_chunk(
    w: jnp.ndarray,  # (E, S, n_out) readout weights per lane
    xb: jnp.ndarray,  # (K, E, S) feature vectors, one row per tick
    y: jnp.ndarray,  # (K, E, n_out) targets per tick
    mask: jnp.ndarray,  # (K, E) bool; False ticks leave w value-frozen
    mu: float,  # STATIC step size in (0, 2)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K sequential NLMS steps -> (W', a-priori preds (K, E, n_out)).

    A lax.scan of `lms_update` over the chunk's ticks: unlike RLS there is
    no O(S^2) P block to amortize, so the per-tick recursion IS the cheap
    spelling — O(K * S) work, O(S) state. The per-tick op sequence is
    exactly `lms_update`'s, so chunked serving at any chunk_ticks is
    bit-identical to per-tick application (and to the offline
    `core.reservoir.fit_lms` oracle at E = 1).
    """
    xb = xb.astype(w.dtype)
    y = y.astype(w.dtype)

    def tick(w_c, rows):
        x_t, y_t, m_t = rows
        w_n, pred = lms_update(w_c, x_t, y_t, m_t, mu)
        return w_n, pred

    w_fin, preds = jax.lax.scan(tick, w, (xb, y, mask))
    return w_fin, preds  # (E, S, n_out), (K, E, n_out)
