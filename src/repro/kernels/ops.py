"""Public jit'd wrappers around the Pallas kernels.

Handles layout conversion ((E, N, 3) user layout <-> (3, N, E) kernel
layout), MXU-alignment padding, and implementation dispatch:

    impl="fused"  VMEM-resident whole-RK4(-multi-step) kernel (small/med N)
    impl="tiled"  per-stage row-tiled kernel (large N)
    impl="ref"    pure-jnp oracle (also the non-TPU production path)
    impl="chunk"  chunk-resident serving kernel: the K-tick x hold_steps x
                  4-stage loop as ONE device-side region (Pallas rk4_chunk
                  on TPU — W and state planes VMEM-resident per chunk; the
                  jnp chunk oracle elsewhere). Per-hold-window entry points
                  fall back to the ref math (a chunk of one window).
    impl="auto"   measured-latency table if populated; else fused while
                  W + state + stages fit the VMEM budget, else tiled
                  (on non-TPU backends: always ref — Pallas is unavailable)

Precision policies (ExecPlan.precision) resolve HERE into a single W-cast
hoisted outside the integration loops: "bf16_coupling"/"mixed" pass a bf16
W into the kernels/oracle, whose coupling dots consume the reduced
operands and accumulate in the state dtype. The dispatch table is keyed by
precision as well as shape — a winner measured at f32 says nothing about
the bf16-coupling ranking.

Serving extensions (repro/serve/reservoir.py rides on these):
  - `h_in`: an (N, E) input-drive x-field added to the coupling field inside
    the kernels, held constant over the integration window — one kernel
    invocation advances a whole hold window of a *driven* reservoir.
  - `lane_mask`: partial-batch masking over the ensemble axis. Lanes where
    the mask is False come back bit-identical to their input state, so idle
    serving slots stay frozen while active slots advance in the same batch.

Zero-padding correctness: padded W rows/cols are zero so padded oscillators
receive/contribute no coupling; padded h_in rows/lanes are zero; padded
ensemble lanes evolve garbage that is sliced away on exit; params rows are
broadcast into padded lanes so no division hits uninitialized memory
(denominators are 1 + lam*m.p >= 1-lam).
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.constants import STOParams
from repro.kernels import ref as kref
from repro.kernels import sto_step

# VMEM budget used by auto-dispatch (bytes); v5e has ~16 MiB per core.
VMEM_BUDGET = 12 * 1024 * 1024
LANE = sto_step.LANE


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def fused_fits_vmem(n: int, block_e: int, itemsize: int = 4) -> bool:
    """W (n^2) + ~8 live (n, block_e) planes per fused step must fit VMEM."""
    need = n * n * itemsize + 8 * n * block_e * itemsize
    return need <= VMEM_BUDGET


# ---------------------------------------------------------------------------
# Measured-latency dispatch table
# ---------------------------------------------------------------------------

# (platform, N_padded, E_padded, itemsize, precision) -> impl name.
# Populated by measure_impl_latency(), register_impl_choice(), or the
# persisted per-platform JSON tables (kernels/dispatch_table.py, loaded
# lazily by choose_impl); consulted before falling back to the VMEM
# heuristic. itemsize is part of the key because a choice measured at f32
# says nothing about the f64 VMEM footprint / bandwidth at the same padded
# shape; precision is part of the key because the impl ranking shifts when
# the coupling GEMM goes bf16 (e.g. MXU-native on TPU, software-emulated
# on most CPUs).
_LATENCY_TABLE: Dict[Tuple[str, int, int, int, str], str] = {}

# Bumped on every register_impl_choice(): the api-layer PlanCache keys
# impl="auto" resolutions on this, so a cached auto plan is invalidated
# (and re-resolves) the moment a new measurement pins a different winner.
_DISPATCH_GEN = 0


def dispatch_generation() -> int:
    """Monotonic version of the in-process dispatch table state."""
    return _DISPATCH_GEN

# The bit-exact default's tag in dispatch keys (ExecPlan.precision None
# and "highest" collapse to this).
PRECISION_DEFAULT = "highest"


def normalize_precision(precision: Optional[str]) -> str:
    """Collapse the ExecPlan.precision aliases to a dispatch-key tag."""
    return PRECISION_DEFAULT if precision in (None, PRECISION_DEFAULT) else precision


def register_impl_choice(
    n: int,
    e: int,
    impl: str,
    platform: Optional[str] = None,
    itemsize: int = 4,
    precision: Optional[str] = None,
):
    """Pin the dispatch choice for a padded (N, E, itemsize, precision)
    shape on a platform."""
    global _DISPATCH_GEN
    platform = platform or jax.default_backend()
    _DISPATCH_GEN += 1
    _LATENCY_TABLE[
        (
            platform,
            _round_up(n, LANE),
            _round_up(e, LANE),
            itemsize,
            normalize_precision(precision),
        )
    ] = impl


def latency_table() -> Dict[Tuple[str, int, int, int, str], str]:
    return dict(_LATENCY_TABLE)


def choose_impl(
    n: int,
    e: int,
    itemsize: int = 4,
    platform: Optional[str] = None,
    precision: Optional[str] = None,
) -> str:
    """Resolve impl="auto" for a given (N, E, precision) problem shape.

    Priority: measured-latency table (in-process measurements, then the
    committed per-platform JSON from kernels/dispatch_table.py) — first at
    the exact precision key, then at the bit-exact default key for the
    same shape (the best f32 impl is the best prior for a reduced-precision
    run that was never measured) > platform gate (Pallas kernels only
    compile on TPU; everything else integrates through the jnp oracle,
    which XLA fuses well on CPU/GPU) > VMEM-fit heuristic.
    """
    from repro.kernels import dispatch_table

    platform = platform or jax.default_backend()
    dispatch_table.ensure_loaded(platform)
    prec = normalize_precision(precision)
    shape_key = (platform, _round_up(n, LANE), _round_up(e, LANE), itemsize)
    if shape_key + (prec,) in _LATENCY_TABLE:
        return _LATENCY_TABLE[shape_key + (prec,)]
    if prec != PRECISION_DEFAULT and shape_key + (PRECISION_DEFAULT,) in _LATENCY_TABLE:
        return _LATENCY_TABLE[shape_key + (PRECISION_DEFAULT,)]
    if platform != "tpu":
        return "ref"
    return "fused" if fused_fits_vmem(_round_up(n, LANE), LANE, itemsize) else "tiled"


def measure_impl_latency(
    n: int,
    e: int,
    dt: float = 1.0e-11,
    n_steps: int = 8,
    candidates: Optional[Tuple[str, ...]] = None,
    dtype=jnp.float32,
    reps: int = 3,
    register: bool = True,
    precision: Optional[str] = None,
    chunk_ticks: int = 4,
) -> Dict[str, object]:
    """Time each candidate impl at (N, E, precision) and record the winner.

    Each candidate runs the CHUNKED serving shape of the problem —
    chunk_ticks hold windows of n_steps each (the serving hot path the
    dispatch table mostly arbitrates) — so the measurement captures what
    chunk residency is worth on TPU, where impl="chunk" is the Pallas
    rk4_chunk kernel (W read once per chunk) while fused/tiled re-enter
    per tick. Off-TPU, "chunk" lowers to the SAME fused XLA region as
    "ref" (see _tick_chunk_planes_jit), so it is excluded from the default
    candidates there — timing two names for one computation would register
    a coin-flip winner; pass it via `candidates` explicitly if you must.

    Returns {impl: seconds per chunk} for the candidates that ran, plus —
    when any candidate failed — a "failed" entry mapping impl name to the
    error string. Failures are also surfaced as a RuntimeWarning: a broken
    backend must show up in the measurement report, not silently skew the
    dispatch table toward whatever happened to survive. With register=True
    the fastest surviving impl is written into the dispatch table so
    subsequent impl="auto" calls at this padded (shape, precision) use the
    measured choice.
    """
    if candidates is None:
        candidates = (
            ("fused", "tiled", "chunk", "ref")
            if jax.default_backend() == "tpu"
            else ("ref",)
        )
    from repro.core import constants, coupling

    w = jnp.asarray(coupling.make_coupling_matrix(n, seed=0), dtype)
    m0 = to_planes(
        jnp.broadcast_to(constants.initial_magnetization(n, dtype), (e, n, 3))
    )
    pv = kref.pack_params(constants.default_params(dtype), e, dtype)
    h_block = jnp.zeros((chunk_ticks, n, e), dtype)
    mask_block = jnp.ones((chunk_ticks, e), dtype=bool)
    timings: Dict[str, object] = {}
    failed: Dict[str, str] = {}
    for impl in candidates:
        fn = lambda: sto_rk4_tick_chunk_planes(
            m0, w, pv, float(dt), n_steps, h_block, mask_block,
            impl=impl, precision=precision,
        )[0]
        try:
            jax.block_until_ready(fn())  # compile + warm
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times.append(time.perf_counter() - t0)
            timings[impl] = sorted(times)[len(times) // 2]
        except Exception as exc:  # impl unavailable on this backend/shape
            failed[impl] = f"{type(exc).__name__}: {exc}"
    if failed:
        import warnings

        timings["failed"] = failed
        warnings.warn(
            f"measure_impl_latency({n}, {e}): candidate impl(s) failed and "
            f"were excluded from dispatch: "
            + ", ".join(f"{k} ({v})" for k, v in failed.items()),
            RuntimeWarning,
            stacklevel=2,
        )
    successes = {k: v for k, v in timings.items() if isinstance(v, float)}
    if register and successes:
        register_impl_choice(
            n, e, min(successes, key=successes.get),
            itemsize=jnp.dtype(dtype).itemsize,
            precision=precision,
        )
    return timings


# ---------------------------------------------------------------------------
# Layout conversion + padding
# ---------------------------------------------------------------------------


def to_planes(m_user: jnp.ndarray) -> jnp.ndarray:
    """(..., N, 3) -> (3, N, E) kernel layout (E = flattened batch, >=1)."""
    if m_user.ndim == 2:
        m_user = m_user[None]
    e = 1
    for s in m_user.shape[:-2]:
        e *= int(s)
    n = m_user.shape[-2]
    flat = m_user.reshape(e, n, 3)
    return jnp.transpose(flat, (2, 1, 0))


def from_planes(m_planes: jnp.ndarray, batch_shape) -> jnp.ndarray:
    """(3, N, E) -> (*batch_shape, N, 3)."""
    e = m_planes.shape[-1]
    out = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
    return out.reshape(*batch_shape, m_planes.shape[1], 3)


def _pad_planes(m, w, params, h_in, block_n, block_e):
    _, n, e = m.shape
    n_p = _round_up(max(n, 1), block_n)
    e_p = _round_up(max(e, 1), block_e)
    if n_p != n or e_p != e:
        m = jnp.pad(m, ((0, 0), (0, n_p - n), (0, e_p - e)))
        w = jnp.pad(w, ((0, n_p - n), (0, n_p - n)))
        if h_in is not None:
            h_in = jnp.pad(h_in, ((0, n_p - n), (0, e_p - e)))
        # broadcast params into padded lanes (edge mode keeps denominators sane)
        params = jnp.pad(params, ((0, 0), (0, e_p - e)), mode="edge")
    return m, w, params, h_in, n, e


# ---------------------------------------------------------------------------
# Integration entry points
# ---------------------------------------------------------------------------


def sto_rk4_integrate_planes(
    m0: jnp.ndarray,  # (3, N, E) kernel layout
    w_cp: jnp.ndarray,  # (N, N)
    params_vec: jnp.ndarray,  # (NP, E) packed (kernels/ref.pack_params)
    dt: float,
    n_steps: int,
    h_in: Optional[jnp.ndarray] = None,  # (N, E) input-drive x-field
    lane_mask: Optional[jnp.ndarray] = None,  # (E,) bool; False lanes frozen
    impl: str = "auto",
    n_inner: int = 8,
    block_n: int = LANE,
    block_e: int = LANE,
    interpret: bool = False,
    precision: Optional[str] = None,
) -> jnp.ndarray:
    """Integrate n_steps of (optionally driven) coupled-STO RK4 in kernel
    layout. Returns the final (3, N, E) state.

    This is the serving engine's hot path: one call advances every ensemble
    lane (= serving slot) by a full hold window. n_steps must be divisible by
    n_inner for the fused path (auto-adjusted otherwise).

    impl="auto" is resolved HERE, outside the jit, so dispatch-table updates
    (measure_impl_latency / register_impl_choice) take effect on the next
    call — the resolved impl is the jit cache key, never the string "auto".
    """
    _, n, e = m0.shape
    if impl == "auto":
        impl = choose_impl(n, e, m0.dtype.itemsize, precision=precision)
    return _integrate_planes_jit(
        m0, w_cp, params_vec, h_in, lane_mask,
        dt=dt, n_steps=n_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
        precision=normalize_precision(precision),
    )


def input_field_einsum(eq: str, w_in, u, precision) -> jnp.ndarray:
    """The input-field GEMM under the precision policy — ONE home for it.

    "mixed" (ExecPlan.precision) runs W^in u on bf16 operands accumulating
    in the input dtype; every other policy traces the exact einsum the
    callers have always used. Callers (api/compiled._input_field,
    api/sharded._input_field_local) own their layout/equation strings and
    their a_in scaling op order — only the reduction policy lives here, so
    a future policy (e.g. fp8) lands in one place for planes AND sharded
    plans.
    """
    if precision == "mixed":
        return jnp.einsum(
            eq, w_in.astype(jnp.bfloat16), u.astype(jnp.bfloat16),
            preferred_element_type=u.dtype,
        )
    return jnp.einsum(eq, w_in, u)


def _coupling_operand(w: jnp.ndarray, precision: str) -> jnp.ndarray:
    """Resolve the precision policy into the W operand the kernels consume.

    The cast happens ONCE, outside the integration loops; the kernels and
    the jnp oracle detect the reduced dtype and accumulate the coupling dot
    in the state dtype. "mixed" adds the input-field GEMM on top of
    "bf16_coupling" — that GEMM lives at the API layer (repro/api), so here
    both map to a bf16 W.
    """
    if precision in ("bf16_coupling", "mixed"):
        return w.astype(jnp.bfloat16)
    return w


@functools.partial(
    jax.jit,
    static_argnames=("dt", "n_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _integrate_planes_jit(
    m0, w_cp, params_vec, h_in, lane_mask,
    *, dt, n_steps, impl, n_inner, block_n, block_e, interpret,
    precision=PRECISION_DEFAULT,
):
    # the oracle is pure XLA — no MXU tile constraint, so padding would only
    # burn FLOPs on dead lanes; the Pallas kernels need lane alignment.
    # "chunk" at the per-hold-window level is a one-tick chunk: the Pallas
    # rk4_chunk kernel on TPU (W VMEM-resident for the whole window — so a
    # dispatch winner measured on the chunked shape stays a sane choice for
    # tick()/drive()/integrate() too), the same math as the jnp oracle
    # elsewhere.
    use_pallas_chunk = impl == "chunk" and (
        jax.default_backend() == "tpu" or interpret
    )
    pb_n, pb_e = (
        (1, 1)
        if impl in ("ref", "chunk") and not use_pallas_chunk
        else (block_n, block_e)
    )
    m, w, pv, h, n_orig, e_orig = _pad_planes(
        m0, w_cp, params_vec, h_in, pb_n, pb_e
    )
    w = _coupling_operand(w, precision)

    if use_pallas_chunk:
        _, n_p, e_p = m.shape
        h_block = (
            jnp.zeros((1, n_p, e_p), m.dtype) if h is None else h[None]
        )
        m, _ = sto_step.rk4_chunk(
            m, w, pv, dt, n_steps, h_block,
            jnp.ones((1, e_p), m.dtype), block_e=block_e, interpret=interpret,
        )
    elif impl in ("ref", "chunk"):
        dt_c = jnp.asarray(dt, m.dtype)

        def body(mm, _):
            return kref.rk4_step_planes(mm, w, pv, dt_c, h), None

        m, _ = jax.lax.scan(body, m, None, length=n_steps)
    elif impl == "fused":
        while n_steps % n_inner != 0:
            n_inner -= 1

        def body(mm, _):
            return (
                sto_step.rk4_fused(
                    mm, w, pv, dt, n_inner=n_inner, block_e=block_e,
                    h_in=h, interpret=interpret,
                ),
                None,
            )

        m, _ = jax.lax.scan(body, m, None, length=n_steps // n_inner)
    elif impl == "tiled":
        def body(mm, _):
            return (
                sto_step.rk4_tiled_step(
                    mm, w, pv, dt, block_n=block_n, block_e=block_e,
                    h_in=h, interpret=interpret,
                ),
                None,
            )

        m, _ = jax.lax.scan(body, m, None, length=n_steps)
    else:
        raise ValueError(f"unknown impl: {impl}")

    m = m[:, :n_orig, :e_orig]
    if lane_mask is not None:
        # Partial-batch masking: frozen lanes return their input state
        # bit-identically (idle serving slots don't drift).
        m = jnp.where(lane_mask[None, None, :], m, m0)
    return m


def sto_rk4_tick_chunk_planes(
    m0: jnp.ndarray,  # (3, N, E) kernel layout
    w_cp: jnp.ndarray,  # (N, N)
    params_vec: jnp.ndarray,  # (NP, E) packed (kernels/ref.pack_params)
    dt: float,
    hold_steps: int,
    h_block: jnp.ndarray,  # (K, N, E) per-tick input-drive x-fields
    mask_block: jnp.ndarray,  # (K, E) bool; False = lane frozen that tick
    impl: str = "auto",
    precision: Optional[str] = None,
    n_inner: int = 8,
    block_n: int = LANE,
    block_e: int = LANE,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K serving ticks (K hold windows) in kernel layout, one dispatch.

    The chunk-level integration entry: per-tick input fields arrive as a
    precomputed (K, N, E) block and the per-tick states block stays device-
    side. impl="chunk" runs the whole K x hold_steps x 4-stage loop as one
    chunk-resident region (Pallas rk4_chunk on TPU — W read from HBM once
    per chunk; the jnp chunk oracle elsewhere); the per-window impls
    (ref/fused/tiled) scan over ticks re-entering their kernels. Returns
    (m' (3, N, E), states (K, N, E) per-tick x-planes). Frozen (masked
    False) lanes come back bit-identical for every impl.
    """
    _, n, e = m0.shape
    if impl == "auto":
        impl = choose_impl(n, e, m0.dtype.itemsize, precision=precision)
    return _tick_chunk_planes_jit(
        m0, w_cp, params_vec, h_block, mask_block,
        dt=dt, hold_steps=hold_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
        precision=normalize_precision(precision),
    )


@functools.partial(
    jax.jit,
    static_argnames=("dt", "hold_steps", "impl", "n_inner", "block_n", "block_e", "interpret", "precision"),
)
def _tick_chunk_planes_jit(
    m0, w_cp, params_vec, h_block, mask_block,
    *, dt, hold_steps, impl, n_inner, block_n, block_e, interpret,
    precision=PRECISION_DEFAULT,
):
    k_ticks = h_block.shape[0]
    pb_n, pb_e = (1, 1) if impl in ("ref", "chunk") else (block_n, block_e)
    use_pallas_chunk = impl == "chunk" and (
        jax.default_backend() == "tpu" or interpret
    )
    if use_pallas_chunk:
        pb_n, pb_e = block_n, block_e
    m, w, pv, _, n_orig, e_orig = _pad_planes(
        m0, w_cp, params_vec, None, pb_n, pb_e
    )
    _, n_p, e_p = m.shape
    if (n_p, e_p) != h_block.shape[1:]:
        h_block = jnp.pad(
            h_block,
            ((0, 0), (0, n_p - h_block.shape[1]), (0, e_p - h_block.shape[2])),
        )
        # padded lanes stay frozen: their params are edge-broadcast so the
        # math is safe either way, but frozen is cheaper to reason about
        mask_block = jnp.pad(mask_block, ((0, 0), (0, e_p - mask_block.shape[1])))
    w = _coupling_operand(w, precision)

    if use_pallas_chunk:
        mT, states = sto_step.rk4_chunk(
            m, w, pv, dt, hold_steps, h_block,
            mask_block.astype(m.dtype), block_e=block_e, interpret=interpret,
        )
    elif impl in ("ref", "chunk"):
        # one fused region either way off-TPU; "chunk" additionally means
        # the caller precomputed h_block with ONE input GEMM per chunk
        mT, states = kref.rk4_chunk_planes(
            m, w, pv, dt, hold_steps, h_block, mask_block
        )
    elif impl in ("fused", "tiled"):
        if impl == "fused":
            while hold_steps % n_inner != 0:
                n_inner -= 1

        def per_tick(mm, tick_in):
            h_t, mask_t = tick_in
            if impl == "fused":
                def win(mw, _):
                    return (
                        sto_step.rk4_fused(
                            mw, w, pv, dt, n_inner=n_inner, block_e=block_e,
                            h_in=h_t, interpret=interpret,
                        ),
                        None,
                    )

                m_new, _ = jax.lax.scan(win, mm, None, length=hold_steps // n_inner)
            else:
                def win(mw, _):
                    return (
                        sto_step.rk4_tiled_step(
                            mw, w, pv, dt, block_n=block_n, block_e=block_e,
                            h_in=h_t, interpret=interpret,
                        ),
                        None,
                    )

                m_new, _ = jax.lax.scan(win, mm, None, length=hold_steps)
            m_new = jnp.where(mask_t[None, None, :], m_new, mm)
            return m_new, m_new[0]

        mT, states = jax.lax.scan(per_tick, m, (h_block, mask_block))
    else:
        raise ValueError(f"unknown impl: {impl}")

    return mT[:, :n_orig, :e_orig], states[:, :n_orig, :e_orig]


def sto_rk4_integrate(
    m0: jnp.ndarray,  # (..., N, 3) user layout
    w_cp: jnp.ndarray,  # (N, N)
    params_vec: jnp.ndarray,  # (NP, E) packed (kernels/ref.pack_params)
    dt: float,
    n_steps: int,
    impl: str = "auto",
    n_inner: int = 8,
    block_n: int = LANE,
    block_e: int = LANE,
    interpret: bool = False,
    precision: Optional[str] = None,
) -> jnp.ndarray:
    """Integrate n_steps of coupled-STO RK4 with the chosen implementation.

    Returns the final state in user layout. n_steps must be divisible by
    n_inner for the fused path (auto-adjusted otherwise). Like the planes
    entry point, impl="auto" is resolved eagerly against the dispatch table.
    """
    batch_shape = m0.shape[:-2]
    e = 1
    for s in batch_shape:
        e *= int(s)
    if impl == "auto":
        impl = choose_impl(m0.shape[-2], e, m0.dtype.itemsize, precision=precision)
    m = _integrate_planes_jit(
        to_planes(m0), w_cp, params_vec, None, None,
        dt=dt, n_steps=n_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
        precision=normalize_precision(precision),
    )
    return from_planes(m, batch_shape)


def sto_rk4_step(
    m0: jnp.ndarray,
    w_cp: jnp.ndarray,
    params: STOParams,
    dt: float,
    impl: str = "auto",
    interpret: bool = False,
    block_n: int = LANE,
    block_e: int = LANE,
) -> jnp.ndarray:
    """Single RK4 step convenience wrapper taking STOParams directly."""
    e = 1
    for s in m0.shape[:-2]:
        e *= s
    pv = kref.pack_params(params, e, dtype=m0.dtype)
    return sto_rk4_integrate(
        m0, w_cp, pv, dt, 1,
        impl=impl, n_inner=1, block_n=block_n, block_e=block_e, interpret=interpret,
    )
