"""Public jit'd wrappers around the Pallas kernels.

Handles layout conversion ((E, N, 3) user layout <-> (3, N, E) kernel
layout), MXU-alignment padding, and implementation dispatch:

    impl="fused"  VMEM-resident whole-RK4(-multi-step) kernel (small/med N)
    impl="tiled"  per-stage row-tiled kernel (large N)
    impl="ref"    pure-jnp oracle (also the non-TPU production path)
    impl="auto"   measured-latency table if populated; else fused while
                  W + state + stages fit the VMEM budget, else tiled
                  (on non-TPU backends: always ref — Pallas is unavailable)

Serving extensions (repro/serve/reservoir.py rides on these):
  - `h_in`: an (N, E) input-drive x-field added to the coupling field inside
    the kernels, held constant over the integration window — one kernel
    invocation advances a whole hold window of a *driven* reservoir.
  - `lane_mask`: partial-batch masking over the ensemble axis. Lanes where
    the mask is False come back bit-identical to their input state, so idle
    serving slots stay frozen while active slots advance in the same batch.

Zero-padding correctness: padded W rows/cols are zero so padded oscillators
receive/contribute no coupling; padded h_in rows/lanes are zero; padded
ensemble lanes evolve garbage that is sliced away on exit; params rows are
broadcast into padded lanes so no division hits uninitialized memory
(denominators are 1 + lam*m.p >= 1-lam).
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.constants import STOParams
from repro.kernels import ref as kref
from repro.kernels import sto_step

# VMEM budget used by auto-dispatch (bytes); v5e has ~16 MiB per core.
VMEM_BUDGET = 12 * 1024 * 1024
LANE = sto_step.LANE


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def fused_fits_vmem(n: int, block_e: int, itemsize: int = 4) -> bool:
    """W (n^2) + ~8 live (n, block_e) planes per fused step must fit VMEM."""
    need = n * n * itemsize + 8 * n * block_e * itemsize
    return need <= VMEM_BUDGET


# ---------------------------------------------------------------------------
# Measured-latency dispatch table
# ---------------------------------------------------------------------------

# (platform, N_padded, E_padded, itemsize) -> impl name. Populated by
# measure_impl_latency(), register_impl_choice(), or the persisted
# per-platform JSON tables (kernels/dispatch_table.py, loaded lazily by
# choose_impl); consulted before falling back to the VMEM heuristic.
# itemsize is part of the key because a choice measured at f32 says nothing
# about the f64 VMEM footprint / bandwidth at the same padded shape.
_LATENCY_TABLE: Dict[Tuple[str, int, int, int], str] = {}


def register_impl_choice(
    n: int, e: int, impl: str, platform: Optional[str] = None, itemsize: int = 4
):
    """Pin the dispatch choice for a padded (N, E, itemsize) shape on a
    platform."""
    platform = platform or jax.default_backend()
    _LATENCY_TABLE[(platform, _round_up(n, LANE), _round_up(e, LANE), itemsize)] = impl


def latency_table() -> Dict[Tuple[str, int, int, int], str]:
    return dict(_LATENCY_TABLE)


def choose_impl(
    n: int,
    e: int,
    itemsize: int = 4,
    platform: Optional[str] = None,
) -> str:
    """Resolve impl="auto" for a given (N, E) problem shape.

    Priority: measured-latency table (in-process measurements, then the
    committed per-platform JSON from kernels/dispatch_table.py) > platform
    gate (Pallas kernels only compile on TPU; everything else integrates
    through the jnp oracle, which XLA fuses well on CPU/GPU) > VMEM-fit
    heuristic.
    """
    from repro.kernels import dispatch_table

    platform = platform or jax.default_backend()
    dispatch_table.ensure_loaded(platform)
    key = (platform, _round_up(n, LANE), _round_up(e, LANE), itemsize)
    if key in _LATENCY_TABLE:
        return _LATENCY_TABLE[key]
    if platform != "tpu":
        return "ref"
    return "fused" if fused_fits_vmem(_round_up(n, LANE), LANE, itemsize) else "tiled"


def measure_impl_latency(
    n: int,
    e: int,
    dt: float = 1.0e-11,
    n_steps: int = 8,
    candidates: Optional[Tuple[str, ...]] = None,
    dtype=jnp.float32,
    reps: int = 3,
    register: bool = True,
) -> Dict[str, float]:
    """Time each candidate impl at (N, E) and record the winner.

    Returns {impl: seconds per call}. With register=True the fastest impl is
    written into the dispatch table so subsequent impl="auto" calls at this
    padded shape use the measured choice — the engine measures once per
    instance instead of trusting the static VMEM heuristic.
    """
    if candidates is None:
        candidates = (
            ("fused", "tiled", "ref")
            if jax.default_backend() == "tpu"
            else ("ref",)
        )
    from repro.core import constants, coupling

    w = jnp.asarray(coupling.make_coupling_matrix(n, seed=0), dtype)
    m0 = jnp.broadcast_to(constants.initial_magnetization(n, dtype), (e, n, 3))
    pv = kref.pack_params(constants.default_params(dtype), e, dtype)
    timings: Dict[str, float] = {}
    for impl in candidates:
        fn = lambda: sto_rk4_integrate(m0, w, pv, float(dt), n_steps, impl=impl)
        try:
            jax.block_until_ready(fn())  # compile + warm
            times = []
            for _ in range(reps):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times.append(time.perf_counter() - t0)
            timings[impl] = sorted(times)[len(times) // 2]
        except Exception:  # impl unavailable on this backend/shape
            continue
    if register and timings:
        register_impl_choice(
            n, e, min(timings, key=timings.get),
            itemsize=jnp.dtype(dtype).itemsize,
        )
    return timings


# ---------------------------------------------------------------------------
# Layout conversion + padding
# ---------------------------------------------------------------------------


def to_planes(m_user: jnp.ndarray) -> jnp.ndarray:
    """(..., N, 3) -> (3, N, E) kernel layout (E = flattened batch, >=1)."""
    if m_user.ndim == 2:
        m_user = m_user[None]
    e = 1
    for s in m_user.shape[:-2]:
        e *= int(s)
    n = m_user.shape[-2]
    flat = m_user.reshape(e, n, 3)
    return jnp.transpose(flat, (2, 1, 0))


def from_planes(m_planes: jnp.ndarray, batch_shape) -> jnp.ndarray:
    """(3, N, E) -> (*batch_shape, N, 3)."""
    e = m_planes.shape[-1]
    out = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
    return out.reshape(*batch_shape, m_planes.shape[1], 3)


def _pad_planes(m, w, params, h_in, block_n, block_e):
    _, n, e = m.shape
    n_p = _round_up(max(n, 1), block_n)
    e_p = _round_up(max(e, 1), block_e)
    if n_p != n or e_p != e:
        m = jnp.pad(m, ((0, 0), (0, n_p - n), (0, e_p - e)))
        w = jnp.pad(w, ((0, n_p - n), (0, n_p - n)))
        if h_in is not None:
            h_in = jnp.pad(h_in, ((0, n_p - n), (0, e_p - e)))
        # broadcast params into padded lanes (edge mode keeps denominators sane)
        params = jnp.pad(params, ((0, 0), (0, e_p - e)), mode="edge")
    return m, w, params, h_in, n, e


# ---------------------------------------------------------------------------
# Integration entry points
# ---------------------------------------------------------------------------


def sto_rk4_integrate_planes(
    m0: jnp.ndarray,  # (3, N, E) kernel layout
    w_cp: jnp.ndarray,  # (N, N)
    params_vec: jnp.ndarray,  # (NP, E) packed (kernels/ref.pack_params)
    dt: float,
    n_steps: int,
    h_in: Optional[jnp.ndarray] = None,  # (N, E) input-drive x-field
    lane_mask: Optional[jnp.ndarray] = None,  # (E,) bool; False lanes frozen
    impl: str = "auto",
    n_inner: int = 8,
    block_n: int = LANE,
    block_e: int = LANE,
    interpret: bool = False,
) -> jnp.ndarray:
    """Integrate n_steps of (optionally driven) coupled-STO RK4 in kernel
    layout. Returns the final (3, N, E) state.

    This is the serving engine's hot path: one call advances every ensemble
    lane (= serving slot) by a full hold window. n_steps must be divisible by
    n_inner for the fused path (auto-adjusted otherwise).

    impl="auto" is resolved HERE, outside the jit, so dispatch-table updates
    (measure_impl_latency / register_impl_choice) take effect on the next
    call — the resolved impl is the jit cache key, never the string "auto".
    """
    _, n, e = m0.shape
    if impl == "auto":
        impl = choose_impl(n, e, m0.dtype.itemsize)
    return _integrate_planes_jit(
        m0, w_cp, params_vec, h_in, lane_mask,
        dt=dt, n_steps=n_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("dt", "n_steps", "impl", "n_inner", "block_n", "block_e", "interpret"),
)
def _integrate_planes_jit(
    m0, w_cp, params_vec, h_in, lane_mask,
    *, dt, n_steps, impl, n_inner, block_n, block_e, interpret,
):
    # the oracle is pure XLA — no MXU tile constraint, so padding would only
    # burn FLOPs on dead lanes; the Pallas kernels need lane alignment
    pb_n, pb_e = (1, 1) if impl == "ref" else (block_n, block_e)
    m, w, pv, h, n_orig, e_orig = _pad_planes(
        m0, w_cp, params_vec, h_in, pb_n, pb_e
    )

    if impl == "ref":
        dt_c = jnp.asarray(dt, m.dtype)

        def body(mm, _):
            return kref.rk4_step_planes(mm, w, pv, dt_c, h), None

        m, _ = jax.lax.scan(body, m, None, length=n_steps)
    elif impl == "fused":
        while n_steps % n_inner != 0:
            n_inner -= 1

        def body(mm, _):
            return (
                sto_step.rk4_fused(
                    mm, w, pv, dt, n_inner=n_inner, block_e=block_e,
                    h_in=h, interpret=interpret,
                ),
                None,
            )

        m, _ = jax.lax.scan(body, m, None, length=n_steps // n_inner)
    elif impl == "tiled":
        def body(mm, _):
            return (
                sto_step.rk4_tiled_step(
                    mm, w, pv, dt, block_n=block_n, block_e=block_e,
                    h_in=h, interpret=interpret,
                ),
                None,
            )

        m, _ = jax.lax.scan(body, m, None, length=n_steps)
    else:
        raise ValueError(f"unknown impl: {impl}")

    m = m[:, :n_orig, :e_orig]
    if lane_mask is not None:
        # Partial-batch masking: frozen lanes return their input state
        # bit-identically (idle serving slots don't drift).
        m = jnp.where(lane_mask[None, None, :], m, m0)
    return m


def sto_rk4_integrate(
    m0: jnp.ndarray,  # (..., N, 3) user layout
    w_cp: jnp.ndarray,  # (N, N)
    params_vec: jnp.ndarray,  # (NP, E) packed (kernels/ref.pack_params)
    dt: float,
    n_steps: int,
    impl: str = "auto",
    n_inner: int = 8,
    block_n: int = LANE,
    block_e: int = LANE,
    interpret: bool = False,
) -> jnp.ndarray:
    """Integrate n_steps of coupled-STO RK4 with the chosen implementation.

    Returns the final state in user layout. n_steps must be divisible by
    n_inner for the fused path (auto-adjusted otherwise). Like the planes
    entry point, impl="auto" is resolved eagerly against the dispatch table.
    """
    batch_shape = m0.shape[:-2]
    e = 1
    for s in batch_shape:
        e *= int(s)
    if impl == "auto":
        impl = choose_impl(m0.shape[-2], e, m0.dtype.itemsize)
    m = _integrate_planes_jit(
        to_planes(m0), w_cp, params_vec, None, None,
        dt=dt, n_steps=n_steps, impl=impl, n_inner=n_inner,
        block_n=block_n, block_e=block_e, interpret=interpret,
    )
    return from_planes(m, batch_shape)


def sto_rk4_step(
    m0: jnp.ndarray,
    w_cp: jnp.ndarray,
    params: STOParams,
    dt: float,
    impl: str = "auto",
    interpret: bool = False,
    block_n: int = LANE,
    block_e: int = LANE,
) -> jnp.ndarray:
    """Single RK4 step convenience wrapper taking STOParams directly."""
    e = 1
    for s in m0.shape[:-2]:
        e *= s
    pv = kref.pack_params(params, e, dtype=m0.dtype)
    return sto_rk4_integrate(
        m0, w_cp, pv, dt, 1,
        impl=impl, n_inner=1, block_n=block_n, block_e=block_e, interpret=interpret,
    )
