"""Public jit'd wrappers around the Pallas kernels.

Handles layout conversion ((E, N, 3) user layout <-> (3, N, E) kernel
layout), MXU-alignment padding, and implementation dispatch:

    impl="fused"  VMEM-resident whole-RK4(-multi-step) kernel (small/med N)
    impl="tiled"  per-stage row-tiled kernel (large N)
    impl="ref"    pure-jnp oracle
    impl="auto"   fused while W + state + stages fit the VMEM budget, else tiled

Zero-padding correctness: padded W rows/cols are zero so padded oscillators
receive/contribute no coupling; padded ensemble lanes evolve garbage that is
sliced away on exit; params rows are broadcast into padded lanes so no
division hits uninitialized memory (denominators are 1 + lam*m.p >= 1-lam).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.constants import STOParams
from repro.kernels import ref as kref
from repro.kernels import sto_step

# VMEM budget used by auto-dispatch (bytes); v5e has ~16 MiB per core.
VMEM_BUDGET = 12 * 1024 * 1024
LANE = sto_step.LANE


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def fused_fits_vmem(n: int, block_e: int, itemsize: int = 4) -> bool:
    """W (n^2) + ~8 live (n, block_e) planes per fused step must fit VMEM."""
    need = n * n * itemsize + 8 * n * block_e * itemsize
    return need <= VMEM_BUDGET


def to_planes(m_user: jnp.ndarray) -> jnp.ndarray:
    """(..., N, 3) -> (3, N, E) kernel layout (E = flattened batch, >=1)."""
    if m_user.ndim == 2:
        m_user = m_user[None]
    e = 1
    for s in m_user.shape[:-2]:
        e *= int(s)
    n = m_user.shape[-2]
    flat = m_user.reshape(e, n, 3)
    return jnp.transpose(flat, (2, 1, 0))


def from_planes(m_planes: jnp.ndarray, batch_shape) -> jnp.ndarray:
    """(3, N, E) -> (*batch_shape, N, 3)."""
    e = m_planes.shape[-1]
    out = jnp.transpose(m_planes, (2, 1, 0))  # (E, N, 3)
    return out.reshape(*batch_shape, m_planes.shape[1], 3)


def _pad_planes(m, w, params, block_n, block_e):
    _, n, e = m.shape
    n_p = _round_up(max(n, 1), block_n)
    e_p = _round_up(max(e, 1), block_e)
    if n_p != n or e_p != e:
        m = jnp.pad(m, ((0, 0), (0, n_p - n), (0, e_p - e)))
        w = jnp.pad(w, ((0, n_p - n), (0, n_p - n)))
        # broadcast params into padded lanes (edge mode keeps denominators sane)
        params = jnp.pad(params, ((0, 0), (0, e_p - e)), mode="edge")
    return m, w, params, n, e


@functools.partial(
    jax.jit,
    static_argnames=("dt", "n_steps", "impl", "n_inner", "block_n", "block_e", "interpret"),
)
def sto_rk4_integrate(
    m0: jnp.ndarray,  # (..., N, 3) user layout
    w_cp: jnp.ndarray,  # (N, N)
    params_vec: jnp.ndarray,  # (NP, E) packed (kernels/ref.pack_params)
    dt: float,
    n_steps: int,
    impl: str = "auto",
    n_inner: int = 8,
    block_n: int = LANE,
    block_e: int = LANE,
    interpret: bool = False,
) -> jnp.ndarray:
    """Integrate n_steps of coupled-STO RK4 with the chosen implementation.

    Returns the final state in user layout. n_steps must be divisible by
    n_inner for the fused path (auto-adjusted otherwise).
    """
    batch_shape = m0.shape[:-2]
    m = to_planes(m0)
    m, w, pv, n_orig, e_orig = _pad_planes(m, w_cp, params_vec, block_n, block_e)

    if impl == "auto":
        impl = "fused" if fused_fits_vmem(m.shape[1], block_e, m.dtype.itemsize) else "tiled"

    if impl == "ref":
        def body(mm, _):
            return kref.rk4_step_planes(mm, w, pv, jnp.asarray(dt, m.dtype)), None
        m, _ = jax.lax.scan(body, m, None, length=n_steps)
    elif impl == "fused":
        while n_steps % n_inner != 0:
            n_inner -= 1
        def body(mm, _):
            return (
                sto_step.rk4_fused(
                    mm, w, pv, dt, n_inner=n_inner, block_e=block_e, interpret=interpret
                ),
                None,
            )
        m, _ = jax.lax.scan(body, m, None, length=n_steps // n_inner)
    elif impl == "tiled":
        def body(mm, _):
            return (
                sto_step.rk4_tiled_step(
                    mm, w, pv, dt, block_n=block_n, block_e=block_e, interpret=interpret
                ),
                None,
            )
        m, _ = jax.lax.scan(body, m, None, length=n_steps)
    else:
        raise ValueError(f"unknown impl: {impl}")

    m = m[:, :n_orig, :e_orig]
    return from_planes(m, batch_shape)


def sto_rk4_step(
    m0: jnp.ndarray,
    w_cp: jnp.ndarray,
    params: STOParams,
    dt: float,
    impl: str = "auto",
    interpret: bool = False,
    block_n: int = LANE,
    block_e: int = LANE,
) -> jnp.ndarray:
    """Single RK4 step convenience wrapper taking STOParams directly."""
    e = 1
    for s in m0.shape[:-2]:
        e *= s
    pv = kref.pack_params(params, e, dtype=m0.dtype)
    return sto_rk4_integrate(
        m0, w_cp, pv, dt, 1,
        impl=impl, n_inner=1, block_n=block_n, block_e=block_e, interpret=interpret,
    )
