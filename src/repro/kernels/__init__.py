from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import sto_rk4_integrate, sto_rk4_step
