"""Optimizers (AdamW, Adafactor), LR schedules, clipping, and gradient
compression. No external deps — states are plain pytrees so the checkpoint
and sharding layers treat them like params.

Adafactor (factored second moments) is the default for >=90B-param archs:
Adam states for jamba-398B would need ~4.8 TB (f32 m+v+master) — over a
single v5e-256 pod's 4 TB HBM before activations; factored states cut that
to ~1.6 TB (DESIGN.md §4).
"""

from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


class Optimizer(NamedTuple):
    name: str
    init: Callable  # params -> state
    update: Callable  # (params, grads, state, step) -> (params, state)
    state_shardings: Callable  # (mesh, param_shardings, params) -> shardings


def cosine_schedule(step, base_lr=3e-4, warmup=200, total=10_000, min_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return base_lr * jnp.where(step < warmup, warm, cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm, gnorm=None):
    if gnorm is None:
        gnorm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def make_adamw(lr_fn=cosine_schedule, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
        }

    def update(params, grads, state, step):
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        bc1 = 1.0 - b1**step_f
        bc2 = 1.0 - b2**step_f

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            mhat = mu / bc1
            nhat = nu / bc2
            delta = mhat / (jnp.sqrt(nhat) + eps) + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_mu = tdef.flatten_up_to(state["mu"])
        flat_nu = tdef.flatten_up_to(state["nu"])
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_mu = tdef.unflatten([o[1] for o in out])
        new_nu = tdef.unflatten([o[2] for o in out])
        return new_p, {"mu": new_mu, "nu": new_nu}

    def state_shardings(mesh, param_shardings, params):
        return {"mu": param_shardings, "nu": param_shardings}

    return Optimizer("adamw", init, update, state_shardings)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no first moment)
# ---------------------------------------------------------------------------


def make_adafactor(lr_fn=cosine_schedule, eps=1e-30, clip_thresh=1.0, wd=0.0):
    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def st(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # reduce last
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(st, params, is_leaf=lambda x: hasattr(x, "shape"))

    def update(params, grads, state, step):
        step_f = jnp.asarray(step, jnp.float32) + 1.0
        lr = lr_fn(step)
        beta2 = 1.0 - step_f**-0.8

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                pre = (vr / jnp.maximum(denom, eps))[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(pre, eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            # update clipping (Adafactor's RMS rule)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_thresh)
            delta = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        return tdef.unflatten([o[0] for o in out]), tdef.unflatten(
            [o[1] for o in out]
        )

    def state_shardings(mesh, param_shardings, params):
        def st(sh, p):
            spec = sh.spec
            if _factored(p):
                # vr drops the last dim's axis, vc the second-to-last's
                full = tuple(spec) + (None,) * (p.ndim - len(spec))
                return {
                    "vr": NamedSharding(mesh, P(*full[:-1])),
                    "vc": NamedSharding(mesh, P(*(full[:-2] + full[-1:]))),
                }
            return {"v": sh}

        return jax.tree.map(
            st, param_shardings, params, is_leaf=lambda x: isinstance(x, NamedSharding)
        )

    return Optimizer("adafactor", init, update, state_shardings)


def make_optimizer(name: str, cfg=None, lr_fn=cosine_schedule) -> Optimizer:
    if name == "adamw":
        return make_adamw(lr_fn=lr_fn)
    if name == "adafactor":
        return make_adafactor(lr_fn=lr_fn)
    if name == "sgd":
        return make_sgd(lr_fn=lr_fn)
    raise ValueError(name)


def make_sgd(lr_fn=cosine_schedule, momentum=0.9):
    def init(params):
        return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(params, grads, state, step):
        lr = lr_fn(step)

        def upd(p, g, m):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["mom"])
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return tdef.unflatten([o[0] for o in out]), {
            "mom": tdef.unflatten([o[1] for o in out])
        }

    def state_shardings(mesh, param_shardings, params):
        return {"mom": param_shardings}

    return Optimizer("sgd", init, update, state_shardings)


# ---------------------------------------------------------------------------
# Gradient compression (distributed-optimization trick)
# ---------------------------------------------------------------------------


def make_compressor(kind: str):
    """Per-tensor int8 quantize->dequantize on gradients.

    Numerics-faithful stand-in for compressed DP all-reduce: the *value*
    effect of int8 gradient exchange is applied here; the *byte* effect on
    the wire requires the shard_map reducer in distributed/collectives.py
    (XLA fuses a plain quant-dequant away, it cannot compress the implicit
    pjit all-reduce).
    """
    if kind == "none":
        return lambda g: g
    if kind == "int8":

        def comp(grads):
            def q(g):
                gf = g.astype(jnp.float32)
                scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
                qi = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
                return (qi.astype(jnp.float32) * scale).astype(g.dtype)

            return jax.tree.map(q, grads)

        return comp
    raise ValueError(kind)
