from repro.optim import optimizer
from repro.optim.optimizer import (
    Optimizer,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    make_adafactor,
    make_adamw,
    make_compressor,
    make_optimizer,
    make_sgd,
)
